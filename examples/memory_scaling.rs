//! Interactive tour of the paper's memory result: runs a trimmed Fig. 1 +
//! Fig. 2 sweep and prints the invertible-vs-stored peak-memory tables.
//!
//!     cargo run --release --example memory_scaling
//!
//! Hermetic by default (RefBackend); set INVERTNET_ARTIFACTS with a
//! `--features xla` build to measure through PJRT.

use anyhow::Result;
use invertnet::{bench_figs, Engine};

fn main() -> Result<()> {
    let mut builder = Engine::builder();
    if let Ok(dir) = std::env::var("INVERTNET_ARTIFACTS") {
        builder = builder.artifacts(dir);
    }
    let engine = builder.build()?;
    bench_figs::fig2(&engine, 40.0)?;
    println!();
    bench_figs::fig1(&engine, 40.0)?;
    Ok(())
}
