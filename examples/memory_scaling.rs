//! Interactive tour of the paper's memory result: runs a trimmed Fig. 1 +
//! Fig. 2 sweep and prints the invertible-vs-stored peak-memory tables.
//!
//!     cargo run --release --example memory_scaling

use std::path::PathBuf;

use anyhow::Result;
use invertnet::{bench_figs, Runtime};

fn main() -> Result<()> {
    let rt = Runtime::new(&PathBuf::from("artifacts"))?;
    bench_figs::fig2(&rt, 40.0)?;
    println!();
    bench_figs::fig1(&rt, 40.0)?;
    Ok(())
}
