//! Density estimation on 2-D toy targets with RealNVP — the canonical
//! normalizing-flow demo (paper §1's density-estimation use case).
//!
//!     cargo run --release --example density2d [-- two-moons|eight-gaussians|checkerboard|spiral]
//!
//! Trains (hermetically, on the RefBackend), reports held-out NLL, and
//! writes model samples + a coarse density histogram comparison against
//! the target.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;
use invertnet::coordinator::ExecMode;
use invertnet::data::Density2d;
use invertnet::train::loop_::tail_mean;
use invertnet::train::{train, Adam, GradClip, TrainConfig};
use invertnet::util::rng::Pcg64;
use invertnet::{Engine, InferOpts, SampleOpts, Tensor};

/// 2-D histogram over [-3,3]^2 as a flat row-major grid.
fn hist2d(points: &Tensor, bins: usize) -> Vec<f64> {
    let mut h = vec![0.0f64; bins * bins];
    let n = points.batch();
    for i in 0..n {
        let x = points.data[2 * i];
        let y = points.data[2 * i + 1];
        let bx = (((x + 3.0) / 6.0) * bins as f32).floor();
        let by = (((y + 3.0) / 6.0) * bins as f32).floor();
        if bx >= 0.0 && by >= 0.0 && (bx as usize) < bins && (by as usize) < bins {
            h[by as usize * bins + bx as usize] += 1.0 / n as f64;
        }
    }
    h
}

fn hist_l1(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum()
}

fn main() -> Result<()> {
    let which = std::env::args().nth(1).unwrap_or_else(|| "two-moons".into());
    let density = Density2d::parse(&which)?;
    let steps: usize = std::env::var("DENSITY2D_STEPS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(600);

    let engine = Engine::builder().build()?;
    let flow = engine.flow("realnvp2d")?;
    let mut params = flow.init_params(42)?;
    println!("realnvp2d on {which}: {} params, {} coupling blocks",
             params.param_count(), flow.def.depth() / 2);

    let mut opt = Adam::new(2e-3);
    let cfg = TrainConfig {
        steps,
        schedule: Arc::new(ExecMode::Invertible),
        clip: Some(GradClip { max_norm: 100.0 }),
        log_every: 50,
        out_dir: Some(PathBuf::from(format!("runs/density2d_{which}"))),
        quiet: false,
        ..TrainConfig::default()
    };
    let mut rng = Pcg64::new(9);
    let report = train(&flow, &mut params, &mut opt, &cfg, |_| {
        Ok((density.sample(256, &mut rng), None))
    })?;
    println!("loss {:.4} -> {:.4}", report.losses[0],
             tail_mean(&report.losses, 25));

    // held-out NLL
    let mut eval_rng = Pcg64::new(4242);
    let mut nll = 0.0f64;
    let eval_batches = 8;
    for _ in 0..eval_batches {
        let x = density.sample(256, &mut eval_rng);
        let ll = flow.log_density(&x, &params, InferOpts::strict())?;
        nll -= ll.iter().sum::<f32>() as f64 / ll.len() as f64;
    }
    nll /= eval_batches as f64;
    println!("held-out NLL: {nll:.4} nats (standard-normal baseline ~{:.3})",
             2.0 * 0.5 * (2.0 * std::f64::consts::PI).ln() + 1.0);

    // sample and compare coarse histograms with the target
    let mut smp_rng = Pcg64::new(77);
    let mut samples = Vec::new();
    for _ in 0..16 {
        samples.extend_from_slice(
            &flow.sample(&params,
                         SampleOpts::new(flow.batch(), &mut smp_rng))?.data);
    }
    let model_pts = Tensor::new(vec![16 * 256, 2], samples)?;
    let target_pts = density.sample(16 * 256, &mut eval_rng);
    let (hm, ht) = (hist2d(&model_pts, 12), hist2d(&target_pts, 12));
    let l1 = hist_l1(&hm, &ht);
    println!("12x12 histogram L1 distance model vs target: {l1:.3} \
              (2.0 = disjoint, 0.0 = identical)");
    invertnet::tensor::npy::save(
        &PathBuf::from(format!("runs/density2d_{which}/samples.npy")), &model_pts)?;

    assert!(report.final_loss < report.losses[0], "flow must improve");
    assert!(l1 < 1.2, "model samples too far from target ({l1:.3})");
    Ok(())
}
