//! Quickstart + end-to-end driver: train a multiscale GLOW on synthetic
//! images with the memory-frugal invertible schedule, log the bits/dim
//! curve, check invertibility, and draw samples.
//!
//!     cargo run --release --example quickstart
//!
//! Hermetic by default (RefBackend + builtin catalog); set
//! INVERTNET_ARTIFACTS with a `--features xla` build to run the same
//! workload through PJRT.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;
use invertnet::coordinator::{ActivationSchedule, ExecMode};
use invertnet::data::synth_images;
use invertnet::train::loop_::tail_mean;
use invertnet::train::{train, Adam, GradClip, TrainConfig};
use invertnet::util::bench::fmt_bytes;
use invertnet::util::rng::Pcg64;
use invertnet::{Engine, SampleOpts};

const LN2: f32 = std::f32::consts::LN_2;

fn main() -> Result<()> {
    let steps: usize = std::env::var("QUICKSTART_STEPS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(300);

    let mut builder = Engine::builder();
    if let Ok(dir) = std::env::var("INVERTNET_ARTIFACTS") {
        builder = builder.artifacts(dir);
    }
    let engine = builder.build()?;
    let flow = engine.flow("glow16")?;
    let mut params = flow.init_params(42)?;
    let dims = flow.def.dims_per_sample() as f32;
    println!(
        "glow16 ({} backend): {} params, depth {}, input {:?}, latents {:?}",
        flow.backend_name(), params.param_count(), flow.def.depth(),
        flow.def.in_shape, flow.def.latent_shapes
    );

    // pre-training invertibility check (the library's CI guarantee)
    let mut rng = Pcg64::new(7);
    let s = &flow.def.in_shape;
    let x0 = synth_images(s[0], s[1], s[2], s[3], &mut rng);
    let rt_err = flow.roundtrip_error(&x0, None, &params)?;
    println!("roundtrip |x - inv(fwd(x))|_inf = {rt_err:.2e}");
    assert!(rt_err < 2e-3);

    let mut opt = Adam::new(1e-3);
    // QUICKSTART_THREADS=N shards each minibatch across N workers
    // (deterministic reduction — same losses as the single-threaded run)
    let cfg = TrainConfig {
        steps,
        schedule: Arc::new(ExecMode::Invertible),
        clip: Some(GradClip { max_norm: 200.0 }),
        log_every: 20,
        out_dir: Some(PathBuf::from("runs/quickstart")),
        quiet: false,
        threads: std::env::var("QUICKSTART_THREADS")
            .ok().and_then(|s| s.parse().ok()).unwrap_or(1),
        ..TrainConfig::default()
    };
    let mut data_rng = Pcg64::new(1234);
    let in_shape = flow.def.in_shape.clone();
    let report = train(&flow, &mut params, &mut opt, &cfg, move |_| {
        Ok((synth_images(in_shape[0], in_shape[1], in_shape[2], in_shape[3],
                         &mut data_rng), None))
    })?;

    // NLL in bits/dim (the standard flow metric)
    let bpd = |loss: f32| loss / dims / LN2;
    println!(
        "loss: {:.1} -> {:.1}  ({:.3} -> {:.3} bits/dim)",
        report.losses[0], report.final_loss,
        bpd(report.losses[0]), bpd(report.final_loss)
    );
    println!(
        "peak scheduling memory {}  ({:.1} steps/s, schedule={})",
        fmt_bytes(report.peak_sched_bytes as u64),
        report.steps_per_sec, cfg.schedule.label()
    );
    assert!(
        tail_mean(&report.losses, 20) < report.losses[0],
        "training must reduce NLL"
    );

    // draw a batch of samples from the trained model
    let samples = flow.sample(&params,
                              SampleOpts::new(flow.batch(), &mut rng))?;
    invertnet::tensor::npy::save(
        &PathBuf::from("runs/quickstart/samples.npy"), &samples)?;
    println!("samples -> runs/quickstart/samples.npy  {:?}", samples.shape);
    println!("metrics -> runs/quickstart/metrics.csv");
    Ok(())
}
