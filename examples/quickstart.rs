//! Quickstart + end-to-end driver: train a multiscale GLOW on synthetic
//! images with the memory-frugal invertible executor, log the bits/dim
//! curve, check invertibility, and draw samples.
//!
//!     cargo run --release --example quickstart
//!
//! This is the EXPERIMENTS.md §E2E run: all three layers compose (Pallas
//! kernels -> JAX layer programs -> rust coordinator) on a real training
//! workload.

use std::path::PathBuf;

use anyhow::Result;
use invertnet::coordinator::{ExecMode, FlowSession};
use invertnet::data::synth_images;
use invertnet::flow::ParamStore;
use invertnet::train::loop_::tail_mean;
use invertnet::train::{train, Adam, GradClip, TrainConfig};
use invertnet::util::bench::fmt_bytes;
use invertnet::util::rng::Pcg64;
use invertnet::{MemoryLedger, Runtime};

const LN2: f32 = std::f32::consts::LN_2;

fn main() -> Result<()> {
    let artifacts = PathBuf::from(
        std::env::var("INVERTNET_ARTIFACTS").unwrap_or_else(|_| "artifacts".into()));
    let steps: usize = std::env::var("QUICKSTART_STEPS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(300);

    let rt = Runtime::new(&artifacts)?;
    let ledger = MemoryLedger::new();
    let session = FlowSession::new(&rt, "glow16", ledger.clone())?;
    let mut params = ParamStore::init(&session.def, &rt.manifest, 42)?;
    let dims = session.def.dims_per_sample() as f32;
    println!(
        "glow16: {} params, depth {}, input {:?}, latents {:?}",
        params.param_count(), session.def.depth(),
        session.def.in_shape, session.def.latent_shapes
    );

    // pre-training invertibility check (the library's CI guarantee)
    let mut rng = Pcg64::new(7);
    let s = &session.def.in_shape;
    let x0 = synth_images(s[0], s[1], s[2], s[3], &mut rng);
    let rt_err = session.roundtrip_error(&x0, None, &params)?;
    println!("roundtrip |x - inv(fwd(x))|_inf = {rt_err:.2e}");
    assert!(rt_err < 2e-3);

    let mut opt = Adam::new(1e-3);
    let cfg = TrainConfig {
        steps,
        mode: ExecMode::Invertible,
        clip: Some(GradClip { max_norm: 200.0 }),
        log_every: 20,
        out_dir: Some(PathBuf::from("runs/quickstart")),
        quiet: false,
    };
    let mut data_rng = Pcg64::new(1234);
    let in_shape = session.def.in_shape.clone();
    let report = train(&session, &mut params, &mut opt, &cfg, move |_| {
        Ok((synth_images(in_shape[0], in_shape[1], in_shape[2], in_shape[3],
                         &mut data_rng), None))
    })?;

    // NLL in bits/dim (the standard flow metric)
    let bpd = |loss: f32| loss / dims / LN2;
    println!(
        "loss: {:.1} -> {:.1}  ({:.3} -> {:.3} bits/dim)",
        report.losses[0], report.final_loss,
        bpd(report.losses[0]), bpd(report.final_loss)
    );
    println!(
        "peak scheduling memory {}  ({:.1} steps/s, mode={})",
        fmt_bytes(report.peak_sched_bytes as u64),
        report.steps_per_sec, cfg.mode.name()
    );
    assert!(
        tail_mean(&report.losses, 20) < report.losses[0],
        "training must reduce NLL"
    );

    // draw a batch of samples from the trained model
    let samples = session.sample(&params, None, &mut rng)?;
    invertnet::tensor::npy::save(
        &PathBuf::from("runs/quickstart/samples.npy"), &samples)?;
    println!("samples -> runs/quickstart/samples.npy  {:?}", samples.shape);
    println!("metrics -> runs/quickstart/metrics.csv");
    Ok(())
}
