//! Amortized Bayesian inference with a conditional flow (paper §4 — the
//! BayesFlow / amortized-VI use case that motivates dcond support).
//!
//! Task: linear-Gaussian inverse problem y = A theta + eps with a
//! closed-form Gaussian posterior. A conditional RealNVP trained on
//! (theta, y) simulations should, for a fixed observation y*, transport
//! N(0, I) to p(theta | y*). We validate the amortized posterior's mean
//! and covariance against the analytic answer.
//!
//!     cargo run --release --example amortized_inference

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::Result;
use invertnet::coordinator::ExecMode;
use invertnet::data::LinearGaussian;
use invertnet::train::{train, Adam, GradClip, TrainConfig};
use invertnet::util::rng::Pcg64;
use invertnet::{Engine, SampleOpts, Tensor};

fn mean_cov(points: &Tensor) -> ([f64; 2], [[f64; 2]; 2]) {
    let n = points.batch();
    let mut mu = [0.0f64; 2];
    for i in 0..n {
        mu[0] += points.data[2 * i] as f64;
        mu[1] += points.data[2 * i + 1] as f64;
    }
    mu[0] /= n as f64;
    mu[1] /= n as f64;
    let mut cov = [[0.0f64; 2]; 2];
    for i in 0..n {
        let d0 = points.data[2 * i] as f64 - mu[0];
        let d1 = points.data[2 * i + 1] as f64 - mu[1];
        cov[0][0] += d0 * d0;
        cov[0][1] += d0 * d1;
        cov[1][0] += d1 * d0;
        cov[1][1] += d1 * d1;
    }
    for r in &mut cov {
        for v in r.iter_mut() {
            *v /= (n - 1) as f64;
        }
    }
    (mu, cov)
}

fn main() -> Result<()> {
    let steps: usize = std::env::var("AMORTIZED_STEPS")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(800);
    let engine = Engine::builder().build()?;
    let flow = engine.flow("cond_realnvp2d")?;
    let mut params = flow.init_params(42)?;
    let prob = LinearGaussian::default_problem();
    println!("amortized posterior p(theta|y), y = A theta + eps: \
              {} params", params.param_count());

    let mut opt = Adam::new(2e-3);
    let cfg = TrainConfig {
        steps,
        schedule: Arc::new(ExecMode::Invertible),
        clip: Some(GradClip { max_norm: 100.0 }),
        log_every: 100,
        out_dir: Some(PathBuf::from("runs/amortized")),
        quiet: false,
        ..TrainConfig::default()
    };
    let mut rng = Pcg64::new(5);
    let report = train(&flow, &mut params, &mut opt, &cfg, |_| {
        let (theta, y) = prob.sample(256, &mut rng);
        Ok((theta, Some(y)))
    })?;
    println!("amortized NLL {:.4} -> {:.4}", report.losses[0], report.final_loss);

    // ---- validate against the analytic posterior for two observations ----
    let mut worst_mu = 0.0f64;
    let mut worst_cov = 0.0f64;
    for y_obs in [[0.8f64, -0.5], [-1.2, 0.6]] {
        let (mu_true, cov_true) = prob.posterior(y_obs);
        // repeat y* across the conditioning batch, sample many batches
        let cond = Tensor::new(
            vec![256, 2],
            (0..256).flat_map(|_| [y_obs[0] as f32, y_obs[1] as f32]).collect(),
        )?;
        let mut smp_rng = Pcg64::new(31);
        let mut all = Vec::new();
        for _ in 0..32 {
            all.extend_from_slice(
                &flow.sample(&params,
                             SampleOpts::new(256, &mut smp_rng)
                                 .cond(&cond))?.data);
        }
        let pts = Tensor::new(vec![32 * 256, 2], all)?;
        let (mu, cov) = mean_cov(&pts);
        println!("y* = {y_obs:?}");
        println!("  posterior mean: flow [{:+.3}, {:+.3}]  analytic [{:+.3}, {:+.3}]",
                 mu[0], mu[1], mu_true[0], mu_true[1]);
        println!("  posterior cov:  flow [{:.3} {:.3}; {:.3} {:.3}]  \
                  analytic [{:.3} {:.3}; {:.3} {:.3}]",
                 cov[0][0], cov[0][1], cov[1][0], cov[1][1],
                 cov_true[0][0], cov_true[0][1], cov_true[1][0], cov_true[1][1]);
        for i in 0..2 {
            worst_mu = worst_mu.max((mu[i] - mu_true[i]).abs());
            for j in 0..2 {
                worst_cov = worst_cov.max((cov[i][j] - cov_true[i][j]).abs());
            }
        }
    }
    println!("worst |mu error| = {worst_mu:.3}, worst |cov error| = {worst_cov:.3}");
    assert!(worst_mu < 0.25, "posterior mean off by {worst_mu}");
    assert!(worst_cov < 0.25, "posterior covariance off by {worst_cov}");
    println!("amortized posterior matches the analytic linear-Gaussian answer");
    Ok(())
}
