"""Numpy mirror of the Rust telemetry histogram (rust/src/telemetry/registry.rs).

The Rust side keeps 65 log2 buckets: bucket 0 holds exact zeros and bucket
i >= 1 holds values v with 2^(i-1) <= v < 2^i. Quantiles walk the bucket
counts to the rank ceil(q*n) (clamped to [1, n]) and interpolate linearly
inside the owning bucket. These tests mirror that arithmetic bit-for-bit
and pin the same constants the Rust unit tests pin, so a drift on either
side breaks one of the two suites.
"""

import importlib.util
import pathlib

import numpy as np
import pytest

NBUCKETS = 65


def _load_ci_smoke():
    """Import scripts/ci_smoke.py (not a package) by path."""
    root = pathlib.Path(__file__).resolve().parents[2]
    spec = importlib.util.spec_from_file_location(
        "ci_smoke", root / "scripts" / "ci_smoke.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


ci_smoke = _load_ci_smoke()


def bucket_of(v):
    """Bucket index of a recorded u64: its bit width (0 for 0)."""
    return int(v).bit_length()


def bucket_upper(i):
    """Inclusive upper bound of bucket i."""
    if i == 0:
        return 0
    if i == 64:
        return 2**64 - 1
    return (1 << i) - 1


def hist_record(buckets, values):
    for v in values:
        buckets[bucket_of(v)] += 1


def hist_quantile(buckets, count, q):
    """Mirror of HistSnapshot::quantile: rank-walk + linear interpolation."""
    if count == 0:
        return 0.0
    target = min(max(np.ceil(q * count), 1.0), float(count))
    before = 0
    for i in range(NBUCKETS):
        c = buckets[i]
        if c == 0:
            continue
        if before + c >= target:
            if i == 0:
                return 0.0
            lo = 2.0 ** (i - 1)
            hi = 2.0**i - 1.0
            frac = (target - before) / c
            return lo + frac * (hi - lo)
        before += c
    return float(bucket_upper(NBUCKETS - 1))


def hist_quantile_u64(buckets, count, q):
    # Rust rounds half away from zero (f64::round); values are
    # non-negative here so floor(x + 0.5) matches.
    return int(np.floor(hist_quantile(buckets, count, q) + 0.5))


def test_bucket_boundaries_match_the_rust_pins():
    # the exact table from registry.rs::bucket_index_pins
    for v, idx in [
        (0, 0),
        (1, 1),
        (2, 2),
        (3, 2),
        (4, 3),
        (7, 3),
        (8, 4),
        (1023, 10),
        (1024, 11),
        (2**64 - 1, 64),
    ]:
        assert bucket_of(v) == idx, f"bucket_of({v})"
        if idx > 0:
            assert v > bucket_upper(idx - 1)
        assert v <= bucket_upper(idx)


def test_every_bucket_edge_is_consistent():
    # 2^(i-1) and 2^i - 1 both land in bucket i; 2^i opens bucket i+1
    for i in range(1, 63):
        lo, hi = 1 << (i - 1), (1 << i) - 1
        assert bucket_of(lo) == i
        assert bucket_of(hi) == i
        assert bucket_of(hi + 1) == i + 1
        assert bucket_upper(i) == hi


def test_quantile_pins_match_the_rust_unit_test():
    # values 1..=8: p50 interpolates to 4.75 inside bucket [4,7]; the
    # wire (rounded) form is 5; p99's rank-8 sample owns bucket [8,15]
    buckets = np.zeros(NBUCKETS, dtype=np.int64)
    values = np.arange(1, 9)
    hist_record(buckets, values)
    assert buckets.sum() == 8
    assert values.sum() == 36  # the _sum cell the exposition carries
    assert hist_quantile(buckets, 8, 0.50) == 4.75
    assert hist_quantile_u64(buckets, 8, 0.50) == 5
    assert hist_quantile(buckets, 8, 0.99) == 15.0
    assert hist_quantile(buckets, 8, 0.0) == 1.0
    assert hist_quantile(np.zeros(NBUCKETS, dtype=np.int64), 0, 0.5) == 0.0


def test_quantiles_bound_the_true_order_statistic():
    # the bucketed estimate can never leave the owning bucket of the true
    # rank statistic: estimate in [2^(i-1), 2^i - 1] for the rank's bucket
    rng = np.random.default_rng(7)
    values = rng.integers(0, 1_000_000, size=500)
    buckets = np.zeros(NBUCKETS, dtype=np.int64)
    hist_record(buckets, values)
    ordered = np.sort(values)
    for q in (0.5, 0.9, 0.99, 0.999):
        rank = int(min(max(np.ceil(q * len(values)), 1), len(values)))
        true_stat = int(ordered[rank - 1])
        est = hist_quantile(buckets, len(values), q)
        i = bucket_of(true_stat)
        lo = 0.0 if i == 0 else 2.0 ** (i - 1)
        assert lo <= est <= float(bucket_upper(i)), (
            f"q={q}: estimate {est} left bucket {i} of true {true_stat}"
        )


def test_merged_histograms_answer_the_pooled_quantile():
    # mirror of registry.rs::merged_snapshots_answer_the_pooled_quantile:
    # merging is bucket-count addition, exact wrt the bucketing
    a = np.zeros(NBUCKETS, dtype=np.int64)
    b = np.zeros(NBUCKETS, dtype=np.int64)
    hist_record(a, [1, 2, 3, 4])
    hist_record(b, [100, 200, 300, 400])
    merged = a + b
    pooled = np.zeros(NBUCKETS, dtype=np.int64)
    hist_record(pooled, [1, 2, 3, 4, 100, 200, 300, 400])
    assert (merged == pooled).all()
    assert hist_quantile(merged, 8, 0.99) > 256.0


def test_exposition_parser_accepts_the_rendered_shape():
    # the exact shape rust/src/telemetry/encode.rs::render produces
    text = (
        '# TYPE demo_gauge gauge\n'
        'demo_gauge -1.5\n'
        '# TYPE demo_lat_us histogram\n'
        'demo_lat_us_bucket{le="0"} 0\n'
        'demo_lat_us_bucket{le="1"} 1\n'
        'demo_lat_us_bucket{le="3"} 3\n'
        'demo_lat_us_bucket{le="+Inf"} 4\n'
        'demo_lat_us_sum 6\n'
        'demo_lat_us_count 4\n'
        '# TYPE demo_total counter\n'
        'demo_total 42\n'
    )
    fams = ci_smoke.parse_exposition(text)
    assert fams == {"demo_gauge": "gauge", "demo_lat_us": "histogram",
                    "demo_total": "counter"}
    labeled = (
        '# TYPE invertnet_serve_model_requests_total counter\n'
        'invertnet_serve_model_requests_total{model="realnvp2d"} 2\n'
        'invertnet_serve_model_requests_total{model="glow16"} 1\n'
    )
    assert ci_smoke.parse_exposition(labeled) == {
        "invertnet_serve_model_requests_total": "counter"}


# each case mirrors a pinned rejection in the Rust strict parser
# (rust/tests/telemetry.rs::exposition_parser_rejects_malformed_inputs
# _with_pinned_messages) — the two readers must reject the same shapes
MALFORMED_EXPOSITIONS = [
    ("truncated-bucket-line",
     '# TYPE h histogram\nh_bucket{le="1"\n',
     "sample line has no value"),
    ("unparsable-bucket-bound",
     '# TYPE h histogram\nh_bucket{le="one"} 1\n'
     'h_bucket{le="+Inf"} 1\nh_sum 1\nh_count 1\n',
     "malformed bucket line"),
    ("non-cumulative-le-counts",
     '# TYPE h histogram\nh_bucket{le="1"} 5\nh_bucket{le="2"} 3\n'
     'h_bucket{le="+Inf"} 5\nh_sum 9\nh_count 5\n',
     "non-cumulative bucket counts"),
    ("bucket-bounds-out-of-order",
     '# TYPE h histogram\nh_bucket{le="2"} 1\nh_bucket{le="1"} 2\n'
     'h_bucket{le="+Inf"} 2\nh_sum 3\nh_count 2\n',
     "bucket bounds out of order"),
    ("count-disagrees-with-inf-bucket",
     '# TYPE h histogram\nh_bucket{le="1"} 2\n'
     'h_bucket{le="+Inf"} 2\nh_sum 2\nh_count 3\n',
     "disagrees"),
    ("missing-sum",
     '# TYPE h histogram\nh_bucket{le="1"} 1\n'
     'h_bucket{le="+Inf"} 1\nh_count 1\n',
     "_sum or _count"),
    ("missing-inf-bucket",
     '# TYPE h histogram\nh_bucket{le="1"} 1\nh_sum 1\nh_count 1\n',
     'le="+Inf"'),
    ("nan-sample-value",
     '# TYPE c counter\nc NaN\n',
     "NaN sample value"),
    ("infinite-counter",
     '# TYPE c counter\nc Inf\n',
     "non-finite counter value"),
    ("negative-counter",
     '# TYPE c counter\nc -4\n',
     "negative counter value"),
    ("negative-bucket-count",
     '# TYPE h histogram\nh_bucket{le="1"} -1\n'
     'h_bucket{le="+Inf"} 1\nh_sum 1\nh_count 1\n',
     "negative or non-finite bucket count"),
    ("sample-before-type",
     'c 4\n',
     "sample before any TYPE line"),
    ("duplicate-family",
     '# TYPE c counter\nc 1\n# TYPE c counter\nc 2\n',
     "duplicate family"),
    ("duplicate-series",
     '# TYPE c counter\nc 1\nc 2\n',
     "duplicate series"),
    ("stray-sample",
     '# TYPE c counter\nc 1\nd 2\n',
     "does not belong to family"),
    ("family-without-samples",
     '# TYPE c counter\n',
     "no samples"),
    ("empty-exposition",
     '',
     "no metric families found"),
    ("bucket-after-inf",
     '# TYPE h histogram\nh_bucket{le="+Inf"} 1\nh_bucket{le="2"} 1\n'
     'h_sum 1\nh_count 1\n',
     'bucket after the le="+Inf" bucket'),
    ("duplicate-inf-bucket",
     '# TYPE h histogram\nh_bucket{le="+Inf"} 1\nh_bucket{le="+Inf"} 1\n'
     'h_sum 1\nh_count 1\n',
     'duplicate le="+Inf" bucket'),
]


@pytest.mark.parametrize(
    "text,needle",
    [case[1:] for case in MALFORMED_EXPOSITIONS],
    ids=[case[0] for case in MALFORMED_EXPOSITIONS])
def test_exposition_parser_rejects_malformed_inputs(text, needle):
    with pytest.raises(AssertionError) as exc:
        ci_smoke.parse_exposition(text)
    assert needle in str(exc.value), (
        f"rejection {exc.value!r} does not mention {needle!r}")
