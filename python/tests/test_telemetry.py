"""Numpy mirror of the Rust telemetry histogram (rust/src/telemetry/registry.rs).

The Rust side keeps 65 log2 buckets: bucket 0 holds exact zeros and bucket
i >= 1 holds values v with 2^(i-1) <= v < 2^i. Quantiles walk the bucket
counts to the rank ceil(q*n) (clamped to [1, n]) and interpolate linearly
inside the owning bucket. These tests mirror that arithmetic bit-for-bit
and pin the same constants the Rust unit tests pin, so a drift on either
side breaks one of the two suites.
"""

import numpy as np

NBUCKETS = 65


def bucket_of(v):
    """Bucket index of a recorded u64: its bit width (0 for 0)."""
    return int(v).bit_length()


def bucket_upper(i):
    """Inclusive upper bound of bucket i."""
    if i == 0:
        return 0
    if i == 64:
        return 2**64 - 1
    return (1 << i) - 1


def hist_record(buckets, values):
    for v in values:
        buckets[bucket_of(v)] += 1


def hist_quantile(buckets, count, q):
    """Mirror of HistSnapshot::quantile: rank-walk + linear interpolation."""
    if count == 0:
        return 0.0
    target = min(max(np.ceil(q * count), 1.0), float(count))
    before = 0
    for i in range(NBUCKETS):
        c = buckets[i]
        if c == 0:
            continue
        if before + c >= target:
            if i == 0:
                return 0.0
            lo = 2.0 ** (i - 1)
            hi = 2.0**i - 1.0
            frac = (target - before) / c
            return lo + frac * (hi - lo)
        before += c
    return float(bucket_upper(NBUCKETS - 1))


def hist_quantile_u64(buckets, count, q):
    # Rust rounds half away from zero (f64::round); values are
    # non-negative here so floor(x + 0.5) matches.
    return int(np.floor(hist_quantile(buckets, count, q) + 0.5))


def test_bucket_boundaries_match_the_rust_pins():
    # the exact table from registry.rs::bucket_index_pins
    for v, idx in [
        (0, 0),
        (1, 1),
        (2, 2),
        (3, 2),
        (4, 3),
        (7, 3),
        (8, 4),
        (1023, 10),
        (1024, 11),
        (2**64 - 1, 64),
    ]:
        assert bucket_of(v) == idx, f"bucket_of({v})"
        if idx > 0:
            assert v > bucket_upper(idx - 1)
        assert v <= bucket_upper(idx)


def test_every_bucket_edge_is_consistent():
    # 2^(i-1) and 2^i - 1 both land in bucket i; 2^i opens bucket i+1
    for i in range(1, 63):
        lo, hi = 1 << (i - 1), (1 << i) - 1
        assert bucket_of(lo) == i
        assert bucket_of(hi) == i
        assert bucket_of(hi + 1) == i + 1
        assert bucket_upper(i) == hi


def test_quantile_pins_match_the_rust_unit_test():
    # values 1..=8: p50 interpolates to 4.75 inside bucket [4,7]; the
    # wire (rounded) form is 5; p99's rank-8 sample owns bucket [8,15]
    buckets = np.zeros(NBUCKETS, dtype=np.int64)
    values = np.arange(1, 9)
    hist_record(buckets, values)
    assert buckets.sum() == 8
    assert values.sum() == 36  # the _sum cell the exposition carries
    assert hist_quantile(buckets, 8, 0.50) == 4.75
    assert hist_quantile_u64(buckets, 8, 0.50) == 5
    assert hist_quantile(buckets, 8, 0.99) == 15.0
    assert hist_quantile(buckets, 8, 0.0) == 1.0
    assert hist_quantile(np.zeros(NBUCKETS, dtype=np.int64), 0, 0.5) == 0.0


def test_quantiles_bound_the_true_order_statistic():
    # the bucketed estimate can never leave the owning bucket of the true
    # rank statistic: estimate in [2^(i-1), 2^i - 1] for the rank's bucket
    rng = np.random.default_rng(7)
    values = rng.integers(0, 1_000_000, size=500)
    buckets = np.zeros(NBUCKETS, dtype=np.int64)
    hist_record(buckets, values)
    ordered = np.sort(values)
    for q in (0.5, 0.9, 0.99, 0.999):
        rank = int(min(max(np.ceil(q * len(values)), 1), len(values)))
        true_stat = int(ordered[rank - 1])
        est = hist_quantile(buckets, len(values), q)
        i = bucket_of(true_stat)
        lo = 0.0 if i == 0 else 2.0 ** (i - 1)
        assert lo <= est <= float(bucket_upper(i)), (
            f"q={q}: estimate {est} left bucket {i} of true {true_stat}"
        )


def test_merged_histograms_answer_the_pooled_quantile():
    # mirror of registry.rs::merged_snapshots_answer_the_pooled_quantile:
    # merging is bucket-count addition, exact wrt the bucketing
    a = np.zeros(NBUCKETS, dtype=np.int64)
    b = np.zeros(NBUCKETS, dtype=np.int64)
    hist_record(a, [1, 2, 3, 4])
    hist_record(b, [100, 200, 300, 400])
    merged = a + b
    pooled = np.zeros(NBUCKETS, dtype=np.int64)
    hist_record(pooled, [1, 2, 3, 4, 100, 200, 300, 400])
    assert (merged == pooled).all()
    assert hist_quantile(merged, 8, 0.99) > 256.0
