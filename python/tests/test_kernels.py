"""L1 Pallas kernels vs the pure-jnp oracle (ref.py), with hypothesis
sweeping shapes and batch sizes. This is the CORE correctness signal for
the compute layer."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import (actnorm, affine_core, conv1x1, dense_core, haar,
                             hyperbolic, ref)

TOL = dict(rtol=2e-5, atol=1e-5)
FAST = settings(max_examples=12, deadline=None)


def _img(rng, n, h, w, c):
    return jnp.asarray(rng.normal(size=(n, h, w, c)).astype(np.float32))


img_dims = st.tuples(
    st.integers(1, 3),                                # n
    st.sampled_from([2, 4, 6]),                       # h (even for haar)
    st.sampled_from([2, 4, 8]),                       # w
    st.integers(1, 5),                                # c
)


@FAST
@given(dims=img_dims, seed=st.integers(0, 2**31 - 1))
def test_actnorm_matches_ref(dims, seed):
    rng = np.random.default_rng(seed)
    x = _img(rng, *dims)
    c = dims[3]
    log_s = jnp.asarray(rng.normal(size=(c,)).astype(np.float32) * 0.3)
    b = jnp.asarray(rng.normal(size=(c,)).astype(np.float32))
    y_k, ld_k = actnorm.actnorm_forward(x, log_s, b)
    y_r, ld_r = ref.actnorm_forward(x, log_s, b)
    np.testing.assert_allclose(y_k, y_r, **TOL)
    np.testing.assert_allclose(ld_k, ld_r, **TOL)
    np.testing.assert_allclose(actnorm.actnorm_inverse(y_k, log_s, b), x, **TOL)


@FAST
@given(dims=img_dims, seed=st.integers(0, 2**31 - 1))
def test_conv1x1_matches_ref(dims, seed):
    rng = np.random.default_rng(seed)
    x = _img(rng, *dims)
    c = dims[3]
    vs = [jnp.asarray(rng.normal(size=(c,)).astype(np.float32)) for _ in range(3)]
    w = ref.householder_matrix(vs)
    y = conv1x1.conv1x1_apply(x, w)
    y_r, ld_r = ref.conv1x1_forward(x, *vs)
    np.testing.assert_allclose(y, y_r, **TOL)
    np.testing.assert_allclose(ld_r, np.zeros(dims[0]), **TOL)
    np.testing.assert_allclose(conv1x1.conv1x1_unapply(y, w), x,
                               rtol=1e-4, atol=1e-4)


@FAST
@given(dims=img_dims, seed=st.integers(0, 2**31 - 1))
def test_affine_core_matches_ref(dims, seed):
    rng = np.random.default_rng(seed)
    x2 = _img(rng, *dims)
    raw = _img(rng, *dims)
    t = _img(rng, *dims)
    y_k, ld_k = affine_core.affine_core_forward(x2, raw, t)
    y_r, ld_r = ref.affine_core_forward(x2, raw, t)
    np.testing.assert_allclose(y_k, y_r, **TOL)
    np.testing.assert_allclose(ld_k, ld_r, **TOL)
    np.testing.assert_allclose(affine_core.affine_core_inverse(y_k, raw, t),
                               x2, rtol=1e-4, atol=1e-4)


@FAST
@given(dims=img_dims, seed=st.integers(0, 2**31 - 1))
def test_haar_matches_ref_and_roundtrips(dims, seed):
    rng = np.random.default_rng(seed)
    x = _img(rng, *dims)
    y_k, _ = haar.haar_forward(x)
    y_r, _ = ref.haar_forward(x)
    np.testing.assert_allclose(y_k, y_r, **TOL)
    np.testing.assert_allclose(haar.haar_inverse(y_k), x, **TOL)
    np.testing.assert_allclose(ref.haar_inverse(y_r), x, **TOL)


def test_haar_is_orthonormal(rng):
    """Haar preserves inner products (orthonormal basis => logdet 0)."""
    x = _img(rng, 2, 4, 4, 3)
    y, _ = haar.haar_forward(x)
    np.testing.assert_allclose(np.sum(np.asarray(x) ** 2),
                               np.sum(np.asarray(y) ** 2), rtol=1e-5)


@FAST
@given(dims=img_dims, seed=st.integers(0, 2**31 - 1))
def test_hyperbolic_core_matches_ref(dims, seed):
    rng = np.random.default_rng(seed)
    xp, xc, act = (_img(rng, *dims) for _ in range(3))
    yp_k, yc_k = hyperbolic.hyperbolic_core_forward(xp, xc, act)
    yp_r, yc_r = ref.hyperbolic_core_forward(xp, xc, act)
    np.testing.assert_allclose(yp_k, yp_r, **TOL)
    np.testing.assert_allclose(yc_k, yc_r, **TOL)
    # roundtrip with act evaluated at x_curr == y_prev
    xp2, xc2 = hyperbolic.hyperbolic_core_inverse(yp_k, yc_k, act)
    np.testing.assert_allclose(xc2, xc, **TOL)
    np.testing.assert_allclose(xp2, xp, **TOL)


@FAST
@given(n=st.integers(1, 200), d=st.integers(1, 9),
       seed=st.integers(0, 2**31 - 1))
def test_dense_core_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    x2, raw, t = (jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
                  for _ in range(3))
    y_k, ld_k = dense_core.dense_core_forward(x2, raw, t)
    y_r, ld_r = ref.affine_core_forward(x2, raw, t)
    np.testing.assert_allclose(y_k, y_r, **TOL)
    np.testing.assert_allclose(ld_k, ld_r, **TOL)
    np.testing.assert_allclose(dense_core.dense_core_inverse(y_k, raw, t), x2,
                               rtol=1e-4, atol=1e-4)


def test_gaussian_logp_matches_scipy_form(rng):
    z = jnp.asarray(rng.normal(size=(4, 5)).astype(np.float32))
    lp = ref.gaussian_logp(z)
    want = -0.5 * np.sum(np.asarray(z) ** 2, axis=1) \
        - 0.5 * 5 * np.log(2 * np.pi)
    np.testing.assert_allclose(lp, want, rtol=1e-5)
