"""Independent mirror of the Rust static cost model (rust/src/analysis/cost.rs).

This file reimplements, from the canonical op-count table alone, the
per-layer arithmetic/bytes cost model and the per-schedule training and
inference totals for the builtin example networks plus the large-image
catalog nets (glow64, hint64deep) — without reading any Rust. Both
implementations are pinned against the committed fixture
``data/cost_model_pins.json`` (all 8 nets x 3 schedules), so the Rust
model and this mirror can never drift apart silently: a change on either
side breaks its pin until the fixture is regenerated *and the other side
agrees*.

Regenerate the fixture (after a deliberate model change on both sides):

    python3 python/tests/test_cost_model.py

The canonical table (1 MAC = 2 flops, elementwise = 1 flop/element,
SAME 3x3 convs counted with clipped border taps, conditioner VJP = 3x
its apply) is documented in full in rust/src/analysis/cost.rs. Bytes
additionally price the vectorized kernels' packed-GEMM panel traffic:
every GEMM weight matrix W (k x m) is repacked into 8-wide column
panels once per entry call (k * ceil8(m) elements written); fwd and inv
pack once, vjp_stored twice (recompute + dx; the scalar order-pinned dW
kernel never packs).
"""

import json
import os

BYTES_PER_ELEM = 4
HINT_MIN_D = 4
FIXTURE = os.path.join(os.path.dirname(__file__), "data",
                       "cost_model_pins.json")

# --------------------------------------------------------------------------
# kernel helpers
# --------------------------------------------------------------------------


def taps(x, k):
    """Clipped-border tap count of a SAME conv along one length-x axis."""
    return x if k == 1 else max(3 * x - 2, 1)


def conv_macs(n, h, w, ci, co, k):
    return n * taps(h, k) * taps(w, k) * ci * co


def conv_flops(n, h, w, ci, co, k):
    return 2 * conv_macs(n, h, w, ci, co, k) + n * h * w * co


def cnn_flops(n, h, w, ci, hid, co):
    """conv3 -> relu -> conv1 -> relu -> conv3, biases included."""
    return (conv_flops(n, h, w, ci, hid, 3) + n * h * w * hid
            + conv_flops(n, h, w, hid, hid, 1) + n * h * w * hid
            + conv_flops(n, h, w, hid, co, 3))


def lin_flops(n, a, b):
    return 2 * n * a * b + n * b


def mlp_flops(n, din, hid, dout):
    """lin -> relu -> lin -> relu -> lin, biases included."""
    return (lin_flops(n, din, hid) + n * hid
            + lin_flops(n, hid, hid) + n * hid
            + lin_flops(n, hid, dout))


def hint_nodes(d, depth):
    """Preorder (d1, d2) conditioner nodes of a HINT layer."""
    out = []

    def rec(d, depth):
        if depth == 0 or d < HINT_MIN_D:
            return
        d1, d2 = d // 2, d - d // 2
        out.append((d1, d2))
        rec(d1, depth - 1)
        rec(d2, depth - 1)

    rec(d, depth)
    return out


# --------------------------------------------------------------------------
# layer programs for the six builtin example nets
# --------------------------------------------------------------------------
# A step is a dict: kind, in_shape, out_shape, plus kind-specific cfg
# (hidden, depth, dcond) and params (scalar parameter count).


def numel(shape):
    p = 1
    for d in shape:
        p *= d
    return p


def cnn_params(ci, hid, co):
    return 9 * ci * hid + hid + hid * hid + hid + 9 * hid * co + co


def mlp_params(din, hid, dout):
    return din * hid + hid + hid * hid + hid + hid * dout + dout


def step(kind, in_shape, out_shape=None, **extra):
    s = {"kind": kind, "in_shape": in_shape,
         "out_shape": out_shape or list(in_shape)}
    s.update(extra)
    return s


def l_actnorm(n, h, w, c):
    return step("actnorm", [n, h, w, c], params=2 * c)


def l_conv1x1(n, h, w, c):
    return step("conv1x1", [n, h, w, c], params=3 * c)


def l_glowcpl(n, h, w, c, hidden):
    c1, c2 = c // 2, c - c // 2
    return step("glowcpl", [n, h, w, c], hidden=hidden,
                params=cnn_params(c1, hidden, 2 * c2))


def l_addcpl(n, h, w, c, hidden):
    c1, c2 = c // 2, c - c // 2
    return step("addcpl", [n, h, w, c], hidden=hidden,
                params=cnn_params(c1, hidden, c2))


def l_haar(n, h, w, c):
    return step("haar", [n, h, w, c], [n, h // 2, w // 2, 4 * c], params=0)


def l_permute(shape):
    return step("permute", list(shape), params=0)


def l_densecpl(n, d, hidden):
    d1, d2 = d // 2, d - d // 2
    return step("densecpl", [n, d], hidden=hidden,
                params=mlp_params(d1, hidden, 2 * d2))


def l_condcpl(n, d, dcond, hidden):
    d1, d2 = d // 2, d - d // 2
    return step("condcpl", [n, d], hidden=hidden, dcond=dcond,
                params=mlp_params(d1 + dcond, hidden, 2 * d2))


def l_hyper(n, h, w, c, hidden):
    return step("hyper", [n, h, w, c], hidden=hidden,
                params=9 * (c // 2) * hidden)


def l_hint(n, d, hidden, depth):
    p = sum(mlp_params(d1, hidden, 2 * d2)
            for d1, d2 in hint_nodes(d, depth))
    return step("hint", [n, d], hidden=hidden, depth=depth, params=p)


def l_split(n, h, w, c):
    zc = c // 2
    return step("split", [n, h, w, c], [n, h, w, c - zc], zc=zc, params=0)


def realnvp_dense(n, d, k, hidden):
    steps = []
    for _ in range(k):
        steps += [l_densecpl(n, d, hidden), l_permute([n, d])]
    return steps


def cond_realnvp_dense(n, d, dcond, k, hidden):
    steps = []
    for _ in range(k):
        steps += [l_condcpl(n, d, dcond, hidden), l_permute([n, d])]
    return steps


def hint_dense(n, d, k, hidden, depth):
    steps = []
    for _ in range(k):
        steps += [l_hint(n, d, hidden, depth), l_permute([n, d])]
    return steps


def glow_multiscale(n, h, w, c_in, scales, k, hidden):
    steps = []
    ch, hh, ww = c_in, h, w
    for s in range(scales):
        steps.append(l_haar(n, hh, ww, ch))
        ch, hh, ww = 4 * ch, hh // 2, ww // 2
        for _ in range(k):
            steps += [l_actnorm(n, hh, ww, ch), l_conv1x1(n, hh, ww, ch),
                      l_glowcpl(n, hh, ww, ch, hidden)]
        if s != scales - 1:
            steps.append(l_split(n, hh, ww, ch))
            ch -= ch // 2
    return steps


def hyperbolic_net(n, h, w, c_in, k, hidden):
    steps = [l_haar(n, h, w, c_in)]
    for _ in range(k):
        steps.append(l_hyper(n, h // 2, w // 2, 4 * c_in, hidden))
    return steps


def nice_net(n, h, w, c_in, k, hidden):
    steps = [l_haar(n, h, w, c_in)]
    c, h2, w2 = 4 * c_in, h // 2, w // 2
    for _ in range(k):
        steps += [l_addcpl(n, h2, w2, c, hidden),
                  l_permute([n, h2, w2, c])]
    return steps


EXAMPLE_NETS = {
    "realnvp2d": realnvp_dense(256, 2, 8, 64),
    "cond_realnvp2d": cond_realnvp_dense(256, 2, 2, 8, 64),
    "hint8d": hint_dense(256, 8, 4, 64, 2),
    "glow16": glow_multiscale(16, 16, 16, 3, 2, 4, 32),
    "hyper16": hyperbolic_net(16, 16, 16, 3, 6, 12),
    "nice16": nice_net(16, 16, 16, 3, 4, 32),
    # large-image catalog nets (vectorized-kernel showcase)
    "glow64": glow_multiscale(4, 64, 64, 3, 3, 12, 64),
    "hint64deep": hint_dense(64, 64, 4, 128, 4),
}


def latent_shapes(steps):
    """Split z-shapes in push order, then the final activation."""
    shapes = []
    for s in steps:
        if s["kind"] == "split":
            z = list(s["in_shape"])
            z[-1] = s["zc"]
            shapes.append(z)
    shapes.append(list(steps[-1]["out_shape"]))
    return shapes


# --------------------------------------------------------------------------
# the cost model proper
# --------------------------------------------------------------------------


def layer_flops(s):
    """(fwd, inv, vjp_stored) arithmetic ops of one layer step."""
    kind, shape = s["kind"], s["in_shape"]
    e, n, c = numel(shape), shape[0], shape[-1]
    if kind == "actnorm":
        return 2 * e + 2 * c + n, 2 * e + c, 3 * e + 2 * c
    if kind == "conv1x1":
        r = e // c
        build = 6 * c * c + 6 * c
        return (build + 2 * r * c * c + n, build + 2 * r * c * c,
                12 * c * c * c + 4 * r * c * c)
    if kind in ("glowcpl", "addcpl"):
        h, w = shape[1], shape[2]
        c1, c2 = c // 2, c - c // 2
        p2 = n * h * w * c2
        if kind == "glowcpl":
            g = cnn_flops(n, h, w, c1, s["hidden"], 2 * c2)
            return g + 8 * p2 + n, g + 6 * p2 + n, 3 * g + 10 * p2 + n
        g = cnn_flops(n, h, w, c1, s["hidden"], c2)
        return g + p2 + n, g + p2 + n, 3 * g + p2
    if kind in ("densecpl", "condcpl"):
        d = shape[1]
        d1, d2 = d // 2, d - d // 2
        g = mlp_flops(n, d1 + s.get("dcond", 0), s["hidden"], 2 * d2)
        return (g + 8 * n * d2 + n, g + 6 * n * d2 + n,
                3 * g + 10 * n * d2 + n)
    if kind == "haar":
        return 4 * e, 4 * e, 4 * e
    if kind == "permute":
        return 0, 0, 0
    if kind == "hyper":
        h, w = shape[1], shape[2]
        g = 2 * conv_macs(n, h, w, c // 2, s["hidden"], 3) + n * h * w * s["hidden"]
        pc = n * h * w * c
        return 2 * g + pc + n, 2 * g + pc + n, 6 * g + 2 * pc
    if kind == "hint":
        f = i = n
        v = n
        for d1, d2 in hint_nodes(shape[1], s["depth"]):
            g = mlp_flops(n, d1, s["hidden"], 2 * d2)
            f += g + 8 * n * d2
            i += g + 6 * n * d2
            v += 3 * g + 10 * n * d2
        return f, i, v
    raise ValueError(f"no cost model for kind {kind!r}")


def ceil8(m):
    """GEMM column count rounded up to the kernels' 8-wide panel."""
    return (m + 7) // 8 * 8


def cnn_pack(ci, hid, co):
    return 9 * ci * ceil8(hid) + hid * ceil8(hid) + 9 * hid * ceil8(co)


def mlp_pack(din, hid, dout):
    return din * ceil8(hid) + hid * ceil8(hid) + hid * ceil8(dout)


def pack_elems(s):
    """Elements written into 8-wide GEMM panels per entry call."""
    kind = s["kind"]
    c = s["in_shape"][-1]
    if kind in ("actnorm", "haar", "permute", "split"):
        return 0
    if kind == "conv1x1":
        return c * ceil8(c)
    if kind == "glowcpl":
        c1, c2 = c // 2, c - c // 2
        return cnn_pack(c1, s["hidden"], 2 * c2)
    if kind == "addcpl":
        c1, c2 = c // 2, c - c // 2
        return cnn_pack(c1, s["hidden"], c2)
    if kind in ("densecpl", "condcpl"):
        d = s["in_shape"][1]
        d1, d2 = d // 2, d - d // 2
        return mlp_pack(d1 + s.get("dcond", 0), s["hidden"], 2 * d2)
    if kind == "hyper":
        return 9 * (c // 2) * ceil8(s["hidden"])
    if kind == "hint":
        return sum(mlp_pack(d1, s["hidden"], 2 * d2)
                   for d1, d2 in hint_nodes(s["in_shape"][1], s["depth"]))
    raise ValueError(f"no pack model for kind {kind!r}")


def layer_bytes(s):
    """(fwd, inv, vjp_stored) bytes moved — the kind-agnostic protocol
    plus the packed-GEMM panel traffic (1x fwd/inv, 2x vjp_stored)."""
    e_in, e_out = numel(s["in_shape"]), numel(s["out_shape"])
    n = s["in_shape"][0]
    params = s["params"]
    e_cond = n * s.get("dcond", 0)
    b = BYTES_PER_ELEM
    pack = pack_elems(s)
    return (b * (e_in + e_out + n + params + e_cond + pack),
            b * (e_in + e_out + params + e_cond + pack),
            b * (2 * e_in + e_out + 2 * params + e_cond + 2 * pack))


def entry_costs(s):
    """{fwd, inv, vjp_stored, vjp} as (flops, bytes) pairs."""
    ff, fi, fv = layer_flops(s)
    bf, bi, bv = layer_bytes(s)
    return {"fwd": (ff, bf), "inv": (fi, bi), "vjp_stored": (fv, bv),
            "vjp": (fi + fv, bi + bv)}


def split_cost(s):
    return 0, 2 * BYTES_PER_ELEM * numel(s["in_shape"])


def logp_cost(shape):
    n = shape[0]
    k = numel(shape) // n
    return 2 * n * k + 2 * n, BYTES_PER_ELEM * (n * k + n)


def nll_seed_cost(shape):
    n = shape[0]
    k = numel(shape) // n
    return n * k + n, BYTES_PER_ELEM * (2 * n * k + n)


def taped_pattern(steps, schedule):
    """Which steps a schedule stores, mirroring the executor's walk."""
    n_layers = sum(1 for s in steps if s["kind"] != "split")
    taped = []
    ord_ = 0
    for s in steps:
        if s["kind"] == "split":
            taped.append(False)
            continue
        if schedule == "invertible":
            t = False
        elif schedule == "stored":
            t = True
        elif schedule.startswith("checkpoint_every_"):
            k = max(int(schedule.rsplit("_", 1)[1]), 1)
            t = ord_ % k == 0
        else:
            raise ValueError(schedule)
        taped.append(t)
        ord_ += 1
    del n_layers
    return taped


def add(a, b):
    return a[0] + b[0], a[1] + b[1]


def train_cost(steps, schedule):
    """One training step: forward + heads + the scheduled backward."""
    taped = taped_pattern(steps, schedule)
    total = (0, 0)
    for s in steps:
        total = add(total, split_cost(s) if s["kind"] == "split"
                    else entry_costs(s)["fwd"])
    for z in latent_shapes(steps):
        total = add(total, logp_cost(z))
        total = add(total, nll_seed_cost(z))
    for s, t in zip(reversed(steps), reversed(taped)):
        if s["kind"] == "split":
            total = add(total, split_cost(s))
        else:
            total = add(total, entry_costs(s)["vjp_stored" if t else "vjp"])
    return total


def inference_cost(steps):
    total = (0, 0)
    for s in steps:
        total = add(total, split_cost(s) if s["kind"] == "split"
                    else entry_costs(s)["fwd"])
    for z in latent_shapes(steps):
        total = add(total, logp_cost(z))
    return total


def sample_cost(steps):
    total = (0, 0)
    for s in reversed(steps):
        total = add(total, split_cost(s) if s["kind"] == "split"
                    else entry_costs(s)["inv"])
    return total


SCHEDULES = ("invertible", "stored", "checkpoint_every_4")


def compute_pins():
    doc = {"schema": "invertnet-cost-pins/v1", "networks": {}}
    for name, steps in EXAMPLE_NETS.items():
        entry = {}
        for sched in SCHEDULES:
            flops, byt = train_cost(steps, sched)
            entry[sched] = {"train_flops": flops, "train_bytes": byt}
        flops, byt = inference_cost(steps)
        entry["inference_flops"] = flops
        entry["inference_bytes"] = byt
        flops, byt = sample_cost(steps)
        entry["sample_flops"] = flops
        entry["sample_bytes"] = byt
        doc["networks"][name] = entry
    return doc


# --------------------------------------------------------------------------
# tests
# --------------------------------------------------------------------------


def load_fixture():
    with open(FIXTURE) as fh:
        return json.load(fh)


def test_fixture_matches_this_mirror_exactly():
    assert load_fixture() == compute_pins(), (
        "cost model drifted from the committed fixture; if the change is "
        "deliberate, regenerate with `python3 python/tests/test_cost_model.py` "
        "and make sure rust/tests/analysis.rs cost pins still pass")


def test_fixture_covers_all_nets_and_schedules():
    doc = load_fixture()
    assert set(doc["networks"]) == set(EXAMPLE_NETS)
    for name, entry in doc["networks"].items():
        for sched in SCHEDULES:
            assert entry[sched]["train_flops"] > 0, (name, sched)
            assert entry[sched]["train_bytes"] > 0, (name, sched)
        assert entry["inference_flops"] > 0, name
        assert entry["sample_flops"] > 0, name


def test_recompute_ordering_invariants():
    # invertible recomputes everything: strictly more expensive than
    # stored; checkpointing lands in between (or equals an endpoint for
    # very shallow nets); inference is always cheaper than training
    for name, steps in EXAMPLE_NETS.items():
        inv, _ = train_cost(steps, "invertible")
        sto, _ = train_cost(steps, "stored")
        mid, _ = train_cost(steps, "checkpoint_every_4")
        assert sto < inv, name
        assert sto <= mid <= inv, (name, sto, mid, inv)
        assert inference_cost(steps)[0] < sto, name


def test_hint_nodes_shape():
    assert hint_nodes(8, 2) == [(4, 4), (2, 2), (2, 2)]
    assert hint_nodes(2, 5) == []


if __name__ == "__main__":
    doc = compute_pins()
    os.makedirs(os.path.dirname(FIXTURE), exist_ok=True)
    with open(FIXTURE, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    print(f"wrote {FIXTURE}")
    for name, entry in sorted(doc["networks"].items()):
        row = ", ".join(f"{s}={entry[s]['train_flops']}" for s in SCHEDULES)
        print(f"  {name}: {row}")
