"""Numpy mirror of the Rust kernel-equivalence suite (rust/tests/kernels.rs).

The Rust side pins its vectorized GEMM / im2col / conv kernels against
scalar references; this file pins the *same mathematical contracts*
against an independent numpy implementation, so a shared misconception
(e.g. a wrong SAME-padding convention baked into both the fast kernel and
its scalar reference) cannot survive:

  * im2col lowering of a stride-1 SAME conv, followed by a plain GEMM,
    equals the direct convolution — including odd channel counts and
    non-multiple-of-8 row/column tails;
  * bf16 round-to-nearest-even storage rounding (the exact bit
    manipulation `backend::math::half::f32_to_bf16` uses) obeys the
    2^-8 relative-error contract and is idempotent;
  * f16 storage rounding matches numpy's IEEE binary16 cast bit-for-bit
    and obeys the 2^-11 relative-error contract over the normal range.
"""

import numpy as np


# -- reference conv / im2col (mirrors rust/src/backend/math.rs) -----------

def conv2d_same(x, w):
    """Direct stride-1 SAME conv: x (n,h,w,ci), w (kh,kw,ci,co) -> NHWC."""
    n, h, wd, ci = x.shape
    kh, kw, wci, co = w.shape
    assert ci == wci
    ph, pw = kh // 2, kw // 2
    out = np.zeros((n, h, wd, co), dtype=np.float32)
    for di in range(kh):
        for dj in range(kw):
            lo_i, hi_i = max(0, ph - di), min(h, h + ph - di)
            lo_j, hi_j = max(0, pw - dj), min(wd, wd + pw - dj)
            xs = x[:, lo_i - ph + di:hi_i - ph + di,
                   lo_j - pw + dj:hi_j - pw + dj, :]
            out[:, lo_i:hi_i, lo_j:hi_j, :] += np.einsum(
                "nhwc,co->nhwo", xs, w[di, dj], dtype=np.float32,
            ).astype(np.float32)
    return out


def im2col_same(x, kh, kw):
    """(n,h,w,ci) -> (n*h*w, kh*kw*ci) patch matrix, zero-padded SAME."""
    n, h, wd, ci = x.shape
    ph, pw = kh // 2, kw // 2
    xp = np.pad(x, ((0, 0), (ph, ph), (pw, pw), (0, 0)))
    rows = np.empty((n, h, wd, kh, kw, ci), dtype=np.float32)
    for di in range(kh):
        for dj in range(kw):
            rows[:, :, :, di, dj, :] = xp[:, di:di + h, dj:dj + wd, :]
    return rows.reshape(n * h * wd, kh * kw * ci)


CONV_SHAPES = [
    (1, 1, 1, 1, 1),
    (2, 4, 5, 3, 4),
    (1, 3, 3, 7, 9),
    (2, 2, 6, 5, 8),
    (1, 8, 8, 12, 64),  # the glow64 coupling shape, scaled down
    (3, 5, 7, 2, 13),
]


def test_im2col_gemm_equals_direct_conv(rng):
    for n, h, w, ci, co in CONV_SHAPES:
        x = rng.normal(size=(n, h, w, ci)).astype(np.float32)
        wt = rng.normal(size=(3, 3, ci, co)).astype(np.float32)
        lowered = im2col_same(x, 3, 3) @ wt.reshape(9 * ci, co)
        direct = conv2d_same(x, wt)
        np.testing.assert_allclose(
            lowered.reshape(n, h, w, co), direct, rtol=2e-5, atol=1e-5,
            err_msg=f"shape ({n},{h},{w},{ci},{co})")


def test_conv_1x1_is_a_pointwise_gemm(rng):
    n, h, w, ci, co = 2, 5, 3, 4, 6
    x = rng.normal(size=(n, h, w, ci)).astype(np.float32)
    w1 = rng.normal(size=(1, 1, ci, co)).astype(np.float32)
    pointwise = x.reshape(-1, ci) @ w1.reshape(ci, co)
    np.testing.assert_allclose(
        pointwise.reshape(n, h, w, co), conv2d_same(x, w1),
        rtol=2e-5, atol=1e-5)


def test_conv_identity_kernel_is_identity(rng):
    x = rng.normal(size=(2, 3, 3, 2)).astype(np.float32)
    w = np.eye(2, dtype=np.float32).reshape(1, 1, 2, 2)
    np.testing.assert_allclose(conv2d_same(x, w), x, rtol=1e-6, atol=1e-6)


def test_conv_all_ones_kernel_sums_the_neighborhood():
    # hand-computed pin shared with the Rust unit test: 2x2 image,
    # 3x3 ones kernel, SAME padding -> every output is the full sum
    x = np.array([1.0, 2.0, 3.0, 4.0], np.float32).reshape(1, 2, 2, 1)
    w = np.ones((3, 3, 1, 1), np.float32)
    np.testing.assert_array_equal(conv2d_same(x, w).ravel(),
                                  [10.0, 10.0, 10.0, 10.0])


# -- half-precision storage rounding (mirrors math::half) ------------------

def round_bf16(x):
    """f32 -> bf16 -> f32, round-to-nearest-even: the exact bit
    manipulation the Rust side applies at weight load."""
    bits = np.asarray(x, np.float32).view(np.uint32)
    rounded = (bits + (((bits >> 16) & 1) + 0x7FFF)) & 0xFFFF0000
    return rounded.view(np.float32)


def test_bf16_roundtrip_error_bound(rng):
    v = rng.normal(size=4096).astype(np.float32)
    r = round_bf16(v)
    np.testing.assert_array_less(
        np.abs(r - v), np.abs(v) * (1 / 256) + np.finfo(np.float32).tiny)


def test_bf16_rounding_is_idempotent_and_ties_to_even(rng):
    v = rng.normal(size=256).astype(np.float32)
    r = round_bf16(v)
    np.testing.assert_array_equal(r, round_bf16(r))
    # exact halfway case rounds to the even bf16 neighbour: with a 7-bit
    # mantissa the bf16 step in [1, 2) is 2^-7, so 1 + 2^-8 sits exactly
    # between bf16(1.0) (even) and bf16(1 + 2^-7) (odd)
    halfway = np.float32(1.0 + 2.0 ** -8)
    assert round_bf16(halfway) == np.float32(1.0)
    # just above halfway rounds up
    above = np.float32(1.0 + 2.0 ** -8 + 2.0 ** -16)
    assert round_bf16(above) == np.float32(1.0 + 2.0 ** -7)


def test_f16_roundtrip_matches_numpy_ieee_cast(rng):
    # normals, subnormal-range values, overflow-range values
    v = np.concatenate([
        rng.normal(size=2048),
        rng.normal(size=64) * 1e-6,
        rng.normal(size=64) * 1e5,
    ]).astype(np.float32)
    with np.errstate(over="ignore"):  # overflow-to-inf is the point
        r = v.astype(np.float16).astype(np.float32)
    # the contract the Rust converter promises (and kernels.rs checks on
    # its side): <= 2^-11 relative over the normal range
    normal = (np.abs(v) >= 2.0 ** -14) & (np.abs(v) <= 65504.0)
    np.testing.assert_array_less(
        np.abs(r[normal] - v[normal]), np.abs(v[normal]) * (1 / 2048) + 1e-30)
    # overflow saturates to inf, in IEEE and in the mirror alike
    assert np.all(np.isinf(r[np.abs(v) > 65520.0]))
