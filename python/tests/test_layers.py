"""L2 layer contracts, for every layer type:

1. invertibility:  inverse(forward(x)) == x
2. hand-written backward == jax.vjp of forward (dx and every dparam)
3. backward's recomputed x == the true input
4. backward_stored agrees with backward
5. logdet == slogdet of the dense Jacobian (small shapes)

These are exactly the CI guarantees the paper advertises (§4).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model

TOL = dict(rtol=2e-3, atol=2e-3)


def _rand_params(inst, rng, scale=0.4):
    out = []
    for name, shape in inst.param_specs():
        out.append(jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale))
    return out


def _rand(shape, rng):
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


LAYERS = [
    model.L_actnorm(2, 4, 4, 3),
    model.L_conv1x1(2, 4, 4, 5),
    model.L_glowcpl(2, 4, 4, 6, hidden=8),
    model.L_addcpl(2, 4, 4, 6, hidden=8),
    model.L_haar(2, 4, 4, 3),
    model.L_permute((2, 4, 4, 6)),
    model.L_permute((3, 5)),
    model.L_densecpl(3, 6, hidden=16),
    model.L_condcpl(3, 6, 4, hidden=16),
    model.L_hyper(2, 4, 4, 6, hidden=4),
    model.L_hint(3, 8, hidden=16, depth=2),
    model.L_hint(3, 9, hidden=8, depth=3),  # odd dims + deeper recursion
]

IDS = [inst.sig for inst in LAYERS]


@pytest.fixture(params=LAYERS, ids=IDS)
def layer(request):
    return request.param


def _setup(layer, rng):
    ent = layer.entries()
    theta = _rand_params(layer, rng)
    x = _rand(layer.in_shape, rng)
    cond = _rand(layer.cond_shape, rng) if layer.cond_shape else None
    args = [x] + ([cond] if cond is not None else [])
    return ent, theta, x, cond, args


def test_invertibility(layer, rng):
    ent, theta, x, cond, args = _setup(layer, rng)
    fwd, _ = ent["forward"]
    inv, _ = ent["inverse"]
    y, logdet = fwd(*args, *theta)
    inv_args = [y] + ([cond] if cond is not None else [])
    (x_rec,) = inv(*inv_args, *theta)
    np.testing.assert_allclose(x_rec, x, **TOL)
    assert logdet.shape == (layer.in_shape[0],)


def test_backward_matches_vjp(layer, rng):
    ent, theta, x, cond, args = _setup(layer, rng)
    fwd, _ = ent["forward"]
    bwd, _ = ent["backward"]

    (y, logdet), vjp_fn = jax.vjp(lambda *a: fwd(*a), *args, *theta)
    n = layer.in_shape[0]
    dy = _rand(y.shape, rng)
    dld = _rand((n,), rng)
    want = vjp_fn((dy, dld))

    bwd_args = [dy, dld, y] + ([cond] if cond is not None else [])
    got = bwd(*bwd_args, *theta)
    # got = (dx, [dcond,] *dtheta, x)
    np.testing.assert_allclose(got[0], want[0], **TOL)
    k = 1
    if cond is not None:
        np.testing.assert_allclose(got[1], want[1], **TOL)
        k = 2
    for g, w in zip(got[k:-1], want[k:]):
        np.testing.assert_allclose(g, w, **TOL)
    # recomputed input
    np.testing.assert_allclose(got[-1], x, **TOL)


def test_backward_stored_agrees(layer, rng):
    ent, theta, x, cond, args = _setup(layer, rng)
    fwd, _ = ent["forward"]
    bwd, _ = ent["backward"]
    bwds, _ = ent["backward_stored"]
    y, _ = fwd(*args, *theta)
    n = layer.in_shape[0]
    dy = _rand(y.shape, rng)
    dld = _rand((n,), rng)
    extra = [cond] if cond is not None else []
    got_inv = bwd(dy, dld, y, *extra, *theta)
    got_st = bwds(dy, dld, x, *extra, *theta)
    for a, b in zip(got_st, got_inv[:-1]):
        np.testing.assert_allclose(a, b, **TOL)


def test_logdet_matches_dense_jacobian(layer, rng):
    """|det J| via slogdet of the explicit Jacobian, one sample."""
    if layer.in_shape != layer.out_shape:
        pytest.skip("shape-changing layer: Jacobian is orthonormal (haar)")
    ent, theta, x, cond, args = _setup(layer, rng)
    fwd, _ = ent["forward"]

    def flat_fwd(xf):
        xx = xf.reshape((1,) + layer.in_shape[1:])
        a = [xx] + ([cond[:1]] if cond is not None else [])
        # single-sample forward: rebuild args with batch 1
        y, ld = fwd(*a, *theta)
        return y.reshape(-1), ld

    # use batch-1 variant of the layer for the dense Jacobian
    x1 = x[:1].reshape(-1)
    jac = jax.jacfwd(lambda v: flat_fwd(v)[0])(x1)
    _, want = np.linalg.slogdet(np.asarray(jac))
    _, ld = flat_fwd(x1)
    np.testing.assert_allclose(ld[0], want, rtol=5e-3, atol=5e-3)
