"""Numpy mirrors for the rust posterior subsystem's analytic contracts.

Three things are pinned here, numpy-only (no jax import):

1. the PCG64 (XSL-RR 128/64, splitmix-seeded) reference streams that
   ``rust/tests/data_determinism.rs`` asserts — computed with python's
   arbitrary-precision integers, so the two implementations are checked
   against each other through shared constants;
2. ``data::LinearGaussian::posterior``: the hand-rolled 2x2 closed form in
   rust/src/data/mod.rs against numpy's generic linear-algebra solution
   Sigma = inv(A^T A / s^2 + I), mu = Sigma A^T y / s^2;
3. the SBC rank-uniformity + coverage contract behind
   ``posterior::analysis::calibrate``: ranks of theta* among draws from
   the TRUE posterior are uniform, and central credible intervals hit
   nominal coverage — the property the rust oracle test relies on.
"""

import numpy as np

MASK64 = (1 << 64) - 1
MASK128 = (1 << 128) - 1
PCG_MUL = 0x2360ED051FC65DA44385DF649FCCF645

# shared with rust/tests/data_determinism.rs — the same table, verbatim
PCG_STREAMS = {
    0: [0x906D4ECA56ED8AE5, 0xE4A474DC21387F33,
        0x9EFD931A70AE01DD, 0x87A81634D5E319BB],
    1: [0x6D47425BCBABC14D, 0xEC400D71D0B112F5,
        0xB1575561E45B957E, 0x0A47D6678A408530],
    42: [0x1C8A598CB5CDE4DF, 0x370266B610066177,
         0x9C11B2EAD90B8E58, 0x0549FF73553B7CF1],
}


def _splitmix(x):
    x = (x + 0x9E3779B97F4A7C15) & MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & MASK64
    return x ^ (x >> 31)


class Pcg64:
    """Integer-exact mirror of rust/src/util/rng.rs (generation only)."""

    def __init__(self, seed):
        s0 = _splitmix(seed)
        s1 = _splitmix(s0)
        s2 = _splitmix(s1)
        s3 = _splitmix(s2)
        self.state = ((s0 << 64) | s1) & MASK128
        self.inc = (((s2 << 64) | s3) | 1) & MASK128
        self.next_u64()  # the rust constructor burns one output

    def next_u64(self):
        self.state = (self.state * PCG_MUL + self.inc) & MASK128
        rot = (self.state >> 122) & 0x3F
        xsl = ((self.state >> 64) ^ self.state) & MASK64
        return ((xsl >> rot) | (xsl << ((64 - rot) & 63))) & MASK64

    def uniform(self):
        return (self.next_u64() >> 11) * (1.0 / (1 << 53))


def test_pcg64_reference_streams_match_the_pinned_table():
    for seed, want in PCG_STREAMS.items():
        rng = Pcg64(seed)
        got = [rng.next_u64() for _ in want]
        assert got == want, f"seed {seed}: {[hex(v) for v in got]}"


def test_pcg64_uniform_values_match_the_rust_test():
    # rust/tests/data_determinism.rs pins these exact f64s for seed 42;
    # (u >> 11) * 2^-53 is exact, so equality holds bit-for-bit
    rng = Pcg64(42)
    want = [0.11148605046565008, 0.2148803896416438,
            0.6096450637206045, 0.02066036763902257]
    got = [rng.uniform() for _ in want]
    assert got == want


# ---------------------------------------------------------------------------
# LinearGaussian::posterior mirror
# ---------------------------------------------------------------------------

def rust_posterior(a, sigma, y):
    """Literal transcription of LinearGaussian::posterior (2x2 inverse)."""
    s2 = sigma * sigma
    p = [[0.0, 0.0], [0.0, 0.0]]
    for i in range(2):
        for j in range(2):
            for k in range(2):
                p[i][j] += a[k][i] * a[k][j] / s2
        p[i][i] += 1.0
    det = p[0][0] * p[1][1] - p[0][1] * p[1][0]
    cov = [[p[1][1] / det, -p[0][1] / det],
           [-p[1][0] / det, p[0][0] / det]]
    aty = [(a[0][0] * y[0] + a[1][0] * y[1]) / s2,
           (a[0][1] * y[0] + a[1][1] * y[1]) / s2]
    mu = [cov[0][0] * aty[0] + cov[0][1] * aty[1],
          cov[1][0] * aty[0] + cov[1][1] * aty[1]]
    return np.array(mu), np.array(cov)


def numpy_posterior(a, sigma, y):
    a = np.asarray(a, dtype=np.float64)
    prec = a.T @ a / sigma**2 + np.eye(2)
    cov = np.linalg.inv(prec)
    mu = cov @ a.T @ np.asarray(y, dtype=np.float64) / sigma**2
    return mu, cov


def test_linear_gaussian_posterior_matches_numpy_linear_algebra():
    rng = np.random.default_rng(0)
    cases = [([[1.0, 0.6], [0.0, 0.8]], 0.5, [0.7, -0.4])]  # the default
    for _ in range(200):
        a = rng.standard_normal((2, 2))
        # keep A well-conditioned enough that inv() is trustworthy
        if abs(np.linalg.det(a)) < 1e-2:
            continue
        cases.append((a.tolist(), float(0.1 + rng.random()),
                      rng.standard_normal(2).tolist()))
    for a, sigma, y in cases:
        mu_r, cov_r = rust_posterior(a, sigma, y)
        mu_n, cov_n = numpy_posterior(a, sigma, y)
        assert np.allclose(mu_r, mu_n, rtol=1e-10, atol=1e-12), (a, sigma, y)
        assert np.allclose(cov_r, cov_n, rtol=1e-10, atol=1e-12), (a, sigma, y)
        # posterior covariance is symmetric positive definite and smaller
        # than the prior (observing y can only shrink uncertainty)
        assert cov_n[0, 1] == cov_n[1, 0] or np.isclose(cov_n[0, 1],
                                                        cov_n[1, 0])
        assert np.all(np.linalg.eigvalsh(cov_n) > 0)
        assert np.all(np.linalg.eigvalsh(cov_n) <= 1.0 + 1e-9)


# ---------------------------------------------------------------------------
# SBC machinery mirror
# ---------------------------------------------------------------------------

A_DEFAULT = np.array([[1.0, 0.6], [0.0, 0.8]])
SIGMA_DEFAULT = 0.5


def test_sbc_ranks_from_the_true_posterior_are_uniform():
    """The contract rust's calibrate() holds trained flows to: an exactly
    calibrated sampler gives uniform ranks and nominal coverage."""
    rng = np.random.default_rng(99)
    # 127 draws keep the finite-sample coverage bias of the interpolated
    # central interval small (~0.011; it is ~0.028 at 63 draws)
    datasets, draws, bins, level = 256, 127, 8, 0.9
    ranks = np.zeros((2, datasets), dtype=int)
    inside = np.zeros(2)
    for d in range(datasets):
        theta = rng.standard_normal(2)
        y = A_DEFAULT @ theta + rng.standard_normal(2) * SIGMA_DEFAULT
        mu, cov = numpy_posterior(A_DEFAULT, SIGMA_DEFAULT, y)
        draws_ = rng.multivariate_normal(mu, cov, size=draws)
        for dim in range(2):
            ranks[dim, d] = int((draws_[:, dim] < theta[dim]).sum())
            lo, hi = np.quantile(draws_[:, dim],
                                 [(1 - level) / 2, 1 - (1 - level) / 2])
            inside[dim] += lo <= theta[dim] <= hi
    crit = 24.32  # chi2(df=7) upper tail at alpha = 0.001
    for dim in range(2):
        counts = np.bincount(ranks[dim] * bins // (draws + 1),
                             minlength=bins)
        expect = datasets / bins
        chi2 = float(((counts - expect) ** 2 / expect).sum())
        assert chi2 < crit, f"dim {dim}: chi2 {chi2}"
        coverage = inside[dim] / datasets
        assert abs(coverage - level) < 0.08, f"dim {dim}: {coverage}"


def test_wilson_hilferty_crit_matches_tables():
    """Mirror of posterior::analysis::chi2_crit (same approximation)."""

    import math

    def inv_norm(p):
        # bisection on the erf-based normal CDF (no scipy dependency)
        lo, hi = -10.0, 10.0
        for _ in range(200):
            mid = (lo + hi) / 2
            if 0.5 * (1 + math.erf(mid / math.sqrt(2))) < p:
                lo = mid
            else:
                hi = mid
        return (lo + hi) / 2

    def chi2_crit(df, alpha):
        z = inv_norm(1 - alpha)
        t = 1 - 2 / (9 * df) + z * np.sqrt(2 / (9 * df))
        return df * t**3

    # textbook upper-tail values
    assert abs(chi2_crit(7, 0.05) - 14.07) < 0.2
    assert abs(chi2_crit(7, 0.001) - 24.32) < 0.5
    assert abs(chi2_crit(9, 0.05) - 16.92) < 0.2
