import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(1234)


@pytest.fixture(autouse=True, scope="session")
def _ref_backend_for_layer_algebra():
    """test_layers differentiates through layer forwards with jax.vjp, which
    cannot trace interpret-mode pallas_call. Kernel<->ref equivalence is
    pinned by test_kernels (which imports the pallas modules directly), so
    layer-algebra tests run on the ref backend."""
    from compile.kernels import backend
    prev = backend._current
    backend.set_backend("ref")
    yield
    backend.set_backend(prev)
