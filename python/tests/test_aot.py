"""AOT pipeline: lowering produces loadable HLO text with the declared
operand/result ABI, and the manifest is self-consistent."""

import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model


def test_result_and_operand_naming():
    assert aot._operand_names("forward", False, ["a", "b"]) == ["x", "a", "b"]
    assert aot._operand_names("backward", True, ["a"]) == \
        ["dy", "dlogdet", "y", "cond", "a"]
    assert aot._result_names("backward", False, ["a"]) == ["dx", "da", "x"]
    assert aot._result_names("backward_stored", True, ["a"]) == \
        ["dx", "dcond", "da"]
    with pytest.raises(ValueError):
        aot._operand_names("nope", False, [])


def test_lower_entry_writes_hlo_text(tmp_path):
    def fn(x, y):
        return (x @ y + 1.0, jnp.sum(x, axis=1))

    path = str(tmp_path / "t.hlo.txt")
    out_shapes = aot.lower_entry(fn, [(2, 3), (3, 4)], path, force=True)
    assert out_shapes == [[2, 4], [2]]
    text = open(path).read()
    assert text.startswith("HloModule"), text[:60]
    assert "f32[2,4]" in text
    # idempotent: unchanged without force
    mtime = os.path.getmtime(path)
    aot.lower_entry(fn, [(2, 3), (3, 4)], path, force=False)
    assert os.path.getmtime(path) == mtime


def test_build_tiny_manifest(tmp_path):
    out = str(tmp_path / "arts")
    aot.build(out, "realnvp2d", force=False)
    m = json.load(open(os.path.join(out, "manifest.json")))
    assert m["backend"] in ("pallas-interpret", "jnp-ref")  # conftest pins ref
    assert "realnvp2d" in m["networks"]
    assert "realnvp2d" in m["monoliths"]
    net = m["networks"]["realnvp2d"]
    # every referenced layer exists with all four entries on disk
    for sig in net["layers"]:
        layer = m["layers"][sig]
        assert set(layer["entries"]) == \
            {"forward", "inverse", "backward", "backward_stored"}
        for e in layer["entries"].values():
            assert os.path.exists(os.path.join(out, e["file"]))
    # heads exist for every latent shape
    for shape in net["latent_shapes"]:
        tag = "x".join(map(str, shape))
        assert tag in m["heads"]


def test_unknown_net_filter_errors(tmp_path):
    with pytest.raises(SystemExit):
        aot.build(str(tmp_path / "x"), "not-a-network", force=False)
