"""Numerical contract behind rust/src/train/parallel.rs.

The Rust ``ParallelTrainer`` shards a minibatch into microbatches, runs the
per-shard NLL backward pass (whose cotangent seeds scale as 1/shard), and
combines per-shard means with shard-size weights in f64, in microbatch-index
order. These tests pin the two float32 facts that design rests on:

1. scaling a float32 cotangent chain by a power of two is *exact*, so for
   power-of-two shard sizes the per-sample backward signals of the sharded
   walk are bit-identical to the full-batch walk;
2. the only remaining difference — re-associating the final batch sums —
   stays well inside the 1e-5 tolerance the Rust equivalence tests assert.

numpy-only (no jax import) so it runs on any test substrate.
"""

import numpy as np

f32 = np.float32


def _serial_f32_sum(values):
    acc = f32(0.0)
    for v in values:
        acc = f32(acc + f32(v))
    return acc


def test_power_of_two_seed_scaling_is_exact():
    rng = np.random.default_rng(0)
    for _ in range(500):
        seed = f32(rng.standard_normal())
        a = f32(rng.standard_normal())
        b = f32(rng.standard_normal())
        s = f32(abs(rng.standard_normal()) + 0.1)

        def chain(c):
            # the op shapes a VJP cotangent passes through: multiply by
            # forward-derived factors, add such products, divide by a
            # forward value
            c1 = f32(c * a)
            c2 = f32(c1 + f32(c * b))
            c3 = f32(c2 / s)
            return f32(c3 * f32(0.731))

        assert f32(chain(seed) * f32(4.0)) == chain(f32(seed * f32(4.0)))


def test_grouped_f64_reduction_error_is_below_rust_tolerance():
    rng = np.random.default_rng(1)
    worst = 0.0
    for _ in range(200):
        # per-sample gradient contributions with cancellation
        g = (rng.standard_normal(256) * rng.standard_normal(256) * 0.05)
        g = g.astype(f32)
        full = float(_serial_f32_sum(g))
        parts = [_serial_f32_sum(g[lo:lo + 64]) for lo in range(0, 256, 64)]
        grouped = float(f32(np.sum(np.asarray(parts, dtype=np.float64))))
        worst = max(worst, abs(full - grouped) / max(abs(full), 1.0))
    # rust/tests/parallel_train.rs asserts 1e-5 of scale; keep 2x headroom
    assert worst < 5e-6, worst


def test_slot_ordered_reduction_is_completion_order_invariant():
    # Mirror of the Rust scheme: workers deposit (slot_index, result) in
    # whatever order they finish; the reduction then walks slots 0..n.
    # The result must be a pure function of the slot contents — and a
    # completion-ordered f32 reduction (the design rejected) is not.
    rng = np.random.default_rng(2)
    per_slot = [rng.standard_normal(32).astype(f32) for _ in range(8)]
    weight = np.float64(32.0 / 256.0)
    orders = [[0, 1, 2, 3, 4, 5, 6, 7], [7, 3, 1, 0, 2, 6, 5, 4],
              [5, 4, 7, 6, 1, 0, 3, 2]]

    def reduce_like_rust(completion_order):
        slots = [None] * 8
        for j in completion_order:  # workers finish in arbitrary order
            slots[j] = per_slot[j]
        acc = np.zeros(32, dtype=np.float64)
        for j in range(8):  # reduction always walks slot order
            acc += weight * slots[j].astype(np.float64)
        return acc.astype(f32)

    a = reduce_like_rust(orders[0])
    for order in orders[1:]:
        b = reduce_like_rust(order)
        assert np.array_equal(a.view(np.int32), b.view(np.int32))

    # counterpoint: summing in completion order in f32 (no slots, no f64)
    # does depend on the order — which is why the Rust reduction is
    # slot-ordered with f64 accumulators
    def reduce_naive_f32(completion_order):
        acc = np.zeros(32, dtype=f32)
        for j in completion_order:
            acc = (acc + f32(weight) * per_slot[j]).astype(f32)
        return acc
    naive = [reduce_naive_f32(o) for o in orders]
    assert any(not np.array_equal(naive[0].view(np.int32),
                                  n.view(np.int32)) for n in naive[1:])
