"""Model registry + network builder contracts: signatures are stable and
collision-free, shapes chain correctly, manifests are self-consistent."""

import numpy as np
import pytest

from compile import model


def test_default_networks_chain_shapes():
    for net in model.default_networks():
        cur = net.in_shape
        for inst in net.layers:
            assert inst.in_shape == cur, \
                f"{net.name}: {inst.sig} expects {inst.in_shape}, at {cur}"
            cur = inst.out_shape
        # final shape is the last latent
        assert tuple(net.latent_shapes()[-1]) == cur


def test_signatures_unique_per_distinct_config():
    a = model.L_glowcpl(8, 16, 16, 12, hidden=32)
    b = model.L_glowcpl(8, 16, 16, 12, hidden=64)
    c = model.L_glowcpl(8, 32, 32, 12, hidden=32)
    assert len({a.sig, b.sig, c.sig}) == 3


def test_shared_signatures_dedupe():
    nets = [n for n in model.default_networks()
            if n.name.startswith("glow_fig2")]
    insts = model.collect_layer_instances(nets)
    # all fig2 depths share the same 64x64 layer artifacts (+1 haar)
    assert len(insts) == 4, sorted(insts)


def test_multiscale_split_bookkeeping():
    net = next(n for n in model.default_networks() if n.name == "glow16")
    splits = [l for l in net.layers if l.kind == "split"]
    assert len(splits) == 1
    latents = net.latent_shapes()
    assert len(latents) == 2
    # total latent elements == input elements (bijectivity requirement)
    total = sum(int(np.prod(s[1:])) for s in latents)
    assert total == int(np.prod(net.in_shape[1:]))


def test_every_network_conserves_dimension():
    """Change of variables requires latent dim == input dim."""
    for net in model.default_networks():
        total = sum(int(np.prod(s[1:])) for s in net.latent_shapes())
        assert total == int(np.prod(net.in_shape[1:])), net.name


def test_param_specs_have_positive_shapes():
    for net in model.default_networks():
        for inst in net.layers:
            if inst.kind == "split":
                continue
            for name, shape in inst.param_specs():
                assert all(d > 0 for d in shape), (net.name, inst.sig, name)


def test_entries_cover_all_four():
    inst = model.L_glowcpl(2, 4, 4, 6, hidden=8)
    ents = inst.entries()
    assert set(ents) == {"forward", "inverse", "backward", "backward_stored"}


def test_hint_param_count_matches_tree():
    inst = model.L_hint(4, 8, hidden=16, depth=2)
    # d=8: root(4|4), left on 4 (2|2 -> d<4 leaf? d=4 >= MIN_D so node),
    # right likewise => 3 nodes x 6 params
    assert len(inst.param_specs()) == 3 * 6


def test_monolith_nets_exist():
    names = {n.name for n in model.default_networks()}
    for m in model.MONOLITH_NETS:
        assert m in names
