"""AOT lowering: every (layer, entry) and head to HLO *text* artifacts.

HLO text — not `.serialize()` — is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which xla_extension 0.5.1 (the
version the `xla` rust crate binds) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Usage:
    python -m compile.aot --out ../artifacts [--nets all] [--force]

Idempotent: existing .hlo.txt files are kept unless --force; manifest.json
is always rewritten in full.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels.backend import backend_name


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_entry(fn, arg_shapes, path, force):
    """Lower fn at the given f32 arg shapes; return result shapes."""
    specs = [_spec(s) for s in arg_shapes]
    out = jax.eval_shape(fn, *specs)
    out_shapes = [list(o.shape) for o in jax.tree_util.tree_leaves(out)]
    if force or not os.path.exists(path):
        lowered = jax.jit(fn, keep_unused=True).lower(*specs)
        text = to_hlo_text(lowered)
        tmp = path + ".tmp"
        with open(tmp, "w") as f:
            f.write(text)
        os.replace(tmp, path)
        sys.stderr.write(f"  lowered {os.path.basename(path)}\n")
    return out_shapes


def _operand_names(entry, cond, param_names):
    if entry == "forward":
        base = ["x"] + (["cond"] if cond else [])
    elif entry == "inverse":
        base = ["y"] + (["cond"] if cond else [])
    elif entry == "backward":
        base = ["dy", "dlogdet", "y"] + (["cond"] if cond else [])
    elif entry == "backward_stored":
        base = ["dy", "dlogdet", "x"] + (["cond"] if cond else [])
    else:
        raise ValueError(entry)
    return base + list(param_names)


def _result_names(entry, cond, param_names):
    d = [f"d{p}" for p in param_names]
    if entry == "forward":
        return ["y", "logdet"]
    if entry == "inverse":
        return ["x"]
    if entry == "backward":
        return ["dx"] + (["dcond"] if cond else []) + d + ["x"]
    if entry == "backward_stored":
        return ["dx"] + (["dcond"] if cond else []) + d
    raise ValueError(entry)


def build(out_dir, net_filter, force):
    os.makedirs(out_dir, exist_ok=True)
    nets = model.default_networks()
    if net_filter != "all":
        keep = set(net_filter.split(","))
        nets = [n for n in nets if n.name in keep]
        if not nets:
            raise SystemExit(f"no networks match {net_filter!r}")

    manifest = {
        "version": 1,
        "backend": backend_name(),
        "layers": {},
        "heads": {},
        "networks": {},
        "monoliths": {},
    }

    insts = model.collect_layer_instances(nets)
    for sig, inst in sorted(insts.items()):
        param_names = [nm for nm, _ in inst.param_specs()]
        param_shapes = [sh for _, sh in inst.param_specs()]
        ent_manifest = {}
        for entry, (fn, operand_shapes) in inst.entries().items():
            arg_shapes = list(operand_shapes) + list(param_shapes)
            fname = f"{sig}.{entry}.hlo.txt"
            path = os.path.join(out_dir, fname)
            out_shapes = lower_entry(fn, arg_shapes, path, force)
            names_in = _operand_names(entry, inst.cond_shape is not None,
                                      param_names)
            names_out = _result_names(entry, inst.cond_shape is not None,
                                      param_names)
            assert len(names_in) == len(arg_shapes), (sig, entry)
            assert len(names_out) == len(out_shapes), \
                (sig, entry, names_out, out_shapes)
            ent_manifest[entry] = {
                "file": fname,
                "operands": [{"name": n, "shape": list(s)}
                             for n, s in zip(names_in, arg_shapes)],
                "results": [{"name": n, "shape": s}
                            for n, s in zip(names_out, out_shapes)],
            }
        m = inst.manifest_entry()
        m["entries"] = ent_manifest
        manifest["layers"][sig] = m

    # loss heads, one pair per unique latent shape
    for shape in model.head_shapes(nets):
        tag = "x".join(map(str, shape))
        ent_manifest = {}
        for entry, fn in model.HEAD_ENTRIES.items():
            fname = f"head_{tag}.{entry}.hlo.txt"
            path = os.path.join(out_dir, fname)
            out_shapes = lower_entry(fn, [shape], path, force)
            names_out = (["logp"] if entry == "gaussian_logp"
                         else ["dz", "dld"])
            ent_manifest[entry] = {
                "file": fname,
                "operands": [{"name": "z", "shape": list(shape)}],
                "results": [{"name": n, "shape": s}
                            for n, s in zip(names_out, out_shapes)],
            }
        manifest["heads"][tag] = {"shape": list(shape), "entries": ent_manifest}

    # monolithic full-AD ablation programs (ref backend: AD cannot trace
    # interpret-mode pallas, and an AD framework differentiates plain ops)
    from .kernels import backend as kbackend
    for net in nets:
        if net.name not in model.MONOLITH_NETS:
            continue
        prev_backend = kbackend._current
        kbackend.set_backend("ref")
        try:
            step_fn, _ = model.full_vjp_fn(net)
            param_shapes = []
            for inst in net.layers:
                if inst.kind != "split":
                    param_shapes.extend(sh for _, sh in inst.param_specs())
            fname = f"monolith_{net.name}.full_vjp.hlo.txt"
            path = os.path.join(out_dir, fname)
            out_shapes = lower_entry(step_fn,
                                     [list(net.in_shape)] + param_shapes, path,
                                     force)
            manifest.setdefault("monoliths", {})[net.name] = {
                "file": fname,
                "operands": [{"name": "x", "shape": list(net.in_shape)}]
                + [{"name": f"p{i}", "shape": list(sh)}
                   for i, sh in enumerate(param_shapes)],
                "results": [{"name": "loss", "shape": out_shapes[0]}]
                + [{"name": f"dp{i}", "shape": sh}
                   for i, sh in enumerate(out_shapes[1:])],
            }
        finally:
            kbackend.set_backend(prev_backend)

    for net in nets:
        manifest["networks"][net.name] = net.manifest_entry()

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath + ".tmp", "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    os.replace(mpath + ".tmp", mpath)
    n_art = sum(len(m["entries"]) for m in manifest["layers"].values())
    n_art += sum(len(m["entries"]) for m in manifest["heads"].values())
    print(f"manifest: {len(manifest['layers'])} layers, "
          f"{len(manifest['heads'])} heads, {len(manifest['networks'])} "
          f"networks, {n_art} artifacts -> {mpath}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--nets", default="all",
                    help="comma-separated network names, or 'all'")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the artifact file exists")
    args = ap.parse_args()
    build(args.out, args.nets, args.force)


if __name__ == "__main__":
    main()
