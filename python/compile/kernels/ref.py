"""Pure-jnp reference oracles for every L1 Pallas kernel.

These are the ground truth used by pytest (`python/tests/`): each Pallas
kernel in this package must `assert_allclose` against the function of the
same name here, across a hypothesis sweep of shapes/batches.

All image tensors are NHWC float32; dense tensors are (N, D) float32.
`logdet` is always a per-sample vector of shape (N,).
"""

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# ActNorm: per-channel affine y = x * exp(log_s) + b
# ---------------------------------------------------------------------------


def actnorm_forward(x, log_s, b):
    """y = x * exp(log_s) + b, logdet = (H*W) * sum(log_s) per sample."""
    s = jnp.exp(log_s)
    y = x * s + b
    spatial = 1
    for d in x.shape[1:-1]:
        spatial *= d
    logdet = jnp.full((x.shape[0],), spatial * jnp.sum(log_s), dtype=x.dtype)
    return y, logdet


def actnorm_inverse(y, log_s, b):
    return (y - b) * jnp.exp(-log_s)


# ---------------------------------------------------------------------------
# Orthogonal (Householder) 1x1 convolution -- GLOW-style channel mixing.
# W = H(v1) @ H(v2) @ H(v3),  H(v) = I - 2 v v^T / (v^T v).
# Orthogonal => inverse is W^T and log|det| = 0.
# (InvertibleNetworks.jl parameterizes Conv1x1 the same way.)
# ---------------------------------------------------------------------------


def householder_matrix(vs):
    """Product of Householder reflections, one per v in vs."""
    c = vs[0].shape[0]
    w = jnp.eye(c, dtype=vs[0].dtype)
    for v in vs:
        hv = jnp.eye(c, dtype=v.dtype) - 2.0 * jnp.outer(v, v) / jnp.dot(v, v)
        w = w @ hv
    return w


def conv1x1_forward(x, v1, v2, v3):
    """y[..., :] = W x[..., :]; logdet = 0 (orthogonal W)."""
    w = householder_matrix([v1, v2, v3])
    y = jnp.einsum("...j,ij->...i", x, w)
    return y, jnp.zeros((x.shape[0],), dtype=x.dtype)


def conv1x1_inverse(y, v1, v2, v3):
    w = householder_matrix([v1, v2, v3])
    return jnp.einsum("...i,ij->...j", y, w)  # x = W^T y


# ---------------------------------------------------------------------------
# Affine coupling core: given the conditioner outputs (raw, t) acting on x2.
# s = 2*sigmoid(raw) ("Sigmoid2", InvertibleNetworks.jl).
# ---------------------------------------------------------------------------


def coupling_scale(raw):
    """GLOW-stabilized coupling scale: s = 2*sigmoid(raw), range (0, 2).

    InvertibleNetworks.jl's "Sigmoid2": identity (s=1) at raw=0 so
    zero-initialized conditioners start as the identity map, and the flow
    can both contract (s<1) and expand (s>1)."""
    return 2.0 / (1.0 + jnp.exp(-raw))


def affine_core_forward(x2, raw, t):
    """y2 = s * x2 + t with s = 2*sigmoid(raw); logdet = sum log s."""
    s = coupling_scale(raw)
    y2 = s * x2 + t
    axes = tuple(range(1, x2.ndim))
    logdet = jnp.sum(jnp.log(s), axis=axes)
    return y2, logdet


def affine_core_inverse(y2, raw, t):
    s = coupling_scale(raw)
    return (y2 - t) / s


# ---------------------------------------------------------------------------
# Haar wavelet squeeze: (N, H, W, C) -> (N, H/2, W/2, 4C), orthonormal.
# Channel order of the output: [LL, LH, HL, HH], each C wide.
# ---------------------------------------------------------------------------


def haar_forward(x):
    n, h, w, c = x.shape
    xb = x.reshape(n, h // 2, 2, w // 2, 2, c)
    a = xb[:, :, 0, :, 0, :]
    b = xb[:, :, 0, :, 1, :]
    cc = xb[:, :, 1, :, 0, :]
    d = xb[:, :, 1, :, 1, :]
    ll = (a + b + cc + d) * 0.5
    lh = (a - b + cc - d) * 0.5
    hl = (a + b - cc - d) * 0.5
    hh = (a - b - cc + d) * 0.5
    y = jnp.concatenate([ll, lh, hl, hh], axis=-1)
    logdet = jnp.zeros((n,), dtype=x.dtype)
    return y, logdet


def haar_inverse(y):
    n, h2, w2, c4 = y.shape
    c = c4 // 4
    ll, lh, hl, hh = (y[..., i * c:(i + 1) * c] for i in range(4))
    a = (ll + lh + hl + hh) * 0.5
    b = (ll - lh + hl - hh) * 0.5
    cc = (ll + lh - hl - hh) * 0.5
    d = (ll - lh - hl + hh) * 0.5
    x = jnp.stack([jnp.stack([a, b], axis=3), jnp.stack([cc, d], axis=3)], axis=2)
    # x: (N, H/2, 2, W/2, 2, C)
    return x.reshape(n, h2 * 2, w2 * 2, c)


# ---------------------------------------------------------------------------
# Hyperbolic (leapfrog) residual step on a channel-paired state.
# State (N, H, W, 2C) = [x_prev | x_curr];
#   y_prev = x_curr
#   y_curr = 2 x_curr - x_prev + act(x_curr)
# where act is supplied by the caller (alpha * K^T sigma(K x)).
# Volume preserving: log|det J| = 0.
# ---------------------------------------------------------------------------


def hyperbolic_core_forward(x_prev, x_curr, act):
    y_prev = x_curr
    y_curr = 2.0 * x_curr - x_prev + act
    return y_prev, y_curr


def hyperbolic_core_inverse(y_prev, y_curr, act):
    """act must be evaluated at x_curr == y_prev."""
    x_curr = y_prev
    x_prev = 2.0 * x_curr - y_curr + act
    return x_prev, x_curr


# ---------------------------------------------------------------------------
# Gaussian NLL head: standard-normal log-density per sample.
# ---------------------------------------------------------------------------


def gaussian_logp(z):
    axes = tuple(range(1, z.ndim))
    dim = 1
    for d in z.shape[1:]:
        dim *= d
    return -0.5 * jnp.sum(z * z, axis=axes) - 0.5 * dim * jnp.log(2.0 * jnp.pi)
