"""Pallas kernel: fused affine-coupling core for dense (N, D) inputs.

Same math as affine_core.py but on flat feature vectors — used by the
RealNVP-2D / HINT networks on toy densities and by conditional flows for
amortized inference. One batch-row tile per program.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_N = 128


def _fwd_kernel(x2_ref, raw_ref, t_ref, y2_ref, logs_ref):
    s = 2.0 / (1.0 + jnp.exp(-raw_ref[...]))
    y2_ref[...] = s * x2_ref[...] + t_ref[...]
    logs_ref[...] = jnp.log(s)


def _inv_kernel(y2_ref, raw_ref, t_ref, x2_ref):
    s = 2.0 / (1.0 + jnp.exp(-raw_ref[...]))
    x2_ref[...] = (y2_ref[...] - t_ref[...]) / s


def _tiles(n):
    tile = min(TILE_N, n)
    pad = (-n) % tile
    return tile, pad


@functools.partial(jax.jit, static_argnames=())
def dense_core_forward(x2, raw, t):
    n, d = x2.shape
    tile, pad = _tiles(n)
    if pad:
        x2p = jnp.pad(x2, ((0, pad), (0, 0)))
        rawp = jnp.pad(raw, ((0, pad), (0, 0)))
        tp = jnp.pad(t, ((0, pad), (0, 0)))
    else:
        x2p, rawp, tp = x2, raw, t
    blk = pl.BlockSpec((tile, d), lambda i: (i, 0))
    y2, logs = pl.pallas_call(
        _fwd_kernel,
        grid=(x2p.shape[0] // tile,),
        in_specs=[blk, blk, blk],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct(x2p.shape, x2.dtype),
            jax.ShapeDtypeStruct(x2p.shape, x2.dtype),
        ],
        interpret=True,
    )(x2p, rawp, tp)
    y2, logs = y2[:n], logs[:n]
    return y2, jnp.sum(logs, axis=1)


@functools.partial(jax.jit, static_argnames=())
def dense_core_inverse(y2, raw, t):
    n, d = y2.shape
    tile, pad = _tiles(n)
    if pad:
        y2p = jnp.pad(y2, ((0, pad), (0, 0)))
        rawp = jnp.pad(raw, ((0, pad), (0, 0)))
        tp = jnp.pad(t, ((0, pad), (0, 0)))
    else:
        y2p, rawp, tp = y2, raw, t
    blk = pl.BlockSpec((tile, d), lambda i: (i, 0))
    x2 = pl.pallas_call(
        _inv_kernel,
        grid=(y2p.shape[0] // tile,),
        in_specs=[blk, blk, blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(y2p.shape, y2.dtype),
        interpret=True,
    )(y2p, rawp, tp)
    return x2[:n]
