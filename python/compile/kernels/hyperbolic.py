"""Pallas kernel: hyperbolic (leapfrog) state update.

Given the two-step state halves and the precomputed nonlinearity
act = alpha * K^T sigma(K x_curr):

    y_prev = x_curr
    y_curr = 2 x_curr - x_prev + act

Volume preserving (block-triangular-with-unit-blocks Jacobian), logdet 0.
Elementwise — one (1, Hb, W, C) row block per program (VMEM-budgeted), all VPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(xp_ref, xc_ref, act_ref, yp_ref, yc_ref):
    xc = xc_ref[...]
    yp_ref[...] = xc
    yc_ref[...] = 2.0 * xc - xp_ref[...] + act_ref[...]


def _inv_kernel(yp_ref, yc_ref, act_ref, xp_ref, xc_ref):
    yp = yp_ref[...]
    xc_ref[...] = yp
    xp_ref[...] = 2.0 * yp - yc_ref[...] + act_ref[...]


def _call(kernel, a, b, c):
    n, h, w, ch = a.shape
    hb = _row_block(h, w, ch, n_bufs=5)
    blk = pl.BlockSpec((1, hb, w, ch), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        kernel,
        grid=(n, h // hb),
        in_specs=[blk, blk, blk],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct(a.shape, a.dtype),
            jax.ShapeDtypeStruct(a.shape, a.dtype),
        ],
        interpret=True,
    )(a, b, c)


@functools.partial(jax.jit, static_argnames=())
def hyperbolic_core_forward(x_prev, x_curr, act):
    return _call(_fwd_kernel, x_prev, x_curr, act)


@functools.partial(jax.jit, static_argnames=())
def hyperbolic_core_inverse(y_prev, y_curr, act):
    """Returns (x_prev, x_curr); act evaluated at x_curr == y_prev."""
    xp, xc = _call(_inv_kernel, y_prev, y_curr, act)
    return xp, xc


def _row_block(h, w, c, budget_bytes=2 << 20, n_bufs=3):
    """Largest divisor Hb of H such that n_bufs blocks of (Hb, W, C) f32
    fit in the VMEM budget — fewer grid steps, same VMEM discipline."""
    per_row = w * c * 4 * n_bufs
    max_rows = max(1, budget_bytes // max(per_row, 1))
    hb = 1
    for d in range(1, h + 1):
        if h % d == 0 and d <= max_rows:
            hb = d
    return hb
