"""Pallas kernel: ActNorm per-channel affine transform.

TPU mapping: elementwise over an (N, H/Hb) grid — each program normalizes a
row block (1, Hb, W, C) sized by `_row_block` to stay within a ~2 MiB VMEM
budget (at 1024x1024x3 that is Hb=170 rows) while the per-channel
scale/shift vectors stay resident. Coarser blocks also minimize grid steps,
which is what interpret-mode execution pays for per program.
On CPU we run interpret=True (Mosaic custom-calls cannot execute on the
CPU PJRT plugin); the block structure is kept identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, s_ref, b_ref, y_ref):
    y_ref[...] = x_ref[...] * s_ref[...] + b_ref[...]


def _inv_kernel(y_ref, s_ref, b_ref, x_ref):
    x_ref[...] = (y_ref[...] - b_ref[...]) / s_ref[...]


def _rowwise_call(kernel, x, s, b):
    n, h, w, c = x.shape
    hb = _row_block(h, w, c)
    return pl.pallas_call(
        kernel,
        grid=(n, h // hb),
        in_specs=[
            pl.BlockSpec((1, hb, w, c), lambda i, j: (i, j, 0, 0)),
            pl.BlockSpec((c,), lambda i, j: (0,)),
            pl.BlockSpec((c,), lambda i, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, hb, w, c), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=True,
    )(x, s, b)


@functools.partial(jax.jit, static_argnames=())
def actnorm_forward(x, log_s, b):
    """y = x * exp(log_s) + b; logdet = H*W*sum(log_s) per sample."""
    s = jnp.exp(log_s)
    y = _rowwise_call(_fwd_kernel, x, s, b)
    spatial = x.shape[1] * x.shape[2]
    logdet = jnp.full((x.shape[0],), spatial * jnp.sum(log_s), dtype=x.dtype)
    return y, logdet


@functools.partial(jax.jit, static_argnames=())
def actnorm_inverse(y, log_s, b):
    s = jnp.exp(log_s)
    return _rowwise_call(_inv_kernel, y, s, b)


def _row_block(h, w, c, budget_bytes=2 << 20, n_bufs=3):
    """Largest divisor Hb of H such that n_bufs blocks of (Hb, W, C) f32
    fit in the VMEM budget — fewer grid steps, same VMEM discipline."""
    per_row = w * c * 4 * n_bufs
    max_rows = max(1, budget_bytes // max(per_row, 1))
    hb = 1
    for d in range(1, h + 1):
        if h % d == 0 and d <= max_rows:
            hb = d
    return hb
