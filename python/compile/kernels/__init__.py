"""L1 Pallas kernels (interpret=True) + pure-jnp reference oracles."""
