"""Pallas kernel: Haar wavelet squeeze (invertible downsampling).

(N, H, W, C) -> (N, H/2, W/2, 4C) with the orthonormal 2x2 Haar basis;
output channels ordered [LL, LH, HL, HH]. logdet = 0.

TPU mapping: each program handles one (1, 2, W, C) strip of input rows and
emits one (1, 1, W/2, 4C) output row — the butterfly is 4 loads / 4 adds
per output element, all VPU, and the layout change is expressed through the
BlockSpecs rather than a CUDA strided gather. interpret=True on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x_ref, y_ref):
    x = x_ref[...]  # (1, 2*Hb, W, C)
    _, h2, w, c = x.shape
    xb = x.reshape(1, h2 // 2, 2, w // 2, 2, c)
    a = xb[:, :, 0, :, 0, :]
    b = xb[:, :, 0, :, 1, :]
    cc = xb[:, :, 1, :, 0, :]
    d = xb[:, :, 1, :, 1, :]
    ll = (a + b + cc + d) * 0.5
    lh = (a - b + cc - d) * 0.5
    hl = (a + b - cc - d) * 0.5
    hh = (a - b - cc + d) * 0.5
    y_ref[...] = jnp.concatenate([ll, lh, hl, hh], axis=-1)


def _inv_kernel(y_ref, x_ref):
    y = y_ref[...]  # (1, Hb, W/2, 4C)
    _, hb, w2, c4 = y.shape
    c = c4 // 4
    ll, lh, hl, hh = (y[..., i * c:(i + 1) * c] for i in range(4))
    a = (ll + lh + hl + hh) * 0.5
    b = (ll - lh + hl - hh) * 0.5
    cc = (ll + lh - hl - hh) * 0.5
    d = (ll - lh - hl + hh) * 0.5
    top = jnp.stack([a, b], axis=3)   # (1, Hb, W/2, 2, C): interleave W
    bot = jnp.stack([cc, d], axis=3)
    x = jnp.stack([top, bot], axis=2)  # (1, Hb, 2, W/2, 2, C)
    x_ref[...] = x.reshape(1, 2 * hb, 2 * w2, c)


@functools.partial(jax.jit, static_argnames=())
def haar_forward(x):
    n, h, w, c = x.shape
    hb = _row_block(h // 2, w, 4 * c, n_bufs=2)
    y = pl.pallas_call(
        _fwd_kernel,
        grid=(n, (h // 2) // hb),
        in_specs=[pl.BlockSpec((1, 2 * hb, w, c), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, hb, w // 2, 4 * c), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h // 2, w // 2, 4 * c), x.dtype),
        interpret=True,
    )(x)
    return y, jnp.zeros((n,), dtype=x.dtype)


@functools.partial(jax.jit, static_argnames=())
def haar_inverse(y):
    n, h2, w2, c4 = y.shape
    c = c4 // 4
    hb = _row_block(h2, w2, c4, n_bufs=2)
    return pl.pallas_call(
        _inv_kernel,
        grid=(n, h2 // hb),
        in_specs=[pl.BlockSpec((1, hb, w2, c4), lambda i, j: (i, j, 0, 0))],
        out_specs=pl.BlockSpec((1, 2 * hb, 2 * w2, c), lambda i, j: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 2 * h2, 2 * w2, c), y.dtype),
        interpret=True,
    )(y)


def _row_block(h, w, c, budget_bytes=2 << 20, n_bufs=3):
    """Largest divisor Hb of H such that n_bufs blocks of (Hb, W, C) f32
    fit in the VMEM budget — fewer grid steps, same VMEM discipline."""
    per_row = w * c * 4 * n_bufs
    max_rows = max(1, budget_bytes // max(per_row, 1))
    hb = 1
    for d in range(1, h + 1):
        if h % d == 0 and d <= max_rows:
            hb = d
    return hb
