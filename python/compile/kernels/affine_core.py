"""Pallas kernel: fused affine-coupling core.

Computes, in one VMEM-resident pass over the transformed half x2:
    s    = 2*sigmoid(raw)            ("Sigmoid2" stabilized scale)
    y2   = s * x2 + t
    logs = log(s)                    (summed outside for the logdet)

TPU mapping: the CUDA version would fuse this into the conditioner's
epilogue per threadblock; on TPU we tile an (N, H) grid so each program's
(1, Hb, W, C2) block of x2/raw/t lives in VMEM and the sigmoid/mul/add chain
is a single VPU pass (no HBM round-trips between the ops). interpret=True
on CPU; structure identical to the Mosaic path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(x2_ref, raw_ref, t_ref, y2_ref, logs_ref):
    s = 2.0 / (1.0 + jnp.exp(-raw_ref[...]))
    y2_ref[...] = s * x2_ref[...] + t_ref[...]
    logs_ref[...] = jnp.log(s)


def _inv_kernel(y2_ref, raw_ref, t_ref, x2_ref):
    s = 2.0 / (1.0 + jnp.exp(-raw_ref[...]))
    x2_ref[...] = (y2_ref[...] - t_ref[...]) / s


def _specs(shape):
    _, h, w, c = shape
    hb = _row_block(h, w, c, n_bufs=5)
    blk = pl.BlockSpec((1, hb, w, c), lambda i, j: (i, j, 0, 0))
    return blk, hb


@functools.partial(jax.jit, static_argnames=())
def affine_core_forward(x2, raw, t):
    """(y2, logdet): y2 = 2*sigmoid(raw)*x2 + t, logdet = sum log s."""
    n, h, w, c = x2.shape
    blk, hb = _specs(x2.shape)
    y2, logs = pl.pallas_call(
        _fwd_kernel,
        grid=(n, h // hb),
        in_specs=[blk, blk, blk],
        out_specs=[blk, blk],
        out_shape=[
            jax.ShapeDtypeStruct(x2.shape, x2.dtype),
            jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        ],
        interpret=True,
    )(x2, raw, t)
    logdet = jnp.sum(logs, axis=(1, 2, 3))
    return y2, logdet


@functools.partial(jax.jit, static_argnames=())
def affine_core_inverse(y2, raw, t):
    n, h, w, c = y2.shape
    blk, hb = _specs(y2.shape)
    return pl.pallas_call(
        _inv_kernel,
        grid=(n, h // hb),
        in_specs=[blk, blk, blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(y2.shape, y2.dtype),
        interpret=True,
    )(y2, raw, t)


def _row_block(h, w, c, budget_bytes=2 << 20, n_bufs=3):
    """Largest divisor Hb of H such that n_bufs blocks of (Hb, W, C) f32
    fit in the VMEM budget — fewer grid steps, same VMEM discipline."""
    per_row = w * c * 4 * n_bufs
    max_rows = max(1, budget_bytes // max(per_row, 1))
    hb = 1
    for d in range(1, h + 1):
        if h % d == 0 and d <= max_rows:
            hb = d
    return hb
