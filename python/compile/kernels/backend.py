"""Kernel backend dispatch (call-time switchable).

Default backend: L1 Pallas kernels (interpret=True on CPU). The pure-jnp
reference backend is selected with INVERTNET_PALLAS=0 or `set_backend("ref")`
— used (a) by python/tests/test_layers.py, because reverse-mode AD cannot
trace through interpret-mode pallas_call (the layers' hand-written backward
entries never need to: they only *call* kernels, never differentiate them),
and (b) by the perf ablation measuring interpret-mode grid-loop overhead.

test_kernels.py pins the two backends to identical semantics.
"""

import os

import jax.numpy as jnp

from . import actnorm as _pa
from . import affine_core as _pf
from . import conv1x1 as _pc
from . import dense_core as _pd
from . import haar as _ph
from . import hyperbolic as _py
from . import ref as _r


def _ref_conv1x1_apply(x, w):
    return jnp.einsum("...j,ij->...i", x, w)


def _ref_conv1x1_unapply(y, w):
    return jnp.einsum("...i,ij->...j", y, w)


_IMPL = {
    "pallas": {
        "actnorm_forward": _pa.actnorm_forward,
        "actnorm_inverse": _pa.actnorm_inverse,
        "affine_core_forward": _pf.affine_core_forward,
        "affine_core_inverse": _pf.affine_core_inverse,
        "conv1x1_apply": _pc.conv1x1_apply,
        "conv1x1_unapply": _pc.conv1x1_unapply,
        "dense_core_forward": _pd.dense_core_forward,
        "dense_core_inverse": _pd.dense_core_inverse,
        "haar_forward": _ph.haar_forward,
        "haar_inverse": _ph.haar_inverse,
        "hyperbolic_core_forward": _py.hyperbolic_core_forward,
        "hyperbolic_core_inverse": _py.hyperbolic_core_inverse,
    },
    "ref": {
        "actnorm_forward": _r.actnorm_forward,
        "actnorm_inverse": _r.actnorm_inverse,
        "affine_core_forward": _r.affine_core_forward,
        "affine_core_inverse": _r.affine_core_inverse,
        "conv1x1_apply": _ref_conv1x1_apply,
        "conv1x1_unapply": _ref_conv1x1_unapply,
        "dense_core_forward": _r.affine_core_forward,
        "dense_core_inverse": _r.affine_core_inverse,
        "haar_forward": _r.haar_forward,
        "haar_inverse": _r.haar_inverse,
        "hyperbolic_core_forward": _r.hyperbolic_core_forward,
        "hyperbolic_core_inverse": _r.hyperbolic_core_inverse,
    },
}

_current = "pallas" if os.environ.get("INVERTNET_PALLAS", "1") != "0" else "ref"


def set_backend(name):
    global _current
    assert name in _IMPL, name
    _current = name


def backend_name():
    return "pallas-interpret" if _current == "pallas" else "jnp-ref"


def _dispatch(fname):
    def fn(*args, **kwargs):
        return _IMPL[_current][fname](*args, **kwargs)
    fn.__name__ = fname
    return fn


actnorm_forward = _dispatch("actnorm_forward")
actnorm_inverse = _dispatch("actnorm_inverse")
affine_core_forward = _dispatch("affine_core_forward")
affine_core_inverse = _dispatch("affine_core_inverse")
conv1x1_apply = _dispatch("conv1x1_apply")
conv1x1_unapply = _dispatch("conv1x1_unapply")
dense_core_forward = _dispatch("dense_core_forward")
dense_core_inverse = _dispatch("dense_core_inverse")
haar_forward = _dispatch("haar_forward")
haar_inverse = _dispatch("haar_inverse")
hyperbolic_core_forward = _dispatch("hyperbolic_core_forward")
hyperbolic_core_inverse = _dispatch("hyperbolic_core_inverse")
