"""Pallas kernel: GLOW 1x1 invertible convolution as a pixel matmul.

The CUDA implementations treat this as a grouped conv; the TPU-native view
is a plain (P, C) x (C, C) matmul with P = N*H*W flattened pixels, which
feeds the MXU directly. We tile P into TILE_P-row blocks (sized so a block + weight stay in a ~2 MiB VMEM budget at C<=128) (the weight is
tiny and stays VMEM-resident across the whole grid) — the same schedule a
Mosaic lowering would emit. interpret=True for CPU execution.

The weight passed in is the dense W built from Householder vectors at L2;
forward multiplies by W^T (y_p = W x_p), inverse multiplies by W (W is
orthogonal, so W^{-1} = W^T).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_P = 4096


def _matmul_kernel(x_ref, w_ref, y_ref):
    # x: (TILE_P, C), w: (C, C); y = x @ w
    y_ref[...] = jnp.dot(x_ref[...], w_ref[...])


def _pixel_matmul(x_flat, w):
    p, c = x_flat.shape
    tile = min(TILE_P, p)
    # pad P to a multiple of the tile so the grid is rectangular
    pad = (-p) % tile
    if pad:
        x_flat = jnp.pad(x_flat, ((0, pad), (0, 0)))
    grid = (x_flat.shape[0] // tile,)
    y = pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, c), lambda i: (i, 0)),
            pl.BlockSpec((c, c), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((x_flat.shape[0], c), x_flat.dtype),
        interpret=True,
    )(x_flat, w)
    return y[:p] if pad else y


@functools.partial(jax.jit, static_argnames=())
def conv1x1_apply(x, w):
    """y[n,h,w,:] = W @ x[n,h,w,:]  (pass w = W.T to this matmul form)."""
    shape = x.shape
    x_flat = x.reshape(-1, shape[-1])
    y = _pixel_matmul(x_flat, w.T)
    return y.reshape(shape)


@functools.partial(jax.jit, static_argnames=())
def conv1x1_unapply(y, w):
    """x = W^T y — the inverse for orthogonal W."""
    shape = y.shape
    y_flat = y.reshape(-1, shape[-1])
    x = _pixel_matmul(y_flat, w)
    return x.reshape(shape)
