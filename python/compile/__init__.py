"""Build-time compile package: L1 Pallas kernels, L2 JAX layers, AOT lowering.

Never imported at runtime — `make artifacts` runs this once to emit HLO
text artifacts + manifest.json consumed by the rust coordinator.
"""
