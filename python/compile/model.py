"""L2 model registry: layer instances, their AOT entries, and the named
network configurations the rust coordinator composes at runtime.

A *layer instance* is (kind, cfg) with a deterministic signature string;
`entries()` maps it to the four (five with cond) jittable entry functions
plus example-argument shapes for lowering. A *network* is an ordered list
of layer instances plus input/latent shape metadata; the coordinator
replays it from manifest.json.

Split (multiscale factor-out) is a coordinator-native layer: it is pure
memory movement, so it appears in network layer lists with kind "split"
but has no artifacts.
"""

import math

from .layers import (actnorm, conv1x1, coupling_additive, coupling_dense,
                     coupling_glow, haar, heads, hint, hyperbolic, permute)


def _shape_tag(shape):
    return "x".join(str(s) for s in shape)


class LayerInstance:
    """One concrete (kind, cfg) layer with fixed activation shape."""

    def __init__(self, kind, cfg, in_shape, out_shape=None, cond_shape=None):
        self.kind = kind
        self.cfg = cfg
        self.in_shape = tuple(in_shape)
        self.out_shape = tuple(out_shape or in_shape)
        self.cond_shape = tuple(cond_shape) if cond_shape else None

    @property
    def sig(self):
        parts = [self.kind, _shape_tag(self.in_shape)]
        if "hidden" in self.cfg:
            parts.append(f"hd{self.cfg['hidden']}")
        if "depth" in self.cfg:
            parts.append(f"dep{self.cfg['depth']}")
        if self.cond_shape:
            parts.append(f"cond{_shape_tag(self.cond_shape)}")
        return "__".join(parts)

    # -- parameter specs ----------------------------------------------------
    def param_specs(self):
        mod = _MODULES[self.kind]
        if self.kind == "condcpl":
            return coupling_dense.cond_param_specs(self.cfg)
        return mod.param_specs(self.cfg)

    # -- entry functions ----------------------------------------------------
    def entries(self):
        """{entry_name: (fn, [operand shapes])}; params appended last."""
        n = self.in_shape[0]
        x, y = self.in_shape, self.out_shape
        ld = (n,)
        if self.kind == "hint":
            fwd, inv, bwd, bwds = hint.make(self.cfg)
        elif self.kind == "condcpl":
            fwd = coupling_dense.cond_forward
            inv = coupling_dense.cond_inverse
            bwd = coupling_dense.cond_backward
            bwds = coupling_dense.cond_backward_stored
        else:
            mod = _MODULES[self.kind]
            fwd, inv, bwd, bwds = (mod.forward, mod.inverse, mod.backward,
                                   mod.backward_stored)
        if self.cond_shape:
            c = self.cond_shape
            return {
                "forward": (fwd, [x, c]),
                "inverse": (inv, [y, c]),
                "backward": (bwd, [y, ld, y, c]),
                "backward_stored": (bwds, [y, ld, x, c]),
            }
        return {
            "forward": (fwd, [x]),
            "inverse": (inv, [y]),
            "backward": (bwd, [y, ld, y]),
            "backward_stored": (bwds, [y, ld, x]),
        }

    def manifest_entry(self):
        return {
            "sig": self.sig,
            "kind": self.kind,
            "in_shape": list(self.in_shape),
            "out_shape": list(self.out_shape),
            "cond_shape": list(self.cond_shape) if self.cond_shape else None,
            "params": [{"name": nm, "shape": list(sh)}
                       for nm, sh in self.param_specs()],
            "cfg": self.cfg,
        }


_MODULES = {
    "actnorm": actnorm,
    "conv1x1": conv1x1,
    "glowcpl": coupling_glow,
    "addcpl": coupling_additive,
    "densecpl": coupling_dense,
    "condcpl": coupling_dense,
    "haar": haar,
    "permute": permute,
    "hyper": hyperbolic,
    "hint": hint,
}


# ---------------------------------------------------------------------------
# Layer-instance constructors
# ---------------------------------------------------------------------------


def L_actnorm(n, h, w, c):
    return LayerInstance("actnorm", {"c": c}, (n, h, w, c))


def L_conv1x1(n, h, w, c):
    return LayerInstance("conv1x1", {"c": c}, (n, h, w, c))


def L_glowcpl(n, h, w, c, hidden):
    return LayerInstance("glowcpl", {"c": c, "hidden": hidden}, (n, h, w, c))


def L_addcpl(n, h, w, c, hidden):
    return LayerInstance("addcpl", {"c": c, "hidden": hidden}, (n, h, w, c))


def L_haar(n, h, w, c):
    return LayerInstance("haar", {"c": c}, (n, h, w, c),
                         out_shape=(n, h // 2, w // 2, 4 * c))


def L_permute(shape):
    return LayerInstance("permute", {}, shape)


def L_densecpl(n, d, hidden):
    return LayerInstance("densecpl", {"d": d, "hidden": hidden}, (n, d))


def L_condcpl(n, d, dcond, hidden):
    return LayerInstance("condcpl", {"d": d, "dcond": dcond, "hidden": hidden},
                         (n, d), cond_shape=(n, dcond))


def L_hyper(n, h, w, c, hidden):
    return LayerInstance("hyper", {"c": c, "hidden": hidden}, (n, h, w, c))


def L_hint(n, d, hidden, depth):
    return LayerInstance("hint", {"d": d, "hidden": hidden, "depth": depth},
                         (n, d))


def L_split(n, h, w, c):
    """Coordinator-native factor-out: first c//2 channels exit as latent."""
    zc = c // 2
    inst = LayerInstance("split", {"zc": zc}, (n, h, w, c),
                         out_shape=(n, h, w, c - zc))
    return inst


# ---------------------------------------------------------------------------
# Networks
# ---------------------------------------------------------------------------


class Network:
    def __init__(self, name, layers, in_shape, cond_shape=None):
        self.name = name
        self.layers = layers
        self.in_shape = tuple(in_shape)
        self.cond_shape = tuple(cond_shape) if cond_shape else None

    def latent_shapes(self):
        """Shapes entering the Gaussian head: split z's + final output."""
        shapes = []
        for inst in self.layers:
            if inst.kind == "split":
                n, h, w, c = inst.in_shape
                shapes.append((n, h, w, inst.cfg["zc"]))
        shapes.append(self.layers[-1].out_shape)
        return shapes

    def manifest_entry(self):
        return {
            "name": self.name,
            "in_shape": list(self.in_shape),
            "cond_shape": list(self.cond_shape) if self.cond_shape else None,
            "layers": [inst.sig if inst.kind != "split"
                       else f"split_zc{inst.cfg['zc']}__{_shape_tag(inst.in_shape)}"
                       for inst in self.layers],
            "latent_shapes": [list(s) for s in self.latent_shapes()],
        }


def glow_flat(name, n, h, w, c_in, k, hidden):
    """Haar squeeze then K x (ActNorm -> Conv1x1 -> AffineCoupling)."""
    layers = [L_haar(n, h, w, c_in)]
    c = 4 * c_in
    h2, w2 = h // 2, w // 2
    for _ in range(k):
        layers += [L_actnorm(n, h2, w2, c), L_conv1x1(n, h2, w2, c),
                   L_glowcpl(n, h2, w2, c, hidden)]
    return Network(name, layers, (n, h, w, c_in))


def glow_multiscale(name, n, h, w, c_in, scales, k, hidden):
    """GLOW with Haar squeeze + factor-out between scales (paper §1)."""
    layers = []
    ch, hh, ww = c_in, h, w
    for s in range(scales):
        layers.append(L_haar(n, hh, ww, ch))
        ch, hh, ww = 4 * ch, hh // 2, ww // 2
        for _ in range(k):
            layers += [L_actnorm(n, hh, ww, ch), L_conv1x1(n, hh, ww, ch),
                       L_glowcpl(n, hh, ww, ch, hidden)]
        if s != scales - 1:
            layers.append(L_split(n, hh, ww, ch))
            ch = ch - ch // 2
    return Network(name, layers, (n, h, w, c_in))


def realnvp_dense(name, n, d, k, hidden):
    layers = []
    for _ in range(k):
        layers += [L_densecpl(n, d, hidden), L_permute((n, d))]
    return Network(name, layers, (n, d))


def cond_realnvp_dense(name, n, d, dcond, k, hidden):
    layers = []
    for _ in range(k):
        layers += [L_condcpl(n, d, dcond, hidden), L_permute((n, d))]
    return Network(name, layers, (n, d), cond_shape=(n, dcond))


def hint_dense(name, n, d, k, hidden, depth):
    layers = []
    for _ in range(k):
        layers += [L_hint(n, d, hidden, depth), L_permute((n, d))]
    return Network(name, layers, (n, d))


def hyperbolic_net(name, n, h, w, c_in, k, hidden):
    """Haar squeeze to 4*c_in channels, then K leapfrog steps on the
    (prev|curr) paired state."""
    layers = [L_haar(n, h, w, c_in)]
    c = 4 * c_in
    for _ in range(k):
        layers.append(L_hyper(n, h // 2, w // 2, c, hidden))
    return Network(name, layers, (n, h, w, c_in))


# ---------------------------------------------------------------------------
# The default network catalog: examples + every figure's sweep.
# ---------------------------------------------------------------------------


def default_networks():
    nets = []
    # e2e examples
    nets.append(realnvp_dense("realnvp2d", n=256, d=2, k=8, hidden=64))
    nets.append(cond_realnvp_dense("cond_realnvp2d", n=256, d=2, dcond=2,
                                   k=8, hidden=64))
    nets.append(hint_dense("hint8d", n=256, d=8, k=4, hidden=64, depth=2))
    nets.append(glow_multiscale("glow16", n=16, h=16, w=16, c_in=3,
                                scales=2, k=4, hidden=32))
    nets.append(hyperbolic_net("hyper16", n=16, h=16, w=16, c_in=3,
                               k=6, hidden=12))
    # fig1: spatial-size sweep, GLOW, 3 input channels, batch 8 (paper setup)
    for hw in (16, 32, 64, 128, 256):
        nets.append(glow_flat(f"glow_fig1_{hw}", n=8, h=hw, w=hw, c_in=3,
                              k=16, hidden=32))
    # fig2: depth sweep at 64x64 — all depths share the 64x64 artifacts
    for k in (2, 4, 8, 16, 32, 48):
        nets.append(glow_flat(f"glow_fig2_d{k}", n=8, h=64, w=64, c_in=3,
                              k=k, hidden=32))
    # throughput / ablation nets
    nets.append(glow_flat("glow_bench32", n=8, h=32, w=32, c_in=3,
                          k=8, hidden=32))
    return nets


def collect_layer_instances(nets):
    """Dedupe layer instances by signature across all networks."""
    seen = {}
    for net in nets:
        for inst in net.layers:
            if inst.kind == "split":
                continue
            seen.setdefault(inst.sig, inst)
    return seen


def head_shapes(nets):
    """Unique latent shapes needing gaussian_logp / nll_seed artifacts."""
    shapes = set()
    for net in nets:
        for s in net.latent_shapes():
            shapes.add(tuple(s))
    return sorted(shapes)


HEAD_ENTRIES = {
    "gaussian_logp": heads.gaussian_logp,
    "nll_seed": heads.nll_seed,
}


# ---------------------------------------------------------------------------
# Monolithic full-AD ablation ("what normflows does"): the entire network
# forward + NLL loss differentiated by jax in ONE program. Used to check
# the per-layer hand-written gradients end-to-end and as the XLA-fused
# wall-clock reference in the throughput bench. Lowered with the ref
# backend (reverse-mode AD cannot trace interpret-mode pallas_call — and
# an AD framework would be differentiating standard ops anyway).
# ---------------------------------------------------------------------------


def full_vjp_fn(net):
    """(x, *flat_params) -> (loss, *dparams) for an unconditional net."""
    import jax
    import jax.numpy as jnp

    from .kernels.ref import gaussian_logp

    insts = [inst for inst in net.layers]
    param_counts = [0 if inst.kind == "split" else len(inst.param_specs())
                    for inst in insts]

    def loss_fn(x, *flat):
        latents = []
        ld_total = 0.0
        cur = x
        off = 0
        for inst, npar in zip(insts, param_counts):
            if inst.kind == "split":
                zc = inst.cfg["zc"]
                latents.append(cur[..., :zc])
                cur = cur[..., zc:]
                continue
            theta = flat[off:off + npar]
            off += npar
            fwd = inst.entries()["forward"][0]
            cur, ld = fwd(cur, *theta)
            ld_total = ld_total + ld
        latents.append(cur)
        logp = sum(gaussian_logp(z) for z in latents)
        return -jnp.mean(logp + ld_total)

    def step(x, *flat):
        loss, grads = jax.value_and_grad(
            loss_fn, argnums=tuple(range(1, 1 + sum(param_counts))))(x, *flat)
        return (loss,) + tuple(grads)

    return step, param_counts


MONOLITH_NETS = ["realnvp2d", "glow_bench32", "glow_fig2_d8"]
