"""Haar wavelet squeeze layer (paper §1's multiscale transform).

Orthonormal linear map, parameter-free, logdet = 0; the gradient is the
transpose, which for an orthonormal map *is* the inverse transform.
"""

from ..kernels import backend as k


def param_specs(cfg):
    return []


def forward(x):
    return k.haar_forward(x)


def inverse(y):
    return (k.haar_inverse(y),)


def backward(dy, dld, y):
    del dld
    dx = k.haar_inverse(dy)
    x = k.haar_inverse(y)
    return dx, x


def backward_stored(dy, dld, x):
    del dld, x
    return (k.haar_inverse(dy),)
