"""Conditioner networks used inside coupling layers.

These are the "arbitrary neural networks that need not be invertible"
(paper §1): a 3-layer CNN for image couplings (GLOW's conv3x3 -> relu ->
conv1x1 -> relu -> conv3x3, zero-initialized final layer) and a 3-layer MLP
for dense couplings. They are differentiated with jax.vjp *inside* the
hand-written layer backward — the analogue of the paper's ChainRules/Zygote
integration where only the flow-level graph is manual.
"""

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# CNN conditioner (image couplings)
# ---------------------------------------------------------------------------


def cnn_param_specs(c_in, hidden, c_out):
    return [
        ("w1", (3, 3, c_in, hidden)),
        ("b1", (hidden,)),
        ("w2", (1, 1, hidden, hidden)),
        ("b2", (hidden,)),
        ("w3", (3, 3, hidden, c_out)),
        ("b3", (c_out,)),
    ]


def _conv(x, w):
    return lax.conv_general_dilated(
        x, w,
        window_strides=(1, 1),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def cnn_apply(x, w1, b1, w2, b2, w3, b3):
    h = jax.nn.relu(_conv(x, w1) + b1)
    h = jax.nn.relu(_conv(h, w2) + b2)
    return _conv(h, w3) + b3


# ---------------------------------------------------------------------------
# MLP conditioner (dense couplings)
# ---------------------------------------------------------------------------


def mlp_param_specs(d_in, hidden, d_out):
    return [
        ("w1", (d_in, hidden)),
        ("b1", (hidden,)),
        ("w2", (hidden, hidden)),
        ("b2", (hidden,)),
        ("w3", (hidden, d_out)),
        ("b3", (d_out,)),
    ]


def mlp_apply(x, w1, b1, w2, b2, w3, b3):
    h = jax.nn.relu(x @ w1 + b1)
    h = jax.nn.relu(h @ w2 + b2)
    return h @ w3 + b3


def split_raw_t(out):
    """Split conditioner output channels into (raw_scale, shift)."""
    c2 = out.shape[-1] // 2
    return out[..., :c2], out[..., c2:]
