"""Dense affine coupling (RealNVP on flat (N, D) vectors) and its
conditional variant for amortized inference.

Unconditional:
    x1, x2 = split(x);  raw, t = MLP(x1);  y2 = 2*sigmoid(raw)*x2 + t
Conditional (cond is a per-sample context vector, e.g. an observation or a
summary-network embedding):
    raw, t = MLP(concat(x1, cond))
and backward additionally returns dcond so an upstream summary network can
be trained through the flow (paper §4, BayesFlow pattern).
"""

import jax
import jax.numpy as jnp

from ..kernels import backend as k
from ..kernels.ref import coupling_scale
from .conditioner import mlp_apply, mlp_param_specs, split_raw_t


def _split(x, d1):
    return x[:, :d1], x[:, d1:]


# ---------------------------------------------------------------------------
# Unconditional
# ---------------------------------------------------------------------------


def param_specs(cfg):
    d = cfg["d"]
    d1 = d // 2
    d2 = d - d1
    return mlp_param_specs(d1, cfg["hidden"], 2 * d2)


def forward(x, *theta):
    d1 = x.shape[-1] // 2
    x1, x2 = _split(x, d1)
    raw, t = split_raw_t(mlp_apply(x1, *theta))
    y2, logdet = k.dense_core_forward(x2, raw, t)
    return jnp.concatenate([x1, y2], axis=-1), logdet


def inverse(y, *theta):
    d1 = y.shape[-1] // 2
    y1, y2 = _split(y, d1)
    raw, t = split_raw_t(mlp_apply(y1, *theta))
    x2 = k.dense_core_inverse(y2, raw, t)
    return (jnp.concatenate([y1, x2], axis=-1),)


def _grads(dy, dld, x1, y2_or_x2, theta, stored, cond=None):
    d1 = x1.shape[-1]
    dy1, dy2 = _split(dy, d1)
    if cond is None:
        out, mlp_vjp = jax.vjp(lambda a, *th: mlp_apply(a, *th), x1, *theta)
    else:
        out, mlp_vjp = jax.vjp(
            lambda a, c, *th: mlp_apply(jnp.concatenate([a, c], axis=-1), *th),
            x1, cond, *theta)
    raw, t = split_raw_t(out)
    s = coupling_scale(raw)
    x2 = y2_or_x2 if stored else (y2_or_x2 - t) / s
    dx2 = dy2 * s
    ds = dy2 * x2 + dld[:, None] / s
    draw = ds * s * (1.0 - 0.5 * s)
    dout = jnp.concatenate([draw, dy2], axis=-1)
    pulled = mlp_vjp(dout)
    dx1 = dy1 + pulled[0]
    if cond is None:
        dcond, dtheta = None, pulled[1:]
    else:
        dcond, dtheta = pulled[1], pulled[2:]
    dx = jnp.concatenate([dx1, dx2], axis=-1)
    return dx, dcond, dtheta, x2


def backward(dy, dld, y, *theta):
    d1 = y.shape[-1] // 2
    y1, y2 = _split(y, d1)
    dx, _, dtheta, x2 = _grads(dy, dld, y1, y2, theta, stored=False)
    return (dx,) + tuple(dtheta) + (jnp.concatenate([y1, x2], axis=-1),)


def backward_stored(dy, dld, x, *theta):
    d1 = x.shape[-1] // 2
    x1, x2 = _split(x, d1)
    dx, _, dtheta, _ = _grads(dy, dld, x1, x2, theta, stored=True)
    return (dx,) + tuple(dtheta)


# ---------------------------------------------------------------------------
# Conditional
# ---------------------------------------------------------------------------


def cond_param_specs(cfg):
    d = cfg["d"]
    d1 = d // 2
    d2 = d - d1
    return mlp_param_specs(d1 + cfg["dcond"], cfg["hidden"], 2 * d2)


def cond_forward(x, cond, *theta):
    d1 = x.shape[-1] // 2
    x1, x2 = _split(x, d1)
    raw, t = split_raw_t(mlp_apply(jnp.concatenate([x1, cond], axis=-1), *theta))
    y2, logdet = k.dense_core_forward(x2, raw, t)
    return jnp.concatenate([x1, y2], axis=-1), logdet


def cond_inverse(y, cond, *theta):
    d1 = y.shape[-1] // 2
    y1, y2 = _split(y, d1)
    raw, t = split_raw_t(mlp_apply(jnp.concatenate([y1, cond], axis=-1), *theta))
    x2 = k.dense_core_inverse(y2, raw, t)
    return (jnp.concatenate([y1, x2], axis=-1),)


def cond_backward(dy, dld, y, cond, *theta):
    d1 = y.shape[-1] // 2
    y1, y2 = _split(y, d1)
    dx, dcond, dtheta, x2 = _grads(dy, dld, y1, y2, theta, stored=False, cond=cond)
    x = jnp.concatenate([y1, x2], axis=-1)
    return (dx, dcond) + tuple(dtheta) + (x,)


def cond_backward_stored(dy, dld, x, cond, *theta):
    d1 = x.shape[-1] // 2
    x1, x2 = _split(x, d1)
    dx, dcond, dtheta, _ = _grads(dy, dld, x1, x2, theta, stored=True, cond=cond)
    return (dx, dcond) + tuple(dtheta)
