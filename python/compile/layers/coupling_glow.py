"""GLOW-style affine coupling layer (image, NHWC).

    x1, x2 = split_channels(x)           # C1 = C//2, C2 = C - C1
    raw, t = CNN(x1)                     # conditioner, 2*C2 output channels
    s      = 2*sigmoid(raw)            ("Sigmoid2")
    y      = concat(x1, s * x2 + t)
    logdet = sum_{h,w,c2} log s          # per sample

Hand-written backward (the paper's core contribution — the flow-level
graph never hits an AD tape):
    x1 = y1;   x2 = (y2 - t) / s                       (recomputed, O(1) mem)
    dx2   = dy2 * s
    ds    = dy2 * x2 + dld / s                         (logdet pullback)
    draw  = ds * s * (1 - s/2)                         (d(2*sigmoid)/draw)
    dt    = dy2
    dx1   = dy1 + vjp_CNN(concat(draw, dt))            (inner net by AD)
"""

import jax
import jax.numpy as jnp

from ..kernels import backend as k
from ..kernels.ref import coupling_scale
from .conditioner import cnn_apply, cnn_param_specs, split_raw_t


def split_channels(x, c1):
    return x[..., :c1], x[..., c1:]


def param_specs(cfg):
    c = cfg["c"]
    c1 = c // 2
    c2 = c - c1
    return cnn_param_specs(c1, cfg["hidden"], 2 * c2)


def forward(x, *theta):
    c1 = x.shape[-1] // 2
    x1, x2 = split_channels(x, c1)
    raw, t = split_raw_t(cnn_apply(x1, *theta))
    y2, logdet = k.affine_core_forward(x2, raw, t)
    return jnp.concatenate([x1, y2], axis=-1), logdet


def inverse(y, *theta):
    c1 = y.shape[-1] // 2
    y1, y2 = split_channels(y, c1)
    raw, t = split_raw_t(cnn_apply(y1, *theta))
    x2 = k.affine_core_inverse(y2, raw, t)
    return (jnp.concatenate([y1, x2], axis=-1),)


def _grads(dy, dld, x1, y2_or_x2, theta, stored):
    """Shared manual-gradient core.

    stored=False: y2_or_x2 is y2 and x2 is recomputed via the inverse.
    stored=True:  y2_or_x2 is x2 (taped by the AD-baseline executor).
    """
    c1 = x1.shape[-1]
    dy1, dy2 = split_channels(dy, c1)
    out, cnn_vjp = jax.vjp(lambda x1_, *th: cnn_apply(x1_, *th), x1, *theta)
    raw, t = split_raw_t(out)
    s = coupling_scale(raw)
    if stored:
        x2 = y2_or_x2
    else:
        x2 = (y2_or_x2 - t) / s
    dld_b = dld.reshape((-1,) + (1,) * (dy.ndim - 1))
    dx2 = dy2 * s
    ds = dy2 * x2 + dld_b / s
    draw = ds * s * (1.0 - 0.5 * s)
    dt = dy2
    dout = jnp.concatenate([draw, dt], axis=-1)
    pulled = cnn_vjp(dout)
    dx1 = dy1 + pulled[0]
    dtheta = pulled[1:]
    dx = jnp.concatenate([dx1, dx2], axis=-1)
    return dx, dtheta, x2


def backward(dy, dld, y, *theta):
    c1 = y.shape[-1] // 2
    y1, y2 = split_channels(y, c1)
    x1 = y1
    dx, dtheta, x2 = _grads(dy, dld, x1, y2, theta, stored=False)
    x = jnp.concatenate([x1, x2], axis=-1)
    return (dx,) + tuple(dtheta) + (x,)


def backward_stored(dy, dld, x, *theta):
    c1 = x.shape[-1] // 2
    x1, x2 = split_channels(x, c1)
    dx, dtheta, _ = _grads(dy, dld, x1, x2, theta, stored=True)
    return (dx,) + tuple(dtheta)
