"""Hyperbolic (fully hyperbolic CNN, Lensink/Peters/Haber) layer.

State (N, H, W, 2C) = [x_prev | x_curr]; one leapfrog step
    y_prev = x_curr
    y_curr = 2 x_curr - x_prev + g(x_curr),   g(x) = alpha K^T sigma(K x)
with K a 3x3 conv (C -> hidden) and K^T its adjoint. Volume preserving
(logdet = 0) and invertible by construction.

Hand-written backward:
    dx_curr = dy_prev + 2 dy_curr + Jg(x_curr)^T dy_curr
    dx_prev = -dy_curr
dK via jax.vjp over g (inner-net-by-AD, like the coupling conditioners).
"""

import jax
import jax.numpy as jnp
from jax import lax

from ..kernels import backend as k

ALPHA = 0.2


def param_specs(cfg):
    c = cfg["c"] // 2  # per-half channels
    return [("kw", (3, 3, c, cfg["hidden"]))]


def _conv(x, w):
    return lax.conv_general_dilated(
        x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_t(y, w):
    # adjoint of stride-1 SAME conv3x3: spatially flipped, IO-swapped kernel
    return _conv(y, jnp.flip(w, (0, 1)).swapaxes(2, 3))


def _g(x, kw):
    return ALPHA * _conv_t(jnp.tanh(_conv(x, kw)), kw)


def _split(x):
    c = x.shape[-1] // 2
    return x[..., :c], x[..., c:]


def forward(x, kw):
    x_prev, x_curr = _split(x)
    y_prev, y_curr = k.hyperbolic_core_forward(x_prev, x_curr, _g(x_curr, kw))
    return (jnp.concatenate([y_prev, y_curr], axis=-1),
            jnp.zeros((x.shape[0],), dtype=x.dtype))


def inverse(y, kw):
    y_prev, y_curr = _split(y)
    x_prev, x_curr = k.hyperbolic_core_inverse(y_prev, y_curr, _g(y_prev, kw))
    return (jnp.concatenate([x_prev, x_curr], axis=-1),)


def _grads(dy, x_curr, kw):
    dy_prev, dy_curr = _split(dy)
    _, g_vjp = jax.vjp(lambda xc, w: _g(xc, w), x_curr, kw)
    gx, dkw = g_vjp(dy_curr)
    dx_curr = dy_prev + 2.0 * dy_curr + gx
    dx_prev = -dy_curr
    return jnp.concatenate([dx_prev, dx_curr], axis=-1), dkw


def backward(dy, dld, y, kw):
    del dld
    y_prev, y_curr = _split(y)
    x_curr = y_prev
    x_prev = 2.0 * x_curr - y_curr + _g(x_curr, kw)
    dx, dkw = _grads(dy, x_curr, kw)
    return dx, dkw, jnp.concatenate([x_prev, x_curr], axis=-1)


def backward_stored(dy, dld, x, kw):
    del dld
    _, x_curr = _split(x)
    dx, dkw = _grads(dy, x_curr, kw)
    return dx, dkw
