"""HINT: Hierarchical Invertible Neural Transport (Kruse et al., AAAI'21).

A recursive coupling on dense (N, D) inputs:

    H(x, depth):
        x1, x2 = split(x)
        y1 = H(x1, depth-1)                       # recurse on the pass-half
        raw, t = MLP_node(x1)                     # conditioned on the INPUT half
        y2a = 2*sigmoid(raw) * x2 + t
        y2 = H(y2a, depth-1)                      # recurse on the transformed half
        return concat(y1, y2)

Leaves (depth 0 or D < 4) are identities. The full Jacobian is triangular
down to the leaf granularity, giving HINT its dense-triangular transport.

Parameters: one conditioner MLP per internal node, flattened in preorder
(node path "r", "rl", "rr", ...). The hand-written backward recurses the
same tree, reusing the affine-coupling pullback at every node, so the
memory behaviour matches the flat couplings (x recomputed from y).
"""

import jax
import jax.numpy as jnp

from ..kernels.ref import coupling_scale
from .conditioner import mlp_apply, mlp_param_specs, split_raw_t

MIN_D = 4


def _split_dims(d):
    d1 = d // 2
    return d1, d - d1


def _nodes(d, depth, path="r"):
    """Preorder list of (path, d1, d2) for every internal node."""
    if depth == 0 or d < MIN_D:
        return []
    d1, d2 = _split_dims(d)
    out = [(path, d1, d2)]
    out += _nodes(d1, depth - 1, path + "l")
    out += _nodes(d2, depth - 1, path + "t")
    return out


def param_specs(cfg):
    specs = []
    for path, d1, d2 in _nodes(cfg["d"], cfg["depth"]):
        for name, shape in mlp_param_specs(d1, cfg["hidden"], 2 * d2):
            specs.append((f"{path}_{name}", shape))
    return specs


def _theta_tree(cfg, theta):
    """Map flat theta tuple back to {path: (6 params)}."""
    tree = {}
    i = 0
    for path, _, _ in _nodes(cfg["d"], cfg["depth"]):
        tree[path] = tuple(theta[i:i + 6])
        i += 6
    assert i == len(theta)
    return tree


def _fwd(x, depth, path, tree):
    d = x.shape[-1]
    if depth == 0 or d < MIN_D:
        return x, jnp.zeros((x.shape[0],), dtype=x.dtype)
    d1, _ = _split_dims(d)
    x1, x2 = x[:, :d1], x[:, d1:]
    y1, ld1 = _fwd(x1, depth - 1, path + "l", tree)
    raw, t = split_raw_t(mlp_apply(x1, *tree[path]))
    s = coupling_scale(raw)
    y2a = s * x2 + t
    ld_aff = jnp.sum(jnp.log(s), axis=1)
    y2, ld2 = _fwd(y2a, depth - 1, path + "t", tree)
    return jnp.concatenate([y1, y2], axis=-1), ld1 + ld_aff + ld2


def _inv(y, depth, path, tree):
    d = y.shape[-1]
    if depth == 0 or d < MIN_D:
        return y
    d1, _ = _split_dims(d)
    y1, y2 = y[:, :d1], y[:, d1:]
    x1 = _inv(y1, depth - 1, path + "l", tree)
    y2a = _inv(y2, depth - 1, path + "t", tree)
    raw, t = split_raw_t(mlp_apply(x1, *tree[path]))
    x2 = (y2a - t) / coupling_scale(raw)
    return jnp.concatenate([x1, x2], axis=-1)


def _bwd(dy, dld, y, depth, path, tree, grads):
    """Returns (dx, x); accumulates dtheta into grads[path]."""
    d = y.shape[-1]
    if depth == 0 or d < MIN_D:
        return dy, y
    d1, _ = _split_dims(d)
    dy1, dy2 = dy[:, :d1], dy[:, d1:]
    y1, y2 = y[:, :d1], y[:, d1:]
    dx1a, x1 = _bwd(dy1, dld, y1, depth - 1, path + "l", tree, grads)
    dy2a, y2a = _bwd(dy2, dld, y2, depth - 1, path + "t", tree, grads)
    out, mlp_vjp = jax.vjp(lambda a, *th: mlp_apply(a, *th), x1, *tree[path])
    raw, t = split_raw_t(out)
    s = coupling_scale(raw)
    x2 = (y2a - t) / s
    dx2 = dy2a * s
    ds = dy2a * x2 + dld[:, None] / s
    draw = ds * s * (1.0 - 0.5 * s)
    pulled = mlp_vjp(jnp.concatenate([draw, dy2a], axis=-1))
    dx1 = dx1a + pulled[0]
    grads[path] = tuple(pulled[1:])
    return (jnp.concatenate([dx1, dx2], axis=-1),
            jnp.concatenate([x1, x2], axis=-1))


def make(cfg):
    """Build (forward, inverse, backward, backward_stored) closures."""
    depth = cfg["depth"]

    def forward(x, *theta):
        return _fwd(x, depth, "r", _theta_tree(cfg, theta))

    def inverse(y, *theta):
        return (_inv(y, depth, "r", _theta_tree(cfg, theta)),)

    def backward(dy, dld, y, *theta):
        tree = _theta_tree(cfg, theta)
        grads = {}
        dx, x = _bwd(dy, dld, y, depth, "r", tree, grads)
        flat = []
        for p, _, _ in _nodes(cfg["d"], depth):
            flat.extend(grads[p])
        return (dx,) + tuple(flat) + (x,)

    def backward_stored(dy, dld, x, *theta):
        # identical math; recover y cheaply from x then run the same pullback
        tree = _theta_tree(cfg, theta)
        y, _ = _fwd(x, depth, "r", tree)
        grads = {}
        dx, _ = _bwd(dy, dld, y, depth, "r", tree, grads)
        flat = []
        for p, _, _ in _nodes(cfg["d"], depth):
            flat.extend(grads[p])
        return (dx,) + tuple(flat)

    return forward, inverse, backward, backward_stored
