"""L2 layer definitions: forward / inverse / backward / backward_stored.

Every layer module exposes:
  param_specs(cfg) -> [(name, shape), ...]
  forward(x, *params)            -> (y, logdet)
  inverse(y, *params)            -> (x,)
  backward(dy, dld, y, *params)  -> (dx, *dparams, x)    # recomputes x
  backward_stored(dy, dld, x, *params) -> (dx, *dparams) # AD-baseline tape
plus conditional variants where applicable (extra `cond` operand right
after the activation, and a `dcond` result right after `dx`).
"""
