"""Channel-reverse permutation (RealNVP's alternating mask), logdet 0.

Self-inverse orthogonal map; gradient = inverse = reverse.
"""

import jax.numpy as jnp


def param_specs(cfg):
    return []


def _rev(x):
    return x[..., ::-1]


def forward(x):
    return _rev(x), jnp.zeros((x.shape[0],), dtype=x.dtype)


def inverse(y):
    return (_rev(y),)


def backward(dy, dld, y):
    del dld
    return _rev(dy), _rev(y)


def backward_stored(dy, dld, x):
    del dld, x
    return (_rev(dy),)
