"""ActNorm layer (image, NHWC): y = x * exp(log_s) + b.

Hand-written gradients (paper §3):
    dx      = dy * s
    dlog_s  = sum_{n,h,w} dy * (y - b)          [since x*s = y - b]
              + (sum_n dld) * H*W               [logdet = H*W*sum(log_s)]
    db      = sum_{n,h,w} dy
backward recomputes x from y via the inverse; backward_stored takes the
taped x instead (the AD-baseline path).
"""

import jax.numpy as jnp

from ..kernels import backend as k
from ..kernels import ref


def param_specs(cfg):
    return [("log_s", (cfg["c"],)), ("b", (cfg["c"],))]


def forward(x, log_s, b):
    return k.actnorm_forward(x, log_s, b)


def inverse(y, log_s, b):
    return (k.actnorm_inverse(y, log_s, b),)


def _grads(dy, dld, x, y, log_s, b):
    s = jnp.exp(log_s)
    dx = dy * s
    spatial = x.shape[1] * x.shape[2]
    dlog_s = jnp.sum(dy * (y - b), axis=(0, 1, 2)) + jnp.sum(dld) * spatial
    db = jnp.sum(dy, axis=(0, 1, 2))
    return dx, dlog_s, db


def backward(dy, dld, y, log_s, b):
    x = k.actnorm_inverse(y, log_s, b)
    dx, dlog_s, db = _grads(dy, dld, x, y, log_s, b)
    return dx, dlog_s, db, x


def backward_stored(dy, dld, x, log_s, b):
    y = x * jnp.exp(log_s) + b
    dx, dlog_s, db = _grads(dy, dld, x, y, log_s, b)
    return dx, dlog_s, db
