"""Additive (NICE) coupling layer (image, NHWC).

    y = concat(x1, x2 + CNN(x1)),  logdet = 0.

Backward: dx2 = dy2; dx1 = dy1 + vjp_CNN(dy2); x2 = y2 - CNN(y1).
"""

import jax
import jax.numpy as jnp

from .conditioner import cnn_apply, cnn_param_specs
from .coupling_glow import split_channels


def param_specs(cfg):
    c = cfg["c"]
    c1 = c // 2
    c2 = c - c1
    return cnn_param_specs(c1, cfg["hidden"], c2)


def forward(x, *theta):
    c1 = x.shape[-1] // 2
    x1, x2 = split_channels(x, c1)
    y2 = x2 + cnn_apply(x1, *theta)
    logdet = jnp.zeros((x.shape[0],), dtype=x.dtype)
    return jnp.concatenate([x1, y2], axis=-1), logdet


def inverse(y, *theta):
    c1 = y.shape[-1] // 2
    y1, y2 = split_channels(y, c1)
    x2 = y2 - cnn_apply(y1, *theta)
    return (jnp.concatenate([y1, x2], axis=-1),)


def _grads(dy, x1, theta):
    c1 = x1.shape[-1]
    dy1, dy2 = split_channels(dy, c1)
    nn_out, cnn_vjp = jax.vjp(lambda x1_, *th: cnn_apply(x1_, *th), x1, *theta)
    pulled = cnn_vjp(dy2)
    dx1 = dy1 + pulled[0]
    dx = jnp.concatenate([dx1, dy2], axis=-1)
    return dx, pulled[1:], nn_out


def backward(dy, dld, y, *theta):
    del dld
    c1 = y.shape[-1] // 2
    y1, y2 = split_channels(y, c1)
    dx, dtheta, nn_out = _grads(dy, y1, theta)
    x = jnp.concatenate([y1, y2 - nn_out], axis=-1)
    return (dx,) + tuple(dtheta) + (x,)


def backward_stored(dy, dld, x, *theta):
    del dld
    c1 = x.shape[-1] // 2
    x1, _ = split_channels(x, c1)
    dx, dtheta, _ = _grads(dy, x1, theta)
    return (dx,) + tuple(dtheta)
