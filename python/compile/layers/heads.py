"""Loss heads lowered as standalone artifacts.

gaussian_logp:  z -> (logp,)          per-sample standard-normal log-density
nll_seed:       z -> (dz, dld)        gradient seeds for the NLL objective
                                      L = -mean_n(logp_n + logdet_n):
                                      dz = z/N, dld = -1/N.
The scalar loss itself is assembled on the rust side from logp + the
accumulated per-layer logdets (tiny (N,) vectors).
"""

import jax.numpy as jnp

from ..kernels.ref import gaussian_logp as _logp


def gaussian_logp(z):
    return (_logp(z),)


def nll_seed(z):
    n = z.shape[0]
    dz = z / n
    dld = jnp.full((n,), -1.0 / n, dtype=z.dtype)
    return dz, dld
