"""GLOW 1x1 invertible convolution, Householder-orthogonal parameterization.

W = H(v1) H(v2) H(v3) with H(v) = I - 2 v v^T / v^T v; orthogonal, so the
inverse is W^T and log|det| = 0 (InvertibleNetworks.jl's Conv1x1).

Hand-written flow-level gradients:
    y_p = W x_p   =>   dx_p = W^T dy_p,   dW = sum_p dy_p x_p^T
dW is pulled back onto (v1, v2, v3) with jax.vjp over the tiny W-builder
(the "inner function by AD" pattern — W construction is O(C^2), not a
memory concern).
"""

import jax
import jax.numpy as jnp

from ..kernels import backend as k
from ..kernels.ref import householder_matrix


def param_specs(cfg):
    c = cfg["c"]
    return [("v1", (c,)), ("v2", (c,)), ("v3", (c,))]


def _w(v1, v2, v3):
    return householder_matrix([v1, v2, v3])


def forward(x, v1, v2, v3):
    w = _w(v1, v2, v3)
    y = k.conv1x1_apply(x, w)
    return y, jnp.zeros((x.shape[0],), dtype=x.dtype)


def inverse(y, v1, v2, v3):
    w = _w(v1, v2, v3)
    return (k.conv1x1_unapply(y, w),)


def _grads(dy, x, v1, v2, v3):
    w, w_vjp = jax.vjp(_w, v1, v2, v3)
    dx = k.conv1x1_unapply(dy, w)  # W^T dy
    # dW_{ij} = sum_p dy_{pi} x_{pj}
    dw = jnp.einsum("...i,...j->ij", dy, x)
    dv1, dv2, dv3 = w_vjp(dw)
    return dx, dv1, dv2, dv3, w


def backward(dy, dld, y, v1, v2, v3):
    del dld  # logdet == 0 identically
    w = _w(v1, v2, v3)
    x = k.conv1x1_unapply(y, w)
    dx, dv1, dv2, dv3, _ = _grads(dy, x, v1, v2, v3)
    return dx, dv1, dv2, dv3, x


def backward_stored(dy, dld, x, v1, v2, v3):
    del dld
    dx, dv1, dv2, dv3, _ = _grads(dy, x, v1, v2, v3)
    return dx, dv1, dv2, dv3
