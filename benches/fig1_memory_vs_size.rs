//! Regenerates the paper's Figure 1: peak training memory vs spatial image
//! size, invertible (InvertibleNetworks.jl) vs stored (PyTorch/normflows),
//! GLOW with 3 input channels, batch 8, under a 40 GB budget.
//!
//!     cargo bench --bench fig1_memory_vs_size

use std::path::PathBuf;

fn main() {
    let rt = invertnet::Runtime::new(&PathBuf::from("artifacts"))
        .expect("run `make artifacts` first");
    invertnet::bench_figs::fig1(&rt, 40.0).unwrap();
}
