//! Regenerates the paper's Figure 1: peak training memory vs spatial image
//! size, invertible (InvertibleNetworks.jl) vs stored (PyTorch/normflows),
//! GLOW with 3 input channels, batch 8, under a 40 GB budget.
//!
//!     cargo bench --bench fig1_memory_vs_size
//!
//! Runs hermetically on the RefBackend; set INVERTNET_ARTIFACTS (with a
//! `--features xla` build) to measure through PJRT instead.

use invertnet::Engine;

fn main() {
    let mut builder = Engine::builder();
    if let Ok(dir) = std::env::var("INVERTNET_ARTIFACTS") {
        builder = builder.artifacts(dir);
    }
    let engine = builder.build().expect("engine boot");
    invertnet::bench_figs::fig1(&engine, 40.0).unwrap();
}
