//! Regenerates the paper's Figure 2: peak training memory vs network
//! depth — constant for the invertible executor, linear for the
//! autodiff-style stored executor.
//!
//!     cargo bench --bench fig2_memory_vs_depth

use std::path::PathBuf;

fn main() {
    let rt = invertnet::Runtime::new(&PathBuf::from("artifacts"))
        .expect("run `make artifacts` first");
    invertnet::bench_figs::fig2(&rt, 40.0).unwrap();
}
