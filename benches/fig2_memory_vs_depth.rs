//! Regenerates the paper's Figure 2: peak training memory vs network
//! depth — constant for the invertible schedule, linear for the
//! autodiff-style stored schedule.
//!
//!     cargo bench --bench fig2_memory_vs_depth
//!
//! Runs hermetically on the RefBackend; set INVERTNET_ARTIFACTS (with a
//! `--features xla` build) to measure through PJRT instead.

use invertnet::Engine;

fn main() {
    let mut builder = Engine::builder();
    if let Ok(dir) = std::env::var("INVERTNET_ARTIFACTS") {
        builder = builder.artifacts(dir);
    }
    let engine = builder.build().expect("engine boot");
    invertnet::bench_figs::fig2(&engine, 40.0).unwrap();
}
