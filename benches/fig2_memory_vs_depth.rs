//! Regenerates the paper's Figure 2: peak training memory vs network
//! depth — constant for the invertible schedule, linear for the
//! autodiff-style stored schedule.
//!
//!     cargo bench --bench fig2_memory_vs_depth
//!
//! Prints the full paper table (measured + planner-model rows) and then
//! runs the gated library suite [`invertnet::perf::memory_vs_depth`],
//! writing `BENCH_memory_vs_depth.json` (override with
//! INVERTNET_FIG2_JSON — each bench binary has its own override so
//! `cargo bench` runs don't clobber each other's records) so figure
//! regenerations also land on the perf trajectory. Runs hermetically on
//! the RefBackend; set INVERTNET_ARTIFACTS (with a `--features xla`
//! build) to measure through PJRT instead.

use std::path::PathBuf;

use invertnet::perf::{memory_vs_depth, Scale, SuiteReport};
use invertnet::Engine;

fn main() {
    let mut builder = Engine::builder();
    if let Ok(dir) = std::env::var("INVERTNET_ARTIFACTS") {
        builder = builder.artifacts(dir);
    }
    let engine = builder.build().expect("engine boot");
    invertnet::bench_figs::fig2(&engine, 40.0).unwrap();
    let mut report = SuiteReport::new("memory_vs_depth");
    report.absorb(memory_vs_depth(&engine, Scale::Full).expect("suite"));
    let out = PathBuf::from(std::env::var("INVERTNET_FIG2_JSON")
        .unwrap_or_else(|_| "BENCH_memory_vs_depth.json".to_string()));
    report.write(engine.backend_name(), engine.default_threads(), &out)
        .expect("write report");
}
