//! Serving throughput: coalesced micro-batching vs one-request-per-pass.
//!
//! Many concurrent clients fire single-item `sample`/`score` requests at
//! the transport-agnostic server core. `max-batch 1` is the unbatched
//! baseline (every request pays a full pass); `max-batch 8` lets the
//! scheduler coalesce, amortizing per-pass overhead across requests —
//! the tentpole claim is >= 2x throughput at max-batch >= 8.
//!
//!     cargo bench --bench serve_latency
//!
//! Machine-readable results: one `BENCH {json}` line on stdout, also
//! written to `bench_serve_latency.json` (override with
//! INVERTNET_SERVE_JSON).

use std::time::{Duration, Instant};

use invertnet::api::Engine;
use invertnet::serve::{BatchConfig, Registry, Request, Response, Server,
                       StatsSnapshot};
use invertnet::util::json::Json;
use invertnet::util::rng::Pcg64;
use invertnet::Tensor;

const NET: &str = "realnvp2d";
const CLIENTS: usize = 8;
const REQS_PER_CLIENT: usize = 150;

fn boot(max_batch: usize) -> Server {
    let registry = Registry::new(Engine::native().expect("engine boot"), 2);
    registry.register_untrained(NET, 3).expect("register model");
    Server::new(registry, BatchConfig {
        max_batch,
        max_delay: Duration::from_micros(300),
        workers: 2,
        queue_cap: 1024,
    }).allow_untrained()
}

/// Fire `CLIENTS * REQS_PER_CLIENT` single-item requests, return
/// (requests/sec, stats).
fn run_load(server: &Server, op: &str) -> (f64, StatsSnapshot) {
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for client in 0..CLIENTS as u64 {
            scope.spawn(move || {
                let mut rng = Pcg64::new(0xbe7c ^ client);
                for i in 0..REQS_PER_CLIENT as u64 {
                    let req = match op {
                        "sample" => Request::Sample {
                            model: None,
                            n: 1,
                            temperature: 1.0,
                            seed: client * 10_000 + i,
                            cond: None,
                        },
                        _ => Request::Score {
                            model: None,
                            x: Tensor {
                                shape: vec![1, 2],
                                data: rng.normal_vec(2),
                            },
                            cond: None,
                        },
                    };
                    let resp = server.handle(req);
                    assert!(!resp.is_error(), "{op}: {resp:?}");
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let total = (CLIENTS * REQS_PER_CLIENT) as f64;
    let Response::Stats(snap) = server.handle(Request::Stats) else {
        panic!("stats failed")
    };
    (total / elapsed, snap)
}

fn stats_json(rps: f64, s: &StatsSnapshot) -> Json {
    Json::obj(vec![
        ("reqs_per_sec", Json::Num(rps)),
        ("mean_batch", Json::Num(s.mean_batch)),
        ("mean_items", Json::Num(s.mean_items)),
        ("p50_us", Json::Num(s.p50_us as f64)),
        ("p99_us", Json::Num(s.p99_us as f64)),
        ("batches", Json::Num(s.batches as f64)),
    ])
}

fn main() {
    let backend = Engine::native().expect("engine").backend_name().to_string();
    println!("# serving throughput, {CLIENTS} clients x {REQS_PER_CLIENT} \
              single-item requests, net {NET}, backend {backend}");
    let mut doc = vec![
        ("bench", Json::Str("serve_latency".to_string())),
        ("backend", Json::Str(backend)),
        ("net", Json::Str(NET.to_string())),
        ("clients", Json::Num(CLIENTS as f64)),
        ("requests", Json::Num((CLIENTS * REQS_PER_CLIENT) as f64)),
    ];

    for op in ["score", "sample"] {
        // unbatched baseline: every request is its own pass
        let base = boot(1);
        let (rps_1, snap_1) = run_load(&base, op);
        // coalesced: up to 8 requests share one pass
        let coal = boot(8);
        let (rps_8, snap_8) = run_load(&coal, op);

        let speedup = rps_8 / rps_1;
        println!(
            "{op:<7} max-batch 1: {rps_1:>9.0} req/s  p50 {:>5}us  \
             p99 {:>6}us  mean batch {:.2}",
            snap_1.p50_us, snap_1.p99_us, snap_1.mean_batch);
        println!(
            "{op:<7} max-batch 8: {rps_8:>9.0} req/s  p50 {:>5}us  \
             p99 {:>6}us  mean batch {:.2}   {speedup:.2}x",
            snap_8.p50_us, snap_8.p99_us, snap_8.mean_batch);

        doc.push((match op {
            "sample" => "sample_unbatched",
            _ => "score_unbatched",
        }, stats_json(rps_1, &snap_1)));
        doc.push((match op {
            "sample" => "sample_coalesced",
            _ => "score_coalesced",
        }, stats_json(rps_8, &snap_8)));
        doc.push((match op {
            "sample" => "sample_speedup",
            _ => "score_speedup",
        }, Json::Num(speedup)));
    }

    let doc = Json::obj(doc);
    println!("BENCH {}", doc.to_string());
    let out = std::env::var("INVERTNET_SERVE_JSON")
        .unwrap_or_else(|_| "bench_serve_latency.json".to_string());
    if let Err(e) = std::fs::write(&out, doc.to_string_pretty()) {
        eprintln!("could not write {out}: {e}");
    } else {
        println!("# serve-latency results -> {out}");
    }
}
