//! Serving throughput: coalesced micro-batching vs one-request-per-pass.
//!
//! Thin wrapper over the library suite [`invertnet::perf::serve_latency`]
//! (full scale): many concurrent clients fire single-item `sample`/`score`
//! requests; `max-batch 1` is the unbatched baseline, `max-batch 8` lets
//! the scheduler coalesce — the tentpole claim is >= 2x throughput.
//!
//!     cargo bench --bench serve_latency
//!
//! Machine-readable results: one `BENCH {json}` line on stdout and
//! `BENCH_serve.json` (override with INVERTNET_SERVE_JSON), carrying the
//! environment block. The CLI equivalent is `invertnet bench --suite serve`.

use std::path::PathBuf;

use invertnet::perf::{serve_latency, Scale, SuiteReport};
use invertnet::Engine;

fn main() {
    let engine = Engine::native().expect("engine boot");
    println!("# serving throughput, backend {}", engine.backend_name());
    let mut report = SuiteReport::new("serve");
    report.absorb(serve_latency(&engine, Scale::Full).expect("suite"));
    report.print();
    let out = PathBuf::from(std::env::var("INVERTNET_SERVE_JSON")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string()));
    report.write(engine.backend_name(), engine.default_threads(), &out)
        .expect("write report");
}
