//! Throughput + recompute-overhead ablation (DESIGN.md experiment index).
//!
//! The memory savings of invertible backprop are bought with inverse
//! recomputation in the backward pass; this bench quantifies that
//! wall-clock trade on the same layer programs, plus end-to-end train-step
//! latency for the example networks, the checkpoint-hybrid schedule, and
//! the data-parallel thread-scaling curve.
//!
//!     cargo bench --bench throughput
//!
//! Machine-readable results: the thread-scaling curve is printed as a
//! one-line `BENCH {json}` record on stdout and written to
//! `bench_throughput.json` (override the path with INVERTNET_BENCH_JSON).

use invertnet::coordinator::{ActivationSchedule, CheckpointEveryK, ExecMode};
use invertnet::data::synth_images;
use invertnet::train::ParallelTrainer;
use invertnet::util::bench::{bench, report};
use invertnet::util::json::Json;
use invertnet::util::rng::Pcg64;
use invertnet::{Engine, Flow, Tensor};

fn batch_for(flow: &Flow, rng: &mut Pcg64) -> Tensor {
    let s = &flow.def.in_shape;
    if s.len() == 4 {
        synth_images(s[0], s[1], s[2], s[3], rng)
    } else {
        Tensor { shape: s.clone(), data: rng.normal_vec(s.iter().product()) }
    }
}

fn main() {
    let mut builder = Engine::builder();
    if let Ok(dir) = std::env::var("INVERTNET_ARTIFACTS") {
        builder = builder.artifacts(dir);
    }
    let engine = builder.build().expect("engine boot");
    println!("# train-step latency, invertible vs stored (same layer programs, \
              backend {})", engine.backend_name());
    let mut rng = Pcg64::new(11);
    for net in ["realnvp2d", "hint8d", "glow_bench32", "glow_fig2_d8", "hyper16"] {
        let flow = engine.flow(net).unwrap();
        let params = flow.init_params(3).unwrap();
        let x = batch_for(&flow, &mut rng);

        let schedules: [(&str, &dyn ActivationSchedule); 3] = [
            ("invertible", &ExecMode::Invertible),
            ("stored", &ExecMode::Stored),
            ("checkpoint:4", &CheckpointEveryK(4)),
        ];
        let mut stats = Vec::new();
        for (name, sched) in schedules {
            let s = bench(2, 8, || {
                flow.train_step(&x, None, &params, sched).unwrap();
            });
            report(&format!("{net}/{name}"), &s);
            stats.push(s);
        }
        println!(
            "{net:<48} recompute overhead: {:+.1}% wall-clock for O(1) memory",
            (stats[0].mean_s / stats[1].mean_s - 1.0) * 100.0
        );

        // phase split: forward-only vs full step
        let fs = bench(1, 8, || {
            flow.forward(&x, None, &params).unwrap();
        });
        report(&format!("{net}/forward_only"), &fs);
        engine.clear_cache();
    }

    // ---- thread scaling: ParallelTrainer over the small + medium nets ----
    println!("\n# data-parallel thread scaling (invertible schedule)");
    let mut curve: Vec<Json> = Vec::new();
    for net in ["realnvp2d", "glow_bench32"] {
        let flow = engine.flow(net).unwrap();
        let params = flow.init_params(3).unwrap();
        let x = batch_for(&flow, &mut rng);
        let mut base_sps = 0.0f64;
        for threads in [1usize, 2, 4, 8] {
            let trainer = ParallelTrainer::new(threads);
            let s = bench(1, 5, || {
                trainer
                    .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
                    .unwrap();
            });
            let sps = 1.0 / s.mean_s;
            if threads == 1 {
                base_sps = sps;
            }
            let speedup = sps / base_sps;
            report(&format!("{net}/threads={threads}"), &s);
            println!("{:<48} {sps:>8.2} steps/s  {speedup:>5.2}x vs 1 thread",
                     format!("{net}/threads={threads}"));
            curve.push(Json::obj(vec![
                ("net", Json::Str(net.to_string())),
                ("threads", Json::Num(threads as f64)),
                ("mean_s", Json::Num(s.mean_s)),
                ("steps_per_sec", Json::Num(sps)),
                ("speedup_vs_1_thread", Json::Num(speedup)),
            ]));
        }
        engine.clear_cache();
    }
    let doc = Json::obj(vec![
        ("bench", Json::Str("throughput".to_string())),
        ("backend", Json::Str(engine.backend_name().to_string())),
        ("host_parallelism", Json::Num(
            std::thread::available_parallelism().map_or(0, |p| p.get()) as f64)),
        ("thread_scaling", Json::Arr(curve)),
    ]);
    println!("BENCH {}", doc.to_string());
    let out = std::env::var("INVERTNET_BENCH_JSON")
        .unwrap_or_else(|_| "bench_throughput.json".to_string());
    if let Err(e) = std::fs::write(&out, doc.to_string_pretty()) {
        eprintln!("could not write {out}: {e}");
    } else {
        println!("# thread-scaling curve -> {out}");
    }
}
