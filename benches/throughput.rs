//! Throughput + recompute-overhead ablation (DESIGN.md experiment index).
//!
//! The memory savings of invertible backprop are bought with inverse
//! recomputation in the backward pass; this bench quantifies that
//! wall-clock trade on the same layer programs, plus end-to-end train-step
//! latency for the example networks and the checkpoint-hybrid schedule.
//!
//!     cargo bench --bench throughput

use invertnet::coordinator::{ActivationSchedule, CheckpointEveryK, ExecMode};
use invertnet::data::synth_images;
use invertnet::util::bench::{bench, report};
use invertnet::util::rng::Pcg64;
use invertnet::{Engine, Flow, Tensor};

fn batch_for(flow: &Flow, rng: &mut Pcg64) -> Tensor {
    let s = &flow.def.in_shape;
    if s.len() == 4 {
        synth_images(s[0], s[1], s[2], s[3], rng)
    } else {
        Tensor { shape: s.clone(), data: rng.normal_vec(s.iter().product()) }
    }
}

fn main() {
    let mut builder = Engine::builder();
    if let Ok(dir) = std::env::var("INVERTNET_ARTIFACTS") {
        builder = builder.artifacts(dir);
    }
    let engine = builder.build().expect("engine boot");
    println!("# train-step latency, invertible vs stored (same layer programs, \
              backend {})", engine.backend_name());
    let mut rng = Pcg64::new(11);
    for net in ["realnvp2d", "hint8d", "glow_bench32", "glow_fig2_d8", "hyper16"] {
        let flow = engine.flow(net).unwrap();
        let params = flow.init_params(3).unwrap();
        let x = batch_for(&flow, &mut rng);

        let schedules: [(&str, &dyn ActivationSchedule); 3] = [
            ("invertible", &ExecMode::Invertible),
            ("stored", &ExecMode::Stored),
            ("checkpoint:4", &CheckpointEveryK(4)),
        ];
        let mut stats = Vec::new();
        for (name, sched) in schedules {
            let s = bench(2, 8, || {
                flow.train_step(&x, None, &params, sched).unwrap();
            });
            report(&format!("{net}/{name}"), &s);
            stats.push(s);
        }
        println!(
            "{net:<48} recompute overhead: {:+.1}% wall-clock for O(1) memory",
            (stats[0].mean_s / stats[1].mean_s - 1.0) * 100.0
        );

        // phase split: forward-only vs full step
        let fs = bench(1, 8, || {
            flow.forward(&x, None, &params).unwrap();
        });
        report(&format!("{net}/forward_only"), &fs);
        engine.clear_cache();
    }
}
