//! Throughput + recompute-overhead ablation (DESIGN.md experiment index).
//!
//! The memory savings of invertible backprop are bought with inverse
//! recomputation in the backward pass; this bench quantifies that
//! wall-clock trade on the same executables, plus end-to-end train-step
//! latency for the example networks.
//!
//!     cargo bench --bench throughput

use std::path::PathBuf;

use invertnet::coordinator::{ExecMode, FlowSession};
use invertnet::data::synth_images;
use invertnet::flow::ParamStore;
use invertnet::util::bench::{bench, report};
use invertnet::util::rng::Pcg64;
use invertnet::{MemoryLedger, Runtime, Tensor};

fn batch_for(session: &FlowSession, rng: &mut Pcg64) -> Tensor {
    let s = &session.def.in_shape;
    if s.len() == 4 {
        synth_images(s[0], s[1], s[2], s[3], rng)
    } else {
        Tensor { shape: s.clone(), data: rng.normal_vec(s.iter().product()) }
    }
}

fn main() {
    let rt = Runtime::new(&PathBuf::from("artifacts"))
        .expect("run `make artifacts` first");
    println!("# train-step latency, invertible vs stored (same executables)");
    let mut rng = Pcg64::new(11);
    for net in ["realnvp2d", "hint8d", "glow_bench32", "glow_fig2_d8", "hyper16"] {
        let session = FlowSession::new(&rt, net, MemoryLedger::new()).unwrap();
        let params = ParamStore::init(&session.def, &rt.manifest, 3).unwrap();
        let x = batch_for(&session, &mut rng);

        let mut stats = Vec::new();
        for mode in [ExecMode::Invertible, ExecMode::Stored] {
            let s = bench(2, 8, || {
                session.train_step(&x, None, &params, mode).unwrap();
            });
            report(&format!("{net}/{}", mode.name()), &s);
            stats.push(s);
        }
        println!(
            "{net:<48} recompute overhead: {:+.1}% wall-clock for O(1) memory",
            (stats[0].mean_s / stats[1].mean_s - 1.0) * 100.0
        );

        // phase split: forward-only vs full step (invertible)
        let fs = bench(1, 8, || {
            session.forward(&x, None, &params, false).unwrap();
        });
        report(&format!("{net}/forward_only"), &fs);

        // whole-network XLA-fused full-AD program (the upper bound a
        // monolithic AD framework could reach; no per-layer dispatch)
        if rt.manifest.monoliths.contains_key(net) {
            let mono = rt.monolith_entry(net).unwrap();
            let x_lit = x.to_literal().unwrap();
            let flat: Vec<xla::Literal> = params.tensors.iter().flatten()
                .map(|t| t.to_literal().unwrap()).collect();
            let s = bench(2, 8, || {
                let mut args = vec![&x_lit];
                args.extend(flat.iter());
                mono.execute_t(&args).unwrap();
            });
            report(&format!("{net}/full_vjp_monolith"), &s);
        }
        rt.clear_cache();
    }
}
