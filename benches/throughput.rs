//! Throughput + recompute-overhead ablation (DESIGN.md experiment index).
//!
//! Thin wrapper over the library suite [`invertnet::perf::train_throughput`]
//! (full scale): train-step latency per activation schedule, the
//! recompute-overhead trade, the data-parallel thread-scaling curve, and
//! the threaded inference hot path (relaxed-batch `log_density` /
//! `sample` rows/sec vs thread count).
//!
//!     cargo bench --bench throughput
//!
//! Machine-readable results: one `BENCH {json}` line on stdout and
//! `BENCH_throughput.json` (override the path with INVERTNET_BENCH_JSON),
//! carrying the environment block (git rev, threads, cpus, profile).
//! The CLI equivalent is `invertnet bench --suite throughput`.

use std::path::PathBuf;

use invertnet::perf::{train_throughput, Scale, SuiteReport};
use invertnet::Engine;

fn main() {
    let mut builder = Engine::builder();
    if let Ok(dir) = std::env::var("INVERTNET_ARTIFACTS") {
        builder = builder.artifacts(dir);
    }
    let engine = builder.build().expect("engine boot");
    println!("# train/inference throughput, backend {}",
             engine.backend_name());
    let mut report = SuiteReport::new("throughput");
    report.absorb(train_throughput(&engine, Scale::Full).expect("suite"));
    report.print();
    let out = PathBuf::from(std::env::var("INVERTNET_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_throughput.json".to_string()));
    report.write(engine.backend_name(), engine.default_threads(), &out)
        .expect("write report");
}
