//! The posterior subsystem's end-to-end correctness oracle: on the
//! linear-Gaussian inverse problem the true posterior is known in closed
//! form, so the amortized pipeline (simulate -> train -> sample ->
//! calibrate -> serve) can be held to analytic answers rather than smoke
//! checks.
//!
//! * the SBC/coverage *machinery* is validated against the exact
//!   posterior sampler (uniform ranks, nominal coverage, by construction);
//! * a conditional flow trained by `amortized_train` must reproduce the
//!   closed-form posterior mean/covariance, pass the SBC chi-square at
//!   the pinned seed, and hit nominal coverage;
//! * a serve-side `posterior` request must be bit-identical to the direct
//!   `posterior::analysis` call on the same trained weights.

mod common;

use std::sync::Arc;

use invertnet::posterior::analysis::{self, chi2_crit};
use invertnet::posterior::{amortized_train, calibrate, posterior_samples,
                           summarize, PosteriorTrainConfig, Simulator};
use invertnet::serve::{BatchConfig, Registry, Request, Response, Server};
use invertnet::serve::registry::ServedModel;
use invertnet::util::rng::Pcg64;
use invertnet::SampleOpts;

#[test]
fn sbc_machinery_is_calibrated_for_the_exact_posterior_sampler() {
    // ranks of theta* among draws from the TRUE posterior are uniform by
    // construction — this pins the diagnostics themselves before any
    // trained flow is judged by them
    let sim = Simulator::parse("linear-gaussian").unwrap();
    let prob = invertnet::data::LinearGaussian::default_problem();
    let mut rng = Pcg64::new(1234);
    // 127 draws keep the finite-sample coverage bias of the interpolated
    // central interval small (~0.011; it is ~0.028 at 63 draws)
    let cal = calibrate(&sim, 256, 127, 0.9, 8, &mut rng, |y, l, r| {
        Ok(prob.sample_posterior([y[0] as f64, y[1] as f64], l, r))
    })
    .unwrap();

    assert_eq!(cal.df(), 7);
    let crit = chi2_crit(7, 1e-3);
    for (d, &chi2) in cal.chi2.iter().enumerate() {
        assert!(chi2 < crit,
                "dim {d}: exact sampler rejected uniformity \
                 (chi2 {chi2:.2} >= {crit:.2})");
    }
    for (d, &cov) in cal.coverage.iter().enumerate() {
        assert!((cov - 0.9).abs() < 0.08,
                "dim {d}: exact sampler coverage {cov} far from 0.9");
    }
    // every rank is in range and they are not all equal
    for r in &cal.ranks {
        assert_eq!(r.len(), 256);
        assert!(r.iter().all(|&v| v <= 127));
        assert!(r.iter().any(|&v| v != r[0]));
    }
}

/// The acceptance oracle: train a conditional flow on simulator stream,
/// then hold its posterior to the closed form.
#[test]
fn amortized_flow_recovers_the_closed_form_posterior() {
    let engine = common::engine();
    let flow = engine.flow("cond_lingauss2d").unwrap();
    let mut params = flow.init_params(42).unwrap();
    let sim = Simulator::parse("linear-gaussian").unwrap();
    let prob = sim.oracle().expect("linear-gaussian has the oracle");

    let cfg = PosteriorTrainConfig {
        steps: 450,
        lr: 3e-3,
        seed: 42,
        eval_every: 100,
        quiet: true,
        log_every: usize::MAX,
        ..PosteriorTrainConfig::default()
    };
    let report = amortized_train(&flow, &mut params, &sim, &cfg).unwrap();
    assert!(report.final_loss.is_finite());
    // the eval-split NLL must reflect actual learning: an untrained
    // (identity-coupling) flow scores the 2-D standard normal at ~2.84
    // nats; the true conditional entropy is ~1.37
    let eval_nll = report.eval_nll.expect("eval split configured");
    assert!(eval_nll < 2.0,
            "eval NLL {eval_nll} says the flow did not learn the cond");

    // ---- posterior mean/cov vs the closed form -----------------------
    for y_obs in [[0.8f64, -0.5], [-1.2, 0.6]] {
        let (mu_true, cov_true) = prob.posterior(y_obs);
        let y32 = [y_obs[0] as f32, y_obs[1] as f32];
        let samples =
            posterior_samples(&flow, &params, &y32, 4096, 1.0, 31).unwrap();
        let (mu, cov) = analysis::sample_mean_cov(&samples);
        for i in 0..2 {
            assert!((mu[i] - mu_true[i]).abs() < 0.25,
                    "y {y_obs:?} dim {i}: mean {mu:?} vs {mu_true:?}");
            for j in 0..2 {
                assert!((cov[i][j] - cov_true[i][j]).abs() < 0.25,
                        "y {y_obs:?}: cov {cov:?} vs {cov_true:?}");
            }
        }
        // the std map agrees with the covariance diagonal
        let s = summarize(&samples);
        for i in 0..2 {
            assert!((s.std[i] as f64 - cov[i][i].sqrt()).abs() < 1e-3);
        }
    }

    // ---- SBC + coverage at the pinned seed ---------------------------
    let mut rng = Pcg64::new(777);
    let cal = calibrate(&sim, 128, 127, 0.9, 8, &mut rng, |y, l, r| {
        let cond = analysis::tile_observation(y, l)?;
        flow.sample(&params, SampleOpts::new(l, r).cond(&cond))
    })
    .unwrap();
    let crit = chi2_crit(7, 1e-4);
    for (d, &chi2) in cal.chi2.iter().enumerate() {
        assert!(chi2 < crit,
                "dim {d}: trained flow fails SBC (chi2 {chi2:.2} >= \
                 {crit:.2}; ranks not uniform)");
    }
    for (d, &cov) in cal.coverage.iter().enumerate() {
        assert!((cov - 0.9).abs() < 0.12,
                "dim {d}: credible-interval coverage {cov} misses 0.9");
    }

    // ---- serve-side posterior op, bit-identical on trained weights ---
    let registry = Registry::new(common::engine(), 2);
    registry.insert(ServedModel {
        name: flow.def.name.clone(),
        flow: flow.clone(),
        params: Arc::new(params.clone()),
        trained: true,
    })
    .unwrap();
    let server = Server::new(registry, BatchConfig::default());
    let y = vec![0.8f32, -0.5];
    let resp = server.handle(Request::Posterior {
        model: None,
        y: y.clone(),
        n: 64,
        temperature: 1.0,
        seed: 9,
        return_samples: true,
    });
    let Response::Posterior { n, mean, std, samples } = resp else {
        panic!("posterior request failed: {resp:?}")
    };
    assert_eq!(n, 64);
    let direct = posterior_samples(&flow, &params, &y, 64, 1.0, 9).unwrap();
    let direct_sum = summarize(&direct);
    let served = samples.expect("samples requested");
    assert_eq!(served.shape, direct.shape);
    for (a, b) in served.data.iter().zip(&direct.data) {
        assert_eq!(a.to_bits(), b.to_bits(),
                   "served posterior samples differ from the direct call");
    }
    for (a, b) in mean.iter().zip(&direct_sum.mean) {
        assert_eq!(a.to_bits(), b.to_bits(), "served mean map differs");
    }
    for (a, b) in std.iter().zip(&direct_sum.std) {
        assert_eq!(a.to_bits(), b.to_bits(), "served std map differs");
    }
}

#[test]
fn metrics_csv_gains_the_eval_nll_column() {
    let dir = std::env::temp_dir()
        .join(format!("invertnet_postcsv_{}", std::process::id()));
    let engine = common::engine();
    let flow = engine.flow("cond_lingauss2d").unwrap();
    let mut params = flow.init_params(5).unwrap();
    let sim = Simulator::parse("linear-gaussian").unwrap();
    let cfg = PosteriorTrainConfig {
        steps: 5,
        eval_every: 2,
        quiet: true,
        log_every: usize::MAX,
        out_dir: Some(dir.clone()),
        ..PosteriorTrainConfig::default()
    };
    amortized_train(&flow, &mut params, &sim, &cfg).unwrap();

    let csv = std::fs::read_to_string(dir.join("metrics.csv")).unwrap();
    let mut lines = csv.lines();
    let header = lines.next().unwrap();
    assert!(header.ends_with(",eval_nll"), "header: {header}");
    let rows: Vec<&str> = lines.collect();
    assert_eq!(rows.len(), 5);
    for (i, row) in rows.iter().enumerate() {
        let cells: Vec<&str> = row.split(',').collect();
        assert_eq!(cells.len(), header.split(',').count(), "row {i}: {row}");
        let eval = cells.last().unwrap();
        // cadence 2 + the final step -> steps 0, 2, 4 carry a value
        if i % 2 == 0 || i + 1 == rows.len() {
            let v: f32 = eval.parse().unwrap_or_else(
                |e| panic!("row {i} eval cell {eval:?}: {e}"));
            assert!(v.is_finite());
        } else {
            assert!(eval.is_empty(), "row {i} should have no eval: {row}");
        }
    }

    // the checkpoint written alongside reloads into the serving path
    let (loaded_flow, loaded) = Registry::load_checkpoint(
        &engine, &dir.join("checkpoint")).unwrap();
    assert_eq!(loaded_flow.def.name, "cond_lingauss2d");
    for (a, b) in loaded.tensors.iter().flatten()
        .zip(params.tensors.iter().flatten()) {
        assert_eq!(a, b, "checkpoint roundtrip changed params");
    }
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn posterior_samples_respect_temperature_and_seed() {
    let flow = common::flow("cond_lingauss2d");
    let params = flow.init_params(3).unwrap();
    let y = [0.5f32, 0.5];

    // same seed -> bit-identical; different seed -> different
    let a = posterior_samples(&flow, &params, &y, 8, 1.0, 7).unwrap();
    let b = posterior_samples(&flow, &params, &y, 8, 1.0, 7).unwrap();
    assert_eq!(a, b);
    let c = posterior_samples(&flow, &params, &y, 8, 1.0, 8).unwrap();
    assert!(a.data.iter().zip(&c.data).any(|(x, y)| x != y));

    // temperature 0 collapses the cloud onto the mode path: std map 0
    let t0 = posterior_samples(&flow, &params, &y, 8, 0.0, 7).unwrap();
    let s = summarize(&t0);
    assert!(s.std.iter().all(|&v| v == 0.0), "{:?}", s.std);
}
