//! The ISSUE acceptance path, end-to-end on the RefBackend with zero
//! artifacts: `invertnet train --net realnvp2d --data two-moons --steps 50`
//! in both `--mode invertible` and `--mode stored`, with invertible peak
//! scheduling bytes strictly below stored (the Fig. 1/2 claim).

mod common;

use std::sync::Arc;

use invertnet::coordinator::ExecMode;
use invertnet::data::Density2d;
use invertnet::train::loop_::tail_mean;
use invertnet::train::{train, Adam, GradClip, TrainConfig};
use invertnet::util::rng::Pcg64;

fn run_50_steps(mode: ExecMode) -> invertnet::train::TrainReport {
    let flow = common::flow("realnvp2d");
    let mut params = flow.init_params(42).unwrap();
    let mut opt = Adam::new(2e-3);
    let mut rng = Pcg64::new(4242);
    let cfg = TrainConfig {
        steps: 50,
        schedule: Arc::new(mode),
        clip: Some(GradClip { max_norm: 100.0 }),
        log_every: usize::MAX,
        out_dir: None,
        quiet: true,
        ..TrainConfig::default()
    };
    train(&flow, &mut params, &mut opt, &cfg, |_| {
        Ok((Density2d::TwoMoons.sample(256, &mut rng), None))
    })
    .unwrap()
}

#[test]
fn two_moons_50_steps_invertible_vs_stored() {
    let inv = run_50_steps(ExecMode::Invertible);
    let sto = run_50_steps(ExecMode::Stored);

    // both schedules run end-to-end and learn something
    for (name, r) in [("invertible", &inv), ("stored", &sto)] {
        assert!(r.final_loss.is_finite(), "{name}: non-finite loss");
        assert!(
            tail_mean(&r.losses, 10) < r.losses[0],
            "{name}: loss did not improve ({} -> {})",
            r.losses[0],
            tail_mean(&r.losses, 10)
        );
    }

    // the paper's claim, measured: invertible scheduling memory is
    // STRICTLY below the autodiff-style tape
    assert!(
        inv.peak_sched_bytes < sto.peak_sched_bytes,
        "invertible peak {} must be strictly below stored peak {}",
        inv.peak_sched_bytes,
        sto.peak_sched_bytes
    );
}

/// Same path through the CLI dispatch (`invertnet train ...`).
#[test]
fn cli_train_two_moons_both_modes() {
    for mode in ["invertible", "stored"] {
        let argv: Vec<String> = [
            "train", "--net", "realnvp2d", "--data", "two-moons",
            "--steps", "5", "--mode", mode, "--quiet",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        invertnet::app::run(&argv)
            .unwrap_or_else(|e| panic!("cli train --mode {mode}: {e:#}"));
    }
}

/// The CLI also exposes the hybrid schedule.
#[test]
fn cli_train_checkpoint_hybrid() {
    let argv: Vec<String> = [
        "train", "--net", "realnvp2d", "--data", "two-moons",
        "--steps", "3", "--mode", "checkpoint:4", "--quiet",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    invertnet::app::run(&argv).unwrap();
}
