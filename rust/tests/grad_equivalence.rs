//! The paper's §3 claim, verified end-to-end through the RefBackend: the
//! invertible (recompute-from-inverse) schedule produces the SAME loss and
//! parameter gradients as the stored (autodiff-tape) schedule — memory is
//! the only difference. Exercised for every network family.

mod common;

use common::{assert_close, batch_for, flow};
use invertnet::coordinator::ExecMode;

fn check_net(net: &str, tol: f32) {
    let flow = flow(net);
    let params = flow.init_params(1234).unwrap();
    let (x, cond) = batch_for(&flow, 77);

    let inv = flow
        .train_step(&x, cond.as_ref(), &params, &ExecMode::Invertible)
        .unwrap();
    let sto = flow
        .train_step(&x, cond.as_ref(), &params, &ExecMode::Stored)
        .unwrap();

    assert!(
        (inv.loss - sto.loss).abs() <= tol * inv.loss.abs().max(1.0),
        "{net}: loss {} vs {}",
        inv.loss,
        sto.loss
    );
    assert_eq!(inv.grads.len(), sto.grads.len());
    for (si, (gi, gs)) in inv.grads.iter().zip(&sto.grads).enumerate() {
        assert_eq!(gi.len(), gs.len(), "{net} step {si} arity");
        for (pi, (a, b)) in gi.iter().zip(gs).enumerate() {
            assert_close(a, b, tol, &format!("{net} step {si} param {pi}"));
        }
    }
    match (&inv.dcond, &sto.dcond) {
        (Some(a), Some(b)) => assert_close(a, b, tol, &format!("{net} dcond")),
        (None, None) => {}
        _ => panic!("{net}: dcond presence differs"),
    }
    // and the memory claim: invertible must not exceed stored
    assert!(
        inv.peak_sched_bytes <= sto.peak_sched_bytes,
        "{net}: invertible peak {} > stored peak {}",
        inv.peak_sched_bytes,
        sto.peak_sched_bytes
    );
}

#[test]
fn realnvp_dense() {
    check_net("realnvp2d", 2e-4);
}

#[test]
fn conditional_realnvp() {
    check_net("cond_realnvp2d", 2e-4);
}

#[test]
fn hint() {
    check_net("hint8d", 2e-4);
}

#[test]
fn glow_multiscale() {
    check_net("glow16", 5e-4);
}

#[test]
fn hyperbolic() {
    check_net("hyper16", 5e-4);
}

#[test]
fn nice_additive() {
    check_net("nice16", 5e-4);
}
