//! Perf-harness contracts: the Fig. 1 OOM crossover under a fixed memory
//! budget, bit-identical threaded inference, and the bench-report /
//! baseline-gate roundtrip.

use invertnet::coordinator::ExecMode;
use invertnet::perf::{check_report, memory_vs_size, Baseline, Scale};
use invertnet::util::rng::Pcg64;
use invertnet::{Engine, InferOpts, MemoryLedger, SampleOpts, Tensor};

/// One real training step of `net` under `mode`; returns
/// (peak_sched_bytes, peak_total_bytes).
fn measure(engine: &Engine, net: &str, mode: ExecMode,
           budget: Option<u64>) -> anyhow::Result<(i64, i64)> {
    let ledger = match budget {
        Some(b) => MemoryLedger::with_budget(b),
        None => MemoryLedger::new(),
    };
    let flow = engine.flow_with_ledger(net, ledger)?;
    let params = flow.init_params(42)?;
    let s = &flow.def.in_shape;
    let mut rng = Pcg64::new(99);
    let x = invertnet::data::synth_images(s[0], s[1], s[2], s[3], &mut rng);
    let r = flow.train_step(&x, None, &params, &mode)?;
    Ok((r.peak_sched_bytes, r.peak_total_bytes))
}

/// The paper's Fig. 1 claim as a regression test: under a budget pinned
/// between the two schedules' peaks, stored-mode training OOMs while
/// invertible mode trains — same network, same data, same step.
#[test]
fn stored_mode_ooms_where_invertible_succeeds() {
    let engine = Engine::native().unwrap();
    let net = "glow_fig1_16";
    let (inv_sched, inv_total) =
        measure(&engine, net, ExecMode::Invertible, None).unwrap();
    let (sto_sched, sto_total) =
        measure(&engine, net, ExecMode::Stored, None).unwrap();
    assert!(sto_sched > inv_sched,
            "stored ({sto_sched}) must tape more than invertible \
             ({inv_sched})");

    // a budget between the two totals: invertible fits, stored cannot
    let budget = ((inv_total + sto_total) / 2) as u64;
    let (inv_b, _) = measure(&engine, net, ExecMode::Invertible,
                             Some(budget)).unwrap();
    // the budget changes what is *allowed*, not what is allocated
    assert_eq!(inv_b, inv_sched, "budgeted run must reproduce the peak");
    let err = measure(&engine, net, ExecMode::Stored, Some(budget))
        .unwrap_err();
    assert!(format!("{err:#}").contains("OOM"), "{err:#}");
}

/// Threaded inference is bit-identical to the single-threaded walk for a
/// fixed chunk size, on both relaxed-batch `sample` (inverse) and
/// `log_density` (forward), including a ragged final chunk and a
/// multiscale net.
#[test]
fn threaded_inference_is_bit_identical() {
    let e1 = Engine::builder().threads(1).build().unwrap();
    let e4 = Engine::builder().threads(4).build().unwrap();
    for net in ["realnvp2d", "glow16"] {
        let f1 = e1.flow(net).unwrap();
        let f4 = e4.flow(net).unwrap();
        assert_eq!(f1.infer_chunk(), f4.infer_chunk(),
                   "chunk size must not depend on the thread count");
        let params = f1.init_params(5).unwrap();
        let params4 = f4.init_params(5).unwrap();
        // 3 full chunks + a ragged tail
        let n = f1.infer_chunk() * 3 + 3;

        // sample: same rng stream, chunked inverse
        let mut r1 = Pcg64::new(123);
        let mut r4 = Pcg64::new(123);
        let s1 = f1.sample(&params, SampleOpts::new(n, &mut r1)).unwrap();
        let s4 = f4.sample(&params4, SampleOpts::new(n, &mut r4)).unwrap();
        assert_eq!(s1.shape, s4.shape);
        for (a, b) in s1.data.iter().zip(&s4.data) {
            assert_eq!(a.to_bits(), b.to_bits(),
                       "{net}: threaded sample diverged");
        }

        // log_density: chunked forward over the samples just drawn
        let d1 = f1.log_density(&s1, &params, InferOpts::relaxed()).unwrap();
        let d4 = f4.log_density(&s1, &params4, InferOpts::relaxed()).unwrap();
        assert_eq!(d1.len(), n);
        for (a, b) in d1.iter().zip(&d4) {
            assert_eq!(a.to_bits(), b.to_bits(),
                       "{net}: threaded log_density diverged");
        }

        // the per-call threads override reproduces the same bits too
        let d4b = f1.log_density(&s1, &params,
                                 InferOpts::relaxed().threads(4)).unwrap();
        for (a, b) in d1.iter().zip(&d4b) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}

/// The conditional (serving/posterior) path threads bit-identically too.
#[test]
fn threaded_conditional_inference_matches() {
    let e4 = Engine::builder().threads(4).build().unwrap();
    let f1 = Engine::builder().threads(1).build().unwrap()
        .flow("cond_lingauss2d").unwrap();
    let f4 = e4.flow("cond_lingauss2d").unwrap();
    let params = f1.init_params(9).unwrap();
    let params4 = f4.init_params(9).unwrap();
    let n = f1.infer_chunk() * 2 + 5;
    let cond = Tensor {
        shape: vec![n, 2],
        data: Pcg64::new(31).normal_vec(n * 2),
    };
    let mut r1 = Pcg64::new(77);
    let mut r4 = Pcg64::new(77);
    let s1 = f1.sample(&params, SampleOpts::new(n, &mut r1)
                           .temperature(0.8).cond(&cond)).unwrap();
    let s4 = f4.sample(&params4, SampleOpts::new(n, &mut r4)
                           .temperature(0.8).cond(&cond)).unwrap();
    for (a, b) in s1.data.iter().zip(&s4.data) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let d1 = f1.log_density(&s1, &params,
                            InferOpts::relaxed().cond(&cond)).unwrap();
    let d4 = f4.log_density(&s1, &params4,
                            InferOpts::relaxed().cond(&cond)).unwrap();
    for (a, b) in d1.iter().zip(&d4) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

/// Bad inputs fail with the serial path's error messages even when the
/// flow carries a thread pool (the chunked path must not mangle errors).
#[test]
fn threaded_path_preserves_validation_errors() {
    let engine = Engine::builder().threads(4).build().unwrap();
    let flow = engine.flow("realnvp2d").unwrap();
    let params = flow.init_params(3).unwrap();
    let n = flow.infer_chunk() * 2 + 1;
    // wrong per-sample width
    let bad = Tensor::zeros(&[n, 5]);
    let err = flow.log_density(&bad, &params, InferOpts::relaxed())
        .unwrap_err();
    assert!(format!("{err:#}").contains("shape"), "{err:#}");
    // cond on an unconditioned net
    let x = Tensor::zeros(&[n, 2]);
    let cond = Tensor::zeros(&[n, 2]);
    let err = flow.log_density(&x, &params, InferOpts::relaxed().cond(&cond))
        .unwrap_err();
    assert!(format!("{err:#}").contains("no cond"), "{err:#}");
}

/// A fresh report is clean against its own serialization; a perturbed
/// baseline flags exactly the regressed metric; on-disk roundtrip works.
#[test]
fn bench_report_baseline_roundtrip() {
    let engine = Engine::native().unwrap();
    let report = memory_vs_size(&engine, Scale::Quick).unwrap();
    assert!(report.metrics.iter().any(|m| m.check),
            "memory suite must emit gated metrics");

    let dir = std::env::temp_dir()
        .join(format!("invertnet_perf_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("quick.json");
    report.write(engine.backend_name(), engine.default_threads(), &path)
        .unwrap();

    let baseline = Baseline::load(&path).unwrap();
    let clean = check_report(&report, &baseline, 0.0).unwrap();
    assert!(clean.ok(), "self-check regressed: {:?}", clean.regressions);
    assert!(clean.compared > 0);
    assert!(clean.missing.is_empty());

    // shrink one byte baseline by 20% -> a lower-is-better regression
    let mut bad = baseline.clone();
    let name = report.metrics.iter()
        .find(|m| m.check && m.unit == "bytes")
        .map(|m| m.name.clone())
        .expect("a gated bytes metric");
    let entry = bad.metrics.get_mut(&name).unwrap();
    entry.value = Some(entry.value.unwrap() * 0.8);
    let out = check_report(&report, &bad, 5.0).unwrap();
    assert_eq!(out.regressions.len(), 1, "{:?}", out.regressions);
    assert_eq!(out.regressions[0].0, name);

    std::fs::remove_dir_all(&dir).ok();
}
