//! Randomized property tests on coordinator invariants (a proptest-lite
//! built on the project's PCG64, since proptest is not in the offline
//! vendor set). Each property runs across a seed sweep.
//!
//! Properties:
//!  P1 planner: invertible peak is depth-invariant for random GLOW configs.
//!  P2 planner: stored peak is strictly monotonic in depth.
//!  P3 planner: stored >= invertible for every random config.
//!  P4 ledger: random alloc/free interleavings conserve bytes and never
//!     let live exceed peak or a budget.
//!  P5 split/concat: round-trips random tensors for random split points.

use invertnet::coordinator::planner::{glow_flat_shape_def, predict_peak_sched};
use invertnet::coordinator::{ExecMode, MemClass, MemoryLedger, Tracked};
use invertnet::tensor::ops::{concat_last_axis, split_last_axis};
use invertnet::util::rng::Pcg64;
use invertnet::Tensor;

const CASES: usize = 40;

fn rand_cfg(rng: &mut Pcg64) -> (usize, usize, usize, usize) {
    let n = 1 + rng.below(8);
    let hw = [8usize, 16, 32, 64, 128][rng.below(5)];
    let c = 1 + rng.below(4);
    let k = 1 + rng.below(40);
    (n, hw, c, k)
}

#[test]
fn p1_invertible_peak_depth_invariant() {
    let mut rng = Pcg64::new(101);
    for _ in 0..CASES {
        let (n, hw, c, k) = rand_cfg(&mut rng);
        let a = predict_peak_sched(&glow_flat_shape_def(n, hw, hw, c, k),
                                   ExecMode::Invertible);
        let b = predict_peak_sched(&glow_flat_shape_def(n, hw, hw, c, k + 7),
                                   ExecMode::Invertible);
        assert_eq!(a, b, "cfg n={n} hw={hw} c={c} k={k}");
    }
}

#[test]
fn p2_stored_peak_monotone_in_depth() {
    let mut rng = Pcg64::new(102);
    for _ in 0..CASES {
        let (n, hw, c, k) = rand_cfg(&mut rng);
        let a = predict_peak_sched(&glow_flat_shape_def(n, hw, hw, c, k),
                                   ExecMode::Stored);
        let b = predict_peak_sched(&glow_flat_shape_def(n, hw, hw, c, k + 1),
                                   ExecMode::Stored);
        assert!(b > a, "cfg n={n} hw={hw} c={c} k={k}: {a} !< {b}");
    }
}

#[test]
fn p3_stored_never_below_invertible() {
    let mut rng = Pcg64::new(103);
    for _ in 0..CASES {
        let (n, hw, c, k) = rand_cfg(&mut rng);
        let def = glow_flat_shape_def(n, hw, hw, c, k);
        let inv = predict_peak_sched(&def, ExecMode::Invertible);
        let sto = predict_peak_sched(&def, ExecMode::Stored);
        assert!(sto >= inv, "cfg n={n} hw={hw} c={c} k={k}: {sto} < {inv}");
    }
}

#[test]
fn p4_ledger_conserves_bytes_randomly() {
    let mut rng = Pcg64::new(104);
    for case in 0..CASES {
        let budget = 10_000 + rng.below(100_000) as u64;
        let ledger = MemoryLedger::with_budget(budget);
        let mut live: Vec<Tracked> = Vec::new();
        let mut expected: i64 = 0;
        for _ in 0..200 {
            if rng.uniform() < 0.6 {
                let n = 1 + rng.below(2000);
                let class = match rng.below(4) {
                    0 => MemClass::Activation,
                    1 => MemClass::Gradient,
                    2 => MemClass::Latent,
                    _ => MemClass::Param,
                };
                match Tracked::new(Tensor::zeros(&[n]), class, &ledger) {
                    Ok(t) => {
                        expected += (n * 4) as i64;
                        live.push(t);
                    }
                    Err(e) => {
                        // OOM must only happen when it genuinely would not fit
                        assert!(expected + (n * 4) as i64 > budget as i64,
                                "case {case}: spurious OOM: {e}");
                    }
                }
            } else if !live.is_empty() {
                let idx = rng.below(live.len());
                let t = live.swap_remove(idx);
                expected -= t.tensor().size_bytes() as i64;
                drop(t);
            }
            assert_eq!(ledger.live_total(), expected, "case {case}");
            assert!(ledger.live_total() <= ledger.peak_total());
            assert!(ledger.live_total() <= budget as i64);
        }
        drop(live);
        assert_eq!(ledger.live_total(), 0, "case {case}: leak");
    }
}

#[test]
fn p5_split_concat_roundtrips_random() {
    let mut rng = Pcg64::new(105);
    for _ in 0..CASES {
        let ndim = 2 + rng.below(3);
        let mut shape: Vec<usize> = (0..ndim).map(|_| 1 + rng.below(6)).collect();
        if *shape.last().unwrap() < 2 {
            *shape.last_mut().unwrap() = 2 + rng.below(5);
        }
        let numel: usize = shape.iter().product();
        let t = Tensor::new(shape.clone(), rng.normal_vec(numel)).unwrap();
        let c = *shape.last().unwrap();
        let k = 1 + rng.below(c - 1);
        let (a, b) = split_last_axis(&t, k).unwrap();
        let back = concat_last_axis(&a, &b).unwrap();
        assert_eq!(back, t, "shape {shape:?} k={k}");
    }
}
