//! Invertibility guarantees through the RefBackend (the paper's §4 CI
//! promise), with zero external artifacts: forward->invert round-trips the
//! input; invert->forward round-trips the latents; log-likelihood is
//! finite and sensible.

mod common;

use common::{batch_for, flow};
use invertnet::coordinator::ExecMode;
use invertnet::util::rng::Pcg64;
use invertnet::InferOpts;
use invertnet::{MemoryLedger, Tensor};

fn roundtrip(net: &str, tol: f32) {
    let flow = flow(net);
    let params = flow.init_params(31).unwrap();
    let (x, cond) = batch_for(&flow, 55);
    let err = flow.roundtrip_error(&x, cond.as_ref(), &params).unwrap();
    assert!(err < tol, "{net}: roundtrip error {err} >= {tol}");
}

#[test]
fn realnvp_roundtrips() {
    roundtrip("realnvp2d", 1e-4);
}

#[test]
fn cond_realnvp_roundtrips() {
    roundtrip("cond_realnvp2d", 1e-4);
}

#[test]
fn hint_roundtrips() {
    roundtrip("hint8d", 1e-4);
}

#[test]
fn glow_multiscale_roundtrips() {
    roundtrip("glow16", 2e-3); // conv + sigmoid couplings accumulate f32 error
}

#[test]
fn hyperbolic_roundtrips() {
    roundtrip("hyper16", 1e-3);
}

#[test]
fn nice_additive_roundtrips() {
    roundtrip("nice16", 1e-3);
}

#[test]
fn sample_then_forward_recovers_latents() {
    let flow = flow("realnvp2d");
    let params = flow.init_params(9).unwrap();
    let mut rng = Pcg64::new(123);
    let shapes = flow.def.latent_shapes.clone();
    let zs: Vec<Tensor> = shapes
        .iter()
        .map(|s| Tensor {
            shape: s.clone(),
            data: rng.normal_vec(s.iter().product()),
        })
        .collect();
    let x = flow.invert(&zs, &params, InferOpts::strict()).unwrap();
    let (latents, _) = flow.forward(&x, None, &params).unwrap();
    assert_eq!(latents.len(), zs.len());
    for (got, want) in latents.iter().zip(&zs) {
        let d = got.tensor().max_abs_diff(want);
        assert!(d < 1e-3, "latent mismatch {d}");
    }
}

#[test]
fn log_likelihood_finite_and_consistent() {
    let flow = flow("glow16");
    let params = flow.init_params(3).unwrap();
    let (x, _) = batch_for(&flow, 8);
    let ll = flow.log_density(&x, &params, InferOpts::strict()).unwrap();
    assert_eq!(ll.len(), flow.batch());
    for v in &ll {
        assert!(v.is_finite(), "non-finite loglik {v}");
    }
    // scaling sanity: loglik per dim should be O(1)
    let dims = flow.def.dims_per_sample() as f32;
    let mean = ll.iter().sum::<f32>() / ll.len() as f32 / dims;
    assert!(mean.abs() < 30.0, "per-dim loglik {mean} looks wrong");
}

#[test]
fn ledger_returns_to_zero_after_step() {
    let engine = common::engine();
    let ledger = MemoryLedger::new();
    let flow = engine.flow_with_ledger("realnvp2d", ledger.clone()).unwrap();
    let params = flow.init_params(1).unwrap();
    let (x, _) = batch_for(&flow, 2);
    let _ = flow
        .train_step(&x, None, &params, &ExecMode::Invertible)
        .unwrap();
    assert_eq!(
        ledger.live_total(),
        0,
        "all tracked buffers must be freed after a step: {}",
        ledger.report()
    );
}
