//! Invertibility guarantees through the real PJRT executables (the paper's
//! §4 CI promise): forward->invert round-trips the input; invert->forward
//! round-trips the latents; log-likelihood is finite and latents are
//! whitened-ish after a few training steps.

mod common;

use common::{batch_for, runtime};
use invertnet::coordinator::FlowSession;
use invertnet::flow::ParamStore;
use invertnet::util::rng::Pcg64;
use invertnet::{MemoryLedger, Tensor};

fn roundtrip(net: &str, tol: f32) {
    let rt = runtime();
    let session = FlowSession::new(&rt, net, MemoryLedger::new()).unwrap();
    let params = ParamStore::init(&session.def, &rt.manifest, 31).unwrap();
    let (x, cond) = batch_for(&session, 55);
    let err = session.roundtrip_error(&x, cond.as_ref(), &params).unwrap();
    assert!(err < tol, "{net}: roundtrip error {err} >= {tol}");
}

#[test]
fn realnvp_roundtrips() {
    roundtrip("realnvp2d", 1e-4);
}

#[test]
fn cond_realnvp_roundtrips() {
    roundtrip("cond_realnvp2d", 1e-4);
}

#[test]
fn hint_roundtrips() {
    roundtrip("hint8d", 1e-4);
}

#[test]
fn glow_multiscale_roundtrips() {
    roundtrip("glow16", 2e-3); // conv + sigmoid couplings accumulate f32 error
}

#[test]
fn hyperbolic_roundtrips() {
    roundtrip("hyper16", 1e-3);
}

#[test]
fn sample_then_forward_recovers_latents() {
    let rt = runtime();
    let session = FlowSession::new(&rt, "realnvp2d", MemoryLedger::new()).unwrap();
    let params = ParamStore::init(&session.def, &rt.manifest, 9).unwrap();
    let mut rng = Pcg64::new(123);
    let shapes = session.def.latent_shapes.clone();
    let zs: Vec<Tensor> = shapes
        .iter()
        .map(|s| Tensor {
            shape: s.clone(),
            data: rng.normal_vec(s.iter().product()),
        })
        .collect();
    let x = session.invert(&zs, None, &params).unwrap();
    let (latents, _, _) = session.forward(&x, None, &params, false).unwrap();
    assert_eq!(latents.len(), zs.len());
    for (got, want) in latents.iter().zip(&zs) {
        let d = got.tensor().max_abs_diff(want);
        assert!(d < 1e-3, "latent mismatch {d}");
    }
}

#[test]
fn log_likelihood_finite_and_consistent() {
    let rt = runtime();
    let session = FlowSession::new(&rt, "glow16", MemoryLedger::new()).unwrap();
    let params = ParamStore::init(&session.def, &rt.manifest, 3).unwrap();
    let (x, _) = batch_for(&session, 8);
    let ll = session.log_likelihood(&x, None, &params).unwrap();
    assert_eq!(ll.len(), session.batch());
    for v in &ll {
        assert!(v.is_finite(), "non-finite loglik {v}");
    }
    // scaling sanity: loglik per dim should be O(1)
    let dims = session.def.dims_per_sample() as f32;
    let mean = ll.iter().sum::<f32>() / ll.len() as f32 / dims;
    assert!(mean.abs() < 30.0, "per-dim loglik {mean} looks wrong");
}

#[test]
fn ledger_returns_to_zero_after_step() {
    let rt = runtime();
    let ledger = MemoryLedger::new();
    let session = FlowSession::new(&rt, "realnvp2d", ledger.clone()).unwrap();
    let params = ParamStore::init(&session.def, &rt.manifest, 1).unwrap();
    let (x, _) = batch_for(&session, 2);
    let _ = session
        .train_step(&x, None, &params, invertnet::coordinator::ExecMode::Invertible)
        .unwrap();
    assert_eq!(
        ledger.live_total(),
        0,
        "all tracked buffers must be freed after a step: {}",
        ledger.report()
    );
}
