//! End-to-end training behaviour on the RefBackend: loss decreases,
//! checkpoints round-trip, both schedules train to the same place.

mod common;

use std::sync::Arc;

use common::{batch_for, flow};
use invertnet::coordinator::{ActivationSchedule, ExecMode};
use invertnet::data::Density2d;
use invertnet::train::loop_::tail_mean;
use invertnet::train::{train, Adam, GradClip, Optimizer, TrainConfig};
use invertnet::util::rng::Pcg64;

fn quick_cfg(steps: usize, schedule: Arc<dyn ActivationSchedule>) -> TrainConfig {
    TrainConfig {
        steps,
        schedule,
        clip: Some(GradClip { max_norm: 100.0 }),
        log_every: usize::MAX,
        out_dir: None,
        quiet: true,
        ..TrainConfig::default()
    }
}

#[test]
fn loss_decreases_on_two_moons() {
    let flow = flow("realnvp2d");
    let mut params = flow.init_params(11).unwrap();
    let mut opt = Adam::new(2e-3);
    let mut rng = Pcg64::new(70);
    let report = train(
        &flow,
        &mut params,
        &mut opt,
        &quick_cfg(120, Arc::new(ExecMode::Invertible)),
        |_| Ok((Density2d::TwoMoons.sample(256, &mut rng), None)),
    )
    .unwrap();
    let head = tail_mean(&report.losses[..10], 10);
    let tail = tail_mean(&report.losses, 10);
    assert!(
        tail < head - 0.3,
        "no learning: first10 {head:.3} -> last10 {tail:.3}"
    );
}

#[test]
fn both_schedules_train_identically() {
    // identical seeds + data order => identical loss trajectories
    let run = |mode: ExecMode| {
        let flow = flow("realnvp2d");
        let mut params = flow.init_params(21).unwrap();
        let mut opt = Adam::new(1e-3);
        let mut rng = Pcg64::new(33);
        train(
            &flow,
            &mut params,
            &mut opt,
            &quick_cfg(25, Arc::new(mode)),
            |_| Ok((Density2d::TwoMoons.sample(256, &mut rng), None)),
        )
        .unwrap()
        .losses
    };
    let li = run(ExecMode::Invertible);
    let ls = run(ExecMode::Stored);
    for (step, (a, b)) in li.iter().zip(&ls).enumerate() {
        assert!(
            (a - b).abs() <= 5e-3 * a.abs().max(1.0),
            "step {step}: {a} vs {b}"
        );
    }
}

#[test]
fn checkpoint_roundtrip_preserves_loss() {
    let flow = flow("hint8d");
    let mut params = flow.init_params(77).unwrap();
    // perturb from init so the checkpoint is non-trivial
    let mut opt = Adam::new(1e-3);
    let mut rng = Pcg64::new(44);
    let mk = |rng: &mut Pcg64| invertnet::Tensor {
        shape: vec![256, 8],
        data: rng.normal_vec(256 * 8),
    };
    for _ in 0..3 {
        let x = mk(&mut rng);
        let mut r = flow
            .train_step(&x, None, &params, &ExecMode::Invertible)
            .unwrap();
        GradClip { max_norm: 100.0 }.apply(&mut r.grads);
        opt.step(&mut params, &r.grads).unwrap();
    }
    let x_eval = mk(&mut rng);
    let loss_before = flow
        .train_step(&x_eval, None, &params, &ExecMode::Invertible)
        .unwrap()
        .loss;

    let dir = std::env::temp_dir().join(format!("invertnet_ckpt_{}", std::process::id()));
    params.save(&dir, "hint8d").unwrap();

    let mut params2 = flow.init_params(999).unwrap();
    params2.load(&dir).unwrap();
    let loss_after = flow
        .train_step(&x_eval, None, &params2, &ExecMode::Invertible)
        .unwrap()
        .loss;
    std::fs::remove_dir_all(&dir).ok();
    assert!(
        (loss_before - loss_after).abs() < 1e-5,
        "{loss_before} vs {loss_after}"
    );
}

#[test]
fn conditional_training_reduces_loss() {
    let flow = flow("cond_realnvp2d");
    let mut params = flow.init_params(10).unwrap();
    let mut opt = Adam::new(2e-3);
    let prob = invertnet::data::LinearGaussian::default_problem();
    let mut rng = Pcg64::new(71);
    let report = train(
        &flow,
        &mut params,
        &mut opt,
        &quick_cfg(100, Arc::new(ExecMode::Invertible)),
        |_| {
            let (theta, y) = prob.sample(256, &mut rng);
            Ok((theta, Some(y)))
        },
    )
    .unwrap();
    let head = tail_mean(&report.losses[..10], 10);
    let tail = tail_mean(&report.losses, 10);
    assert!(tail < head - 0.1, "cond flow not learning: {head} -> {tail}");
}

/// Regression: with `clip: None`, metrics.csv used to log
/// `grad_norm = 0.0` because the norm was only computed as a clipping
/// by-product. The loop now reports the true global L2 norm regardless.
#[test]
fn metrics_report_true_grad_norm_without_clip() {
    let flow = flow("realnvp2d");
    let mut params = flow.init_params(17).unwrap();
    let mut opt = Adam::new(1e-3);
    let mut rng = Pcg64::new(55);
    let dir = std::env::temp_dir()
        .join(format!("invertnet_metrics_{}", std::process::id()));
    let mut cfg = quick_cfg(3, Arc::new(ExecMode::Invertible));
    cfg.clip = None;
    cfg.out_dir = Some(dir.clone());
    train(&flow, &mut params, &mut opt, &cfg, |_| {
        Ok((Density2d::TwoMoons.sample(256, &mut rng), None))
    })
    .unwrap();
    let csv = std::fs::read_to_string(dir.join("metrics.csv")).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let mut lines = csv.lines();
    let header: Vec<&str> = lines.next().unwrap().split(',').collect();
    let col = header.iter().position(|h| *h == "grad_norm").unwrap();
    let mut rows = 0;
    for line in lines {
        let norm: f32 = line.split(',').nth(col).unwrap().parse().unwrap();
        assert!(norm > 0.0, "grad_norm must be the true norm, got {norm}");
        rows += 1;
    }
    assert_eq!(rows, 3);
}

/// metrics.csv carries per-row wall-clock columns: `wall_ms`
/// (row-to-row elapsed, including logging I/O) and `ts_unix_ms`
/// (absolute write time, for correlating rows with the event log and
/// span trace).
#[test]
fn metrics_csv_carries_wall_clock_columns() {
    let flow = flow("realnvp2d");
    let mut params = flow.init_params(23).unwrap();
    let mut opt = Adam::new(1e-3);
    let mut rng = Pcg64::new(56);
    let dir = std::env::temp_dir()
        .join(format!("invertnet_wallcsv_{}", std::process::id()));
    let mut cfg = quick_cfg(3, Arc::new(ExecMode::Invertible));
    cfg.out_dir = Some(dir.clone());
    train(&flow, &mut params, &mut opt, &cfg, |_| {
        Ok((Density2d::TwoMoons.sample(64, &mut rng), None))
    })
    .unwrap();
    let csv = std::fs::read_to_string(dir.join("metrics.csv")).unwrap();
    std::fs::remove_dir_all(&dir).ok();
    let mut lines = csv.lines();
    let header: Vec<&str> = lines.next().unwrap().split(',').collect();
    let wall = header.iter().position(|h| *h == "wall_ms").unwrap();
    let ts = header.iter().position(|h| *h == "ts_unix_ms").unwrap();
    // eval_nll stays the last column (downstream scripts key on it)
    assert_eq!(header.last(), Some(&"eval_nll"), "header: {header:?}");
    let mut prev_ts = 0u64;
    let mut rows = 0;
    for line in lines {
        let cells: Vec<&str> = line.split(',').collect();
        assert_eq!(cells.len(), header.len(), "row: {line}");
        let wall_ms: f64 = cells[wall].parse().unwrap();
        assert!(wall_ms >= 0.0, "wall_ms: {line}");
        let ts_ms: u64 = cells[ts].parse().unwrap();
        // sanity: a real unix timestamp (after 2020), non-decreasing
        assert!(ts_ms > 1_577_836_800_000, "ts_unix_ms: {line}");
        assert!(ts_ms >= prev_ts, "timestamps went backwards: {line}");
        prev_ts = ts_ms;
        rows += 1;
    }
    assert_eq!(rows, 3);
}

#[test]
fn rejects_wrong_shapes() {
    let flow = flow("realnvp2d");
    let params = flow.init_params(1).unwrap();
    let bad = invertnet::Tensor::zeros(&[8, 2]);
    assert!(flow
        .train_step(&bad, None, &params, &ExecMode::Invertible)
        .is_err());
    let (x, _) = batch_for(&flow, 1);
    let cond = invertnet::Tensor::zeros(&[256, 2]);
    assert!(
        flow.train_step(&x, Some(&cond), &params, &ExecMode::Invertible)
            .is_err(),
        "unconditional net must reject cond input"
    );
}
