//! End-to-end training behaviour: loss decreases, checkpoints round-trip,
//! both executors train to the same place.

mod common;

use common::{batch_for, runtime};
use invertnet::coordinator::{ExecMode, FlowSession};
use invertnet::data::Density2d;
use invertnet::flow::ParamStore;
use invertnet::train::loop_::tail_mean;
use invertnet::train::{train, Adam, GradClip, Optimizer, TrainConfig};
use invertnet::util::rng::Pcg64;
use invertnet::MemoryLedger;

fn quick_cfg(steps: usize, mode: ExecMode) -> TrainConfig {
    TrainConfig {
        steps,
        mode,
        clip: Some(GradClip { max_norm: 100.0 }),
        log_every: usize::MAX,
        out_dir: None,
        quiet: true,
    }
}

#[test]
fn loss_decreases_on_two_moons() {
    let rt = runtime();
    let session = FlowSession::new(&rt, "realnvp2d", MemoryLedger::new()).unwrap();
    let mut params = ParamStore::init(&session.def, &rt.manifest, 11).unwrap();
    let mut opt = Adam::new(2e-3);
    let mut rng = Pcg64::new(70);
    let report = train(
        &session,
        &mut params,
        &mut opt,
        &quick_cfg(120, ExecMode::Invertible),
        |_| Ok((Density2d::TwoMoons.sample(256, &mut rng), None)),
    )
    .unwrap();
    let head = tail_mean(&report.losses[..10], 10);
    let tail = tail_mean(&report.losses, 10);
    assert!(
        tail < head - 0.3,
        "no learning: first10 {head:.3} -> last10 {tail:.3}"
    );
}

#[test]
fn both_modes_train_identically() {
    // identical seeds + data order => identical loss trajectories
    let rt = runtime();
    let run = |mode| {
        let session = FlowSession::new(&rt, "realnvp2d", MemoryLedger::new()).unwrap();
        let mut params = ParamStore::init(&session.def, &rt.manifest, 21).unwrap();
        let mut opt = Adam::new(1e-3);
        let mut rng = Pcg64::new(33);
        train(
            &session,
            &mut params,
            &mut opt,
            &quick_cfg(25, mode),
            |_| Ok((Density2d::TwoMoons.sample(256, &mut rng), None)),
        )
        .unwrap()
        .losses
    };
    let li = run(ExecMode::Invertible);
    let ls = run(ExecMode::Stored);
    for (step, (a, b)) in li.iter().zip(&ls).enumerate() {
        assert!(
            (a - b).abs() <= 5e-3 * a.abs().max(1.0),
            "step {step}: {a} vs {b}"
        );
    }
}

#[test]
fn checkpoint_roundtrip_preserves_loss() {
    let rt = runtime();
    let session = FlowSession::new(&rt, "hint8d", MemoryLedger::new()).unwrap();
    let mut params = ParamStore::init(&session.def, &rt.manifest, 77).unwrap();
    // perturb from init so the checkpoint is non-trivial
    let mut opt = Adam::new(1e-3);
    let mut rng = Pcg64::new(44);
    let mk = |rng: &mut Pcg64| invertnet::Tensor {
        shape: vec![256, 8],
        data: rng.normal_vec(256 * 8),
    };
    for _ in 0..3 {
        let x = mk(&mut rng);
        let mut r = session
            .train_step(&x, None, &params, ExecMode::Invertible)
            .unwrap();
        GradClip { max_norm: 100.0 }.apply(&mut r.grads);
        opt.step(&mut params, &r.grads).unwrap();
    }
    let x_eval = mk(&mut rng);
    let loss_before = session
        .train_step(&x_eval, None, &params, ExecMode::Invertible)
        .unwrap()
        .loss;

    let dir = std::env::temp_dir().join(format!("invertnet_ckpt_{}", std::process::id()));
    params.save(&dir, "hint8d").unwrap();

    let mut params2 = ParamStore::init(&session.def, &rt.manifest, 999).unwrap();
    params2.load(&dir).unwrap();
    let loss_after = session
        .train_step(&x_eval, None, &params2, ExecMode::Invertible)
        .unwrap()
        .loss;
    std::fs::remove_dir_all(&dir).ok();
    assert!(
        (loss_before - loss_after).abs() < 1e-5,
        "{loss_before} vs {loss_after}"
    );
}

#[test]
fn conditional_training_reduces_loss() {
    let rt = runtime();
    let session = FlowSession::new(&rt, "cond_realnvp2d", MemoryLedger::new()).unwrap();
    let mut params = ParamStore::init(&session.def, &rt.manifest, 10).unwrap();
    let mut opt = Adam::new(2e-3);
    let prob = invertnet::data::LinearGaussian::default_problem();
    let mut rng = Pcg64::new(71);
    let report = train(
        &session,
        &mut params,
        &mut opt,
        &quick_cfg(100, ExecMode::Invertible),
        |_| {
            let (theta, y) = prob.sample(256, &mut rng);
            Ok((theta, Some(y)))
        },
    )
    .unwrap();
    let head = tail_mean(&report.losses[..10], 10);
    let tail = tail_mean(&report.losses, 10);
    assert!(tail < head - 0.1, "cond flow not learning: {head} -> {tail}");
}

#[test]
fn rejects_wrong_shapes() {
    let rt = runtime();
    let session = FlowSession::new(&rt, "realnvp2d", MemoryLedger::new()).unwrap();
    let params = ParamStore::init(&session.def, &rt.manifest, 1).unwrap();
    let bad = invertnet::Tensor::zeros(&[8, 2]);
    assert!(session
        .train_step(&bad, None, &params, ExecMode::Invertible)
        .is_err());
    let (x, _) = batch_for(&session, 1);
    let cond = invertnet::Tensor::zeros(&[256, 2]);
    assert!(
        session
            .train_step(&x, Some(&cond), &params, ExecMode::Invertible)
            .is_err(),
        "unconditional net must reject cond input"
    );
}
