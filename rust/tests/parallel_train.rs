//! Cross-thread gradient equivalence for the data-parallel trainer.
//!
//! Contract under test (see `train/parallel.rs` module docs):
//! * one worker + one microbatch is **bit-exact** vs `Flow::train_step`;
//! * a fixed microbatch size makes the reduced result **bit-identical at
//!   any thread count** (slot-ordered f64 reduction);
//! * any sharding matches the single-threaded step to f32
//!   summation-reassociation error (per-sample signals never mix, only
//!   the final batch reductions re-associate; observed ≲ 2e-6, asserted
//!   at 1e-5 of scale);
//! * same seed + same thread count → identical losses, run to run.

mod common;

use std::sync::Arc;

use common::{assert_close, batch_for, flow};
use invertnet::coordinator::{ExecMode, StepResult};
use invertnet::data::Density2d;
use invertnet::train::{train, Adam, GradClip, ParallelTrainer, TrainConfig};
use invertnet::util::rng::Pcg64;

const TOL: f32 = 1e-5;

fn assert_grads_close(a: &StepResult, b: &StepResult, tol: f32, what: &str) {
    assert_eq!(a.grads.len(), b.grads.len(), "{what}: step arity");
    for (si, (ga, gb)) in a.grads.iter().zip(&b.grads).enumerate() {
        assert_eq!(ga.len(), gb.len(), "{what}: step {si} param arity");
        for (pi, (ta, tb)) in ga.iter().zip(gb).enumerate() {
            assert_close(ta, tb, tol, &format!("{what} step {si} param {pi}"));
        }
    }
    match (&a.dcond, &b.dcond) {
        (Some(x), Some(y)) => assert_close(x, y, tol, &format!("{what} dcond")),
        (None, None) => {}
        _ => panic!("{what}: dcond presence differs"),
    }
}

fn assert_bit_identical(a: &StepResult, b: &StepResult, what: &str) {
    assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{what}: loss bits");
    assert_eq!(a.logp_mean.to_bits(), b.logp_mean.to_bits(), "{what}: logp");
    for (si, (ga, gb)) in a.grads.iter().zip(&b.grads).enumerate() {
        for (pi, (ta, tb)) in ga.iter().zip(gb).enumerate() {
            assert_eq!(ta.max_abs_diff(tb), 0.0,
                       "{what}: step {si} param {pi} not bit-identical");
        }
    }
}

/// One worker, one microbatch: the exact same code path as train_step,
/// plus a weight-1.0 f64 round-trip — must be bit-exact.
#[test]
fn single_worker_is_bit_exact() {
    let flow = flow("realnvp2d");
    let params = flow.init_params(11).unwrap();
    let (x, _) = batch_for(&flow, 22);
    let single = flow
        .train_step(&x, None, &params, &ExecMode::Invertible)
        .unwrap();
    let par = ParallelTrainer::new(1)
        .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
        .unwrap();
    assert_bit_identical(&single, &par, "t=1");
    assert_eq!(single.peak_sched_bytes, par.peak_sched_bytes);
}

/// 1, 2 and 4 threads vs the single-threaded train_step, under both the
/// invertible and stored schedules.
#[test]
fn thread_counts_match_single_threaded_step() {
    for (sched, name) in [(ExecMode::Invertible, "invertible"),
                          (ExecMode::Stored, "stored")] {
        let flow = flow("realnvp2d");
        let params = flow.init_params(1234).unwrap();
        let (x, _) = batch_for(&flow, 77);
        let base = flow.train_step(&x, None, &params, &sched).unwrap();
        for threads in [1usize, 2, 4] {
            let par = ParallelTrainer::new(threads)
                .train_step(&flow, &x, None, &params, &sched)
                .unwrap();
            assert!(
                (par.loss - base.loss).abs() <= TOL * base.loss.abs().max(1.0),
                "{name} t={threads}: loss {} vs {}", par.loss, base.loss
            );
            assert!(
                (par.logdet_mean - base.logdet_mean).abs()
                    <= TOL * base.logdet_mean.abs().max(1.0),
                "{name} t={threads}: logdet {} vs {}",
                par.logdet_mean, base.logdet_mean
            );
            assert_grads_close(&base, &par, TOL,
                               &format!("{name} t={threads}"));
        }
    }
}

/// With a pinned microbatch size the reduction runs over the exact same
/// slot sequence whatever the thread count — results are bit-identical.
#[test]
fn fixed_microbatch_is_thread_count_invariant() {
    let flow = flow("realnvp2d");
    let params = flow.init_params(5).unwrap();
    let (x, _) = batch_for(&flow, 6);
    let reference = ParallelTrainer::new(1).microbatch(64)
        .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
        .unwrap();
    for threads in [2usize, 4] {
        let par = ParallelTrainer::new(threads).microbatch(64)
            .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
            .unwrap();
        assert_bit_identical(&reference, &par, &format!("mb=64 t={threads}"));
    }
}

/// Same seed + same thread count -> identical losses on every run.
#[test]
fn same_seed_same_threads_is_deterministic() {
    let run = || -> Vec<f32> {
        let flow = flow("realnvp2d");
        let mut params = flow.init_params(21).unwrap();
        let mut opt = Adam::new(1e-3);
        let mut rng = Pcg64::new(33);
        let cfg = TrainConfig {
            steps: 8,
            schedule: Arc::new(ExecMode::Invertible),
            clip: Some(GradClip { max_norm: 100.0 }),
            log_every: usize::MAX,
            quiet: true,
            threads: 4,
            ..TrainConfig::default()
        };
        train(&flow, &mut params, &mut opt, &cfg, |_| {
            Ok((Density2d::TwoMoons.sample(256, &mut rng), None))
        })
        .unwrap()
        .losses
    };
    let a = run();
    let b = run();
    for (step, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "step {step}: {x} vs {y}");
    }
}

/// Conditional nets: the per-shard dcond rows reassemble (reweighted) into
/// the full-batch conditioning gradient.
#[test]
fn conditional_net_parallel_matches() {
    let flow = flow("cond_realnvp2d");
    let params = flow.init_params(9).unwrap();
    let (x, cond) = batch_for(&flow, 13);
    let base = flow
        .train_step(&x, cond.as_ref(), &params, &ExecMode::Invertible)
        .unwrap();
    let par = ParallelTrainer::new(4)
        .train_step(&flow, &x, cond.as_ref(), &params, &ExecMode::Invertible)
        .unwrap();
    assert!((par.loss - base.loss).abs() <= TOL * base.loss.abs().max(1.0));
    assert_grads_close(&base, &par, TOL, "cond t=4");
}

/// A mismatched cond batch must fail with a shape error up front, not
/// panic inside a worker thread mid-slice.
#[test]
fn mismatched_cond_is_a_clean_error() {
    let flow = flow("cond_realnvp2d");
    let params = flow.init_params(1).unwrap();
    let (x, _) = batch_for(&flow, 2);
    let short_cond = invertnet::Tensor::zeros(&[128, 2]); // batch is 256
    let err = ParallelTrainer::new(2)
        .train_step(&flow, &x, Some(&short_cond), &params,
                    &ExecMode::Invertible)
        .unwrap_err();
    assert!(format!("{err:#}").contains("cond"), "{err:#}");
    // missing cond on a conditional net is also rejected up front
    let err = ParallelTrainer::new(2)
        .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
        .unwrap_err();
    assert!(format!("{err:#}").contains("cond"), "{err:#}");
}

/// Multiscale conv net (split steps + image layers) shards cleanly too.
#[test]
fn multiscale_glow_parallel_matches() {
    let flow = flow("glow16");
    let params = flow.init_params(17).unwrap();
    let (x, _) = batch_for(&flow, 23);
    let base = flow
        .train_step(&x, None, &params, &ExecMode::Invertible)
        .unwrap();
    let par = ParallelTrainer::new(2)
        .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
        .unwrap();
    assert!((par.loss - base.loss).abs() <= 5e-5 * base.loss.abs().max(1.0),
            "loss {} vs {}", par.loss, base.loss);
    assert_grads_close(&base, &par, 5e-5, "glow16 t=2");
}

/// Gradient-accumulation microbatching: the activation envelope follows
/// the microbatch size, so large effective batches fit the invertible
/// memory envelope.
#[test]
fn microbatching_caps_the_memory_envelope() {
    let flow = flow("realnvp2d");
    let params = flow.init_params(2).unwrap();
    let (x, _) = batch_for(&flow, 3);
    let full = flow
        .train_step(&x, None, &params, &ExecMode::Invertible)
        .unwrap()
        .peak_sched_bytes;
    let quarter = ParallelTrainer::new(1).microbatch(64)
        .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
        .unwrap()
        .peak_sched_bytes;
    assert!(quarter < full,
            "microbatched peak {quarter} should undercut full-batch {full}");
    // activations scale ~linearly in batch: a 4x smaller shard should cut
    // the envelope by well over half
    assert!(2 * quarter < full, "{quarter} vs {full}");
}

/// Ragged batches (batch not divisible by threads) reduce with shard-size
/// weights and still match.
#[test]
fn ragged_shards_match() {
    let flow = flow("realnvp2d");
    let params = flow.init_params(41).unwrap();
    let (x, _) = batch_for(&flow, 42);
    let base = flow.train_step(&x, None, &params, &ExecMode::Invertible).unwrap();
    // 256 = 3 * 86 - 2: shards of 86, 86, 84
    let par = ParallelTrainer::new(3)
        .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
        .unwrap();
    assert!((par.loss - base.loss).abs() <= 2e-5 * base.loss.abs().max(1.0),
            "loss {} vs {}", par.loss, base.loss);
    assert_grads_close(&base, &par, 2e-5, "ragged t=3");
}

/// A memory budget on the source flow's ledger carries into the forked
/// worker ledgers: an undersized budget must trip the simulated OOM on
/// the parallel path too.
#[test]
fn ledger_budget_survives_fork() {
    let engine = common::engine();
    let ledger = invertnet::MemoryLedger::with_budget(1024); // absurdly small
    let flow = engine.flow_with_ledger("realnvp2d", ledger).unwrap();
    let params = flow.init_params(1).unwrap();
    let (x, _) = batch_for(&flow, 2);
    let err = ParallelTrainer::new(2)
        .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
        .unwrap_err();
    assert!(format!("{err:#}").contains("OOM"), "{err:#}");
}

/// The training loop's `threads` config routes through the parallel path
/// and still learns.
#[test]
fn train_loop_parallel_path_learns() {
    let flow = flow("realnvp2d");
    let mut params = flow.init_params(11).unwrap();
    let mut opt = Adam::new(2e-3);
    let mut rng = Pcg64::new(70);
    let cfg = TrainConfig {
        steps: 40,
        schedule: Arc::new(ExecMode::Invertible),
        clip: Some(GradClip { max_norm: 100.0 }),
        log_every: usize::MAX,
        quiet: true,
        threads: 2,
        ..TrainConfig::default()
    };
    let report = train(&flow, &mut params, &mut opt, &cfg, |_| {
        Ok((Density2d::TwoMoons.sample(256, &mut rng), None))
    })
    .unwrap();
    assert!(report.final_loss.is_finite());
    assert!(
        invertnet::train::loop_::tail_mean(&report.losses, 10)
            < report.losses[0],
        "parallel loop did not learn: {} -> {}",
        report.losses[0], report.final_loss
    );
}

/// CLI: `invertnet train --threads 2` goes end to end.
#[test]
fn cli_train_with_threads() {
    let argv: Vec<String> = [
        "train", "--net", "realnvp2d", "--data", "two-moons", "--steps", "3",
        "--threads", "2", "--microbatch", "64", "--quiet",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    invertnet::app::run(&argv).unwrap_or_else(|e| panic!("{e:#}"));
}
