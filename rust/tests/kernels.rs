//! Kernel-equivalence suite: the vectorized GEMM/conv/im2col kernels
//! against their scalar references, from outside the crate — the CI
//! `kernel-equivalence` job runs exactly this target. The python numpy
//! mirror (`python/tests/test_vector_kernels.py`) pins the same
//! contracts against an independent implementation.
//!
//! Contracts pinned here:
//!  * packed 8-wide GEMM == naive triple loop within 1e-5, across shapes
//!    straddling the 4-row block and 8-column panel boundaries;
//!  * im2col lowering is bit-exact against direct indexing, and the
//!    lowered conv (1x1 fast path and 3x3 general path) matches the
//!    scalar scatter loop within 1e-5 — odd channel counts and
//!    non-multiple-of-8 tails included;
//!  * kernel-thread row splitting is bitwise invisible at any fixed
//!    thread count (disjoint rows, serial per-cell accumulation);
//!  * bf16/f16 storage round-trips obey their precision contracts
//!    (relative error <= 2^-8 / 2^-11), end to end through
//!    `Engine::load_weights`, and inference still runs on the rounded
//!    weights.

use invertnet::backend::math::{self, half, naive, par};
use invertnet::backend::WeightDtype;
use invertnet::util::rng::Pcg64;
use invertnet::{Engine, InferOpts, Tensor};

fn rand_t(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    Tensor {
        shape: shape.to_vec(),
        data: rng.normal_vec(shape.iter().product()),
    }
}

/// Shapes chosen to straddle every blocking boundary the packed kernel
/// has: MR=4 row blocks, NR=8 column panels, k tails, degenerate dims.
const GEMM_SHAPES: [(usize, usize, usize); 12] = [
    (1, 1, 1),
    (1, 1, 9),
    (2, 3, 8),
    (3, 5, 7),
    (4, 8, 16),
    (5, 3, 2),
    (7, 66, 9),
    (9, 13, 17),
    (13, 7, 25),
    (16, 32, 8),
    (31, 17, 23),
    (64, 108, 64),
];

#[test]
fn gemm_matches_scalar_reference_across_tail_shapes() {
    let mut rng = Pcg64::new(0xbead);
    for (n, k, m) in GEMM_SHAPES {
        let a = rand_t(&[n, k], &mut rng);
        let b = rand_t(&[k, m], &mut rng);
        let fast = math::matmul(&a, &b);
        let want = naive::matmul(&a, &b);
        let err = fast.max_abs_diff(&want);
        assert!(err < 1e-5, "gemm ({n},{k},{m}): max abs err {err}");
    }
}

#[test]
fn gemm_transpose_variants_agree_with_explicit_transposes() {
    let mut rng = Pcg64::new(0xfeed);
    for (n, k, m) in [(5, 3, 7), (8, 16, 9), (13, 4, 25)] {
        let a = rand_t(&[n, k], &mut rng);
        let b = rand_t(&[n, m], &mut rng);
        // aᵀ b via an explicitly transposed naive product
        let mut at = Tensor::zeros(&[k, n]);
        for i in 0..n {
            for p in 0..k {
                at.data[p * n + i] = a.data[i * k + p];
            }
        }
        let want = naive::matmul(&at, &b);
        let got = math::matmul_at(&a, &b);
        assert!(got.max_abs_diff(&want) < 1e-5, "matmul_at ({n},{k},{m})");
        // a bᵀ with b in the transposed layout
        let c = rand_t(&[m, k], &mut rng);
        let mut ct = Tensor::zeros(&[k, m]);
        for i in 0..m {
            for p in 0..k {
                ct.data[p * m + i] = c.data[i * k + p];
            }
        }
        let want = naive::matmul(&a, &ct);
        let got = math::matmul_bt(&a, &c);
        assert!(got.max_abs_diff(&want) < 1e-5, "matmul_bt ({n},{k},{m})");
    }
}

#[test]
fn im2col_is_bit_exact_and_conv_matches_scalar() {
    let mut rng = Pcg64::new(0xc0de);
    for (n, h, w, ci, co) in [
        (1, 1, 1, 1, 1),
        (2, 4, 5, 3, 4),
        (1, 3, 3, 7, 9),
        (2, 2, 6, 5, 8),
        (1, 8, 8, 12, 64), // the glow64 coupling shape, scaled down
        (3, 5, 7, 2, 13),
    ] {
        let x = rand_t(&[n, h, w, ci], &mut rng);
        let cols = math::im2col_same(&x, 3, 3);
        let want_cols = naive::im2col_same(&x, 3, 3);
        assert_eq!(cols.shape, want_cols.shape);
        assert_eq!(cols.data, want_cols.data, "im2col must be bit-exact");
        let wt = rand_t(&[3, 3, ci, co], &mut rng);
        let fast = math::conv2d_same(&x, &wt);
        let want = naive::conv2d_same(&x, &wt);
        let err = fast.max_abs_diff(&want);
        assert!(err < 1e-5, "conv ({n},{h},{w},{ci},{co}): {err}");
        // 1x1 fast path against the same scalar loop
        let w1 = rand_t(&[1, 1, ci, co], &mut rng);
        let fast1 = math::conv2d_same(&x, &w1);
        let want1 = naive::conv2d_same(&x, &w1);
        let err1 = fast1.max_abs_diff(&want1);
        assert!(err1 < 1e-5, "1x1 conv ({n},{h},{w},{ci},{co}): {err1}");
    }
}

#[test]
fn fixed_thread_count_is_bitwise_deterministic() {
    let mut rng = Pcg64::new(0xd117);
    let a = rand_t(&[67, 33], &mut rng);
    let b = rand_t(&[33, 29], &mut rng);
    let x = rand_t(&[2, 9, 9, 5], &mut rng);
    let w = rand_t(&[3, 3, 5, 11], &mut rng);
    let serial = (math::matmul(&a, &b), math::conv2d_same(&x, &w));
    for t in [1usize, 2, 3, 4, 7] {
        // two runs at the same fixed count: bit-equal to each other AND
        // to the serial walk (row splits never change accumulation order)
        let r1 = par::with_kernel_threads(t, || {
            (math::matmul(&a, &b), math::conv2d_same(&x, &w))
        });
        let r2 = par::with_kernel_threads(t, || {
            (math::matmul(&a, &b), math::conv2d_same(&x, &w))
        });
        assert_eq!(r1.0.data, r2.0.data, "gemm not deterministic at t={t}");
        assert_eq!(r1.1.data, r2.1.data, "conv not deterministic at t={t}");
        assert_eq!(r1.0.data, serial.0.data, "gemm differs from serial at t={t}");
        assert_eq!(r1.1.data, serial.1.data, "conv differs from serial at t={t}");
    }
}

#[test]
fn half_storage_roundtrip_obeys_precision_contracts() {
    let mut rng = Pcg64::new(0x4a1f);
    let vals = rng.normal_vec(4096);
    for &v in &vals {
        let b = half::bf16_to_f32(half::f32_to_bf16(v));
        // bf16 keeps 8 significand bits: relative error <= 2^-8
        assert!(
            (b - v).abs() <= v.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE,
            "bf16 {v} -> {b}"
        );
        let h = half::f16_to_f32(half::f32_to_f16(v));
        // f16 keeps 11 significand bits over the normal range
        assert!(
            (h - v).abs() <= v.abs() * (1.0 / 2048.0) + 6.2e-5,
            "f16 {v} -> {h}"
        );
    }
    // idempotent: a rounded value is a fixed point of the round-trip
    for &v in vals.iter().take(64) {
        let b = half::bf16_to_f32(half::f32_to_bf16(v));
        assert_eq!(b, half::bf16_to_f32(half::f32_to_bf16(b)));
        let h = half::f16_to_f32(half::f32_to_f16(v));
        assert_eq!(h, half::f16_to_f32(half::f32_to_f16(h)));
    }
}

#[test]
fn engine_weight_dtype_rounds_weights_and_inference_survives() {
    let full = Engine::native().unwrap();
    let flow = full.flow("realnvp2d").unwrap();
    let params = flow.init_params(42).unwrap();

    let engine = Engine::builder()
        .weight_dtype(WeightDtype::Bf16)
        .build()
        .unwrap();
    assert_eq!(engine.config().weight_dtype, WeightDtype::Bf16);
    let mut rounded = params.clone();
    engine.load_weights(&mut rounded);

    let mut changed = 0usize;
    for (a, b) in params.tensors.iter().flatten()
        .zip(rounded.tensors.iter().flatten())
    {
        for (&x, &y) in a.data.iter().zip(&b.data) {
            assert!(
                (x - y).abs() <= x.abs() * (1.0 / 256.0) + f32::MIN_POSITIVE,
                "bf16 load moved {x} to {y}"
            );
            if x != y {
                changed += 1;
            }
        }
    }
    assert!(changed > 0, "bf16 rounding should actually change weights");

    // inference on the rounded store still runs and stays finite
    let rflow = engine.flow("realnvp2d").unwrap();
    let mut rng = Pcg64::new(7);
    let x = rand_t(&[rflow.batch(), 2], &mut rng);
    let lp = rflow
        .log_density(&x, &rounded, InferOpts::strict())
        .unwrap();
    assert!(lp.iter().all(|v| v.is_finite()));

    // f32 mode is a strict no-op
    let noop = Engine::builder().weight_dtype(WeightDtype::F32)
        .build().unwrap();
    let mut same = params.clone();
    noop.load_weights(&mut same);
    for (a, b) in params.tensors.iter().flatten()
        .zip(same.tensors.iter().flatten())
    {
        assert_eq!(a.data, b.data);
    }
}
