//! Whole-objective gradient cross-checks — the strongest end-to-end test
//! of the hand-written per-layer backward programs (paper §3).
//!
//! Historically this file compared against a jax-lowered full-AD monolith
//! executable; the hermetic replacement checks the same thing two ways:
//! 1. central finite differences of the NLL objective against the
//!    coordinator's analytic gradients, and
//! 2. a checkpoint-every-k hybrid schedule (mixing `backward` and
//!    `backward_stored` within one walk) against both pure schedules.

mod common;

use common::{assert_close, batch_for, flow};
use invertnet::coordinator::{CheckpointEveryK, ExecMode, InferOpts};

/// NLL(x) = -mean_n(logp_n + logdet_n), same objective train_step reports.
fn nll(flow: &invertnet::Flow, x: &invertnet::Tensor,
       cond: Option<&invertnet::Tensor>, params: &invertnet::flow::ParamStore)
       -> f64 {
    let ll = flow.log_density(
        x, params, InferOpts::strict().cond_opt(cond)).unwrap();
    -(ll.iter().map(|v| *v as f64).sum::<f64>() / ll.len() as f64)
}

#[test]
fn analytic_gradients_match_finite_differences() {
    let flow = flow("realnvp2d");
    let params = flow.init_params(321).unwrap();
    let (x, _) = batch_for(&flow, 99);

    let step = flow
        .train_step(&x, None, &params, &ExecMode::Invertible)
        .unwrap();
    // the reported loss and the eval-path objective must agree
    let base = nll(&flow, &x, None, &params);
    assert!(
        (base - step.loss as f64).abs() < 1e-4 * base.abs().max(1.0),
        "loss {} vs eval-path {base}",
        step.loss
    );

    // central differences on a spread of parameter coordinates:
    // (step, param, flat index) across first/middle/last couplings and
    // every conditioner parameter role (w1, b1, w2, b2, w3, b3)
    let probes: &[(usize, usize)] = &[
        (0, 0), (0, 5), (6, 2), (6, 4), (14, 1), (14, 3), (14, 5),
    ];
    let eps = 1e-2f32;
    let mut checked = 0;
    for &(si, pi) in probes {
        let g = &step.grads[si][pi];
        if g.is_empty() {
            continue;
        }
        let idx = g.len() / 2;
        let mut pp = params.clone();
        pp.tensors[si][pi].data[idx] += eps;
        let mut pm = params.clone();
        pm.tensors[si][pi].data[idx] -= eps;
        let fd = (nll(&flow, &x, None, &pp) - nll(&flow, &x, None, &pm))
            / (2.0 * eps as f64);
        let an = g.data[idx] as f64;
        assert!(
            (fd - an).abs() <= 0.05 * an.abs().max(fd.abs()).max(0.05),
            "step {si} param {pi} idx {idx}: fd {fd} vs analytic {an}"
        );
        checked += 1;
    }
    assert!(checked >= 5, "probed too few coordinates ({checked})");
}

#[test]
fn finite_differences_on_multiscale_glow() {
    let flow = flow("glow16");
    let params = flow.init_params(17).unwrap();
    let (x, _) = batch_for(&flow, 23);
    let step = flow
        .train_step(&x, None, &params, &ExecMode::Invertible)
        .unwrap();

    // probe one coordinate in an actnorm (log_s), a conv1x1 (v2) and a
    // coupling conditioner (b1) — three different gradient paths
    let mut probes: Vec<(usize, usize)> = Vec::new();
    for (si, step_def) in flow.def.steps.iter().enumerate() {
        if step_def.sig.starts_with("actnorm") && probes.is_empty() {
            probes.push((si, 0)); // log_s
        }
        if step_def.sig.starts_with("conv1x1") && probes.len() == 1 {
            probes.push((si, 1)); // v2
        }
        if step_def.sig.starts_with("glowcpl") && probes.len() == 2 {
            probes.push((si, 1)); // b1
        }
    }
    assert_eq!(probes.len(), 3);
    let eps = 1e-2f32;
    for (si, pi) in probes {
        let g = &step.grads[si][pi];
        let idx = g.len() / 2;
        let mut pp = params.clone();
        pp.tensors[si][pi].data[idx] += eps;
        let mut pm = params.clone();
        pm.tensors[si][pi].data[idx] -= eps;
        let fd = (nll(&flow, &x, None, &pp) - nll(&flow, &x, None, &pm))
            / (2.0 * eps as f64);
        let an = g.data[idx] as f64;
        assert!(
            (fd - an).abs() <= 0.08 * an.abs().max(fd.abs()).max(0.05),
            "step {si} param {pi} idx {idx}: fd {fd} vs analytic {an}"
        );
    }
}

/// A hybrid schedule interleaves `backward` (recompute) and
/// `backward_stored` (tape) calls in one walk; its loss/grads must match
/// both pure schedules exactly (same math, different buffer lifetimes).
#[test]
fn checkpoint_hybrid_matches_pure_schedules() {
    for net in ["realnvp2d", "glow16"] {
        let flow = flow(net);
        let params = flow.init_params(4321).unwrap();
        let (x, cond) = batch_for(&flow, 55);

        let inv = flow
            .train_step(&x, cond.as_ref(), &params, &ExecMode::Invertible)
            .unwrap();
        for k in [2usize, 3, 5] {
            let hyb = flow
                .train_step(&x, cond.as_ref(), &params, &CheckpointEveryK(k))
                .unwrap();
            assert!(
                (inv.loss - hyb.loss).abs() <= 5e-4 * inv.loss.abs().max(1.0),
                "{net} k={k}: loss {} vs {}",
                inv.loss,
                hyb.loss
            );
            for (si, (gi, gh)) in inv.grads.iter().zip(&hyb.grads).enumerate() {
                for (pi, (a, b)) in gi.iter().zip(gh).enumerate() {
                    assert_close(a, b, 5e-4,
                                 &format!("{net} k={k} step {si} param {pi}"));
                }
            }
        }
    }
}
