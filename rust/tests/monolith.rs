//! Full-AD monolith ablation: the whole network + NLL loss differentiated
//! by jax in ONE XLA program must produce the same loss and parameter
//! gradients as the coordinator's per-layer hand-written backward walk —
//! the strongest end-to-end check of the paper's "gradients by hand"
//! claim (§3).

mod common;

use common::{assert_close, batch_for, runtime};
use invertnet::coordinator::{ExecMode, FlowSession};
use invertnet::flow::ParamStore;
use invertnet::MemoryLedger;

fn check(net: &str, tol: f32) {
    let rt = runtime();
    let session = FlowSession::new(&rt, net, MemoryLedger::new()).unwrap();
    let params = ParamStore::init(&session.def, &rt.manifest, 321).unwrap();
    let (x, _) = batch_for(&session, 99);

    // coordinator path
    let step = session
        .train_step(&x, None, &params, ExecMode::Invertible)
        .unwrap();

    // monolith path: (x, *flat_params) -> (loss, *dparams)
    let mono = rt.monolith_entry(net).unwrap();
    let x_lit = x.to_literal().unwrap();
    let flat: Vec<xla::Literal> = params
        .tensors
        .iter()
        .flatten()
        .map(|t| t.to_literal().unwrap())
        .collect();
    let mut args = vec![&x_lit];
    args.extend(flat.iter());
    let results = mono.execute_t(&args).unwrap();

    let loss = results[0].data[0];
    assert!(
        (loss - step.loss).abs() <= tol * loss.abs().max(1.0),
        "{net}: monolith loss {loss} vs coordinator {}",
        step.loss
    );

    let coord_grads: Vec<_> = step.grads.iter().flatten().collect();
    assert_eq!(coord_grads.len(), results.len() - 1, "{net}: grad arity");
    for (i, (mono_g, coord_g)) in results[1..].iter().zip(coord_grads).enumerate() {
        assert_close(mono_g, coord_g, tol, &format!("{net} grad {i}"));
    }
}

#[test]
fn realnvp_monolith_matches_coordinator() {
    check("realnvp2d", 3e-4);
}

#[test]
fn glow_monolith_matches_coordinator() {
    check("glow_bench32", 1e-3);
}

#[test]
fn missing_monolith_is_an_error() {
    let rt = runtime();
    assert!(rt.monolith_entry("hint8d").is_err());
}
