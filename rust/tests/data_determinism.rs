//! Fixed-seed determinism contracts for every synthetic data generator.
//!
//! Reproducibility guarantees across the crate (serve-side seeded
//! sampling, SBC at pinned seeds, checkpoint comparisons in CI) all
//! bottom out in these generators being bit-exact functions of their
//! seed. The PCG64 reference streams below are pinned against an
//! independent integer-exact implementation
//! (`python/tests/test_posterior_oracle.py` checks the same constants),
//! so a silent change to the generator cannot slip through.

use invertnet::data::{synth_images, Density2d, LinearGaussian};
use invertnet::util::rng::Pcg64;

/// First four raw outputs for seeds 0, 1, 42 — computed with an
/// independent big-integer implementation of PCG-XSL-RR 128/64 with this
/// crate's splitmix seeding (exact integer arithmetic, no float).
const PCG_STREAMS: [(u64, [u64; 4]); 3] = [
    (0, [0x906d4eca56ed8ae5, 0xe4a474dc21387f33,
         0x9efd931a70ae01dd, 0x87a81634d5e319bb]),
    (1, [0x6d47425bcbabc14d, 0xec400d71d0b112f5,
         0xb1575561e45b957e, 0x0a47d6678a408530]),
    (42, [0x1c8a598cb5cde4df, 0x370266b610066177,
          0x9c11b2ead90b8e58, 0x0549ff73553b7cf1]),
];

#[test]
fn pcg64_matches_the_reference_streams() {
    for (seed, want) in PCG_STREAMS {
        let mut rng = Pcg64::new(seed);
        for (i, &w) in want.iter().enumerate() {
            let got = rng.next_u64();
            assert_eq!(got, w,
                       "seed {seed} output {i}: {got:#018x} != {w:#018x}");
        }
    }
}

#[test]
fn uniform_is_a_pure_function_of_the_stream() {
    // (next_u64() >> 11) * 2^-53 involves no rounding, so these values
    // are exact — equality, not tolerance
    let mut rng = Pcg64::new(42);
    let want = [0.11148605046565008f64, 0.2148803896416438,
                0.6096450637206045, 0.02066036763902257];
    for (i, &w) in want.iter().enumerate() {
        let got = rng.uniform();
        assert_eq!(got, w, "uniform output {i}");
    }
}

#[test]
fn density2d_sampling_is_bit_exact_per_seed() {
    for d in [Density2d::TwoMoons, Density2d::EightGaussians,
              Density2d::Checkerboard, Density2d::Spiral] {
        let a = d.sample(64, &mut Pcg64::new(91));
        let b = d.sample(64, &mut Pcg64::new(91));
        assert_eq!(a.shape, b.shape);
        for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{d:?} elem {i} drifted");
        }
        // a different seed actually changes the draw
        let c = d.sample(64, &mut Pcg64::new(92));
        assert!(a.data.iter().zip(&c.data).any(|(x, y)| x != y),
                "{d:?} ignores its seed");
    }
}

#[test]
fn synth_images_is_bit_exact_per_seed() {
    let a = synth_images(3, 8, 8, 2, &mut Pcg64::new(17));
    let b = synth_images(3, 8, 8, 2, &mut Pcg64::new(17));
    assert_eq!(a.shape, vec![3, 8, 8, 2]);
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "image elem {i} drifted");
    }
    let c = synth_images(3, 8, 8, 2, &mut Pcg64::new(18));
    assert!(a.data.iter().zip(&c.data).any(|(x, y)| x != y));
}

#[test]
fn linear_gaussian_sampling_is_bit_exact_per_seed() {
    let prob = LinearGaussian::default_problem();
    let (ta, ya) = prob.sample(128, &mut Pcg64::new(23));
    let (tb, yb) = prob.sample(128, &mut Pcg64::new(23));
    for (a, b) in ta.data.iter().zip(&tb.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "theta drifted");
    }
    for (a, b) in ya.data.iter().zip(&yb.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "y drifted");
    }
    // the exact posterior sampler is deterministic too
    let pa = prob.sample_posterior([0.7, -0.4], 32, &mut Pcg64::new(5));
    let pb = prob.sample_posterior([0.7, -0.4], 32, &mut Pcg64::new(5));
    for (a, b) in pa.data.iter().zip(&pb.data) {
        assert_eq!(a.to_bits(), b.to_bits(), "posterior draw drifted");
    }
}

#[test]
fn below_is_deterministic_and_in_range() {
    let mut a = Pcg64::new(7);
    let mut b = Pcg64::new(7);
    for _ in 0..200 {
        let (x, y) = (a.below(8), b.below(8));
        assert_eq!(x, y);
        assert!(x < 8);
    }
}
