//! Shared helpers for integration tests: runtime bootstrap + batch makers.

use std::path::PathBuf;

use invertnet::coordinator::FlowSession;
use invertnet::data::{synth_images, Density2d, LinearGaussian};
use invertnet::util::rng::Pcg64;
use invertnet::{Runtime, Tensor};

pub fn artifacts_dir() -> PathBuf {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts/manifest.json missing — run `make artifacts` first"
    );
    dir
}

pub fn runtime() -> Runtime {
    Runtime::new(&artifacts_dir()).expect("runtime boot")
}

/// A deterministic input batch matching the network's shape (and cond if
/// conditional).
pub fn batch_for(session: &FlowSession, seed: u64) -> (Tensor, Option<Tensor>) {
    let mut rng = Pcg64::new(seed);
    let s = &session.def.in_shape;
    if session.def.cond_shape.is_some() {
        let prob = LinearGaussian::default_problem();
        let (theta, y) = prob.sample(s[0], &mut rng);
        (theta, Some(y))
    } else if s.len() == 2 && s[1] == 2 {
        (Density2d::TwoMoons.sample(s[0], &mut rng), None)
    } else if s.len() == 2 {
        (Tensor { shape: s.clone(), data: rng.normal_vec(s.iter().product()) },
         None)
    } else {
        (synth_images(s[0], s[1], s[2], s[3], &mut rng), None)
    }
}

pub fn assert_close(a: &Tensor, b: &Tensor, tol: f32, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shape mismatch");
    let d = a.max_abs_diff(b);
    let scale = a.linf().max(b.linf()).max(1.0);
    assert!(
        d <= tol * scale,
        "{what}: max|diff| {d} > {tol} * scale {scale}"
    );
}
