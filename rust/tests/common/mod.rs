//! Shared helpers for integration tests: hermetic engine bootstrap + batch
//! makers. Everything runs on the RefBackend over the builtin catalog —
//! no `artifacts/` directory required.

#![allow(dead_code)] // not every test file uses every helper

use invertnet::api::{Engine, Flow};
use invertnet::data::{synth_images, Density2d, LinearGaussian};
use invertnet::util::rng::Pcg64;
use invertnet::Tensor;

/// Hermetic engine: builtin network catalog + pure-Rust RefBackend.
pub fn engine() -> Engine {
    Engine::builder().build().expect("engine boot")
}

/// An owned flow handle on the hermetic engine.
pub fn flow(net: &str) -> Flow {
    engine().flow(net).expect("flow boot")
}

/// A deterministic input batch matching the network's shape (and cond if
/// conditional).
pub fn batch_for(flow: &Flow, seed: u64) -> (Tensor, Option<Tensor>) {
    let mut rng = Pcg64::new(seed);
    let s = &flow.def.in_shape;
    if flow.def.cond_shape.is_some() {
        let prob = LinearGaussian::default_problem();
        let (theta, y) = prob.sample(s[0], &mut rng);
        (theta, Some(y))
    } else if s.len() == 2 && s[1] == 2 {
        (Density2d::TwoMoons.sample(s[0], &mut rng), None)
    } else if s.len() == 2 {
        (Tensor { shape: s.clone(), data: rng.normal_vec(s.iter().product()) },
         None)
    } else {
        (synth_images(s[0], s[1], s[2], s[3], &mut rng), None)
    }
}

pub fn assert_close(a: &Tensor, b: &Tensor, tol: f32, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shape mismatch");
    let d = a.max_abs_diff(b);
    let scale = a.linf().max(b.linf()).max(1.0);
    assert!(
        d <= tol * scale,
        "{what}: max|diff| {d} > {tol} * scale {scale}"
    );
}
