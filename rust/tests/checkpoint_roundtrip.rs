//! Checkpoint round-trip guarantees: `ParamStore::save`/`load` through
//! `tensor/npy` must preserve every parameter bit-exactly for every
//! builtin example network (including the ragged multiscale shapes of
//! glow16 and the per-node HINT parameter trees), and inference after a
//! reload must reproduce pre-save results exactly — a served model must
//! not drift by a ULP across a restart.

mod common;

use invertnet::tensor::ops::slice_rows;
use invertnet::util::rng::Pcg64;
use invertnet::{InferOpts, SampleOpts};

/// Every layer kind + split topology in the catalog, at test-runnable
/// sizes (the fig-sweep nets repeat these kinds bigger).
const NETS: [&str; 6] = ["realnvp2d", "cond_realnvp2d", "hint8d", "glow16",
                         "hyper16", "nice16"];

fn tmp_dir(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir()
        .join(format!("invertnet_ckpt_{tag}_{}", std::process::id()))
}

#[test]
fn params_roundtrip_bit_exactly_for_every_builtin_net() {
    for net in NETS {
        let flow = common::flow(net);
        let saved = flow.init_params(31).unwrap();
        let dir = tmp_dir(net);
        saved.save(&dir, net).unwrap();

        // start from different weights so the load has to do the work
        let mut loaded = flow.init_params(99).unwrap();
        let differs = saved.tensors.iter().flatten()
            .zip(loaded.tensors.iter().flatten())
            .any(|(a, b)| a != b);
        assert!(differs, "{net}: seeds 31 and 99 initialized identically?");

        loaded.load(&dir).unwrap();
        assert_eq!(saved.num_steps(), loaded.num_steps(), "{net}");
        for (si, (ts_a, ts_b)) in saved.tensors.iter()
            .zip(&loaded.tensors).enumerate() {
            assert_eq!(ts_a.len(), ts_b.len(), "{net} step {si}");
            for (pi, (a, b)) in ts_a.iter().zip(ts_b).enumerate() {
                assert_eq!(a.shape, b.shape, "{net} s{si}/p{pi}");
                for (va, vb) in a.data.iter().zip(&b.data) {
                    assert_eq!(va.to_bits(), vb.to_bits(),
                               "{net} s{si}/p{pi}: {va} != {vb}");
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn log_density_is_bit_identical_after_reload() {
    for net in NETS {
        let flow = common::flow(net);
        let params = flow.init_params(31).unwrap();
        let dir = tmp_dir(&format!("ld_{net}"));
        params.save(&dir, net).unwrap();

        // a small relaxed batch keeps the image nets fast
        let (x_full, cond_full) = common::batch_for(&flow, 7);
        let k = 4.min(x_full.batch());
        let x = slice_rows(&x_full, 0, k).unwrap();
        let cond = cond_full.as_ref()
            .map(|c| slice_rows(c, 0, k).unwrap());

        let before = flow.log_density(
            &x, &params, InferOpts::relaxed().cond_opt(cond.as_ref())).unwrap();

        let mut reloaded = flow.init_params(99).unwrap();
        reloaded.load(&dir).unwrap();
        let after = flow.log_density(
            &x, &reloaded, InferOpts::relaxed().cond_opt(cond.as_ref())).unwrap();

        assert_eq!(before.len(), after.len(), "{net}");
        for (a, b) in before.iter().zip(&after) {
            assert!(a.is_finite(), "{net}: non-finite log-density {a}");
            assert_eq!(a.to_bits(), b.to_bits(),
                       "{net}: pre-save {a} != post-reload {b}");
        }

        // sampling is pinned too: same latents, same weights, same bits
        let c2 = cond.as_ref().map(|c| slice_rows(c, 0, 2).unwrap());
        let s_before = flow.sample(&params,
            SampleOpts::new(2, &mut Pcg64::new(12))
                .cond_opt(c2.as_ref())).unwrap();
        let s_after = flow.sample(&reloaded,
            SampleOpts::new(2, &mut Pcg64::new(12))
                .cond_opt(c2.as_ref())).unwrap();
        assert_eq!(s_before, s_after, "{net}: sampling drifted after reload");

        std::fs::remove_dir_all(&dir).ok();
    }
}

#[test]
fn load_rejects_mismatched_checkpoints() {
    let flow_a = common::flow("realnvp2d");
    let params_a = flow_a.init_params(1).unwrap();
    let dir = tmp_dir("mismatch");
    params_a.save(&dir, "realnvp2d").unwrap();

    // a different architecture must refuse these tensors
    let flow_b = common::flow("hint8d");
    let mut params_b = flow_b.init_params(1).unwrap();
    assert!(params_b.load(&dir).is_err(),
            "hint8d accepted a realnvp2d checkpoint");
    std::fs::remove_dir_all(&dir).ok();
}
