//! Integration tests for the `serve/` subsystem.
//!
//! The load-bearing contract: **micro-batching is invisible**. A response
//! produced by a coalesced pass must be bit-identical to a direct
//! `Flow::sample` / `Flow::log_density` call with the same inputs —
//! concurrency and batching may only change throughput, never bits.

mod common;

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

use invertnet::api::Engine;
use invertnet::serve::{BatchConfig, Registry, Request, Response, Server};
use invertnet::tensor::ops::slice_rows;
use invertnet::util::rng::Pcg64;
use invertnet::{InferOpts, SampleOpts, Tensor};

const NET: &str = "realnvp2d";
const PARAM_SEED: u64 = 3;

fn boot_server(max_batch: usize, delay: Duration, workers: usize) -> Server {
    let registry = Registry::new(Engine::native().unwrap(), 4);
    registry.register_untrained(NET, PARAM_SEED).unwrap();
    Server::new(registry, BatchConfig {
        max_batch,
        max_delay: delay,
        workers,
        queue_cap: 256,
    }).allow_untrained()
}

/// What one client sends in one round, derived only from (client, round) —
/// so the expected bits can be recomputed independently.
fn round_inputs(flow: &invertnet::Flow, client: u64, round: u64)
                -> (u64, usize, f32, Tensor) {
    let seed = 1000 * client + round;
    let n = 1 + ((client + round) % 3) as usize;
    let temperature = [1.0f32, 0.7, 1.3][(round % 3) as usize];
    let d = flow.def.in_shape[1];
    let mut rng = Pcg64::new(seed ^ 0xd0_0d);
    let x = Tensor { shape: vec![n, d], data: rng.normal_vec(n * d) };
    (seed, n, temperature, x)
}

/// The acceptance-criterion test: >= 4 concurrent TCP clients interleaving
/// `sample` and `score`, every response bit-identical to a direct
/// in-process call on an independent engine.
#[test]
fn tcp_four_concurrent_clients_get_bit_identical_answers() {
    let server = boot_server(8, Duration::from_micros(400), 2);
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    let server = Arc::new(server);

    // reference results come from a *separate* engine: same catalog, same
    // param seed -> same weights
    let ref_flow = common::flow(NET);
    let ref_params = ref_flow.init_params(PARAM_SEED).unwrap();

    std::thread::scope(|scope| {
        let srv = server.clone();
        let acceptor = scope.spawn(move || srv.serve_tcp(listener).unwrap());

        let clients: Vec<_> = (0..4u64).map(|client| {
            let ref_flow = &ref_flow;
            let ref_params = &ref_params;
            scope.spawn(move || {
                let stream = TcpStream::connect(addr).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                let mut writer = stream;
                for round in 0..5u64 {
                    let (seed, n, temperature, x) =
                        round_inputs(ref_flow, client, round);

                    // sample, then recompute the same draw directly
                    let req = Request::Sample {
                        model: None, n, temperature, seed, cond: None,
                    };
                    writeln!(writer, "{}", req.to_json().to_string()).unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let Response::Sample { x: got } =
                        Response::parse_line(line.trim()).unwrap()
                    else { panic!("client {client}: {line}") };
                    let want = ref_flow.sample(ref_params,
                        SampleOpts::new(n, &mut Pcg64::new(seed))
                            .temperature(temperature)).unwrap();
                    assert_eq!(got.shape, want.shape);
                    for (a, b) in got.data.iter().zip(&want.data) {
                        assert_eq!(a.to_bits(), b.to_bits(),
                                   "client {client} round {round}: sample \
                                    {a} != direct {b}");
                    }

                    // score, same deal
                    let req = Request::Score {
                        model: None, x: x.clone(), cond: None,
                    };
                    writeln!(writer, "{}", req.to_json().to_string()).unwrap();
                    let mut line = String::new();
                    reader.read_line(&mut line).unwrap();
                    let Response::Score { log_density } =
                        Response::parse_line(line.trim()).unwrap()
                    else { panic!("client {client}: {line}") };
                    let want = ref_flow.log_density(
                        &x, ref_params, InferOpts::relaxed()).unwrap();
                    assert_eq!(log_density.len(), want.len());
                    for (a, b) in log_density.iter().zip(&want) {
                        assert_eq!(a.to_bits(), b.to_bits(),
                                   "client {client} round {round}: score \
                                    {a} != direct {b}");
                    }
                }
            })
        }).collect();
        for c in clients {
            c.join().unwrap();
        }

        // stats reflect the traffic; then shut the listener down
        let stream = TcpStream::connect(addr).unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        let mut writer = stream;
        writeln!(writer, "{}", Request::Stats.to_json().to_string()).unwrap();
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let Response::Stats(snap) = Response::parse_line(line.trim()).unwrap()
        else { panic!("{line}") };
        assert_eq!(snap.requests, 4 * 5 * 2, "{snap:?}");
        assert!(snap.batches >= 1 && snap.batches <= snap.requests);
        assert_eq!(snap.errors, 0, "{snap:?}");

        writeln!(writer, "{}",
                 Request::Shutdown.to_json().to_string()).unwrap();
        line.clear();
        reader.read_line(&mut line).unwrap();
        assert_eq!(Response::parse_line(line.trim()).unwrap(),
                   Response::Shutdown);
        acceptor.join().unwrap();
    });
}

/// A complete scripted stdio session: sample + score + stats + shutdown
/// (the same script CI pipes through `invertnet serve --stdio`).
#[test]
fn stdio_scripted_session() {
    let server = boot_server(8, Duration::from_micros(200), 2);
    let session = concat!(
        r#"{"op":"sample","n":2,"seed":5,"temperature":0.8}"#, "\n",
        r#"{"op":"score","x":{"shape":[2,2],"data":[0.1,-0.2,1.5,0.3]}}"#,
        "\n",
        r#"{"op":"stats"}"#, "\n",
        r#"{"op":"shutdown"}"#, "\n",
    );
    let mut out = Vec::new();
    server.serve_stdio(session.as_bytes(), &mut out).unwrap();
    let text = String::from_utf8(out).unwrap();
    let responses: Vec<Response> = text.lines()
        .map(|l| Response::parse_line(l).unwrap())
        .collect();
    assert_eq!(responses.len(), 4, "{text}");
    let Response::Sample { x } = &responses[0] else { panic!("{text}") };
    assert_eq!(x.shape, vec![2, 2]);
    let Response::Score { log_density } = &responses[1] else {
        panic!("{text}")
    };
    assert!(log_density.iter().all(|v| v.is_finite()), "{log_density:?}");
    let Response::Stats(snap) = &responses[2] else { panic!("{text}") };
    assert_eq!(snap.requests, 2);
    assert_eq!(responses[3], Response::Shutdown);
}

/// Satellite property test, pinned for every servable builtin network:
/// `log_density(sample(z; T=1))` is finite, and scoring a batch equals
/// scoring each item alone, bit-exactly — micro-batching cannot change
/// results.
#[test]
fn log_density_finite_and_batching_exact_on_all_builtin_nets() {
    // the example nets cover every layer kind + ragged multiscale latents;
    // the fig-sweep nets repeat the same kinds at sizes too slow for CI
    let nets = ["realnvp2d", "cond_realnvp2d", "hint8d", "glow16",
                "hyper16", "nice16", "glow_bench32"];
    for net in nets {
        let flow = common::flow(net);
        let params = flow.init_params(17).unwrap();
        let k = 3usize;
        let mut rng = Pcg64::new(99);
        let cond = flow.def.cond_shape.as_ref().map(|s| {
            let inner: usize = s[1..].iter().product();
            let mut shape = s.clone();
            shape[0] = k;
            Tensor { shape, data: rng.normal_vec(k * inner) }
        });

        let x = flow.sample(&params, SampleOpts::new(k, &mut rng)
                                .cond_opt(cond.as_ref()))
            .unwrap_or_else(|e| panic!("{net}: sample: {e:#}"));
        assert_eq!(x.shape[0], k, "{net}");
        assert_eq!(x.shape[1..], flow.def.in_shape[1..], "{net}");

        let batched = flow.log_density(
                &x, &params, InferOpts::relaxed().cond_opt(cond.as_ref()))
            .unwrap_or_else(|e| panic!("{net}: log_density: {e:#}"));
        assert_eq!(batched.len(), k, "{net}");
        assert!(batched.iter().all(|v| v.is_finite()),
                "{net}: non-finite log-density {batched:?}");

        for i in 0..k {
            let xi = slice_rows(&x, i, 1).unwrap();
            let ci = cond.as_ref().map(|c| slice_rows(c, i, 1).unwrap());
            let solo = flow.log_density(
                &xi, &params, InferOpts::relaxed().cond_opt(ci.as_ref()))
                .unwrap();
            assert_eq!(solo.len(), 1);
            assert_eq!(solo[0].to_bits(), batched[i].to_bits(),
                       "{net} row {i}: solo {} != batched {}",
                       solo[0], batched[i]);
        }
    }
}

/// Temperature scales the latent draw: T=0 collapses to the mode path,
/// and the defaulted `SampleOpts` (T=1) is an exact draw.
#[test]
fn sample_temperature_contract() {
    let flow = common::flow(NET);
    let params = flow.init_params(PARAM_SEED).unwrap();

    let canon = flow.sample(&params,
        SampleOpts::new(flow.batch(), &mut Pcg64::new(8))).unwrap();
    let explicit = flow.sample(&params,
        SampleOpts::new(flow.batch(), &mut Pcg64::new(8))
            .temperature(1.0)).unwrap();
    assert_eq!(canon, explicit, "T=1 must equal the defaulted draw");

    // T=0: all latents are zero -> every sample row is the same mode point
    let x0 = flow.sample(&params,
        SampleOpts::new(4, &mut Pcg64::new(8)).temperature(0.0)).unwrap();
    let row0 = slice_rows(&x0, 0, 1).unwrap();
    for i in 1..4 {
        assert_eq!(slice_rows(&x0, i, 1).unwrap().data, row0.data,
                   "T=0 rows must be identical");
    }
    assert!(flow.sample(&params,
        SampleOpts::new(2, &mut Pcg64::new(8))
            .temperature(f32::NAN)).is_err());
    assert!(flow.sample(&params,
        SampleOpts::new(0, &mut Pcg64::new(8))).is_err());
}

/// The `#[deprecated]` pre-unification names are thin wrappers: same
/// bits as the option-struct entry points. This is the one place the old
/// names are still exercised.
#[test]
#[allow(deprecated)]
fn deprecated_wrappers_match_unified_api() {
    let flow = common::flow(NET);
    let params = flow.init_params(PARAM_SEED).unwrap();

    let a = flow.sample_batch(&params, 5, None, 0.7,
                              &mut Pcg64::new(4)).unwrap();
    let b = flow.sample(&params, SampleOpts::new(5, &mut Pcg64::new(4))
                            .temperature(0.7)).unwrap();
    assert_eq!(a, b, "sample_batch wrapper drifted");

    let old = flow.log_likelihood(&b, None, &params);
    // 5 rows != canonical batch: the strict wrapper must reject...
    assert!(old.is_err() == (flow.batch() != 5));
    let ld_old: Vec<f32>; let ld_new: Vec<f32>;
    if flow.batch() == 5 {
        ld_old = old.unwrap();
        ld_new = flow.log_density(&b, &params, InferOpts::strict()).unwrap();
    } else {
        // ...and agree with the new strict call on a canonical batch
        let x = flow.sample(&params,
            SampleOpts::new(flow.batch(), &mut Pcg64::new(4))).unwrap();
        ld_old = flow.log_likelihood(&x, None, &params).unwrap();
        ld_new = flow.log_density(&x, &params, InferOpts::strict()).unwrap();
    }
    for (u, v) in ld_old.iter().zip(&ld_new) {
        assert_eq!(u.to_bits(), v.to_bits(), "log_likelihood wrapper drifted");
    }

    // invert_flex(relax=true) == invert with relaxed opts
    let zs = flow.sample_latents(3, 1.0, &mut Pcg64::new(6)).unwrap();
    let inv_old = flow.invert_flex(&zs, None, &params, true).unwrap();
    let inv_new = flow.invert(&zs, &params, InferOpts::relaxed()).unwrap();
    assert_eq!(inv_old, inv_new, "invert_flex wrapper drifted");
}

/// Bounded-queue backpressure under a burst: nothing is lost, nothing
/// deadlocks — submissions just wait their turn.
#[test]
fn burst_through_tiny_queue_loses_nothing() {
    let server = Arc::new(boot_server(4, Duration::from_micros(100), 1));
    let flow = common::flow(NET);
    let params = flow.init_params(PARAM_SEED).unwrap();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..4u64).map(|client| {
            let server = server.clone();
            let flow = &flow;
            let params = &params;
            scope.spawn(move || {
                for round in 0..8u64 {
                    let (seed, n, temperature, _x) =
                        round_inputs(flow, client, round);
                    let Response::Sample { x } = server.handle(
                        Request::Sample {
                            model: None, n, temperature, seed, cond: None,
                        }) else { panic!("sample failed") };
                    let want = flow.sample(params,
                        SampleOpts::new(n, &mut Pcg64::new(seed))
                            .temperature(temperature)).unwrap();
                    assert_eq!(x, want, "client {client} round {round}");
                }
            })
        }).collect();
        for h in handles {
            h.join().unwrap();
        }
    });
    let Response::Stats(snap) = server.handle(Request::Stats) else {
        panic!()
    };
    assert_eq!(snap.requests, 32, "{snap:?}");
    assert_eq!(snap.errors, 0, "{snap:?}");
}

/// Tracing is bit-invisible: the same request answered with and without
/// `trace_id`/`timing` carries byte-identical payload fields — the
/// decorated reply only ever *adds* keys, never changes the answer.
#[test]
fn tracing_and_timing_leave_the_payload_bit_identical() {
    use invertnet::util::json::Json;
    let server = boot_server(8, Duration::from_micros(200), 2);
    for (plain_req, traced_req) in [
        (
            r#"{"op":"sample","n":3,"seed":9,"temperature":0.7}"#.to_string(),
            r#"{"op":"sample","n":3,"seed":9,"temperature":0.7,"trace_id":"t-1","timing":true}"#.to_string(),
        ),
        (
            r#"{"op":"score","x":{"shape":[2,2],"data":[0.1,-0.2,1.5,0.3]}}"#.to_string(),
            r#"{"op":"score","x":{"shape":[2,2],"data":[0.1,-0.2,1.5,0.3]},"trace_id":"t-2","timing":true}"#.to_string(),
        ),
    ] {
        let plain = Json::parse(&server.answer_line(&plain_req)).unwrap();
        let traced = Json::parse(&server.answer_line(&traced_req)).unwrap();
        let (Json::Obj(p), Json::Obj(t)) = (&plain, &traced) else {
            panic!("{plain:?} / {traced:?}")
        };
        // every payload key of the plain reply appears byte-identically
        // in the traced reply
        for (key, value) in p {
            assert_eq!(
                Some(&value.to_string()),
                t.get(key).map(|v| v.to_string()).as_ref(),
                "payload key {key:?} changed under tracing"
            );
        }
        // and the traced reply adds exactly the decoration keys
        let extras: Vec<&str> = t.keys()
            .filter(|k| !p.contains_key(*k))
            .map(|k| k.as_str())
            .collect();
        assert_eq!(extras, vec!["timing", "trace_id"], "{traced:?}");
        assert_eq!(plain.req("ok").unwrap(), &Json::Bool(true));
    }
}

/// Conditional serving: cond rows ride along with each request and are
/// coalesced with the batch.
#[test]
fn conditional_sample_and_score_through_the_server() {
    let registry = Registry::new(Engine::native().unwrap(), 4);
    registry.register_untrained("cond_realnvp2d", PARAM_SEED).unwrap();
    let server = Server::new(registry, BatchConfig {
        max_delay: Duration::from_micros(200),
        ..BatchConfig::default()
    }).allow_untrained();

    let flow = common::flow("cond_realnvp2d");
    let params = flow.init_params(PARAM_SEED).unwrap();
    let n = 2usize;
    let dc: usize = flow.def.cond_shape.as_ref().unwrap()[1..]
        .iter().product();
    let mut rng = Pcg64::new(21);
    let cond = Tensor { shape: vec![n, dc], data: rng.normal_vec(n * dc) };

    let Response::Sample { x } = server.handle(Request::Sample {
        model: None, n, temperature: 1.0, seed: 77,
        cond: Some(cond.clone()),
    }) else { panic!("cond sample failed") };
    let want = flow.sample(&params,
        SampleOpts::new(n, &mut Pcg64::new(77)).cond(&cond)).unwrap();
    assert_eq!(x, want);

    let Response::Score { log_density } = server.handle(Request::Score {
        model: None, x: x.clone(), cond: Some(cond.clone()),
    }) else { panic!("cond score failed") };
    let want = flow.log_density(&x, &params,
                                InferOpts::relaxed().cond(&cond)).unwrap();
    for (a, b) in log_density.iter().zip(&want) {
        assert_eq!(a.to_bits(), b.to_bits());
    }

    // a missing cond is a clean per-request error
    let resp = server.handle(Request::Sample {
        model: None, n: 1, temperature: 1.0, seed: 1, cond: None,
    });
    assert!(resp.is_error(), "{resp:?}");
}
