//! Pins the shape-only planner to the real ledger measurements
//! byte-for-byte, and verifies the paper's two memory claims on measured
//! data: invertible peak is depth-independent (Fig. 2) and stored peak
//! grows linearly; under a budget the stored schedule OOMs first (Fig. 1).

mod common;

use common::{batch_for, engine};
use invertnet::coordinator::planner::predict_peak_sched;
use invertnet::coordinator::ExecMode;
use invertnet::MemoryLedger;

fn measured_peak(net: &str, mode: ExecMode) -> i64 {
    let engine = engine();
    let ledger = MemoryLedger::new();
    let flow = engine.flow_with_ledger(net, ledger).unwrap();
    let params = flow.init_params(5).unwrap();
    let (x, cond) = batch_for(&flow, 6);
    flow.train_step(&x, cond.as_ref(), &params, &mode)
        .unwrap()
        .peak_sched_bytes
}

fn predicted_peak(net: &str, mode: ExecMode) -> i64 {
    let engine = engine();
    let flow = engine.flow(net).unwrap();
    predict_peak_sched(&flow.def, mode)
}

#[test]
fn planner_matches_ledger_exactly() {
    for net in ["glow_fig2_d2", "glow_fig2_d8", "glow16", "realnvp2d", "hyper16"] {
        for mode in [ExecMode::Invertible, ExecMode::Stored] {
            let measured = measured_peak(net, mode);
            let predicted = predicted_peak(net, mode);
            assert_eq!(
                measured, predicted,
                "{net}/{}: measured {measured} != planner {predicted}",
                mode.name()
            );
        }
    }
}

#[test]
fn invertible_peak_is_depth_independent() {
    let p2 = measured_peak("glow_fig2_d2", ExecMode::Invertible);
    let p8 = measured_peak("glow_fig2_d8", ExecMode::Invertible);
    let p16 = measured_peak("glow_fig2_d16", ExecMode::Invertible);
    assert_eq!(p2, p8, "Fig. 2 claim violated");
    assert_eq!(p8, p16, "Fig. 2 claim violated");
}

#[test]
fn stored_peak_grows_linearly_with_depth() {
    let p2 = measured_peak("glow_fig2_d2", ExecMode::Stored);
    let p4 = measured_peak("glow_fig2_d4", ExecMode::Stored);
    let p8 = measured_peak("glow_fig2_d8", ExecMode::Stored);
    assert!(p4 > p2 && p8 > p4);
    // equal increments per unit depth: p8-p4 == 2*(p4-p2)
    assert_eq!(p8 - p4, 2 * (p4 - p2), "not linear: {p2} {p4} {p8}");
}

#[test]
fn budget_kills_stored_first() {
    // pick a budget between the two schedules' needs at depth 16
    let inv = measured_peak("glow_fig2_d16", ExecMode::Invertible);
    let sto = measured_peak("glow_fig2_d16", ExecMode::Stored);
    assert!(sto > 2 * inv);
    let budget = (inv + sto) as u64 / 2;

    let engine = engine();
    let run = |mode: ExecMode| {
        let ledger = MemoryLedger::with_budget(budget);
        let flow = engine.flow_with_ledger("glow_fig2_d16", ledger).unwrap();
        let params = flow.init_params(5).unwrap();
        let (x, _) = batch_for(&flow, 6);
        flow.train_step(&x, None, &params, &mode)
    };
    assert!(run(ExecMode::Invertible).is_ok(),
            "invertible must fit under the budget");
    let err = match run(ExecMode::Stored) {
        Ok(_) => panic!("stored must OOM under this budget"),
        Err(e) => e,
    };
    assert!(err.to_string().contains("OOM") || format!("{err:#}").contains("OOM"),
            "{err:#}");
}

#[test]
fn spatial_size_scales_quadratically() {
    let p16 = measured_peak("glow_fig1_16", ExecMode::Invertible);
    let p32 = measured_peak("glow_fig1_32", ExecMode::Invertible);
    assert_eq!(p32, 4 * p16, "Fig. 1 x-axis scaling");
}

/// A checkpoint-every-k hybrid must land between the two pure schedules.
#[test]
fn hybrid_schedule_peak_is_between_pure_modes() {
    use invertnet::coordinator::CheckpointEveryK;
    let engine = engine();
    let measure = |sched: &dyn invertnet::coordinator::ActivationSchedule| {
        let flow = engine.flow("glow_fig2_d8").unwrap();
        let params = flow.init_params(5).unwrap();
        let (x, _) = batch_for(&flow, 6);
        flow.train_step(&x, None, &params, sched).unwrap().peak_sched_bytes
    };
    let inv = measure(&ExecMode::Invertible);
    let sto = measure(&ExecMode::Stored);
    let mid = measure(&CheckpointEveryK(6));
    assert!(inv < mid && mid < sto,
            "hybrid peak {mid} not between {inv} and {sto}");
}
