//! Integration tests for the static flow verifier and the memory-peak
//! planner (`invertnet::analysis`): every diagnostic code fires on a
//! malformed spec, and the planner's predicted peak equals the measured
//! ledger peak bit-for-bit for every builtin example network under all
//! three activation schedules.

mod common;

use common::{batch_for, engine};
use invertnet::analysis::{self, codes, predict_peak, verify_checkpoint_k,
                          verify_network};
use invertnet::coordinator::{ActivationSchedule, CheckpointEveryK, ExecMode};
use invertnet::runtime::builtin::EXAMPLE_NETS;
use invertnet::runtime::{builtin_manifest, LayerMeta, Manifest};
use invertnet::MemoryLedger;

fn manifest() -> Manifest {
    builtin_manifest().unwrap()
}

/// The codes a verification run produced, for order-free membership asserts.
fn codes_of(diags: &[analysis::Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.code).collect()
}

fn lint(m: &Manifest, net: &str) -> Vec<analysis::Diagnostic> {
    verify_network(m, m.network(net).unwrap())
}

/// Clone an existing layer's metadata under a fresh sig, mutate it, and
/// register it — the cheapest way to synthesize a malformed layer that is
/// still structurally complete (params, entries, cfg).
fn splice_layer(m: &mut Manifest, base: &str, sig: &str,
                mutate: impl FnOnce(&mut LayerMeta)) {
    let mut meta = m.layer(base).unwrap().clone();
    meta.sig = sig.to_string();
    mutate(&mut meta);
    m.layers.insert(sig.to_string(), meta);
}

// --------------------------------------------------------------------------
// the verifier: one test per diagnostic code, each on a malformed spec
// --------------------------------------------------------------------------

#[test]
fn clean_catalog_yields_no_diagnostics() {
    let m = manifest();
    for (name, diags) in analysis::verify_manifest(&m) {
        assert!(diags.is_empty(), "{name}: {diags:?}");
    }
}

#[test]
fn unknown_layer_fires() {
    let mut m = manifest();
    m.networks.get_mut("realnvp2d").unwrap().layers
        .push("warp__256x2".into());
    assert!(codes_of(&lint(&m, "realnvp2d")).contains(&codes::UNKNOWN_LAYER));
}

#[test]
fn shape_mismatch_fires_on_a_spliced_foreign_layer() {
    let mut m = manifest();
    // glow16's haar squeeze expects [16,16,16,3]; realnvp2d flows [256,2]
    m.networks.get_mut("realnvp2d").unwrap().layers[0] =
        "haar__16x16x16x3".into();
    let cs = codes_of(&lint(&m, "realnvp2d"));
    assert!(cs.contains(&codes::SHAPE_MISMATCH), "{cs:?}");
}

#[test]
fn bad_split_fires_on_degenerate_and_desynced_markers() {
    let m0 = manifest();
    let split_at = m0.network("glow16").unwrap().layers.iter()
        .position(|s| s.starts_with("split_zc"))
        .expect("glow16 has a split marker");
    // zc = 0 and zc >= width both leave one half empty
    for marker in ["split_zc0__16x8x8x12", "split_zc12__16x8x8x12"] {
        let mut m = manifest();
        m.networks.get_mut("glow16").unwrap().layers[split_at] =
            marker.into();
        let cs = codes_of(&lint(&m, "glow16"));
        assert!(cs.contains(&codes::BAD_SPLIT), "{marker}: {cs:?}");
    }
    // marker whose recorded input shape disagrees with the flow shape
    let mut m = manifest();
    m.networks.get_mut("glow16").unwrap().layers[split_at] =
        "split_zc6__16x9x9x12".into();
    let cs = codes_of(&lint(&m, "glow16"));
    assert!(cs.contains(&codes::BAD_SPLIT), "{cs:?}");
}

#[test]
fn bad_squeeze_fires_on_a_non_2x2_haar() {
    let mut m = manifest();
    splice_layer(&mut m, "haar__16x16x16x3", "haar__bad", |meta| {
        meta.out_shape = vec![16, 8, 8, 13]; // not [n, h/2, w/2, 4c]
    });
    m.networks.get_mut("glow16").unwrap().layers[0] = "haar__bad".into();
    let cs = codes_of(&lint(&m, "glow16"));
    assert!(cs.contains(&codes::BAD_SQUEEZE), "{cs:?}");
}

#[test]
fn width_change_fires_outside_squeeze_points() {
    let mut m = manifest();
    let base = m.network("realnvp2d").unwrap().layers[0].clone();
    splice_layer(&mut m, &base, "widened__256x2", |meta| {
        meta.out_shape = vec![256, 3];
    });
    m.networks.get_mut("realnvp2d").unwrap().layers[0] =
        "widened__256x2".into();
    let cs = codes_of(&lint(&m, "realnvp2d"));
    assert!(cs.contains(&codes::WIDTH_CHANGE), "{cs:?}");
}

#[test]
fn no_inverse_fires_on_an_uninvertible_kind() {
    let mut m = manifest();
    let base = m.network("realnvp2d").unwrap().layers[0].clone();
    splice_layer(&mut m, &base, "blackbox__256x2", |meta| {
        meta.kind = "blackbox".into();
    });
    m.networks.get_mut("realnvp2d").unwrap().layers[0] =
        "blackbox__256x2".into();
    let diags = lint(&m, "realnvp2d");
    assert!(codes_of(&diags).contains(&codes::NO_INVERSE), "{diags:?}");
    assert!(analysis::has_errors(&diags));
}

#[test]
fn cond_mismatch_fires_on_width_and_wiring_violations() {
    // network declares a different cond width than its layers consume
    let mut m = manifest();
    m.networks.get_mut("cond_realnvp2d").unwrap().cond_shape =
        Some(vec![256, 3]);
    let cs = codes_of(&lint(&m, "cond_realnvp2d"));
    assert!(cs.contains(&codes::COND_MISMATCH), "{cs:?}");

    // network declares no cond at all, but layers consume one
    let mut m = manifest();
    m.networks.get_mut("cond_realnvp2d").unwrap().cond_shape = None;
    let cs = codes_of(&lint(&m, "cond_realnvp2d"));
    assert!(cs.contains(&codes::COND_MISMATCH), "{cs:?}");
}

#[test]
fn dangling_cond_is_a_warning_not_an_error() {
    let mut m = manifest();
    m.networks.get_mut("realnvp2d").unwrap().cond_shape =
        Some(vec![256, 2]);
    let diags = lint(&m, "realnvp2d");
    assert!(codes_of(&diags).contains(&codes::DANGLING_COND), "{diags:?}");
    assert!(!analysis::has_errors(&diags), "{diags:?}");
}

#[test]
fn latent_mismatch_and_not_bijective_fire_together() {
    let mut m = manifest();
    m.networks.get_mut("realnvp2d").unwrap().latent_shapes =
        vec![vec![256, 3]];
    let cs = codes_of(&lint(&m, "realnvp2d"));
    assert!(cs.contains(&codes::LATENT_MISMATCH), "{cs:?}");
    assert!(cs.contains(&codes::NOT_BIJECTIVE), "{cs:?}");
}

#[test]
fn dangling_split_half_is_caught_by_the_latent_audit() {
    // drop the declared latent for glow16's split half: the derived
    // latents (split half + final shape) no longer match
    let mut m = manifest();
    let net = m.networks.get_mut("glow16").unwrap();
    net.latent_shapes.remove(0);
    let cs = codes_of(&lint(&m, "glow16"));
    assert!(cs.contains(&codes::LATENT_MISMATCH), "{cs:?}");
    assert!(cs.contains(&codes::NOT_BIJECTIVE), "{cs:?}");
}

#[test]
fn checkpoint_k_audit_bounds() {
    let zero = verify_checkpoint_k(26, 0);
    assert_eq!(codes_of(&zero), vec![codes::BAD_CHECKPOINT_K]);
    assert!(analysis::has_errors(&zero));
    let over = verify_checkpoint_k(26, 27);
    assert_eq!(codes_of(&over), vec![codes::BAD_CHECKPOINT_K]);
    assert!(!analysis::has_errors(&over));
    assert!(verify_checkpoint_k(26, 4).is_empty());
}

// --------------------------------------------------------------------------
// checkpoint index codes (the serve-registry gate reuses these)
// --------------------------------------------------------------------------

fn temp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir()
        .join(format!("analysis_it_{tag}_{}", std::process::id()))
}

#[test]
fn checkpoint_index_codes_fire_on_a_doctored_index() {
    use invertnet::util::json::Json;
    let dir = temp("doctored");
    let engine = engine();
    let flow = engine.flow("realnvp2d").unwrap();
    let params = flow.init_params(9).unwrap();
    params.save(&dir, "realnvp2d").unwrap();

    // rename one param (=> unknown + missing) and bend another's shape
    let text = std::fs::read_to_string(dir.join("index.json")).unwrap();
    let mut doc = Json::parse(&text).unwrap();
    {
        let Json::Obj(m) = &mut doc else { panic!("index not an obj") };
        let Some(Json::Arr(entries)) = m.get_mut("params") else {
            panic!("no params array")
        };
        assert!(entries.len() >= 2, "need two params to doctor");
        if let Json::Obj(e) = &mut entries[0] {
            e.insert("name".into(), Json::Str("imposter".into()));
        }
        if let Json::Obj(e) = &mut entries[1] {
            e.insert("shape".into(), Json::arr_usize(&[9, 9, 9]));
        }
    }
    std::fs::write(dir.join("index.json"), doc.to_string()).unwrap();

    let diags = analysis::verify_checkpoint_index(
        engine.manifest(), &flow.def, &dir).unwrap();
    let cs = codes_of(&diags);
    assert!(cs.contains(&codes::CKPT_UNKNOWN_PARAM), "{cs:?}");
    assert!(cs.contains(&codes::CKPT_SHAPE_MISMATCH), "{cs:?}");
    assert!(cs.contains(&codes::CKPT_MISSING_PARAM), "{cs:?}");
    std::fs::remove_dir_all(&dir).ok();
}

// --------------------------------------------------------------------------
// the planner: predicted peak == measured ledger peak, bit for bit, for
// every builtin example net under all three schedules
// --------------------------------------------------------------------------

#[test]
fn predicted_peak_equals_measured_for_all_nets_and_schedules() {
    let engine = engine();
    let schedules: [&dyn ActivationSchedule; 3] = [
        &ExecMode::Invertible,
        &ExecMode::Stored,
        &CheckpointEveryK(3),
    ];
    for &net in EXAMPLE_NETS {
        for sched in schedules {
            let ledger = MemoryLedger::new();
            let flow = engine.flow_with_ledger(net, ledger).unwrap();
            let params = flow.init_params(5).unwrap();
            let (x, cond) = batch_for(&flow, 6);
            let measured = flow
                .train_step(&x, cond.as_ref(), &params, sched)
                .unwrap()
                .peak_sched_bytes;
            let predicted = predict_peak(&flow.def, sched);
            assert_eq!(
                measured, predicted,
                "{net}/{}: measured {measured} != predicted {predicted}",
                sched.label()
            );
        }
    }
}

// --------------------------------------------------------------------------
// the CLI gate: a malformed manifest exits non-zero through `lint --check`
// --------------------------------------------------------------------------

#[test]
fn lint_cli_rejects_a_malformed_manifest() {
    let dir = temp("badmanifest");
    std::fs::create_dir_all(&dir).unwrap();
    // structurally valid manifest whose network is wrong three ways:
    // input shape mismatch, an undefined layer sig, and a latent set
    // that is not a bijection of the input
    let bad = r#"{
      "backend": "bad-demo",
      "layers": {
        "actnorm__2x4x4x3": {
          "sig": "actnorm__2x4x4x3", "kind": "actnorm",
          "in_shape": [2,4,4,3], "out_shape": [2,4,4,3],
          "cond_shape": null, "cfg": {},
          "params": [{"name": "log_s", "shape": [3]},
                     {"name": "b", "shape": [3]}],
          "entries": {}
        }
      },
      "heads": {},
      "networks": {
        "broken": {"name": "broken", "in_shape": [2,4,4,5],
                   "cond_shape": null,
                   "layers": ["actnorm__2x4x4x3", "missing__2x4x4x3"],
                   "latent_shapes": [[2,4,4,3]]}
      }
    }"#;
    std::fs::write(dir.join("manifest.json"), bad).unwrap();
    let argv: Vec<String> = ["lint", "--all", "--check", "--json",
                             "--artifacts", dir.to_str().unwrap()]
        .iter().map(|s| s.to_string()).collect();
    let err = invertnet::app::run(&argv).unwrap_err();
    assert!(err.to_string().contains("lint failed"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}
