//! Integration tests for the static flow verifier, the memory-peak
//! planner, and the cost model (`invertnet::analysis`): every diagnostic
//! code fires on a malformed spec; the planner's predicted peak equals
//! the measured ledger peak bit-for-bit for every builtin example
//! network under all three activation schedules; the cost model matches
//! the independent Python mirror's committed pins exactly; and automatic
//! schedule selection always returns the cheapest schedule that fits.

mod common;

use common::{batch_for, engine};
use invertnet::analysis::{self, candidate_schedules, choose_schedule,
                          codes, inference_cost, predict_peak, sample_cost,
                          train_cost, verify_checkpoint_k, verify_network};
use invertnet::coordinator::{ActivationSchedule, CheckpointEveryK, ExecMode};
use invertnet::flow::NetworkDef;
use invertnet::runtime::builtin::EXAMPLE_NETS;
use invertnet::runtime::{builtin_manifest, LayerMeta, Manifest};
use invertnet::util::json::Json;
use invertnet::MemoryLedger;

fn manifest() -> Manifest {
    builtin_manifest().unwrap()
}

/// The codes a verification run produced, for order-free membership asserts.
fn codes_of(diags: &[analysis::Diagnostic]) -> Vec<&'static str> {
    diags.iter().map(|d| d.code).collect()
}

fn lint(m: &Manifest, net: &str) -> Vec<analysis::Diagnostic> {
    verify_network(m, m.network(net).unwrap())
}

/// Clone an existing layer's metadata under a fresh sig, mutate it, and
/// register it — the cheapest way to synthesize a malformed layer that is
/// still structurally complete (params, entries, cfg).
fn splice_layer(m: &mut Manifest, base: &str, sig: &str,
                mutate: impl FnOnce(&mut LayerMeta)) {
    let mut meta = m.layer(base).unwrap().clone();
    meta.sig = sig.to_string();
    mutate(&mut meta);
    m.layers.insert(sig.to_string(), meta);
}

// --------------------------------------------------------------------------
// the verifier: one test per diagnostic code, each on a malformed spec
// --------------------------------------------------------------------------

#[test]
fn clean_catalog_yields_no_diagnostics() {
    let m = manifest();
    for (name, diags) in analysis::verify_manifest(&m) {
        assert!(diags.is_empty(), "{name}: {diags:?}");
    }
}

#[test]
fn unknown_layer_fires() {
    let mut m = manifest();
    m.networks.get_mut("realnvp2d").unwrap().layers
        .push("warp__256x2".into());
    assert!(codes_of(&lint(&m, "realnvp2d")).contains(&codes::UNKNOWN_LAYER));
}

#[test]
fn shape_mismatch_fires_on_a_spliced_foreign_layer() {
    let mut m = manifest();
    // glow16's haar squeeze expects [16,16,16,3]; realnvp2d flows [256,2]
    m.networks.get_mut("realnvp2d").unwrap().layers[0] =
        "haar__16x16x16x3".into();
    let cs = codes_of(&lint(&m, "realnvp2d"));
    assert!(cs.contains(&codes::SHAPE_MISMATCH), "{cs:?}");
}

#[test]
fn bad_split_fires_on_degenerate_and_desynced_markers() {
    let m0 = manifest();
    let split_at = m0.network("glow16").unwrap().layers.iter()
        .position(|s| s.starts_with("split_zc"))
        .expect("glow16 has a split marker");
    // zc = 0 and zc >= width both leave one half empty
    for marker in ["split_zc0__16x8x8x12", "split_zc12__16x8x8x12"] {
        let mut m = manifest();
        m.networks.get_mut("glow16").unwrap().layers[split_at] =
            marker.into();
        let cs = codes_of(&lint(&m, "glow16"));
        assert!(cs.contains(&codes::BAD_SPLIT), "{marker}: {cs:?}");
    }
    // marker whose recorded input shape disagrees with the flow shape
    let mut m = manifest();
    m.networks.get_mut("glow16").unwrap().layers[split_at] =
        "split_zc6__16x9x9x12".into();
    let cs = codes_of(&lint(&m, "glow16"));
    assert!(cs.contains(&codes::BAD_SPLIT), "{cs:?}");
}

#[test]
fn bad_squeeze_fires_on_a_non_2x2_haar() {
    let mut m = manifest();
    splice_layer(&mut m, "haar__16x16x16x3", "haar__bad", |meta| {
        meta.out_shape = vec![16, 8, 8, 13]; // not [n, h/2, w/2, 4c]
    });
    m.networks.get_mut("glow16").unwrap().layers[0] = "haar__bad".into();
    let cs = codes_of(&lint(&m, "glow16"));
    assert!(cs.contains(&codes::BAD_SQUEEZE), "{cs:?}");
}

#[test]
fn width_change_fires_outside_squeeze_points() {
    let mut m = manifest();
    let base = m.network("realnvp2d").unwrap().layers[0].clone();
    splice_layer(&mut m, &base, "widened__256x2", |meta| {
        meta.out_shape = vec![256, 3];
    });
    m.networks.get_mut("realnvp2d").unwrap().layers[0] =
        "widened__256x2".into();
    let cs = codes_of(&lint(&m, "realnvp2d"));
    assert!(cs.contains(&codes::WIDTH_CHANGE), "{cs:?}");
}

#[test]
fn no_inverse_fires_on_an_uninvertible_kind() {
    let mut m = manifest();
    let base = m.network("realnvp2d").unwrap().layers[0].clone();
    splice_layer(&mut m, &base, "blackbox__256x2", |meta| {
        meta.kind = "blackbox".into();
    });
    m.networks.get_mut("realnvp2d").unwrap().layers[0] =
        "blackbox__256x2".into();
    let diags = lint(&m, "realnvp2d");
    assert!(codes_of(&diags).contains(&codes::NO_INVERSE), "{diags:?}");
    assert!(analysis::has_errors(&diags));
}

#[test]
fn cond_mismatch_fires_on_width_and_wiring_violations() {
    // network declares a different cond width than its layers consume
    let mut m = manifest();
    m.networks.get_mut("cond_realnvp2d").unwrap().cond_shape =
        Some(vec![256, 3]);
    let cs = codes_of(&lint(&m, "cond_realnvp2d"));
    assert!(cs.contains(&codes::COND_MISMATCH), "{cs:?}");

    // network declares no cond at all, but layers consume one
    let mut m = manifest();
    m.networks.get_mut("cond_realnvp2d").unwrap().cond_shape = None;
    let cs = codes_of(&lint(&m, "cond_realnvp2d"));
    assert!(cs.contains(&codes::COND_MISMATCH), "{cs:?}");
}

#[test]
fn dangling_cond_is_a_warning_not_an_error() {
    let mut m = manifest();
    m.networks.get_mut("realnvp2d").unwrap().cond_shape =
        Some(vec![256, 2]);
    let diags = lint(&m, "realnvp2d");
    assert!(codes_of(&diags).contains(&codes::DANGLING_COND), "{diags:?}");
    assert!(!analysis::has_errors(&diags), "{diags:?}");
}

#[test]
fn latent_mismatch_and_not_bijective_fire_together() {
    let mut m = manifest();
    m.networks.get_mut("realnvp2d").unwrap().latent_shapes =
        vec![vec![256, 3]];
    let cs = codes_of(&lint(&m, "realnvp2d"));
    assert!(cs.contains(&codes::LATENT_MISMATCH), "{cs:?}");
    assert!(cs.contains(&codes::NOT_BIJECTIVE), "{cs:?}");
}

#[test]
fn dangling_split_half_is_caught_by_the_latent_audit() {
    // drop the declared latent for glow16's split half: the derived
    // latents (split half + final shape) no longer match
    let mut m = manifest();
    let net = m.networks.get_mut("glow16").unwrap();
    net.latent_shapes.remove(0);
    let cs = codes_of(&lint(&m, "glow16"));
    assert!(cs.contains(&codes::LATENT_MISMATCH), "{cs:?}");
    assert!(cs.contains(&codes::NOT_BIJECTIVE), "{cs:?}");
}

#[test]
fn checkpoint_k_audit_bounds() {
    let zero = verify_checkpoint_k(26, 0);
    assert_eq!(codes_of(&zero), vec![codes::BAD_CHECKPOINT_K]);
    assert!(analysis::has_errors(&zero));
    let over = verify_checkpoint_k(26, 27);
    assert_eq!(codes_of(&over), vec![codes::BAD_CHECKPOINT_K]);
    assert!(!analysis::has_errors(&over));
    assert!(verify_checkpoint_k(26, 4).is_empty());
}

// --------------------------------------------------------------------------
// checkpoint index codes (the serve-registry gate reuses these)
// --------------------------------------------------------------------------

fn temp(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir()
        .join(format!("analysis_it_{tag}_{}", std::process::id()))
}

#[test]
fn checkpoint_index_codes_fire_on_a_doctored_index() {
    let dir = temp("doctored");
    let engine = engine();
    let flow = engine.flow("realnvp2d").unwrap();
    let params = flow.init_params(9).unwrap();
    params.save(&dir, "realnvp2d").unwrap();

    // rename one param (=> unknown + missing) and bend another's shape
    let text = std::fs::read_to_string(dir.join("index.json")).unwrap();
    let mut doc = Json::parse(&text).unwrap();
    {
        let Json::Obj(m) = &mut doc else { panic!("index not an obj") };
        let Some(Json::Arr(entries)) = m.get_mut("params") else {
            panic!("no params array")
        };
        assert!(entries.len() >= 2, "need two params to doctor");
        if let Json::Obj(e) = &mut entries[0] {
            e.insert("name".into(), Json::Str("imposter".into()));
        }
        if let Json::Obj(e) = &mut entries[1] {
            e.insert("shape".into(), Json::arr_usize(&[9, 9, 9]));
        }
    }
    std::fs::write(dir.join("index.json"), doc.to_string()).unwrap();

    let diags = analysis::verify_checkpoint_index(
        engine.manifest(), &flow.def, &dir).unwrap();
    let cs = codes_of(&diags);
    assert!(cs.contains(&codes::CKPT_UNKNOWN_PARAM), "{cs:?}");
    assert!(cs.contains(&codes::CKPT_SHAPE_MISMATCH), "{cs:?}");
    assert!(cs.contains(&codes::CKPT_MISSING_PARAM), "{cs:?}");
    std::fs::remove_dir_all(&dir).ok();
}

// --------------------------------------------------------------------------
// the planner: predicted peak == measured ledger peak, bit for bit, for
// every builtin example net under all three schedules
// --------------------------------------------------------------------------

#[test]
fn predicted_peak_equals_measured_for_all_nets_and_schedules() {
    let engine = engine();
    let schedules: [&dyn ActivationSchedule; 3] = [
        &ExecMode::Invertible,
        &ExecMode::Stored,
        &CheckpointEveryK(3),
    ];
    for &net in EXAMPLE_NETS {
        for sched in schedules {
            let ledger = MemoryLedger::new();
            let flow = engine.flow_with_ledger(net, ledger).unwrap();
            let params = flow.init_params(5).unwrap();
            let (x, cond) = batch_for(&flow, 6);
            let measured = flow
                .train_step(&x, cond.as_ref(), &params, sched)
                .unwrap()
                .peak_sched_bytes;
            let predicted = predict_peak(&flow.def, sched);
            assert_eq!(
                measured, predicted,
                "{net}/{}: measured {measured} != predicted {predicted}",
                sched.label()
            );
        }
    }
}

// --------------------------------------------------------------------------
// the cost model: Rust must match the independent Python mirror
// (python/tests/test_cost_model.py) exactly, via the committed fixture
// --------------------------------------------------------------------------

fn pin_u64(doc: &Json, key: &str) -> u64 {
    doc.req(key).unwrap().as_f64().unwrap() as u64
}

#[test]
fn cost_model_matches_the_python_mirror_pins() {
    let text = std::fs::read_to_string(concat!(
        env!("CARGO_MANIFEST_DIR"),
        "/python/tests/data/cost_model_pins.json")).unwrap();
    let pins = Json::parse(&text).unwrap();
    assert_eq!(pins.req("schema").unwrap().as_str().unwrap(),
               "invertnet-cost-pins/v1");
    let m = manifest();
    let schedules: [(&str, &dyn ActivationSchedule); 3] = [
        ("invertible", &ExecMode::Invertible),
        ("stored", &ExecMode::Stored),
        ("checkpoint_every_4", &CheckpointEveryK(4)),
    ];
    let nets = pins.req("networks").unwrap();
    let mut checked = 0usize;
    let pinned: Vec<&str> = EXAMPLE_NETS.iter().copied()
        .chain(["glow64", "hint64deep"])
        .collect();
    for &net in &pinned {
        let def = NetworkDef::resolve(&m, net).unwrap();
        let pin = nets.req(net).unwrap();
        for (label, sched) in schedules {
            let c = train_cost(&def, &m, sched).unwrap();
            let p = pin.req(label).unwrap();
            assert_eq!(c.flops, pin_u64(p, "train_flops"),
                       "{net}/{label} train flops");
            assert_eq!(c.bytes, pin_u64(p, "train_bytes"),
                       "{net}/{label} train bytes");
            checked += 1;
        }
        let inf = inference_cost(&def, &m).unwrap();
        assert_eq!(inf.flops, pin_u64(pin, "inference_flops"),
                   "{net} inference flops");
        assert_eq!(inf.bytes, pin_u64(pin, "inference_bytes"),
                   "{net} inference bytes");
        let smp = sample_cost(&def, &m).unwrap();
        assert_eq!(smp.flops, pin_u64(pin, "sample_flops"),
                   "{net} sample flops");
        assert_eq!(smp.bytes, pin_u64(pin, "sample_bytes"),
                   "{net} sample bytes");
    }
    assert_eq!(checked, pinned.len() * 3,
               "every pinned net x schedule cell must be pinned");
}

// --------------------------------------------------------------------------
// automatic schedule selection: the chosen schedule always fits the
// budget, and no other fitting candidate is compute-cheaper
// --------------------------------------------------------------------------

#[test]
fn chosen_schedule_always_fits_and_is_never_beaten() {
    let m = manifest();
    for &net in EXAMPLE_NETS {
        let def = NetworkDef::resolve(&m, net).unwrap();
        let peaks: Vec<i64> = candidate_schedules(def.depth()).iter()
            .map(|s| predict_peak(&def, s.as_ref())).collect();
        let lo = *peaks.iter().min().unwrap();
        let hi = *peaks.iter().max().unwrap();
        let mut budgets = vec![None, Some(lo), Some(hi), Some(hi + 1)];
        for f in [0.25f64, 0.5, 0.75] {
            budgets.push(Some(lo + ((hi - lo) as f64 * f) as i64));
        }
        for b in budgets {
            let choice = choose_schedule(&def, &m, b).unwrap();
            if let Some(b) = b {
                assert!(choice.peak_bytes <= b,
                        "{net}: chose {} with peak {} over budget {b}",
                        choice.label, choice.peak_bytes);
            }
            for cand in candidate_schedules(def.depth()) {
                let peak = predict_peak(&def, cand.as_ref());
                if peak <= b.unwrap_or(i64::MAX) {
                    let flops =
                        train_cost(&def, &m, cand.as_ref()).unwrap().flops;
                    assert!(choice.train_flops <= flops,
                            "{net}: chose {} ({} flops) but {} fits the \
                             budget {b:?} with {} flops",
                            choice.label, choice.train_flops, cand.label(),
                            flops);
                }
            }
        }
        // below the minimum peak, nothing fits — the error names it
        let err = choose_schedule(&def, &m, Some(lo - 1)).unwrap_err();
        assert!(err.to_string().contains("minimum predicted peak"),
                "{net}: {err:#}");
    }
}

// --------------------------------------------------------------------------
// numeric-range lints: each code fires on a spliced hazardous cfg and
// rides the verify_network diagnostic stream
// --------------------------------------------------------------------------

/// Set cfg keys on a spliced layer (the builtin catalog declares none of
/// these, so the hazard has to be spliced in).
fn set_cfg(meta: &mut LayerMeta, entries: &[(&str, Json)]) {
    let Json::Obj(cfg) = &mut meta.cfg else {
        panic!("cfg is not an object")
    };
    for (k, v) in entries {
        cfg.insert((*k).to_string(), v.clone());
    }
}

/// Position and sig of the first layer of `kind` in `net`.
fn find_kind(m: &Manifest, net: &str, kind: &str) -> (usize, String) {
    let layers = &m.network(net).unwrap().layers;
    let pos = layers.iter()
        .position(|s| m.layer(s).map(|l| l.kind == kind).unwrap_or(false))
        .unwrap_or_else(|| panic!("{net} has no {kind} layer"));
    (pos, layers[pos].clone())
}

#[test]
fn exp_overflow_fires_on_an_unbounded_exp_scale() {
    let mut m = manifest();
    let (pos, base) = find_kind(&m, "realnvp2d", "densecpl");
    splice_layer(&mut m, &base, "hotexp__256x2", |meta| {
        set_cfg(meta, &[("scale_act", Json::Str("exp".into())),
                        ("raw_bound", Json::Num(100.0))]);
    });
    m.networks.get_mut("realnvp2d").unwrap().layers[pos] =
        "hotexp__256x2".into();
    let diags = lint(&m, "realnvp2d");
    assert!(codes_of(&diags).contains(&codes::EXP_OVERFLOW), "{diags:?}");
    assert!(analysis::has_errors(&diags));
}

#[test]
fn exp_overflow_fires_once_on_a_propagated_amplitude_bound() {
    // each layer's raw bound (85) is individually under ln(f32::MAX),
    // but ten of them compound past ln(f64::MAX) — the propagated
    // cumulative log-gain is the hazard, reported exactly once
    let mut m = manifest();
    let (_, base) = find_kind(&m, "realnvp2d", "densecpl");
    splice_layer(&mut m, &base, "warmexp__256x2", |meta| {
        set_cfg(meta, &[("scale_act", Json::Str("exp".into())),
                        ("raw_bound", Json::Num(85.0))]);
    });
    {
        let net = m.networks.get_mut("realnvp2d").unwrap();
        for sig in net.layers.iter_mut() {
            if sig.contains("densecpl") {
                *sig = "warmexp__256x2".into();
            }
        }
        while net.layers.iter()
            .filter(|s| s.as_str() == "warmexp__256x2").count() < 10
        {
            net.layers.push("warmexp__256x2".into());
        }
    }
    let diags = lint(&m, "realnvp2d");
    let hits = diags.iter()
        .filter(|d| d.code == codes::EXP_OVERFLOW).count();
    assert_eq!(hits, 1, "propagated overflow reported once: {diags:?}");
    assert!(analysis::has_errors(&diags));
}

#[test]
fn actnorm_degenerate_scale_fires_on_a_zero_lower_bound() {
    let mut m = manifest();
    let (pos, base) = find_kind(&m, "glow16", "actnorm");
    let sig = format!("deadnorm__{}", pos);
    splice_layer(&mut m, &base, &sig, |meta| {
        set_cfg(meta, &[("scale_min", Json::Num(0.0))]);
    });
    m.networks.get_mut("glow16").unwrap().layers[pos] = sig;
    let diags = lint(&m, "glow16");
    assert!(codes_of(&diags).contains(&codes::ACTNORM_DEGENERATE_SCALE),
            "{diags:?}");
    assert!(analysis::has_errors(&diags));
}

#[test]
fn logdet_underflow_is_a_warning_not_an_error() {
    // sigmoid2 with a huge raw bound: s_lo = 2*sigmoid(-100) ~ 7e-44 —
    // forward stays finite, but ln(s) in the log-det sum can hit -inf
    let mut m = manifest();
    let (pos, base) = find_kind(&m, "realnvp2d", "densecpl");
    splice_layer(&mut m, &base, "deepsig__256x2", |meta| {
        set_cfg(meta, &[("raw_bound", Json::Num(100.0))]);
    });
    m.networks.get_mut("realnvp2d").unwrap().layers[pos] =
        "deepsig__256x2".into();
    let diags = lint(&m, "realnvp2d");
    assert!(codes_of(&diags).contains(&codes::LOGDET_UNDERFLOW),
            "{diags:?}");
    assert!(!analysis::has_errors(&diags), "{diags:?}");
}

// --------------------------------------------------------------------------
// the CLI gate: a malformed manifest exits non-zero through `lint --check`
// --------------------------------------------------------------------------

#[test]
fn lint_cli_rejects_a_malformed_manifest() {
    let dir = temp("badmanifest");
    std::fs::create_dir_all(&dir).unwrap();
    // structurally valid manifest whose network is wrong three ways:
    // input shape mismatch, an undefined layer sig, and a latent set
    // that is not a bijection of the input
    let bad = r#"{
      "backend": "bad-demo",
      "layers": {
        "actnorm__2x4x4x3": {
          "sig": "actnorm__2x4x4x3", "kind": "actnorm",
          "in_shape": [2,4,4,3], "out_shape": [2,4,4,3],
          "cond_shape": null, "cfg": {},
          "params": [{"name": "log_s", "shape": [3]},
                     {"name": "b", "shape": [3]}],
          "entries": {}
        }
      },
      "heads": {},
      "networks": {
        "broken": {"name": "broken", "in_shape": [2,4,4,5],
                   "cond_shape": null,
                   "layers": ["actnorm__2x4x4x3", "missing__2x4x4x3"],
                   "latent_shapes": [[2,4,4,3]]}
      }
    }"#;
    std::fs::write(dir.join("manifest.json"), bad).unwrap();
    let argv: Vec<String> = ["lint", "--all", "--check", "--json",
                             "--artifacts", dir.to_str().unwrap()]
        .iter().map(|s| s.to_string()).collect();
    let err = invertnet::app::run(&argv).unwrap_err();
    assert!(err.to_string().contains("lint failed"), "{err:#}");
    std::fs::remove_dir_all(&dir).ok();
}
