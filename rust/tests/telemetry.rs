//! Integration tests for the telemetry spine.
//!
//! Contracts under test (see `telemetry` module docs):
//! * concurrent increments are lossless — counter totals and histogram
//!   cells are exact under contention, not sampled;
//! * the Prometheus encoder's output is pinned against a committed
//!   golden file (`tests/data/metrics_golden.txt`);
//! * the runtime kill switch makes every instrument inert;
//! * instrumentation is provably inert numerically: `train_step`,
//!   `ParallelTrainer`, and threaded `log_density` are bit-identical
//!   with telemetry enabled and disabled;
//! * the serve stack answers the `metrics` op with valid exposition
//!   covering batcher, registry, and per-op latency series.

mod common;

use std::sync::Mutex;
use std::time::Duration;

use common::{batch_for, flow};
use invertnet::coordinator::{ExecMode, InferOpts};
use invertnet::serve::{BatchConfig, Registry as ServeRegistry, Request,
                       Response, Server};
use invertnet::telemetry::{self, bucket_of, Histogram, Registry, Sample};
use invertnet::train::ParallelTrainer;

/// `telemetry::set_enabled` is process-global and cargo runs the tests in
/// this binary on parallel threads, so every test that flips the switch
/// (or asserts exact counts that the switch could suppress) serializes
/// here.
static ENABLED_LOCK: Mutex<()> = Mutex::new(());

#[test]
fn concurrent_increments_sum_exactly() {
    let _g = ENABLED_LOCK.lock().unwrap();
    let r = Registry::new();
    let c = r.counter("contended_total");
    let h = r.histogram("contended_us");
    const THREADS: usize = 8;
    const PER_THREAD: u64 = 10_000;
    std::thread::scope(|s| {
        for _ in 0..THREADS {
            let c = c.clone();
            let h = h.clone();
            s.spawn(move || {
                for _ in 0..PER_THREAD {
                    c.inc();
                    h.record(3);
                }
            });
        }
    });
    let total = THREADS as u64 * PER_THREAD;
    assert_eq!(c.get(), total, "counter dropped increments");
    let snap = h.snapshot();
    assert_eq!(snap.count, total);
    assert_eq!(snap.sum, 3 * total);
    assert_eq!(snap.buckets[bucket_of(3)], total,
               "every record lands in one bucket");
}

#[test]
fn encoder_matches_the_committed_golden_file() {
    let _g = ENABLED_LOCK.lock().unwrap();
    let h = Histogram::new();
    for v in [0u64, 1, 3, 6] {
        h.record(v);
    }
    let snap = vec![
        ("golden_gauge".to_string(), Sample::Gauge(2.5)),
        ("golden_lat_us".to_string(), Sample::Histogram(h.snapshot())),
        ("golden_total".to_string(), Sample::Counter(7)),
    ];
    let text = telemetry::encode::render(&snap);
    assert_eq!(text, include_str!("data/metrics_golden.txt"),
               "encoder output drifted from the committed golden file");
    let fams = telemetry::encode::parse_exposition(&text).unwrap();
    assert_eq!(fams.len(), 3);
    assert_eq!(fams[1].kind, "histogram");
    assert_eq!(fams[1].samples, 7, "5 buckets + sum + count");
}

#[test]
fn kill_switch_makes_instruments_inert() {
    let _g = ENABLED_LOCK.lock().unwrap();
    let r = Registry::new();
    let c = r.counter("killed_total");
    let h = r.histogram("killed_us");
    let g = r.gauge("killed_gauge");
    telemetry::set_enabled(false);
    c.inc();
    c.add(5);
    h.record(9);
    g.set(1.25);
    telemetry::set_enabled(true);
    assert_eq!(c.get(), 0);
    assert_eq!(h.snapshot().count, 0);
    assert_eq!(g.get(), 0.0);
    // and the switch is a switch, not a latch
    c.inc();
    h.record(2);
    assert_eq!(c.get(), 1);
    assert_eq!(h.snapshot().count, 1);
}

/// The overhead gate's premise: telemetry never touches numeric state.
/// The same fixed-seed step must be bit-identical with instruments on
/// and off — single-threaded, data-parallel, and threaded inference.
#[test]
fn numeric_pins_hold_with_telemetry_toggled() {
    let _g = ENABLED_LOCK.lock().unwrap();
    let flow = flow("realnvp2d");
    let params = flow.init_params(5).unwrap();
    let (x, _) = batch_for(&flow, 9);

    let solo_on = flow
        .train_step(&x, None, &params, &ExecMode::Invertible)
        .unwrap();
    let par_on = ParallelTrainer::new(2)
        .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
        .unwrap();
    let tflow = flow.clone().with_threads(2);
    let ld_on = tflow.log_density(&x, &params, InferOpts::relaxed()).unwrap();

    telemetry::set_enabled(false);
    let solo_off = flow
        .train_step(&x, None, &params, &ExecMode::Invertible)
        .unwrap();
    let par_off = ParallelTrainer::new(2)
        .train_step(&flow, &x, None, &params, &ExecMode::Invertible)
        .unwrap();
    let ld_off = tflow.log_density(&x, &params, InferOpts::relaxed()).unwrap();
    telemetry::set_enabled(true);

    for (on, off, what) in [(&solo_on, &solo_off, "solo"),
                            (&par_on, &par_off, "parallel")] {
        assert_eq!(on.loss.to_bits(), off.loss.to_bits(), "{what}: loss");
        assert_eq!(on.logp_mean.to_bits(), off.logp_mean.to_bits(),
                   "{what}: logp");
        assert_eq!(on.peak_sched_bytes, off.peak_sched_bytes,
                   "{what}: peak");
        for (si, (ga, gb)) in on.grads.iter().zip(&off.grads).enumerate() {
            for (pi, (ta, tb)) in ga.iter().zip(gb).enumerate() {
                assert_eq!(ta.max_abs_diff(tb), 0.0,
                           "{what}: step {si} param {pi} grads drifted");
            }
        }
    }
    assert_eq!(ld_on.len(), ld_off.len());
    for (a, b) in ld_on.iter().zip(&ld_off) {
        assert_eq!(a.to_bits(), b.to_bits(), "threaded log_density drifted");
    }
}

/// Table-driven rejection coverage for the exposition parser: every
/// malformed shape the strict reader guards against, each pinned to its
/// diagnostic (mirrored in `scripts/ci_smoke.py`'s Python parser).
#[test]
fn exposition_parser_rejects_malformed_inputs_with_pinned_messages() {
    let cases: &[(&str, &str, &str)] = &[
        ("truncated bucket line",
         "# TYPE h histogram\nh_bucket{le=\"1\"\n",
         "sample line has no value"),
        ("bucket with unparsable bound",
         "# TYPE h histogram\nh_bucket{le=\"one\"} 1\n\
          h_bucket{le=\"+Inf\"} 1\nh_sum 1\nh_count 1\n",
         "malformed bucket line"),
        ("non-cumulative le counts",
         "# TYPE h histogram\nh_bucket{le=\"1\"} 5\nh_bucket{le=\"2\"} 3\n\
          h_bucket{le=\"+Inf\"} 5\nh_sum 9\nh_count 5\n",
         "non-cumulative bucket counts"),
        ("bucket bounds out of order",
         "# TYPE h histogram\nh_bucket{le=\"2\"} 1\nh_bucket{le=\"1\"} 2\n\
          h_bucket{le=\"+Inf\"} 2\nh_sum 3\nh_count 2\n",
         "bucket bounds out of order"),
        ("count disagrees with +Inf bucket",
         "# TYPE h histogram\nh_bucket{le=\"1\"} 2\n\
          h_bucket{le=\"+Inf\"} 2\nh_sum 2\nh_count 3\n",
         "disagree"),
        ("histogram missing _sum",
         "# TYPE h histogram\nh_bucket{le=\"1\"} 1\n\
          h_bucket{le=\"+Inf\"} 1\nh_count 1\n",
         "_sum or _count"),
        ("histogram missing +Inf bucket",
         "# TYPE h histogram\nh_bucket{le=\"1\"} 1\nh_sum 1\nh_count 1\n",
         "le=\"+Inf\""),
        ("NaN sample value",
         "# TYPE c counter\nc NaN\n",
         "NaN sample value"),
        ("infinite counter value",
         "# TYPE c counter\nc Inf\n",
         "non-finite counter value"),
        ("negative counter value",
         "# TYPE c counter\nc -4\n",
         "negative counter value"),
        ("infinite histogram sum",
         "# TYPE h histogram\nh_bucket{le=\"1\"} 1\n\
          h_bucket{le=\"+Inf\"} 1\nh_sum Inf\nh_count 1\n",
         "non-finite histogram _sum"),
        ("negative bucket count",
         "# TYPE h histogram\nh_bucket{le=\"1\"} -1\n\
          h_bucket{le=\"+Inf\"} 1\nh_sum 1\nh_count 1\n",
         "negative or non-finite bucket count"),
        ("sample before any TYPE line",
         "c 4\n",
         "sample before any TYPE line"),
        ("duplicate family",
         "# TYPE c counter\nc 1\n# TYPE c counter\nc 2\n",
         "duplicate family"),
        ("duplicate series",
         "# TYPE c counter\nc 1\nc 2\n",
         "duplicate series"),
        ("stray sample in another family",
         "# TYPE c counter\nc 1\nd 2\n",
         "does not belong to family"),
        ("family with no samples",
         "# TYPE c counter\n",
         "declares no samples"),
        ("empty exposition",
         "",
         "no metric families found"),
    ];
    for (what, text, needle) in cases {
        let err = telemetry::encode::parse_exposition(text)
            .expect_err(&format!("{what}: parser accepted:\n{text}"));
        assert!(format!("{err:#}").contains(needle),
                "{what}: error {err:#} does not mention {needle:?}");
    }
}

/// The event stream honors the same process-wide kill switch as the
/// metric instruments: with telemetry disabled, `emit` records nothing —
/// not in the counters and not in the flight-recorder ring.
#[test]
fn kill_switch_silences_the_event_stream() {
    use invertnet::telemetry::events::{self, Level};
    use invertnet::util::json::Json;
    let _g = ENABLED_LOCK.lock().unwrap();
    let before = events::ring_len();
    telemetry::set_enabled(false);
    events::emit(Level::Warn, "killed_event",
                 vec![("k", Json::Num(1.0))]);
    telemetry::set_enabled(true);
    assert_eq!(events::ring_len(), before,
               "emit must be a no-op while telemetry is disabled");
    // and the switch is a switch: the next emit lands in the ring
    events::emit(Level::Info, "revived_event", vec![]);
    assert_eq!(events::ring_len(), before + 1);
}

#[test]
fn serve_answers_the_metrics_op_with_valid_exposition() {
    let _g = ENABLED_LOCK.lock().unwrap();
    let registry = ServeRegistry::new(common::engine(), 2);
    registry.register_untrained("realnvp2d", 3).unwrap();
    let server = Server::new(registry, BatchConfig {
        max_batch: 4,
        max_delay: Duration::from_micros(200),
        workers: 1,
        queue_cap: 64,
    })
    .allow_untrained();

    // populate both per-op latency histograms before scraping
    let resp = server.handle(Request::Sample {
        model: None, n: 1, temperature: 1.0, seed: 1, cond: None,
    });
    assert!(!resp.is_error(), "{resp:?}");
    let resp = server.handle(Request::Score {
        model: None,
        x: invertnet::Tensor { shape: vec![1, 2], data: vec![0.1, -0.2] },
        cond: None,
    });
    assert!(!resp.is_error(), "{resp:?}");

    let Response::Metrics { text } = server.handle(Request::Metrics) else {
        panic!("metrics op did not answer with Response::Metrics");
    };
    telemetry::encode::parse_exposition(&text).unwrap();
    for series in [
        "invertnet_serve_requests_total",
        "invertnet_serve_batches_total",
        "invertnet_serve_queue_depth",
        "invertnet_serve_batch_rows",
        "invertnet_serve_sample_latency_us",
        "invertnet_serve_score_latency_us",
        "invertnet_registry_loads_total",
        "invertnet_registry_evictions_total",
    ] {
        assert!(text.contains(series), "{series} missing from:\n{text}");
    }
    assert!(text.contains("invertnet_serve_requests_total 2"),
            "exact request count missing from:\n{text}");
}
