//! Data-parallel training: shard each minibatch across worker threads,
//! run forward/backward per shard under the existing
//! [`ActivationSchedule`], and deterministically reduce the per-shard
//! gradients and losses.
//!
//! ## Design
//!
//! * The batch is cut into contiguous **microbatches** (gradient
//!   accumulation): each microbatch runs one full forward/backward walk,
//!   so the activation envelope scales with the microbatch size, not the
//!   effective batch — large effective batches fit the invertible O(1)
//!   memory envelope.
//! * Worker `w` of `T` owns microbatches `w, w+T, w+2T, ...` (static
//!   round-robin over [`std::thread::scope`] with [`Flow::fork`]ed
//!   handles). No work stealing, so both the assignment and the
//!   per-worker ledger peaks are reproducible run-to-run. Workers are
//!   scoped per step (spawn cost is ~µs against ms-scale steps); the
//!   per-thread scratch pools in `backend::math` therefore warm up
//!   within a step (across a worker's microbatches) but restart each
//!   step — a persistent worker pool that keeps them warm across steps
//!   is future work.
//! * Reduction is **slot-ordered**: microbatch results combine in
//!   microbatch-index order with f64 accumulators, weighted by shard
//!   size. The reduced value never depends on thread completion order —
//!   the same microbatch size yields bit-identical results at any thread
//!   count, and the same seed + thread count yields identical losses on
//!   every run.
//!
//! ## Numerics
//!
//! Per-sample forward/backward signals are identical to the
//! single-threaded walk (batch entries never mix, and the NLL cotangent
//! seeds scale by exact powers of two for power-of-two shard sizes); only
//! the *final* batch reductions — parameter-gradient sums and loss means
//! — are re-associated. Parallel results therefore match
//! [`Flow::train_step`] to f32 summation-reassociation error (observed
//! ≲ 2e-6 absolute; asserted at 1e-5 in `tests/parallel_train.rs`), and
//! one worker with one microbatch is bit-exact.

use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::api::Flow;
use crate::coordinator::{ActivationSchedule, StepResult};
use crate::flow::ParamStore;
use crate::tensor::ops::slice_rows;
use crate::tensor::Tensor;

/// Shards minibatches across worker threads with deterministic reduction.
///
/// ```text
/// let trainer = ParallelTrainer::new(4).microbatch(64);
/// let step = trainer.train_step(&flow, &x, None, &params, &ExecMode::Invertible)?;
/// ```
#[derive(Debug, Clone, Copy)]
pub struct ParallelTrainer {
    threads: usize,
    microbatch: Option<usize>,
}

impl ParallelTrainer {
    /// A trainer fanning out over `threads` workers (clamped to >= 1).
    pub fn new(threads: usize) -> ParallelTrainer {
        ParallelTrainer { threads: threads.max(1), microbatch: None }
    }

    /// Fix the microbatch (gradient-accumulation) size. Defaults to
    /// `ceil(batch / threads)` — one shard per worker. Smaller values trade
    /// wall-clock for a tighter activation envelope; a fixed value makes
    /// the reduced result independent of the thread count.
    pub fn microbatch(mut self, size: usize) -> ParallelTrainer {
        self.microbatch = Some(size.max(1));
        self
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Human-readable config for logs ("threads=4, microbatch=64").
    pub fn describe(&self, batch: usize) -> String {
        format!("threads={}, microbatch={}", self.threads,
                self.resolve_microbatch(batch))
    }

    fn resolve_microbatch(&self, batch: usize) -> usize {
        self.microbatch
            .unwrap_or_else(|| batch.div_ceil(self.threads))
            .max(1)
    }

    /// One NLL training step over `x`, sharded across the workers; returns
    /// the same [`StepResult`] as [`Flow::train_step`], with
    /// `peak_sched_bytes` / `peak_total_bytes` reporting the *concurrent*
    /// envelope (sum over workers of each worker's peak).
    ///
    /// Unlike the strict [`Flow::train_step`], `x` may have ANY leading
    /// batch size (non-batch dims must still match): gradient-accumulation
    /// microbatching exists precisely so effective batches larger (or
    /// smaller) than the network's canonical batch can train, so the
    /// batch-flexible contract is this type's public API, not an accident
    /// of the internal relaxed path.
    pub fn train_step(
        &self,
        flow: &Flow,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
        schedule: &dyn ActivationSchedule,
    ) -> Result<StepResult> {
        let in_shape = &flow.def.in_shape;
        if x.shape.len() != in_shape.len() || x.shape[1..] != in_shape[1..] {
            bail!("input shape {:?} incompatible with network {:?}",
                  x.shape, in_shape);
        }
        let n = x.shape.first().copied().unwrap_or(0);
        if n == 0 {
            bail!("empty batch");
        }
        // validate cond up front with the same predicate the per-shard
        // walk applies: slicing a short cond inside a worker would panic
        // there and surface only as "worker panicked"
        flow.check_cond(cond, n, true)?;
        let mb = self.resolve_microbatch(n);
        let n_micro = n.div_ceil(mb);
        let threads = self.threads.min(n_micro);

        let mut slots: Vec<Option<StepResult>> = Vec::new();
        slots.resize_with(n_micro, || None);
        // (peak_sched, peak_total) per worker: max over its microbatches
        let mut worker_peaks = vec![(0i64, 0i64); threads];

        // per-worker wall time and reduction time feed global histograms;
        // timers and atomics only — the numeric path is untouched, so the
        // parallel-vs-solo bit-exactness pins hold with telemetry on
        let worker_hist =
            crate::telemetry::global().histogram("invertnet_train_worker_us");
        let reduce_hist =
            crate::telemetry::global().histogram("invertnet_train_reduce_us");

        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(threads);
            for w in 0..threads {
                let worker_flow = flow.fork();
                let worker_hist = worker_hist.clone();
                handles.push(scope.spawn(move || -> Result<Vec<(usize, StepResult)>> {
                    let t_w = Instant::now();
                    let mut done = Vec::new();
                    let mut j = w;
                    while j < n_micro {
                        let lo = j * mb;
                        let hi = ((j + 1) * mb).min(n);
                        let xs = slice_rows(x, lo, hi - lo)?;
                        let cs = cond.map(|c| slice_rows(c, lo, hi - lo))
                            .transpose()?;
                        let r = worker_flow
                            .train_step_flex(&xs, cs.as_ref(), params,
                                             schedule, true)?;
                        done.push((j, r));
                        j += threads;
                    }
                    worker_hist.record(t_w.elapsed().as_micros() as u64);
                    Ok(done)
                }));
            }
            // join EVERY handle before reporting any failure: an early
            // return would let thread::scope auto-join a panicked worker
            // and re-panic, turning a clean Err into a process abort
            let mut first_err: Option<anyhow::Error> = None;
            for (w, handle) in handles.into_iter().enumerate() {
                match handle.join() {
                    Err(payload) => {
                        // preserve the panic message the worker died with
                        let msg = payload.downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>().cloned())
                            .unwrap_or_else(|| "non-string panic payload".into());
                        first_err.get_or_insert_with(
                            || anyhow!("worker {w} panicked: {msg}"));
                    }
                    Ok(Err(e)) => {
                        first_err.get_or_insert(e);
                    }
                    Ok(Ok(results)) => {
                        for (j, r) in results {
                            worker_peaks[w].0 =
                                worker_peaks[w].0.max(r.peak_sched_bytes);
                            worker_peaks[w].1 =
                                worker_peaks[w].1.max(r.peak_total_bytes);
                            slots[j] = Some(r);
                        }
                    }
                }
            }
            match first_err {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })?;

        // ---- deterministic slot-ordered reduction (f64 accumulators) ----
        let t_reduce = Instant::now();
        let total = n as f64;
        let mut loss = 0.0f64;
        let mut logp = 0.0f64;
        let mut logdet = 0.0f64;
        // per (step, param): shape + f64 accumulation buffer
        let mut acc: Vec<Vec<(Vec<usize>, Vec<f64>)>> = Vec::new();
        let mut dcond_parts: Vec<(f64, Tensor)> = Vec::new();
        for (j, slot) in slots.iter_mut().enumerate() {
            let r = slot.take()
                .ok_or_else(|| anyhow!("microbatch {j} missing (scheduler bug)"))?;
            let lo = j * mb;
            let hi = ((j + 1) * mb).min(n);
            let w = (hi - lo) as f64 / total;
            loss += w * r.loss as f64;
            logp += w * r.logp_mean as f64;
            logdet += w * r.logdet_mean as f64;
            if acc.is_empty() {
                acc = r.grads.iter()
                    .map(|ts| ts.iter()
                        .map(|t| (t.shape.clone(),
                                  t.data.iter()
                                      .map(|&v| w * v as f64)
                                      .collect::<Vec<f64>>()))
                        .collect())
                    .collect();
            } else {
                for (accs, gs) in acc.iter_mut().zip(&r.grads) {
                    for ((_, ad), g) in accs.iter_mut().zip(gs) {
                        for (s, &v) in ad.iter_mut().zip(&g.data) {
                            *s += w * v as f64;
                        }
                    }
                }
            }
            if let Some(dc) = r.dcond {
                dcond_parts.push((w, dc));
            }
        }
        let grads: Vec<Vec<Tensor>> = acc.into_iter()
            .map(|ts| ts.into_iter()
                .map(|(shape, ad)| Tensor {
                    shape,
                    data: ad.into_iter().map(|v| v as f32).collect(),
                })
                .collect())
            .collect();
        let dcond = match dcond_parts.first() {
            None => None,
            Some((_, first)) => {
                let inner = first.inner_len();
                let mut shape = first.shape.clone();
                shape[0] = n;
                let mut data = Vec::with_capacity(n * inner);
                for (w, dc) in &dcond_parts {
                    // shard dconds are means over their shard; reweight to
                    // the full-batch mean (rows stay in input order)
                    data.extend(dc.data.iter().map(|&v| (*w * v as f64) as f32));
                }
                Some(Tensor::new(shape, data)?)
            }
        };

        reduce_hist.record(t_reduce.elapsed().as_micros() as u64);

        Ok(StepResult {
            loss: loss as f32,
            logp_mean: logp as f32,
            logdet_mean: logdet as f32,
            grads,
            dcond,
            peak_sched_bytes: worker_peaks.iter().map(|p| p.0).sum(),
            peak_total_bytes: worker_peaks.iter().map(|p| p.1).sum(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn microbatch_resolution() {
        // default: one shard per worker, ceil division
        assert_eq!(ParallelTrainer::new(4).resolve_microbatch(256), 64);
        assert_eq!(ParallelTrainer::new(3).resolve_microbatch(256), 86);
        assert_eq!(ParallelTrainer::new(1).resolve_microbatch(256), 256);
        assert_eq!(ParallelTrainer::new(8).resolve_microbatch(4), 1);
        // explicit microbatch wins; zero clamps to 1
        assert_eq!(ParallelTrainer::new(4).microbatch(32).resolve_microbatch(256), 32);
        assert_eq!(ParallelTrainer::new(4).microbatch(0).resolve_microbatch(256), 1);
    }

    #[test]
    fn thread_clamping_and_describe() {
        let t = ParallelTrainer::new(0);
        assert_eq!(t.threads(), 1);
        assert_eq!(ParallelTrainer::new(4).describe(256),
                   "threads=4, microbatch=64");
    }

    #[test]
    fn slice_rows_is_contiguous() {
        // shard slicing rides on the shared tensor::ops::slice_rows
        let t = Tensor::new(vec![4, 2],
                            vec![0., 1., 2., 3., 4., 5., 6., 7.]).unwrap();
        let s = slice_rows(&t, 1, 2).unwrap();
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![2., 3., 4., 5.]);
    }
}
