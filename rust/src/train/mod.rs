//! Training: optimizers, schedules, the minibatch loop, metrics.

pub mod loop_;
pub mod optimizer;

pub use loop_::{train, TrainConfig, TrainReport};
pub use optimizer::{Adam, GradClip, Optimizer, Sgd};
