//! Training: optimizers, schedules, the minibatch loop, the data-parallel
//! trainer, metrics.

pub mod loop_;
pub mod optimizer;
pub mod parallel;

pub use loop_::{bits_per_dim, train, TrainConfig, TrainReport};
pub use optimizer::{grad_l2_norm, Adam, GradClip, Optimizer, Sgd};
pub use parallel::ParallelTrainer;
