//! The minibatch training loop: NLL objective, Adam, grad clipping,
//! CSV metrics, checkpointing.

use std::io::Write as _;
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::api::Flow;
use crate::coordinator::{ActivationSchedule, ExecMode, InferOpts};
use crate::flow::ParamStore;
use crate::tensor::Tensor;
use crate::util::bench::fmt_bytes;

use super::optimizer::{grad_l2_norm, GradClip, Optimizer};
use super::parallel::ParallelTrainer;

pub struct TrainConfig {
    pub steps: usize,
    /// Activation schedule (invertible / stored / any custom
    /// [`ActivationSchedule`]).
    pub schedule: Arc<dyn ActivationSchedule>,
    pub clip: Option<GradClip>,
    pub log_every: usize,
    /// Write metrics.csv + checkpoint here if set.
    pub out_dir: Option<PathBuf>,
    pub quiet: bool,
    /// Data-parallel worker threads; > 1 shards every minibatch through
    /// [`ParallelTrainer`] (deterministic reduction, same gradients).
    pub threads: usize,
    /// Gradient-accumulation microbatch size for the parallel path
    /// (None = one shard per worker). Setting this with `threads: 1`
    /// still bounds the activation envelope to the microbatch size.
    pub microbatch: Option<usize>,
    /// Held-out eval split `(x, cond)` for model selection. When set, the
    /// loop scores it with [`Flow::log_density`] every `eval_every` steps
    /// (and at the last step) and logs the mean NLL as the `eval_nll`
    /// column of metrics.csv — the signal `posterior-train` and plain
    /// `train` expose for comparing runs. Any leading batch size works.
    pub eval_set: Option<(Tensor, Option<Tensor>)>,
    /// Cadence of eval-split scoring (steps); 0 scores only the last step.
    pub eval_every: usize,
    /// Emit a `train_slow_step` warn event (structured event log) for any
    /// step whose wall clock exceeds this many milliseconds.
    pub slow_step_ms: Option<u64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 100,
            schedule: Arc::new(ExecMode::Invertible),
            clip: Some(GradClip { max_norm: 50.0 }),
            log_every: 10,
            out_dir: None,
            quiet: false,
            threads: 1,
            microbatch: None,
            eval_set: None,
            eval_every: 50,
            slow_step_ms: None,
        }
    }
}

pub struct TrainReport {
    pub losses: Vec<f32>,
    pub final_loss: f32,
    /// Last eval-split mean NLL (None when no eval set was configured).
    pub eval_nll: Option<f32>,
    pub peak_sched_bytes: i64,
    pub steps_per_sec: f64,
}

/// NLL (nats/sample) -> bits per dimension, the standard density-model
/// comparison unit.
pub fn bits_per_dim(nll: f32, dims_per_sample: usize) -> f32 {
    nll / (dims_per_sample.max(1) as f32 * std::f32::consts::LN_2)
}

/// Run `cfg.steps` optimizer steps, drawing a fresh minibatch from
/// `next_batch(step) -> (x, cond)` each iteration.
pub fn train(
    flow: &Flow,
    params: &mut ParamStore,
    opt: &mut dyn Optimizer,
    cfg: &TrainConfig,
    mut next_batch: impl FnMut(usize) -> Result<(Tensor, Option<Tensor>)>,
) -> Result<TrainReport> {
    let mut losses = Vec::with_capacity(cfg.steps);
    let mut peak = 0i64;
    let mut csv: Option<std::fs::File> = match &cfg.out_dir {
        Some(dir) => {
            std::fs::create_dir_all(dir)?;
            let mut f = std::fs::File::create(dir.join("metrics.csv"))?;
            // ms = the step's compute+eval time; wall_ms = row-to-row
            // wall clock (includes logging/IO between rows); ts_unix_ms
            // = absolute write time, for correlating rows with the
            // event log and span trace
            writeln!(f, "step,loss,logp_mean,logdet_mean,grad_norm,\
                         peak_sched_bytes,ms,wall_ms,ts_unix_ms,eval_nll")?;
            Some(f)
        }
        None => None,
    };

    // threads > 1 (or an explicit microbatch) routes through the
    // data-parallel trainer; its reduction is deterministic, so the two
    // paths train to the same losses
    let trainer = if cfg.threads > 1 || cfg.microbatch.is_some() {
        let mut t = ParallelTrainer::new(cfg.threads);
        if let Some(mb) = cfg.microbatch {
            t = t.microbatch(mb);
        }
        Some(t)
    } else {
        None
    };

    // telemetry handles, resolved once (the per-step cost is a few
    // relaxed atomic stores; posterior training rides this same loop)
    let telem = crate::telemetry::global();
    let m_steps = telem.counter("invertnet_train_steps_total");
    let m_loss = telem.gauge("invertnet_train_loss");
    let m_gnorm = telem.gauge("invertnet_train_grad_norm");
    let m_eval = telem.gauge("invertnet_train_eval_nll");
    let m_peak = telem.gauge("invertnet_train_peak_sched_bytes");

    let mut last_eval: Option<f32> = None;
    let dims = flow.def.dims_per_sample();
    let t0 = Instant::now();
    let mut last_row = Instant::now();
    for step in 0..cfg.steps {
        let step_span = crate::span!("train_step");
        let ts = Instant::now();
        let (x, cond) = next_batch(step)?;
        if step == 0 && !cfg.quiet {
            if let Some(t) = &trainer {
                eprintln!("data-parallel: {}", t.describe(x.batch()));
            }
        }
        let mut result = match &trainer {
            Some(t) => t
                .train_step(flow, &x, cond.as_ref(), params,
                            cfg.schedule.as_ref())
                .with_context(|| format!("parallel train step {step}"))?,
            None => flow
                .train_step(&x, cond.as_ref(), params, cfg.schedule.as_ref())
                .with_context(|| format!("train step {step}"))?,
        };
        // the true global norm is reported whether or not clipping is on
        // (previously the CSV logged 0.0 under `clip: None`)
        let grad_norm = grad_l2_norm(&result.grads);
        if let Some(c) = &cfg.clip {
            c.scale_to(&mut result.grads, grad_norm);
        }
        opt.step(params, &result.grads)?;
        peak = peak.max(result.peak_sched_bytes);
        losses.push(result.loss);
        m_steps.inc();
        m_loss.set(result.loss as f64);
        m_gnorm.set(grad_norm as f64);
        m_peak.set(peak as f64);

        // eval-split NLL on the (post-update) parameters, at the
        // configured cadence plus always at the final step
        let mut eval_cell = String::new();
        if let Some((ex, ec)) = &cfg.eval_set {
            let due = step + 1 == cfg.steps
                || (cfg.eval_every > 0 && step % cfg.eval_every == 0);
            if due {
                let _eval_span = crate::span!("train_eval");
                let scores = flow.log_density(
                        ex, params, InferOpts::relaxed().cond_opt(ec.as_ref()))
                    .with_context(|| format!("eval split at step {step}"))?;
                let nll = -(scores.iter().map(|&v| v as f64).sum::<f64>()
                            / scores.len().max(1) as f64) as f32;
                last_eval = Some(nll);
                m_eval.set(nll as f64);
                eval_cell = format!("{nll}");
            }
        }
        drop(step_span); // close the span before the logging I/O

        let ms = ts.elapsed().as_secs_f64() * 1e3;
        if let Some(limit) = cfg.slow_step_ms {
            if ms > limit as f64 {
                crate::telemetry::events::emit(
                    crate::telemetry::events::Level::Warn,
                    "train_slow_step",
                    vec![
                        ("step", crate::util::json::Json::Num(step as f64)),
                        ("ms", crate::util::json::Json::Num(ms)),
                        ("limit_ms",
                         crate::util::json::Json::Num(limit as f64)),
                    ],
                );
            }
        }
        if let Some(f) = &mut csv {
            let wall_ms = last_row.elapsed().as_secs_f64() * 1e3;
            last_row = Instant::now();
            let ts_unix_ms = std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .map(|d| d.as_millis())
                .unwrap_or(0);
            writeln!(
                f,
                "{step},{},{},{},{grad_norm},{},{ms:.1},{wall_ms:.1},\
                 {ts_unix_ms},{eval_cell}",
                result.loss, result.logp_mean, result.logdet_mean,
                result.peak_sched_bytes
            )?;
        }
        if !cfg.quiet && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            let eval_note = match (&cfg.eval_set, last_eval) {
                (Some(_), Some(nll)) => format!(
                    "  eval_nll {nll:>8.4} ({:.3} b/d)",
                    bits_per_dim(nll, dims)),
                _ => String::new(),
            };
            eprintln!(
                "step {step:>5}  loss {:>10.4}  logp {:>10.4}  logdet {:>8.4}  \
                 |g| {grad_norm:>8.2}  peak {:>10}  {ms:>7.1} ms{eval_note}",
                result.loss, result.logp_mean, result.logdet_mean,
                fmt_bytes(result.peak_sched_bytes as u64)
            );
        }
    }
    let elapsed = t0.elapsed().as_secs_f64();

    if let Some(dir) = &cfg.out_dir {
        params.save(&dir.join("checkpoint"), &flow.def.name)?;
    }

    Ok(TrainReport {
        final_loss: *losses.last().unwrap_or(&f32::NAN),
        losses,
        eval_nll: last_eval,
        peak_sched_bytes: peak,
        steps_per_sec: cfg.steps as f64 / elapsed,
    })
}

/// Smoothed loss over the last `k` entries (for convergence asserts).
pub fn tail_mean(losses: &[f32], k: usize) -> f32 {
    if losses.is_empty() {
        return f32::NAN;
    }
    let k = k.min(losses.len());
    losses[losses.len() - k..].iter().sum::<f32>() / k as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tail_mean_works() {
        assert!((tail_mean(&[1.0, 2.0, 3.0, 4.0], 2) - 3.5).abs() < 1e-6);
        assert!((tail_mean(&[1.0], 5) - 1.0).abs() < 1e-6);
        assert!(tail_mean(&[], 3).is_nan());
    }

    #[test]
    fn default_config_uses_invertible_schedule() {
        let cfg = TrainConfig::default();
        assert_eq!(cfg.schedule.label(), "invertible");
        assert_eq!(cfg.steps, 100);
        assert!(cfg.eval_set.is_none());
        assert_eq!(cfg.eval_every, 50);
    }

    #[test]
    fn bits_per_dim_conversion() {
        // 2-dim samples at NLL = 2 ln 2 nats -> exactly 1 bit/dim
        let nll = 2.0 * std::f32::consts::LN_2;
        assert!((bits_per_dim(nll, 2) - 1.0).abs() < 1e-6);
        assert!(bits_per_dim(1.0, 0).is_finite()); // clamped denominator
    }
}
