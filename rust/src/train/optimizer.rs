//! Host-side optimizers over the per-step parameter store.
//!
//! Parameters are small relative to activations (the paper's whole point),
//! so the update runs on host f32 slices.

use anyhow::{bail, Result};

use crate::flow::ParamStore;
use crate::tensor::Tensor;

/// Global L2 norm over an aligned gradient store (f64 accumulation).
///
/// Lives outside [`GradClip`] so the training loop can report the true
/// norm whether or not clipping is enabled — `metrics.csv` used to log
/// `grad_norm = 0.0` whenever `clip: None` because the norm was only
/// computed as a clipping by-product.
pub fn grad_l2_norm(grads: &[Vec<Tensor>]) -> f32 {
    let mut sq = 0.0f64;
    for g in grads.iter().flatten() {
        sq += g.data.iter().map(|x| (*x as f64) * (*x as f64)).sum::<f64>();
    }
    sq.sqrt() as f32
}

/// Gradient-clipping config (global L2 norm).
#[derive(Debug, Clone, Copy)]
pub struct GradClip {
    pub max_norm: f32,
}

impl GradClip {
    /// Scale all grads in-place so the global norm is <= max_norm.
    /// Returns the pre-clip norm (see [`grad_l2_norm`]).
    pub fn apply(&self, grads: &mut [Vec<Tensor>]) -> f32 {
        let norm = grad_l2_norm(grads);
        self.scale_to(grads, norm);
        norm
    }

    /// The scaling half of [`GradClip::apply`], given an already-computed
    /// global norm: rescales so the norm is <= max_norm, or leaves the
    /// grads untouched if it already is.
    pub fn scale_to(&self, grads: &mut [Vec<Tensor>], norm: f32) {
        if norm > self.max_norm && norm > 0.0 {
            let scale = self.max_norm / norm;
            for g in grads.iter_mut().flatten() {
                for v in &mut g.data {
                    *v *= scale;
                }
            }
        }
    }
}

pub trait Optimizer {
    /// Apply one update. `grads` is aligned with the store layout
    /// (per step, per param).
    fn step(&mut self, params: &mut ParamStore, grads: &[Vec<Tensor>]) -> Result<()>;

    fn lr(&self) -> f32;
    fn set_lr(&mut self, lr: f32);

    /// Bytes of optimizer state (for the memory report).
    fn state_bytes(&self) -> usize;
}

/// Plain SGD (optionally with momentum).
pub struct Sgd {
    pub lr: f32,
    pub momentum: f32,
    velocity: Option<Vec<Vec<Tensor>>>,
}

impl Sgd {
    pub fn new(lr: f32, momentum: f32) -> Sgd {
        Sgd { lr, momentum, velocity: None }
    }
}

impl Optimizer for Sgd {
    fn step(&mut self, params: &mut ParamStore, grads: &[Vec<Tensor>]) -> Result<()> {
        if grads.len() != params.tensors.len() {
            bail!("grad layout mismatch");
        }
        if self.momentum > 0.0 && self.velocity.is_none() {
            self.velocity = Some(
                params.tensors.iter()
                    .map(|ts| ts.iter().map(|t| Tensor::zeros(&t.shape)).collect())
                    .collect());
        }
        for (si, (ts, gs)) in params.tensors.iter_mut().zip(grads).enumerate() {
            if gs.is_empty() {
                continue;
            }
            if gs.len() != ts.len() {
                bail!("step {si}: {} grads for {} params", gs.len(), ts.len());
            }
            for (pi, (t, g)) in ts.iter_mut().zip(gs).enumerate() {
                match &mut self.velocity {
                    Some(vel) => {
                        let v = &mut vel[si][pi];
                        for ((vv, gv), tv) in
                            v.data.iter_mut().zip(&g.data).zip(&mut t.data)
                        {
                            *vv = self.momentum * *vv + gv;
                            *tv -= self.lr * *vv;
                        }
                    }
                    None => {
                        for (tv, gv) in t.data.iter_mut().zip(&g.data) {
                            *tv -= self.lr * gv;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn state_bytes(&self) -> usize {
        self.velocity.as_ref().map_or(0, |v| {
            v.iter().flatten().map(|t| t.size_bytes()).sum()
        })
    }
}

/// Adam (Kingma & Ba) with bias correction.
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u64,
    m: Option<Vec<Vec<Tensor>>>,
    v: Option<Vec<Vec<Tensor>>>,
}

impl Adam {
    pub fn new(lr: f32) -> Adam {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8, t: 0, m: None, v: None }
    }
}

impl Optimizer for Adam {
    fn step(&mut self, params: &mut ParamStore, grads: &[Vec<Tensor>]) -> Result<()> {
        if grads.len() != params.tensors.len() {
            bail!("grad layout mismatch");
        }
        if self.m.is_none() {
            let zeros: Vec<Vec<Tensor>> = params.tensors.iter()
                .map(|ts| ts.iter().map(|t| Tensor::zeros(&t.shape)).collect())
                .collect();
            self.m = Some(zeros.clone());
            self.v = Some(zeros);
        }
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        let m = self.m.as_mut().unwrap();
        let v = self.v.as_mut().unwrap();
        for (si, (ts, gs)) in params.tensors.iter_mut().zip(grads).enumerate() {
            if gs.is_empty() {
                continue;
            }
            if gs.len() != ts.len() {
                bail!("step {si}: {} grads for {} params", gs.len(), ts.len());
            }
            for (pi, (t, g)) in ts.iter_mut().zip(gs).enumerate() {
                let (mi, vi) = (&mut m[si][pi], &mut v[si][pi]);
                for k in 0..t.data.len() {
                    let gk = g.data[k];
                    mi.data[k] = self.beta1 * mi.data[k] + (1.0 - self.beta1) * gk;
                    vi.data[k] = self.beta2 * vi.data[k] + (1.0 - self.beta2) * gk * gk;
                    let mhat = mi.data[k] / b1t;
                    let vhat = vi.data[k] / b2t;
                    t.data[k] -= self.lr * mhat / (vhat.sqrt() + self.eps);
                }
            }
        }
        Ok(())
    }

    fn lr(&self) -> f32 {
        self.lr
    }

    fn set_lr(&mut self, lr: f32) {
        self.lr = lr;
    }

    fn state_bytes(&self) -> usize {
        let per = self.m.as_ref().map_or(0, |m| {
            m.iter().flatten().map(|t| t.size_bytes()).sum()
        });
        per * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(vals: &[f32]) -> ParamStore {
        ParamStore {
            tensors: vec![vec![Tensor::new(vec![vals.len()], vals.to_vec()).unwrap()]],
            names: vec![vec!["w1".into()]],
        }
    }

    // Use a tiny quadratic f(w) = 0.5*||w||^2, grad = w.
    fn grad_of(p: &ParamStore) -> Vec<Vec<Tensor>> {
        vec![vec![p.tensors[0][0].clone()]]
    }

    #[test]
    fn sgd_descends_quadratic() {
        let mut p = store(&[1.0, -2.0, 3.0]);
        let mut opt = Sgd::new(0.1, 0.0);
        for _ in 0..100 {
            let g = grad_of(&p);
            opt.step(&mut p, &g).unwrap();
        }
        assert!(p.tensors[0][0].linf() < 1e-3);
    }

    #[test]
    fn adam_descends_quadratic() {
        let mut p = store(&[1.0, -2.0, 3.0]);
        let mut opt = Adam::new(0.05);
        for _ in 0..400 {
            let g = grad_of(&p);
            opt.step(&mut p, &g).unwrap();
        }
        assert!(p.tensors[0][0].linf() < 1e-2, "{:?}", p.tensors[0][0]);
    }

    #[test]
    fn momentum_allocates_state() {
        let mut p = store(&[1.0; 8]);
        let mut opt = Sgd::new(0.01, 0.9);
        let g = grad_of(&p);
        opt.step(&mut p, &g).unwrap();
        assert_eq!(opt.state_bytes(), 32);
    }

    #[test]
    fn clip_bounds_norm() {
        let mut g = vec![vec![Tensor::new(vec![2], vec![30.0, 40.0]).unwrap()]];
        let pre = GradClip { max_norm: 5.0 }.apply(&mut g);
        assert!((pre - 50.0).abs() < 1e-4);
        let post = (g[0][0].data[0].powi(2) + g[0][0].data[1].powi(2)).sqrt();
        assert!((post - 5.0).abs() < 1e-4);
    }

    #[test]
    fn norm_is_computable_without_clipping() {
        let g = vec![vec![Tensor::new(vec![2], vec![3.0, 4.0]).unwrap()],
                     vec![Tensor::new(vec![1], vec![12.0]).unwrap()]];
        assert!((grad_l2_norm(&g) - 13.0).abs() < 1e-5);
        // under the threshold, scale_to must not touch the grads
        let mut g2 = g.clone();
        GradClip { max_norm: 100.0 }.scale_to(&mut g2, 13.0);
        assert_eq!(g2[0][0].data, vec![3.0, 4.0]);
    }

    #[test]
    fn layout_mismatch_rejected() {
        let mut p = store(&[1.0]);
        let mut opt = Adam::new(0.1);
        assert!(opt.step(&mut p, &[]).is_err());
    }
}
