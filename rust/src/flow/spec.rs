//! Resolved network definition: the ordered steps the coordinator replays.
//!
//! A step is either a backend-executed layer or a coordinator-native
//! `split` (multiscale factor-out — pure host memory movement, see
//! `tensor::ops`).

use anyhow::{bail, Result};

use crate::runtime::manifest::parse_split;
use crate::runtime::{LayerMeta, Manifest, NetworkMeta};

#[derive(Debug, Clone, PartialEq)]
pub enum StepKind {
    /// Backend-executed layer with the given manifest signature.
    Layer,
    /// Factor-out: first `zc` channels leave as a latent, rest continues.
    Split { zc: usize },
}

#[derive(Debug, Clone)]
pub struct Step {
    pub kind: StepKind,
    /// Manifest signature (layers) or the split marker string.
    pub sig: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
}

impl Step {
    /// For a split step, the shape of the factored-out latent (the first
    /// `zc` channels of the input); `None` for layer steps. Shared by the
    /// resolver's latent derivation and the static memory planner.
    pub fn split_z_shape(&self) -> Option<Vec<usize>> {
        match self.kind {
            StepKind::Split { zc } => {
                let mut z = self.in_shape.clone();
                *z.last_mut().expect("split input has at least one dim") = zc;
                Some(z)
            }
            StepKind::Layer => None,
        }
    }
}

/// A network resolved against the manifest.
#[derive(Debug, Clone)]
pub struct NetworkDef {
    pub name: String,
    pub in_shape: Vec<usize>,
    pub cond_shape: Option<Vec<usize>>,
    pub steps: Vec<Step>,
    /// Latent shapes in push order: one per split step, then the final
    /// activation (which is always a latent but never a split product).
    pub latent_shapes: Vec<Vec<usize>>,
}

impl NetworkDef {
    pub fn resolve(manifest: &Manifest, name: &str) -> Result<NetworkDef> {
        let net: &NetworkMeta = manifest.network(name)?;
        let mut steps = Vec::with_capacity(net.layers.len());
        let mut cur = net.in_shape.clone();
        for sig in &net.layers {
            if let Some((zc, in_shape)) = parse_split(sig) {
                if in_shape != cur {
                    bail!("{name}: split expects {in_shape:?}, flow is at {cur:?}");
                }
                let mut out = cur.clone();
                let c = *out.last().unwrap();
                if zc == 0 || zc >= c {
                    bail!("{name}: bad split zc={zc} for {c} channels");
                }
                *out.last_mut().unwrap() = c - zc;
                steps.push(Step {
                    kind: StepKind::Split { zc },
                    sig: sig.clone(),
                    in_shape: cur.clone(),
                    out_shape: out.clone(),
                });
                cur = out;
            } else {
                let meta: &LayerMeta = manifest.layer(sig)?;
                if meta.in_shape != cur {
                    bail!("{name}: layer {sig} expects {:?}, flow is at {cur:?}",
                          meta.in_shape);
                }
                steps.push(Step {
                    kind: StepKind::Layer,
                    sig: sig.clone(),
                    in_shape: meta.in_shape.clone(),
                    out_shape: meta.out_shape.clone(),
                });
                cur = meta.out_shape.clone();
            }
        }
        // sanity: latent shapes = splits' z shapes + final shape
        let mut want_latents: Vec<Vec<usize>> = steps.iter()
            .filter_map(Step::split_z_shape)
            .collect();
        want_latents.push(cur.clone());
        if want_latents != net.latent_shapes {
            bail!("{name}: manifest latents {:?} != derived {:?}",
                  net.latent_shapes, want_latents);
        }
        Ok(NetworkDef {
            name: net.name.clone(),
            in_shape: net.in_shape.clone(),
            cond_shape: net.cond_shape.clone(),
            steps,
            latent_shapes: net.latent_shapes.clone(),
        })
    }

    /// Total number of scalar parameters across all steps.
    pub fn param_count(&self, manifest: &Manifest) -> Result<usize> {
        let mut total = 0;
        for s in &self.steps {
            if s.kind == StepKind::Layer {
                total += manifest.layer(&s.sig)?.param_count();
            }
        }
        Ok(total)
    }

    /// Input elements per sample (bits/dim denominators etc.).
    pub fn dims_per_sample(&self) -> usize {
        self.in_shape.iter().skip(1).product()
    }

    pub fn depth(&self) -> usize {
        self.steps.iter().filter(|s| s.kind == StepKind::Layer).count()
    }

    /// Number of split (factor-out) steps.
    pub fn num_splits(&self) -> usize {
        self.latent_shapes.len().saturating_sub(1)
    }

    /// Latent shape produced by the `split_idx`-th split step.
    ///
    /// `latent_shapes` holds one entry per split **plus** the final
    /// activation as its last element; the final latent is not a split
    /// product, so indexing `latent_shapes` directly with a split index is
    /// off-by-one-prone (the old `find_latent_for` did exactly that).
    /// This accessor is bounds-correct: it returns `None` for
    /// `split_idx >= num_splits()`, never the final latent.
    pub fn split_latent(&self, split_idx: usize) -> Option<&Vec<usize>> {
        if split_idx < self.num_splits() {
            self.latent_shapes.get(split_idx)
        } else {
            None
        }
    }

    /// Shape of the final latent (the activation left after all steps).
    pub fn final_latent(&self) -> &Vec<usize> {
        self.latent_shapes.last()
            .expect("a resolved network always has a final latent")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::builtin_manifest;

    // (split-marker parse/format coverage lives with the parser in
    // runtime/manifest.rs)

    /// Regression: the final latent is not a split latent. The old
    /// `find_latent_for` indexed `latent_shapes` directly, so asking for
    /// the split after the last one silently returned the final latent.
    #[test]
    fn split_latent_accessor_is_bounds_correct() {
        let m = builtin_manifest().unwrap();
        // glow16 has exactly one split ([16,8,8,6]) and a final latent
        // ([16,4,4,24]).
        let def = NetworkDef::resolve(&m, "glow16").unwrap();
        assert_eq!(def.num_splits(), 1);
        assert_eq!(def.split_latent(0), Some(&vec![16, 8, 8, 6]));
        // index 1 points at the final latent in latent_shapes — a split
        // accessor must NOT hand it out (the old `find_latent_for` did)
        assert_eq!(def.split_latent(1), None);
        assert_eq!(def.split_latent(99), None);
        assert_eq!(def.final_latent(), &vec![16, 4, 4, 24]);

        // a split-free net: no split latents at all, final latent = input
        let def = NetworkDef::resolve(&m, "realnvp2d").unwrap();
        assert_eq!(def.num_splits(), 0);
        assert_eq!(def.split_latent(0), None);
        assert_eq!(def.final_latent(), &vec![256, 2]);
    }

    #[test]
    fn depth_counts_layers_not_splits() {
        let m = builtin_manifest().unwrap();
        let def = NetworkDef::resolve(&m, "glow16").unwrap();
        // 2 scales x (haar + 4x3 glow steps) = 2 + 24 layers; 1 split
        assert_eq!(def.depth(), 26);
        assert_eq!(def.steps.len(), 27);
    }
}
