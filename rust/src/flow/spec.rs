//! Resolved network definition: the ordered steps the coordinator replays.
//!
//! A step is either an AOT artifact layer (executed via the runtime) or a
//! coordinator-native `split` (multiscale factor-out — pure host memory
//! movement, see `tensor::ops`).

use anyhow::{bail, Result};

use crate::runtime::{LayerMeta, Manifest, NetworkMeta};

#[derive(Debug, Clone, PartialEq)]
pub enum StepKind {
    /// AOT-compiled layer with the given manifest signature.
    Layer,
    /// Factor-out: first `zc` channels leave as a latent, rest continues.
    Split { zc: usize },
}

#[derive(Debug, Clone)]
pub struct Step {
    pub kind: StepKind,
    /// Manifest signature (layers) or the split marker string.
    pub sig: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
}

/// A network resolved against the manifest.
#[derive(Debug, Clone)]
pub struct NetworkDef {
    pub name: String,
    pub in_shape: Vec<usize>,
    pub cond_shape: Option<Vec<usize>>,
    pub steps: Vec<Step>,
    pub latent_shapes: Vec<Vec<usize>>,
}

/// Parse `split_zc<k>__<HxWx...>` markers emitted by model.py.
fn parse_split(s: &str) -> Option<(usize, Vec<usize>)> {
    let rest = s.strip_prefix("split_zc")?;
    let (zc, shape) = rest.split_once("__")?;
    let zc = zc.parse().ok()?;
    let dims = shape.split('x').map(|d| d.parse().ok()).collect::<Option<Vec<_>>>()?;
    Some((zc, dims))
}

impl NetworkDef {
    pub fn resolve(manifest: &Manifest, name: &str) -> Result<NetworkDef> {
        let net: &NetworkMeta = manifest.network(name)?;
        let mut steps = Vec::with_capacity(net.layers.len());
        let mut cur = net.in_shape.clone();
        for sig in &net.layers {
            if let Some((zc, in_shape)) = parse_split(sig) {
                if in_shape != cur {
                    bail!("{name}: split expects {in_shape:?}, flow is at {cur:?}");
                }
                let mut out = cur.clone();
                let c = *out.last().unwrap();
                if zc == 0 || zc >= c {
                    bail!("{name}: bad split zc={zc} for {c} channels");
                }
                *out.last_mut().unwrap() = c - zc;
                steps.push(Step {
                    kind: StepKind::Split { zc },
                    sig: sig.clone(),
                    in_shape: cur.clone(),
                    out_shape: out.clone(),
                });
                cur = out;
            } else {
                let meta: &LayerMeta = manifest.layer(sig)?;
                if meta.in_shape != cur {
                    bail!("{name}: layer {sig} expects {:?}, flow is at {cur:?}",
                          meta.in_shape);
                }
                steps.push(Step {
                    kind: StepKind::Layer,
                    sig: sig.clone(),
                    in_shape: meta.in_shape.clone(),
                    out_shape: meta.out_shape.clone(),
                });
                cur = meta.out_shape.clone();
            }
        }
        // sanity: latent shapes = splits' z shapes + final shape
        let mut want_latents: Vec<Vec<usize>> = steps.iter()
            .filter_map(|s| match s.kind {
                StepKind::Split { zc } => {
                    let mut z = s.in_shape.clone();
                    *z.last_mut().unwrap() = zc;
                    Some(z)
                }
                _ => None,
            })
            .collect();
        want_latents.push(cur.clone());
        if want_latents != net.latent_shapes {
            bail!("{name}: manifest latents {:?} != derived {:?}",
                  net.latent_shapes, want_latents);
        }
        Ok(NetworkDef {
            name: net.name.clone(),
            in_shape: net.in_shape.clone(),
            cond_shape: net.cond_shape.clone(),
            steps,
            latent_shapes: net.latent_shapes.clone(),
        })
    }

    /// Total number of scalar parameters across all steps.
    pub fn param_count(&self, manifest: &Manifest) -> Result<usize> {
        let mut total = 0;
        for s in &self.steps {
            if s.kind == StepKind::Layer {
                total += manifest.layer(&s.sig)?.param_count();
            }
        }
        Ok(total)
    }

    /// Input elements per sample (bits/dim denominators etc.).
    pub fn dims_per_sample(&self) -> usize {
        self.in_shape.iter().skip(1).product()
    }

    pub fn depth(&self) -> usize {
        self.steps.iter().filter(|s| s.kind == StepKind::Layer).count()
    }

    pub fn find_latent_for(&self, split_idx: usize) -> Option<&Vec<usize>> {
        self.latent_shapes.get(split_idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_marker_parses() {
        let (zc, dims) = parse_split("split_zc6__16x8x8x12").unwrap();
        assert_eq!(zc, 6);
        assert_eq!(dims, vec![16, 8, 8, 12]);
        assert!(parse_split("actnorm__2x2").is_none());
        assert!(parse_split("split_zcX__2").is_none());
    }
}
