//! Parameter initialization rules, keyed by parameter name (matching
//! InvertibleNetworks.jl / GLOW conventions):
//!
//! * `w1`, `w2`, `kw` (+ hint node prefixes): Glorot-normal weights
//! * `w3`, `b3`: zeros — the coupling conditioner's final layer is
//!   zero-initialized so every coupling starts near the identity (GLOW)
//! * other `b*`: zeros
//! * `log_s`: zeros (ActNorm starts as identity)
//! * `v1`/`v2`/`v3`: unit-normal Householder vectors (random orthogonal W)

use crate::runtime::TensorSpec;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Base name after any hint-node prefix (`rlt_w1` -> `w1`).
fn base_name(name: &str) -> &str {
    match name.rsplit_once('_') {
        Some((_, tail)) if matches!(
            tail, "w1" | "w2" | "w3" | "b1" | "b2" | "b3") => tail,
        _ => name,
    }
}

fn glorot(shape: &[usize], rng: &mut Pcg64) -> Tensor {
    // conv HWIO: fan_in = prod(all but last), fan_out = last
    let fan_out = *shape.last().unwrap_or(&1);
    let fan_in: usize = shape.iter().rev().skip(1).product::<usize>().max(1);
    let std = (2.0 / (fan_in + fan_out) as f64).sqrt();
    let data = (0..shape.iter().product::<usize>())
        .map(|_| (rng.normal() * std) as f32)
        .collect();
    Tensor { shape: shape.to_vec(), data }
}

/// Initialize one parameter tensor by naming convention.
pub fn init_param(spec: &TensorSpec, rng: &mut Pcg64) -> Tensor {
    let name = spec.name.as_str();
    let base = base_name(name);
    match base {
        "w1" | "w2" | "kw" => glorot(&spec.shape, rng),
        "w3" | "b3" => Tensor::zeros(&spec.shape),
        "log_s" => Tensor::zeros(&spec.shape),
        "b" | "b1" | "b2" => Tensor::zeros(&spec.shape),
        "v1" | "v2" | "v3" => {
            let data = (0..spec.shape.iter().product::<usize>())
                .map(|_| rng.normal_f32())
                .collect();
            Tensor { shape: spec.shape.clone(), data }
        }
        _ => glorot(&spec.shape, rng),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(name: &str, shape: &[usize]) -> TensorSpec {
        TensorSpec { name: name.into(), shape: shape.to_vec() }
    }

    #[test]
    fn zero_init_final_conv() {
        let mut rng = Pcg64::new(0);
        let t = init_param(&spec("w3", &[3, 3, 8, 12]), &mut rng);
        assert!(t.linf() == 0.0);
        let t = init_param(&spec("rlt_w3", &[4, 6]), &mut rng); // hint node
        assert!(t.linf() == 0.0);
        let t = init_param(&spec("b3", &[12]), &mut rng);
        assert!(t.linf() == 0.0);
    }

    #[test]
    fn glorot_scale_reasonable() {
        let mut rng = Pcg64::new(1);
        let t = init_param(&spec("w1", &[3, 3, 16, 32]), &mut rng);
        let std = (t.data.iter().map(|x| x * x).sum::<f32>()
            / t.len() as f32).sqrt();
        let want = (2.0f32 / (3.0 * 3.0 * 16.0 + 32.0)).sqrt();
        assert!((std - want).abs() / want < 0.2, "std={std} want={want}");
    }

    #[test]
    fn householder_vectors_random() {
        let mut rng = Pcg64::new(2);
        let t = init_param(&spec("v1", &[8]), &mut rng);
        assert!(t.l2() > 0.5);
    }

    #[test]
    fn hint_prefixes_resolve() {
        assert_eq!(base_name("rlt_w1"), "w1");
        assert_eq!(base_name("r_b2"), "b2");
        assert_eq!(base_name("log_s"), "log_s");
        assert_eq!(base_name("kw"), "kw");
    }
}
