//! Flow graphs: network definitions resolved from the manifest, parameter
//! stores and initialization.

pub mod init;
pub mod params;
pub mod spec;

pub use params::ParamStore;
pub use spec::{NetworkDef, Step, StepKind};
