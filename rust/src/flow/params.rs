//! Per-step parameter store.
//!
//! Layer signatures repeat within a network (e.g. 48 GLOW steps share one
//! set of layer metadata), but every step owns its own parameters, so the
//! store is indexed by step position. The store is plain host data — any
//! backend-specific upload/caching is the backend's concern, which keeps
//! this type free of execution-substrate types.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::runtime::Manifest;
use crate::tensor::{npy, Tensor};
use crate::util::json::Json;
use crate::util::rng::Pcg64;

use super::init::init_param;
use super::spec::{NetworkDef, StepKind};

#[derive(Debug, Clone)]
pub struct ParamStore {
    /// `tensors[step_idx][param_idx]`; empty vec for split / param-free steps.
    pub tensors: Vec<Vec<Tensor>>,
    /// Parameter names aligned with `tensors` (for checkpoints/debug).
    pub names: Vec<Vec<String>>,
}

impl ParamStore {
    /// Random-initialize parameters for `def` (see `flow::init` rules).
    pub fn init(def: &NetworkDef, manifest: &Manifest, seed: u64) -> Result<ParamStore> {
        let mut rng = Pcg64::new(seed);
        let mut tensors = Vec::with_capacity(def.steps.len());
        let mut names = Vec::with_capacity(def.steps.len());
        for step in &def.steps {
            if step.kind != StepKind::Layer {
                tensors.push(Vec::new());
                names.push(Vec::new());
                continue;
            }
            let meta = manifest.layer(&step.sig)?;
            let mut ts = Vec::with_capacity(meta.params.len());
            let mut ns = Vec::with_capacity(meta.params.len());
            for spec in &meta.params {
                ts.push(init_param(spec, &mut rng));
                ns.push(spec.name.clone());
            }
            tensors.push(ts);
            names.push(ns);
        }
        Ok(ParamStore { tensors, names })
    }

    pub fn num_steps(&self) -> usize {
        self.tensors.len()
    }

    pub fn param_count(&self) -> usize {
        self.tensors.iter().flatten().map(|t| t.len()).sum()
    }

    pub fn size_bytes(&self) -> usize {
        self.tensors.iter().flatten().map(|t| t.size_bytes()).sum()
    }

    /// The parameter tensors of one step.
    pub fn step(&self, step_idx: usize) -> &[Tensor] {
        &self.tensors[step_idx]
    }

    // ---- checkpointing -----------------------------------------------------

    /// Save as a directory of .npy files + index.json.
    pub fn save(&self, dir: &Path, net_name: &str) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut index = Vec::new();
        for (si, (ts, ns)) in self.tensors.iter().zip(&self.names).enumerate() {
            for (t, n) in ts.iter().zip(ns) {
                let fname = format!("s{si:03}_{n}.npy");
                npy::save(&dir.join(&fname), t)?;
                index.push(Json::obj(vec![
                    ("step", Json::Num(si as f64)),
                    ("name", Json::Str(n.clone())),
                    ("file", Json::Str(fname)),
                    ("shape", Json::arr_usize(&t.shape)),
                ]));
            }
        }
        let meta = Json::obj(vec![
            ("network", Json::Str(net_name.to_string())),
            ("params", Json::Arr(index)),
        ]);
        std::fs::write(dir.join("index.json"), meta.to_string_pretty())?;
        Ok(())
    }

    /// Load a checkpoint saved by [`ParamStore::save`]; shapes are validated
    /// against the current store layout.
    pub fn load(&mut self, dir: &Path) -> Result<()> {
        let text = std::fs::read_to_string(dir.join("index.json"))
            .with_context(|| format!("reading checkpoint {dir:?}"))?;
        let meta = Json::parse(&text)?;
        for p in meta.req("params")?.as_arr()? {
            let si = p.req("step")?.as_usize()?;
            let name = p.req("name")?.as_str()?;
            let file = p.req("file")?.as_str()?;
            let t = npy::load(&dir.join(file))?;
            let Some(pi) = self.names.get(si).and_then(
                |ns| ns.iter().position(|n| n == name)) else {
                bail!("checkpoint has unknown param step={si} name={name}");
            };
            if self.tensors[si][pi].shape != t.shape {
                bail!("checkpoint shape mismatch for s{si}/{name}: \
                       {:?} vs {:?}", self.tensors[si][pi].shape, t.shape);
            }
            self.tensors[si][pi] = t;
        }
        Ok(())
    }
}
