//! CLI dispatch for the `invertnet` binary (kept in the library so the
//! command paths are integration-testable).
//!
//! ```text
//! invertnet train   --net realnvp2d --data two-moons --steps 500
//!                   [--mode invertible|stored|checkpoint:K|auto[:BUDGET]]
//!                   [--threads N] [--microbatch N]
//! invertnet sample  --net realnvp2d --ckpt runs/x/checkpoint --out samples.npy
//! invertnet serve   --ckpt runs/x/checkpoint [--log-json F] [--slow-ms MS]
//! invertnet top     [--url http://127.0.0.1:7878/metrics] [--once]
//! invertnet bench   --suite quick --check --baseline baselines/quick.json
//! invertnet bench   fig1|fig2   [--budget-gb 40]
//! invertnet inspect --net glow16
//! invertnet profile --net glow16 [--iters 5] [--json]
//! invertnet lint    [--net NAME | --all | --ckpt DIR] [--json] [--check]
//! invertnet metrics [FILE]
//! invertnet list
//! ```
//!
//! Exit codes are uniform across the `--check` verbs: 0 = pass, 1 =
//! check/runtime failure, 2 = usage error (see [`exit_code`]).
//!
//! Every subcommand accepts `--backend ref|xla` (default `ref`: the
//! artifact-free pure-Rust backend over the builtin catalog) and
//! `--artifacts DIR` (load a manifest produced by `python -m compile.aot`;
//! required for `--backend xla`).

use std::fmt;
use std::net::TcpListener;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use crate::api::Engine;
use crate::backend::RefBackend;
use crate::coordinator::{ActivationSchedule, CheckpointEveryK, ExecMode,
                         InferOpts, MemoryLedger, SampleOpts};
use crate::data::{synth_images, Density2d, LinearGaussian};
use crate::posterior::analysis::{self, chi2_crit};
use crate::posterior::{amortized_train, calibrate, posterior_samples,
                       summarize, PosteriorTrainConfig, Simulator};
use crate::flow::NetworkDef;
use crate::runtime::{builtin_manifest, parse_split, Manifest};
use crate::serve::{BatchConfig, Registry, Server};
use crate::tensor::npy;
use crate::tensor::ops::concat_rows;
use crate::train::{bits_per_dim, train, Adam, GradClip, TrainConfig};
use crate::util::bench::fmt_bytes;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::Tensor;

pub const USAGE: &str = "\
invertnet — memory-frugal normalizing flows (InvertibleNetworks.jl reproduction)

USAGE:
  invertnet train   --net NAME [--data two-moons|eight-gaussians|checkerboard|spiral|images|linear-gaussian]
                    [--steps N] [--lr F] [--mode invertible|stored|checkpoint:K|auto[:BUDGET]] [--seed N]
                    [--threads N] [--microbatch N] [--out DIR] [--clip F] [--log-every N] [--quiet]
                    [--eval-every N] [--eval-batches B] [--metrics-out FILE] [--trace FILE]
                    [--log-json FILE|stderr] [--slow-ms MS]
  invertnet sample  --net NAME [--ckpt DIR] [--out FILE.npy] [--batches N] [--seed N]
                    [--temperature F]
  invertnet posterior-train
                    --sim linear-gaussian|denoise|deblur|inpaint [--net NAME]
                    [--steps N] [--lr F] [--seed N] [--out DIR] [--eval-every N]
                    [--eval-batches B] [--threads N] [--microbatch N] [--mode M]
                    [--clip F] [--log-every N] [--quiet] [--metrics-out FILE]
                    [--trace FILE]
  invertnet posterior-sample
                    --ckpt DIR --y V1,V2,... | --y-file FILE.npy
                    [--n N] [--temperature F] [--seed N] [--level F]
                    [--out FILE.npy] [--net NAME] [--allow-untrained]
  invertnet calibrate
                    --ckpt DIR --sim NAME [--datasets N] [--draws N] [--bins N]
                    [--level F] [--alpha F] [--tol F] [--seed N] [--check]
                    [--net NAME] [--allow-untrained]
  invertnet serve   --ckpt DIR | --net NAME --allow-untrained
                    [--port P | --stdio] [--max-batch N] [--max-delay-us U]
                    [--workers N] [--queue-cap N] [--models N] [--root DIR]
                    [--log-json FILE|stderr] [--slow-ms MS]
  invertnet top     [--url http://HOST:PORT/metrics | --file FILE.prom]
                    [--interval SECS] [--once]
  invertnet score   --ckpt DIR --data FILE.npy [--out FILE.npy] [--cond FILE.npy]
                    [--net NAME] [--allow-untrained] [--seed N]
  invertnet bench   --suite all|quick|memory|throughput|serve|posterior
                    [--out FILE|DIR] [--baseline FILE|DIR] [--check] [--tol PCT]
                    [--metrics-out FILE]
  invertnet bench   fig1|fig2 [--budget-gb F]
  invertnet inspect --net NAME
  invertnet profile --net NAME [--iters N] [--json]
  invertnet lint    [--net NAME | --all | --ckpt DIR] [--json] [--check]
                    [--checkpoint K]
  invertnet metrics [FILE]
  invertnet list

AMORTIZED POSTERIOR INFERENCE:
  --sim NAME          synthetic inverse problem streaming (x, y) training
                      pairs: linear-gaussian (closed-form oracle), denoise,
                      deblur, inpaint (over 4x4 textured-blob fields);
                      each has a matching builtin conditional net
                      (cond_lingauss2d, cond_denoise16, ...)
  --eval-every N      score a held-out eval split every N steps; the mean
                      NLL lands in metrics.csv as eval_nll (default 50;
                      0 disables — note the split consumes --eval-batches
                      draws from the data stream before training starts)
  --eval-batches B    eval-split size, in canonical batches (default 1;
                      0 disables the eval split)
  --y V1,V2,...       one observation row for posterior-sample (or
                      --y-file FILE.npy with a single row)
  --datasets/--draws  SBC datasets and posterior draws per dataset for
                      calibrate (defaults 128 / 63)
  --check             make calibrate exit non-zero when the SBC chi-square
                      rejects at --alpha or coverage misses --level by
                      more than --tol

SERVING (see README for the JSON-lines protocol):
  --ckpt DIR          checkpoint directory written by `train --out` (DIR is
                      the `.../checkpoint` folder); its index.json names the
                      network, so --net is optional
  --stdio             answer JSON-lines requests on stdin/stdout (tests, CI)
  --port P            JSON-lines loopback TCP listener (default: 7878)
  --max-batch N       max requests coalesced into one batched pass (default 8)
  --max-delay-us U    coalescing window for the oldest queued request
                      (default 500)
  --workers N         batched-pass executor threads (default 2)
  --root DIR          lazily load models from DIR/<name>[/checkpoint] on
                      first request for <name>
  --allow-untrained   serve/score randomly initialized weights (loudly)
  --slow-ms MS        emit a slow_request event for any request whose
                      end-to-end handling exceeds MS milliseconds
  requests may carry \"trace_id\" (echoed verbatim on the reply; assigned
  srv-N otherwise) and \"timing\": true (per-phase microseconds on the
  reply); {\"op\":\"debug-dump\"} returns the flight-recorder ring; the TCP
  front also answers GET /healthz (liveness) and GET /readyz (readiness)

BENCH SUITES (see BENCHMARKS.md for the schema and baseline procedure):
  --suite NAME        quick (CI-sized union of all suites), memory,
                      throughput, serve, posterior, or all (every full
                      suite as its own report)
  --out FILE|DIR      where BENCH_<suite>.json lands (DIR => DIR/<suite>.json,
                      the committed-baseline layout under baselines/)
  --baseline F|DIR    compare gated (deterministic) metrics against a
                      committed baseline; with --check, exit non-zero on
                      any regression beyond --tol percent (default 5)

STATIC ANALYSIS (no execution — see README \"Static guarantees\"):
  lint                verify every network in the manifest without running
                      it: shape/width propagation, split/concat bookkeeping,
                      squeeze factors, conditional wiring, invertibility of
                      the composed chain, and numeric-range interval lints
                      (exp-overflow, actnorm-degenerate-scale,
                      logdet-underflow); clean networks also report the
                      planner's predicted peak bytes AND the cost model's
                      predicted train/inference flops per schedule
  --net NAME | --all  one network, or the whole catalog (default: all)
  --ckpt DIR          lint the checkpoint's network plus its index.json
                      contents (shapes/params vs the spec) in one shot
  --json              machine-readable report on stdout (invertnet-lint/v2,
                      with a per-network \"cost\" block)
  --check             exit 1 if any error-severity diagnostic fires
  --checkpoint K      also audit checkpoint-every-K against each depth

OBSERVABILITY (see README \"Observability\" for the metric catalog):
  --metrics-out FILE  (train / posterior-train / bench) on exit, write the
                      process metrics registry as Prometheus text exposition
  --trace FILE        (train / posterior-train) export span timings as a
                      Chrome trace_event JSON — open in chrome://tracing
                      or Perfetto; finalized (strictly valid JSON) on
                      every exit path, including check failures
  --log-json T        (train / posterior-train / serve) structured event
                      log (invertnet-event/v1 JSON lines) to T = a file
                      path or the literal \"stderr\"; rate-limited per
                      event kind, errors always written
  --slow-ms MS        (train: slow steps / serve: slow requests) emit a
                      warn event when a step/request exceeds MS ms
  metrics [FILE]      no FILE: dump this process's live registry; with
                      FILE: validate a --metrics-out dump and summarize
                      its families (exit 1 on malformed exposition)
  profile --json      machine-readable invertnet-profile/v1 report with
                      histogram-derived p50/p99 per (layer, entry)
  serve               answers {\"op\":\"metrics\"} with the exposition text
                      on the JSON-lines protocol, and plain-HTTP
                      `GET /metrics` + /healthz + /readyz on the TCP
                      listener; {\"op\":\"debug-dump\"} returns the last
                      256 events as an invertnet-dump/v1 report
  top                 live operator dashboard over the /metrics scrape
                      (or a --metrics-out file): QPS, latency quantiles,
                      realized batch size, queue depth, per-model rows;
                      --once prints a single snapshot and exits

  --mode auto[:BUDGET]  (train / posterior-train) pick the cheapest-compute
                      schedule whose statically predicted peak fits BUDGET
                      bytes (suffixes k/m/g; default budget: --mem-budget,
                      else unconstrained => stored). The choice is logged as
                      \"auto schedule: chose ...\" and enforced at runtime by
                      a budgeted ledger.

EXIT CODES (uniform across lint/bench/calibrate --check):
  0  pass             the command ran and every gate passed
  1  check failure    a --check gate tripped, or a runtime error
  2  usage error      bad flags/arguments; nothing was run

COMMON OPTIONS:
  --backend ref|xla   execution backend (default: ref — pure Rust, no artifacts)
  --artifacts DIR     manifest/artifact directory (required for --backend xla)
  --mem-budget BYTES  engine-wide scheduling-memory budget (suffixes k/m/g):
                      the default --mode auto budget, and static admission
                      control in serve — a model whose minimum predicted
                      peak exceeds it is rejected at load, before any
                      allocation
  --threads N         worker threads (default: 1). Training shards
                      minibatches with a deterministic reduction; inference
                      (sample/score/serve/posterior-sample) chunks large
                      batches across the same pool — both bit-identical to
                      the single-threaded run
  --kernel-threads N  intra-kernel fan-out (default: 1): the vectorized
                      GEMM/conv kernels split output rows across N threads
                      inside one layer call. Orthogonal to --threads and
                      bit-identical at any N (fixed accumulation order)
  --weight-dtype D    weight STORAGE precision for inference paths
                      (f32|bf16|f16, default f32): checkpoint weights are
                      rounded through D once at load; compute stays f32.
                      Applies to sample/score/serve/posterior-sample, not
                      training
  --microbatch N      gradient-accumulation shard size (default: batch/threads);
                      smaller values tighten the activation-memory envelope
";

/// A `--check` gate that tripped (or an equivalent pass/fail verdict):
/// the command ran to completion and the answer is "fail". Exit code 1.
#[derive(Debug)]
pub struct CheckFailed(pub String);

impl fmt::Display for CheckFailed {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for CheckFailed {}

/// Bad flags or arguments: nothing was run. Exit code 2, so scripts can
/// tell "the gate failed" (1) from "the invocation was wrong" (2).
#[derive(Debug)]
pub struct UsageError(pub String);

impl fmt::Display for UsageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for UsageError {}

fn check_failed(msg: String) -> anyhow::Error {
    anyhow::Error::new(CheckFailed(msg))
}

fn usage_err(msg: String) -> anyhow::Error {
    anyhow::Error::new(UsageError(msg))
}

/// The process exit code for a [`run`] error: 2 for usage errors, 1 for
/// everything else (check failures and runtime errors alike). The
/// contract is documented under EXIT CODES in [`USAGE`].
pub fn exit_code(err: &anyhow::Error) -> i32 {
    if err.downcast_ref::<UsageError>().is_some() { 2 } else { 1 }
}

/// Parse argv and dispatch. Unknown subcommands are an error; no
/// subcommand prints the usage text.
pub fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("sample") => cmd_sample(&args),
        Some("posterior-train") => cmd_posterior_train(&args),
        Some("posterior-sample") => cmd_posterior_sample(&args),
        Some("calibrate") => cmd_calibrate(&args),
        Some("serve") => cmd_serve(&args),
        Some("score") => cmd_score(&args),
        Some("top") => cmd_top(&args),
        Some("bench") => cmd_bench(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("lint") => cmd_lint(&args),
        Some("profile") => {
            let engine = engine_of(&args)?;
            let net = args.req("net")?;
            let iters = args.usize_or("iters", 5)?;
            if args.flag("json") {
                let doc = crate::profile::profile_network_json(
                    &engine, net, iters)?;
                println!("{}", doc.to_string_pretty());
                Ok(())
            } else {
                crate::profile::profile_network(&engine, net, iters)
            }
        }
        Some("metrics") => cmd_metrics(&args),
        Some("list") => cmd_list(&args),
        Some(other) => {
            eprintln!("{USAGE}");
            Err(usage_err(format!("unknown subcommand {other:?}")))
        }
        None => {
            eprintln!("{USAGE}");
            Ok(())
        }
    }
}

/// Build the engine a subcommand asked for. Every engine-level knob
/// (`--backend`, `--artifacts`, `--threads`, `--kernel-threads`,
/// `--mem-budget`, `--weight-dtype`) funnels through [`EngineBuilder`] —
/// the single configuration front — so `Engine::config()` reports exactly
/// what this invocation was built with.
fn engine_of(args: &Args) -> Result<Engine> {
    let artifacts = args.get("artifacts").map(PathBuf::from);
    let kernel_threads = args.usize_or("kernel-threads", 1)?;
    let mut builder = Engine::builder()
        .threads(args.usize_or("threads", 1)?)
        .kernel_threads(kernel_threads);
    if let Some(dir) = &artifacts {
        builder = builder.artifacts(dir);
    }
    if let Some(spec) = args.get("mem-budget") {
        builder = builder.mem_budget(parse_bytes(spec)?);
    }
    if let Some(spec) = args.get("weight-dtype") {
        let dtype = crate::backend::WeightDtype::parse(spec).ok_or_else(
            || usage_err(format!(
                "unknown --weight-dtype {spec:?} (f32|bf16|f16)")))?;
        builder = builder.weight_dtype(dtype);
    }
    match args.str_or("backend", "ref") {
        "ref" => Ok(builder
            .backend(Arc::new(
                RefBackend::with_kernel_threads(kernel_threads)))
            .build()?),
        "xla" => {
            if artifacts.is_none() {
                bail!("--backend xla requires --artifacts DIR");
            }
            // with artifacts set and no explicit backend, build() selects
            // XlaBackend sharing the loaded manifest (xla feature only)
            xla_engine(builder)
        }
        other => bail!("unknown --backend {other:?} (ref|xla)"),
    }
}

#[cfg(feature = "xla")]
fn xla_engine(builder: crate::api::EngineBuilder) -> Result<Engine> {
    builder.build()
}

#[cfg(not(feature = "xla"))]
fn xla_engine(_builder: crate::api::EngineBuilder) -> Result<Engine> {
    bail!("this build has no xla support; rebuild with --features xla")
}

/// Parse `--mode` into a schedule: `invertible`, `stored`, `checkpoint:K`.
/// `auto` is handled one level up by [`schedule_spec`].
fn schedule_of(args: &Args) -> Result<Arc<dyn ActivationSchedule>> {
    let spec = args.str_or("mode", "invertible");
    if let Some(k) = spec.strip_prefix("checkpoint:") {
        let k: usize = k.parse().map_err(
            |e| usage_err(format!("--mode checkpoint:K — bad K: {e}")))?;
        if k == 0 {
            return Err(usage_err("--mode checkpoint:K needs K >= 1".into()));
        }
        return Ok(Arc::new(CheckpointEveryK(k)));
    }
    match spec {
        "invertible" => Ok(Arc::new(ExecMode::Invertible)),
        "stored" => Ok(Arc::new(ExecMode::Stored)),
        other => Err(usage_err(format!(
            "unknown --mode {other:?} \
             (invertible|stored|checkpoint:K|auto[:BUDGET])"))),
    }
}

/// A byte count with an optional binary-unit suffix: `64m`, `2g`, `900k`,
/// or a plain integer.
fn parse_bytes(s: &str) -> Result<i64> {
    let (digits, mult) = match s.as_bytes().last() {
        Some(b'k' | b'K') => (&s[..s.len() - 1], 1i64 << 10),
        Some(b'm' | b'M') => (&s[..s.len() - 1], 1i64 << 20),
        Some(b'g' | b'G') => (&s[..s.len() - 1], 1i64 << 30),
        _ => (s, 1i64),
    };
    let v: i64 = digits.trim().parse().map_err(
        |e| usage_err(format!("bad byte count {s:?}: {e}")))?;
    if v <= 0 {
        return Err(usage_err(format!(
            "byte count must be positive, got {s:?}")));
    }
    Ok(v.saturating_mul(mult))
}

/// `--mode` parsed one level above [`schedule_of`]: either a fixed
/// schedule, or `auto[:BUDGET]` deferring the choice to the static cost
/// model once the network is known.
enum ScheduleSpec {
    Fixed(Arc<dyn ActivationSchedule>),
    Auto(Option<i64>),
}

fn schedule_spec(args: &Args) -> Result<ScheduleSpec> {
    let spec = args.str_or("mode", "invertible");
    if spec == "auto" {
        return Ok(ScheduleSpec::Auto(None));
    }
    if let Some(b) = spec.strip_prefix("auto:") {
        return Ok(ScheduleSpec::Auto(Some(parse_bytes(b)?)));
    }
    Ok(ScheduleSpec::Fixed(schedule_of(args)?))
}

/// Resolve `--mode` to a `(flow, schedule)` pair. Fixed modes build the
/// flow directly. `auto[:BUDGET]` asks [`choose_schedule`] for the
/// cheapest-compute schedule whose statically predicted peak fits the
/// budget (the engine's `--mem-budget` when no inline budget is given;
/// unconstrained otherwise) and, when a budget is set, attaches a
/// budgeted ledger so the static promise is also enforced at runtime.
///
/// [`choose_schedule`]: crate::analysis::choose_schedule
fn flow_and_schedule(args: &Args, engine: &Engine, net: &str)
    -> Result<(crate::Flow, Arc<dyn ActivationSchedule>)> {
    match schedule_spec(args)? {
        ScheduleSpec::Fixed(s) => Ok((engine.flow(net)?, s)),
        ScheduleSpec::Auto(inline) => {
            let budget = inline.or_else(|| engine.mem_budget());
            let flow = match budget {
                Some(b) => engine.flow_with_ledger(
                    net, MemoryLedger::with_budget(b as u64))?,
                None => engine.flow(net)?,
            };
            let choice = crate::analysis::choose_schedule(
                &flow.def, engine.manifest(), budget)?;
            eprintln!(
                "auto schedule: chose {} (predicted peak {}, train flops \
                 {})",
                choice.label, fmt_bytes(choice.peak_bytes as u64),
                choice.train_flops);
            Ok((flow, choice.schedule))
        }
    }
}

/// `--trace FILE`: start Chrome-trace span export before the workload
/// runs (spans recorded before this point are counted but not traced).
fn trace_setup(args: &Args) -> Result<()> {
    if let Some(path) = args.get("trace") {
        crate::telemetry::enable_trace(Path::new(path))?;
        eprintln!("span trace -> {path} (chrome://tracing format)");
    }
    Ok(())
}

/// After the workload: finalize the span trace (if `--trace` was given;
/// idempotent, so the unconditional hook in `main.rs` covering error
/// exits is free to run it again) and dump the global metrics registry
/// (if `--metrics-out FILE` was given) as Prometheus text exposition.
fn telemetry_finish(args: &Args) -> Result<()> {
    if args.get("trace").is_some() {
        crate::telemetry::finish_trace();
    }
    if let Some(path) = args.get("metrics-out") {
        crate::telemetry::write_metrics_file(Path::new(path))?;
        eprintln!("metrics -> {path}");
    }
    Ok(())
}

/// `--log-json FILE|stderr`: route the structured event stream
/// (invertnet-event/v1 JSON lines) before the workload runs.
fn events_setup(args: &Args) -> Result<()> {
    if let Some(target) = args.get("log-json") {
        crate::telemetry::events::configure(target)?;
        eprintln!("event log -> {target} (invertnet-event/v1)");
    }
    Ok(())
}

/// `invertnet metrics [FILE]` — the operator-side exposition tool. Bare:
/// dump this process's live registry (mostly a debugging aid — a fresh
/// process has only just-registered series). With FILE: strictly parse a
/// dump written by `--metrics-out` and summarize its families, failing
/// (exit 1) on malformed exposition so CI can gate on it.
fn cmd_metrics(args: &Args) -> Result<()> {
    match args.subcommand.get(1) {
        None => {
            print!("{}", crate::telemetry::render_global());
            Ok(())
        }
        Some(path) => {
            let text = std::fs::read_to_string(path)
                .with_context(|| format!("reading {path}"))?;
            let families = crate::telemetry::encode::parse_exposition(&text)
                .map_err(|e| check_failed(format!(
                    "{path}: invalid exposition: {e:#}")))?;
            println!("{:<48} {:>10} {:>8}", "family", "kind", "samples");
            for f in &families {
                println!("{:<48} {:>10} {:>8}", f.name, f.kind, f.samples);
            }
            println!("metrics: {path} OK ({} families)", families.len());
            Ok(())
        }
    }
}

/// `--microbatch N` (0 / absent = one shard per worker).
fn microbatch_of(args: &Args) -> Result<Option<usize>> {
    Ok(match args.usize_or("microbatch", 0)? {
        0 => None,
        mb => Some(mb),
    })
}

/// "  eval_nll X (Y b/d)" suffix for the final training summary line.
fn eval_note(report: &crate::train::TrainReport, dims: usize) -> String {
    match report.eval_nll {
        Some(nll) => format!("  eval_nll {nll:.4} ({:.3} b/d)",
                             bits_per_dim(nll, dims)),
        None => String::new(),
    }
}

/// Pick a sensible default data source for a network's input shape.
fn default_data(in_shape: &[usize], cond: bool) -> &'static str {
    if cond {
        "linear-gaussian"
    } else if in_shape.len() == 2 {
        "two-moons"
    } else {
        "images"
    }
}

/// Build the batch closure for a (network, data source) pair.
#[allow(clippy::type_complexity)]
fn batcher(
    data: &str,
    in_shape: Vec<usize>,
    cond: bool,
    seed: u64,
) -> Result<Box<dyn FnMut(usize) -> Result<(Tensor, Option<Tensor>)>>> {
    let mut rng = Pcg64::new(seed ^ 0xda7a);
    match data {
        "images" => {
            if in_shape.len() != 4 {
                bail!("--data images needs an image network");
            }
            Ok(Box::new(move |_| {
                let (n, h, w, c) = (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                Ok((synth_images(n, h, w, c, &mut rng), None))
            }))
        }
        "linear-gaussian" => {
            if !cond {
                bail!("--data linear-gaussian needs a conditional network");
            }
            let prob = LinearGaussian::default_problem();
            let n = in_shape[0];
            Ok(Box::new(move |_| {
                let (theta, y) = prob.sample(n, &mut rng);
                Ok((theta, Some(y)))
            }))
        }
        name => {
            let d = Density2d::parse(name)?;
            if in_shape.len() != 2 || cond {
                bail!("--data {name} needs an unconditional dense network");
            }
            let n = in_shape[0];
            Ok(Box::new(move |_| Ok((d.sample(n, &mut rng), None))))
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let net = args.req("net")?;
    let engine = engine_of(args)?;
    let (flow, schedule) = flow_and_schedule(args, &engine, net)?;
    let seed = args.u64_or("seed", 42)?;
    let mut params = flow.init_params(seed)?;
    let mut opt = Adam::new(args.f64_or("lr", 1e-3)? as f32);

    let cond = flow.def.cond_shape.is_some();
    let data = args
        .get("data")
        .unwrap_or(default_data(&flow.def.in_shape, cond));
    let mut next = batcher(data, flow.def.in_shape.clone(), cond, seed)?;

    let microbatch = microbatch_of(args)?;
    // hold out an eval split up front (drawn from the same stream, before
    // any training batch) so metrics.csv carries the eval_nll signal
    let eval_every = args.usize_or("eval-every", 50)?;
    let eval_batches = args.usize_or("eval-batches", 1)?;
    let eval_set = if eval_every > 0 && eval_batches > 0 {
        let mut xs = Vec::with_capacity(eval_batches);
        let mut cs = Vec::with_capacity(eval_batches);
        for _ in 0..eval_batches {
            let (x, c) = next(0)?;
            xs.push(x);
            if let Some(c) = c {
                cs.push(c);
            }
        }
        let x = concat_rows(&xs.iter().collect::<Vec<_>>())?;
        let c = if cs.is_empty() {
            None
        } else {
            Some(concat_rows(&cs.iter().collect::<Vec<_>>())?)
        };
        Some((x, c))
    } else {
        None
    };
    let cfg = TrainConfig {
        steps: args.usize_or("steps", 200)?,
        schedule,
        clip: Some(GradClip { max_norm: args.f64_or("clip", 50.0)? as f32 }),
        log_every: args.usize_or("log-every", 10)?,
        out_dir: args.get("out").map(PathBuf::from),
        quiet: args.flag("quiet"),
        threads: engine.default_threads(),
        microbatch,
        eval_set,
        eval_every,
        slow_step_ms: args.get("slow-ms")
            .map(|_| args.u64_or("slow-ms", 0)).transpose()?,
    };

    eprintln!(
        "training {net} ({} params, depth {}, schedule {}, backend {}, \
         threads {}) on {data}",
        params.param_count(),
        flow.def.depth(),
        cfg.schedule.label(),
        flow.backend_name(),
        cfg.threads,
    );
    trace_setup(args)?;
    events_setup(args)?;
    let report = train(&flow, &mut params, &mut opt, &cfg, next)?;
    println!(
        "final_loss {:.4}{}  peak_sched {}  {:.2} steps/s",
        report.final_loss,
        eval_note(&report, flow.def.dims_per_sample()),
        fmt_bytes(report.peak_sched_bytes as u64),
        report.steps_per_sec
    );
    telemetry_finish(args)
}

fn cmd_sample(args: &Args) -> Result<()> {
    let net = args.req("net")?;
    let engine = engine_of(args)?;
    let flow = engine.flow(net)?;
    let seed = args.u64_or("seed", 42)?;
    let mut params = flow.init_params(seed)?;
    match args.get("ckpt") {
        Some(ckpt) => params.load(Path::new(ckpt))?,
        None => eprintln!(
            "WARNING: no --ckpt given — sampling from UNTRAINED (randomly \
             initialized, seed {seed}) weights; pass --ckpt DIR for samples \
             from a trained model"),
    }
    engine.load_weights(&mut params);
    if flow.def.cond_shape.is_some() {
        bail!("use `invertnet serve` (cond-carrying sample requests) or the \
               amortized_inference example for conditional sampling");
    }
    let temperature = args.f64_or("temperature", 1.0)? as f32;
    let mut rng = Pcg64::new(seed ^ 0x5a3d1e);
    let batches = args.usize_or("batches", 1)?;
    let mut all: Vec<f32> = Vec::new();
    let mut shape = flow.def.in_shape.clone();
    for _ in 0..batches {
        let x = flow.sample(&params, SampleOpts::new(flow.batch(), &mut rng)
                                         .temperature(temperature))?;
        all.extend_from_slice(&x.data);
    }
    shape[0] *= batches;
    let out = args.str_or("out", "samples.npy");
    npy::save(Path::new(out), &Tensor::new(shape, all)?)?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_posterior_train(args: &Args) -> Result<()> {
    let engine = engine_of(args)?;
    let sim = Simulator::parse(args.str_or("sim", "linear-gaussian"))?;
    let net = args.get("net").unwrap_or_else(|| sim.default_net());
    let (flow, schedule) = flow_and_schedule(args, &engine, net)?;
    let seed = args.u64_or("seed", 42)?;
    let mut params = flow.init_params(seed)?;
    let microbatch = microbatch_of(args)?;
    let cfg = PosteriorTrainConfig {
        steps: args.usize_or("steps", 500)?,
        lr: args.f64_or("lr", 3e-3)? as f32,
        seed,
        eval_every: args.usize_or("eval-every", 50)?,
        eval_batches: args.usize_or("eval-batches", 1)?,
        schedule,
        clip: Some(GradClip { max_norm: args.f64_or("clip", 50.0)? as f32 }),
        log_every: args.usize_or("log-every", 50)?,
        out_dir: args.get("out").map(PathBuf::from),
        quiet: args.flag("quiet"),
        threads: engine.default_threads(),
        microbatch,
    };
    eprintln!(
        "amortized posterior training: {net} ({} params) on simulator {} \
         (x dim {}, y dim {}), {} steps, backend {}",
        params.param_count(), sim.name(), sim.x_dim(), sim.y_dim(),
        cfg.steps, flow.backend_name());
    trace_setup(args)?;
    events_setup(args)?;
    let report = amortized_train(&flow, &mut params, &sim, &cfg)?;
    println!("final_loss {:.4}{}  {:.2} steps/s",
             report.final_loss,
             eval_note(&report, flow.def.dims_per_sample()),
             report.steps_per_sec);
    telemetry_finish(args)
}

/// Parse the observation row: `--y v1,v2,...` or `--y-file FILE.npy`
/// (flattened; a (1, d) file and a (d,) file both work).
fn observation_of(args: &Args) -> Result<Vec<f32>> {
    // same contract as the serve protocol's posterior op: a non-empty,
    // all-finite observation row (a NaN here would otherwise surface
    // later as a misleading "model diverged" error)
    let finite = |y: Vec<f32>, what: &str| -> Result<Vec<f32>> {
        if y.is_empty() {
            bail!("{what} needs at least one component");
        }
        if let Some(bad) = y.iter().find(|v| !v.is_finite()) {
            bail!("{what} must be finite, got {bad}");
        }
        Ok(y)
    };
    if let Some(spec) = args.get("y") {
        let y = spec.split(',')
            .map(|v| v.trim().parse::<f32>()
                 .map_err(|e| anyhow!("--y component {v:?}: {e}")))
            .collect::<Result<_>>()?;
        return finite(y, "--y");
    }
    if let Some(path) = args.get("y-file") {
        let t = npy::load(Path::new(path))?;
        if t.batch() != 1 && t.shape.len() > 1 {
            bail!("--y-file {path} holds {} rows; posterior-sample takes \
                   one observation", t.batch());
        }
        return finite(t.data, "--y-file");
    }
    bail!("posterior-sample needs --y V1,V2,... or --y-file FILE.npy")
}

fn cmd_posterior_sample(args: &Args) -> Result<()> {
    let engine = engine_of(args)?;
    let (flow, params) = serving_weights(args, &engine, "posterior-sample")?;
    if flow.def.cond_shape.is_none() {
        bail!("network {} takes no cond — posterior sampling needs a \
               conditional (amortized) flow", flow.def.name);
    }
    let y = observation_of(args)?;
    let n = args.usize_or("n", 256)?;
    let temperature = args.f64_or("temperature", 1.0)? as f32;
    let seed = args.u64_or("seed", 42)?;
    let level = args.f64_or("level", 0.9)?;

    let samples = posterior_samples(&flow, &params, &y, n, temperature, seed)?;
    let s = summarize(&samples);
    let (lo, hi) = analysis::central_interval(&samples, level)?;

    println!("posterior p(x | y) from {} ({} draws, seed {seed}):",
             flow.def.name, n);
    println!("{:>5} {:>12} {:>12} {:>12} {:>12}",
             "dim", "mean", "std", format!("q{:.1}", 50.0 * (1.0 - level)),
             format!("q{:.1}", 100.0 - 50.0 * (1.0 - level)));
    for d in 0..s.mean.len() {
        println!("{d:>5} {:>12.5} {:>12.5} {:>12.5} {:>12.5}",
                 s.mean[d], s.std[d], lo[d], hi[d]);
    }
    let out = args.str_or("out", "posterior_samples.npy");
    npy::save(Path::new(out), &samples)?;
    println!("wrote {n} posterior samples -> {out}");
    Ok(())
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let engine = engine_of(args)?;
    let (flow, params) = serving_weights(args, &engine, "calibrate")?;
    let sim = Simulator::parse(args.req("sim")?)?;
    crate::posterior::trainer::check_sim_matches_flow(&sim, &flow)?;

    let datasets = args.usize_or("datasets", 128)?;
    let draws = args.usize_or("draws", 63)?;
    let bins = args.usize_or("bins", 8)?;
    let level = args.f64_or("level", 0.9)?;
    let alpha = args.f64_or("alpha", 1e-3)?;
    if !(alpha > 0.0 && alpha < 1.0) {
        return Err(usage_err(format!(
            "--alpha must be in (0, 1), got {alpha}")));
    }
    let tol = args.f64_or("tol", 0.1)?;
    let seed = args.u64_or("seed", 42)?;

    let mut rng = Pcg64::new(seed ^ 0xca11_b7a7);
    let cal = calibrate(&sim, datasets, draws, level, bins, &mut rng,
                        |y, l, r| {
        let cond = analysis::tile_observation(y, l)?;
        flow.sample(&params, SampleOpts::new(l, r).cond(&cond))
    })?;

    let crit = chi2_crit(cal.df(), alpha);
    println!("calibration of {} on {} ({datasets} datasets x {draws} \
              draws, {bins} bins):", flow.def.name, sim.name());
    println!("{:>5} {:>12} {:>12} {:>12} {:>10}",
             "dim", "sbc_chi2", format!("crit@{alpha}"), "coverage",
             format!("target{level}"));
    let mut ok = true;
    for d in 0..cal.chi2.len() {
        let pass = cal.chi2[d] <= crit
            && (cal.coverage[d] - level).abs() <= tol;
        ok &= pass;
        println!("{d:>5} {:>12.3} {:>12.3} {:>12.3} {:>10}",
                 cal.chi2[d], crit, cal.coverage[d],
                 if pass { "ok" } else { "MISS" });
    }
    // machine-readable line for CI
    println!(
        "CALIB {{\"sim\":\"{}\",\"net\":\"{}\",\"worst_chi2\":{:.4},\
         \"chi2_crit\":{:.4},\"worst_coverage_gap\":{:.4},\"tol\":{tol},\
         \"pass\":{ok}}}",
        sim.name(), flow.def.name, cal.worst_chi2(), crit,
        cal.worst_coverage_gap());
    if args.flag("check") && !ok {
        return Err(check_failed(format!(
            "calibration check failed: worst chi2 {:.3} (crit {crit:.3}), \
             worst coverage gap {:.3} (tol {tol})",
            cal.worst_chi2(), cal.worst_coverage_gap())));
    }
    Ok(())
}

/// Load (flow, params) for the serve/score paths: from `--ckpt`, or — only
/// with `--allow-untrained` — a loud random init of `--net`.
fn serving_weights(args: &Args, engine: &Engine, what: &str)
                   -> Result<(crate::Flow, crate::flow::ParamStore)> {
    match args.get("ckpt") {
        Some(dir) => {
            let (flow, params) =
                Registry::load_checkpoint(engine, Path::new(dir))?;
            if let Some(net) = args.get("net") {
                if net != flow.def.name {
                    bail!("--net {net:?} does not match checkpoint \
                           network {:?}", flow.def.name);
                }
            }
            Ok((flow, params))
        }
        None => {
            if !args.flag("allow-untrained") {
                bail!("{what} needs --ckpt DIR (a checkpoint written by \
                       `train --out`); to {what} from an untrained random \
                       init anyway, pass --net NAME --allow-untrained");
            }
            let net = args.req("net")?;
            let seed = args.u64_or("seed", 42)?;
            eprintln!(
                "WARNING: {what} running on UNTRAINED (randomly \
                 initialized, seed {seed}) weights for {net}");
            let flow = engine.flow(net)?;
            let params = flow.init_params(seed)?;
            Ok((flow, params))
        }
    }
}

fn cmd_serve(args: &Args) -> Result<()> {
    let engine = engine_of(args)?;
    let cap = args.usize_or("models", 8)?;
    let registry = match args.get("root") {
        Some(root) => Registry::with_root(engine, cap, root),
        None => Registry::new(engine, cap),
    };
    let allow_untrained = args.flag("allow-untrained");

    // warm the registry at startup
    match args.get("ckpt") {
        Some(dir) => {
            let m = registry.register_checkpoint(Path::new(dir))?;
            if let Some(net) = args.get("net") {
                if net != m.name {
                    bail!("--net {net:?} does not match checkpoint \
                           network {:?}", m.name);
                }
            }
            eprintln!("serving {} from {dir}", m.name);
        }
        None => {
            if let Some(net) = args.get("net") {
                if !allow_untrained {
                    bail!("refusing to serve untrained weights for {net}; \
                           pass --ckpt DIR, or add --allow-untrained");
                }
                let seed = args.u64_or("seed", 42)?;
                eprintln!(
                    "WARNING: serving UNTRAINED (randomly initialized, \
                     seed {seed}) weights for {net}");
                registry.register_untrained(net, seed)?;
            } else if args.get("root").is_none() {
                bail!("serve needs --ckpt DIR, --net NAME, or --root DIR");
            }
        }
    }

    let cfg = BatchConfig {
        max_batch: args.usize_or("max-batch", 8)?,
        max_delay: Duration::from_micros(args.u64_or("max-delay-us", 500)?),
        workers: args.usize_or("workers", 2)?,
        queue_cap: args.usize_or("queue-cap", 1024)?,
    };
    eprintln!(
        "micro-batching: max-batch {}, max-delay {}us, {} workers",
        cfg.max_batch, cfg.max_delay.as_micros(), cfg.workers);
    events_setup(args)?;
    let mut server = Server::new(registry, cfg);
    if allow_untrained {
        server = server.allow_untrained();
    }
    if let Some(ms) = args.get("slow-ms") {
        let ms: u64 = ms.parse().map_err(
            |e| usage_err(format!("--slow-ms MS — bad MS: {e}")))?;
        server = server.slow_ms(ms);
    }

    if args.flag("stdio") {
        let stdin = std::io::stdin();
        server.serve_stdio(stdin.lock(), std::io::stdout().lock())
    } else {
        let port = args.usize_or("port", 7878)?;
        let port = u16::try_from(port)
            .map_err(|_| anyhow!("--port {port} out of range"))?;
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding 127.0.0.1:{port}"))?;
        eprintln!("listening on 127.0.0.1:{} (JSON lines; send \
                   {{\"op\":\"shutdown\"}} to stop)",
                  listener.local_addr()?.port());
        server.serve_tcp(listener)
    }
}

fn cmd_score(args: &Args) -> Result<()> {
    let engine = engine_of(args)?;
    let (flow, params) = serving_weights(args, &engine, "score")?;
    let x = npy::load(Path::new(args.req("data")?))?;
    if x.shape.len() != flow.def.in_shape.len()
        || x.shape[1..] != flow.def.in_shape[1..]
    {
        bail!("--data shape {:?} does not match network {} per-sample \
               shape {:?}", x.shape, flow.def.name, &flow.def.in_shape[1..]);
    }
    let cond = match args.get("cond") {
        Some(p) => Some(npy::load(Path::new(p))?),
        None => None,
    };
    let n = x.batch();
    if n == 0 {
        bail!("--data has no rows");
    }
    if let Some(c) = &cond {
        if c.batch() != n {
            bail!("--cond has {} rows, --data has {n}", c.batch());
        }
    }

    // log_density chunks through the canonical batch internally (bounding
    // activation memory on arbitrarily large score files) and fans the
    // chunks across the engine's worker pool (`--threads N`) —
    // bit-identical to the sequential walk at any thread count
    let scores = flow.log_density(
        &x, &params, InferOpts::relaxed().cond_opt(cond.as_ref()))?;

    let mean = scores.iter().sum::<f32>() / n as f32;
    let out = args.str_or("out", "scores.npy");
    npy::save(Path::new(out), &Tensor::new(vec![n], scores)?)?;
    println!("scored {n} samples  mean log-density {mean:.4}  -> {out}");
    Ok(())
}

/// One-shot HTTP/1.0 GET against the serve front (one request per
/// connection, no keep-alive — exactly what [`Server::http_scrape`]
/// speaks). Returns the body of a 200 response.
fn http_get(url: &str) -> Result<String> {
    use std::io::{Read, Write};
    let rest = url.strip_prefix("http://").ok_or_else(|| usage_err(
        format!("--url must start with http://, got {url:?}")))?;
    let (host, path) = match rest.find('/') {
        Some(i) => (&rest[..i], &rest[i..]),
        None => (rest, "/metrics"),
    };
    let mut stream = std::net::TcpStream::connect(host)
        .with_context(|| format!("connecting to {host}"))?;
    write!(stream, "GET {path} HTTP/1.0\r\n\r\n")?;
    stream.flush()?;
    let mut resp = String::new();
    stream.read_to_string(&mut resp)
        .with_context(|| format!("reading response from {url}"))?;
    let (head, body) = resp.split_once("\r\n\r\n")
        .ok_or_else(|| anyhow!("malformed HTTP response from {url}"))?;
    let status = head.lines().next().unwrap_or("");
    if !status.contains(" 200 ") {
        bail!("{url} answered {status:?}: {}", body.trim());
    }
    Ok(body.to_string())
}

/// Render one `invertnet top` frame from a parsed exposition. `prev`
/// carries the previous scrape's request counter and its age, turning
/// two snapshots into a QPS rate.
fn top_frame(vals: &std::collections::BTreeMap<String, crate::telemetry::encode::Value>,
             prev: Option<(f64, f64)>) -> String {
    use crate::telemetry::encode::Value;
    use std::fmt::Write as _;
    let num = |name: &str| match vals.get(name) {
        Some(Value::Counter(v)) | Some(Value::Gauge(v)) => *v,
        _ => 0.0,
    };
    let hist = |name: &str| match vals.get(name) {
        Some(Value::Histogram(h)) => Some(h),
        _ => None,
    };
    let requests = num("invertnet_serve_requests_total");
    let batches = num("invertnet_serve_batches_total");
    let errors = num("invertnet_serve_errors_total");
    let depth = num("invertnet_serve_queue_depth");
    let models = num("invertnet_serve_models");
    let qps = match prev {
        Some((prev_requests, dt)) if dt > 0.0 =>
            format!("{:8.1}", (requests - prev_requests).max(0.0) / dt),
        _ => format!("{:>8}", "-"),
    };
    let realized = if batches > 0.0 { requests / batches } else { 0.0 };
    let mut out = String::new();
    let _ = writeln!(
        out,
        "invertnet top  requests {requests:.0}  errors {errors:.0}  \
         qps {qps}  queue {depth:.0}  models {models:.0}  \
         realized_batch {realized:.2}");
    let _ = writeln!(
        out, "{:<34} {:>8} {:>10} {:>10} {:>10}",
        "latency (us)", "count", "p50", "p99", "p99.9");
    for (label, family) in [
        ("sample", "invertnet_serve_sample_latency_us"),
        ("score", "invertnet_serve_score_latency_us"),
        ("phase: queue_wait", "invertnet_serve_phase_queue_wait_us"),
        ("phase: batch_assembly", "invertnet_serve_phase_batch_assembly_us"),
        ("phase: execute", "invertnet_serve_phase_execute_us"),
        ("phase: encode", "invertnet_serve_phase_encode_us"),
    ] {
        if let Some(h) = hist(family) {
            let _ = writeln!(
                out, "{label:<34} {:>8.0} {:>10.0} {:>10.0} {:>10.0}",
                h.count, h.quantile(0.5), h.quantile(0.99),
                h.quantile(0.999));
        }
    }
    // per-model rows come from the labeled counter series
    let model_prefix = "invertnet_serve_model_requests_total{model=\"";
    let mut wrote_header = false;
    for (series, value) in vals.range::<str, _>((
        std::ops::Bound::Included(model_prefix),
        std::ops::Bound::Unbounded,
    )) {
        let Some(rest) = series.strip_prefix(model_prefix) else { break };
        let Some(model) = rest.strip_suffix("\"}") else { continue };
        if !wrote_header {
            let _ = writeln!(out, "{:<34} {:>8} {:>10}",
                             "model", "requests", "rows");
            wrote_header = true;
        }
        let (Value::Counter(reqs) | Value::Gauge(reqs)) = value else {
            continue;
        };
        let rows = num(&format!(
            "invertnet_serve_model_rows_total{{model=\"{model}\"}}"));
        let _ = writeln!(out, "{model:<34} {reqs:>8.0} {rows:>10.0}");
    }
    out
}

/// `invertnet top` — live operator view over the Prometheus exposition,
/// scraped from a running server (`--url`) or read from a `--metrics-out`
/// style file (`--file`). Default: clear-and-redraw every `--interval`
/// seconds; `--once` prints a single plain snapshot and exits (CI).
fn cmd_top(args: &Args) -> Result<()> {
    let file = args.get("file");
    let url = args.str_or("url", "http://127.0.0.1:7878/metrics");
    if file.is_some() && args.get("url").is_some() {
        return Err(usage_err("pass --url or --file, not both".into()));
    }
    let interval = args.f64_or("interval", 2.0)?;
    if !(interval > 0.0) {
        return Err(usage_err(format!(
            "--interval must be positive, got {interval}")));
    }
    let scrape = || -> Result<String> {
        match file {
            Some(path) => std::fs::read_to_string(path)
                .with_context(|| format!("reading {path}")),
            None => http_get(url),
        }
    };
    let frame = |prev: Option<(f64, f64)>| -> Result<(String, f64)> {
        let text = scrape()?;
        let vals = crate::telemetry::encode::parse_values(&text)
            .map_err(|e| anyhow!("invalid exposition: {e:#}"))?;
        let requests = match vals.get("invertnet_serve_requests_total") {
            Some(crate::telemetry::encode::Value::Counter(v)) => *v,
            _ => 0.0,
        };
        Ok((top_frame(&vals, prev), requests))
    };
    if args.flag("once") {
        let (text, _) = frame(None)?;
        print!("{text}");
        return Ok(());
    }
    let mut prev: Option<(f64, f64)> = None;
    loop {
        let (text, requests) = frame(prev)?;
        // clear screen + home, then the frame (plain ANSI, no deps)
        print!("\x1b[2J\x1b[H{text}");
        use std::io::Write;
        std::io::stdout().flush()?;
        std::thread::sleep(Duration::from_secs_f64(interval));
        prev = Some((requests, interval));
    }
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let engine = engine_of(args)?;
    let flow = engine.flow(args.req("net")?)?;
    print!("{}", flow.inspect()?);
    Ok(())
}

/// The cost model's verdict for one clean, resolved network.
struct NetCosts {
    /// `(schedule label, predicted train-step cost)` per builtin schedule.
    train: Vec<(String, crate::analysis::Cost)>,
    inference: crate::analysis::Cost,
    sample: crate::analysis::Cost,
}

fn net_costs(def: &NetworkDef, manifest: &Manifest) -> Result<NetCosts> {
    Ok(NetCosts {
        train: crate::analysis::schedule_costs(def, manifest)?,
        inference: crate::analysis::inference_cost(def, manifest)?,
        sample: crate::analysis::sample_cost(def, manifest)?,
    })
}

/// One lint report row: a network's diagnostics plus, when it is clean,
/// the planner's peaks and the cost model's flop counts.
struct LintRow {
    name: String,
    diags: Vec<crate::analysis::Diagnostic>,
    peaks: Option<Vec<(String, i64)>>,
    costs: Option<NetCosts>,
}

/// `invertnet lint` — run the static flow verifier (and, for clean
/// networks, the peak planner and cost model) over the manifest WITHOUT
/// building an engine, so malformed manifests produce structured
/// diagnostics instead of a build error. With `--ckpt DIR` the
/// checkpoint's index contents are audited in the same report.
fn cmd_lint(args: &Args) -> Result<()> {
    let manifest: Manifest = match args.get("artifacts") {
        Some(dir) => Manifest::load(Path::new(dir))
            .with_context(|| format!("loading manifest from {dir:?}"))?,
        None => builtin_manifest()?,
    };
    // parse --checkpoint by hand: usize_or would conflate "absent" with
    // K=0, and K=0 must reach the auditor (it is the error case)
    let ckpt_k: Option<usize> = match args.get("checkpoint") {
        Some(s) => Some(s.parse().map_err(
            |e| usage_err(format!("--checkpoint K — bad K: {e}")))?),
        None => None,
    };
    let ckpt_dir = args.get("ckpt").map(PathBuf::from);
    let names: Vec<String> = match (&ckpt_dir, args.get("net"),
                                    args.flag("all")) {
        (Some(dir), net, _) => {
            // checkpoint mode: the index names the network, so lint
            // exactly that one (plus the checkpoint contents below)
            let name = Registry::checkpoint_network_name(dir)?;
            if let Some(n) = net {
                if n != name {
                    return Err(usage_err(format!(
                        "--net {n:?} does not match checkpoint network \
                         {name:?}")));
                }
            }
            if !manifest.networks.contains_key(&name) {
                return Err(usage_err(format!(
                    "checkpoint names unknown network {name:?} (try \
                     `invertnet list`)")));
            }
            vec![name]
        }
        (None, Some(_), true) => {
            return Err(usage_err("pass --net NAME or --all, not both"
                                 .into()));
        }
        (None, Some(n), false) => {
            if !manifest.networks.contains_key(n) {
                return Err(usage_err(format!(
                    "unknown network {n:?} (try `invertnet list`)")));
            }
            vec![n.to_string()]
        }
        _ => manifest.networks.keys().cloned().collect(),
    };

    let mut total_err = 0usize;
    let mut total_warn = 0usize;
    let mut rows: Vec<LintRow> = Vec::new();
    for name in &names {
        let net = manifest.network(name)?;
        let mut diags = crate::analysis::verify_network(&manifest, net);
        if let Some(k) = ckpt_k {
            let depth = net.layers.iter()
                .filter(|s| parse_split(s).is_none()).count();
            diags.extend(crate::analysis::verify_checkpoint_k(depth, k));
        }
        let mut peaks = None;
        let mut costs = None;
        if !crate::analysis::has_errors(&diags) {
            // a verifier-clean network should always resolve; if it does
            // not, the gap is itself a finding, not a CLI crash
            match NetworkDef::resolve(&manifest, name) {
                Ok(def) => {
                    peaks = Some(crate::analysis::schedule_peaks(&def));
                    match net_costs(&def, &manifest) {
                        Ok(c) => costs = Some(c),
                        Err(e) => diags.push(
                            crate::analysis::Diagnostic::error(
                                crate::analysis::codes::SHAPE_MISMATCH,
                                None,
                                format!("cost model failed on a clean \
                                         network: {e:#}"))),
                    }
                    if let Some(dir) = &ckpt_dir {
                        diags.extend(
                            crate::analysis::verify_checkpoint_index(
                                &manifest, &def, dir)?);
                    }
                }
                Err(e) => diags.push(crate::analysis::Diagnostic::error(
                    crate::analysis::codes::SHAPE_MISMATCH, None,
                    format!("verifier passed but resolve failed: {e:#}"))),
            }
        }
        let errs = diags.iter().filter(|d| d.is_error()).count();
        total_err += errs;
        total_warn += diags.len() - errs;
        rows.push(LintRow { name: name.clone(), diags, peaks, costs });
    }

    if args.flag("json") {
        // stdout carries pure JSON in this mode (scripts pipe it)
        let nets: Vec<Json> = rows.iter().map(|row| {
            let ds: Vec<Json> = row.diags.iter().map(|d| Json::obj(vec![
                ("severity", Json::Str(
                    if d.is_error() { "error" } else { "warning" }.into())),
                ("layer_idx", match d.layer_idx {
                    Some(i) => Json::Num(i as f64),
                    None => Json::Null,
                }),
                ("code", Json::Str(d.code.into())),
                ("message", Json::Str(d.message.clone())),
            ])).collect();
            Json::obj(vec![
                ("name", Json::Str(row.name.clone())),
                ("ok", Json::Bool(
                    !crate::analysis::has_errors(&row.diags))),
                ("errors", Json::Num(row.diags.iter()
                    .filter(|d| d.is_error()).count() as f64)),
                ("warnings", Json::Num(row.diags.iter()
                    .filter(|d| !d.is_error()).count() as f64)),
                ("diagnostics", Json::Arr(ds)),
                ("peaks", match &row.peaks {
                    Some(ps) => Json::Obj(ps.iter().map(
                        |(l, b)| (l.clone(), Json::Num(*b as f64))).collect()),
                    None => Json::Null,
                }),
                ("cost", match &row.costs {
                    Some(c) => Json::obj(vec![
                        ("train", Json::Obj(c.train.iter().map(|(l, t)| (
                            l.clone(), Json::obj(vec![
                                ("flops", Json::Num(t.flops as f64)),
                                ("bytes", Json::Num(t.bytes as f64)),
                            ]))).collect())),
                        ("inference_flops",
                         Json::Num(c.inference.flops as f64)),
                        ("sample_flops", Json::Num(c.sample.flops as f64)),
                    ]),
                    None => Json::Null,
                }),
            ])
        }).collect();
        let doc = Json::obj(vec![
            ("schema", Json::Str("invertnet-lint/v2".into())),
            ("backend", Json::Str(manifest.backend.clone())),
            ("networks", Json::Arr(nets)),
            ("errors", Json::Num(total_err as f64)),
            ("warnings", Json::Num(total_warn as f64)),
        ]);
        println!("{}", doc.to_string_pretty());
    } else {
        for row in &rows {
            if row.diags.is_empty() {
                let peaks = row.peaks.as_ref().map(|ps| ps.iter()
                    .map(|(l, b)| format!("{l} {}", fmt_bytes(*b as u64)))
                    .collect::<Vec<_>>().join("  "))
                    .unwrap_or_default();
                println!("{:<24} ok   peak {peaks}", row.name);
                if let Some(c) = &row.costs {
                    let flops = c.train.iter()
                        .map(|(l, t)| format!("{l} {}", t.flops))
                        .collect::<Vec<_>>().join("  ");
                    println!("{:<24}      train flops {flops}  \
                              inference flops {}", "", c.inference.flops);
                }
            } else {
                println!("{:<24} {} diagnostic(s)", row.name,
                         row.diags.len());
                for d in &row.diags {
                    println!("  {d}");
                }
            }
        }
        println!("lint: {} network(s), {total_err} error(s), \
                  {total_warn} warning(s)", rows.len());
    }
    if args.flag("check") && total_err > 0 {
        return Err(check_failed(format!(
            "lint failed: {total_err} error(s) across {} network(s)",
            rows.len())));
    }
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let engine = engine_of(args)?;
    println!("manifest: {}   backend: {}",
             engine.manifest().backend, engine.backend_name());
    println!("{:<24} {:>18} {:>12} {:>7} {:>9}",
             "network", "input", "cond", "depth", "params");
    let names: Vec<String> = engine.manifest().networks.keys().cloned().collect();
    for name in names {
        let flow = engine.flow(&name)?;
        let params = flow.def.param_count(engine.manifest())?;
        let cond = match &flow.def.cond_shape {
            Some(c) => format!("{c:?}"),
            None => "-".to_string(),
        };
        println!(
            "{name:<24} {:>18} {cond:>12} {:>7} {:>9}",
            format!("{:?}", flow.def.in_shape),
            flow.def.depth(),
            params
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// bench — the unified perf harness (suites + regression gate), plus the
// paper's two figures as interactive tables (`bench fig1|fig2`).
// ---------------------------------------------------------------------------

/// Where one suite's JSON lands: `BENCH_<suite>.json` by default; an
/// explicit `--out` names the file directly, unless it is (or must be,
/// because several suites ran) a directory — then `<dir>/<suite>.json`,
/// which is also the committed-baseline layout.
fn bench_out_path(out: Option<&str>, suite: &str, multi: bool) -> PathBuf {
    match out {
        None => PathBuf::from(format!("BENCH_{suite}.json")),
        Some(o) => {
            let p = PathBuf::from(o);
            if multi || o.ends_with('/') || p.is_dir() {
                p.join(format!("{suite}.json"))
            } else {
                p
            }
        }
    }
}

fn cmd_bench(args: &Args) -> Result<()> {
    let engine = engine_of(args)?;
    match args.subcommand.get(1).map(|s| s.as_str()) {
        Some("fig1") => {
            return crate::bench_figs::fig1(&engine,
                                           args.f64_or("budget-gb", 40.0)?);
        }
        Some("fig2") => {
            return crate::bench_figs::fig2(&engine,
                                           args.f64_or("budget-gb", 40.0)?);
        }
        Some(other) => {
            return Err(usage_err(format!(
                "unknown bench target {other:?} (fig1|fig2, or --suite \
                 NAME)")));
        }
        None => {}
    }
    let Some(suite) = args.get("suite") else {
        return Err(usage_err(format!(
            "usage: invertnet bench fig1|fig2  |  invertnet bench \
             --suite {} [--out FILE|DIR] [--baseline FILE|DIR] \
             [--check] [--tol PCT]",
            crate::perf::SUITE_NAMES.join("|"))));
    };
    let tol = args.f64_or("tol", 5.0)?;
    if tol < 0.0 {
        return Err(usage_err(format!("--tol must be >= 0, got {tol}")));
    }
    let baseline = args.get("baseline").map(PathBuf::from);
    if args.flag("check") && baseline.is_none() {
        return Err(usage_err(
            "--check needs --baseline FILE|DIR (e.g. baselines/quick.json)"
                .into()));
    }

    let reports = crate::perf::run_suite(&engine, suite)?;
    let multi = reports.len() > 1;
    let mut regressions = 0usize;
    let mut missing = 0usize;
    for report in &reports {
        report.print();
        let path = bench_out_path(args.get("out"), &report.suite, multi);
        report.write(engine.backend_name(), engine.default_threads(),
                     &path)?;
        if let Some(base) = &baseline {
            let bfile = if base.is_dir() {
                base.join(format!("{}.json", report.suite))
            } else {
                base.clone()
            };
            let b = crate::perf::Baseline::load(&bfile)?;
            let outcome = crate::perf::check_report(report, &b, tol)?;
            println!(
                "# {}: {} gated metric(s) compared, {} bootstrap, \
                 {} missing, {} regression(s) beyond {tol}%",
                report.suite, outcome.compared, outcome.bootstrap,
                outcome.missing.len(), outcome.regressions.len());
            regressions += outcome.regressions.len();
            missing += outcome.missing.len();
        }
    }
    telemetry_finish(args)?;
    if args.flag("check") && (regressions > 0 || missing > 0) {
        return Err(check_failed(format!(
            "perf check failed: {regressions} regression(s) beyond \
             --tol {tol}%, {missing} gated metric(s) missing from the \
             baseline (see CHECK lines above; regenerate baselines \
             after intentional changes)")));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(words: &[&str]) -> Vec<String> {
        words.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn no_subcommand_prints_usage_ok() {
        assert!(run(&argv(&[])).is_ok());
    }

    #[test]
    fn unknown_subcommand_is_an_error() {
        let err = run(&argv(&["frobnicate"])).unwrap_err();
        assert!(err.to_string().contains("unknown subcommand"), "{err:#}");
    }

    #[test]
    fn schedule_parsing() {
        let a = Args::parse(&argv(&["train", "--mode", "stored"])).unwrap();
        assert_eq!(schedule_of(&a).unwrap().label(), "stored");
        let a = Args::parse(&argv(&["train", "--mode", "checkpoint:4"])).unwrap();
        assert_eq!(schedule_of(&a).unwrap().label(), "checkpoint_every_4");
        let a = Args::parse(&argv(&["train"])).unwrap();
        assert_eq!(schedule_of(&a).unwrap().label(), "invertible");
        let a = Args::parse(&argv(&["train", "--mode", "sideways"])).unwrap();
        assert!(schedule_of(&a).is_err());
        let a = Args::parse(&argv(&["train", "--mode", "checkpoint:0"])).unwrap();
        assert!(schedule_of(&a).is_err());
    }

    #[test]
    fn xla_backend_requires_artifacts_flag() {
        let a = Args::parse(&argv(&["list", "--backend", "xla"])).unwrap();
        let err = engine_of(&a).unwrap_err();
        assert!(err.to_string().contains("--artifacts"), "{err:#}");
        let a = Args::parse(&argv(&["list", "--backend", "warp"])).unwrap();
        assert!(engine_of(&a).is_err());
    }

    #[test]
    fn threads_flag_reaches_the_engine() {
        let a = Args::parse(&argv(&["train", "--threads", "3"])).unwrap();
        assert_eq!(engine_of(&a).unwrap().default_threads(), 3);
        // absent flag -> single-threaded default
        let a = Args::parse(&argv(&["train"])).unwrap();
        assert_eq!(engine_of(&a).unwrap().default_threads(), 1);
    }

    #[test]
    fn kernel_threads_and_weight_dtype_reach_the_engine_config() {
        let a = Args::parse(&argv(&["score", "--kernel-threads", "4",
                                    "--weight-dtype", "bf16"])).unwrap();
        let cfg = engine_of(&a).unwrap().config().clone();
        assert_eq!(cfg.kernel_threads, 4);
        assert_eq!(cfg.weight_dtype, crate::backend::WeightDtype::Bf16);
        // defaults: serial kernels, full-precision storage
        let a = Args::parse(&argv(&["score"])).unwrap();
        let cfg = engine_of(&a).unwrap().config().clone();
        assert_eq!(cfg.kernel_threads, 1);
        assert_eq!(cfg.weight_dtype, crate::backend::WeightDtype::F32);
        // a bad dtype is a usage error (exit 2), caught before anything runs
        let a = Args::parse(&argv(&["score", "--weight-dtype", "f8"]))
            .unwrap();
        assert_eq!(exit_code(&engine_of(&a).unwrap_err()), 2);
    }

    #[test]
    fn list_and_inspect_run_on_the_builtin_catalog() {
        assert!(run(&argv(&["list"])).is_ok());
        assert!(run(&argv(&["inspect", "--net", "glow16"])).is_ok());
        assert!(run(&argv(&["inspect", "--net", "nope"])).is_err());
    }

    #[test]
    fn lint_passes_on_the_builtin_catalog() {
        assert!(run(&argv(&["lint", "--all", "--check"])).is_ok());
        assert!(run(&argv(&["lint", "--net", "glow16", "--json",
                            "--check"])).is_ok());
        let err = run(&argv(&["lint", "--net", "nope"])).unwrap_err();
        assert!(err.to_string().contains("unknown network"), "{err:#}");
        let err = run(&argv(&["lint", "--net", "glow16", "--all"]))
            .unwrap_err();
        assert!(err.to_string().contains("not both"), "{err:#}");
    }

    #[test]
    fn lint_audits_the_checkpoint_interval() {
        // K = 0 is an error under --check; K > depth only warns
        let err = run(&argv(&["lint", "--all", "--check",
                              "--checkpoint", "0"])).unwrap_err();
        assert!(err.to_string().contains("lint failed"), "{err:#}");
        assert!(run(&argv(&["lint", "--net", "realnvp2d", "--check",
                            "--checkpoint", "99"])).is_ok());
        assert!(run(&argv(&["lint", "--net", "realnvp2d", "--check",
                            "--checkpoint", "4"])).is_ok());
    }

    #[test]
    fn exit_codes_separate_check_failures_from_usage_errors() {
        // a tripped --check gate is exit 1, carried as CheckFailed
        let err = run(&argv(&["lint", "--all", "--check",
                              "--checkpoint", "0"])).unwrap_err();
        assert!(err.downcast_ref::<CheckFailed>().is_some(), "{err:#}");
        assert_eq!(exit_code(&err), 1);
        // bad flags are exit 2, before anything runs
        let err = run(&argv(&["lint", "--net", "glow16", "--all"]))
            .unwrap_err();
        assert_eq!(exit_code(&err), 2);
        let err = run(&argv(&["bench", "--suite", "quick", "--check"]))
            .unwrap_err();
        assert_eq!(exit_code(&err), 2);
        let err = run(&argv(&["frobnicate"])).unwrap_err();
        assert_eq!(exit_code(&err), 2);
        // runtime errors stay exit 1
        let err = run(&argv(&["inspect", "--net", "nope"])).unwrap_err();
        assert_eq!(exit_code(&err), 1);
    }

    #[test]
    fn byte_counts_parse_with_binary_suffixes() {
        assert_eq!(parse_bytes("123").unwrap(), 123);
        assert_eq!(parse_bytes("64k").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("64M").unwrap(), 64 << 20);
        assert_eq!(parse_bytes("2g").unwrap(), 2i64 << 30);
        assert!(parse_bytes("0").is_err());
        assert!(parse_bytes("-5m").is_err());
        assert!(parse_bytes("lots").is_err());
    }

    #[test]
    fn mem_budget_flag_reaches_the_engine() {
        let a = Args::parse(&argv(&["train", "--mem-budget", "64m"]))
            .unwrap();
        assert_eq!(engine_of(&a).unwrap().mem_budget(), Some(64 << 20));
        let a = Args::parse(&argv(&["train"])).unwrap();
        assert_eq!(engine_of(&a).unwrap().mem_budget(), None);
        let a = Args::parse(&argv(&["train", "--mem-budget", "none"]))
            .unwrap();
        assert_eq!(exit_code(&engine_of(&a).unwrap_err()), 2);
    }

    #[test]
    fn auto_mode_resolves_to_the_cheapest_fitting_schedule() {
        let engine = Engine::builder()
            .backend(Arc::new(RefBackend::new())).build().unwrap();
        // unconstrained auto: stored is the compute-cheapest schedule
        let a = Args::parse(&argv(&["train", "--mode", "auto"])).unwrap();
        let (_f, s) = flow_and_schedule(&a, &engine, "glow16").unwrap();
        assert_eq!(s.label(), "stored");
        // a budget between the stored and invertible peaks forces a
        // recompute schedule, attaches a budgeted ledger, and the chosen
        // schedule's predicted peak fits
        let peaks = crate::analysis::schedule_peaks(
            &engine.flow("glow16").unwrap().def);
        let peak = |l: &str| peaks.iter().find(|(n, _)| n == l).unwrap().1;
        let budget = (peak("invertible") + peak("stored")) / 2;
        let a = Args::parse(&argv(&["train", "--mode",
                                    &format!("auto:{budget}")])).unwrap();
        let (flow, s) = flow_and_schedule(&a, &engine, "glow16").unwrap();
        assert_ne!(s.label(), "stored");
        assert!(crate::analysis::predict_peak(&flow.def, s.as_ref())
                <= budget);
        assert_eq!(flow.ledger().budget_bytes(), Some(budget as u64));
        // --mem-budget is the default budget when auto carries none
        let a = Args::parse(&argv(&["train", "--mode", "auto",
                                    "--mem-budget",
                                    &budget.to_string()])).unwrap();
        let engine2 = engine_of(&a).unwrap();
        let (_f, s2) = flow_and_schedule(&a, &engine2, "glow16").unwrap();
        assert_eq!(s2.label(), s.label());
        // an impossible budget names the minimum feasible peak
        let a = Args::parse(&argv(&["train", "--mode", "auto:1k"]))
            .unwrap();
        let err = flow_and_schedule(&a, &engine, "glow16").unwrap_err();
        assert!(err.to_string().contains("minimum predicted peak"),
                "{err:#}");
    }

    #[test]
    fn lint_audits_a_checkpoint_directory() {
        let dir = std::env::temp_dir()
            .join(format!("invertnet_lintckpt_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let engine = Engine::builder()
            .backend(Arc::new(RefBackend::new())).build().unwrap();
        let flow = engine.flow("realnvp2d").unwrap();
        let params = flow.init_params(7).unwrap();
        params.save(&dir, "realnvp2d").unwrap();
        // one shot: network verifier + cost model + checkpoint index
        run(&argv(&["lint", "--ckpt", dir.to_str().unwrap(), "--check"]))
            .unwrap();
        run(&argv(&["lint", "--ckpt", dir.to_str().unwrap(), "--json",
                    "--check"])).unwrap();
        // a --net that disagrees with the index is a usage error
        let err = run(&argv(&["lint", "--ckpt", dir.to_str().unwrap(),
                              "--net", "glow16"])).unwrap_err();
        assert_eq!(exit_code(&err), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn serve_refuses_untrained_weights_without_opt_in() {
        let err = run(&argv(&["serve", "--net", "realnvp2d", "--stdio"]))
            .unwrap_err();
        assert!(err.to_string().contains("untrained"), "{err:#}");
        let err = run(&argv(&["serve", "--stdio"])).unwrap_err();
        assert!(err.to_string().contains("--ckpt"), "{err:#}");
    }

    /// A serve-flavored exposition, as `/metrics` would answer it.
    const TOP_SCRAPE: &str = "\
# TYPE invertnet_serve_requests_total counter
invertnet_serve_requests_total 12
# TYPE invertnet_serve_batches_total counter
invertnet_serve_batches_total 4
# TYPE invertnet_serve_errors_total counter
invertnet_serve_errors_total 1
# TYPE invertnet_serve_queue_depth gauge
invertnet_serve_queue_depth 2
# TYPE invertnet_serve_models gauge
invertnet_serve_models 1
# TYPE invertnet_serve_sample_latency_us histogram
invertnet_serve_sample_latency_us_bucket{le=\"127\"} 8
invertnet_serve_sample_latency_us_bucket{le=\"255\"} 12
invertnet_serve_sample_latency_us_bucket{le=\"+Inf\"} 12
invertnet_serve_sample_latency_us_sum 1200
invertnet_serve_sample_latency_us_count 12
# TYPE invertnet_serve_model_requests_total counter
invertnet_serve_model_requests_total{model=\"realnvp2d\"} 12
# TYPE invertnet_serve_model_rows_total counter
invertnet_serve_model_rows_total{model=\"realnvp2d\"} 24
";

    #[test]
    fn top_renders_a_frame_and_rejects_conflicting_sources() {
        let vals =
            crate::telemetry::encode::parse_values(TOP_SCRAPE).unwrap();
        // cold frame: no previous scrape, so QPS is a dash
        let cold = top_frame(&vals, None);
        assert!(cold.contains("requests 12"), "{cold}");
        assert!(cold.contains("realized_batch 3.00"), "{cold}");
        assert!(cold.contains("sample"), "{cold}");
        assert!(cold.contains("realnvp2d"), "{cold}");
        assert!(cold.contains("24"), "per-model rows column: {cold}");
        // warm frame: 12 requests total, 2 seen last frame, 5s apart
        let warm = top_frame(&vals, Some((2.0, 5.0)));
        assert!(warm.contains("2.0  queue"), "(12-2)/5 qps: {warm}");
        // the CLI path renders the same frame off --file --once
        let path = std::env::temp_dir()
            .join(format!("invertnet_top_{}.prom", std::process::id()));
        std::fs::write(&path, TOP_SCRAPE).unwrap();
        run(&argv(&["top", "--file", path.to_str().unwrap(), "--once"]))
            .unwrap();
        // conflicting sources and degenerate intervals are usage errors
        let err = run(&argv(&["top", "--file", path.to_str().unwrap(),
                              "--url", "http://x/", "--once"]))
            .unwrap_err();
        assert_eq!(exit_code(&err), 2, "{err:#}");
        let err = run(&argv(&["top", "--file", path.to_str().unwrap(),
                              "--interval", "0", "--once"]))
            .unwrap_err();
        assert_eq!(exit_code(&err), 2, "{err:#}");
        std::fs::remove_file(&path).ok();
        // an unreadable --file is a runtime error, not a panic
        let err = run(&argv(&["top", "--file", "/nonexistent.prom",
                              "--once"])).unwrap_err();
        assert_eq!(exit_code(&err), 1, "{err:#}");
    }

    #[test]
    fn score_refuses_untrained_weights_without_opt_in() {
        let err = run(&argv(&["score", "--net", "realnvp2d",
                              "--data", "x.npy"]))
            .unwrap_err();
        assert!(err.to_string().contains("--ckpt"), "{err:#}");
    }

    #[test]
    fn score_runs_end_to_end_with_explicit_untrained_opt_in() {
        let dir = std::env::temp_dir()
            .join(format!("invertnet_score_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let data = dir.join("x.npy");
        let out = dir.join("scores.npy");
        let mut rng = Pcg64::new(4);
        npy::save(&data, &Tensor {
            shape: vec![5, 2],
            data: rng.normal_vec(10),
        }).unwrap();
        run(&argv(&["score", "--net", "realnvp2d", "--allow-untrained",
                    "--data", data.to_str().unwrap(),
                    "--out", out.to_str().unwrap()])).unwrap();
        let scores = npy::load(&out).unwrap();
        assert_eq!(scores.shape, vec![5]);
        assert!(scores.data.iter().all(|v| v.is_finite()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn posterior_sample_runs_untrained_with_opt_in() {
        let dir = std::env::temp_dir()
            .join(format!("invertnet_postsmp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("post.npy");
        run(&argv(&["posterior-sample", "--net", "cond_lingauss2d",
                    "--allow-untrained", "--y", "0.7,-0.4", "--n", "12",
                    "--out", out.to_str().unwrap()])).unwrap();
        let t = npy::load(&out).unwrap();
        assert_eq!(t.shape, vec![12, 2]);
        assert!(t.data.iter().all(|v| v.is_finite()));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn posterior_sample_needs_an_observation_and_a_conditional_net() {
        let err = run(&argv(&["posterior-sample", "--net", "cond_lingauss2d",
                              "--allow-untrained"])).unwrap_err();
        assert!(err.to_string().contains("--y"), "{err:#}");
        let err = run(&argv(&["posterior-sample", "--net", "realnvp2d",
                              "--allow-untrained", "--y", "0.1,0.2"]))
            .unwrap_err();
        assert!(err.to_string().contains("no cond"), "{err:#}");
        // a NaN observation is a CLI error, not "model diverged" later
        let err = run(&argv(&["posterior-sample", "--net", "cond_lingauss2d",
                              "--allow-untrained", "--y", "nan,0.4"]))
            .unwrap_err();
        assert!(err.to_string().contains("finite"), "{err:#}");
    }

    #[test]
    fn calibrate_runs_and_validates_inputs() {
        // calibrate on an (explicitly allowed) untrained flow reports
        // without erroring...
        run(&argv(&["calibrate", "--net", "cond_lingauss2d",
                    "--allow-untrained", "--sim", "linear-gaussian",
                    "--datasets", "24", "--draws", "15", "--bins", "4"]))
            .unwrap();
        // ...but a sim/net mismatch is always an error
        let err = run(&argv(&["calibrate", "--net", "cond_lingauss2d",
                              "--allow-untrained", "--sim", "denoise",
                              "--datasets", "4", "--draws", "7"]))
            .unwrap_err();
        assert!(err.to_string().contains("does not match"), "{err:#}");
        let err = run(&argv(&["calibrate", "--net", "cond_lingauss2d",
                              "--allow-untrained"])).unwrap_err();
        assert!(err.to_string().contains("--sim"), "{err:#}");
        // bad alpha is a CLI error, never a panic deep in chi2_crit
        let err = run(&argv(&["calibrate", "--net", "cond_lingauss2d",
                              "--allow-untrained", "--sim", "linear-gaussian",
                              "--alpha", "0"])).unwrap_err();
        assert!(err.to_string().contains("--alpha"), "{err:#}");
    }

    #[test]
    fn posterior_train_validates_sim_names() {
        let err = run(&argv(&["posterior-train", "--sim", "warp"]))
            .unwrap_err();
        assert!(err.to_string().contains("unknown simulator"), "{err:#}");
    }

    #[test]
    fn bench_verb_validates_its_arguments() {
        // no target and no suite -> usage error naming the suites
        let err = run(&argv(&["bench"])).unwrap_err();
        assert!(err.to_string().contains("--suite"), "{err:#}");
        let err = run(&argv(&["bench", "fig3"])).unwrap_err();
        assert!(err.to_string().contains("unknown bench target"), "{err:#}");
        let err = run(&argv(&["bench", "--suite", "warp"])).unwrap_err();
        assert!(err.to_string().contains("unknown suite"), "{err:#}");
        // --check without a baseline is a CLI error before any measuring
        let err = run(&argv(&["bench", "--suite", "quick", "--check"]))
            .unwrap_err();
        assert!(err.to_string().contains("--baseline"), "{err:#}");
        let err = run(&argv(&["bench", "--suite", "quick", "--check",
                              "--baseline", "b.json", "--tol", "-1"]))
            .unwrap_err();
        assert!(err.to_string().contains("--tol"), "{err:#}");
    }

    #[test]
    fn bench_out_paths_follow_the_baseline_layout() {
        use std::path::Path;
        assert_eq!(bench_out_path(None, "quick", false),
                   Path::new("BENCH_quick.json"));
        assert_eq!(bench_out_path(Some("x.json"), "quick", false),
                   Path::new("x.json"));
        // multiple reports, or a trailing slash, force the dir layout
        assert_eq!(bench_out_path(Some("baselines"), "memory", true),
                   Path::new("baselines/memory.json"));
        assert_eq!(bench_out_path(Some("baselines/"), "memory", false),
                   Path::new("baselines/memory.json"));
    }

    #[test]
    fn metrics_verb_dumps_and_validates_exposition() {
        // bare dump of the live registry always succeeds
        run(&argv(&["metrics"])).unwrap();

        let dir = std::env::temp_dir()
            .join(format!("invertnet_metricsverb_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        // a well-formed dump summarizes cleanly
        let good = dir.join("good.prom");
        std::fs::write(&good, "# TYPE demo_total counter\ndemo_total 3\n")
            .unwrap();
        run(&argv(&["metrics", good.to_str().unwrap()])).unwrap();
        // malformed exposition is a CheckFailed (exit 1), not a panic
        let bad = dir.join("bad.prom");
        std::fs::write(&bad, "demo_total 3\n").unwrap();
        let err = run(&argv(&["metrics", bad.to_str().unwrap()]))
            .unwrap_err();
        assert!(err.downcast_ref::<CheckFailed>().is_some(), "{err:#}");
        assert_eq!(exit_code(&err), 1);
        // a missing file is a runtime error naming the path
        let err = run(&argv(&["metrics", "/nonexistent/x.prom"]))
            .unwrap_err();
        assert!(err.to_string().contains("x.prom"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn train_writes_metrics_and_trace_files() {
        let dir = std::env::temp_dir()
            .join(format!("invertnet_trainobs_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let prom = dir.join("train.prom");
        let trace = dir.join("train.trace.json");
        run(&argv(&["train", "--net", "realnvp2d", "--steps", "3",
                    "--quiet", "--eval-every", "0", "--eval-batches", "0",
                    "--metrics-out", prom.to_str().unwrap(),
                    "--trace", trace.to_str().unwrap()])).unwrap();
        // the dump is valid exposition carrying the train series
        let text = std::fs::read_to_string(&prom).unwrap();
        crate::telemetry::encode::parse_exposition(&text).unwrap();
        for series in ["invertnet_train_steps_total", "invertnet_train_loss",
                       "invertnet_span_train_step_us"] {
            assert!(text.contains(series), "{series} missing:\n{text}");
        }
        // the trace holds at least the train_step spans — and because
        // telemetry_finish routes through finish_trace, the array is
        // closed: the file is strictly valid JSON, not just Chrome's
        // comma-tolerant dialect
        let tr = std::fs::read_to_string(&trace).unwrap();
        assert!(tr.starts_with("[\n"), "{tr}");
        assert!(tr.contains("\"name\":\"train_step\""), "{tr}");
        let doc = Json::parse(&tr).unwrap();
        let Json::Arr(events) = doc else { panic!("not an array: {tr}") };
        assert!(!events.is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn score_rejects_mismatched_data_shape() {
        let dir = std::env::temp_dir()
            .join(format!("invertnet_badscore_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let data = dir.join("x.npy");
        npy::save(&data, &Tensor::zeros(&[3, 7])).unwrap();
        let err = run(&argv(&["score", "--net", "realnvp2d",
                              "--allow-untrained",
                              "--data", data.to_str().unwrap()]))
            .unwrap_err();
        assert!(err.to_string().contains("per-sample"), "{err:#}");
        std::fs::remove_dir_all(&dir).ok();
    }
}
