//! Synthetic workloads (DESIGN.md §7 substitutions).
//!
//! * 2-D toy densities (two-moons, 8-gaussians, checkerboard, spiral) — the
//!   standard normalizing-flow density-estimation benchmarks.
//! * A textured-blob image sampler standing in for RGB image corpora: the
//!   paper's memory figures depend only on image *shape*, and the training
//!   examples need inputs with multi-scale spatial correlation, which
//!   gaussian blobs + sinusoidal texture provide.
//! * A linear-Gaussian inverse problem with a closed-form posterior for
//!   validating amortized (conditional) inference.

use anyhow::{bail, Result};

use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Named 2-D densities: sample `n` points, shape (n, 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Density2d {
    TwoMoons,
    EightGaussians,
    Checkerboard,
    Spiral,
}

impl Density2d {
    pub fn parse(name: &str) -> Result<Density2d> {
        Ok(match name {
            "two-moons" | "moons" => Density2d::TwoMoons,
            "eight-gaussians" | "8g" => Density2d::EightGaussians,
            "checkerboard" => Density2d::Checkerboard,
            "spiral" => Density2d::Spiral,
            other => bail!("unknown 2d density {other:?} \
                            (two-moons|eight-gaussians|checkerboard|spiral)"),
        })
    }

    pub fn sample(self, n: usize, rng: &mut Pcg64) -> Tensor {
        let mut data = Vec::with_capacity(n * 2);
        for _ in 0..n {
            let (x, y) = match self {
                Density2d::TwoMoons => {
                    let a = rng.uniform() * std::f64::consts::PI;
                    let (sx, sy, off) = if rng.uniform() < 0.5 {
                        (a.cos(), a.sin(), -0.5)
                    } else {
                        (1.0 - a.cos(), 0.5 - a.sin(), -0.0)
                    };
                    (sx + rng.normal() * 0.08 - 0.5,
                     sy + off + rng.normal() * 0.08)
                }
                Density2d::EightGaussians => {
                    let k = rng.below(8) as f64;
                    let th = k * std::f64::consts::PI / 4.0;
                    (2.0 * th.cos() + rng.normal() * 0.15,
                     2.0 * th.sin() + rng.normal() * 0.15)
                }
                Density2d::Checkerboard => loop {
                    let x = rng.uniform_in(-2.0, 2.0);
                    let y = rng.uniform_in(-2.0, 2.0);
                    let cx = (x.floor() as i64).rem_euclid(2);
                    let cy = (y.floor() as i64).rem_euclid(2);
                    if cx == cy {
                        break (x, y);
                    }
                },
                Density2d::Spiral => {
                    let t = 3.0 * std::f64::consts::PI * rng.uniform().sqrt();
                    let r = t / (3.0 * std::f64::consts::PI) * 2.0;
                    let sgn = if rng.uniform() < 0.5 { 1.0 } else { -1.0 };
                    (sgn * r * t.cos() + rng.normal() * 0.05,
                     sgn * r * t.sin() + rng.normal() * 0.05)
                }
            };
            data.push(x as f32);
            data.push(y as f32);
        }
        Tensor { shape: vec![n, 2], data }
    }
}

/// Textured-blob images, NHWC in [-1, 1]: a random mixture of gaussian
/// bumps plus oriented sinusoidal texture per channel.
pub fn synth_images(n: usize, h: usize, w: usize, c: usize, rng: &mut Pcg64) -> Tensor {
    let mut data = vec![0.0f32; n * h * w * c];
    for img in 0..n {
        // 2-4 random blobs shared across channels + per-channel texture
        let n_blobs = 2 + rng.below(3);
        let blobs: Vec<(f64, f64, f64, f64)> = (0..n_blobs)
            .map(|_| (rng.uniform() * h as f64,
                      rng.uniform() * w as f64,
                      (0.1 + rng.uniform() * 0.2) * h as f64,
                      rng.uniform_in(0.5, 1.5)))
            .collect();
        for ch in 0..c {
            let fx = rng.uniform_in(0.02, 0.2);
            let fy = rng.uniform_in(0.02, 0.2);
            let phase = rng.uniform() * std::f64::consts::TAU;
            let amp = rng.uniform_in(0.05, 0.25);
            for i in 0..h {
                for j in 0..w {
                    let mut v = 0.0f64;
                    for (bi, bj, bs, ba) in &blobs {
                        let d2 = (i as f64 - bi).powi(2) + (j as f64 - bj).powi(2);
                        v += ba * (-d2 / (2.0 * bs * bs)).exp();
                    }
                    v += amp
                        * (fx * i as f64 * std::f64::consts::TAU
                            + fy * j as f64 * std::f64::consts::TAU
                            + phase)
                            .sin();
                    v += rng.normal() * 0.02;
                    let idx = ((img * h + i) * w + j) * c + ch;
                    data[idx] = (v.clamp(-1.5, 1.5) - 0.5) as f32;
                }
            }
        }
    }
    Tensor { shape: vec![n, h, w, c], data }
}

/// Linear-Gaussian inverse problem y = A theta + eps, theta ~ N(0, I),
/// eps ~ N(0, sigma^2 I). The posterior p(theta | y) is Gaussian with
///   Sigma_post = (A^T A / sigma^2 + I)^{-1},
///   mu_post    = Sigma_post A^T y / sigma^2,
/// giving the amortized-inference example an analytic ground truth.
pub struct LinearGaussian {
    pub a: [[f64; 2]; 2],
    pub sigma: f64,
}

impl LinearGaussian {
    pub fn default_problem() -> LinearGaussian {
        LinearGaussian { a: [[1.0, 0.6], [0.0, 0.8]], sigma: 0.5 }
    }

    /// Sample (theta, y) pairs; returns ((n,2) thetas, (n,2) ys).
    pub fn sample(&self, n: usize, rng: &mut Pcg64) -> (Tensor, Tensor) {
        let mut th = Vec::with_capacity(n * 2);
        let mut ys = Vec::with_capacity(n * 2);
        for _ in 0..n {
            let t0 = rng.normal();
            let t1 = rng.normal();
            let y0 = self.a[0][0] * t0 + self.a[0][1] * t1 + rng.normal() * self.sigma;
            let y1 = self.a[1][0] * t0 + self.a[1][1] * t1 + rng.normal() * self.sigma;
            th.push(t0 as f32);
            th.push(t1 as f32);
            ys.push(y0 as f32);
            ys.push(y1 as f32);
        }
        (Tensor { shape: vec![n, 2], data: th },
         Tensor { shape: vec![n, 2], data: ys })
    }

    /// Draw `n` exact posterior samples theta ~ p(theta | y) via the
    /// closed form: mu + L eps with L the Cholesky factor of Sigma_post.
    /// This is the exactly-calibrated reference sampler the posterior
    /// subsystem's SBC/coverage diagnostics are validated against.
    pub fn sample_posterior(&self, y: [f64; 2], n: usize, rng: &mut Pcg64)
                            -> Tensor {
        let (mu, cov) = self.posterior(y);
        // 2x2 lower Cholesky of the (SPD) posterior covariance
        let l00 = cov[0][0].sqrt();
        let l10 = cov[1][0] / l00;
        let l11 = (cov[1][1] - l10 * l10).sqrt();
        let mut data = Vec::with_capacity(n * 2);
        for _ in 0..n {
            let e0 = rng.normal();
            let e1 = rng.normal();
            data.push((mu[0] + l00 * e0) as f32);
            data.push((mu[1] + l10 * e0 + l11 * e1) as f32);
        }
        Tensor { shape: vec![n, 2], data }
    }

    /// Analytic posterior (mu, Sigma) for one observation y.
    pub fn posterior(&self, y: [f64; 2]) -> ([f64; 2], [[f64; 2]; 2]) {
        let a = self.a;
        let s2 = self.sigma * self.sigma;
        // P = A^T A / s2 + I  (precision)
        let mut p = [[0.0; 2]; 2];
        for i in 0..2 {
            for j in 0..2 {
                for k in 0..2 {
                    p[i][j] += a[k][i] * a[k][j] / s2;
                }
            }
            p[i][i] += 1.0;
        }
        // Sigma = P^{-1} (2x2 inverse)
        let det = p[0][0] * p[1][1] - p[0][1] * p[1][0];
        let cov = [
            [p[1][1] / det, -p[0][1] / det],
            [-p[1][0] / det, p[0][0] / det],
        ];
        // mu = Sigma A^T y / s2
        let aty = [
            (a[0][0] * y[0] + a[1][0] * y[1]) / s2,
            (a[0][1] * y[0] + a[1][1] * y[1]) / s2,
        ];
        let mu = [
            cov[0][0] * aty[0] + cov[0][1] * aty[1],
            cov[1][0] * aty[0] + cov[1][1] * aty[1],
        ];
        (mu, cov)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn densities_have_right_shape_and_spread() {
        let mut rng = Pcg64::new(5);
        for d in [Density2d::TwoMoons, Density2d::EightGaussians,
                  Density2d::Checkerboard, Density2d::Spiral] {
            let t = d.sample(500, &mut rng);
            assert_eq!(t.shape, vec![500, 2]);
            assert!(t.linf() < 6.0, "{d:?} blew up: {}", t.linf());
            assert!(t.l2() > 1.0, "{d:?} collapsed");
        }
    }

    #[test]
    fn checkerboard_occupies_right_cells() {
        let mut rng = Pcg64::new(6);
        let t = Density2d::Checkerboard.sample(200, &mut rng);
        for p in t.data.chunks(2) {
            let cx = (p[0].floor() as i64).rem_euclid(2);
            let cy = (p[1].floor() as i64).rem_euclid(2);
            assert_eq!(cx, cy, "point {p:?} in a forbidden cell");
        }
    }

    #[test]
    fn images_bounded() {
        let mut rng = Pcg64::new(7);
        let t = synth_images(2, 8, 8, 3, &mut rng);
        assert_eq!(t.shape, vec![2, 8, 8, 3]);
        assert!(t.linf() <= 2.0);
        // different images differ
        let a = &t.data[..192];
        let b = &t.data[192..];
        assert!(a.iter().zip(b).any(|(x, y)| (x - y).abs() > 1e-3));
    }

    #[test]
    fn linear_gaussian_posterior_matches_monte_carlo() {
        // importance-free check: posterior mean should roughly equal the
        // empirical mean of thetas whose simulated y lands near y_obs
        let prob = LinearGaussian::default_problem();
        let mut rng = Pcg64::new(8);
        let (th, ys) = prob.sample(200_000, &mut rng);
        let y_obs = [0.7, -0.4];
        let (mu, cov) = prob.posterior(y_obs);
        let mut acc = [0.0f64; 2];
        let mut count = 0.0;
        for i in 0..200_000 {
            let dy0 = ys.data[2 * i] as f64 - y_obs[0];
            let dy1 = ys.data[2 * i + 1] as f64 - y_obs[1];
            if dy0 * dy0 + dy1 * dy1 < 0.02 {
                acc[0] += th.data[2 * i] as f64;
                acc[1] += th.data[2 * i + 1] as f64;
                count += 1.0;
            }
        }
        assert!(count > 100.0, "not enough ABC hits");
        let emp = [acc[0] / count, acc[1] / count];
        assert!((emp[0] - mu[0]).abs() < 0.15, "{emp:?} vs {mu:?}");
        assert!((emp[1] - mu[1]).abs() < 0.15, "{emp:?} vs {mu:?}");
        assert!(cov[0][0] > 0.0 && cov[1][1] > 0.0);
    }

    #[test]
    fn exact_posterior_sampler_has_the_analytic_moments() {
        let prob = LinearGaussian::default_problem();
        let y = [0.9, -0.3];
        let (mu, cov) = prob.posterior(y);
        let mut rng = Pcg64::new(13);
        let t = prob.sample_posterior(y, 40_000, &mut rng);
        assert_eq!(t.shape, vec![40_000, 2]);
        let n = 40_000f64;
        let mut m = [0.0f64; 2];
        for p in t.data.chunks(2) {
            m[0] += p[0] as f64;
            m[1] += p[1] as f64;
        }
        m[0] /= n;
        m[1] /= n;
        let mut c = [[0.0f64; 2]; 2];
        for p in t.data.chunks(2) {
            let d = [p[0] as f64 - m[0], p[1] as f64 - m[1]];
            for i in 0..2 {
                for j in 0..2 {
                    c[i][j] += d[i] * d[j] / n;
                }
            }
        }
        for i in 0..2 {
            assert!((m[i] - mu[i]).abs() < 0.02, "mean {m:?} vs {mu:?}");
            for j in 0..2 {
                assert!((c[i][j] - cov[i][j]).abs() < 0.02,
                        "cov {c:?} vs {cov:?}");
            }
        }
    }
}
