//! Minimal JSON: parser + serializer.
//!
//! Supports everything `artifacts/manifest.json`, checkpoints and run
//! metadata need: objects, arrays, strings (with escapes), numbers, bools,
//! null. No streaming; documents are a few MB at most.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }

    // ---- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let f = self.as_f64()?;
        if f < 0.0 || f.fract() != 0.0 {
            bail!("expected non-negative integer, got {f}");
        }
        Ok(f as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Convenience: `[1,2,3]` -> `vec![1,2,3]`.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f32(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    pub fn arr_usize(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|x| Json::Num(*x as f64)).collect())
    }

    // ---- serialization -----------------------------------------------------

    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // bare `inf`/`NaN` is not JSON; null is the standard
                    // lossy encoding (the serve protocol documents it)
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15
                    && !(*n == 0.0 && n.is_sign_negative())
                {
                    // integral fast path; -0.0 is excluded because casting
                    // it to i64 would drop the sign and break the serve
                    // protocol's bit-exact f32 wire contract
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    v.write(out, indent + 1, pretty);
                }
                if !a.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !m.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, want: u8) -> Result<()> {
        let got = self.peek()?;
        if got != want {
            bail!("expected {:?} at byte {}, got {:?}",
                  want as char, self.pos, got as char);
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}",
                           self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(a));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}",
                           self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            s.push(char::from_u32(code)
                                .ok_or_else(|| anyhow!("bad codepoint"))?);
                        }
                        c => bail!("bad escape \\{}", c as char),
                    }
                }
                b => {
                    // re-consume multi-byte UTF-8 sequences whole
                    if b < 0x80 {
                        s.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(b);
                        let chunk = self
                            .bytes
                            .get(start..start + len)
                            .ok_or_else(|| anyhow!("truncated utf8"))?;
                        s.push_str(std::str::from_utf8(chunk)?);
                        self.pos = start + len;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while let Some(b) = self.bytes.get(self.pos) {
            if matches!(b, b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()
            .map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    if first >= 0xF0 {
        4
    } else if first >= 0xE0 {
        3
    } else {
        2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": null, "d": true}, "s": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap()[1].as_f64().unwrap(), 2.5);
        assert!(v.req("b").unwrap().req("c").unwrap().is_null());
        assert_eq!(v.req("s").unwrap().as_str().unwrap(), "x\ny");
        let back = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("nul").is_err());
    }

    #[test]
    fn unicode_strings() {
        let v = Json::parse(r#""é café ☕""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é café ☕");
    }

    #[test]
    fn usize_vec() {
        let v = Json::parse("[8, 64, 64, 3]").unwrap();
        assert_eq!(v.as_usize_vec().unwrap(), vec![8, 64, 64, 3]);
        assert!(Json::parse("[1.5]").unwrap().as_usize_vec().is_err());
    }

    #[test]
    fn negative_zero_keeps_its_sign() {
        assert_eq!(Json::Num(-0.0).to_string(), "-0");
        let back = Json::parse("-0").unwrap();
        let Json::Num(v) = back else { panic!("{back:?}") };
        assert!(v == 0.0 && v.is_sign_negative(), "{v}");
        assert_eq!(Json::Num(0.0).to_string(), "0");
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        let v = Json::Arr(vec![Json::Num(1.0), Json::Num(f64::NEG_INFINITY)]);
        assert_eq!(Json::parse(&v.to_string()).unwrap(),
                   Json::Arr(vec![Json::Num(1.0), Json::Null]));
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("xs", Json::arr_f32(&[1.0, 2.0])),
            ("name", Json::Str("net".into())),
        ]);
        let text = v.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), v);
    }
}
