//! Offline substrates: JSON, RNG, CLI parsing, micro-bench harness.
//!
//! The build environment is fully offline (serde/clap/criterion/rand are
//! unavailable) — these modules implement the slices of them this project
//! needs (documented in DESIGN.md §7).

pub mod bench;
pub mod cli;
pub mod json;
pub mod rng;
