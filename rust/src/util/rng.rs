//! PCG64 pseudo-random generator + normal/uniform sampling.
//!
//! Deterministic, seedable RNG used for parameter init, synthetic data and
//! latent sampling. (The vendored crate set has no `rand`; `rand_core`
//! alone has no generators.) PCG-XSL-RR 128/64, O'Neill 2014.

/// PCG64 (XSL-RR 128/64) generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const MUL: u128 = 0x2360ed051fc65da44385df649fccf645;

impl Pcg64 {
    pub fn new(seed: u64) -> Self {
        // splitmix-style seeding of the 128-bit state/stream
        let s0 = splitmix(seed);
        let s1 = splitmix(s0);
        let s2 = splitmix(s1);
        let s3 = splitmix(s2);
        let mut rng = Pcg64 {
            state: ((s0 as u128) << 64) | s1 as u128,
            inc: (((s2 as u128) << 64) | s3 as u128) | 1,
        };
        rng.next_u64();
        rng
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(MUL).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Standard normal via Box-Muller, cosine half only. No caching: each
    /// call consumes two uniforms and returns one deviate, which keeps the
    /// generator's consumption pattern independent of call history.
    pub fn normal(&mut self) -> f64 {
        let u1 = loop {
            let u = self.uniform();
            if u > 1e-12 {
                break u;
            }
        };
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal_f32()).collect()
    }

    /// Random integer in [0, n), exactly uniform.
    ///
    /// Rejection sampling: draws below `2^64 mod n` are discarded so every
    /// residue class is equally likely (a bare `% n` over-weights the low
    /// residues by one part in `2^64 / n`). The rejection probability is
    /// `n / 2^64`, so a retry essentially never happens for the small `n`
    /// used here.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0, "below(0) is meaningless");
        let n = n as u64;
        let lim = n.wrapping_neg() % n; // == 2^64 mod n
        loop {
            let v = self.next_u64();
            if v >= lim {
                return (v % n) as usize;
            }
        }
    }
}

fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E3779B97f4A7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D049BB133111EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Pcg64::new(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            sum += u;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut r = Pcg64::new(9);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.below(5)] += 1;
        }
        for &c in &counts {
            // 5 sigma of a binomial(50_000, 1/5) is ~450
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::new(3);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }
}
