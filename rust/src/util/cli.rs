//! Tiny CLI argument parser: `--key value` / `--flag` pairs after a
//! subcommand, with typed accessors and defaults. (clap is not available in
//! the offline vendor set.)

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse: positional words first (the subcommand path), then
    /// `--key value` pairs; `--key` followed by another `--...` or end of
    /// argv is a boolean flag.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let word = &argv[i];
            if let Some(key) = word.strip_prefix("--") {
                let next = argv.get(i + 1);
                match next {
                    Some(v) if !v.starts_with("--") => {
                        a.opts.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        a.flags.push(key.to_string());
                        i += 1;
                    }
                }
            } else {
                if !a.opts.is_empty() || !a.flags.is_empty() {
                    bail!("positional arg {word:?} after options");
                }
                a.subcommand.push(word.clone());
                i += 1;
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(words: &[&str]) -> Vec<String> {
        words.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(&argv(&[
            "bench", "fig1", "--steps", "10", "--verbose", "--lr", "0.001",
        ]))
        .unwrap();
        assert_eq!(a.subcommand, vec!["bench", "fig1"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 10);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.001);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn rejects_positional_after_options() {
        assert!(Args::parse(&argv(&["x", "--a", "1", "y"])).is_err());
    }

    #[test]
    fn missing_required() {
        let a = Args::parse(&argv(&["train"])).unwrap();
        assert!(a.req("net").is_err());
    }
}
