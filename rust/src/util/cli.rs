//! Tiny CLI argument parser: `--key value` / `--flag` pairs after a
//! subcommand, with typed accessors and defaults. (clap is not available in
//! the offline vendor set.)

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Vec<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    /// Parse: positional words first (the subcommand path), then
    /// `--key value` pairs; `--key` followed by another `--...` or end of
    /// argv is a boolean flag.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut a = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let word = &argv[i];
            if let Some(key) = word.strip_prefix("--") {
                let next = argv.get(i + 1);
                match next {
                    Some(v) if !v.starts_with("--") => {
                        a.opts.insert(key.to_string(), v.clone());
                        i += 2;
                    }
                    _ => {
                        a.flags.push(key.to_string());
                        i += 1;
                    }
                }
            } else {
                if !a.opts.is_empty() || !a.flags.is_empty() {
                    bail!("positional arg {word:?} after options");
                }
                a.subcommand.push(word.clone());
                i += 1;
            }
        }
        Ok(a)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn req(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(words: &[&str]) -> Vec<String> {
        words.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let a = Args::parse(&argv(&[
            "bench", "fig1", "--steps", "10", "--verbose", "--lr", "0.001",
        ]))
        .unwrap();
        assert_eq!(a.subcommand, vec!["bench", "fig1"]);
        assert_eq!(a.usize_or("steps", 0).unwrap(), 10);
        assert_eq!(a.f64_or("lr", 0.0).unwrap(), 0.001);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn rejects_positional_after_options() {
        assert!(Args::parse(&argv(&["x", "--a", "1", "y"])).is_err());
    }

    #[test]
    fn missing_required() {
        let a = Args::parse(&argv(&["train"])).unwrap();
        assert!(a.req("net").is_err());
    }

    #[test]
    fn typed_accessors_fall_back_to_defaults() {
        let a = Args::parse(&argv(&["train"])).unwrap();
        assert_eq!(a.usize_or("steps", 200).unwrap(), 200);
        assert_eq!(a.u64_or("seed", 42).unwrap(), 42);
        assert!((a.f64_or("lr", 1e-3).unwrap() - 1e-3).abs() < 1e-12);
        assert_eq!(a.str_or("mode", "invertible"), "invertible");
        assert_eq!(a.get("mode"), None);
    }

    #[test]
    fn typed_accessors_reject_garbage_values() {
        let a = Args::parse(&argv(&["train", "--steps", "many", "--lr", "fast"]))
            .unwrap();
        assert!(a.usize_or("steps", 1).is_err());
        assert!(a.f64_or("lr", 1.0).is_err());
        // a numeric-looking value still parses
        let a = Args::parse(&argv(&["train", "--steps", "12"])).unwrap();
        assert_eq!(a.usize_or("steps", 1).unwrap(), 12);
    }

    #[test]
    fn unknown_subcommand_words_are_captured_positionally() {
        // dispatch-level rejection is app::run's job; the parser just
        // records the words so the caller can report them
        let a = Args::parse(&argv(&["frobnicate", "--x", "1"])).unwrap();
        assert_eq!(a.subcommand, vec!["frobnicate"]);
        assert_eq!(a.get("x"), Some("1"));
    }

    #[test]
    fn trailing_flag_and_value_forms() {
        let a = Args::parse(&argv(&["list", "--quiet"])).unwrap();
        assert!(a.flag("quiet"));
        let a = Args::parse(&argv(&["list", "--quiet", "--out", "d"])).unwrap();
        assert!(a.flag("quiet"));
        assert_eq!(a.get("out"), Some("d"));
        // negative numbers are values, not flags? the simple rule treats
        // "--key --..." as a flag, so numbers must not start with "--"
        let a = Args::parse(&argv(&["train", "--lr", "0.5"])).unwrap();
        assert!((a.f64_or("lr", 0.0).unwrap() - 0.5).abs() < 1e-12);
    }
}
