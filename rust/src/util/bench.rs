//! Micro-bench harness (criterion is not in the offline vendor set).
//!
//! Warmup + timed iterations with mean / stddev / min, printed in a
//! criterion-like one-liner. Used by the `benches/` binaries.

use std::time::Instant;

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    stats_of(&samples)
}

pub fn stats_of(samples: &[f64]) -> Stats {
    let n = samples.len().max(1) as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    Stats {
        iters: samples.len(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().copied().fold(0.0, f64::max),
    }
}

/// criterion-style report line.
pub fn report(name: &str, s: &Stats) {
    println!(
        "{name:<48} time: [{:>9.3} ms  ±{:>7.3} ms]  min {:>9.3} ms  ({} iters)",
        s.mean_s * 1e3,
        s.std_s * 1e3,
        s.min_s * 1e3,
        s.iters
    );
}

/// Human-readable byte count (GiB/MiB/KiB).
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let bf = b as f64;
    if bf >= K * K * K {
        format!("{:.2} GiB", bf / (K * K * K))
    } else if bf >= K * K {
        format!("{:.2} MiB", bf / (K * K))
    } else if bf >= K {
        format!("{:.1} KiB", bf / K)
    } else {
        format!("{b} B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let s = bench(1, 5, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(s.mean_s >= 0.001);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn bytes_fmt() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert!(fmt_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
        assert!(fmt_bytes(40 * 1024 * 1024 * 1024).starts_with("40.00 GiB"));
    }
}
