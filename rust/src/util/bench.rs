//! Micro-bench harness (criterion is not in the offline vendor set).
//!
//! Warmup + timed iterations with mean / stddev / min, printed in a
//! criterion-like one-liner. Used by the `benches/` binaries and the
//! [`crate::perf`] suites.
//!
//! Also home of the **environment block** every `BENCH_*.json` document
//! carries ([`env_json`]): git revision, worker threads, CPU count and
//! build profile — the context that makes historical perf records
//! comparable across machines.

use std::path::Path;
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub iters: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
}

impl Stats {
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

/// Run `f` for `warmup` untimed + `iters` timed iterations.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    stats_of(&samples)
}

pub fn stats_of(samples: &[f64]) -> Stats {
    let n = samples.len().max(1) as f64;
    let mean = samples.iter().sum::<f64>() / n;
    let var = samples.iter().map(|s| (s - mean) * (s - mean)).sum::<f64>() / n;
    Stats {
        iters: samples.len(),
        mean_s: mean,
        std_s: var.sqrt(),
        min_s: samples.iter().copied().fold(f64::INFINITY, f64::min),
        max_s: samples.iter().copied().fold(0.0, f64::max),
    }
}

/// criterion-style report line.
pub fn report(name: &str, s: &Stats) {
    println!(
        "{name:<48} time: [{:>9.3} ms  ±{:>7.3} ms]  min {:>9.3} ms  ({} iters)",
        s.mean_s * 1e3,
        s.std_s * 1e3,
        s.min_s * 1e3,
        s.iters
    );
}

/// Human-readable byte count (GiB/MiB/KiB).
pub fn fmt_bytes(b: u64) -> String {
    const K: f64 = 1024.0;
    let bf = b as f64;
    if bf >= K * K * K {
        format!("{:.2} GiB", bf / (K * K * K))
    } else if bf >= K * K {
        format!("{:.2} MiB", bf / (K * K))
    } else if bf >= K {
        format!("{:.1} KiB", bf / K)
    } else {
        format!("{b} B")
    }
}

// ---------------------------------------------------------------------------
// Environment capture
// ---------------------------------------------------------------------------

/// The build profile this binary was compiled under (release benches are
/// the only ones worth comparing; debug records are flagged as such).
pub fn build_profile() -> &'static str {
    if cfg!(debug_assertions) {
        "debug"
    } else {
        "release"
    }
}

/// Logical CPU count (0 when the platform cannot say).
pub fn cpu_count() -> usize {
    std::thread::available_parallelism().map_or(0, |p| p.get())
}

/// The current git revision, best-effort and offline: `INVERTNET_GIT_REV`
/// override, then `GITHUB_SHA` (CI), then a walk up from the working
/// directory reading `.git/HEAD` (following one level of `ref:`
/// indirection, with a `packed-refs` fallback). `"unknown"` when nothing
/// answers — never an error, so env capture cannot fail a bench run.
pub fn git_rev() -> String {
    for var in ["INVERTNET_GIT_REV", "GITHUB_SHA"] {
        if let Ok(sha) = std::env::var(var) {
            let sha = sha.trim().to_string();
            if !sha.is_empty() {
                return short_rev(&sha);
            }
        }
    }
    let mut dir = std::env::current_dir().ok();
    while let Some(d) = dir {
        // `.git` is a directory in a normal checkout, but a one-line
        // `gitdir: <path>` FILE in worktrees and submodules — stopping
        // at the first `.git` of either kind keeps the walk from
        // attributing the record to an enclosing, unrelated repository
        if let Some(git_dir) = locate_git_dir(&d) {
            if let Ok(head) = std::fs::read_to_string(git_dir.join("HEAD")) {
                return resolve_head(&git_dir, head.trim());
            }
            return "unknown".to_string();
        }
        dir = d.parent().map(Path::to_path_buf);
    }
    "unknown".to_string()
}

/// The actual git dir for a checkout rooted at `d`, if `d/.git` exists:
/// the directory itself, or the target of a `gitdir:` file.
fn locate_git_dir(d: &Path) -> Option<std::path::PathBuf> {
    let dotgit = d.join(".git");
    if dotgit.is_dir() {
        return Some(dotgit);
    }
    let text = std::fs::read_to_string(&dotgit).ok()?;
    let target = text.trim().strip_prefix("gitdir:")?.trim();
    let target = Path::new(target);
    Some(if target.is_absolute() {
        target.to_path_buf()
    } else {
        d.join(target)
    })
}

fn resolve_head(git_dir: &Path, head: &str) -> String {
    let Some(refname) = head.strip_prefix("ref: ") else {
        return short_rev(head); // detached HEAD holds the sha directly
    };
    let refname = refname.trim();
    // worktree git dirs keep HEAD locally but share refs/packed-refs with
    // the main repository via `commondir`
    let mut ref_dirs = vec![git_dir.to_path_buf()];
    if let Ok(common) = std::fs::read_to_string(git_dir.join("commondir")) {
        let common = Path::new(common.trim());
        ref_dirs.push(if common.is_absolute() {
            common.to_path_buf()
        } else {
            git_dir.join(common)
        });
    }
    for rd in &ref_dirs {
        if let Ok(sha) = std::fs::read_to_string(rd.join(refname)) {
            return short_rev(sha.trim());
        }
    }
    for rd in &ref_dirs {
        if let Ok(packed) = std::fs::read_to_string(rd.join("packed-refs")) {
            for line in packed.lines() {
                // "  <sha> <refname>"
                if let Some((sha, name)) = line.trim().split_once(' ') {
                    if name == refname {
                        return short_rev(sha);
                    }
                }
            }
        }
    }
    "unknown".to_string()
}

fn short_rev(sha: &str) -> String {
    let sha: String = sha.chars().take(12).collect();
    if sha.is_empty() {
        "unknown".to_string()
    } else {
        sha
    }
}

/// The environment block carried by every `BENCH_*.json` document:
/// `{git_rev, threads, cpus, profile}`. `threads` is the worker count the
/// run was configured with (training/inference pool size), not the
/// machine's — `cpus` records that.
pub fn env_json(threads: usize) -> Json {
    Json::obj(vec![
        ("git_rev", Json::Str(git_rev())),
        ("threads", Json::Num(threads as f64)),
        ("cpus", Json::Num(cpu_count() as f64)),
        ("profile", Json::Str(build_profile().to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_sane() {
        let s = bench(1, 5, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(s.mean_s >= 0.001);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s);
        assert_eq!(s.iters, 5);
    }

    #[test]
    fn env_block_has_the_comparability_fields() {
        let env = env_json(3);
        assert_eq!(env.req("threads").unwrap().as_usize().unwrap(), 3);
        // profile is whatever this test binary was built as
        let profile = env.req("profile").unwrap().as_str().unwrap();
        assert!(profile == "debug" || profile == "release");
        // git_rev is best-effort but always a non-empty string
        let rev = env.req("git_rev").unwrap().as_str().unwrap();
        assert!(!rev.is_empty());
        assert!(env.req("cpus").unwrap().as_f64().unwrap() >= 0.0);
    }

    #[test]
    fn rev_shortening_and_detached_heads() {
        assert_eq!(short_rev("0123456789abcdef0123"), "0123456789ab");
        assert_eq!(short_rev(""), "unknown");
        let d = std::env::temp_dir()
            .join(format!("invertnet_git_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        // detached: HEAD holds the sha itself
        assert_eq!(resolve_head(&d, "feedfacefeedfacefeedface"),
                   "feedfacefeed");
        // symbolic ref with a loose ref file
        std::fs::create_dir_all(d.join("refs/heads")).unwrap();
        std::fs::write(d.join("refs/heads/main"),
                       "cafebabecafebabecafebabe\n").unwrap();
        assert_eq!(resolve_head(&d, "ref: refs/heads/main"), "cafebabecafe");
        // missing ref and no packed-refs -> unknown, never an error
        assert_eq!(resolve_head(&d, "ref: refs/heads/gone"), "unknown");
        std::fs::write(d.join("packed-refs"),
                       "# pack-refs with: peeled\n\
                        aabbccddeeff00112233 refs/heads/gone\n").unwrap();
        assert_eq!(resolve_head(&d, "ref: refs/heads/gone"), "aabbccddeeff");
        std::fs::remove_dir_all(&d).ok();
    }

    #[test]
    fn gitfile_worktrees_resolve_through_commondir() {
        let root = std::env::temp_dir()
            .join(format!("invertnet_wt_{}", std::process::id()));
        let main = root.join("main/.git");
        let wt_git = main.join("worktrees/feature");
        let checkout = root.join("feature");
        std::fs::create_dir_all(main.join("refs/heads")).unwrap();
        std::fs::create_dir_all(&wt_git).unwrap();
        std::fs::create_dir_all(&checkout).unwrap();
        // the checkout's .git is a FILE pointing at the worktree git dir
        std::fs::write(checkout.join(".git"),
                       format!("gitdir: {}\n", wt_git.display())).unwrap();
        std::fs::write(wt_git.join("HEAD"),
                       "ref: refs/heads/feature\n").unwrap();
        std::fs::write(wt_git.join("commondir"), "../..\n").unwrap();
        std::fs::write(main.join("refs/heads/feature"),
                       "0123456789abcdef0123\n").unwrap();
        let gd = locate_git_dir(&checkout).expect("gitfile resolves");
        assert_eq!(resolve_head(&gd, "ref: refs/heads/feature"),
                   "0123456789ab");
        // a directory .git still resolves to itself
        assert_eq!(locate_git_dir(&root.join("main")).unwrap(), main);
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn bytes_fmt() {
        assert_eq!(fmt_bytes(512), "512 B");
        assert_eq!(fmt_bytes(2048), "2.0 KiB");
        assert!(fmt_bytes(3 * 1024 * 1024).starts_with("3.00 MiB"));
        assert!(fmt_bytes(40 * 1024 * 1024 * 1024).starts_with("40.00 GiB"));
    }
}
