//! Host tensor type: row-major f32 arrays with shape, plus the slicing /
//! concat ops the coordinator performs natively (multiscale factor-out).
//! Backend-specific conversions (e.g. XLA literals) live with their
//! backend, keeping this type substrate-free.

pub mod npy;
pub mod ops;

use anyhow::{bail, Result};

/// A row-major f32 host tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let want: usize = shape.iter().product();
        if want != data.len() {
            bail!("shape {shape:?} wants {want} elems, got {}", data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * std::mem::size_of::<f32>()
    }

    /// Leading (batch) dimension.
    pub fn batch(&self) -> usize {
        *self.shape.first().unwrap_or(&1)
    }

    /// Product of all non-leading dims.
    pub fn inner_len(&self) -> usize {
        self.shape.iter().skip(1).product()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    /// Max |x|.
    pub fn linf(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// sqrt(sum x^2).
    pub fn l2(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// Elementwise maximum absolute difference.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .fold(0.0f32, |m, (a, b)| m.max((a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_len() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn stats() {
        let t = Tensor::new(vec![4], vec![1.0, -2.0, 3.0, -4.0]).unwrap();
        assert_eq!(t.mean(), -0.5);
        assert_eq!(t.linf(), 4.0);
        assert!((t.l2() - (30.0f32).sqrt()).abs() < 1e-6);
        assert_eq!(t.size_bytes(), 16);
    }

    #[test]
    fn batch_and_inner() {
        let t = Tensor::zeros(&[8, 4, 4, 3]);
        assert_eq!(t.batch(), 8);
        assert_eq!(t.inner_len(), 48);
    }
}
