//! Host tensor ops the coordinator performs natively.
//!
//! Multiscale factor-out ("split") is pure memory movement, so it is not
//! worth an XLA round-trip: these routines split/concat along the LAST axis
//! (channels for NHWC images, features for dense), which is contiguous in
//! row-major layout.

use anyhow::{bail, Result};

use super::Tensor;

/// Split along the last axis: first `k` components -> left, rest -> right.
pub fn split_last_axis(t: &Tensor, k: usize) -> Result<(Tensor, Tensor)> {
    let c = *t.shape.last().unwrap_or(&0);
    if k == 0 || k >= c {
        bail!("split k={k} out of range for last dim {c}");
    }
    let rows = t.len() / c;
    let (mut a, mut b) = (Vec::with_capacity(rows * k),
                          Vec::with_capacity(rows * (c - k)));
    for r in 0..rows {
        let row = &t.data[r * c..(r + 1) * c];
        a.extend_from_slice(&row[..k]);
        b.extend_from_slice(&row[k..]);
    }
    let mut sa = t.shape.clone();
    *sa.last_mut().unwrap() = k;
    let mut sb = t.shape.clone();
    *sb.last_mut().unwrap() = c - k;
    Ok((Tensor::new(sa, a)?, Tensor::new(sb, b)?))
}

/// Concat along the last axis (inverse of [`split_last_axis`]).
pub fn concat_last_axis(a: &Tensor, b: &Tensor) -> Result<Tensor> {
    if a.shape.len() != b.shape.len()
        || a.shape[..a.shape.len() - 1] != b.shape[..b.shape.len() - 1]
    {
        bail!("concat shape mismatch: {:?} vs {:?}", a.shape, b.shape);
    }
    let ca = *a.shape.last().unwrap();
    let cb = *b.shape.last().unwrap();
    let rows = a.len() / ca;
    let mut out = Vec::with_capacity(a.len() + b.len());
    for r in 0..rows {
        out.extend_from_slice(&a.data[r * ca..(r + 1) * ca]);
        out.extend_from_slice(&b.data[r * cb..(r + 1) * cb]);
    }
    let mut shape = a.shape.clone();
    *shape.last_mut().unwrap() = ca + cb;
    Tensor::new(shape, out)
}

/// out += src (elementwise, shapes must match).
pub fn add_assign(dst: &mut Tensor, src: &Tensor) -> Result<()> {
    if dst.shape != src.shape {
        bail!("add_assign shape mismatch: {:?} vs {:?}", dst.shape, src.shape);
    }
    for (d, s) in dst.data.iter_mut().zip(&src.data) {
        *d += s;
    }
    Ok(())
}

/// Stack tensors along axis 0 (the serving micro-batcher coalesces
/// per-request payloads with this). All parts must share per-sample dims;
/// row-major layout makes this pure memory movement, so row `i` of the
/// output is bit-identical to the row it came from.
pub fn concat_rows(parts: &[&Tensor]) -> Result<Tensor> {
    let Some(first) = parts.first() else {
        bail!("concat_rows needs at least one part");
    };
    let mut n = 0usize;
    let mut out = Vec::with_capacity(parts.iter().map(|t| t.len()).sum());
    for t in parts {
        if t.shape.len() != first.shape.len()
            || t.shape[1..] != first.shape[1..]
        {
            bail!("concat_rows per-sample shape mismatch: {:?} vs {:?}",
                  t.shape, first.shape);
        }
        n += t.batch();
        out.extend_from_slice(&t.data);
    }
    let mut shape = first.shape.clone();
    shape[0] = n;
    Tensor::new(shape, out)
}

/// Rows `[start, start+len)` along axis 0 (inverse of [`concat_rows`]).
pub fn slice_rows(t: &Tensor, start: usize, len: usize) -> Result<Tensor> {
    let n = t.batch();
    if start + len > n {
        bail!("slice_rows [{start}, {}) out of range {n}", start + len);
    }
    let inner = t.inner_len();
    let mut shape = t.shape.clone();
    shape[0] = len;
    Tensor::new(shape,
                t.data[start * inner..(start + len) * inner].to_vec())
}

/// Flatten a batch of rows from a bigger tensor: select `idx` rows along
/// axis 0 (used by the data loader for minibatching).
pub fn gather_rows(t: &Tensor, idx: &[usize]) -> Result<Tensor> {
    let inner = t.inner_len();
    let n = t.batch();
    let mut out = Vec::with_capacity(idx.len() * inner);
    for &i in idx {
        if i >= n {
            bail!("row {i} out of range {n}");
        }
        out.extend_from_slice(&t.data[i * inner..(i + 1) * inner]);
    }
    let mut shape = t.shape.clone();
    shape[0] = idx.len();
    Tensor::new(shape, out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(shape.to_vec(), (0..n).map(|i| i as f32).collect()).unwrap()
    }

    #[test]
    fn split_concat_roundtrip() {
        let x = t(&[2, 3, 4, 6]);
        let (a, b) = split_last_axis(&x, 2).unwrap();
        assert_eq!(a.shape, vec![2, 3, 4, 2]);
        assert_eq!(b.shape, vec![2, 3, 4, 4]);
        let back = concat_last_axis(&a, &b).unwrap();
        assert_eq!(back, x);
    }

    #[test]
    fn split_values_correct() {
        let x = t(&[1, 4]); // [0,1,2,3]
        let (a, b) = split_last_axis(&x, 1).unwrap();
        assert_eq!(a.data, vec![0.0]);
        assert_eq!(b.data, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn split_rejects_bad_k() {
        let x = t(&[2, 4]);
        assert!(split_last_axis(&x, 0).is_err());
        assert!(split_last_axis(&x, 4).is_err());
    }

    #[test]
    fn add_assign_works() {
        let mut a = t(&[2, 2]);
        let b = t(&[2, 2]);
        add_assign(&mut a, &b).unwrap();
        assert_eq!(a.data, vec![0.0, 2.0, 4.0, 6.0]);
        let c = t(&[4]);
        assert!(add_assign(&mut a, &c).is_err());
    }

    #[test]
    fn concat_and_slice_rows_roundtrip() {
        let a = t(&[2, 3]);
        let b = t(&[1, 3]);
        let cat = concat_rows(&[&a, &b]).unwrap();
        assert_eq!(cat.shape, vec![3, 3]);
        assert_eq!(slice_rows(&cat, 0, 2).unwrap(), a);
        assert_eq!(slice_rows(&cat, 2, 1).unwrap(), b);
        assert!(slice_rows(&cat, 2, 2).is_err());
        let bad = t(&[2, 4]);
        assert!(concat_rows(&[&a, &bad]).is_err());
        assert!(concat_rows(&[]).is_err());
    }

    #[test]
    fn gather() {
        let x = t(&[4, 2]);
        let g = gather_rows(&x, &[3, 0]).unwrap();
        assert_eq!(g.shape, vec![2, 2]);
        assert_eq!(g.data, vec![6.0, 7.0, 0.0, 1.0]);
        assert!(gather_rows(&x, &[9]).is_err());
    }
}
