//! Minimal NPY v1.0 writer/reader for f32 tensors (checkpoints, sample
//! dumps readable by numpy) plus a multi-tensor NPZ-like container
//! implemented as a directory of .npy files + an index.json.

use std::fs;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::Tensor;

const MAGIC: &[u8] = b"\x93NUMPY";

/// Write `t` as a little-endian f32 .npy file.
pub fn save(path: &Path, t: &Tensor) -> Result<()> {
    let shape_str = match t.shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", t.shape[0]),
        _ => format!(
            "({})",
            t.shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_str}, }}"
    );
    // pad so that magic(6)+ver(2)+len(2)+header is a multiple of 64
    let unpadded = MAGIC.len() + 4 + header.len() + 1;
    let pad = (64 - unpadded % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');

    let mut f = fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&[1, 0])?;
    f.write_all(&(header.len() as u16).to_le_bytes())?;
    f.write_all(header.as_bytes())?;
    let mut buf = Vec::with_capacity(t.data.len() * 4);
    for v in &t.data {
        buf.extend_from_slice(&v.to_le_bytes());
    }
    f.write_all(&buf)?;
    Ok(())
}

/// Read a little-endian f32 .npy file written by [`save`] or numpy.
pub fn load(path: &Path) -> Result<Tensor> {
    let mut f = fs::File::open(path)?;
    let mut magic = [0u8; 6];
    f.read_exact(&mut magic)?;
    if magic != MAGIC {
        bail!("{path:?}: not an NPY file");
    }
    let mut ver = [0u8; 2];
    f.read_exact(&mut ver)?;
    let hlen = if ver[0] == 1 {
        let mut b = [0u8; 2];
        f.read_exact(&mut b)?;
        u16::from_le_bytes(b) as usize
    } else {
        let mut b = [0u8; 4];
        f.read_exact(&mut b)?;
        u32::from_le_bytes(b) as usize
    };
    let mut header = vec![0u8; hlen];
    f.read_exact(&mut header)?;
    let header = String::from_utf8(header)?;
    if !header.contains("'<f4'") {
        bail!("{path:?}: only <f4 supported, header={header}");
    }
    if header.contains("'fortran_order': True") {
        bail!("{path:?}: fortran order unsupported");
    }
    let shape = parse_shape(&header)
        .ok_or_else(|| anyhow!("{path:?}: cannot parse shape from {header}"))?;
    let count: usize = shape.iter().product();
    let mut raw = Vec::new();
    f.read_to_end(&mut raw)?;
    if raw.len() < count * 4 {
        bail!("{path:?}: truncated payload");
    }
    let data: Vec<f32> = raw[..count * 4]
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect();
    Tensor::new(shape, data)
}

fn parse_shape(header: &str) -> Option<Vec<usize>> {
    let start = header.find("'shape':")? + 8;
    let open = header[start..].find('(')? + start;
    let close = header[open..].find(')')? + open;
    let inner = &header[open + 1..close];
    let mut dims = Vec::new();
    for part in inner.split(',') {
        let p = part.trim();
        if p.is_empty() {
            continue;
        }
        dims.push(p.parse().ok()?);
    }
    Some(dims)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join(format!("npy_test_{}", std::process::id()));
        fs::create_dir_all(&dir).unwrap();
        for shape in [vec![], vec![5], vec![2, 3], vec![2, 3, 4, 5]] {
            let n: usize = shape.iter().product();
            let t = Tensor::new(shape.clone(),
                                (0..n).map(|i| i as f32 * 0.5 - 1.0).collect())
                .unwrap();
            let p = dir.join(format!("t{}.npy", shape.len()));
            save(&p, &t).unwrap();
            let back = load(&p).unwrap();
            assert_eq!(back, t, "shape {shape:?}");
        }
        fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_garbage() {
        let dir = std::env::temp_dir();
        let p = dir.join(format!("garbage_{}.npy", std::process::id()));
        fs::write(&p, b"not an npy").unwrap();
        assert!(load(&p).is_err());
        fs::remove_file(&p).ok();
    }
}
