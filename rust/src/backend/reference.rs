//! `RefBackend`: a pure-Rust execution backend implementing every layer
//! entry (forward / inverse / backward / backward_stored) natively, with
//! zero external artifacts.
//!
//! The math is a transcription of the per-layer programs in
//! `python/compile/layers/` (themselves specified against
//! `python/compile/kernels/ref.py`), cross-validated numerically against
//! jax before porting. Layer kinds: actnorm, conv1x1 (Householder), GLOW
//! affine coupling, additive coupling, dense/conditional coupling, Haar
//! squeeze, channel permute, hyperbolic leapfrog, and recursive HINT —
//! plus the Gaussian loss heads.

use anyhow::{bail, Result};

use crate::runtime::builtin::HINT_MIN_D;
use crate::runtime::LayerMeta;
use crate::tensor::ops::{concat_last_axis, split_last_axis};
use crate::tensor::Tensor;

use super::math::{apply_mat, apply_mat_t, cnn_apply, cnn_vjp, conv2d_same,
                  conv2d_vjp_w, conv2d_vjp_x, flip_swap, householder,
                  householder_vjp, matmul_at, mlp_apply, mlp_vjp, scratch,
                  sum_to_last};
use super::Backend;

const HYPER_ALPHA: f32 = 0.2;

/// The default backend: per-layer math executed natively on host f32.
///
/// `kernel_threads` is the intra-kernel fan-out the GEMM/conv row-split
/// paths may use (see `math::par`); it is bit-invisible to results and
/// defaults to 1 so the data-parallel outer loops never nest pools.
#[derive(Debug, Clone, Copy)]
pub struct RefBackend {
    kernel_threads: usize,
}

impl Default for RefBackend {
    fn default() -> RefBackend {
        RefBackend::new()
    }
}

impl RefBackend {
    pub fn new() -> RefBackend {
        RefBackend { kernel_threads: 1 }
    }

    /// A backend whose kernels may split output rows across `n` scoped
    /// threads when a layer's work amortizes the spawns.
    pub fn with_kernel_threads(n: usize) -> RefBackend {
        RefBackend { kernel_threads: n.max(1) }
    }

    pub fn kernel_threads(&self) -> usize {
        self.kernel_threads
    }

    fn dispatch(
        &self,
        meta: &LayerMeta,
        entry: &str,
        acts: &[&Tensor],
        cond: Option<&Tensor>,
        params: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let want_acts = match entry {
            "forward" | "inverse" => 1,
            "backward" | "backward_stored" => 3,
            other => bail!("{}: unknown entry {other:?}", meta.sig),
        };
        if acts.len() != want_acts {
            bail!("{}.{entry}: got {} activations, want {want_acts}",
                  meta.sig, acts.len());
        }
        if meta.cond_shape.is_some() != cond.is_some() {
            bail!("{}.{entry}: conditioning mismatch (layer takes cond: {})",
                  meta.sig, meta.cond_shape.is_some());
        }
        if params.len() != meta.params.len() {
            bail!("{}.{entry}: got {} params, want {}",
                  meta.sig, params.len(), meta.params.len());
        }
        match meta.kind.as_str() {
            "actnorm" => actnorm(entry, acts, params),
            "conv1x1" => conv1x1(entry, acts, params),
            "glowcpl" => glowcpl(entry, acts, params),
            "addcpl" => addcpl(entry, acts, params),
            "densecpl" => densecpl(entry, acts, params),
            "condcpl" => condcpl(entry, acts, cond.unwrap(), params),
            "haar" => haar(entry, acts),
            "permute" => permute(entry, acts),
            "hyper" => hyper(entry, acts, params),
            "hint" => hint(entry, acts, params, meta),
            other => bail!(
                "RefBackend does not implement layer kind {other:?} \
                 (sig {}); use the xla backend with compiled artifacts",
                meta.sig
            ),
        }
    }
}

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn execute_layer(
        &self,
        meta: &LayerMeta,
        entry: &str,
        acts: &[&Tensor],
        cond: Option<&Tensor>,
        params: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        if self.kernel_threads > 1 {
            super::math::par::with_kernel_threads(self.kernel_threads, || {
                self.dispatch(meta, entry, acts, cond, params)
            })
        } else {
            self.dispatch(meta, entry, acts, cond, params)
        }
    }

    fn execute_head(&self, entry: &str, z: &Tensor) -> Result<Vec<Tensor>> {
        let n = z.shape[0];
        match entry {
            "gaussian_logp" => {
                let dim = z.inner_len();
                let ln2pi = (2.0 * std::f32::consts::PI).ln();
                let data: Vec<f32> = z.data.chunks(dim).map(|row| {
                    let ss: f32 = row.iter().map(|v| v * v).sum();
                    -0.5 * ss - 0.5 * dim as f32 * ln2pi
                }).collect();
                Ok(vec![Tensor { shape: vec![n], data }])
            }
            "nll_seed" => {
                let inv_n = 1.0 / n as f32;
                let dz = Tensor {
                    shape: z.shape.clone(),
                    data: z.data.iter().map(|v| v * inv_n).collect(),
                };
                Ok(vec![dz, Tensor::full(&[n], -inv_n)])
            }
            other => bail!("unknown head entry {other:?}"),
        }
    }
}

// ---------------------------------------------------------------------------
// Shared coupling helpers
// ---------------------------------------------------------------------------

/// GLOW-stabilized coupling scale s = 2*sigmoid(raw), range (0, 2).
fn sigmoid2(raw: &Tensor) -> Tensor {
    Tensor {
        shape: raw.shape.clone(),
        data: raw.data.iter().map(|v| 2.0 / (1.0 + (-v).exp())).collect(),
    }
}

/// y2 = s * x2 + t
fn affine_fwd(x2: &Tensor, s: &Tensor, t: &Tensor) -> Tensor {
    Tensor {
        shape: x2.shape.clone(),
        data: x2.data.iter().zip(&s.data).zip(&t.data)
            .map(|((x, sv), tv)| x * sv + tv).collect(),
    }
}

/// x2 = (y2 - t) / s
fn affine_inv(y2: &Tensor, s: &Tensor, t: &Tensor) -> Tensor {
    Tensor {
        shape: y2.shape.clone(),
        data: y2.data.iter().zip(&s.data).zip(&t.data)
            .map(|((y, sv), tv)| (y - tv) / sv).collect(),
    }
}

/// Per-sample sum of ln(s): the coupling logdet.
fn log_sum_per_sample(s: &Tensor) -> Tensor {
    let n = s.shape[0];
    let inner = s.inner_len();
    let data: Vec<f32> = s.data.chunks(inner)
        .map(|row| row.iter().map(|v| v.ln()).sum())
        .collect();
    Tensor { shape: vec![n], data }
}

/// The affine-coupling pullback core shared by glowcpl/densecpl/condcpl/hint:
///   dx2  = dy2 * s
///   ds   = dy2 * x2 + dld / s        (per-sample dld broadcast)
///   draw = ds * s * (1 - s/2)        (d(2*sigmoid)/draw)
/// Returns (dx2, draw).
fn coupling_pullback(dy2: &Tensor, x2: &Tensor, s: &Tensor,
                     dld: &Tensor) -> (Tensor, Tensor) {
    let n = dy2.shape[0];
    let inner = dy2.inner_len();
    let mut dx2 = Vec::with_capacity(dy2.len());
    let mut draw = Vec::with_capacity(dy2.len());
    for i in 0..n {
        let dldv = dld.data[i];
        for k in 0..inner {
            let idx = i * inner + k;
            let sv = s.data[idx];
            let dy2v = dy2.data[idx];
            dx2.push(dy2v * sv);
            let dsv = dy2v * x2.data[idx] + dldv / sv;
            draw.push(dsv * sv * (1.0 - 0.5 * sv));
        }
    }
    (Tensor { shape: dy2.shape.clone(), data: dx2 },
     Tensor { shape: dy2.shape.clone(), data: draw })
}

fn zeros_ld(n: usize) -> Tensor {
    Tensor::zeros(&[n])
}

// ---------------------------------------------------------------------------
// ActNorm: y = x * exp(log_s) + b
// ---------------------------------------------------------------------------

fn actnorm(entry: &str, acts: &[&Tensor], p: &[Tensor]) -> Result<Vec<Tensor>> {
    let (log_s, b) = (&p[0], &p[1]);
    let c = log_s.len();
    let per_ch = |t: &Tensor, f: &mut dyn FnMut(usize, f32) -> f32| -> Tensor {
        let mut out = t.clone();
        for row in out.data.chunks_mut(c) {
            for (k, v) in row.iter_mut().enumerate() {
                *v = f(k, *v);
            }
        }
        out
    };
    let s: Vec<f32> = log_s.data.iter().map(|v| v.exp()).collect();
    match entry {
        "forward" => {
            let x = acts[0];
            let n = x.shape[0];
            let spatial: usize = x.shape[1..x.shape.len() - 1].iter().product();
            let y = per_ch(x, &mut |k, v| v * s[k] + b.data[k]);
            let ld = spatial as f32 * log_s.data.iter().sum::<f32>();
            Ok(vec![y, Tensor::full(&[n], ld)])
        }
        "inverse" => {
            let y = acts[0];
            Ok(vec![per_ch(y, &mut |k, v| (v - b.data[k]) / s[k])])
        }
        "backward" | "backward_stored" => {
            let (dy, dld, given) = (acts[0], acts[1], acts[2]);
            let spatial: usize = dy.shape[1..dy.shape.len() - 1].iter().product();
            // recover x (backward recomputes it from y; stored has it taped)
            let x = if entry == "backward" {
                per_ch(given, &mut |k, v| (v - b.data[k]) / s[k])
            } else {
                given.clone()
            };
            let dx = per_ch(dy, &mut |k, v| v * s[k]);
            // dlog_s = sum dy * (y - b) + sum(dld) * spatial; y - b = x * s
            let mut dlog_s = vec![0.0f32; c];
            for (dyrow, xrow) in dy.data.chunks(c).zip(x.data.chunks(c)) {
                for k in 0..c {
                    dlog_s[k] += dyrow[k] * xrow[k] * s[k];
                }
            }
            let dld_sum: f32 = dld.data.iter().sum();
            for v in &mut dlog_s {
                *v += dld_sum * spatial as f32;
            }
            let db = sum_to_last(dy);
            let dlog_s = Tensor { shape: vec![c], data: dlog_s };
            if entry == "backward" {
                Ok(vec![dx, dlog_s, db, x])
            } else {
                Ok(vec![dx, dlog_s, db])
            }
        }
        other => bail!("actnorm: unknown entry {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Conv1x1: y = W x per pixel, W = Householder product (orthogonal, logdet 0)
// ---------------------------------------------------------------------------

/// View a tensor as (rows, c) for channel-wise contractions (copies data).
fn flat_rows(t: &Tensor) -> Tensor {
    let c = *t.shape.last().unwrap();
    Tensor { shape: vec![t.len() / c, c], data: t.data.clone() }
}

fn conv1x1(entry: &str, acts: &[&Tensor], p: &[Tensor]) -> Result<Vec<Tensor>> {
    let vs = [&p[0], &p[1], &p[2]];
    let w = householder(&vs);
    match entry {
        "forward" => {
            let x = acts[0];
            Ok(vec![apply_mat(x, &w), zeros_ld(x.shape[0])])
        }
        "inverse" => Ok(vec![apply_mat_t(acts[0], &w)]),
        "backward" | "backward_stored" => {
            let dy = acts[0]; // acts[1] = dld unused: logdet == 0 identically
            let x = if entry == "backward" {
                apply_mat_t(acts[2], &w) // recompute x = Wᵀ y
            } else {
                acts[2].clone()
            };
            let dx = apply_mat_t(dy, &w);
            // dW_ij = sum_p dy_pi x_pj
            let dw = matmul_at(&flat_rows(dy), &flat_rows(&x));
            let mut dvs = householder_vjp(&vs, &dw);
            let (dv3, dv2, dv1) = (dvs.pop().unwrap(), dvs.pop().unwrap(),
                                   dvs.pop().unwrap());
            if entry == "backward" {
                Ok(vec![dx, dv1, dv2, dv3, x])
            } else {
                Ok(vec![dx, dv1, dv2, dv3])
            }
        }
        other => bail!("conv1x1: unknown entry {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// GLOW affine coupling (image, CNN conditioner)
// ---------------------------------------------------------------------------

fn glowcpl(entry: &str, acts: &[&Tensor], theta: &[Tensor]) -> Result<Vec<Tensor>> {
    let c = *acts.last().unwrap().shape.last().unwrap();
    let c1 = c / 2;
    let c2 = c - c1;
    match entry {
        "forward" => {
            let x = acts[0];
            let (x1, x2) = split_last_axis(x, c1)?;
            let (out, cache) = cnn_apply(&x1, theta);
            cache.recycle();
            let (raw, t) = split_last_axis(&out, c2)?;
            scratch::recycle(out);
            let s = sigmoid2(&raw);
            scratch::recycle(raw);
            let y2 = affine_fwd(&x2, &s, &t);
            Ok(vec![concat_last_axis(&x1, &y2)?, log_sum_per_sample(&s)])
        }
        "inverse" => {
            let y = acts[0];
            let (y1, y2) = split_last_axis(y, c1)?;
            let (out, cache) = cnn_apply(&y1, theta);
            cache.recycle();
            let (raw, t) = split_last_axis(&out, c2)?;
            scratch::recycle(out);
            let s = sigmoid2(&raw);
            scratch::recycle(raw);
            let x2 = affine_inv(&y2, &s, &t);
            Ok(vec![concat_last_axis(&y1, &x2)?])
        }
        "backward" | "backward_stored" => {
            let (dy, dld, given) = (acts[0], acts[1], acts[2]);
            let stored = entry == "backward_stored";
            // x1 == y1 either way (coupling passes the first half through)
            let (x1, second) = split_last_axis(given, c1)?;
            let (out, cache) = cnn_apply(&x1, theta);
            let (raw, t) = split_last_axis(&out, c2)?;
            scratch::recycle(out);
            let s = sigmoid2(&raw);
            scratch::recycle(raw);
            let x2 = if stored { second } else { affine_inv(&second, &s, &t) };
            let (dy1, dy2) = split_last_axis(dy, c1)?;
            let (dx2, draw) = coupling_pullback(&dy2, &x2, &s, dld);
            let dout = concat_last_axis(&draw, &dy2)?;
            scratch::recycle(draw);
            let (dx1_cnn, dtheta) = cnn_vjp(&dout, &x1, &cache, theta);
            scratch::recycle(dout);
            cache.recycle();
            let mut dx1 = dy1;
            for (v, g) in dx1.data.iter_mut().zip(&dx1_cnn.data) {
                *v += g;
            }
            scratch::recycle(dx1_cnn);
            let dx = concat_last_axis(&dx1, &dx2)?;
            let mut results = vec![dx];
            results.extend(dtheta);
            if !stored {
                results.push(concat_last_axis(&x1, &x2)?);
            }
            Ok(results)
        }
        other => bail!("glowcpl: unknown entry {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Additive (NICE) coupling: y = concat(x1, x2 + CNN(x1)), logdet 0
// ---------------------------------------------------------------------------

fn addcpl(entry: &str, acts: &[&Tensor], theta: &[Tensor]) -> Result<Vec<Tensor>> {
    let c = *acts.last().unwrap().shape.last().unwrap();
    let c1 = c / 2;
    match entry {
        "forward" => {
            let x = acts[0];
            let (x1, x2) = split_last_axis(x, c1)?;
            let (nn, cache) = cnn_apply(&x1, theta);
            cache.recycle();
            let mut y2 = x2;
            for (v, g) in y2.data.iter_mut().zip(&nn.data) {
                *v += g;
            }
            scratch::recycle(nn);
            Ok(vec![concat_last_axis(&x1, &y2)?, zeros_ld(x.shape[0])])
        }
        "inverse" => {
            let y = acts[0];
            let (y1, y2) = split_last_axis(y, c1)?;
            let (nn, cache) = cnn_apply(&y1, theta);
            cache.recycle();
            let mut x2 = y2;
            for (v, g) in x2.data.iter_mut().zip(&nn.data) {
                *v -= g;
            }
            scratch::recycle(nn);
            Ok(vec![concat_last_axis(&y1, &x2)?])
        }
        "backward" | "backward_stored" => {
            let (dy, _dld, given) = (acts[0], acts[1], acts[2]); // logdet == 0
            let stored = entry == "backward_stored";
            let (x1, second) = split_last_axis(given, c1)?;
            let (nn, cache) = cnn_apply(&x1, theta);
            let (dy1, dy2) = split_last_axis(dy, c1)?;
            let (dx1_cnn, dtheta) = cnn_vjp(&dy2, &x1, &cache, theta);
            cache.recycle();
            let mut dx1 = dy1;
            for (v, g) in dx1.data.iter_mut().zip(&dx1_cnn.data) {
                *v += g;
            }
            scratch::recycle(dx1_cnn);
            let dx = concat_last_axis(&dx1, &dy2)?;
            let mut results = vec![dx];
            results.extend(dtheta);
            if !stored {
                // x2 = y2 - CNN(y1)
                let mut x2 = second;
                for (v, g) in x2.data.iter_mut().zip(&nn.data) {
                    *v -= g;
                }
                results.push(concat_last_axis(&x1, &x2)?);
            }
            scratch::recycle(nn);
            Ok(results)
        }
        other => bail!("addcpl: unknown entry {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Dense coupling (RealNVP on (N, D)) + conditional variant
// ---------------------------------------------------------------------------

fn densecpl(entry: &str, acts: &[&Tensor], theta: &[Tensor]) -> Result<Vec<Tensor>> {
    dense_core(entry, acts, None, theta)
}

fn condcpl(entry: &str, acts: &[&Tensor], cond: &Tensor,
           theta: &[Tensor]) -> Result<Vec<Tensor>> {
    dense_core(entry, acts, Some(cond), theta)
}

fn dense_core(entry: &str, acts: &[&Tensor], cond: Option<&Tensor>,
              theta: &[Tensor]) -> Result<Vec<Tensor>> {
    let d = *acts.last().unwrap().shape.last().unwrap();
    let d1 = d / 2;
    let d2 = d - d1;
    let mlp_in = |x1: &Tensor| -> Result<Tensor> {
        match cond {
            Some(c) => concat_last_axis(x1, c),
            None => Ok(x1.clone()),
        }
    };
    match entry {
        "forward" => {
            let x = acts[0];
            let (x1, x2) = split_last_axis(x, d1)?;
            let net_in = mlp_in(&x1)?;
            let (out, cache) = mlp_apply(&net_in, theta);
            cache.recycle();
            scratch::recycle(net_in);
            let (raw, t) = split_last_axis(&out, d2)?;
            scratch::recycle(out);
            let s = sigmoid2(&raw);
            scratch::recycle(raw);
            let y2 = affine_fwd(&x2, &s, &t);
            Ok(vec![concat_last_axis(&x1, &y2)?, log_sum_per_sample(&s)])
        }
        "inverse" => {
            let y = acts[0];
            let (y1, y2) = split_last_axis(y, d1)?;
            let net_in = mlp_in(&y1)?;
            let (out, cache) = mlp_apply(&net_in, theta);
            cache.recycle();
            scratch::recycle(net_in);
            let (raw, t) = split_last_axis(&out, d2)?;
            scratch::recycle(out);
            let s = sigmoid2(&raw);
            scratch::recycle(raw);
            let x2 = affine_inv(&y2, &s, &t);
            Ok(vec![concat_last_axis(&y1, &x2)?])
        }
        "backward" | "backward_stored" => {
            let (dy, dld, given) = (acts[0], acts[1], acts[2]);
            let stored = entry == "backward_stored";
            let (x1, second) = split_last_axis(given, d1)?;
            let net_in = mlp_in(&x1)?;
            let (out, cache) = mlp_apply(&net_in, theta);
            let (raw, t) = split_last_axis(&out, d2)?;
            scratch::recycle(out);
            let s = sigmoid2(&raw);
            scratch::recycle(raw);
            let x2 = if stored { second } else { affine_inv(&second, &s, &t) };
            let (dy1, dy2) = split_last_axis(dy, d1)?;
            let (dx2, draw) = coupling_pullback(&dy2, &x2, &s, dld);
            let dout = concat_last_axis(&draw, &dy2)?;
            scratch::recycle(draw);
            let (din, dtheta) = mlp_vjp(&dout, &net_in, &cache, theta);
            scratch::recycle(dout);
            cache.recycle();
            scratch::recycle(net_in);
            // din covers (x1 | cond) jointly for the conditional variant
            let (dx1_net, dcond) = match cond {
                Some(_) => {
                    let (a, b) = split_last_axis(&din, d1)?;
                    (a, Some(b))
                }
                None => (din, None),
            };
            let mut dx1 = dy1;
            for (v, g) in dx1.data.iter_mut().zip(&dx1_net.data) {
                *v += g;
            }
            let dx = concat_last_axis(&dx1, &dx2)?;
            let mut results = vec![dx];
            if let Some(dc) = dcond {
                results.push(dc);
            }
            results.extend(dtheta);
            if !stored {
                results.push(concat_last_axis(&x1, &x2)?);
            }
            Ok(results)
        }
        other => bail!("densecpl: unknown entry {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Haar wavelet squeeze: (N,H,W,C) -> (N,H/2,W/2,4C), orthonormal, logdet 0
// ---------------------------------------------------------------------------

fn haar_fwd(x: &Tensor) -> Tensor {
    let (n, h, w, c) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (h2, w2) = (h / 2, w / 2);
    let mut out = vec![0.0f32; n * h2 * w2 * 4 * c];
    let xi = |b: usize, i: usize, j: usize| ((b * h + i) * w + j) * c;
    for b in 0..n {
        for i in 0..h2 {
            for j in 0..w2 {
                let a = xi(b, 2 * i, 2 * j);
                let bb = xi(b, 2 * i, 2 * j + 1);
                let cc = xi(b, 2 * i + 1, 2 * j);
                let dd = xi(b, 2 * i + 1, 2 * j + 1);
                let o = ((b * h2 + i) * w2 + j) * 4 * c;
                for k in 0..c {
                    let (av, bv, cv, dv) = (x.data[a + k], x.data[bb + k],
                                            x.data[cc + k], x.data[dd + k]);
                    out[o + k] = (av + bv + cv + dv) * 0.5;
                    out[o + c + k] = (av - bv + cv - dv) * 0.5;
                    out[o + 2 * c + k] = (av + bv - cv - dv) * 0.5;
                    out[o + 3 * c + k] = (av - bv - cv + dv) * 0.5;
                }
            }
        }
    }
    Tensor { shape: vec![n, h2, w2, 4 * c], data: out }
}

fn haar_inv(y: &Tensor) -> Tensor {
    let (n, h2, w2, c4) = (y.shape[0], y.shape[1], y.shape[2], y.shape[3]);
    let c = c4 / 4;
    let (h, w) = (h2 * 2, w2 * 2);
    let mut out = vec![0.0f32; n * h * w * c];
    let oi = |b: usize, i: usize, j: usize| ((b * h + i) * w + j) * c;
    for b in 0..n {
        for i in 0..h2 {
            for j in 0..w2 {
                let yoff = ((b * h2 + i) * w2 + j) * c4;
                let a = oi(b, 2 * i, 2 * j);
                let bb = oi(b, 2 * i, 2 * j + 1);
                let cc = oi(b, 2 * i + 1, 2 * j);
                let dd = oi(b, 2 * i + 1, 2 * j + 1);
                for k in 0..c {
                    let ll = y.data[yoff + k];
                    let lh = y.data[yoff + c + k];
                    let hl = y.data[yoff + 2 * c + k];
                    let hh = y.data[yoff + 3 * c + k];
                    out[a + k] = (ll + lh + hl + hh) * 0.5;
                    out[bb + k] = (ll - lh + hl - hh) * 0.5;
                    out[cc + k] = (ll + lh - hl - hh) * 0.5;
                    out[dd + k] = (ll - lh - hl + hh) * 0.5;
                }
            }
        }
    }
    Tensor { shape: vec![n, h, w, c], data: out }
}

fn haar(entry: &str, acts: &[&Tensor]) -> Result<Vec<Tensor>> {
    match entry {
        "forward" => {
            let x = acts[0];
            Ok(vec![haar_fwd(x), zeros_ld(x.shape[0])])
        }
        "inverse" => Ok(vec![haar_inv(acts[0])]),
        // orthonormal: gradient = transpose = inverse transform
        "backward" => Ok(vec![haar_inv(acts[0]), haar_inv(acts[2])]),
        "backward_stored" => Ok(vec![haar_inv(acts[0])]),
        other => bail!("haar: unknown entry {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Channel-reverse permutation (self-inverse orthogonal map)
// ---------------------------------------------------------------------------

fn rev_last(t: &Tensor) -> Tensor {
    let c = *t.shape.last().unwrap();
    let mut out = t.clone();
    for row in out.data.chunks_mut(c) {
        row.reverse();
    }
    out
}

fn permute(entry: &str, acts: &[&Tensor]) -> Result<Vec<Tensor>> {
    match entry {
        "forward" => Ok(vec![rev_last(acts[0]), zeros_ld(acts[0].shape[0])]),
        "inverse" => Ok(vec![rev_last(acts[0])]),
        "backward" => Ok(vec![rev_last(acts[0]), rev_last(acts[2])]),
        "backward_stored" => Ok(vec![rev_last(acts[0])]),
        other => bail!("permute: unknown entry {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// Hyperbolic leapfrog: state [x_prev | x_curr], g(x) = a K^T tanh(K x)
// ---------------------------------------------------------------------------

/// v = tanh(conv(x, kw)) — the activations the pullback needs.
fn hyper_v(x: &Tensor, kw: &Tensor) -> Tensor {
    let u = conv2d_same(x, kw);
    Tensor {
        shape: u.shape.clone(),
        data: u.data.iter().map(|a| a.tanh()).collect(),
    }
}

/// g(x) = ALPHA * conv_t(tanh(conv(x, kw)), kw); also returns v = tanh(u)
/// for the pullback.
fn hyper_g(x: &Tensor, kw: &Tensor) -> (Tensor, Tensor) {
    let v = hyper_v(x, kw);
    let kwf = flip_swap(kw);
    let mut g = conv2d_same(&v, &kwf);
    scratch::recycle(kwf);
    for a in &mut g.data {
        *a *= HYPER_ALPHA;
    }
    (g, v)
}

/// Pullback of `hyper_g` w.r.t. (x, kw), evaluated with the saved v.
fn hyper_g_vjp(dg: &Tensor, x: &Tensor, v: &Tensor, kw: &Tensor) -> (Tensor, Tensor) {
    // g = ALPHA * conv(v, flip_swap(kw)); adjoint wrt v is conv(dg, kw)
    let mut dv = conv2d_same(dg, kw);
    for a in &mut dv.data {
        *a *= HYPER_ALPHA;
    }
    // kernel grad through the conv_t branch (in flip_swap coordinates)
    let mut dw_t = conv2d_vjp_w(v, dg, 3, 3);
    for a in &mut dw_t.data {
        *a *= HYPER_ALPHA;
    }
    let dkw2 = flip_swap(&dw_t);
    scratch::recycle(dw_t);
    // du = dv * (1 - v^2)
    let du = Tensor {
        shape: dv.shape.clone(),
        data: dv.data.iter().zip(&v.data).map(|(d, t)| d * (1.0 - t * t)).collect(),
    };
    scratch::recycle(dv);
    let dx = conv2d_vjp_x(&du, kw);
    let mut dkw = conv2d_vjp_w(x, &du, 3, 3);
    scratch::recycle(du);
    for (a, b) in dkw.data.iter_mut().zip(&dkw2.data) {
        *a += b;
    }
    scratch::recycle(dkw2);
    (dx, dkw)
}

fn hyper(entry: &str, acts: &[&Tensor], p: &[Tensor]) -> Result<Vec<Tensor>> {
    let kw = &p[0];
    let c = *acts.last().unwrap().shape.last().unwrap() / 2;
    match entry {
        "forward" => {
            let x = acts[0];
            let (x_prev, x_curr) = split_last_axis(x, c)?;
            let (g, _) = hyper_g(&x_curr, kw);
            // y_prev = x_curr; y_curr = 2 x_curr - x_prev + g
            let y_curr = Tensor {
                shape: x_curr.shape.clone(),
                data: x_curr.data.iter().zip(&x_prev.data).zip(&g.data)
                    .map(|((xc, xp), gv)| 2.0 * xc - xp + gv).collect(),
            };
            Ok(vec![concat_last_axis(&x_curr, &y_curr)?, zeros_ld(x.shape[0])])
        }
        "inverse" => {
            let y = acts[0];
            let (y_prev, y_curr) = split_last_axis(y, c)?;
            // x_curr = y_prev; x_prev = 2 x_curr - y_curr + g(x_curr)
            let (g, _) = hyper_g(&y_prev, kw);
            let x_prev = Tensor {
                shape: y_prev.shape.clone(),
                data: y_prev.data.iter().zip(&y_curr.data).zip(&g.data)
                    .map(|((yp, yc), gv)| 2.0 * yp - yc + gv).collect(),
            };
            Ok(vec![concat_last_axis(&x_prev, &y_prev)?])
        }
        "backward" | "backward_stored" => {
            let (dy, _dld, given) = (acts[0], acts[1], acts[2]); // logdet == 0
            let stored = entry == "backward_stored";
            let (dy_prev, dy_curr) = split_last_axis(dy, c)?;
            let (x_curr, v, x_prev_opt) = if stored {
                let (_, x_curr) = split_last_axis(given, c)?;
                let v = hyper_v(&x_curr, kw); // g itself is not needed
                (x_curr, v, None)
            } else {
                // x_curr = y_prev; its g() both recomputes x_prev and
                // provides the tanh activations for the pullback
                let (y_prev, y_curr) = split_last_axis(given, c)?;
                let (g, v) = hyper_g(&y_prev, kw);
                let x_prev = Tensor {
                    shape: y_prev.shape.clone(),
                    data: y_prev.data.iter().zip(&y_curr.data).zip(&g.data)
                        .map(|((yp, yc), gv)| 2.0 * yp - yc + gv).collect(),
                };
                (y_prev, v, Some(x_prev))
            };
            let (gx, dkw) = hyper_g_vjp(&dy_curr, &x_curr, &v, kw);
            // dx_curr = dy_prev + 2 dy_curr + gx; dx_prev = -dy_curr
            let dx_curr = Tensor {
                shape: dy_curr.shape.clone(),
                data: dy_prev.data.iter().zip(&dy_curr.data).zip(&gx.data)
                    .map(|((dp, dc), g)| dp + 2.0 * dc + g).collect(),
            };
            let dx_prev = Tensor {
                shape: dy_curr.shape.clone(),
                data: dy_curr.data.iter().map(|d| -d).collect(),
            };
            let dx = concat_last_axis(&dx_prev, &dx_curr)?;
            if stored {
                Ok(vec![dx, dkw])
            } else {
                let x = concat_last_axis(&x_prev_opt.unwrap(), &x_curr)?;
                Ok(vec![dx, dkw, x])
            }
        }
        other => bail!("hyper: unknown entry {other:?}"),
    }
}

// ---------------------------------------------------------------------------
// HINT: recursive triangular coupling (Kruse et al.). One conditioner MLP
// per internal node, parameters flattened in preorder ("r", "rl", "rt", ...).
// ---------------------------------------------------------------------------

struct HintCtx<'a> {
    theta: &'a [Tensor],
    next: usize,
}

impl<'a> HintCtx<'a> {
    fn take(&mut self) -> &'a [Tensor] {
        let my = self.next;
        self.next += 1;
        &self.theta[my * 6..my * 6 + 6]
    }
}

fn hint_fwd(x: &Tensor, depth: usize, ctx: &mut HintCtx) -> (Tensor, Tensor) {
    let d = *x.shape.last().unwrap();
    let n = x.shape[0];
    if depth == 0 || d < HINT_MIN_D {
        return (x.clone(), zeros_ld(n));
    }
    let th = ctx.take();
    let d1 = d / 2;
    let d2 = d - d1;
    let (x1, x2) = split_last_axis(x, d1).expect("hint split");
    let (y1, ld1) = hint_fwd(&x1, depth - 1, ctx);
    let (out, cache) = mlp_apply(&x1, th);
    cache.recycle();
    let (raw, t) = split_last_axis(&out, d2).expect("hint raw/t split");
    scratch::recycle(out);
    let s = sigmoid2(&raw);
    scratch::recycle(raw);
    let y2a = affine_fwd(&x2, &s, &t);
    let ld_aff = log_sum_per_sample(&s);
    let (y2, ld2) = hint_fwd(&y2a, depth - 1, ctx);
    let mut ld = ld1;
    for ((a, b), c) in ld.data.iter_mut().zip(&ld_aff.data).zip(&ld2.data) {
        *a += b + c;
    }
    (concat_last_axis(&y1, &y2).expect("hint concat"), ld)
}

fn hint_inv(y: &Tensor, depth: usize, ctx: &mut HintCtx) -> Tensor {
    let d = *y.shape.last().unwrap();
    if depth == 0 || d < HINT_MIN_D {
        return y.clone();
    }
    let th = ctx.take();
    let d1 = d / 2;
    let d2 = d - d1;
    let (y1, y2) = split_last_axis(y, d1).expect("hint split");
    let x1 = hint_inv(&y1, depth - 1, ctx);
    let y2a = hint_inv(&y2, depth - 1, ctx);
    let (out, cache) = mlp_apply(&x1, th);
    cache.recycle();
    let (raw, t) = split_last_axis(&out, d2).expect("hint raw/t split");
    scratch::recycle(out);
    let s = sigmoid2(&raw);
    scratch::recycle(raw);
    let x2 = affine_inv(&y2a, &s, &t);
    concat_last_axis(&x1, &x2).expect("hint concat")
}

/// Returns (dx, x); fills `grads[node]` (preorder ids) with the node's
/// six parameter gradients.
fn hint_bwd(dy: &Tensor, dld: &Tensor, y: &Tensor, depth: usize,
            ctx: &mut HintCtx, grads: &mut [Option<Vec<Tensor>>])
            -> (Tensor, Tensor) {
    let d = *y.shape.last().unwrap();
    if depth == 0 || d < HINT_MIN_D {
        return (dy.clone(), y.clone());
    }
    let my = ctx.next;
    let th = ctx.take();
    let d1 = d / 2;
    let d2 = d - d1;
    let (dy1, dy2) = split_last_axis(dy, d1).expect("hint split");
    let (y1, y2) = split_last_axis(y, d1).expect("hint split");
    let (dx1a, x1) = hint_bwd(&dy1, dld, &y1, depth - 1, ctx, grads);
    let (dy2a, y2a) = hint_bwd(&dy2, dld, &y2, depth - 1, ctx, grads);
    let (out, cache) = mlp_apply(&x1, th);
    let (raw, t) = split_last_axis(&out, d2).expect("hint raw/t split");
    scratch::recycle(out);
    let s = sigmoid2(&raw);
    scratch::recycle(raw);
    let x2 = affine_inv(&y2a, &s, &t);
    let (dx2, draw) = coupling_pullback(&dy2a, &x2, &s, dld);
    let dout = concat_last_axis(&draw, &dy2a).expect("hint concat");
    scratch::recycle(draw);
    let (din, dtheta) = mlp_vjp(&dout, &x1, &cache, th);
    scratch::recycle(dout);
    cache.recycle();
    let mut dx1 = dx1a;
    for (v, g) in dx1.data.iter_mut().zip(&din.data) {
        *v += g;
    }
    grads[my] = Some(dtheta);
    (concat_last_axis(&dx1, &dx2).expect("hint concat"),
     concat_last_axis(&x1, &x2).expect("hint concat"))
}

fn hint(entry: &str, acts: &[&Tensor], theta: &[Tensor],
        meta: &LayerMeta) -> Result<Vec<Tensor>> {
    let depth = match meta.cfg_usize("depth") {
        Some(d) => d,
        None => bail!("{}: hint layer needs cfg.depth", meta.sig),
    };
    let n_nodes = theta.len() / 6;
    match entry {
        "forward" => {
            let mut ctx = HintCtx { theta, next: 0 };
            let (y, ld) = hint_fwd(acts[0], depth, &mut ctx);
            Ok(vec![y, ld])
        }
        "inverse" => {
            let mut ctx = HintCtx { theta, next: 0 };
            Ok(vec![hint_inv(acts[0], depth, &mut ctx)])
        }
        "backward" | "backward_stored" => {
            let (dy, dld, given) = (acts[0], acts[1], acts[2]);
            let stored = entry == "backward_stored";
            // stored path recovers y cheaply from the taped x, then runs the
            // identical pullback (matches the python layer)
            let y = if stored {
                let mut ctx = HintCtx { theta, next: 0 };
                hint_fwd(given, depth, &mut ctx).0
            } else {
                given.clone()
            };
            let mut grads: Vec<Option<Vec<Tensor>>> = vec![None; n_nodes];
            let mut ctx = HintCtx { theta, next: 0 };
            let (dx, x) = hint_bwd(dy, dld, &y, depth, &mut ctx, &mut grads);
            let mut results = vec![dx];
            for g in grads {
                results.extend(g.expect("hint node gradient missing"));
            }
            if !stored {
                results.push(x);
            }
            Ok(results)
        }
        other => bail!("hint: unknown entry {other:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::{NetworkDef, ParamStore};
    use crate::runtime::builtin_manifest;
    use crate::util::rng::Pcg64;

    fn rand_t(shape: &[usize], rng: &mut Pcg64) -> Tensor {
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(shape.iter().product()) }
    }

    /// forward -> inverse must round-trip for every layer kind in the
    /// builtin catalog, at the layer level (network level is covered by
    /// integration tests).
    #[test]
    fn every_layer_kind_roundtrips() {
        let m = builtin_manifest().unwrap();
        let backend = RefBackend::new();
        let mut rng = Pcg64::new(11);
        let mut kinds_seen = std::collections::BTreeSet::new();
        for net in ["realnvp2d", "cond_realnvp2d", "hint8d", "glow16",
                    "hyper16", "nice16"] {
            let def = NetworkDef::resolve(&m, net).unwrap();
            let params = ParamStore::init(&def, &m, 3).unwrap();
            for (i, step) in def.steps.iter().enumerate() {
                if step.kind != crate::flow::StepKind::Layer {
                    continue;
                }
                let meta = m.layer(&step.sig).unwrap();
                if !kinds_seen.insert(meta.sig.clone()) {
                    continue;
                }
                let x = rand_t(&step.in_shape, &mut rng);
                let cond_t = meta.cond_shape.as_ref()
                    .map(|s| rand_t(s, &mut rng));
                let outs = backend.execute_layer(
                    meta, "forward", &[&x], cond_t.as_ref(),
                    &params.tensors[i]).unwrap();
                assert_eq!(outs.len(), 2, "{}: forward arity", step.sig);
                let y = &outs[0];
                assert_eq!(y.shape, step.out_shape, "{}", step.sig);
                assert_eq!(outs[1].shape, vec![step.in_shape[0]]);
                let back = backend.execute_layer(
                    meta, "inverse", &[y], cond_t.as_ref(),
                    &params.tensors[i]).unwrap();
                let err = x.max_abs_diff(&back[0]);
                assert!(err < 1e-3, "{}: roundtrip err {err}", step.sig);
            }
        }
        assert!(kinds_seen.len() >= 8, "covered {kinds_seen:?}");
    }

    /// backward and backward_stored must agree on dx and parameter grads
    /// for a single layer (x taped vs recomputed from y).
    #[test]
    fn backward_matches_backward_stored_per_layer() {
        let m = builtin_manifest().unwrap();
        let backend = RefBackend::new();
        let mut rng = Pcg64::new(21);
        for net in ["realnvp2d", "glow16", "hyper16", "hint8d", "nice16"] {
            let def = NetworkDef::resolve(&m, net).unwrap();
            let params = ParamStore::init(&def, &m, 9).unwrap();
            let mut seen = std::collections::BTreeSet::new();
            for (i, step) in def.steps.iter().enumerate() {
                if step.kind != crate::flow::StepKind::Layer
                    || !seen.insert(step.sig.clone()) {
                    continue;
                }
                let meta = m.layer(&step.sig).unwrap();
                if meta.cond_shape.is_some() {
                    continue;
                }
                let n = step.in_shape[0];
                let x = rand_t(&step.in_shape, &mut rng);
                let y = backend.execute_layer(
                    meta, "forward", &[&x], None, &params.tensors[i])
                    .unwrap().remove(0);
                let dy = rand_t(&step.out_shape, &mut rng);
                let dld = rand_t(&[n], &mut rng);
                let bwd = backend.execute_layer(
                    meta, "backward", &[&dy, &dld, &y], None,
                    &params.tensors[i]).unwrap();
                let bwds = backend.execute_layer(
                    meta, "backward_stored", &[&dy, &dld, &x], None,
                    &params.tensors[i]).unwrap();
                assert_eq!(bwd.len(), bwds.len() + 1, "{}", step.sig);
                for (k, (a, b)) in bwd.iter().zip(&bwds).enumerate() {
                    let scale = a.linf().max(b.linf()).max(1.0);
                    let err = a.max_abs_diff(b);
                    assert!(err <= 2e-3 * scale,
                            "{} result {k}: {err} (scale {scale})", step.sig);
                }
                // last backward result is the recomputed input
                let x_rec = bwd.last().unwrap();
                assert!(x.max_abs_diff(x_rec) < 1e-3, "{} x_rec", step.sig);
            }
        }
    }

    #[test]
    fn heads_match_closed_form() {
        let backend = RefBackend::new();
        let mut rng = Pcg64::new(31);
        let z = rand_t(&[4, 3, 3, 2], &mut rng);
        let logp = backend.execute_head("gaussian_logp", &z).unwrap();
        assert_eq!(logp[0].shape, vec![4]);
        let dim = 18.0f32;
        for (i, row) in z.data.chunks(18).enumerate() {
            let ss: f32 = row.iter().map(|v| v * v).sum();
            let want = -0.5 * ss - 0.5 * dim * (2.0 * std::f32::consts::PI).ln();
            assert!((logp[0].data[i] - want).abs() < 1e-4);
        }
        let seeds = backend.execute_head("nll_seed", &z).unwrap();
        assert_eq!(seeds.len(), 2);
        assert!((seeds[0].data[0] - z.data[0] / 4.0).abs() < 1e-6);
        assert!((seeds[1].data[0] + 0.25).abs() < 1e-6);
        assert!(backend.execute_head("nope", &z).is_err());
    }

    #[test]
    fn rejects_malformed_calls() {
        let m = builtin_manifest().unwrap();
        let backend = RefBackend::new();
        let meta = m.layer("densecpl__256x2__hd64").unwrap();
        let x = Tensor::zeros(&[256, 2]);
        // wrong act arity
        assert!(backend.execute_layer(meta, "backward", &[&x], None, &[])
                .is_err());
        // wrong param count
        assert!(backend.execute_layer(meta, "forward", &[&x], None, &[])
                .is_err());
        // unexpected cond
        let def = NetworkDef::resolve(&m, "realnvp2d").unwrap();
        let params = ParamStore::init(&def, &m, 1).unwrap();
        assert!(backend.execute_layer(meta, "forward", &[&x], Some(&x),
                                      &params.tensors[0]).is_err());
        // unknown entry
        assert!(backend.execute_layer(meta, "sideways", &[&x], None,
                                      &params.tensors[0]).is_err());
    }
}
