//! `XlaBackend`: the PJRT runtime over AOT-compiled HLO-text artifacts
//! (`python -m compile.aot` -> `artifacts/`). Feature-gated behind
//! `--features xla`; the vendored `xla` crate is a stub documenting the
//! required API, so real execution needs an actual xla-rs checkout patched
//! in (see `rust/vendor/xla/src/lib.rs`).
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! instruction ids, which is what makes jax>=0.5 output loadable on
//! xla_extension 0.5.1 (see DESIGN.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{EntryMeta, LayerMeta, Manifest};
use crate::tensor::Tensor;

use super::Backend;

/// Convert the xla crate's error type into anyhow.
pub fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// Host tensor -> device literal.
// the one sanctioned `unsafe` in the crate (lib.rs denies it globally):
// a read-only f32 -> u8 view of an initialized, fully-in-bounds Vec
#[allow(unsafe_code)]
pub fn to_literal(t: &Tensor) -> Result<xla::Literal> {
    // single-copy path (vec1 + reshape would copy twice)
    let bytes = unsafe {
        std::slice::from_raw_parts(
            t.data.as_ptr() as *const u8,
            t.data.len() * std::mem::size_of::<f32>(),
        )
    };
    xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32, &t.shape, bytes)
        .map_err(xerr)
}

/// Device literal -> host tensor.
pub fn from_literal(lit: &xla::Literal) -> Result<Tensor> {
    let shape = lit.array_shape().map_err(xerr)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(xerr)?;
    Tensor::new(dims, data)
}

/// A compiled (layer, entry) artifact ready to execute.
pub struct CompiledEntry {
    pub key: String,
    pub meta: EntryMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledEntry {
    /// Execute with host literals; returns one literal per manifest result
    /// (the PJRT result tuple is decomposed).
    pub fn execute(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.meta.operands.len() {
            bail!("{}: got {} operands, manifest wants {}",
                  self.key, args.len(), self.meta.operands.len());
        }
        let out = self.exe.execute::<&xla::Literal>(args).map_err(xerr)?;
        let lit = out[0][0].to_literal_sync().map_err(xerr)?;
        // aot.py lowers with return_tuple=True: always a tuple root.
        let parts = lit.to_tuple().map_err(xerr)?;
        if parts.len() != self.meta.results.len() {
            bail!("{}: got {} results, manifest wants {}",
                  self.key, parts.len(), self.meta.results.len());
        }
        Ok(parts)
    }

    /// Execute and convert every result to a host [`Tensor`].
    pub fn execute_t(&self, args: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        self.execute(args)?.iter().map(from_literal).collect()
    }
}

/// PJRT client + artifact directory + executable cache.
///
/// Compilation is lazy and cached per artifact file: a training loop
/// compiles each of its network's entries exactly once.
pub struct XlaBackend {
    client: xla::PjRtClient,
    manifest: Arc<Manifest>,
    dir: PathBuf,
    cache: Mutex<HashMap<String, Arc<CompiledEntry>>>,
}

impl XlaBackend {
    /// CPU-backed runtime over an artifact directory (`artifacts/`).
    pub fn new(artifact_dir: &Path) -> Result<XlaBackend> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        Self::with_manifest(artifact_dir, manifest)
    }

    /// Share an already-loaded manifest (the `Engine` builder path).
    pub fn with_manifest(artifact_dir: &Path, manifest: Arc<Manifest>)
                         -> Result<XlaBackend> {
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(XlaBackend {
            client,
            manifest,
            dir: artifact_dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, meta: &EntryMeta, key: &str) -> Result<Arc<CompiledEntry>> {
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?)
            .map_err(xerr)
            .with_context(|| format!("loading {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)
            .with_context(|| format!("compiling {key}"))?;
        Ok(Arc::new(CompiledEntry {
            key: key.to_string(),
            meta: meta.clone(),
            exe,
        }))
    }

    fn cached(&self, key: &str, meta: &EntryMeta) -> Result<Arc<CompiledEntry>> {
        if let Some(hit) = self.cache.lock().unwrap().get(key) {
            return Ok(hit.clone());
        }
        let compiled = self.compile(meta, key)?;
        self.cache.lock().unwrap()
            .insert(key.to_string(), compiled.clone());
        Ok(compiled)
    }

    /// Compiled whole-network full-AD ablation program (see
    /// `python/compile/model.py::full_vjp_fn`). Cached.
    pub fn monolith_entry(&self, net: &str) -> Result<Arc<CompiledEntry>> {
        let meta = self.manifest.monoliths.get(net)
            .ok_or_else(|| anyhow!("no monolith artifact for {net}"))?
            .clone();
        self.cached(&format!("monolith_{net}"), &meta)
    }

    /// Number of compiled executables held in the cache.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl Backend for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn execute_layer(
        &self,
        meta: &LayerMeta,
        entry: &str,
        acts: &[&Tensor],
        cond: Option<&Tensor>,
        params: &[Tensor],
    ) -> Result<Vec<Tensor>> {
        let emeta = meta.entry(entry)?;
        let key = format!("{}.{entry}", meta.sig);
        let compiled = self.cached(&key, emeta)?;
        // NOTE: parameters are re-uploaded as literals on every call. The
        // old ParamStore literal cache amortized this to one upload per
        // optimizer step; restoring that here needs a param-version hook
        // on ParamStore (worth doing if the xla path becomes hot again).
        let mut lits: Vec<xla::Literal> = Vec::with_capacity(
            acts.len() + cond.is_some() as usize + params.len());
        for a in acts {
            lits.push(to_literal(a)?);
        }
        if let Some(c) = cond {
            lits.push(to_literal(c)?);
        }
        for p in params {
            lits.push(to_literal(p)?);
        }
        let args: Vec<&xla::Literal> = lits.iter().collect();
        compiled.execute_t(&args)
            .with_context(|| format!("executing {key}"))
    }

    fn execute_head(&self, entry: &str, z: &Tensor) -> Result<Vec<Tensor>> {
        let head = self.manifest.head_for(&z.shape)?;
        let tag = crate::runtime::shape_tag(&z.shape);
        let emeta = head.entries.get(entry)
            .ok_or_else(|| anyhow!("head {tag} has no entry {entry}"))?
            .clone();
        let compiled = self.cached(&format!("head_{tag}.{entry}"), &emeta)?;
        let lit = to_literal(z)?;
        compiled.execute_t(&[&lit])
    }

    /// Drop all compiled executables (used by benches between configs to
    /// keep executable memory out of the activation measurements).
    fn clear_cache(&self) {
        self.cache.lock().unwrap().clear();
    }
}
