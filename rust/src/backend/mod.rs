//! Execution backends: the [`Backend`] trait owns *how* layer programs run;
//! the coordinator owns *when* (activation lifetimes, the paper's actual
//! contribution). Decoupling the two is what lets the crate build, run and
//! test hermetically.
//!
//! * [`RefBackend`] (default): pure-Rust per-layer math, zero artifacts.
//! * `XlaBackend` (`--features xla`): the original PJRT runtime over
//!   AOT-compiled HLO artifacts from `python -m compile.aot`.
//!
//! Operand convention (shared with aot.py): activations first, then the
//! conditioning tensor (if the layer takes one), then the parameters.
//! Entries and their activation operands / results:
//!
//! | entry             | acts            | results                          |
//! |-------------------|-----------------|----------------------------------|
//! | `forward`         | `[x]`           | `[y, logdet]`                    |
//! | `inverse`         | `[y]`           | `[x]`                            |
//! | `backward`        | `[dy, dld, y]`  | `[dx, (dcond), dθ..., x]`        |
//! | `backward_stored` | `[dy, dld, x]`  | `[dx, (dcond), dθ...]`           |

pub mod math;
pub mod reference;
// module binding named `xla_backend` so in-crate paths never collide with
// (or grep like) the external `xla` crate — which stays confined to the
// file itself
#[cfg(feature = "xla")]
#[path = "xla.rs"]
pub mod xla_backend;

use anyhow::Result;

use crate::runtime::LayerMeta;
use crate::tensor::Tensor;

pub use reference::RefBackend;
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

/// Reduced-precision *storage* formats for inference weights. Compute is
/// always f32: a non-f32 dtype means weights are rounded through the
/// half-width format exactly once at load time ([`Backend::load_weight`])
/// and widened straight back, so what the kernels see is an f32 tensor
/// carrying the storage format's precision contract (bf16: relative error
/// <= 2^-8; f16: <= 2^-11 over the normal range, saturating past 65504).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum WeightDtype {
    #[default]
    F32,
    Bf16,
    F16,
}

impl WeightDtype {
    /// Parse a CLI-style dtype name ("f32" | "bf16" | "f16").
    pub fn parse(s: &str) -> Option<WeightDtype> {
        match s {
            "f32" => Some(WeightDtype::F32),
            "bf16" => Some(WeightDtype::Bf16),
            "f16" => Some(WeightDtype::F16),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WeightDtype::F32 => "f32",
            WeightDtype::Bf16 => "bf16",
            WeightDtype::F16 => "f16",
        }
    }
}

/// A program-execution substrate. `Send + Sync` so owned flow handles can
/// cross threads.
pub trait Backend: Send + Sync {
    /// Short backend identifier ("ref", "xla", ...).
    fn name(&self) -> &'static str;

    /// Import one weight tensor under the engine's weight-storage dtype.
    /// The default rounds the buffer through the requested half format in
    /// place (compute stays f32); backends with genuinely typed device
    /// buffers may override to keep the narrow representation resident.
    fn load_weight(&self, t: &mut Tensor, dtype: WeightDtype) {
        match dtype {
            WeightDtype::F32 => {}
            WeightDtype::Bf16 => math::half::round_bf16_slice(&mut t.data),
            WeightDtype::F16 => math::half::round_f16_slice(&mut t.data),
        }
    }

    /// Execute one layer entry. `acts` follows the entry's activation
    /// convention (see module docs); `cond` is present exactly when
    /// `meta.cond_shape` is; `params` are the step's parameter tensors in
    /// manifest order.
    fn execute_layer(
        &self,
        meta: &LayerMeta,
        entry: &str,
        acts: &[&Tensor],
        cond: Option<&Tensor>,
        params: &[Tensor],
    ) -> Result<Vec<Tensor>>;

    /// Execute a Gaussian-head entry on a latent:
    /// `"gaussian_logp"` -> `[logp (N,)]`, `"nll_seed"` -> `[dz, dld]`.
    fn execute_head(&self, entry: &str, z: &Tensor) -> Result<Vec<Tensor>>;

    /// Drop any cached executables (bench hygiene between configs).
    /// No-op for stateless backends.
    fn clear_cache(&self) {}
}
