//! Execution backends: the [`Backend`] trait owns *how* layer programs run;
//! the coordinator owns *when* (activation lifetimes, the paper's actual
//! contribution). Decoupling the two is what lets the crate build, run and
//! test hermetically.
//!
//! * [`RefBackend`] (default): pure-Rust per-layer math, zero artifacts.
//! * `XlaBackend` (`--features xla`): the original PJRT runtime over
//!   AOT-compiled HLO artifacts from `python -m compile.aot`.
//!
//! Operand convention (shared with aot.py): activations first, then the
//! conditioning tensor (if the layer takes one), then the parameters.
//! Entries and their activation operands / results:
//!
//! | entry             | acts            | results                          |
//! |-------------------|-----------------|----------------------------------|
//! | `forward`         | `[x]`           | `[y, logdet]`                    |
//! | `inverse`         | `[y]`           | `[x]`                            |
//! | `backward`        | `[dy, dld, y]`  | `[dx, (dcond), dθ..., x]`        |
//! | `backward_stored` | `[dy, dld, x]`  | `[dx, (dcond), dθ...]`           |

pub mod math;
pub mod reference;
// module binding named `xla_backend` so in-crate paths never collide with
// (or grep like) the external `xla` crate — which stays confined to the
// file itself
#[cfg(feature = "xla")]
#[path = "xla.rs"]
pub mod xla_backend;

use anyhow::Result;

use crate::runtime::LayerMeta;
use crate::tensor::Tensor;

pub use reference::RefBackend;
#[cfg(feature = "xla")]
pub use xla_backend::XlaBackend;

/// A program-execution substrate. `Send + Sync` so owned flow handles can
/// cross threads.
pub trait Backend: Send + Sync {
    /// Short backend identifier ("ref", "xla", ...).
    fn name(&self) -> &'static str;

    /// Execute one layer entry. `acts` follows the entry's activation
    /// convention (see module docs); `cond` is present exactly when
    /// `meta.cond_shape` is; `params` are the step's parameter tensors in
    /// manifest order.
    fn execute_layer(
        &self,
        meta: &LayerMeta,
        entry: &str,
        acts: &[&Tensor],
        cond: Option<&Tensor>,
        params: &[Tensor],
    ) -> Result<Vec<Tensor>>;

    /// Execute a Gaussian-head entry on a latent:
    /// `"gaussian_logp"` -> `[logp (N,)]`, `"nll_seed"` -> `[dz, dld]`.
    fn execute_head(&self, entry: &str, z: &Tensor) -> Result<Vec<Tensor>>;

    /// Drop any cached executables (bench hygiene between configs).
    /// No-op for stateless backends.
    fn clear_cache(&self) {}
}
