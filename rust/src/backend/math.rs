//! Dense math kernels for the pure-Rust [`super::RefBackend`]: SAME-padded
//! NHWC convolution (im2col + packed GEMM) with its input/weight VJPs,
//! a cache-tiled packed GEMM built on hand-unrolled 8-wide microkernels,
//! conditioner networks (CNN/MLP) with hand-written pullbacks, the
//! Householder orthogonal parameterization used by Conv1x1, and the
//! bf16/f16 weight-storage conversions.
//!
//! Every routine here was cross-validated against the JAX reference layers
//! in `python/compile/layers/` before being transcribed (forward, inverse
//! and gradient paths all agree to f32 precision), and the vectorized
//! kernels are pinned against the scalar references in [`naive`] by the
//! kernel-equivalence suite (`rust/tests/kernels.rs`).
//!
//! # Kernel architecture
//!
//! The GEMM packs B once per call into column panels of width [`NR`]=8
//! (zero-padded tails), then sweeps 4-row blocks of A against the packed
//! panels with a 4x8 register-accumulator microkernel ([`fma8`]). Each
//! output cell is a single serial k-ascending sum — the 32 in-flight
//! accumulators give the ILP, not split sums — so results are bitwise
//! independent of the blocking and of [`par::kernel_threads`] (threads
//! split disjoint row ranges; no cross-thread reduction exists).
//! Convolutions lower to the same GEMM through a SAME-padded im2col whose
//! column order matches the HWIO weight row order.

use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Scratch workspace: a thread-local free-list of f32 buffers
// ---------------------------------------------------------------------------

/// The training inner loop executes the same layer shapes thousands of
/// times; allocating a fresh `Vec` per matmul/conv dominated allocator
/// traffic. Kernels take their output, packing and im2col buffers from
/// this thread-local pool, and callers `recycle` dead intermediates so the
/// buffers cycle instead of round-tripping through the allocator. The pool
/// is per-thread, so the data-parallel workers never contend on it.
pub mod scratch {
    use std::cell::{Cell, RefCell};
    use std::sync::{Arc, OnceLock};

    use crate::telemetry::Counter;
    use crate::tensor::Tensor;

    /// Free-list count cap (cheap scans).
    const MAX_POOLED: usize = 32;
    /// Floor of the per-thread byte budget. Large-image nets (64x64+)
    /// produce multi-MiB im2col slabs; the old fixed 8 MiB cap made every
    /// layer call on such nets a fresh allocation.
    const BASE_POOLED_BYTES: usize = 32 << 20; // 32 MiB
    /// Hard ceiling on the adaptive budget so a pass over a pathological
    /// net cannot pin unbounded dead memory per thread.
    const HARD_CAP_BYTES: usize = 256 << 20; // 256 MiB

    thread_local! {
        static POOL: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
        /// Largest single request seen on this thread, in bytes. The pool
        /// budget scales with it so the working set of the biggest planned
        /// activation (plus its GEMM-side buffers) always fits.
        static HIGH_WATER: Cell<usize> = const { Cell::new(0) };
    }

    /// Pool telemetry (this is the hottest instrumented path in the
    /// crate: one counter bump per kernel buffer request). Handles are
    /// cached in `OnceLock`s so steady state is a relaxed `fetch_add` —
    /// the registry lock is taken once per process, not per event.
    fn hits() -> &'static Arc<Counter> {
        static C: OnceLock<Arc<Counter>> = OnceLock::new();
        C.get_or_init(|| {
            crate::telemetry::global().counter("invertnet_scratch_hits_total")
        })
    }

    fn misses() -> &'static Arc<Counter> {
        static C: OnceLock<Arc<Counter>> = OnceLock::new();
        C.get_or_init(|| {
            crate::telemetry::global()
                .counter("invertnet_scratch_misses_total")
        })
    }

    fn miss_bytes() -> &'static Arc<Counter> {
        static C: OnceLock<Arc<Counter>> = OnceLock::new();
        C.get_or_init(|| {
            crate::telemetry::global()
                .counter("invertnet_scratch_miss_bytes_total")
        })
    }

    /// Current per-thread byte budget: max(32 MiB, 4x the largest single
    /// request seen on this thread), capped at 256 MiB. Exposed so the
    /// throughput suite's scratch-miss regression check can report it.
    pub fn pool_budget_bytes() -> usize {
        HIGH_WATER.with(|h| {
            BASE_POOLED_BYTES
                .max(h.get().saturating_mul(4))
                .min(HARD_CAP_BYTES)
        })
    }

    fn take_impl(len: usize, zero: bool) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        HIGH_WATER.with(|h| {
            if len * 4 > h.get() {
                h.set(len * 4);
            }
        });
        POOL.with(|pool| {
            let mut pool = pool.borrow_mut();
            let mut best: Option<(usize, usize)> = None; // (idx, capacity)
            for (i, b) in pool.iter().enumerate() {
                let c = b.capacity();
                if c >= len && best.map_or(true, |(_, bc)| c < bc) {
                    best = Some((i, c));
                }
            }
            match best {
                Some((i, _)) => {
                    hits().inc();
                    let mut b = pool.swap_remove(i);
                    if zero {
                        b.clear();
                        b.resize(len, 0.0);
                    } else {
                        // keep whatever initialized values are already
                        // there; only the grown tail (if any) is filled
                        b.resize(len, 0.0);
                    }
                    b
                }
                None => {
                    misses().inc();
                    miss_bytes().add(len as u64 * 4);
                    vec![0.0f32; len]
                }
            }
        })
    }

    /// A zeroed buffer of `len` f32s, reusing the smallest adequate pooled
    /// allocation when one exists. For accumulating consumers.
    pub fn take(len: usize) -> Vec<f32> {
        take_impl(len, true)
    }

    /// Like [`take`] but skips the zero-fill on pooled reuse: contents are
    /// arbitrary (stale but initialized) values. ONLY for consumers that
    /// write every element before reading — it saves a full memset per
    /// kernel call on the training hot path.
    pub fn take_any(len: usize) -> Vec<f32> {
        take_impl(len, false)
    }

    /// Return a buffer to the pool for reuse. Dropped (deallocated) when
    /// the pool is at its count cap or the byte budget would overflow.
    pub fn put(buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        let budget = pool_budget_bytes();
        POOL.with(|pool| {
            let mut pool = pool.borrow_mut();
            let held: usize = pool.iter().map(|b| b.capacity() * 4).sum();
            if pool.len() < MAX_POOLED
                && held + buf.capacity() * 4 <= budget
            {
                pool.push(buf);
            }
        });
    }

    /// Recycle a dead intermediate tensor's storage.
    pub fn recycle(t: Tensor) {
        put(t.data);
    }
}

// ---------------------------------------------------------------------------
// Kernel-internal parallelism knob
// ---------------------------------------------------------------------------

/// Intra-kernel thread count for the GEMM/conv row-split paths. This is a
/// per-thread setting (default 1 = serial) so the data-parallel outer
/// loops (ParallelTrainer workers, `infer_parallel` forks) never nest
/// thread pools unless explicitly asked to. Because the kernels split
/// disjoint output-row ranges and every cell keeps its serial k-ascending
/// accumulation order, results are bit-identical at *any* thread count.
pub mod par {
    use std::cell::Cell;

    thread_local! {
        static KERNEL_THREADS: Cell<usize> = const { Cell::new(1) };
    }

    /// Threads the current thread's kernel calls may fan out to.
    pub fn kernel_threads() -> usize {
        KERNEL_THREADS.with(|c| c.get().max(1))
    }

    /// Set the intra-kernel thread count for the current thread.
    pub fn set_kernel_threads(n: usize) {
        KERNEL_THREADS.with(|c| c.set(n.max(1)));
    }

    /// Run `f` with the intra-kernel thread count set to `n`, restoring
    /// the previous value afterwards (RAII-style for backend dispatch).
    pub fn with_kernel_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
        let prev = kernel_threads();
        set_kernel_threads(n);
        let r = f();
        set_kernel_threads(prev);
        r
    }
}

// ---------------------------------------------------------------------------
// Reduced-precision weight storage (bf16 / IEEE f16)
// ---------------------------------------------------------------------------

/// Conversions for the `Backend`-level reduced-precision *storage* mode:
/// inference weights are rounded through bf16 or f16 once at load time and
/// widened straight back, so all compute stays f32 while the stored values
/// carry the half-width precision contract (bf16: 8 significand bits,
/// relative error <= 2^-8; f16: 11 significand bits, <= 2^-11 over the
/// normal range, subnormal below 2^-14, overflow to inf above 65504).
/// Rounding is IEEE round-to-nearest-even in both directions of interest.
pub mod half {
    /// f32 -> bf16 bits, round-to-nearest-even. NaN payloads are quieted.
    pub fn f32_to_bf16(x: f32) -> u16 {
        let bits = x.to_bits();
        if x.is_nan() {
            return ((bits >> 16) as u16) | 0x0040;
        }
        let round = ((bits >> 16) & 1) + 0x7FFF;
        ((bits.wrapping_add(round)) >> 16) as u16
    }

    /// bf16 bits -> f32 (exact: bf16 is a truncated f32).
    pub fn bf16_to_f32(h: u16) -> f32 {
        f32::from_bits((h as u32) << 16)
    }

    /// f32 -> IEEE binary16 bits, round-to-nearest-even, with subnormal
    /// and overflow-to-infinity handling.
    pub fn f32_to_f16(x: f32) -> u16 {
        let bits = x.to_bits();
        let sign = ((bits >> 16) & 0x8000) as u16;
        let exp32 = ((bits >> 23) & 0xFF) as i32;
        let man = bits & 0x007F_FFFF;
        if exp32 == 255 {
            // inf / nan: keep nan-ness, quiet the payload
            return if man == 0 { sign | 0x7C00 } else { sign | 0x7E00 };
        }
        let exp = exp32 - 127 + 15;
        if exp >= 31 {
            return sign | 0x7C00; // overflow -> inf
        }
        if exp <= 0 {
            if exp < -10 {
                return sign; // underflow -> signed zero
            }
            // subnormal: shift the (implicit-1) significand into place
            let full = man | 0x0080_0000;
            let shift = (14 - exp) as u32; // 14..=24
            let half_man = full >> shift;
            let rem = full & ((1u32 << shift) - 1);
            let halfway = 1u32 << (shift - 1);
            let up = rem > halfway || (rem == halfway && (half_man & 1) == 1);
            return sign | (half_man + up as u32) as u16;
        }
        let half_man = man >> 13;
        let rem = man & 0x1FFF;
        let mut h = ((exp as u32) << 10) | half_man;
        if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
            h += 1; // carry may roll into the exponent; that is correct
        }
        sign | h as u16
    }

    /// IEEE binary16 bits -> f32 (exact).
    pub fn f16_to_f32(h: u16) -> f32 {
        let neg = h & 0x8000 != 0;
        let exp = (h >> 10) & 0x1F;
        let man = (h & 0x3FF) as f32;
        let mag = match exp {
            0 => man * (-24f32).exp2(),
            31 => {
                if man == 0.0 {
                    f32::INFINITY
                } else {
                    return f32::NAN;
                }
            }
            e => (1.0 + man * (-10f32).exp2()) * ((e as i32 - 15) as f32).exp2(),
        };
        if neg {
            -mag
        } else {
            mag
        }
    }

    /// Round a buffer through bf16 storage precision in place.
    pub fn round_bf16_slice(data: &mut [f32]) {
        for v in data {
            *v = bf16_to_f32(f32_to_bf16(*v));
        }
    }

    /// Round a buffer through f16 storage precision in place.
    pub fn round_f16_slice(data: &mut [f32]) {
        for v in data {
            *v = f16_to_f32(f32_to_f16(*v));
        }
    }
}

fn dims4(t: &Tensor) -> (usize, usize, usize, usize) {
    assert_eq!(t.shape.len(), 4, "expected rank-4 tensor, got {:?}", t.shape);
    (t.shape[0], t.shape[1], t.shape[2], t.shape[3])
}

fn dims2(t: &Tensor) -> (usize, usize) {
    assert_eq!(t.shape.len(), 2, "expected rank-2 tensor, got {:?}", t.shape);
    (t.shape[0], t.shape[1])
}

// ---------------------------------------------------------------------------
// Packed GEMM: NR=8 column panels, 4x8 register microkernel
// ---------------------------------------------------------------------------

/// Panel width: one microkernel column tile, matching an 8-lane f32 SIMD
/// register on the targets we care about.
const NR: usize = 8;
/// Row-block height: rows of A held live against one packed panel.
const MR: usize = 4;

/// The 8-wide accumulate: acc += a * b[0..8], hand-unrolled so the
/// optimizer sees eight independent lane updates (one vfmadd on AVX2).
#[inline(always)]
fn fma8(acc: &mut [f32; NR], a: f32, b: &[f32]) {
    acc[0] += a * b[0];
    acc[1] += a * b[1];
    acc[2] += a * b[2];
    acc[3] += a * b[3];
    acc[4] += a * b[4];
    acc[5] += a * b[5];
    acc[6] += a * b[6];
    acc[7] += a * b[7];
}

fn panels_of(m: usize) -> usize {
    (m + NR - 1) / NR
}

/// Pack row-major B (k x m) into column panels: panel `pj` holds columns
/// `pj*NR .. pj*NR+8` contiguously by k, tail columns zero-padded. Packed
/// once per layer call and reused across every row block of A.
fn pack_b_panels(b: &[f32], k: usize, m: usize) -> Vec<f32> {
    let panels = panels_of(m);
    let mut packed = scratch::take_any(panels * k * NR);
    for pj in 0..panels {
        let j0 = pj * NR;
        let w = NR.min(m - j0);
        let dst = &mut packed[pj * k * NR..][..k * NR];
        for p in 0..k {
            let d = &mut dst[p * NR..][..NR];
            d[..w].copy_from_slice(&b[p * m + j0..][..w]);
            d[w..].fill(0.0);
        }
    }
    packed
}

/// Pack transposed-layout B (m x k row-major, i.e. `bt[j*k + p]`) into the
/// same panel layout as [`pack_b_panels`].
fn pack_bt_panels(bt: &[f32], k: usize, m: usize) -> Vec<f32> {
    let panels = panels_of(m);
    let mut packed = scratch::take_any(panels * k * NR);
    for pj in 0..panels {
        let j0 = pj * NR;
        let w = NR.min(m - j0);
        let dst = &mut packed[pj * k * NR..][..k * NR];
        for jj in 0..w {
            let src = &bt[(j0 + jj) * k..][..k];
            for p in 0..k {
                dst[p * NR + jj] = src[p];
            }
        }
        if w < NR {
            for p in 0..k {
                dst[p * NR + w..p * NR + NR].fill(0.0);
            }
        }
    }
    packed
}

/// out[r, j] = sum_p a[r, p] * B[p, j] over packed panels; every output
/// cell is a single serial k-ascending sum written exactly once. Main loop
/// is the 4x8 microkernel (32 live accumulators); row tails fall back to a
/// 1x8 kernel; column tails are zero-padded in the panels and trimmed on
/// store.
fn gemm_packed(a: &[f32], packed: &[f32], rows: usize, k: usize, m: usize,
               out: &mut [f32]) {
    let panels = panels_of(m);
    let mut r = 0;
    while r + MR <= rows {
        let a0 = &a[r * k..][..k];
        let a1 = &a[(r + 1) * k..][..k];
        let a2 = &a[(r + 2) * k..][..k];
        let a3 = &a[(r + 3) * k..][..k];
        for pj in 0..panels {
            let bp = &packed[pj * k * NR..][..k * NR];
            let mut acc = [[0.0f32; NR]; MR];
            for p in 0..k {
                let b8 = &bp[p * NR..][..NR];
                fma8(&mut acc[0], a0[p], b8);
                fma8(&mut acc[1], a1[p], b8);
                fma8(&mut acc[2], a2[p], b8);
                fma8(&mut acc[3], a3[p], b8);
            }
            let j0 = pj * NR;
            let w = NR.min(m - j0);
            for (i, accr) in acc.iter().enumerate() {
                out[(r + i) * m + j0..][..w].copy_from_slice(&accr[..w]);
            }
        }
        r += MR;
    }
    while r < rows {
        let ar = &a[r * k..][..k];
        for pj in 0..panels {
            let bp = &packed[pj * k * NR..][..k * NR];
            let mut acc = [0.0f32; NR];
            for p in 0..k {
                fma8(&mut acc, ar[p], &bp[p * NR..][..NR]);
            }
            let j0 = pj * NR;
            let w = NR.min(m - j0);
            out[r * m + j0..][..w].copy_from_slice(&acc[..w]);
        }
        r += 1;
    }
}

/// Minimum per-thread multiply count before the row-split parallel path
/// engages (thread spawn + im2col slab setup must amortize).
const PAR_MIN_WORK: usize = 1 << 18;

/// Row-split parallel GEMM: output rows are partitioned into contiguous
/// disjoint chunks, one scoped thread each. No cross-thread reduction, so
/// the result is bitwise identical to the serial kernel.
fn gemm_rows_parallel(a: &[f32], packed: &[f32], rows: usize, k: usize,
                      m: usize, out: &mut [f32]) {
    let mut t = par::kernel_threads().min(rows.max(1));
    while t > 1 && rows * k * m / t < PAR_MIN_WORK {
        t -= 1;
    }
    if t <= 1 {
        return gemm_packed(a, packed, rows, k, m, out);
    }
    let chunk = (rows + t - 1) / t;
    std::thread::scope(|s| {
        for (ti, o) in out.chunks_mut(chunk * m).enumerate() {
            let r0 = ti * chunk;
            let nr = o.len() / m;
            let ar = &a[r0 * k..][..nr * k];
            s.spawn(move || gemm_packed(ar, packed, nr, k, m, o));
        }
    });
}

// ---------------------------------------------------------------------------
// Convolution (stride 1, SAME, NHWC x HWIO) + VJPs
// ---------------------------------------------------------------------------

/// Write `nrows` im2col rows starting at flattened pixel row `r0` into
/// `dst` (each row is kh*kw*ci wide, column order (di, dj, ci) matching
/// the HWIO weight row order; out-of-bounds taps are zero).
fn im2col_into(x: &Tensor, kh: usize, kw: usize, r0: usize, nrows: usize,
               dst: &mut [f32]) {
    let (_, h, wd, ci) = dims4(x);
    let (ph, pw) = (kh / 2, kw / 2);
    let kk = kh * kw * ci;
    for rr in 0..nrows {
        let r = r0 + rr;
        let b = r / (h * wd);
        let rem = r % (h * wd);
        let i = rem / wd;
        let j = rem % wd;
        let drow = &mut dst[rr * kk..][..kk];
        for di in 0..kh {
            let si = (i + di).wrapping_sub(ph);
            for dj in 0..kw {
                let sj = (j + dj).wrapping_sub(pw);
                let d = &mut drow[(di * kw + dj) * ci..][..ci];
                if si >= h || sj >= wd {
                    d.fill(0.0);
                } else {
                    d.copy_from_slice(
                        &x.data[((b * h + si) * wd + sj) * ci..][..ci]);
                }
            }
        }
    }
}

/// The full im2col matrix for a stride-1 SAME conv: (n*h*w, kh*kw*ci).
/// `conv2d_same(x, w) == im2col_same(x, kh, kw) @ w.reshape(kh*kw*ci, co)`.
pub fn im2col_same(x: &Tensor, kh: usize, kw: usize) -> Tensor {
    let (n, h, wd, ci) = dims4(x);
    let rows = n * h * wd;
    let kk = kh * kw * ci;
    let mut out = scratch::take_any(rows * kk);
    im2col_into(x, kh, kw, 0, rows, &mut out);
    Tensor { shape: vec![rows, kk], data: out }
}

/// y[b,i,j,o] = sum_{di,dj,c} x[b, i+di-ph, j+dj-pw, c] * w[di,dj,c,o]
/// with zero padding (odd kernels: 1x1 or 3x3 here).
///
/// 1x1 kernels run as one pointwise GEMM over the flattened pixel rows;
/// general kernels lower through im2col into the same packed GEMM. Both
/// paths split output rows across [`par::kernel_threads`] when the work
/// amortizes a spawn (each thread builds its own im2col slab — the im2col
/// is parallel, not just the GEMM).
pub fn conv2d_same(x: &Tensor, w: &Tensor) -> Tensor {
    let (n, h, wd, ci) = dims4(x);
    let (kh, kw, wci, co) = dims4(w);
    assert_eq!(ci, wci, "conv channel mismatch: {ci} vs {wci}");
    let rows = n * h * wd;
    if kh == 1 && kw == 1 {
        let packed = pack_b_panels(&w.data, ci, co);
        let mut out = scratch::take_any(rows * co);
        gemm_rows_parallel(&x.data, &packed, rows, ci, co, &mut out);
        scratch::put(packed);
        return Tensor { shape: vec![n, h, wd, co], data: out };
    }
    let kk = kh * kw * ci;
    let packed = pack_b_panels(&w.data, kk, co);
    let mut out = scratch::take_any(rows * co);
    let mut t = par::kernel_threads().min(rows.max(1));
    while t > 1 && rows * kk * co / t < PAR_MIN_WORK {
        t -= 1;
    }
    if t <= 1 {
        let mut cols = scratch::take_any(rows * kk);
        im2col_into(x, kh, kw, 0, rows, &mut cols);
        gemm_packed(&cols, &packed, rows, kk, co, &mut out);
        scratch::put(cols);
    } else {
        let chunk = (rows + t - 1) / t;
        let packed = &packed[..];
        std::thread::scope(|s| {
            for (ti, o) in out.chunks_mut(chunk * co).enumerate() {
                let r0 = ti * chunk;
                let nr = o.len() / co;
                s.spawn(move || {
                    let mut cols = scratch::take_any(nr * kk);
                    im2col_into(x, kh, kw, r0, nr, &mut cols);
                    gemm_packed(&cols, packed, nr, kk, co, o);
                    scratch::put(cols);
                });
            }
        });
    }
    scratch::put(packed);
    Tensor { shape: vec![n, h, wd, co], data: out }
}

/// Spatially flip and swap the I/O axes of an HWIO kernel:
/// (kh,kw,ci,co) -> (kh,kw,co,ci). `conv2d_same(dy, flip_swap(w))` is the
/// adjoint of `conv2d_same(., w)` for stride-1 SAME odd kernels.
pub fn flip_swap(w: &Tensor) -> Tensor {
    let (kh, kw, ci, co) = dims4(w);
    let mut out = scratch::take_any(w.data.len());
    for di in 0..kh {
        for dj in 0..kw {
            for ii in 0..ci {
                for oo in 0..co {
                    let src = ((di * kw + dj) * ci + ii) * co + oo;
                    let dst = (((kh - 1 - di) * kw + (kw - 1 - dj)) * co + oo)
                        * ci + ii;
                    out[dst] = w.data[src];
                }
            }
        }
    }
    Tensor { shape: vec![kh, kw, co, ci], data: out }
}

/// dL/dx of `conv2d_same(x, w)` given dL/dy.
pub fn conv2d_vjp_x(dy: &Tensor, w: &Tensor) -> Tensor {
    let wf = flip_swap(w);
    let dx = conv2d_same(dy, &wf);
    scratch::recycle(wf);
    dx
}

/// dL/dw of `conv2d_same(x, w)` given dL/dy:
/// dw[di,dj,c,o] = sum_{b,i,j} x[b, i+di-ph, j+dj-pw, c] * dy[b,i,j,o].
///
/// Deliberately scalar and row-serial: the accumulation order over samples
/// (b, i, j ascending) is the canonical one the data-parallel gradient
/// reduction is compared against (`train::parallel`), so this kernel is a
/// numerics contract, not a throughput path.
pub fn conv2d_vjp_w(x: &Tensor, dy: &Tensor, kh: usize, kw: usize) -> Tensor {
    let (n, h, wd, ci) = dims4(x);
    let (_, _, _, co) = dims4(dy);
    if kh == 1 && kw == 1 {
        // pointwise kernel grad == matmul_at over the flattened pixel
        // rows; same row-serial accumulation order as the general loop
        // below (b, i, j ascending), so the numerics are bit-identical
        let rows = n * h * wd;
        let mut dw = scratch::take(ci * co);
        for r in 0..rows {
            let xrow = &x.data[r * ci..][..ci];
            let dyrow = &dy.data[r * co..][..co];
            for (p, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let orow = &mut dw[p * co..][..co];
                for (o, &g) in orow.iter_mut().zip(dyrow) {
                    *o += xv * g;
                }
            }
        }
        return Tensor { shape: vec![1, 1, ci, co], data: dw };
    }
    let (ph, pw) = (kh / 2, kw / 2);
    let mut dw = scratch::take(kh * kw * ci * co);
    for b in 0..n {
        for i in 0..h {
            for j in 0..wd {
                let dyrow = &dy.data[((b * h + i) * wd + j) * co..][..co];
                for di in 0..kh {
                    let si = (i + di).wrapping_sub(ph);
                    if si >= h {
                        continue;
                    }
                    for dj in 0..kw {
                        let sj = (j + dj).wrapping_sub(pw);
                        if sj >= wd {
                            continue;
                        }
                        let xrow = &x.data[((b * h + si) * wd + sj) * ci..][..ci];
                        let dwblk = &mut dw[(di * kw + dj) * ci * co..][..ci * co];
                        for (ii, &xv) in xrow.iter().enumerate() {
                            if xv == 0.0 {
                                continue;
                            }
                            let drow = &mut dwblk[ii * co..][..co];
                            for (d, &g) in drow.iter_mut().zip(dyrow) {
                                *d += xv * g;
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor { shape: vec![kh, kw, ci, co], data: dw }
}

// ---------------------------------------------------------------------------
// Small matmuls (row-major, over the packed-panel GEMM)
// ---------------------------------------------------------------------------

/// Dot product with four independent accumulators (ILP/SIMD friendly;
/// the serial-dependency chain of a naive fold defeats vectorization).
/// Used by the Householder path, where operands are short rows.
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut i = 0;
    while i + 4 <= n {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// (rows, cols) row-major -> (cols, rows) row-major.
fn transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    for i in 0..rows {
        for j in 0..cols {
            dst[j * rows + i] = src[i * cols + j];
        }
    }
}

/// (n,k) x (k,m) -> (n,m)
///
/// B is packed into panels on every call; at O(k*m) against the O(n*k*m)
/// kernel this is <1% for the shapes here, which is why there is no
/// per-weight packed cache (that would need weight identity tracking
/// across ParamStore updates).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, k) = dims2(a);
    let (k2, m) = dims2(b);
    assert_eq!(k, k2, "matmul inner dim: {k} vs {k2}");
    let packed = pack_b_panels(&b.data, k, m);
    let mut out = scratch::take_any(n * m);
    gemm_rows_parallel(&a.data, &packed, n, k, m, &mut out);
    scratch::put(packed);
    Tensor { shape: vec![n, m], data: out }
}

/// aᵀ b: (n,k) x (n,m) -> (k,m)
///
/// Accumulates row-serially over `n` (the batch axis) so the f32
/// summation order over samples is the canonical one the data-parallel
/// reduction is compared against (`train::parallel`).
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, k) = dims2(a);
    let (n2, m) = dims2(b);
    assert_eq!(n, n2, "matmul_at outer dim: {n} vs {n2}");
    let mut out = scratch::take(k * m);
    for i in 0..n {
        let arow = &a.data[i * k..(i + 1) * k];
        let brow = &b.data[i * m..(i + 1) * m];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // post-ReLU activations are ~half zeros
            }
            let orow = &mut out[p * m..(p + 1) * m];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    Tensor { shape: vec![k, m], data: out }
}

/// a bᵀ: (n,m) x (k,m) -> (n,k). `b` arrives in the transposed layout, so
/// it packs through [`pack_bt_panels`] without a materialized transpose.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, m) = dims2(a);
    let (k, m2) = dims2(b);
    assert_eq!(m, m2, "matmul_bt inner dim: {m} vs {m2}");
    let packed = pack_bt_panels(&b.data, m, k);
    let mut out = scratch::take_any(n * k);
    gemm_rows_parallel(&a.data, &packed, n, m, k, &mut out);
    scratch::put(packed);
    Tensor { shape: vec![n, k], data: out }
}

fn mat_t(a: &Tensor) -> Tensor {
    let (n, m) = dims2(a);
    let mut out = scratch::take_any(n * m);
    transpose_into(&a.data, n, m, &mut out);
    Tensor { shape: vec![m, n], data: out }
}

// ---------------------------------------------------------------------------
// Elementwise / reduction helpers
// ---------------------------------------------------------------------------

/// t[..., c] += bias[c]  (broadcast over leading axes)
pub fn add_bias(t: &mut Tensor, bias: &Tensor) {
    let c = bias.len();
    assert_eq!(*t.shape.last().unwrap(), c, "bias width mismatch");
    for row in t.data.chunks_mut(c) {
        for (v, &b) in row.iter_mut().zip(&bias.data) {
            *v += b;
        }
    }
}

pub fn relu_inplace(t: &mut Tensor) {
    for v in &mut t.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// d *= (act > 0), elementwise (ReLU pullback; `act` is the post-ReLU value).
pub fn relu_mask(d: &mut Tensor, act: &Tensor) {
    debug_assert_eq!(d.shape, act.shape);
    for (v, &a) in d.data.iter_mut().zip(&act.data) {
        if a <= 0.0 {
            *v = 0.0;
        }
    }
}

/// Sum over all leading axes -> (c,) where c is the last dim.
pub fn sum_to_last(t: &Tensor) -> Tensor {
    let c = *t.shape.last().unwrap();
    let mut out = vec![0.0f32; c];
    for row in t.data.chunks(c) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    Tensor { shape: vec![c], data: out }
}

// ---------------------------------------------------------------------------
// Conditioner networks: 3-layer MLP and CNN with hand-written pullbacks
// (python: compile/layers/conditioner.py, differentiated there by jax.vjp)
// ---------------------------------------------------------------------------

/// Post-ReLU hidden activations saved by the forward pass for the pullback.
pub struct NetCache {
    h1: Tensor,
    h2: Tensor,
}

impl NetCache {
    /// Hand the hidden-activation buffers back to the scratch pool.
    /// Callers that run the forward pass without a pullback (inverse paths,
    /// logdet-only evaluation) use this so the two largest per-layer
    /// temporaries never hit the allocator in steady state.
    pub fn recycle(self) {
        scratch::recycle(self.h1);
        scratch::recycle(self.h2);
    }
}

/// out = (relu(relu(x w1 + b1) w2 + b2)) w3 + b3 on (N, D) inputs.
pub fn mlp_apply(x: &Tensor, theta: &[Tensor]) -> (Tensor, NetCache) {
    let mut h1 = matmul(x, &theta[0]);
    add_bias(&mut h1, &theta[1]);
    relu_inplace(&mut h1);
    let mut h2 = matmul(&h1, &theta[2]);
    add_bias(&mut h2, &theta[3]);
    relu_inplace(&mut h2);
    let mut out = matmul(&h2, &theta[4]);
    add_bias(&mut out, &theta[5]);
    (out, NetCache { h1, h2 })
}

/// Pullback of [`mlp_apply`]: returns (dx, [dw1,db1,dw2,db2,dw3,db3]).
pub fn mlp_vjp(dout: &Tensor, x: &Tensor, cache: &NetCache,
               theta: &[Tensor]) -> (Tensor, Vec<Tensor>) {
    let dw3 = matmul_at(&cache.h2, dout);
    let db3 = sum_to_last(dout);
    let mut dh2 = matmul_bt(dout, &theta[4]);
    relu_mask(&mut dh2, &cache.h2);
    let dw2 = matmul_at(&cache.h1, &dh2);
    let db2 = sum_to_last(&dh2);
    let mut dh1 = matmul_bt(&dh2, &theta[2]);
    relu_mask(&mut dh1, &cache.h1);
    let dw1 = matmul_at(x, &dh1);
    let db1 = sum_to_last(&dh1);
    let dx = matmul_bt(&dh1, &theta[0]);
    scratch::recycle(dh1);
    scratch::recycle(dh2);
    (dx, vec![dw1, db1, dw2, db2, dw3, db3])
}

/// GLOW conditioner CNN: conv3x3 -> relu -> conv1x1 -> relu -> conv3x3
/// on NHWC inputs.
pub fn cnn_apply(x: &Tensor, theta: &[Tensor]) -> (Tensor, NetCache) {
    let mut h1 = conv2d_same(x, &theta[0]);
    add_bias(&mut h1, &theta[1]);
    relu_inplace(&mut h1);
    let mut h2 = conv2d_same(&h1, &theta[2]);
    add_bias(&mut h2, &theta[3]);
    relu_inplace(&mut h2);
    let mut out = conv2d_same(&h2, &theta[4]);
    add_bias(&mut out, &theta[5]);
    (out, NetCache { h1, h2 })
}

/// Pullback of [`cnn_apply`]: returns (dx, [dw1,db1,dw2,db2,dw3,db3]).
pub fn cnn_vjp(dout: &Tensor, x: &Tensor, cache: &NetCache,
               theta: &[Tensor]) -> (Tensor, Vec<Tensor>) {
    let dw3 = conv2d_vjp_w(&cache.h2, dout, 3, 3);
    let db3 = sum_to_last(dout);
    let mut dh2 = conv2d_vjp_x(dout, &theta[4]);
    relu_mask(&mut dh2, &cache.h2);
    let dw2 = conv2d_vjp_w(&cache.h1, &dh2, 1, 1);
    let db2 = sum_to_last(&dh2);
    let mut dh1 = conv2d_vjp_x(&dh2, &theta[2]);
    relu_mask(&mut dh1, &cache.h1);
    let dw1 = conv2d_vjp_w(x, &dh1, 3, 3);
    let db1 = sum_to_last(&dh1);
    let dx = conv2d_vjp_x(&dh1, &theta[0]);
    scratch::recycle(dh1);
    scratch::recycle(dh2);
    (dx, vec![dw1, db1, dw2, db2, dw3, db3])
}

// ---------------------------------------------------------------------------
// Householder orthogonal parameterization (Conv1x1)
// ---------------------------------------------------------------------------

fn eye(c: usize) -> Tensor {
    let mut data = scratch::take(c * c);
    for i in 0..c {
        data[i * c + i] = 1.0;
    }
    Tensor { shape: vec![c, c], data }
}

fn single_householder(v: &Tensor) -> Tensor {
    let c = v.len();
    let s: f32 = v.data.iter().map(|x| x * x).sum();
    let f = 2.0 / s;
    let mut h = eye(c);
    for i in 0..c {
        for j in 0..c {
            h.data[i * c + j] -= f * v.data[i] * v.data[j];
        }
    }
    h
}

/// W = H(v1) H(v2) ... H(vk) with H(v) = I - 2 v vᵀ / (vᵀ v); orthogonal.
pub fn householder(vs: &[&Tensor]) -> Tensor {
    let c = vs[0].len();
    let mut w = eye(c);
    for v in vs {
        let s: f32 = v.data.iter().map(|x| x * x).sum();
        let f = 2.0 / s;
        // w <- w - f * (w v) vᵀ
        let mut wv = scratch::take_any(c);
        for (i, o) in wv.iter_mut().enumerate() {
            *o = dot(&w.data[i * c..(i + 1) * c], &v.data);
        }
        for i in 0..c {
            for j in 0..c {
                w.data[i * c + j] -= f * wv[i] * v.data[j];
            }
        }
        scratch::put(wv);
    }
    w
}

/// Pullback of [`householder`] onto the reflection vectors:
/// dH_k = A_kᵀ dW B_kᵀ with A_k/B_k the prefix/suffix products, then
/// dv = -(2/s)(dH v + dHᵀ v) + (4 (vᵀ dH v)/s²) v.
pub fn householder_vjp(vs: &[&Tensor], dw: &Tensor) -> Vec<Tensor> {
    let c = vs[0].len();
    let hs: Vec<Tensor> = vs.iter().map(|v| single_householder(v)).collect();
    let mut dvs = Vec::with_capacity(vs.len());
    for (k, v) in vs.iter().enumerate() {
        let mut a = eye(c);
        for h in &hs[..k] {
            let next = matmul(&a, h);
            scratch::recycle(std::mem::replace(&mut a, next));
        }
        let mut b = eye(c);
        for h in &hs[k + 1..] {
            let next = matmul(&b, h);
            scratch::recycle(std::mem::replace(&mut b, next));
        }
        let at = mat_t(&a);
        let bt = mat_t(&b);
        let at_dw = matmul(&at, dw);
        let g = matmul(&at_dw, &bt);
        scratch::recycle(a);
        scratch::recycle(b);
        scratch::recycle(at);
        scratch::recycle(bt);
        scratch::recycle(at_dw);
        let s: f32 = v.data.iter().map(|x| x * x).sum();
        let gv: Vec<f32> = (0..c).map(|i| {
            g.data[i * c..(i + 1) * c].iter().zip(&v.data).map(|(x, y)| x * y).sum()
        }).collect();
        let gtv: Vec<f32> = (0..c).map(|j| {
            (0..c).map(|i| g.data[i * c + j] * v.data[i]).sum()
        }).collect();
        let vgv: f32 = v.data.iter().zip(&gv).map(|(x, y)| x * y).sum();
        scratch::recycle(g);
        let data: Vec<f32> = (0..c).map(|j| {
            -(2.0 / s) * (gv[j] + gtv[j]) + (4.0 * vgv / (s * s)) * v.data[j]
        }).collect();
        dvs.push(Tensor { shape: vec![c], data });
    }
    for h in hs {
        scratch::recycle(h);
    }
    dvs
}

/// y_p = W x_p applied along the last axis (einsum "...j,ij->...i").
pub fn apply_mat(x: &Tensor, w: &Tensor) -> Tensor {
    let c = *x.shape.last().unwrap();
    let rows = x.len() / c;
    // W's rows are contiguous dot operands, i.e. already the transposed
    // layout: out[r, i] = dot(x_r, w_i)
    let packed = pack_bt_panels(&w.data, c, c);
    let mut out = scratch::take_any(x.len());
    gemm_rows_parallel(&x.data, &packed, rows, c, c, &mut out);
    scratch::put(packed);
    Tensor { shape: x.shape.clone(), data: out }
}

/// x_p = Wᵀ y_p along the last axis (einsum "...i,ij->...j").
pub fn apply_mat_t(y: &Tensor, w: &Tensor) -> Tensor {
    let c = *y.shape.last().unwrap();
    let rows = y.len() / c;
    let mut out = scratch::take(y.len());
    for r in 0..rows {
        let yr = &y.data[r * c..(r + 1) * c];
        let or = &mut out[r * c..(r + 1) * c];
        for (i, &yv) in yr.iter().enumerate() {
            if yv == 0.0 {
                continue;
            }
            let wrow = &w.data[i * c..(i + 1) * c];
            for (o, &wv) in or.iter_mut().zip(wrow) {
                *o += yv * wv;
            }
        }
    }
    Tensor { shape: y.shape.clone(), data: out }
}

// ---------------------------------------------------------------------------
// Naive scalar references
// ---------------------------------------------------------------------------

/// Unblocked, unpacked scalar kernels: the ground truth the vectorized
/// paths are pinned against (kernel-equivalence suite) and the baseline
/// the throughput suite's gated speedup metrics are measured from. Not
/// used on any production path.
pub mod naive {
    use super::{dims2, dims4};
    use crate::tensor::Tensor;

    /// Scalar triple-loop (n,k) x (k,m) -> (n,m).
    pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
        let (n, k) = dims2(a);
        let (k2, m) = dims2(b);
        assert_eq!(k, k2, "matmul inner dim: {k} vs {k2}");
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            for j in 0..m {
                let mut s = 0.0f32;
                for p in 0..k {
                    s += a.data[i * k + p] * b.data[p * m + j];
                }
                out[i * m + j] = s;
            }
        }
        Tensor { shape: vec![n, m], data: out }
    }

    /// Scalar scatter-loop SAME conv (the pre-vectorization kernel; no
    /// 1x1 fast path, no im2col).
    pub fn conv2d_same(x: &Tensor, w: &Tensor) -> Tensor {
        let (n, h, wd, ci) = dims4(x);
        let (kh, kw, wci, co) = dims4(w);
        assert_eq!(ci, wci, "conv channel mismatch: {ci} vs {wci}");
        let (ph, pw) = (kh / 2, kw / 2);
        let mut out = vec![0.0f32; n * h * wd * co];
        for b in 0..n {
            for i in 0..h {
                for j in 0..wd {
                    let orow = &mut out[((b * h + i) * wd + j) * co..][..co];
                    for di in 0..kh {
                        let si = (i + di).wrapping_sub(ph);
                        if si >= h {
                            continue;
                        }
                        for dj in 0..kw {
                            let sj = (j + dj).wrapping_sub(pw);
                            if sj >= wd {
                                continue;
                            }
                            let xrow =
                                &x.data[((b * h + si) * wd + sj) * ci..][..ci];
                            let wblk =
                                &w.data[(di * kw + dj) * ci * co..][..ci * co];
                            for (ii, &xv) in xrow.iter().enumerate() {
                                let wrow = &wblk[ii * co..][..co];
                                for (o, &wv) in orow.iter_mut().zip(wrow) {
                                    *o += xv * wv;
                                }
                            }
                        }
                    }
                }
            }
        }
        Tensor { shape: vec![n, h, wd, co], data: out }
    }

    /// Direct-indexing im2col reference (one scalar gather per cell).
    pub fn im2col_same(x: &Tensor, kh: usize, kw: usize) -> Tensor {
        let (n, h, wd, ci) = dims4(x);
        let (ph, pw) = (kh / 2, kw / 2);
        let rows = n * h * wd;
        let kk = kh * kw * ci;
        let mut out = vec![0.0f32; rows * kk];
        for b in 0..n {
            for i in 0..h {
                for j in 0..wd {
                    let r = (b * h + i) * wd + j;
                    for di in 0..kh {
                        for dj in 0..kw {
                            for c in 0..ci {
                                let si = (i + di).wrapping_sub(ph);
                                let sj = (j + dj).wrapping_sub(pw);
                                let v = if si < h && sj < wd {
                                    x.data[((b * h + si) * wd + sj) * ci + c]
                                } else {
                                    0.0
                                };
                                out[r * kk + (di * kw + dj) * ci + c] = v;
                            }
                        }
                    }
                }
            }
        }
        Tensor { shape: vec![rows, kk], data: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_t(shape: &[usize], rng: &mut Pcg64) -> Tensor {
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(shape.iter().product()) }
    }

    fn dot(a: &Tensor, b: &Tensor) -> f64 {
        a.data.iter().zip(&b.data).map(|(x, y)| (*x as f64) * (*y as f64)).sum()
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 identity kernel leaves x unchanged
        let mut rng = Pcg64::new(1);
        let x = rand_t(&[2, 3, 3, 2], &mut rng);
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let y = conv2d_same(&x, &w);
        assert!(x.max_abs_diff(&y) < 1e-6);
    }

    #[test]
    fn conv_matches_hand_computed() {
        // single channel 2x2 image, 3x3 kernel of ones: SAME conv = local sums
        let x = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::full(&[3, 3, 1, 1], 1.0);
        let y = conv2d_same(&x, &w);
        assert_eq!(y.data, vec![10.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn conv_vjps_are_adjoint() {
        // <conv(x,w), dy> == <x, vjp_x(dy,w)> == <w, vjp_w(x,dy)>
        let mut rng = Pcg64::new(2);
        let x = rand_t(&[2, 4, 5, 3], &mut rng);
        let w = rand_t(&[3, 3, 3, 4], &mut rng);
        let dy = rand_t(&[2, 4, 5, 4], &mut rng);
        let lhs = dot(&conv2d_same(&x, &w), &dy);
        let via_x = dot(&x, &conv2d_vjp_x(&dy, &w));
        let via_w = dot(&w, &conv2d_vjp_w(&x, &dy, 3, 3));
        assert!((lhs - via_x).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} {via_x}");
        assert!((lhs - via_w).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} {via_w}");
    }

    /// The packed-panel GEMM must agree with the naive triple loop on
    /// shapes around both blocking boundaries: the 4-row block and the
    /// 8-column panel (1x1, ragged rows, odd columns, exact multiples).
    #[test]
    fn packed_gemm_matches_naive() {
        let mut rng = Pcg64::new(71);
        for (n, k, m) in [(1, 1, 1), (3, 5, 7), (4, 8, 16), (5, 3, 2),
                          (7, 66, 9), (8, 4, 4), (9, 13, 17), (4, 1, 8),
                          (13, 7, 25), (16, 32, 8)] {
            let a = rand_t(&[n, k], &mut rng);
            let b = rand_t(&[k, m], &mut rng);
            let fast = matmul(&a, &b);
            let want = naive::matmul(&a, &b);
            assert!(fast.max_abs_diff(&want) < 1e-5,
                    "({n},{k},{m}): {}", fast.max_abs_diff(&want));
        }
    }

    /// im2col columns must match the direct-indexing reference, and the
    /// lowered conv must match the scalar scatter loop, including odd
    /// channel counts and non-multiple-of-8 output widths.
    #[test]
    fn im2col_conv_matches_naive() {
        let mut rng = Pcg64::new(73);
        for (n, h, w, ci, co) in [(1, 1, 1, 1, 1), (2, 4, 5, 3, 4),
                                  (1, 3, 3, 7, 9), (2, 2, 6, 5, 8)] {
            let x = rand_t(&[n, h, w, ci], &mut rng);
            let cols = im2col_same(&x, 3, 3);
            let want_cols = naive::im2col_same(&x, 3, 3);
            assert_eq!(cols.shape, want_cols.shape);
            assert!(cols.max_abs_diff(&want_cols) == 0.0, "im2col mismatch");
            let wt = rand_t(&[3, 3, ci, co], &mut rng);
            let fast = conv2d_same(&x, &wt);
            let want = naive::conv2d_same(&x, &wt);
            assert!(fast.max_abs_diff(&want) < 1e-5,
                    "({n},{h},{w},{ci},{co}): {}", fast.max_abs_diff(&want));
        }
    }

    /// 1x1 convs take the pointwise-GEMM fast path; it must agree with
    /// the general im2col path (exercised via a 1x1 kernel padded to 3x3
    /// with zeros, which routes through the general path).
    #[test]
    fn conv_1x1_fast_path_matches_general() {
        let mut rng = Pcg64::new(72);
        let x = rand_t(&[2, 5, 3, 4], &mut rng);
        let w1 = rand_t(&[1, 1, 4, 6], &mut rng);
        let fast = conv2d_same(&x, &w1);
        // same kernel embedded at the center of a zero 3x3 (di=1, dj=1)
        let mut w3 = Tensor::zeros(&[3, 3, 4, 6]);
        let center = (3 + 1) * 4 * 6;
        w3.data[center..center + 4 * 6].copy_from_slice(&w1.data);
        let general = conv2d_same(&x, &w3);
        assert!(fast.max_abs_diff(&general) < 1e-5);
    }

    /// Kernel-thread row splitting must be bitwise invisible: disjoint
    /// output ranges, serial per-cell accumulation.
    #[test]
    fn kernel_threads_are_bit_exact() {
        let mut rng = Pcg64::new(74);
        let a = rand_t(&[67, 33], &mut rng);
        let b = rand_t(&[33, 29], &mut rng);
        let x = rand_t(&[2, 9, 9, 5], &mut rng);
        let w = rand_t(&[3, 3, 5, 11], &mut rng);
        let (mm1, cv1) = (matmul(&a, &b), conv2d_same(&x, &w));
        for t in [2, 3, 4] {
            let (mm, cv) = par::with_kernel_threads(t, || {
                (matmul(&a, &b), conv2d_same(&x, &w))
            });
            assert_eq!(mm.data, mm1.data, "matmul differs at {t} threads");
            assert_eq!(cv.data, cv1.data, "conv differs at {t} threads");
        }
        assert_eq!(par::kernel_threads(), 1, "guard must restore");
    }

    #[test]
    fn scratch_pool_reuses_buffers() {
        let len = 123_457; // distinctive size so other tests' buffers lose
        let b = scratch::take(len);
        let ptr = b.as_ptr();
        scratch::put(b);
        let b2 = scratch::take(len);
        assert_eq!(b2.as_ptr(), ptr, "pooled buffer should be reused");
        assert!(b2.iter().all(|&v| v == 0.0), "reused buffers must be zeroed");
        scratch::put(b2);
        // take_any reuses too, and the right length comes back even when
        // the pooled buffer held a different length
        let mut dirty = scratch::take_any(len);
        dirty.iter_mut().for_each(|v| *v = 7.0);
        scratch::put(dirty);
        let again = scratch::take_any(len / 2);
        assert_eq!(again.len(), len / 2);
        scratch::put(again);
        // zero-length requests never touch the pool
        assert!(scratch::take(0).is_empty());
    }

    /// The pool byte budget scales with the largest request seen, so a
    /// 64x64-scale im2col slab still pools instead of thrashing.
    #[test]
    fn scratch_budget_tracks_high_water() {
        assert!(scratch::pool_budget_bytes() >= 32 << 20);
        let big = 10 << 20; // 10M floats = 40 MB request
        let b = scratch::take_any(big);
        assert!(scratch::pool_budget_bytes() >= 4 * big * 4,
                "budget should scale to 4x the high-water request");
        let ptr = b.as_ptr();
        scratch::put(b);
        let b2 = scratch::take_any(big);
        assert_eq!(b2.as_ptr(), ptr, "large buffer should pool under the \
                                      scaled budget");
        // do not pool a 40 MB buffer back into the shared test thread
        drop(b2);
    }

    #[test]
    fn matmul_variants_consistent() {
        let mut rng = Pcg64::new(3);
        let a = rand_t(&[4, 3], &mut rng);
        let b = rand_t(&[3, 5], &mut rng);
        let ab = matmul(&a, &b);
        assert_eq!(ab.shape, vec![4, 5]);
        // a (bᵀ)ᵀ == a b
        let via_bt = matmul_bt(&a, &mat_t(&b));
        assert!(ab.max_abs_diff(&via_bt) < 1e-4);
        // matmul_at(a, c) == aᵀ c
        let lhs = matmul_at(&a, &ab);
        let rhs = matmul(&mat_t(&a), &ab);
        assert!(lhs.max_abs_diff(&rhs) < 1e-4);
    }

    #[test]
    fn half_roundtrip_error_bounds() {
        let mut rng = Pcg64::new(75);
        let xs = rng.normal_vec(4096);
        for &x in &xs {
            let qb = half::bf16_to_f32(half::f32_to_bf16(x));
            let qh = half::f16_to_f32(half::f32_to_f16(x));
            let ax = x.abs().max(f32::MIN_POSITIVE);
            assert!((qb - x).abs() <= ax * 0.00390625, // 2^-8
                    "bf16 {x} -> {qb}");
            assert!((qh - x).abs() <= ax * 0.00048828125 + 6e-8, // 2^-11 + sub
                    "f16 {x} -> {qh}");
        }
        // powers of two and zero are exact in both formats
        for x in [0.0f32, 1.0, -2.0, 0.25, 1024.0, -0.5] {
            assert_eq!(half::bf16_to_f32(half::f32_to_bf16(x)), x);
            assert_eq!(half::f16_to_f32(half::f32_to_f16(x)), x);
        }
        // f16 saturates to inf past 65504; bf16 keeps the f32 range
        assert_eq!(half::f16_to_f32(half::f32_to_f16(1.0e6)), f32::INFINITY);
        assert!(half::bf16_to_f32(half::f32_to_bf16(1.0e6)).is_finite());
        // nan stays nan, sign survives
        assert!(half::f16_to_f32(half::f32_to_f16(f32::NAN)).is_nan());
        assert!(half::bf16_to_f32(half::f32_to_bf16(f32::NAN)).is_nan());
        assert_eq!(half::f16_to_f32(half::f32_to_f16(-0.0)).to_bits(),
                   (-0.0f32).to_bits());
    }

    /// Round-to-nearest-even at the exact halfway point (f16 has 10
    /// mantissa bits: 1 + 2^-11 is halfway between 1.0 and 1 + 2^-10).
    #[test]
    fn half_rounds_to_nearest_even() {
        let halfway = 1.0f32 + (-11f32).exp2();
        assert_eq!(half::f16_to_f32(half::f32_to_f16(halfway)), 1.0);
        let above = 1.0f32 + (-11f32).exp2() + (-20f32).exp2();
        assert_eq!(half::f16_to_f32(half::f32_to_f16(above)),
                   1.0 + (-10f32).exp2());
        // bf16: 1 + 2^-8 is halfway between 1.0 and 1 + 2^-7
        let bhalf = 1.0f32 + (-8f32).exp2();
        assert_eq!(half::bf16_to_f32(half::f32_to_bf16(bhalf)), 1.0);
    }

    #[test]
    fn householder_is_orthogonal() {
        let mut rng = Pcg64::new(4);
        let v1 = rand_t(&[6], &mut rng);
        let v2 = rand_t(&[6], &mut rng);
        let v3 = rand_t(&[6], &mut rng);
        let w = householder(&[&v1, &v2, &v3]);
        let wtw = matmul(&mat_t(&w), &w);
        assert!(wtw.max_abs_diff(&eye(6)) < 1e-5);
        // apply then apply_t round-trips
        let x = rand_t(&[3, 4, 6], &mut rng);
        let y = apply_mat(&x, &w);
        let back = apply_mat_t(&y, &w);
        assert!(x.max_abs_diff(&back) < 1e-5);
    }

    #[test]
    fn householder_vjp_matches_finite_difference() {
        let mut rng = Pcg64::new(5);
        let v1 = rand_t(&[4], &mut rng);
        let v2 = rand_t(&[4], &mut rng);
        let v3 = rand_t(&[4], &mut rng);
        let dw = rand_t(&[4, 4], &mut rng);
        let dvs = householder_vjp(&[&v1, &v2, &v3], &dw);
        let loss = |vs: &[&Tensor]| dot(&householder(vs), &dw);
        let eps = 1e-3f32;
        for (vi, v) in [&v1, &v2, &v3].iter().enumerate() {
            for j in 0..4 {
                let mut vp = (*v).clone();
                vp.data[j] += eps;
                let mut vm = (*v).clone();
                vm.data[j] -= eps;
                let args_p: Vec<&Tensor> = (0..3).map(|i| {
                    if i == vi { &vp } else { [&v1, &v2, &v3][i] }
                }).collect();
                let args_m: Vec<&Tensor> = (0..3).map(|i| {
                    if i == vi { &vm } else { [&v1, &v2, &v3][i] }
                }).collect();
                let fd = (loss(&args_p) - loss(&args_m)) / (2.0 * eps as f64);
                let an = dvs[vi].data[j] as f64;
                assert!((fd - an).abs() < 2e-2 * an.abs().max(1.0),
                        "v{vi}[{j}]: fd {fd} vs {an}");
            }
        }
    }

    #[test]
    fn mlp_vjp_matches_finite_difference() {
        let mut rng = Pcg64::new(6);
        let x = rand_t(&[3, 4], &mut rng);
        let theta: Vec<Tensor> = [
            vec![4usize, 8], vec![8], vec![8, 8], vec![8], vec![8, 5], vec![5],
        ].iter().map(|s| {
            let mut t = rand_t(s, &mut rng);
            for v in &mut t.data {
                *v *= 0.3;
            }
            t
        }).collect();
        let dout = rand_t(&[3, 5], &mut rng);
        let (_, cache) = mlp_apply(&x, &theta);
        let (dx, dth) = mlp_vjp(&dout, &x, &cache, &theta);
        let loss = |x_: &Tensor, th: &[Tensor]| {
            dot(&mlp_apply(x_, th).0, &dout)
        };
        let eps = 1e-2f32;
        // spot-check a few coordinates of dx and each dtheta
        for j in [0usize, 5, 11] {
            let mut xp = x.clone();
            xp.data[j] += eps;
            let mut xm = x.clone();
            xm.data[j] -= eps;
            let fd = (loss(&xp, &theta) - loss(&xm, &theta)) / (2.0 * eps as f64);
            let an = dx.data[j] as f64;
            assert!((fd - an).abs() < 2e-2 * an.abs().max(1.0), "dx[{j}]: {fd} {an}");
        }
        for (pi, g) in dth.iter().enumerate() {
            let j = g.len() / 2;
            let mut thp: Vec<Tensor> = theta.to_vec();
            thp[pi].data[j] += eps;
            let mut thm: Vec<Tensor> = theta.to_vec();
            thm[pi].data[j] -= eps;
            let fd = (loss(&x, &thp) - loss(&x, &thm)) / (2.0 * eps as f64);
            let an = g.data[j] as f64;
            assert!((fd - an).abs() < 2e-2 * an.abs().max(1.0),
                    "dtheta[{pi}][{j}]: {fd} {an}");
        }
    }
}
