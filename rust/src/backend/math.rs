//! Dense math kernels for the pure-Rust [`super::RefBackend`]: SAME-padded
//! NHWC convolution with its input/weight VJPs, small matmuls, conditioner
//! networks (CNN/MLP) with hand-written pullbacks, and the Householder
//! orthogonal parameterization used by Conv1x1.
//!
//! Every routine here was cross-validated against the JAX reference layers
//! in `python/compile/layers/` before being transcribed (forward, inverse
//! and gradient paths all agree to f32 precision).

use crate::tensor::Tensor;

// ---------------------------------------------------------------------------
// Scratch workspace: a thread-local free-list of f32 buffers
// ---------------------------------------------------------------------------

/// The training inner loop executes the same layer shapes thousands of
/// times; allocating a fresh `Vec` per matmul/conv dominated allocator
/// traffic. Kernels take their output and transpose buffers from this
/// thread-local pool, and callers `recycle` dead intermediates so the
/// buffers cycle instead of round-tripping through the allocator. The pool
/// is per-thread, so the data-parallel workers never contend on it.
pub(crate) mod scratch {
    use std::cell::RefCell;
    use std::sync::{Arc, OnceLock};

    use crate::telemetry::Counter;
    use crate::tensor::Tensor;

    /// Free-list caps: buffer count for cheap scans, plus a byte budget so
    /// a pass over a large image net cannot pin tens of MB of dead
    /// buffers per thread for the process lifetime.
    const MAX_POOLED: usize = 16;
    const MAX_POOLED_BYTES: usize = 8 << 20; // 8 MiB per thread

    thread_local! {
        static POOL: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
    }

    /// Pool telemetry (this is the hottest instrumented path in the
    /// crate: one counter bump per kernel buffer request). Handles are
    /// cached in `OnceLock`s so steady state is a relaxed `fetch_add` —
    /// the registry lock is taken once per process, not per event.
    fn hits() -> &'static Arc<Counter> {
        static C: OnceLock<Arc<Counter>> = OnceLock::new();
        C.get_or_init(|| {
            crate::telemetry::global().counter("invertnet_scratch_hits_total")
        })
    }

    fn misses() -> &'static Arc<Counter> {
        static C: OnceLock<Arc<Counter>> = OnceLock::new();
        C.get_or_init(|| {
            crate::telemetry::global()
                .counter("invertnet_scratch_misses_total")
        })
    }

    fn miss_bytes() -> &'static Arc<Counter> {
        static C: OnceLock<Arc<Counter>> = OnceLock::new();
        C.get_or_init(|| {
            crate::telemetry::global()
                .counter("invertnet_scratch_miss_bytes_total")
        })
    }

    fn take_impl(len: usize, zero: bool) -> Vec<f32> {
        if len == 0 {
            return Vec::new();
        }
        POOL.with(|pool| {
            let mut pool = pool.borrow_mut();
            let mut best: Option<(usize, usize)> = None; // (idx, capacity)
            for (i, b) in pool.iter().enumerate() {
                let c = b.capacity();
                if c >= len && best.map_or(true, |(_, bc)| c < bc) {
                    best = Some((i, c));
                }
            }
            match best {
                Some((i, _)) => {
                    hits().inc();
                    let mut b = pool.swap_remove(i);
                    if zero {
                        b.clear();
                        b.resize(len, 0.0);
                    } else {
                        // keep whatever initialized values are already
                        // there; only the grown tail (if any) is filled
                        b.resize(len, 0.0);
                    }
                    b
                }
                None => {
                    misses().inc();
                    miss_bytes().add(len as u64 * 4);
                    vec![0.0f32; len]
                }
            }
        })
    }

    /// A zeroed buffer of `len` f32s, reusing the smallest adequate pooled
    /// allocation when one exists. For accumulating consumers.
    pub fn take(len: usize) -> Vec<f32> {
        take_impl(len, true)
    }

    /// Like [`take`] but skips the zero-fill on pooled reuse: contents are
    /// arbitrary (stale but initialized) values. ONLY for consumers that
    /// write every element before reading — it saves a full memset per
    /// kernel call on the training hot path.
    pub fn take_any(len: usize) -> Vec<f32> {
        take_impl(len, false)
    }

    /// Return a buffer to the pool for reuse. Dropped (deallocated) when
    /// the pool is at its count cap or the byte budget would overflow.
    pub fn put(buf: Vec<f32>) {
        if buf.capacity() == 0 {
            return;
        }
        POOL.with(|pool| {
            let mut pool = pool.borrow_mut();
            let held: usize = pool.iter().map(|b| b.capacity() * 4).sum();
            if pool.len() < MAX_POOLED
                && held + buf.capacity() * 4 <= MAX_POOLED_BYTES
            {
                pool.push(buf);
            }
        });
    }

    /// Recycle a dead intermediate tensor's storage.
    pub fn recycle(t: Tensor) {
        put(t.data);
    }
}

fn dims4(t: &Tensor) -> (usize, usize, usize, usize) {
    assert_eq!(t.shape.len(), 4, "expected rank-4 tensor, got {:?}", t.shape);
    (t.shape[0], t.shape[1], t.shape[2], t.shape[3])
}

fn dims2(t: &Tensor) -> (usize, usize) {
    assert_eq!(t.shape.len(), 2, "expected rank-2 tensor, got {:?}", t.shape);
    (t.shape[0], t.shape[1])
}

// ---------------------------------------------------------------------------
// Convolution (stride 1, SAME, NHWC x HWIO) + VJPs
// ---------------------------------------------------------------------------

/// y[b,i,j,o] = sum_{di,dj,c} x[b, i+di-ph, j+dj-pw, c] * w[di,dj,c,o]
/// with zero padding (odd kernels: 1x1 or 3x3 here).
pub fn conv2d_same(x: &Tensor, w: &Tensor) -> Tensor {
    let (n, h, wd, ci) = dims4(x);
    let (kh, kw, wci, co) = dims4(w);
    assert_eq!(ci, wci, "conv channel mismatch: {ci} vs {wci}");
    if kh == 1 && kw == 1 {
        // pointwise conv == one matmul over the flattened pixel rows;
        // the blocked transposed-W kernel beats the scatter loop below
        let rows = n * h * wd;
        let mut wt = scratch::take_any(ci * co);
        transpose_into(&w.data, ci, co, &mut wt);
        let mut out = scratch::take_any(rows * co);
        matmul_rows_into(&x.data, &wt, rows, ci, co, &mut out);
        scratch::put(wt);
        return Tensor { shape: vec![n, h, wd, co], data: out };
    }
    let (ph, pw) = (kh / 2, kw / 2);
    let mut out = scratch::take(n * h * wd * co);
    for b in 0..n {
        for i in 0..h {
            for j in 0..wd {
                let orow = &mut out[((b * h + i) * wd + j) * co..][..co];
                for di in 0..kh {
                    let si = (i + di).wrapping_sub(ph);
                    if si >= h {
                        continue;
                    }
                    for dj in 0..kw {
                        let sj = (j + dj).wrapping_sub(pw);
                        if sj >= wd {
                            continue;
                        }
                        let xrow = &x.data[((b * h + si) * wd + sj) * ci..][..ci];
                        let wblk = &w.data[(di * kw + dj) * ci * co..][..ci * co];
                        for (ii, &xv) in xrow.iter().enumerate() {
                            if xv == 0.0 {
                                continue;
                            }
                            let wrow = &wblk[ii * co..][..co];
                            for (o, &wv) in orow.iter_mut().zip(wrow) {
                                *o += xv * wv;
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor { shape: vec![n, h, wd, co], data: out }
}

/// Spatially flip and swap the I/O axes of an HWIO kernel:
/// (kh,kw,ci,co) -> (kh,kw,co,ci). `conv2d_same(dy, flip_swap(w))` is the
/// adjoint of `conv2d_same(., w)` for stride-1 SAME odd kernels.
pub fn flip_swap(w: &Tensor) -> Tensor {
    let (kh, kw, ci, co) = dims4(w);
    let mut out = scratch::take_any(w.data.len());
    for di in 0..kh {
        for dj in 0..kw {
            for ii in 0..ci {
                for oo in 0..co {
                    let src = ((di * kw + dj) * ci + ii) * co + oo;
                    let dst = (((kh - 1 - di) * kw + (kw - 1 - dj)) * co + oo)
                        * ci + ii;
                    out[dst] = w.data[src];
                }
            }
        }
    }
    Tensor { shape: vec![kh, kw, co, ci], data: out }
}

/// dL/dx of `conv2d_same(x, w)` given dL/dy.
pub fn conv2d_vjp_x(dy: &Tensor, w: &Tensor) -> Tensor {
    let wf = flip_swap(w);
    let dx = conv2d_same(dy, &wf);
    scratch::recycle(wf);
    dx
}

/// dL/dw of `conv2d_same(x, w)` given dL/dy:
/// dw[di,dj,c,o] = sum_{b,i,j} x[b, i+di-ph, j+dj-pw, c] * dy[b,i,j,o].
pub fn conv2d_vjp_w(x: &Tensor, dy: &Tensor, kh: usize, kw: usize) -> Tensor {
    let (n, h, wd, ci) = dims4(x);
    let (_, _, _, co) = dims4(dy);
    if kh == 1 && kw == 1 {
        // pointwise kernel grad == matmul_at over the flattened pixel
        // rows; same row-serial accumulation order as the general loop
        // below (b, i, j ascending), so the numerics are bit-identical
        let rows = n * h * wd;
        let mut dw = scratch::take(ci * co);
        for r in 0..rows {
            let xrow = &x.data[r * ci..][..ci];
            let dyrow = &dy.data[r * co..][..co];
            for (p, &xv) in xrow.iter().enumerate() {
                if xv == 0.0 {
                    continue;
                }
                let orow = &mut dw[p * co..][..co];
                for (o, &g) in orow.iter_mut().zip(dyrow) {
                    *o += xv * g;
                }
            }
        }
        return Tensor { shape: vec![1, 1, ci, co], data: dw };
    }
    let (ph, pw) = (kh / 2, kw / 2);
    let mut dw = scratch::take(kh * kw * ci * co);
    for b in 0..n {
        for i in 0..h {
            for j in 0..wd {
                let dyrow = &dy.data[((b * h + i) * wd + j) * co..][..co];
                for di in 0..kh {
                    let si = (i + di).wrapping_sub(ph);
                    if si >= h {
                        continue;
                    }
                    for dj in 0..kw {
                        let sj = (j + dj).wrapping_sub(pw);
                        if sj >= wd {
                            continue;
                        }
                        let xrow = &x.data[((b * h + si) * wd + sj) * ci..][..ci];
                        let dwblk = &mut dw[(di * kw + dj) * ci * co..][..ci * co];
                        for (ii, &xv) in xrow.iter().enumerate() {
                            if xv == 0.0 {
                                continue;
                            }
                            let drow = &mut dwblk[ii * co..][..co];
                            for (d, &g) in drow.iter_mut().zip(dyrow) {
                                *d += xv * g;
                            }
                        }
                    }
                }
            }
        }
    }
    Tensor { shape: vec![kh, kw, ci, co], data: dw }
}

// ---------------------------------------------------------------------------
// Small matmuls (row-major, blocked over a transposed-B layout)
// ---------------------------------------------------------------------------

/// Dot product with four independent accumulators (ILP/SIMD friendly;
/// the serial-dependency chain of a naive fold defeats vectorization).
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len().min(b.len());
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    let mut i = 0;
    while i + 4 <= n {
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
        i += 4;
    }
    let mut s = (s0 + s1) + (s2 + s3);
    while i < n {
        s += a[i] * b[i];
        i += 1;
    }
    s
}

/// out[r, j] = sum_p x[r, p] * wt[j, p] with `wt` in transposed (m, k)
/// layout: every output cell is one contiguous dot product, written once
/// (no read-modify-write). Row-blocked by 4 so each streamed `wt` row is
/// reused across four x rows.
fn matmul_rows_into(x: &[f32], wt: &[f32], rows: usize, k: usize, m: usize,
                    out: &mut [f32]) {
    let mut r = 0;
    while r + 4 <= rows {
        let x0 = &x[r * k..][..k];
        let x1 = &x[(r + 1) * k..][..k];
        let x2 = &x[(r + 2) * k..][..k];
        let x3 = &x[(r + 3) * k..][..k];
        for j in 0..m {
            let wj = &wt[j * k..][..k];
            out[r * m + j] = dot(x0, wj);
            out[(r + 1) * m + j] = dot(x1, wj);
            out[(r + 2) * m + j] = dot(x2, wj);
            out[(r + 3) * m + j] = dot(x3, wj);
        }
        r += 4;
    }
    while r < rows {
        let xr = &x[r * k..][..k];
        for j in 0..m {
            out[r * m + j] = dot(xr, &wt[j * k..][..k]);
        }
        r += 1;
    }
}

/// (rows, cols) row-major -> (cols, rows) row-major.
fn transpose_into(src: &[f32], rows: usize, cols: usize, dst: &mut [f32]) {
    for i in 0..rows {
        for j in 0..cols {
            dst[j * rows + i] = src[i * cols + j];
        }
    }
}

/// (n,k) x (k,m) -> (n,m)
///
/// B is transposed into scratch on every call; at O(k*m) against the
/// O(n*k*m) kernel this is <1% for the shapes here, which is why there is
/// no per-weight transposed cache (that would need weight identity
/// tracking across ParamStore updates).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, k) = dims2(a);
    let (k2, m) = dims2(b);
    assert_eq!(k, k2, "matmul inner dim: {k} vs {k2}");
    let mut bt = scratch::take_any(k * m);
    transpose_into(&b.data, k, m, &mut bt);
    let mut out = scratch::take_any(n * m);
    matmul_rows_into(&a.data, &bt, n, k, m, &mut out);
    scratch::put(bt);
    Tensor { shape: vec![n, m], data: out }
}

/// aᵀ b: (n,k) x (n,m) -> (k,m)
///
/// Accumulates row-serially over `n` (the batch axis) so the f32
/// summation order over samples is the canonical one the data-parallel
/// reduction is compared against (`train::parallel`).
pub fn matmul_at(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, k) = dims2(a);
    let (n2, m) = dims2(b);
    assert_eq!(n, n2, "matmul_at outer dim: {n} vs {n2}");
    let mut out = scratch::take(k * m);
    for i in 0..n {
        let arow = &a.data[i * k..(i + 1) * k];
        let brow = &b.data[i * m..(i + 1) * m];
        for (p, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // post-ReLU activations are ~half zeros
            }
            let orow = &mut out[p * m..(p + 1) * m];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
    Tensor { shape: vec![k, m], data: out }
}

/// a bᵀ: (n,m) x (k,m) -> (n,k). `b` is already in the transposed layout
/// the blocked kernel wants, so this runs without a transpose pass.
pub fn matmul_bt(a: &Tensor, b: &Tensor) -> Tensor {
    let (n, m) = dims2(a);
    let (k, m2) = dims2(b);
    assert_eq!(m, m2, "matmul_bt inner dim: {m} vs {m2}");
    let mut out = scratch::take_any(n * k);
    matmul_rows_into(&a.data, &b.data, n, m, k, &mut out);
    Tensor { shape: vec![n, k], data: out }
}

fn mat_t(a: &Tensor) -> Tensor {
    let (n, m) = dims2(a);
    let mut out = scratch::take_any(n * m);
    transpose_into(&a.data, n, m, &mut out);
    Tensor { shape: vec![m, n], data: out }
}

// ---------------------------------------------------------------------------
// Elementwise / reduction helpers
// ---------------------------------------------------------------------------

/// t[..., c] += bias[c]  (broadcast over leading axes)
pub fn add_bias(t: &mut Tensor, bias: &Tensor) {
    let c = bias.len();
    assert_eq!(*t.shape.last().unwrap(), c, "bias width mismatch");
    for row in t.data.chunks_mut(c) {
        for (v, &b) in row.iter_mut().zip(&bias.data) {
            *v += b;
        }
    }
}

pub fn relu_inplace(t: &mut Tensor) {
    for v in &mut t.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// d *= (act > 0), elementwise (ReLU pullback; `act` is the post-ReLU value).
pub fn relu_mask(d: &mut Tensor, act: &Tensor) {
    debug_assert_eq!(d.shape, act.shape);
    for (v, &a) in d.data.iter_mut().zip(&act.data) {
        if a <= 0.0 {
            *v = 0.0;
        }
    }
}

/// Sum over all leading axes -> (c,) where c is the last dim.
pub fn sum_to_last(t: &Tensor) -> Tensor {
    let c = *t.shape.last().unwrap();
    let mut out = vec![0.0f32; c];
    for row in t.data.chunks(c) {
        for (o, &v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    Tensor { shape: vec![c], data: out }
}

// ---------------------------------------------------------------------------
// Conditioner networks: 3-layer MLP and CNN with hand-written pullbacks
// (python: compile/layers/conditioner.py, differentiated there by jax.vjp)
// ---------------------------------------------------------------------------

/// Post-ReLU hidden activations saved by the forward pass for the pullback.
pub struct NetCache {
    h1: Tensor,
    h2: Tensor,
}

impl NetCache {
    /// Hand the hidden-activation buffers back to the scratch pool.
    /// Callers that run the forward pass without a pullback (inverse paths,
    /// logdet-only evaluation) use this so the two largest per-layer
    /// temporaries never hit the allocator in steady state.
    pub fn recycle(self) {
        scratch::recycle(self.h1);
        scratch::recycle(self.h2);
    }
}

/// out = (relu(relu(x w1 + b1) w2 + b2)) w3 + b3 on (N, D) inputs.
pub fn mlp_apply(x: &Tensor, theta: &[Tensor]) -> (Tensor, NetCache) {
    let mut h1 = matmul(x, &theta[0]);
    add_bias(&mut h1, &theta[1]);
    relu_inplace(&mut h1);
    let mut h2 = matmul(&h1, &theta[2]);
    add_bias(&mut h2, &theta[3]);
    relu_inplace(&mut h2);
    let mut out = matmul(&h2, &theta[4]);
    add_bias(&mut out, &theta[5]);
    (out, NetCache { h1, h2 })
}

/// Pullback of [`mlp_apply`]: returns (dx, [dw1,db1,dw2,db2,dw3,db3]).
pub fn mlp_vjp(dout: &Tensor, x: &Tensor, cache: &NetCache,
               theta: &[Tensor]) -> (Tensor, Vec<Tensor>) {
    let dw3 = matmul_at(&cache.h2, dout);
    let db3 = sum_to_last(dout);
    let mut dh2 = matmul_bt(dout, &theta[4]);
    relu_mask(&mut dh2, &cache.h2);
    let dw2 = matmul_at(&cache.h1, &dh2);
    let db2 = sum_to_last(&dh2);
    let mut dh1 = matmul_bt(&dh2, &theta[2]);
    relu_mask(&mut dh1, &cache.h1);
    let dw1 = matmul_at(x, &dh1);
    let db1 = sum_to_last(&dh1);
    let dx = matmul_bt(&dh1, &theta[0]);
    scratch::recycle(dh1);
    scratch::recycle(dh2);
    (dx, vec![dw1, db1, dw2, db2, dw3, db3])
}

/// GLOW conditioner CNN: conv3x3 -> relu -> conv1x1 -> relu -> conv3x3
/// on NHWC inputs.
pub fn cnn_apply(x: &Tensor, theta: &[Tensor]) -> (Tensor, NetCache) {
    let mut h1 = conv2d_same(x, &theta[0]);
    add_bias(&mut h1, &theta[1]);
    relu_inplace(&mut h1);
    let mut h2 = conv2d_same(&h1, &theta[2]);
    add_bias(&mut h2, &theta[3]);
    relu_inplace(&mut h2);
    let mut out = conv2d_same(&h2, &theta[4]);
    add_bias(&mut out, &theta[5]);
    (out, NetCache { h1, h2 })
}

/// Pullback of [`cnn_apply`]: returns (dx, [dw1,db1,dw2,db2,dw3,db3]).
pub fn cnn_vjp(dout: &Tensor, x: &Tensor, cache: &NetCache,
               theta: &[Tensor]) -> (Tensor, Vec<Tensor>) {
    let dw3 = conv2d_vjp_w(&cache.h2, dout, 3, 3);
    let db3 = sum_to_last(dout);
    let mut dh2 = conv2d_vjp_x(dout, &theta[4]);
    relu_mask(&mut dh2, &cache.h2);
    let dw2 = conv2d_vjp_w(&cache.h1, &dh2, 1, 1);
    let db2 = sum_to_last(&dh2);
    let mut dh1 = conv2d_vjp_x(&dh2, &theta[2]);
    relu_mask(&mut dh1, &cache.h1);
    let dw1 = conv2d_vjp_w(x, &dh1, 3, 3);
    let db1 = sum_to_last(&dh1);
    let dx = conv2d_vjp_x(&dh1, &theta[0]);
    scratch::recycle(dh1);
    scratch::recycle(dh2);
    (dx, vec![dw1, db1, dw2, db2, dw3, db3])
}

// ---------------------------------------------------------------------------
// Householder orthogonal parameterization (Conv1x1)
// ---------------------------------------------------------------------------

fn eye(c: usize) -> Tensor {
    let mut data = scratch::take(c * c);
    for i in 0..c {
        data[i * c + i] = 1.0;
    }
    Tensor { shape: vec![c, c], data }
}

fn single_householder(v: &Tensor) -> Tensor {
    let c = v.len();
    let s: f32 = v.data.iter().map(|x| x * x).sum();
    let f = 2.0 / s;
    let mut h = eye(c);
    for i in 0..c {
        for j in 0..c {
            h.data[i * c + j] -= f * v.data[i] * v.data[j];
        }
    }
    h
}

/// W = H(v1) H(v2) ... H(vk) with H(v) = I - 2 v vᵀ / (vᵀ v); orthogonal.
pub fn householder(vs: &[&Tensor]) -> Tensor {
    let c = vs[0].len();
    let mut w = eye(c);
    for v in vs {
        let s: f32 = v.data.iter().map(|x| x * x).sum();
        let f = 2.0 / s;
        // w <- w - f * (w v) vᵀ
        let mut wv = scratch::take_any(c);
        for (i, o) in wv.iter_mut().enumerate() {
            *o = dot(&w.data[i * c..(i + 1) * c], &v.data);
        }
        for i in 0..c {
            for j in 0..c {
                w.data[i * c + j] -= f * wv[i] * v.data[j];
            }
        }
        scratch::put(wv);
    }
    w
}

/// Pullback of [`householder`] onto the reflection vectors:
/// dH_k = A_kᵀ dW B_kᵀ with A_k/B_k the prefix/suffix products, then
/// dv = -(2/s)(dH v + dHᵀ v) + (4 (vᵀ dH v)/s²) v.
pub fn householder_vjp(vs: &[&Tensor], dw: &Tensor) -> Vec<Tensor> {
    let c = vs[0].len();
    let hs: Vec<Tensor> = vs.iter().map(|v| single_householder(v)).collect();
    let mut dvs = Vec::with_capacity(vs.len());
    for (k, v) in vs.iter().enumerate() {
        let mut a = eye(c);
        for h in &hs[..k] {
            let next = matmul(&a, h);
            scratch::recycle(std::mem::replace(&mut a, next));
        }
        let mut b = eye(c);
        for h in &hs[k + 1..] {
            let next = matmul(&b, h);
            scratch::recycle(std::mem::replace(&mut b, next));
        }
        let at = mat_t(&a);
        let bt = mat_t(&b);
        let at_dw = matmul(&at, dw);
        let g = matmul(&at_dw, &bt);
        scratch::recycle(a);
        scratch::recycle(b);
        scratch::recycle(at);
        scratch::recycle(bt);
        scratch::recycle(at_dw);
        let s: f32 = v.data.iter().map(|x| x * x).sum();
        let gv: Vec<f32> = (0..c).map(|i| {
            g.data[i * c..(i + 1) * c].iter().zip(&v.data).map(|(x, y)| x * y).sum()
        }).collect();
        let gtv: Vec<f32> = (0..c).map(|j| {
            (0..c).map(|i| g.data[i * c + j] * v.data[i]).sum()
        }).collect();
        let vgv: f32 = v.data.iter().zip(&gv).map(|(x, y)| x * y).sum();
        scratch::recycle(g);
        let data: Vec<f32> = (0..c).map(|j| {
            -(2.0 / s) * (gv[j] + gtv[j]) + (4.0 * vgv / (s * s)) * v.data[j]
        }).collect();
        dvs.push(Tensor { shape: vec![c], data });
    }
    for h in hs {
        scratch::recycle(h);
    }
    dvs
}

/// y_p = W x_p applied along the last axis (einsum "...j,ij->...i").
pub fn apply_mat(x: &Tensor, w: &Tensor) -> Tensor {
    let c = *x.shape.last().unwrap();
    let rows = x.len() / c;
    let mut out = scratch::take_any(x.len());
    // W's rows are contiguous, so this is already a transposed-layout
    // matmul: out[r, i] = dot(x_r, w_i)
    matmul_rows_into(&x.data, &w.data, rows, c, c, &mut out);
    Tensor { shape: x.shape.clone(), data: out }
}

/// x_p = Wᵀ y_p along the last axis (einsum "...i,ij->...j").
pub fn apply_mat_t(y: &Tensor, w: &Tensor) -> Tensor {
    let c = *y.shape.last().unwrap();
    let rows = y.len() / c;
    let mut out = scratch::take(y.len());
    for r in 0..rows {
        let yr = &y.data[r * c..(r + 1) * c];
        let or = &mut out[r * c..(r + 1) * c];
        for (i, &yv) in yr.iter().enumerate() {
            if yv == 0.0 {
                continue;
            }
            let wrow = &w.data[i * c..(i + 1) * c];
            for (o, &wv) in or.iter_mut().zip(wrow) {
                *o += yv * wv;
            }
        }
    }
    Tensor { shape: y.shape.clone(), data: out }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn rand_t(shape: &[usize], rng: &mut Pcg64) -> Tensor {
        Tensor { shape: shape.to_vec(), data: rng.normal_vec(shape.iter().product()) }
    }

    fn dot(a: &Tensor, b: &Tensor) -> f64 {
        a.data.iter().zip(&b.data).map(|(x, y)| (*x as f64) * (*y as f64)).sum()
    }

    #[test]
    fn conv_identity_kernel() {
        // 1x1 identity kernel leaves x unchanged
        let mut rng = Pcg64::new(1);
        let x = rand_t(&[2, 3, 3, 2], &mut rng);
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1.0, 0.0, 0.0, 1.0]).unwrap();
        let y = conv2d_same(&x, &w);
        assert!(x.max_abs_diff(&y) < 1e-6);
    }

    #[test]
    fn conv_matches_hand_computed() {
        // single channel 2x2 image, 3x3 kernel of ones: SAME conv = local sums
        let x = Tensor::new(vec![1, 2, 2, 1], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let w = Tensor::full(&[3, 3, 1, 1], 1.0);
        let y = conv2d_same(&x, &w);
        assert_eq!(y.data, vec![10.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn conv_vjps_are_adjoint() {
        // <conv(x,w), dy> == <x, vjp_x(dy,w)> == <w, vjp_w(x,dy)>
        let mut rng = Pcg64::new(2);
        let x = rand_t(&[2, 4, 5, 3], &mut rng);
        let w = rand_t(&[3, 3, 3, 4], &mut rng);
        let dy = rand_t(&[2, 4, 5, 4], &mut rng);
        let lhs = dot(&conv2d_same(&x, &w), &dy);
        let via_x = dot(&x, &conv2d_vjp_x(&dy, &w));
        let via_w = dot(&w, &conv2d_vjp_w(&x, &dy, 3, 3));
        assert!((lhs - via_x).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} {via_x}");
        assert!((lhs - via_w).abs() < 1e-3 * lhs.abs().max(1.0), "{lhs} {via_w}");
    }

    /// The blocked transposed-B kernel must agree with a naive triple loop
    /// on shapes around the 4-row blocking boundary.
    #[test]
    fn blocked_matmul_matches_naive() {
        let mut rng = Pcg64::new(71);
        for (n, k, m) in [(1, 1, 1), (3, 5, 7), (4, 8, 16), (5, 3, 2),
                          (7, 66, 9), (8, 4, 4)] {
            let a = rand_t(&[n, k], &mut rng);
            let b = rand_t(&[k, m], &mut rng);
            let fast = matmul(&a, &b);
            let mut naive = vec![0.0f32; n * m];
            for i in 0..n {
                for j in 0..m {
                    let mut s = 0.0f32;
                    for p in 0..k {
                        s += a.data[i * k + p] * b.data[p * m + j];
                    }
                    naive[i * m + j] = s;
                }
            }
            let want = Tensor { shape: vec![n, m], data: naive };
            assert!(fast.max_abs_diff(&want) < 1e-5,
                    "({n},{k},{m}): {}", fast.max_abs_diff(&want));
        }
    }

    /// 1x1 convs take the pointwise-matmul fast path; it must agree with
    /// the general scatter loop (exercised via a 1x1 kernel padded to 3x3
    /// with zeros, which routes through the general path).
    #[test]
    fn conv_1x1_fast_path_matches_general() {
        let mut rng = Pcg64::new(72);
        let x = rand_t(&[2, 5, 3, 4], &mut rng);
        let w1 = rand_t(&[1, 1, 4, 6], &mut rng);
        let fast = conv2d_same(&x, &w1);
        // same kernel embedded at the center of a zero 3x3 (di=1, dj=1)
        let mut w3 = Tensor::zeros(&[3, 3, 4, 6]);
        let center = (3 + 1) * 4 * 6;
        w3.data[center..center + 4 * 6].copy_from_slice(&w1.data);
        let general = conv2d_same(&x, &w3);
        assert!(fast.max_abs_diff(&general) < 1e-5);
    }

    #[test]
    fn scratch_pool_reuses_buffers() {
        let len = 123_457; // distinctive size so other tests' buffers lose
        let b = scratch::take(len);
        let ptr = b.as_ptr();
        scratch::put(b);
        let b2 = scratch::take(len);
        assert_eq!(b2.as_ptr(), ptr, "pooled buffer should be reused");
        assert!(b2.iter().all(|&v| v == 0.0), "reused buffers must be zeroed");
        scratch::put(b2);
        // take_any reuses too, and the right length comes back even when
        // the pooled buffer held a different length
        let mut dirty = scratch::take_any(len);
        dirty.iter_mut().for_each(|v| *v = 7.0);
        scratch::put(dirty);
        let again = scratch::take_any(len / 2);
        assert_eq!(again.len(), len / 2);
        scratch::put(again);
        // zero-length requests never touch the pool
        assert!(scratch::take(0).is_empty());
    }

    #[test]
    fn matmul_variants_consistent() {
        let mut rng = Pcg64::new(3);
        let a = rand_t(&[4, 3], &mut rng);
        let b = rand_t(&[3, 5], &mut rng);
        let ab = matmul(&a, &b);
        assert_eq!(ab.shape, vec![4, 5]);
        // a (bᵀ)ᵀ == a b
        let via_bt = matmul_bt(&a, &mat_t(&b));
        assert!(ab.max_abs_diff(&via_bt) < 1e-4);
        // matmul_at(a, c) == aᵀ c
        let lhs = matmul_at(&a, &ab);
        let rhs = matmul(&mat_t(&a), &ab);
        assert!(lhs.max_abs_diff(&rhs) < 1e-4);
    }

    #[test]
    fn householder_is_orthogonal() {
        let mut rng = Pcg64::new(4);
        let v1 = rand_t(&[6], &mut rng);
        let v2 = rand_t(&[6], &mut rng);
        let v3 = rand_t(&[6], &mut rng);
        let w = householder(&[&v1, &v2, &v3]);
        let wtw = matmul(&mat_t(&w), &w);
        assert!(wtw.max_abs_diff(&eye(6)) < 1e-5);
        // apply then apply_t round-trips
        let x = rand_t(&[3, 4, 6], &mut rng);
        let y = apply_mat(&x, &w);
        let back = apply_mat_t(&y, &w);
        assert!(x.max_abs_diff(&back) < 1e-5);
    }

    #[test]
    fn householder_vjp_matches_finite_difference() {
        let mut rng = Pcg64::new(5);
        let v1 = rand_t(&[4], &mut rng);
        let v2 = rand_t(&[4], &mut rng);
        let v3 = rand_t(&[4], &mut rng);
        let dw = rand_t(&[4, 4], &mut rng);
        let dvs = householder_vjp(&[&v1, &v2, &v3], &dw);
        let loss = |vs: &[&Tensor]| dot(&householder(vs), &dw);
        let eps = 1e-3f32;
        for (vi, v) in [&v1, &v2, &v3].iter().enumerate() {
            for j in 0..4 {
                let mut vp = (*v).clone();
                vp.data[j] += eps;
                let mut vm = (*v).clone();
                vm.data[j] -= eps;
                let args_p: Vec<&Tensor> = (0..3).map(|i| {
                    if i == vi { &vp } else { [&v1, &v2, &v3][i] }
                }).collect();
                let args_m: Vec<&Tensor> = (0..3).map(|i| {
                    if i == vi { &vm } else { [&v1, &v2, &v3][i] }
                }).collect();
                let fd = (loss(&args_p) - loss(&args_m)) / (2.0 * eps as f64);
                let an = dvs[vi].data[j] as f64;
                assert!((fd - an).abs() < 2e-2 * an.abs().max(1.0),
                        "v{vi}[{j}]: fd {fd} vs {an}");
            }
        }
    }

    #[test]
    fn mlp_vjp_matches_finite_difference() {
        let mut rng = Pcg64::new(6);
        let x = rand_t(&[3, 4], &mut rng);
        let theta: Vec<Tensor> = [
            vec![4usize, 8], vec![8], vec![8, 8], vec![8], vec![8, 5], vec![5],
        ].iter().map(|s| {
            let mut t = rand_t(s, &mut rng);
            for v in &mut t.data {
                *v *= 0.3;
            }
            t
        }).collect();
        let dout = rand_t(&[3, 5], &mut rng);
        let (_, cache) = mlp_apply(&x, &theta);
        let (dx, dth) = mlp_vjp(&dout, &x, &cache, &theta);
        let loss = |x_: &Tensor, th: &[Tensor]| {
            dot(&mlp_apply(x_, th).0, &dout)
        };
        let eps = 1e-2f32;
        // spot-check a few coordinates of dx and each dtheta
        for j in [0usize, 5, 11] {
            let mut xp = x.clone();
            xp.data[j] += eps;
            let mut xm = x.clone();
            xm.data[j] -= eps;
            let fd = (loss(&xp, &theta) - loss(&xm, &theta)) / (2.0 * eps as f64);
            let an = dx.data[j] as f64;
            assert!((fd - an).abs() < 2e-2 * an.abs().max(1.0), "dx[{j}]: {fd} {an}");
        }
        for (pi, g) in dth.iter().enumerate() {
            let j = g.len() / 2;
            let mut thp: Vec<Tensor> = theta.to_vec();
            thp[pi].data[j] += eps;
            let mut thm: Vec<Tensor> = theta.to_vec();
            thm[pi].data[j] -= eps;
            let fd = (loss(&x, &thp) - loss(&x, &thm)) / (2.0 * eps as f64);
            let an = g.data[j] as f64;
            assert!((fd - an).abs() < 2e-2 * an.abs().max(1.0),
                    "dtheta[{pi}][{j}]: {fd} {an}");
        }
    }
}
