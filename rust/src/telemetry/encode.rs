//! Prometheus text-exposition encoding (and a small validating parser).
//!
//! [`render`] turns a sorted registry snapshot into text-exposition
//! format (version 0.0.4): a `# TYPE` line per family, plain
//! `name value` samples for counters and gauges, and the conventional
//! `_bucket{le=...}` / `_sum` / `_count` triple for histograms. Bucket
//! counts are emitted cumulatively, as Prometheus requires; empty
//! trailing buckets above the highest populated one are elided (the
//! mandatory `le="+Inf"` bucket always closes the series).
//!
//! [`parse_exposition`] is the inverse used by `invertnet metrics FILE`
//! and the CI smoke: it does not reconstruct values, it validates shape
//! (every sample parses, every sample belongs to a declared family,
//! every family has at least one sample) and summarizes the families.

use anyhow::{bail, Result};

use super::registry::{bucket_upper, HistSnapshot, Sample, NBUCKETS};

/// Render a snapshot (as produced by `Registry::snapshot`, already
/// sorted by name) to Prometheus text exposition.
pub fn render(entries: &[(String, Sample)]) -> String {
    let mut out = String::new();
    for (name, sample) in entries {
        match sample {
            Sample::Counter(v) => {
                out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
            }
            Sample::Gauge(v) => {
                out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
            }
            Sample::Histogram(h) => render_hist(&mut out, name, h),
        }
    }
    out
}

fn render_hist(out: &mut String, name: &str, h: &HistSnapshot) {
    out.push_str(&format!("# TYPE {name} histogram\n"));
    let top = (0..NBUCKETS).rev().find(|&i| h.buckets[i] > 0);
    let mut cum = 0u64;
    if let Some(top) = top {
        for i in 0..=top {
            cum += h.buckets[i];
            let le = bucket_upper(i);
            out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
        }
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
    out.push_str(&format!("{name}_sum {}\n", h.sum));
    out.push_str(&format!("{name}_count {}\n", h.count));
}

/// One metric family seen by [`parse_exposition`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Family {
    pub name: String,
    pub kind: String,
    pub samples: usize,
}

/// Validate exposition text and summarize its families. Errors name the
/// offending line. Accepts exactly what [`render`] produces (plus any
/// conforming exposition: extra `#` comments are ignored).
pub fn parse_exposition(text: &str) -> Result<Vec<Family>> {
    let mut families: Vec<Family> = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let mut it = rest.split_whitespace();
            let (name, kind) = match (it.next(), it.next(), it.next()) {
                (Some(n), Some(k), None) => (n, k),
                _ => bail!("line {}: malformed TYPE line {line:?}", lineno + 1),
            };
            if !matches!(kind, "counter" | "gauge" | "histogram") {
                bail!("line {}: unknown metric kind {kind:?}", lineno + 1);
            }
            if families.iter().any(|f| f.name == name) {
                bail!("line {}: duplicate family {name:?}", lineno + 1);
            }
            families.push(Family { name: name.to_string(), kind: kind.to_string(), samples: 0 });
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        let Some((series, value)) = line.rsplit_once(' ') else {
            bail!("line {}: sample line has no value: {line:?}", lineno + 1);
        };
        if value.parse::<f64>().is_err() {
            bail!("line {}: unparsable sample value {value:?}", lineno + 1);
        }
        let series_name = series.split('{').next().unwrap_or(series);
        let Some(fam) = families.last_mut() else {
            bail!("line {}: sample before any TYPE line: {line:?}", lineno + 1);
        };
        let belongs = series_name == fam.name
            || (fam.kind == "histogram"
                && [
                    format!("{}_bucket", fam.name),
                    format!("{}_sum", fam.name),
                    format!("{}_count", fam.name),
                ]
                .iter()
                .any(|s| *s == series_name));
        if !belongs {
            bail!(
                "line {}: sample {series_name:?} does not belong to family {:?}",
                lineno + 1,
                fam.name
            );
        }
        fam.samples += 1;
    }
    for fam in &families {
        if fam.samples == 0 {
            bail!("family {:?} declares no samples", fam.name);
        }
    }
    if families.is_empty() {
        bail!("no metric families found");
    }
    Ok(families)
}

#[cfg(test)]
mod tests {
    use super::super::registry::Histogram;
    use super::*;

    fn demo_snapshot() -> Vec<(String, Sample)> {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 4, 5, 6, 7, 8] {
            h.record(v);
        }
        vec![
            ("demo_gauge".to_string(), Sample::Gauge(-1.5)),
            ("demo_lat_us".to_string(), Sample::Histogram(h.snapshot())),
            ("demo_total".to_string(), Sample::Counter(42)),
        ]
    }

    #[test]
    fn renders_cumulative_buckets_in_exposition_format() {
        let text = render(&demo_snapshot());
        let expected = "\
# TYPE demo_gauge gauge
demo_gauge -1.5
# TYPE demo_lat_us histogram
demo_lat_us_bucket{le=\"0\"} 0
demo_lat_us_bucket{le=\"1\"} 1
demo_lat_us_bucket{le=\"3\"} 3
demo_lat_us_bucket{le=\"7\"} 7
demo_lat_us_bucket{le=\"15\"} 8
demo_lat_us_bucket{le=\"+Inf\"} 8
demo_lat_us_sum 36
demo_lat_us_count 8
# TYPE demo_total counter
demo_total 42
";
        assert_eq!(text, expected);
    }

    #[test]
    fn parser_roundtrips_rendered_output() {
        let fams = parse_exposition(&render(&demo_snapshot())).unwrap();
        assert_eq!(
            fams,
            vec![
                Family { name: "demo_gauge".into(), kind: "gauge".into(), samples: 1 },
                Family { name: "demo_lat_us".into(), kind: "histogram".into(), samples: 8 },
                Family { name: "demo_total".into(), kind: "counter".into(), samples: 1 },
            ]
        );
    }

    #[test]
    fn parser_rejects_malformed_text() {
        assert!(parse_exposition("").is_err());
        assert!(parse_exposition("orphan 1\n").is_err(), "sample before TYPE");
        assert!(parse_exposition("# TYPE a counter\n").is_err(), "family with no samples");
        assert!(parse_exposition("# TYPE a counter\na notanumber\n").is_err());
        assert!(parse_exposition("# TYPE a counter\nb 1\n").is_err(), "foreign sample");
        assert!(parse_exposition("# TYPE a summary\na 1\n").is_err(), "unknown kind");
        assert!(
            parse_exposition("# TYPE a counter\na 1\n# TYPE a counter\na 2\n").is_err(),
            "duplicate family"
        );
    }

    #[test]
    fn empty_histogram_still_exposes_inf_bucket() {
        let h = Histogram::new();
        let text =
            render(&[("h_us".to_string(), Sample::Histogram(h.snapshot()))]);
        assert_eq!(
            text,
            "# TYPE h_us histogram\nh_us_bucket{le=\"+Inf\"} 0\nh_us_sum 0\nh_us_count 0\n"
        );
        parse_exposition(&text).unwrap();
    }
}
