//! Prometheus text-exposition encoding (and a small validating parser).
//!
//! [`render`] turns a sorted registry snapshot into text-exposition
//! format (version 0.0.4): a `# TYPE` line per family, plain
//! `name value` samples for counters and gauges, and the conventional
//! `_bucket{le=...}` / `_sum` / `_count` triple for histograms. Bucket
//! counts are emitted cumulatively, as Prometheus requires; empty
//! trailing buckets above the highest populated one are elided (the
//! mandatory `le="+Inf"` bucket always closes the series).
//!
//! [`parse_exposition`] is the inverse used by `invertnet metrics FILE`
//! and the CI smoke: it does not reconstruct values, it validates shape
//! (every sample parses, every sample belongs to a declared family,
//! every family has at least one sample) and summarizes the families.

use anyhow::{bail, Result};

use super::registry::{bucket_upper, HistSnapshot, Sample, NBUCKETS};

/// Render a snapshot (as produced by `Registry::snapshot`, already
/// sorted by name) to Prometheus text exposition.
pub fn render(entries: &[(String, Sample)]) -> String {
    let mut out = String::new();
    for (name, sample) in entries {
        match sample {
            Sample::Counter(v) => {
                out.push_str(&format!("# TYPE {name} counter\n{name} {v}\n"));
            }
            Sample::Gauge(v) => {
                out.push_str(&format!("# TYPE {name} gauge\n{name} {v}\n"));
            }
            Sample::Histogram(h) => render_hist(&mut out, name, h),
            Sample::LabeledCounter { label, values } => {
                out.push_str(&format!("# TYPE {name} counter\n"));
                for (value, count) in values {
                    out.push_str(&format!("{name}{{{label}=\"{value}\"}} {count}\n"));
                }
            }
        }
    }
    out
}

fn render_hist(out: &mut String, name: &str, h: &HistSnapshot) {
    out.push_str(&format!("# TYPE {name} histogram\n"));
    let top = (0..NBUCKETS).rev().find(|&i| h.buckets[i] > 0);
    let mut cum = 0u64;
    if let Some(top) = top {
        for i in 0..=top {
            cum += h.buckets[i];
            let le = bucket_upper(i);
            out.push_str(&format!("{name}_bucket{{le=\"{le}\"}} {cum}\n"));
        }
    }
    out.push_str(&format!("{name}_bucket{{le=\"+Inf\"}} {}\n", h.count));
    out.push_str(&format!("{name}_sum {}\n", h.sum));
    out.push_str(&format!("{name}_count {}\n", h.count));
}

/// One metric family seen by [`parse_exposition`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Family {
    pub name: String,
    pub kind: String,
    pub samples: usize,
}

/// A reconstructed histogram from exposition text: cumulative
/// `(le, count)` buckets in declared order, plus `_sum`/`_count`.
#[derive(Debug, Clone, PartialEq)]
pub struct HistValue {
    pub buckets: Vec<(f64, f64)>,
    pub sum: f64,
    pub count: f64,
}

impl HistValue {
    /// Quantile estimate by rank-walk over the cumulative buckets with
    /// linear interpolation inside the owning bucket — the scrape-side
    /// mirror of `HistSnapshot::quantile`, used by `invertnet top`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count <= 0.0 {
            return 0.0;
        }
        let target = (q * self.count).ceil().clamp(1.0, self.count);
        let mut lower = 0.0f64; // upper bound of the previous bucket
        let mut before = 0.0f64; // cumulative count below this bucket
        for &(le, cum) in &self.buckets {
            if cum >= target {
                if !le.is_finite() {
                    return lower;
                }
                let in_bucket = cum - before;
                let frac = if in_bucket > 0.0 { (target - before) / in_bucket } else { 1.0 };
                return lower + frac * (le - lower);
            }
            before = cum;
            lower = le;
        }
        lower
    }
}

/// One reconstructed series value, keyed by its full series name (so
/// labeled counters like `x_total{model="a"}` stay distinct).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Counter(f64),
    Gauge(f64),
    Histogram(HistValue),
}

/// Per-family validation state while a histogram's samples stream in.
struct HistState {
    buckets: Vec<(f64, f64)>,
    inf: Option<f64>,
    sum: Option<f64>,
    count: Option<f64>,
}

fn finalize_hist(fam: &str, h: &HistState) -> Result<HistValue> {
    let Some(inf) = h.inf else {
        bail!("histogram {fam:?} is missing its le=\"+Inf\" bucket");
    };
    let (Some(sum), Some(count)) = (h.sum, h.count) else {
        bail!("histogram {fam:?} is missing _sum or _count");
    };
    if inf != count {
        bail!(
            "histogram {fam:?}: le=\"+Inf\" bucket {inf} disagrees with _count {count}"
        );
    }
    if let Some(&(_, last_cum)) = h.buckets.last() {
        if last_cum > inf {
            bail!(
                "histogram {fam:?}: bucket count {last_cum} exceeds le=\"+Inf\" count {inf}"
            );
        }
    }
    Ok(HistValue { buckets: h.buckets.clone(), sum, count })
}

fn parse_sample_value(lineno: usize, value: &str) -> Result<f64> {
    let Ok(v) = value.parse::<f64>() else {
        bail!("line {lineno}: unparsable sample value {value:?}");
    };
    if v.is_nan() {
        bail!("line {lineno}: NaN sample value");
    }
    Ok(v)
}

/// Shared parse/validate core behind [`parse_exposition`] and
/// [`parse_values`]. Beyond the shape rules (every sample parses and
/// belongs to a declared family, every family has samples), it enforces
/// the value contracts [`render`] guarantees: counters and histogram
/// cells are finite and non-negative, gauges are finite (negative is
/// fine), bucket lines carry a well-formed `le` label with strictly
/// increasing bounds and non-decreasing cumulative counts, and every
/// histogram closes with a `+Inf` bucket agreeing with `_count`.
fn parse_core(text: &str) -> Result<(Vec<Family>, std::collections::BTreeMap<String, Value>)> {
    use std::collections::BTreeMap;
    let mut families: Vec<Family> = Vec::new();
    let mut values: BTreeMap<String, Value> = BTreeMap::new();
    let mut hist: Option<HistState> = None;

    // Runs when the current family ends (next TYPE line or EOF).
    fn close_family(
        families: &mut [Family],
        hist: &mut Option<HistState>,
        values: &mut BTreeMap<String, Value>,
    ) -> Result<()> {
        if let (Some(fam), Some(h)) = (families.last(), hist.take()) {
            values.insert(fam.name.clone(), Value::Histogram(finalize_hist(&fam.name, &h)?));
        }
        Ok(())
    }

    for (lineno, line) in text.lines().enumerate() {
        let lineno = lineno + 1;
        let line = line.trim_end();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            close_family(&mut families, &mut hist, &mut values)?;
            let mut it = rest.split_whitespace();
            let (name, kind) = match (it.next(), it.next(), it.next()) {
                (Some(n), Some(k), None) => (n, k),
                _ => bail!("line {lineno}: malformed TYPE line {line:?}"),
            };
            if !matches!(kind, "counter" | "gauge" | "histogram") {
                bail!("line {lineno}: unknown metric kind {kind:?}");
            }
            if families.iter().any(|f| f.name == name) {
                bail!("line {lineno}: duplicate family {name:?}");
            }
            families.push(Family { name: name.to_string(), kind: kind.to_string(), samples: 0 });
            if kind == "histogram" {
                hist = Some(HistState { buckets: Vec::new(), inf: None, sum: None, count: None });
            }
            continue;
        }
        if line.starts_with('#') {
            continue;
        }
        let Some((series, value)) = line.rsplit_once(' ') else {
            bail!("line {lineno}: sample line has no value: {line:?}");
        };
        let series_name = series.split('{').next().unwrap_or(series);
        let Some(fam) = families.last_mut() else {
            bail!("line {lineno}: sample before any TYPE line: {line:?}");
        };
        let v = parse_sample_value(lineno, value)?;
        match fam.kind.as_str() {
            "counter" | "gauge" => {
                if series_name != fam.name {
                    bail!(
                        "line {lineno}: sample {series_name:?} does not belong to family {:?}",
                        fam.name
                    );
                }
                if !v.is_finite() {
                    bail!("line {lineno}: non-finite {} value {v}", fam.kind);
                }
                if fam.kind == "counter" && v < 0.0 {
                    bail!("line {lineno}: negative counter value {v}");
                }
                let val =
                    if fam.kind == "counter" { Value::Counter(v) } else { Value::Gauge(v) };
                if values.insert(series.to_string(), val).is_some() {
                    bail!("line {lineno}: duplicate series {series:?}");
                }
            }
            _ => {
                // histogram family: only _bucket / _sum / _count samples
                let h = hist.as_mut().expect("histogram family without state");
                let bucket_prefix = format!("{}_bucket", fam.name);
                if series_name == bucket_prefix {
                    // the full series must be exactly name_bucket{le="..."}
                    let rest = &series[bucket_prefix.len()..];
                    let le_str = rest
                        .strip_prefix("{le=\"")
                        .and_then(|s| s.strip_suffix("\"}"))
                        .ok_or_else(|| {
                            anyhow::anyhow!("line {lineno}: malformed bucket line {line:?}")
                        })?;
                    let le = if le_str == "+Inf" {
                        f64::INFINITY
                    } else {
                        let Ok(le) = le_str.parse::<f64>() else {
                            bail!("line {lineno}: malformed bucket line {line:?}");
                        };
                        le
                    };
                    if !v.is_finite() || v < 0.0 {
                        bail!("line {lineno}: negative or non-finite bucket count {v}");
                    }
                    if le.is_finite() {
                        if h.inf.is_some() {
                            bail!("line {lineno}: bucket after the le=\"+Inf\" bucket");
                        }
                        if let Some(&(prev_le, prev_cum)) = h.buckets.last() {
                            if le <= prev_le {
                                bail!("line {lineno}: bucket bounds out of order");
                            }
                            if v < prev_cum {
                                bail!("line {lineno}: non-cumulative bucket counts");
                            }
                        }
                        h.buckets.push((le, v));
                    } else {
                        if h.inf.is_some() {
                            bail!("line {lineno}: duplicate le=\"+Inf\" bucket");
                        }
                        if let Some(&(_, prev_cum)) = h.buckets.last() {
                            if v < prev_cum {
                                bail!("line {lineno}: non-cumulative bucket counts");
                            }
                        }
                        h.inf = Some(v);
                    }
                } else if series == format!("{}_sum", fam.name) {
                    if !v.is_finite() || v < 0.0 {
                        bail!("line {lineno}: negative or non-finite histogram _sum {v}");
                    }
                    if h.sum.replace(v).is_some() {
                        bail!("line {lineno}: duplicate series {series:?}");
                    }
                } else if series == format!("{}_count", fam.name) {
                    if !v.is_finite() || v < 0.0 {
                        bail!("line {lineno}: negative or non-finite histogram _count {v}");
                    }
                    if h.count.replace(v).is_some() {
                        bail!("line {lineno}: duplicate series {series:?}");
                    }
                } else {
                    bail!(
                        "line {lineno}: sample {series_name:?} does not belong to family {:?}",
                        fam.name
                    );
                }
            }
        }
        fam.samples += 1;
    }
    close_family(&mut families, &mut hist, &mut values)?;
    for fam in &families {
        if fam.samples == 0 {
            bail!("family {:?} declares no samples", fam.name);
        }
    }
    if families.is_empty() {
        bail!("no metric families found");
    }
    Ok((families, values))
}

/// Validate exposition text and summarize its families. Errors name the
/// offending line. Accepts exactly what [`render`] produces (plus any
/// conforming exposition: extra `#` comments are ignored).
pub fn parse_exposition(text: &str) -> Result<Vec<Family>> {
    parse_core(text).map(|(fams, _)| fams)
}

/// Validate exposition text and reconstruct every series value —
/// counters and gauges keyed by their full series name (labels
/// included), histograms keyed by family name. This is the read side
/// `invertnet top` renders its dashboard from.
pub fn parse_values(text: &str) -> Result<std::collections::BTreeMap<String, Value>> {
    parse_core(text).map(|(_, vals)| vals)
}

#[cfg(test)]
mod tests {
    use super::super::registry::Histogram;
    use super::*;

    fn demo_snapshot() -> Vec<(String, Sample)> {
        let h = Histogram::new();
        for v in [1u64, 2, 3, 4, 5, 6, 7, 8] {
            h.record(v);
        }
        vec![
            ("demo_gauge".to_string(), Sample::Gauge(-1.5)),
            ("demo_lat_us".to_string(), Sample::Histogram(h.snapshot())),
            ("demo_total".to_string(), Sample::Counter(42)),
        ]
    }

    #[test]
    fn renders_cumulative_buckets_in_exposition_format() {
        let text = render(&demo_snapshot());
        let expected = "\
# TYPE demo_gauge gauge
demo_gauge -1.5
# TYPE demo_lat_us histogram
demo_lat_us_bucket{le=\"0\"} 0
demo_lat_us_bucket{le=\"1\"} 1
demo_lat_us_bucket{le=\"3\"} 3
demo_lat_us_bucket{le=\"7\"} 7
demo_lat_us_bucket{le=\"15\"} 8
demo_lat_us_bucket{le=\"+Inf\"} 8
demo_lat_us_sum 36
demo_lat_us_count 8
# TYPE demo_total counter
demo_total 42
";
        assert_eq!(text, expected);
    }

    #[test]
    fn parser_roundtrips_rendered_output() {
        let fams = parse_exposition(&render(&demo_snapshot())).unwrap();
        assert_eq!(
            fams,
            vec![
                Family { name: "demo_gauge".into(), kind: "gauge".into(), samples: 1 },
                Family { name: "demo_lat_us".into(), kind: "histogram".into(), samples: 8 },
                Family { name: "demo_total".into(), kind: "counter".into(), samples: 1 },
            ]
        );
    }

    #[test]
    fn parser_rejects_malformed_text() {
        assert!(parse_exposition("").is_err());
        assert!(parse_exposition("orphan 1\n").is_err(), "sample before TYPE");
        assert!(parse_exposition("# TYPE a counter\n").is_err(), "family with no samples");
        assert!(parse_exposition("# TYPE a counter\na notanumber\n").is_err());
        assert!(parse_exposition("# TYPE a counter\nb 1\n").is_err(), "foreign sample");
        assert!(parse_exposition("# TYPE a summary\na 1\n").is_err(), "unknown kind");
        assert!(
            parse_exposition("# TYPE a counter\na 1\n# TYPE a counter\na 2\n").is_err(),
            "duplicate family"
        );
    }

    #[test]
    fn empty_histogram_still_exposes_inf_bucket() {
        let h = Histogram::new();
        let text =
            render(&[("h_us".to_string(), Sample::Histogram(h.snapshot()))]);
        assert_eq!(
            text,
            "# TYPE h_us histogram\nh_us_bucket{le=\"+Inf\"} 0\nh_us_sum 0\nh_us_count 0\n"
        );
        parse_exposition(&text).unwrap();
    }
}
