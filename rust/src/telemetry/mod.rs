//! Telemetry spine: process-wide metrics, span timers, trace export.
//!
//! Every long-running subsystem (train loop, `ParallelTrainer`, the
//! RefBackend scratch pool, the serve stack, posterior training) feeds
//! instruments from this module; the collected state is exported three
//! ways, all as Prometheus text exposition:
//!
//! * the serve protocol's `metrics` op (and a plain `GET` scrape on the
//!   TCP front),
//! * `--metrics-out FILE` on `train` / `posterior-train` / `bench`
//!   (snapshot written at exit),
//! * `invertnet metrics [FILE]` — dump the live registry, or validate
//!   and summarize a previously written exposition file.
//!
//! Hot-path contract: recording an event is a few relaxed atomic adds —
//! no locks, no allocation, no branches beyond one flag load. The flag
//! is [`set_enabled`]: flipping it off makes every instrument a no-op,
//! which is how the `train_throughput` bench suite measures
//! instrumentation overhead (`telemetry_overhead_pct`, gated < 2%)
//! without building the crate twice. Telemetry never touches numeric
//! state, so all bit-exactness pins hold with it enabled.

pub mod encode;
pub mod events;
mod registry;
mod span;

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use anyhow::{Context, Result};

pub use registry::{
    bucket_of, bucket_upper, Counter, Gauge, HistSnapshot, Histogram, Registry, Sample, NBUCKETS,
};
pub use span::{enable_trace, finish_trace, flush_trace, trace_enabled, SpanTimer};

static ENABLED: AtomicBool = AtomicBool::new(true);

/// Process-wide kill switch. With telemetry disabled every counter
/// increment, gauge store, and histogram record returns after a single
/// relaxed load — the compiled-out baseline the overhead gate compares
/// against. Export surfaces keep working (they read whatever was
/// recorded while enabled).
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Whether instruments currently record (default: yes).
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// The process-wide registry. Subsystems with process-global lifetime
/// (train loop, scratch pool, spans) register here; request-scoped
/// state (`ServeStats`, the model registry) embeds instruments directly
/// and contributes snapshots at scrape time instead, so unit tests get
/// isolated counts.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// Render the global registry as Prometheus text exposition.
pub fn render_global() -> String {
    encode::render(&global().snapshot())
}

/// Write the global registry snapshot to `path` (the `--metrics-out`
/// exit dump on train/bench verbs).
pub fn write_metrics_file(path: &Path) -> Result<()> {
    std::fs::write(path, render_global())
        .with_context(|| format!("writing metrics snapshot to {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn global_registry_is_shared_and_renderable() {
        global().counter("invertnet_modtest_total").add(5);
        let text = render_global();
        assert!(text.contains("# TYPE invertnet_modtest_total counter"), "{text}");
        encode::parse_exposition(&text).unwrap();
    }
}
