//! RAII span timers and the optional Chrome `trace_event` export.
//!
//! `let _sp = span!("train_step");` times the enclosing scope and, on
//! drop, records the elapsed microseconds into the global histogram
//! `invertnet_span_<name>_us`. Span names are `&'static str` by contract:
//! the histogram handle is cached in a side map keyed by the name, so the
//! steady-state cost of a span is two `Instant` reads, one map lookup
//! under a short lock, and one histogram record — no allocation.
//!
//! When tracing is enabled (`--trace FILE`), each completed span also
//! appends one complete-event line (`"ph":"X"`) to the trace file in
//! Chrome `trace_event` JSON-array format. The format allows the closing
//! `]` to be omitted, which is what makes append-only writing from many
//! threads (behind one buffered writer) valid: the file is loadable by
//! `chrome://tracing` or Perfetto even if the process is killed mid-run.

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::registry::Histogram;

/// Times a scope; records into `invertnet_span_<name>_us` on drop.
/// Construct via [`SpanTimer::start`] or the [`span!`](crate::span) macro.
pub struct SpanTimer {
    name: &'static str,
    hist: Arc<Histogram>,
    t0: Instant,
}

fn span_hists() -> &'static Mutex<BTreeMap<&'static str, Arc<Histogram>>> {
    static MAP: OnceLock<Mutex<BTreeMap<&'static str, Arc<Histogram>>>> = OnceLock::new();
    MAP.get_or_init(|| Mutex::new(BTreeMap::new()))
}

impl SpanTimer {
    pub fn start(name: &'static str) -> Self {
        let hist = {
            let mut map = span_hists().lock().unwrap();
            match map.get(name) {
                Some(h) => Arc::clone(h),
                None => {
                    // First use of this span name in the process: register
                    // its histogram (the only allocating path).
                    let h = super::global().histogram(&format!("invertnet_span_{name}_us"));
                    map.insert(name, Arc::clone(&h));
                    h
                }
            }
        };
        Self { name, hist, t0: Instant::now() }
    }
}

impl Drop for SpanTimer {
    fn drop(&mut self) {
        let us = self.t0.elapsed().as_micros() as u64;
        self.hist.record(us);
        if TRACE_ON.load(Ordering::Relaxed) {
            emit_trace(self.name, self.t0, us);
        }
    }
}

/// Open a RAII span timer feeding `invertnet_span_<name>_us`.
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::telemetry::SpanTimer::start($name)
    };
}

static TRACE_ON: AtomicBool = AtomicBool::new(false);
static TRACE: OnceLock<Mutex<TraceSink>> = OnceLock::new();
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

struct TraceSink {
    out: BufWriter<File>,
    epoch: Instant,
    closed: bool,
}

/// Start exporting completed spans to `path` in Chrome `trace_event`
/// format. One sink per process; a second call fails.
pub fn enable_trace(path: &Path) -> Result<()> {
    let epoch = Instant::now();
    let mut out = BufWriter::new(
        File::create(path).with_context(|| format!("creating trace file {path:?}"))?,
    );
    out.write_all(b"[\n").context("writing trace header")?;
    if TRACE.set(Mutex::new(TraceSink { out, epoch, closed: false })).is_err() {
        bail!("trace export is already enabled for this process");
    }
    TRACE_ON.store(true, Ordering::Relaxed);
    Ok(())
}

/// Whether a trace sink is active.
pub fn trace_enabled() -> bool {
    TRACE_ON.load(Ordering::Relaxed)
}

/// Flush buffered trace events to disk (call before process exit).
pub fn flush_trace() {
    if let Some(sink) = TRACE.get() {
        let _ = sink.lock().unwrap().out.flush();
    }
}

/// Finalize the trace file: append a terminating `{}` element (which
/// absorbs the trailing comma every event line carries), close the JSON
/// array, and flush. After this the file is strictly valid JSON, not
/// just Chrome's comma-tolerant dialect. Idempotent, and a no-op when
/// tracing was never enabled; further spans are dropped rather than
/// written past the closing bracket. Every `main.rs` exit path —
/// success, `CheckFailed`, `UsageError` — runs through this exactly
/// once.
pub fn finish_trace() {
    let Some(sink) = TRACE.get() else { return };
    let mut sink = sink.lock().unwrap();
    if sink.closed {
        return;
    }
    sink.closed = true;
    TRACE_ON.store(false, Ordering::Relaxed);
    let _ = sink.out.write_all(b"{}\n]\n");
    let _ = sink.out.flush();
}

fn emit_trace(name: &str, t0: Instant, dur_us: u64) {
    let Some(sink) = TRACE.get() else { return };
    let tid = TID.with(|t| *t);
    let mut sink = sink.lock().unwrap();
    if sink.closed {
        return;
    }
    let ts = t0.duration_since(sink.epoch).as_micros() as u64;
    // Complete event ("ph":"X"): name, start, duration. Span names are
    // static identifiers from the code base, so no JSON escaping is
    // needed beyond trusting our own catalog.
    let _ = writeln!(
        sink.out,
        "{{\"name\":\"{name}\",\"cat\":\"invertnet\",\"ph\":\"X\",\"ts\":{ts},\"dur\":{dur_us},\"pid\":1,\"tid\":{tid}}},"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spans_feed_the_global_span_histogram() {
        {
            let _sp = SpanTimer::start("unit_test_span");
            std::hint::black_box(1 + 1);
        }
        {
            let _sp = crate::span!("unit_test_span");
        }
        let snap = super::super::global()
            .histogram("invertnet_span_unit_test_span_us")
            .snapshot();
        assert!(snap.count >= 2, "expected both spans recorded, got {}", snap.count);
    }

    #[test]
    fn tids_are_stable_within_a_thread() {
        let a = TID.with(|t| *t);
        let b = TID.with(|t| *t);
        assert_eq!(a, b);
        let other = std::thread::spawn(|| TID.with(|t| *t)).join().unwrap();
        assert_ne!(a, other);
    }
}
