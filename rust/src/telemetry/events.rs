//! Structured operational event log + in-memory flight recorder.
//!
//! Events are the narrative counterpart to the metrics registry: discrete,
//! leveled, machine-parseable JSON-lines records of the moments an operator
//! cares about — a model loaded or evicted, a batch fired, a request ran
//! slow, the queue saturated, the process shut down. Schema
//! `invertnet-event/v1`: every line carries `schema`, `seq` (process-wide,
//! monotonic), `ts_ms` (unix millis), `level` (`info|warn|error`), `kind`,
//! and flat kind-specific fields.
//!
//! Two consumers see each event:
//!
//! * an optional **sink** (`--log-json FILE|stderr`) — one JSON line per
//!   event, rate-limited per kind (info/warn capped at
//!   [`RATE_LIMIT_PER_SEC`] lines per second per kind; error-level events
//!   are never dropped). Dropped lines are counted, not silently lost:
//!   the count is exported as `invertnet_events_dropped_total` and echoed
//!   in every dump report.
//! * the **flight recorder** — a fixed-capacity ring of the last
//!   [`RING_CAP`] events, kept regardless of whether a sink is configured
//!   and *not* rate-limited. [`dump_report`] serializes the ring as an
//!   `invertnet-dump/v1` incident report; the serve stack emits one on
//!   request-error bursts and answers the `{"op":"debug-dump"}` protocol
//!   op with it.
//!
//! Recording is gated on the process-wide [`enabled`](super::enabled)
//! switch, like every other instrument, so the telemetry-overhead bench
//! gate measures the event path too. The steady-state cost of an emitted
//! event is one mutex lock plus a small allocation — acceptable because
//! events fire per batch / per incident, never per tensor op.

use std::collections::{BTreeMap, VecDeque};
use std::fs::File;
use std::io::{BufWriter, Write as _};
use std::path::Path;
use std::sync::{Mutex, OnceLock};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Schema tag carried by every event line.
pub const EVENT_SCHEMA: &str = "invertnet-event/v1";
/// Schema tag carried by flight-recorder dump reports.
pub const DUMP_SCHEMA: &str = "invertnet-dump/v1";
/// Flight-recorder capacity (last N events, oldest evicted first).
pub const RING_CAP: usize = 256;
/// Per-kind sink budget: info/warn lines per second before dropping.
pub const RATE_LIMIT_PER_SEC: u64 = 32;

/// Event severity. `Error` bypasses the sink rate limit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    Info,
    Warn,
    Error,
}

impl Level {
    fn as_str(self) -> &'static str {
        match self {
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

enum Sink {
    Stderr,
    File(BufWriter<File>),
}

impl Sink {
    fn write_line(&mut self, line: &str) {
        match self {
            Sink::Stderr => eprintln!("{line}"),
            Sink::File(f) => {
                let _ = writeln!(f, "{line}");
                let _ = f.flush();
            }
        }
    }
}

struct State {
    sink: Option<Sink>,
    ring: VecDeque<Json>,
    /// kind -> (window start, lines written to the sink this window).
    windows: BTreeMap<&'static str, (Instant, u64)>,
    seq: u64,
    emitted: u64,
    dropped: u64,
}

fn state() -> &'static Mutex<State> {
    static STATE: OnceLock<Mutex<State>> = OnceLock::new();
    STATE.get_or_init(|| {
        Mutex::new(State {
            sink: None,
            ring: VecDeque::with_capacity(RING_CAP),
            windows: BTreeMap::new(),
            seq: 0,
            emitted: 0,
            dropped: 0,
        })
    })
}

/// Point the event sink at `target`: the literal `"stderr"`, or a file
/// path (created/truncated). Reconfiguring replaces the previous sink —
/// last writer wins — so tests and re-exec'ed daemons need no teardown.
/// The flight recorder is untouched either way.
pub fn configure(target: &str) -> Result<()> {
    let sink = if target == "stderr" {
        Sink::Stderr
    } else {
        let f = File::create(Path::new(target))
            .with_context(|| format!("creating event log {target:?}"))?;
        Sink::File(BufWriter::new(f))
    };
    state().lock().unwrap().sink = Some(sink);
    Ok(())
}

fn unix_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

/// Record one event. `kind` is a static identifier from the fixed event
/// catalog (`model_load`, `batch_fired`, `slow_request`, ...); `fields`
/// are flat kind-specific keys merged into the line. No-op while the
/// telemetry kill switch is off.
pub fn emit(level: Level, kind: &'static str, fields: Vec<(&str, Json)>) {
    if !super::enabled() {
        return;
    }
    let mut st = state().lock().unwrap();
    st.seq += 1;
    st.emitted += 1;
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("schema".into(), Json::Str(EVENT_SCHEMA.into()));
    obj.insert("seq".into(), Json::Num(st.seq as f64));
    obj.insert("ts_ms".into(), Json::Num(unix_ms() as f64));
    obj.insert("level".into(), Json::Str(level.as_str().into()));
    obj.insert("kind".into(), Json::Str(kind.into()));
    for (k, v) in fields {
        obj.insert(k.to_string(), v);
    }
    let event = Json::Obj(obj);

    // Flight recorder sees everything, rate limit or not.
    if st.ring.len() == RING_CAP {
        st.ring.pop_front();
    }
    st.ring.push_back(event.clone());
    super::global().counter("invertnet_events_total").inc();

    if st.sink.is_none() {
        return;
    }
    // Per-kind 1-second token window; error level always goes through.
    let now = Instant::now();
    let allowed = level == Level::Error || {
        let (start, n) = st.windows.entry(kind).or_insert((now, 0));
        if now.duration_since(*start).as_secs() >= 1 {
            *start = now;
            *n = 0;
        }
        *n += 1;
        *n <= RATE_LIMIT_PER_SEC
    };
    if !allowed {
        st.dropped += 1;
        super::global().counter("invertnet_events_dropped_total").inc();
        return;
    }
    let line = event.to_string();
    if let Some(sink) = st.sink.as_mut() {
        sink.write_line(&line);
    }
}

/// Serialize the flight recorder as an `invertnet-dump/v1` incident
/// report: the ring contents (oldest first), emit/drop totals, and any
/// caller-supplied `extra` context (the serve stack attaches its stats
/// snapshot). Read-only — the ring keeps its contents.
pub fn dump_report(reason: &str, extra: Vec<(&str, Json)>) -> Json {
    let st = state().lock().unwrap();
    let mut obj: BTreeMap<String, Json> = BTreeMap::new();
    obj.insert("schema".into(), Json::Str(DUMP_SCHEMA.into()));
    obj.insert("reason".into(), Json::Str(reason.into()));
    obj.insert("ts_ms".into(), Json::Num(unix_ms() as f64));
    obj.insert("events".into(), Json::Arr(st.ring.iter().cloned().collect()));
    obj.insert("emitted_total".into(), Json::Num(st.emitted as f64));
    obj.insert("dropped_total".into(), Json::Num(st.dropped as f64));
    for (k, v) in extra {
        obj.insert(k.to_string(), v);
    }
    Json::Obj(obj)
}

/// Write a dump report straight to the sink (one line, never
/// rate-limited). Used for request-error bursts; no-op without a sink.
pub fn emit_dump(reason: &str, extra: Vec<(&str, Json)>) {
    let report = dump_report(reason, extra);
    let line = report.to_string();
    let mut st = state().lock().unwrap();
    if let Some(sink) = st.sink.as_mut() {
        sink.write_line(&line);
    }
}

/// Number of events currently held by the flight recorder.
pub fn ring_len() -> usize {
    state().lock().unwrap().ring.len()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// One sequential test: the sink and ring are process-global, so
    /// splitting these stages across parallel `#[test]` functions would
    /// race (a reconfigured sink steals another stage's lines; a ring
    /// flood evicts another stage's probe). Other suites' events may
    /// interleave, so every assertion filters by kinds unique to this
    /// module. (Kill-switch behavior is covered in
    /// `tests/telemetry.rs` under its `ENABLED_LOCK`.)
    #[test]
    fn event_log_end_to_end() {
        // -- envelope + flight recorder --------------------------------
        emit(Level::Warn, "events_unit_probe", vec![
            ("model", Json::Str("realnvp2d".into())),
            ("rows", Json::Num(8.0)),
        ]);
        let report = dump_report("unit test", vec![("ctx", Json::Num(7.0))]);
        assert_eq!(report.req("schema").unwrap().as_str().unwrap(), DUMP_SCHEMA);
        assert_eq!(report.req("ctx").unwrap().as_f64().unwrap(), 7.0);
        let events = report.req("events").unwrap().as_arr().unwrap();
        let e = events
            .iter()
            .rev()
            .find(|e| {
                e.get("kind").and_then(|k| k.as_str().ok()) == Some("events_unit_probe")
            })
            .expect("probe event missing from ring");
        assert_eq!(e.req("schema").unwrap().as_str().unwrap(), EVENT_SCHEMA);
        assert_eq!(e.req("level").unwrap().as_str().unwrap(), "warn");
        assert_eq!(e.req("rows").unwrap().as_f64().unwrap(), 8.0);
        assert!(e.req("seq").unwrap().as_f64().unwrap() >= 1.0);
        assert!(e.req("ts_ms").unwrap().as_f64().unwrap() > 0.0);
        // the dump itself reparses as JSON
        Json::parse(&report.to_string()).unwrap();

        // -- file sink -------------------------------------------------
        let dir = std::env::temp_dir().join("invertnet_events_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        configure(path.to_str().unwrap()).unwrap();
        emit(Level::Info, "events_unit_sink", vec![("k", Json::Num(1.0))]);
        let sink_text = std::fs::read_to_string(&path).unwrap();
        let mine: Vec<&str> = sink_text
            .lines()
            .filter(|l| l.contains("\"events_unit_sink\""))
            .collect();
        assert_eq!(mine.len(), 1, "expected exactly one sink line: {sink_text}");
        let parsed = Json::parse(mine[0]).unwrap();
        assert_eq!(parsed.req("schema").unwrap().as_str().unwrap(), EVENT_SCHEMA);
        assert_eq!(parsed.req("k").unwrap().as_f64().unwrap(), 1.0);

        // -- per-kind rate limit ---------------------------------------
        let n = RATE_LIMIT_PER_SEC + 20;
        for _ in 0..n {
            emit(Level::Info, "events_unit_ratelimited", vec![]);
        }
        for _ in 0..n {
            emit(Level::Error, "events_unit_errors", vec![]);
        }
        let text = std::fs::read_to_string(&path).unwrap();
        let infos = text.lines().filter(|l| l.contains("events_unit_ratelimited")).count();
        let errors = text.lines().filter(|l| l.contains("events_unit_errors")).count();
        assert_eq!(infos as u64, RATE_LIMIT_PER_SEC, "info lines past the cap must drop");
        assert_eq!(errors as u64, n, "error lines must never drop");
        let report = dump_report("rate limit test", vec![]);
        assert!(report.req("dropped_total").unwrap().as_f64().unwrap() >= 20.0);

        // -- emit_dump writes one report line to the sink --------------
        emit_dump("events_unit_dump_reason", vec![]);
        let text = std::fs::read_to_string(&path).unwrap();
        let dumps: Vec<&str> = text
            .lines()
            .filter(|l| l.contains("events_unit_dump_reason"))
            .collect();
        assert_eq!(dumps.len(), 1, "expected exactly one dump line");
        let d = Json::parse(dumps[0]).unwrap();
        assert_eq!(d.req("schema").unwrap().as_str().unwrap(), DUMP_SCHEMA);

        // -- ring stays bounded ----------------------------------------
        for _ in 0..(RING_CAP + 10) {
            emit(Level::Info, "events_unit_flood", vec![]);
        }
        assert_eq!(ring_len(), RING_CAP);
    }
}
