//! Metric primitives and the lock-sharded name registry.
//!
//! Three instrument kinds, all built on relaxed `AtomicU64` cells so the
//! hot-path cost of an event is a handful of uncontended atomic adds —
//! no locks, no allocation, no syscalls:
//!
//! * [`Counter`] — monotonic event count (`_total` series).
//! * [`Gauge`] — last-write-wins `f64` (stored as IEEE-754 bits).
//! * [`Histogram`] — fixed log2 bucket bounds. Because every histogram in
//!   the process shares the same 65 bucket edges, percentiles of a *merge*
//!   of histograms are computed by adding bucket counts — never by sorting
//!   samples. This is what lets serve `stats` report p50/p99/p99.9 over
//!   per-op histograms without keeping a sample ring.
//!
//! The [`Registry`] maps names to instruments behind a small fixed set of
//! mutex shards. The lock is taken only at registration and scrape time;
//! callers hold `Arc` handles (or embed instruments directly in their own
//! structs) so steady-state recording never touches the registry.
//!
//! All recording methods are gated on the process-wide
//! [`enabled`](super::enabled) switch, which is how the bench harness
//! measures instrumentation overhead without a second build.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Bucket count for every [`Histogram`]: bucket 0 holds exact zeros and
/// bucket `i >= 1` holds values `v` with `2^(i-1) <= v < 2^i`.
pub const NBUCKETS: usize = 65;

const NSHARDS: usize = 8;

/// Bucket index for a recorded value: its bit width (0 for 0).
#[inline]
pub fn bucket_of(v: u64) -> usize {
    (64 - v.leading_zeros()) as usize
}

/// Inclusive upper bound of bucket `i` (`0`, then `2^i - 1`).
pub fn bucket_upper(i: usize) -> u64 {
    match i {
        0 => 0,
        64 => u64::MAX,
        _ => (1u64 << i) - 1,
    }
}

/// Monotonic counter. `inc`/`add` are single relaxed `fetch_add`s.
#[derive(Debug, Default)]
pub struct Counter {
    v: AtomicU64,
}

impl Counter {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn inc(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        if super::enabled() {
            self.v.fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> u64 {
        self.v.load(Ordering::Relaxed)
    }
}

/// Last-write-wins `f64` gauge stored as raw bits in an `AtomicU64`.
#[derive(Debug, Default)]
pub struct Gauge {
    bits: AtomicU64,
}

impl Gauge {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn set(&self, v: f64) {
        if super::enabled() {
            self.bits.store(v.to_bits(), Ordering::Relaxed);
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.bits.load(Ordering::Relaxed))
    }
}

/// Log2-bucketed histogram of `u64` observations (typically microseconds
/// or row counts). Recording is three relaxed `fetch_add`s.
#[derive(Debug)]
pub struct Histogram {
    count: AtomicU64,
    sum: AtomicU64,
    buckets: [AtomicU64; NBUCKETS],
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn record(&self, v: u64) {
        if super::enabled() {
            self.count.fetch_add(1, Ordering::Relaxed);
            self.sum.fetch_add(v, Ordering::Relaxed);
            self.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        }
    }

    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            count: self.count.load(Ordering::Relaxed),
            sum: self.sum.load(Ordering::Relaxed),
            buckets: std::array::from_fn(|i| self.buckets[i].load(Ordering::Relaxed)),
        }
    }
}

/// Point-in-time copy of a histogram's cells. Snapshots from histograms
/// with the same (fixed) bucket bounds merge by adding counts, so the
/// quantiles of a merge are exact with respect to the bucketing.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HistSnapshot {
    pub count: u64,
    pub sum: u64,
    pub buckets: [u64; NBUCKETS],
}

impl Default for HistSnapshot {
    fn default() -> Self {
        Self { count: 0, sum: 0, buckets: [0; NBUCKETS] }
    }
}

impl HistSnapshot {
    pub fn merge(&mut self, other: &HistSnapshot) {
        self.count += other.count;
        self.sum += other.sum;
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += *o;
        }
    }

    /// Mean of the recorded values (exact — from `sum`/`count`).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Quantile estimate by rank-walk over the buckets with linear
    /// interpolation inside the owning bucket. The rank of quantile `q`
    /// over `n` samples is `ceil(q*n)` clamped to `[1, n]`; bucket `i`
    /// spans `[2^(i-1), 2^i - 1]`. Mirrored bit-for-bit by
    /// `python/tests/test_telemetry.py`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q * self.count as f64).ceil().max(1.0) as u64).min(self.count);
        let mut before = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if before + c >= target {
                if i == 0 {
                    return 0.0;
                }
                let lo = 2f64.powi(i as i32 - 1);
                let hi = 2f64.powi(i as i32) - 1.0;
                let frac = (target - before) as f64 / c as f64;
                return lo + frac * (hi - lo);
            }
            before += c;
        }
        bucket_upper(NBUCKETS - 1) as f64
    }

    /// `quantile` rounded to the nearest integer (wire-friendly µs).
    pub fn quantile_u64(&self, q: f64) -> u64 {
        self.quantile(q).round() as u64
    }
}

/// One scraped series: the value side of a registry snapshot entry.
#[derive(Debug, Clone, PartialEq)]
pub enum Sample {
    Counter(u64),
    Gauge(f64),
    Histogram(HistSnapshot),
    /// A counter family broken out by one label key — e.g. per-model
    /// request counts: `label` is the key, `values` the
    /// `(label_value, count)` rows, rendered as `name{key="value"} n`
    /// lines under a single `# TYPE name counter` declaration. Label
    /// values come from our own model-name catalog (no quotes or
    /// backslashes), so rendering needs no escaping.
    LabeledCounter { label: &'static str, values: Vec<(String, u64)> },
}

enum Entry {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// Name → instrument map behind `NSHARDS` mutex shards. Shard choice is an
/// FNV-1a hash of the name, so unrelated subsystems registering at startup
/// do not serialize on one lock. Instruments are created on first use and
/// live for the life of the registry; `snapshot` walks every shard.
pub struct Registry {
    shards: Vec<Mutex<BTreeMap<String, Entry>>>,
}

impl Default for Registry {
    fn default() -> Self {
        Self::new()
    }
}

fn shard_of(name: &str) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h as usize) % NSHARDS
}

impl Registry {
    pub fn new() -> Self {
        Self { shards: (0..NSHARDS).map(|_| Mutex::new(BTreeMap::new())).collect() }
    }

    /// Get-or-create the counter `name`. A kind collision (the name is
    /// already a gauge or histogram) returns a detached instrument that
    /// records but is never exported — collisions indicate a naming bug,
    /// and the fixed metric catalog avoids them.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut shard = self.shards[shard_of(name)].lock().unwrap();
        if let Some(Entry::Counter(c)) = shard.get(name) {
            return Arc::clone(c);
        }
        if shard.contains_key(name) {
            return Arc::new(Counter::new());
        }
        let c = Arc::new(Counter::new());
        shard.insert(name.to_string(), Entry::Counter(Arc::clone(&c)));
        c
    }

    /// Get-or-create the gauge `name` (collision policy as [`counter`](Self::counter)).
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut shard = self.shards[shard_of(name)].lock().unwrap();
        if let Some(Entry::Gauge(g)) = shard.get(name) {
            return Arc::clone(g);
        }
        if shard.contains_key(name) {
            return Arc::new(Gauge::new());
        }
        let g = Arc::new(Gauge::new());
        shard.insert(name.to_string(), Entry::Gauge(Arc::clone(&g)));
        g
    }

    /// Get-or-create the histogram `name` (collision policy as [`counter`](Self::counter)).
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        let mut shard = self.shards[shard_of(name)].lock().unwrap();
        if let Some(Entry::Histogram(h)) = shard.get(name) {
            return Arc::clone(h);
        }
        if shard.contains_key(name) {
            return Arc::new(Histogram::new());
        }
        let h = Arc::new(Histogram::new());
        shard.insert(name.to_string(), Entry::Histogram(Arc::clone(&h)));
        h
    }

    /// Point-in-time copy of every registered series, sorted by name so
    /// encoder output (and the golden test pinning it) is deterministic.
    pub fn snapshot(&self) -> Vec<(String, Sample)> {
        let mut all = BTreeMap::new();
        for shard in &self.shards {
            let shard = shard.lock().unwrap();
            for (name, entry) in shard.iter() {
                let sample = match entry {
                    Entry::Counter(c) => Sample::Counter(c.get()),
                    Entry::Gauge(g) => Sample::Gauge(g.get()),
                    Entry::Histogram(h) => Sample::Histogram(h.snapshot()),
                };
                all.insert(name.clone(), sample);
            }
        }
        all.into_iter().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_pins() {
        // Bucket of v is its bit width: 0 stays in bucket 0, powers of
        // two open a new bucket.
        for (v, idx) in [
            (0u64, 0usize),
            (1, 1),
            (2, 2),
            (3, 2),
            (4, 3),
            (7, 3),
            (8, 4),
            (1023, 10),
            (1024, 11),
            (u64::MAX, 64),
        ] {
            assert_eq!(bucket_of(v), idx, "bucket_of({v})");
            if idx > 0 {
                assert!(v > bucket_upper(idx - 1), "lower edge of bucket {idx}");
            }
            assert!(v <= bucket_upper(idx), "upper edge of bucket {idx}");
        }
    }

    #[test]
    fn quantiles_interpolate_inside_the_owning_bucket() {
        let h = Histogram::new();
        for v in 1..=8u64 {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count, 8);
        assert_eq!(s.sum, 36);
        // rank ceil(.5*8)=4 lands in bucket 3 ([4,7]) as its first of
        // four samples: 4 + 1/4 * 3 = 4.75.
        assert_eq!(s.quantile(0.50), 4.75);
        assert_eq!(s.quantile_u64(0.50), 5);
        // rank 8 is the only sample of bucket 4 ([8,15]): 8 + 7 = 15.
        assert_eq!(s.quantile(0.99), 15.0);
        assert_eq!(s.quantile(0.0), 1.0);
        assert_eq!(HistSnapshot::default().quantile(0.5), 0.0);
    }

    #[test]
    fn merged_snapshots_answer_the_pooled_quantile() {
        let a = Histogram::new();
        let b = Histogram::new();
        for v in [1u64, 2, 3, 4] {
            a.record(v);
        }
        for v in [100u64, 200, 300, 400] {
            b.record(v);
        }
        let mut m = a.snapshot();
        m.merge(&b.snapshot());
        assert_eq!(m.count, 8);
        assert_eq!(m.sum, 1010);
        let pooled = Histogram::new();
        for v in [1u64, 2, 3, 4, 100, 200, 300, 400] {
            pooled.record(v);
        }
        assert_eq!(m, pooled.snapshot());
        assert!(m.quantile(0.99) > 256.0, "p99 must come from b's buckets");
    }

    #[test]
    fn registry_hands_out_shared_handles() {
        let r = Registry::new();
        let c1 = r.counter("a_total");
        let c2 = r.counter("a_total");
        assert!(Arc::ptr_eq(&c1, &c2));
        c1.inc();
        c2.add(2);
        assert_eq!(c1.get(), 3);
        // Kind collision yields a detached instrument, not a panic and
        // not a silently shared cell of the wrong type.
        let g = r.gauge("a_total");
        g.set(9.0);
        let snap = r.snapshot();
        assert_eq!(snap, vec![("a_total".into(), Sample::Counter(3))]);
    }

    #[test]
    fn snapshot_is_sorted_and_complete() {
        let r = Registry::new();
        r.gauge("z_gauge").set(-1.5);
        r.counter("m_total").inc();
        r.histogram("a_us").record(7);
        let names: Vec<&str> = r.snapshot().iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["a_us", "m_total", "z_gauge"]);
        match &r.snapshot()[2].1 {
            Sample::Gauge(v) => assert_eq!(*v, -1.5),
            other => panic!("expected gauge, got {other:?}"),
        }
    }
}
