//! invertnet CLI — thin binary wrapper over [`invertnet::app::run`] (the
//! dispatch lives in the library so it is integration-testable).
//!
//! ```text
//! invertnet train   --net realnvp2d --data two-moons --steps 500
//!                   [--mode invertible|stored|checkpoint:K|auto[:BUDGET]]
//!                   [--threads N] [--microbatch N] [--eval-every N]
//!                   [--metrics-out FILE] [--trace FILE]
//! invertnet sample  --net realnvp2d --ckpt runs/x/checkpoint --out samples.npy
//! invertnet posterior-train  --sim linear-gaussian --out runs/post
//! invertnet posterior-sample --ckpt runs/post/checkpoint --y 0.7,-0.4 --n 256
//! invertnet calibrate        --ckpt runs/post/checkpoint --sim linear-gaussian
//!                            [--datasets 128] [--draws 63] [--check]
//! invertnet serve   --ckpt runs/x/checkpoint [--port 7878 | --stdio]
//!                   [--max-batch 8] [--max-delay-us 500] [--workers 2]
//! invertnet score   --ckpt runs/x/checkpoint --data x.npy --out scores.npy
//! invertnet bench   --suite all|quick|memory|throughput|serve|posterior
//!                   [--out FILE|DIR] [--baseline FILE|DIR] [--check] [--tol 5]
//! invertnet bench   fig1|fig2 [--budget-gb 40]
//! invertnet inspect --net glow16
//! invertnet profile --net glow16 [--iters 5] [--json]
//! invertnet lint    [--net NAME | --all | --ckpt DIR] [--json] [--check]
//! invertnet metrics [FILE]
//! invertnet list
//! ```
//!
//! All subcommands take `--backend ref|xla` (default `ref`, which needs no
//! artifacts) and `--artifacts DIR`. See `invertnet` with no arguments for
//! the full usage text.
//!
//! Exit codes: 0 = pass, 1 = check/runtime failure, 2 = usage error
//! (see [`invertnet::app::exit_code`]).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = invertnet::app::run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(invertnet::app::exit_code(&e));
    }
}
