//! invertnet CLI — thin binary wrapper over [`invertnet::app::run`] (the
//! dispatch lives in the library so it is integration-testable).
//!
//! ```text
//! invertnet train   --net realnvp2d --data two-moons --steps 500
//!                   [--mode invertible|stored|checkpoint:K|auto[:BUDGET]]
//!                   [--threads N] [--microbatch N] [--eval-every N]
//!                   [--metrics-out FILE] [--trace FILE] [--log-json FILE]
//!                   [--slow-ms MS]
//! invertnet sample  --net realnvp2d --ckpt runs/x/checkpoint --out samples.npy
//! invertnet posterior-train  --sim linear-gaussian --out runs/post
//! invertnet posterior-sample --ckpt runs/post/checkpoint --y 0.7,-0.4 --n 256
//! invertnet calibrate        --ckpt runs/post/checkpoint --sim linear-gaussian
//!                            [--datasets 128] [--draws 63] [--check]
//! invertnet serve   --ckpt runs/x/checkpoint [--port 7878 | --stdio]
//!                   [--max-batch 8] [--max-delay-us 500] [--workers 2]
//!                   [--log-json FILE|stderr] [--slow-ms MS]
//! invertnet score   --ckpt runs/x/checkpoint --data x.npy --out scores.npy
//! invertnet top     [--url http://127.0.0.1:7878/metrics | --file F.prom]
//!                   [--interval SECS] [--once]
//! invertnet bench   --suite all|quick|memory|throughput|serve|posterior
//!                   [--out FILE|DIR] [--baseline FILE|DIR] [--check] [--tol 5]
//! invertnet bench   fig1|fig2 [--budget-gb 40]
//! invertnet inspect --net glow16
//! invertnet profile --net glow16 [--iters 5] [--json]
//! invertnet lint    [--net NAME | --all | --ckpt DIR] [--json] [--check]
//! invertnet metrics [FILE]
//! invertnet list
//! ```
//!
//! All subcommands take `--backend ref|xla` (default `ref`, which needs no
//! artifacts), `--artifacts DIR`, `--kernel-threads N` (intra-kernel
//! GEMM/conv fan-out, bit-identical at any N) and `--weight-dtype
//! f32|bf16|f16` (inference weight-storage precision; compute stays f32).
//! See `invertnet` with no arguments for the full usage text.
//!
//! Exit codes: 0 = pass, 1 = check/runtime failure, 2 = usage error
//! (see [`invertnet::app::exit_code`]).

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let result = invertnet::app::run(&argv);
    // the single exit hook, on EVERY path — success, check failure, usage
    // error, runtime error: finalize the Chrome trace (if one is open) so
    // the emitted file is valid JSON even when the verb bailed early
    invertnet::telemetry::finish_trace();
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(invertnet::app::exit_code(&e));
    }
}
