//! invertnet CLI — leader entrypoint.
//!
//! ```text
//! invertnet train   --net realnvp2d --data two-moons --steps 500 [--mode invertible|stored]
//! invertnet sample  --net realnvp2d --ckpt runs/x/checkpoint --out samples.npy
//! invertnet bench   fig1|fig2   [--budget-gb 40]
//! invertnet inspect --net glow16
//! invertnet list
//! ```

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use invertnet::coordinator::{ExecMode, FlowSession};
use invertnet::data::{synth_images, Density2d, LinearGaussian};
use invertnet::flow::{ParamStore, StepKind};
use invertnet::train::{train, Adam, GradClip, TrainConfig};
use invertnet::util::bench::fmt_bytes;
use invertnet::util::cli::Args;
use invertnet::util::rng::Pcg64;
use invertnet::{MemoryLedger, Runtime, Tensor};

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts", "artifacts"))
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(argv)?;
    match args.subcommand.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("sample") => cmd_sample(&args),
        Some("bench") => cmd_bench(&args),
        Some("inspect") => cmd_inspect(&args),
        Some("profile") => {
            let rt = Runtime::new(&artifacts_dir(&args))?;
            invertnet::profile::profile_network(
                &rt, args.req("net")?, args.usize_or("iters", 5)?)
        }
        Some("list") => cmd_list(&args),
        _ => {
            eprintln!("{}", USAGE);
            Ok(())
        }
    }
}

const USAGE: &str = "\
invertnet — memory-frugal normalizing flows (InvertibleNetworks.jl reproduction)

USAGE:
  invertnet train   --net NAME [--data two-moons|eight-gaussians|checkerboard|spiral|images|linear-gaussian]
                    [--steps N] [--lr F] [--mode invertible|stored] [--seed N]
                    [--out DIR] [--artifacts DIR] [--clip F]
  invertnet sample  --net NAME [--ckpt DIR] [--out FILE.npy] [--batches N]
  invertnet bench   fig1|fig2 [--budget-gb F] [--artifacts DIR]
  invertnet inspect --net NAME [--artifacts DIR]
  invertnet profile --net NAME [--iters N]
  invertnet list    [--artifacts DIR]
";

fn mode_of(args: &Args) -> Result<ExecMode> {
    match args.str_or("mode", "invertible") {
        "invertible" => Ok(ExecMode::Invertible),
        "stored" => Ok(ExecMode::Stored),
        other => bail!("unknown --mode {other:?}"),
    }
}

/// Pick a sensible default data source for a network's input shape.
fn default_data(in_shape: &[usize], cond: bool) -> &'static str {
    if cond {
        "linear-gaussian"
    } else if in_shape.len() == 2 {
        "two-moons"
    } else {
        "images"
    }
}

/// Build the batch closure for a (network, data source) pair.
fn batcher(
    data: &str,
    in_shape: Vec<usize>,
    cond: bool,
    seed: u64,
) -> Result<Box<dyn FnMut(usize) -> Result<(Tensor, Option<Tensor>)>>> {
    let mut rng = Pcg64::new(seed ^ 0xda7a);
    match data {
        "images" => {
            if in_shape.len() != 4 {
                bail!("--data images needs an image network");
            }
            Ok(Box::new(move |_| {
                let (n, h, w, c) = (in_shape[0], in_shape[1], in_shape[2], in_shape[3]);
                Ok((synth_images(n, h, w, c, &mut rng), None))
            }))
        }
        "linear-gaussian" => {
            if !cond {
                bail!("--data linear-gaussian needs a conditional network");
            }
            let prob = LinearGaussian::default_problem();
            let n = in_shape[0];
            Ok(Box::new(move |_| {
                let (theta, y) = prob.sample(n, &mut rng);
                Ok((theta, Some(y)))
            }))
        }
        name => {
            let d = Density2d::parse(name)?;
            if in_shape.len() != 2 || cond {
                bail!("--data {name} needs an unconditional dense network");
            }
            let n = in_shape[0];
            Ok(Box::new(move |_| Ok((d.sample(n, &mut rng), None))))
        }
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let net = args.req("net")?;
    let rt = Runtime::new(&artifacts_dir(args))?;
    let ledger = MemoryLedger::new();
    let session = FlowSession::new(&rt, net, ledger.clone())?;
    let seed = args.u64_or("seed", 42)?;
    let mut params = ParamStore::init(&session.def, &rt.manifest, seed)?;
    let mut opt = Adam::new(args.f64_or("lr", 1e-3)? as f32);

    let cond = session.def.cond_shape.is_some();
    let data = args
        .get("data")
        .unwrap_or(default_data(&session.def.in_shape, cond));
    let next = batcher(data, session.def.in_shape.clone(), cond, seed)?;

    let cfg = TrainConfig {
        steps: args.usize_or("steps", 200)?,
        mode: mode_of(args)?,
        clip: Some(GradClip { max_norm: args.f64_or("clip", 50.0)? as f32 }),
        log_every: args.usize_or("log-every", 10)?,
        out_dir: args.get("out").map(PathBuf::from),
        quiet: args.flag("quiet"),
    };

    eprintln!(
        "training {net} ({} params, depth {}, mode {}) on {data}",
        params.param_count(),
        session.def.depth(),
        cfg.mode.name()
    );
    let report = run_train(&session, &mut params, &mut opt, &cfg, next)?;
    println!(
        "final_loss {:.4}  peak_sched {}  {:.2} steps/s",
        report.final_loss,
        fmt_bytes(report.peak_sched_bytes as u64),
        report.steps_per_sec
    );
    Ok(())
}

fn run_train(
    session: &FlowSession,
    params: &mut ParamStore,
    opt: &mut Adam,
    cfg: &TrainConfig,
    next: Box<dyn FnMut(usize) -> Result<(Tensor, Option<Tensor>)>>,
) -> Result<invertnet::train::TrainReport> {
    train(session, params, opt, cfg, next)
}

fn cmd_sample(args: &Args) -> Result<()> {
    let net = args.req("net")?;
    let rt = Runtime::new(&artifacts_dir(args))?;
    let ledger = MemoryLedger::new();
    let session = FlowSession::new(&rt, net, ledger)?;
    let mut params = ParamStore::init(&session.def, &rt.manifest, 42)?;
    if let Some(ckpt) = args.get("ckpt") {
        params.load(Path::new(ckpt))?;
    }
    if session.def.cond_shape.is_some() {
        bail!("use the amortized_inference example for conditional sampling");
    }
    let mut rng = Pcg64::new(args.u64_or("seed", 7)?);
    let batches = args.usize_or("batches", 1)?;
    let mut all: Vec<f32> = Vec::new();
    let mut shape = session.def.in_shape.clone();
    for _ in 0..batches {
        let x = session.sample(&params, None, &mut rng)?;
        all.extend_from_slice(&x.data);
    }
    shape[0] *= batches;
    let out = args.str_or("out", "samples.npy");
    invertnet::tensor::npy::save(Path::new(out), &Tensor::new(shape, all)?)?;
    println!("wrote {out}");
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let net = args.req("net")?;
    let rt = Runtime::new(&artifacts_dir(args))?;
    let session = FlowSession::new(&rt, net, MemoryLedger::new())?;
    let def = &session.def;
    println!("network {net}: input {:?}, cond {:?}", def.in_shape, def.cond_shape);
    let mut total_params = 0usize;
    for (i, s) in def.steps.iter().enumerate() {
        let (kind, nparams) = match s.kind {
            StepKind::Split { zc } => (format!("split(zc={zc})"), 0),
            StepKind::Layer => {
                let m = rt.manifest.layer(&s.sig)?;
                (m.kind.clone(), m.param_count())
            }
        };
        total_params += nparams;
        println!(
            "  [{i:>3}] {kind:<12} {:>18} -> {:<18} {:>9} params   {}",
            format!("{:?}", s.in_shape),
            format!("{:?}", s.out_shape),
            nparams,
            s.sig
        );
    }
    println!("latents: {:?}", def.latent_shapes);
    println!("total params: {total_params}");
    Ok(())
}

fn cmd_list(args: &Args) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(args))?;
    println!("backend: {}", rt.manifest.backend);
    println!("{:<24} {:>18} {:>7} {:>9}", "network", "input", "depth", "params");
    for name in rt.manifest.networks.keys() {
        let session = FlowSession::new(&rt, name, MemoryLedger::new())?;
        let params = session.def.param_count(&rt.manifest)?;
        println!(
            "{name:<24} {:>18} {:>7} {:>9}",
            format!("{:?}", session.def.in_shape),
            session.def.depth(),
            params
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// bench fig1 / fig2 — the paper's two figures, printed as tables.
// (The criterion-style benches in benches/ wrap the same routines; this
// subcommand is the quick interactive path.)
// ---------------------------------------------------------------------------

fn cmd_bench(args: &Args) -> Result<()> {
    let which = args.subcommand.get(1).map(|s| s.as_str());
    let budget_gb = args.f64_or("budget-gb", 40.0)?;
    let rt = Runtime::new(&artifacts_dir(args))?;
    match which {
        Some("fig1") => invertnet::bench_figs::fig1(&rt, budget_gb),
        Some("fig2") => invertnet::bench_figs::fig2(&rt, budget_gb),
        _ => bail!("usage: invertnet bench fig1|fig2"),
    }
}
