//! Typed model of the layer/network registry: every layer signature
//! (shapes, parameter specs, per-entry artifact metadata) plus the named
//! network compositions.
//!
//! Two sources produce a [`Manifest`]:
//! * `artifacts/manifest.json` written by `python -m compile.aot` (the
//!   XLA-artifact path, loaded with [`Manifest::load`]);
//! * the native catalog in [`super::builtin`] (zero artifacts, used by the
//!   default `RefBackend`).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v.req("name")?.as_str()?.to_string(),
            shape: v.req("shape")?.as_usize_vec()?,
        })
    }
}

/// One AOT artifact: a compiled-to-HLO (layer, entry) pair.
#[derive(Debug, Clone)]
pub struct EntryMeta {
    pub file: String,
    pub operands: Vec<TensorSpec>,
    pub results: Vec<TensorSpec>,
}

impl EntryMeta {
    fn from_json(v: &Json) -> Result<EntryMeta> {
        Ok(EntryMeta {
            file: v.req("file")?.as_str()?.to_string(),
            operands: v.req("operands")?.as_arr()?.iter()
                .map(TensorSpec::from_json).collect::<Result<_>>()?,
            results: v.req("results")?.as_arr()?.iter()
                .map(TensorSpec::from_json).collect::<Result<_>>()?,
        })
    }
}

/// A layer type instantiated at a concrete shape ("signature").
#[derive(Debug, Clone)]
pub struct LayerMeta {
    pub sig: String,
    pub kind: String,
    pub in_shape: Vec<usize>,
    pub out_shape: Vec<usize>,
    pub cond_shape: Option<Vec<usize>>,
    pub params: Vec<TensorSpec>,
    pub entries: BTreeMap<String, EntryMeta>,
    /// Layer configuration (`hidden`, `depth`, ...); `Json::Null` when the
    /// source manifest predates the field.
    pub cfg: Json,
}

impl LayerMeta {
    pub fn entry(&self, name: &str) -> Result<&EntryMeta> {
        self.entries.get(name).ok_or_else(
            || anyhow!("layer {} has no entry {name}", self.sig))
    }

    pub fn param_count(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Read an integer layer-config value (e.g. `hidden`, `depth`).
    pub fn cfg_usize(&self, key: &str) -> Option<usize> {
        self.cfg.get(key).and_then(|v| v.as_usize().ok())
    }

    fn from_json(v: &Json) -> Result<LayerMeta> {
        let cond = v.req("cond_shape")?;
        let mut entries = BTreeMap::new();
        for (k, e) in v.req("entries")?.as_obj()? {
            entries.insert(k.clone(), EntryMeta::from_json(e)?);
        }
        Ok(LayerMeta {
            sig: v.req("sig")?.as_str()?.to_string(),
            kind: v.req("kind")?.as_str()?.to_string(),
            in_shape: v.req("in_shape")?.as_usize_vec()?,
            out_shape: v.req("out_shape")?.as_usize_vec()?,
            cond_shape: if cond.is_null() { None } else { Some(cond.as_usize_vec()?) },
            params: v.req("params")?.as_arr()?.iter()
                .map(TensorSpec::from_json).collect::<Result<_>>()?,
            entries,
            cfg: v.get("cfg").cloned().unwrap_or(Json::Null),
        })
    }
}

/// Gaussian loss head for one latent shape.
#[derive(Debug, Clone)]
pub struct HeadMeta {
    pub shape: Vec<usize>,
    pub entries: BTreeMap<String, EntryMeta>,
}

/// An ordered composition of layers (what the coordinator replays).
#[derive(Debug, Clone)]
pub struct NetworkMeta {
    pub name: String,
    pub in_shape: Vec<usize>,
    pub cond_shape: Option<Vec<usize>>,
    /// Layer signatures; `split_zc<k>__<shape>` marks coordinator-native
    /// factor-out steps.
    pub layers: Vec<String>,
    pub latent_shapes: Vec<Vec<usize>>,
}

#[derive(Debug)]
pub struct Manifest {
    pub backend: String,
    pub layers: BTreeMap<String, LayerMeta>,
    pub heads: BTreeMap<String, HeadMeta>,
    pub networks: BTreeMap<String, NetworkMeta>,
    /// Whole-network full-AD ablation programs (loss + all param grads in
    /// one XLA executable), keyed by network name.
    pub monoliths: BTreeMap<String, EntryMeta>,
}

pub fn shape_tag(shape: &[usize]) -> String {
    shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("x")
}

/// Parse a `split_zc<k>__<HxWx...>` marker (coordinator-native multiscale
/// factor-out steps inside a network's layer list). Returns the factored
/// channel count and the full input shape of the split.
pub fn parse_split(s: &str) -> Option<(usize, Vec<usize>)> {
    let rest = s.strip_prefix("split_zc")?;
    let (zc, shape) = rest.split_once("__")?;
    let zc = zc.parse().ok()?;
    let dims = shape.split('x').map(|d| d.parse().ok()).collect::<Option<Vec<_>>>()?;
    Some((zc, dims))
}

/// Inverse of [`parse_split`]: format a split marker for a network layer
/// list.
pub fn format_split(zc: usize, in_shape: &[usize]) -> String {
    format!("split_zc{zc}__{}", shape_tag(in_shape))
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text)?;
        let mut layers = BTreeMap::new();
        for (sig, l) in v.req("layers")?.as_obj()? {
            let meta = LayerMeta::from_json(l)
                .with_context(|| format!("layer {sig}"))?;
            if &meta.sig != sig {
                bail!("manifest key {sig} != sig {}", meta.sig);
            }
            layers.insert(sig.clone(), meta);
        }
        let mut heads = BTreeMap::new();
        for (tag, h) in v.req("heads")?.as_obj()? {
            let mut entries = BTreeMap::new();
            for (k, e) in h.req("entries")?.as_obj()? {
                entries.insert(k.clone(), EntryMeta::from_json(e)?);
            }
            heads.insert(tag.clone(), HeadMeta {
                shape: h.req("shape")?.as_usize_vec()?,
                entries,
            });
        }
        let mut networks = BTreeMap::new();
        for (name, n) in v.req("networks")?.as_obj()? {
            let cond = n.req("cond_shape")?;
            networks.insert(name.clone(), NetworkMeta {
                name: name.clone(),
                in_shape: n.req("in_shape")?.as_usize_vec()?,
                cond_shape: if cond.is_null() { None } else { Some(cond.as_usize_vec()?) },
                layers: n.req("layers")?.as_arr()?.iter()
                    .map(|s| Ok(s.as_str()?.to_string()))
                    .collect::<Result<_>>()?,
                latent_shapes: n.req("latent_shapes")?.as_arr()?.iter()
                    .map(|s| s.as_usize_vec()).collect::<Result<_>>()?,
            });
        }
        let mut monoliths = BTreeMap::new();
        if let Some(ms) = v.get("monoliths") {
            for (name, e) in ms.as_obj()? {
                monoliths.insert(name.clone(), EntryMeta::from_json(e)?);
            }
        }
        Ok(Manifest {
            backend: v.req("backend")?.as_str()?.to_string(),
            layers,
            heads,
            networks,
            monoliths,
        })
    }

    pub fn layer(&self, sig: &str) -> Result<&LayerMeta> {
        self.layers.get(sig).ok_or_else(|| anyhow!("unknown layer sig {sig}"))
    }

    pub fn head_for(&self, shape: &[usize]) -> Result<&HeadMeta> {
        let tag = shape_tag(shape);
        self.heads.get(&tag).ok_or_else(|| anyhow!("no head for shape {tag}"))
    }

    pub fn network(&self, name: &str) -> Result<&NetworkMeta> {
        self.networks.get(name).ok_or_else(|| {
            anyhow!("unknown network {name}; available: {:?}",
                    self.networks.keys().collect::<Vec<_>>())
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MINI: &str = r#"{
      "backend": "pallas-interpret",
      "layers": {
        "actnorm__2x4x4x3": {
          "sig": "actnorm__2x4x4x3", "kind": "actnorm",
          "in_shape": [2,4,4,3], "out_shape": [2,4,4,3],
          "cond_shape": null, "cfg": {},
          "params": [{"name": "log_s", "shape": [3]}, {"name": "b", "shape": [3]}],
          "entries": {
            "forward": {"file": "a.hlo.txt",
              "operands": [{"name": "x", "shape": [2,4,4,3]},
                           {"name": "log_s", "shape": [3]},
                           {"name": "b", "shape": [3]}],
              "results": [{"name": "y", "shape": [2,4,4,3]},
                          {"name": "logdet", "shape": [2]}]}
          }
        }
      },
      "heads": {
        "2x4x4x3": {"shape": [2,4,4,3], "entries": {
          "gaussian_logp": {"file": "h.hlo.txt",
            "operands": [{"name": "z", "shape": [2,4,4,3]}],
            "results": [{"name": "logp", "shape": [2]}]}}}
      },
      "networks": {
        "tiny": {"name": "tiny", "in_shape": [2,4,4,3], "cond_shape": null,
                 "layers": ["actnorm__2x4x4x3"],
                 "latent_shapes": [[2,4,4,3]]}
      }
    }"#;

    #[test]
    fn parses_minimal_manifest() {
        let m = Manifest::parse(MINI).unwrap();
        let l = m.layer("actnorm__2x4x4x3").unwrap();
        assert_eq!(l.kind, "actnorm");
        assert_eq!(l.param_count(), 6);
        let e = l.entry("forward").unwrap();
        assert_eq!(e.operands.len(), 3);
        assert_eq!(e.results[1].shape, vec![2]);
        assert!(m.head_for(&[2, 4, 4, 3]).is_ok());
        assert!(m.head_for(&[9]).is_err());
        assert_eq!(m.network("tiny").unwrap().layers.len(), 1);
        assert!(m.network("nope").is_err());
    }

    #[test]
    fn split_marker_parses_and_formats() {
        let (zc, dims) = parse_split("split_zc6__16x8x8x12").unwrap();
        assert_eq!(zc, 6);
        assert_eq!(dims, vec![16, 8, 8, 12]);
        assert_eq!(format_split(zc, &dims), "split_zc6__16x8x8x12");
        assert!(parse_split("actnorm__2x2").is_none());
        assert!(parse_split("split_zcX__2").is_none());
        assert!(parse_split("split_zc3").is_none());
    }

    #[test]
    fn split_markers_roundtrip_across_builtin_catalog() {
        // every split marker in the builtin catalog must survive
        // parse -> format unchanged (the coordinator keys off these strings)
        let m = crate::runtime::builtin::builtin_manifest().unwrap();
        let mut seen = 0;
        for net in m.networks.values() {
            for sig in &net.layers {
                if let Some((zc, dims)) = parse_split(sig) {
                    assert_eq!(&format_split(zc, &dims), sig, "marker {sig}");
                    seen += 1;
                } else {
                    assert!(m.layer(sig).is_ok(), "unknown non-split sig {sig}");
                }
            }
        }
        assert!(seen > 0, "catalog should contain split markers");
    }

    #[test]
    fn cfg_field_is_optional_and_typed() {
        let m = Manifest::parse(MINI).unwrap();
        let l = m.layer("actnorm__2x4x4x3").unwrap();
        assert_eq!(l.cfg_usize("hidden"), None); // MINI has empty cfg
        let m2 = crate::runtime::builtin::builtin_manifest().unwrap();
        let hint = m2.layer("hint__256x8__hd64__dep2").unwrap();
        assert_eq!(hint.cfg_usize("depth"), Some(2));
        assert_eq!(hint.cfg_usize("hidden"), Some(64));
    }
}
