//! PJRT runtime: loads HLO-text artifacts, compiles them once, executes
//! them from the coordinator hot path. Adapted from /opt/xla-example.
//!
//! Interchange is HLO *text* — `HloModuleProto::from_text_file` reassigns
//! instruction ids, which is what makes jax>=0.5 output loadable on
//! xla_extension 0.5.1 (see DESIGN.md).

pub mod manifest;

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{anyhow, bail, Context, Result};

pub use manifest::{shape_tag, EntryMeta, HeadMeta, LayerMeta, Manifest,
                   NetworkMeta, TensorSpec};

use crate::tensor::Tensor;

/// Convert the xla crate's error type into anyhow.
pub fn xerr(e: xla::Error) -> anyhow::Error {
    anyhow!("xla: {e:?}")
}

/// A compiled (layer, entry) artifact ready to execute.
pub struct CompiledEntry {
    pub key: String,
    pub meta: EntryMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl CompiledEntry {
    /// Execute with host literals; returns one literal per manifest result
    /// (the PJRT result tuple is decomposed).
    pub fn execute(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if args.len() != self.meta.operands.len() {
            bail!("{}: got {} operands, manifest wants {}",
                  self.key, args.len(), self.meta.operands.len());
        }
        let out = self.exe.execute::<&xla::Literal>(args).map_err(xerr)?;
        let lit = out[0][0].to_literal_sync().map_err(xerr)?;
        // aot.py lowers with return_tuple=True: always a tuple root.
        let parts = lit.to_tuple().map_err(xerr)?;
        if parts.len() != self.meta.results.len() {
            bail!("{}: got {} results, manifest wants {}",
                  self.key, parts.len(), self.meta.results.len());
        }
        Ok(parts)
    }

    /// Execute and convert every result to a host [`Tensor`].
    pub fn execute_t(&self, args: &[&xla::Literal]) -> Result<Vec<Tensor>> {
        self.execute(args)?.iter().map(Tensor::from_literal).collect()
    }
}

/// The PJRT client + artifact directory + executable cache.
///
/// Compilation is lazy and cached per artifact file: a training loop
/// compiles each of its network's entries exactly once.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: RefCell<HashMap<String, Rc<CompiledEntry>>>,
}

impl Runtime {
    /// CPU-backed runtime over an artifact directory (`artifacts/`).
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().map_err(xerr)?;
        Ok(Runtime {
            client,
            manifest,
            dir: artifact_dir.to_path_buf(),
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&self, meta: &EntryMeta, key: &str) -> Result<Rc<CompiledEntry>> {
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?)
            .map_err(xerr)
            .with_context(|| format!("loading {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(xerr)
            .with_context(|| format!("compiling {key}"))?;
        Ok(Rc::new(CompiledEntry {
            key: key.to_string(),
            meta: meta.clone(),
            exe,
        }))
    }

    /// Compiled entry for a layer signature, e.g. `("actnorm__8x32x32x12",
    /// "forward")`. Cached.
    pub fn layer_entry(&self, sig: &str, entry: &str) -> Result<Rc<CompiledEntry>> {
        let key = format!("{sig}.{entry}");
        if let Some(hit) = self.cache.borrow().get(&key) {
            return Ok(hit.clone());
        }
        let meta = self.manifest.layer(sig)?.entry(entry)?.clone();
        let compiled = self.compile(&meta, &key)?;
        self.cache.borrow_mut().insert(key, compiled.clone());
        Ok(compiled)
    }

    /// Compiled head entry (`gaussian_logp` / `nll_seed`) for a latent shape.
    pub fn head_entry(&self, shape: &[usize], entry: &str) -> Result<Rc<CompiledEntry>> {
        let tag = shape_tag(shape);
        let key = format!("head_{tag}.{entry}");
        if let Some(hit) = self.cache.borrow().get(&key) {
            return Ok(hit.clone());
        }
        let head = self.manifest.head_for(shape)?;
        let meta = head.entries.get(entry)
            .ok_or_else(|| anyhow!("head {tag} has no entry {entry}"))?
            .clone();
        let compiled = self.compile(&meta, &key)?;
        self.cache.borrow_mut().insert(key, compiled.clone());
        Ok(compiled)
    }

    /// Compiled whole-network full-AD ablation program (see
    /// `python/compile/model.py::full_vjp_fn`). Cached.
    pub fn monolith_entry(&self, net: &str) -> Result<Rc<CompiledEntry>> {
        let key = format!("monolith_{net}");
        if let Some(hit) = self.cache.borrow().get(&key) {
            return Ok(hit.clone());
        }
        let meta = self.manifest.monoliths.get(net)
            .ok_or_else(|| anyhow!("no monolith artifact for {net}"))?
            .clone();
        let compiled = self.compile(&meta, &key)?;
        self.cache.borrow_mut().insert(key, compiled.clone());
        Ok(compiled)
    }

    /// Number of compiled executables held in the cache.
    pub fn cache_len(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Drop all compiled executables (used by benches between configs to
    /// keep executable memory out of the activation measurements).
    pub fn clear_cache(&self) {
        self.cache.borrow_mut().clear();
    }
}
