//! Model registry: the typed [`Manifest`] of layers/networks plus the
//! builtin (artifact-free) catalog.
//!
//! Program *execution* lives behind the [`crate::backend::Backend`] trait;
//! the XLA/PJRT runtime that used to live here is now the feature-gated
//! [`crate::backend::XlaBackend`] (`--features xla`).

pub mod builtin;
pub mod manifest;

pub use builtin::builtin_manifest;
pub use manifest::{format_split, parse_split, shape_tag, EntryMeta, HeadMeta,
                   LayerMeta, Manifest, NetworkMeta, TensorSpec};
