//! Builtin, artifact-free network catalog: the Rust port of
//! `python/compile/model.py::default_networks`.
//!
//! The signatures, parameter specs, split markers and latent bookkeeping
//! are generated with the exact same rules as the python registry, so a
//! [`Manifest`] from this module and one loaded from
//! `artifacts/manifest.json` describe the same networks — the only
//! difference is that builtin layer entries carry no HLO artifact files,
//! which is fine for the [`crate::backend::RefBackend`] (it executes the
//! layer math natively) and for shape-only tooling.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::util::json::Json;

use super::manifest::{format_split, shape_tag, HeadMeta, LayerMeta, Manifest,
                      NetworkMeta, TensorSpec};

fn ts(name: &str, shape: Vec<usize>) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape }
}

fn cfg_of(pairs: &[(&str, usize)]) -> Json {
    Json::obj(pairs.iter().map(|&(k, v)| (k, Json::Num(v as f64))).collect())
}

/// Signature string, matching model.py: `kind__<in-shape>[__hd<h>][__dep<d>]
/// [__cond<shape>]`.
fn sig_of(
    kind: &str,
    in_shape: &[usize],
    hidden: Option<usize>,
    depth: Option<usize>,
    cond: Option<&[usize]>,
) -> String {
    let mut parts = vec![kind.to_string(), shape_tag(in_shape)];
    if let Some(h) = hidden {
        parts.push(format!("hd{h}"));
    }
    if let Some(d) = depth {
        parts.push(format!("dep{d}"));
    }
    if let Some(c) = cond {
        parts.push(format!("cond{}", shape_tag(c)));
    }
    parts.join("__")
}

// ---------------------------------------------------------------------------
// Conditioner parameter specs (python: conditioner.py)
// ---------------------------------------------------------------------------

fn cnn_specs(c_in: usize, hidden: usize, c_out: usize) -> Vec<TensorSpec> {
    vec![
        ts("w1", vec![3, 3, c_in, hidden]),
        ts("b1", vec![hidden]),
        ts("w2", vec![1, 1, hidden, hidden]),
        ts("b2", vec![hidden]),
        ts("w3", vec![3, 3, hidden, c_out]),
        ts("b3", vec![c_out]),
    ]
}

fn mlp_specs(d_in: usize, hidden: usize, d_out: usize) -> Vec<TensorSpec> {
    vec![
        ts("w1", vec![d_in, hidden]),
        ts("b1", vec![hidden]),
        ts("w2", vec![hidden, hidden]),
        ts("b2", vec![hidden]),
        ts("w3", vec![hidden, d_out]),
        ts("b3", vec![d_out]),
    ]
}

/// HINT leaves recurse no further below this feature width (python MIN_D).
pub const HINT_MIN_D: usize = 4;

/// Preorder list of HINT internal nodes as (path, d1, d2) — one conditioner
/// MLP each; paths are "r", "rl", "rt", ... like the python registry.
pub fn hint_nodes(d: usize, depth: usize) -> Vec<(String, usize, usize)> {
    fn rec(d: usize, depth: usize, path: String, out: &mut Vec<(String, usize, usize)>) {
        if depth == 0 || d < HINT_MIN_D {
            return;
        }
        let d1 = d / 2;
        let d2 = d - d1;
        out.push((path.clone(), d1, d2));
        rec(d1, depth - 1, format!("{path}l"), out);
        rec(d2, depth - 1, format!("{path}t"), out);
    }
    let mut out = Vec::new();
    rec(d, depth, "r".to_string(), &mut out);
    out
}

fn hint_specs(d: usize, hidden: usize, depth: usize) -> Vec<TensorSpec> {
    let mut specs = Vec::new();
    for (path, d1, d2) in hint_nodes(d, depth) {
        for s in mlp_specs(d1, hidden, 2 * d2) {
            specs.push(ts(&format!("{path}_{}", s.name), s.shape));
        }
    }
    specs
}

// ---------------------------------------------------------------------------
// Layer-instance constructors (python: model.py L_*)
// ---------------------------------------------------------------------------

/// A network piece: a concrete layer or a coordinator-native split marker.
enum Piece {
    Layer(Box<LayerMeta>),
    Split { zc: usize, in_shape: Vec<usize> },
}

#[allow(clippy::too_many_arguments)]
fn layer(
    kind: &str,
    in_shape: Vec<usize>,
    out_shape: Vec<usize>,
    cond_shape: Option<Vec<usize>>,
    params: Vec<TensorSpec>,
    cfg: Json,
    hidden: Option<usize>,
    depth: Option<usize>,
) -> Piece {
    let sig = sig_of(kind, &in_shape, hidden, depth, cond_shape.as_deref());
    Piece::Layer(Box::new(LayerMeta {
        sig,
        kind: kind.to_string(),
        in_shape,
        out_shape,
        cond_shape,
        params,
        entries: BTreeMap::new(),
        cfg,
    }))
}

fn l_actnorm(n: usize, h: usize, w: usize, c: usize) -> Piece {
    layer("actnorm", vec![n, h, w, c], vec![n, h, w, c], None,
          vec![ts("log_s", vec![c]), ts("b", vec![c])],
          cfg_of(&[("c", c)]), None, None)
}

fn l_conv1x1(n: usize, h: usize, w: usize, c: usize) -> Piece {
    layer("conv1x1", vec![n, h, w, c], vec![n, h, w, c], None,
          vec![ts("v1", vec![c]), ts("v2", vec![c]), ts("v3", vec![c])],
          cfg_of(&[("c", c)]), None, None)
}

fn l_glowcpl(n: usize, h: usize, w: usize, c: usize, hidden: usize) -> Piece {
    let c1 = c / 2;
    let c2 = c - c1;
    layer("glowcpl", vec![n, h, w, c], vec![n, h, w, c], None,
          cnn_specs(c1, hidden, 2 * c2),
          cfg_of(&[("c", c), ("hidden", hidden)]), Some(hidden), None)
}

fn l_addcpl(n: usize, h: usize, w: usize, c: usize, hidden: usize) -> Piece {
    let c1 = c / 2;
    let c2 = c - c1;
    layer("addcpl", vec![n, h, w, c], vec![n, h, w, c], None,
          cnn_specs(c1, hidden, c2),
          cfg_of(&[("c", c), ("hidden", hidden)]), Some(hidden), None)
}

fn l_haar(n: usize, h: usize, w: usize, c: usize) -> Piece {
    layer("haar", vec![n, h, w, c], vec![n, h / 2, w / 2, 4 * c], None,
          Vec::new(), cfg_of(&[("c", c)]), None, None)
}

fn l_permute(shape: Vec<usize>) -> Piece {
    layer("permute", shape.clone(), shape, None, Vec::new(),
          cfg_of(&[]), None, None)
}

fn l_densecpl(n: usize, d: usize, hidden: usize) -> Piece {
    let d1 = d / 2;
    let d2 = d - d1;
    layer("densecpl", vec![n, d], vec![n, d], None,
          mlp_specs(d1, hidden, 2 * d2),
          cfg_of(&[("d", d), ("hidden", hidden)]), Some(hidden), None)
}

fn l_condcpl(n: usize, d: usize, dcond: usize, hidden: usize) -> Piece {
    let d1 = d / 2;
    let d2 = d - d1;
    layer("condcpl", vec![n, d], vec![n, d], Some(vec![n, dcond]),
          mlp_specs(d1 + dcond, hidden, 2 * d2),
          cfg_of(&[("d", d), ("dcond", dcond), ("hidden", hidden)]),
          Some(hidden), None)
}

fn l_hyper(n: usize, h: usize, w: usize, c: usize, hidden: usize) -> Piece {
    layer("hyper", vec![n, h, w, c], vec![n, h, w, c], None,
          vec![ts("kw", vec![3, 3, c / 2, hidden])],
          cfg_of(&[("c", c), ("hidden", hidden)]), Some(hidden), None)
}

fn l_hint(n: usize, d: usize, hidden: usize, depth: usize) -> Piece {
    layer("hint", vec![n, d], vec![n, d], None,
          hint_specs(d, hidden, depth),
          cfg_of(&[("d", d), ("hidden", hidden), ("depth", depth)]),
          Some(hidden), Some(depth))
}

fn l_split(n: usize, h: usize, w: usize, c: usize) -> Piece {
    Piece::Split { zc: c / 2, in_shape: vec![n, h, w, c] }
}

// ---------------------------------------------------------------------------
// Network builders (python: model.py network constructors)
// ---------------------------------------------------------------------------

struct Catalog {
    layers: BTreeMap<String, LayerMeta>,
    heads: BTreeMap<String, HeadMeta>,
    networks: BTreeMap<String, NetworkMeta>,
}

impl Catalog {
    fn new() -> Catalog {
        Catalog {
            layers: BTreeMap::new(),
            heads: BTreeMap::new(),
            networks: BTreeMap::new(),
        }
    }

    /// Append a network assembled from `pieces`, validating the chain as
    /// it goes. Returning the error (instead of panicking mid-walk) is
    /// what lets a long-lived process — notably `invertnet serve` — report
    /// a bad network definition through `Engine::build` and keep running.
    fn add(&mut self, name: &str, in_shape: Vec<usize>,
           cond_shape: Option<Vec<usize>>, pieces: Vec<Piece>) -> Result<()> {
        if in_shape.is_empty() || in_shape.contains(&0) {
            bail!("network {name}: bad input shape {in_shape:?}");
        }
        let mut sigs = Vec::with_capacity(pieces.len());
        let mut latents: Vec<Vec<usize>> = Vec::new();
        let mut cur = in_shape.clone();
        for (i, p) in pieces.into_iter().enumerate() {
            match p {
                Piece::Split { zc, in_shape } => {
                    let Some(&c) = in_shape.last() else {
                        bail!("network {name} step {i}: split on a \
                               shapeless input");
                    };
                    if zc == 0 || zc >= c {
                        bail!("network {name} step {i}: split zc={zc} out \
                               of range for {c} channels");
                    }
                    if in_shape != cur {
                        bail!("network {name} step {i}: split input \
                               {in_shape:?} does not chain from {cur:?}");
                    }
                    sigs.push(format_split(zc, &in_shape));
                    let mut z = in_shape.clone();
                    *z.last_mut().unwrap() = zc;
                    latents.push(z);
                    cur = in_shape;
                    *cur.last_mut().unwrap() = c - zc;
                }
                Piece::Layer(meta) => {
                    if meta.in_shape != cur {
                        bail!("network {name} step {i} ({}): input \
                               {:?} does not chain from {cur:?}",
                              meta.sig, meta.in_shape);
                    }
                    if meta.out_shape.is_empty() || meta.out_shape.contains(&0)
                    {
                        bail!("network {name} step {i} ({}): bad output \
                               shape {:?}", meta.sig, meta.out_shape);
                    }
                    sigs.push(meta.sig.clone());
                    cur = meta.out_shape.clone();
                    self.layers.entry(meta.sig.clone()).or_insert(*meta);
                }
            }
        }
        latents.push(cur);
        for z in &latents {
            self.heads.entry(shape_tag(z)).or_insert_with(|| HeadMeta {
                shape: z.clone(),
                entries: BTreeMap::new(),
            });
        }
        self.networks.insert(name.to_string(), NetworkMeta {
            name: name.to_string(),
            in_shape,
            cond_shape,
            layers: sigs,
            latent_shapes: latents,
        });
        Ok(())
    }
}

/// Haar squeeze then K x (ActNorm -> Conv1x1 -> AffineCoupling).
#[allow(clippy::too_many_arguments)]
fn glow_flat(cat: &mut Catalog, name: &str, n: usize, h: usize, w: usize,
             c_in: usize, k: usize, hidden: usize) -> Result<()> {
    let mut pieces = vec![l_haar(n, h, w, c_in)];
    let c = 4 * c_in;
    let (h2, w2) = (h / 2, w / 2);
    for _ in 0..k {
        pieces.push(l_actnorm(n, h2, w2, c));
        pieces.push(l_conv1x1(n, h2, w2, c));
        pieces.push(l_glowcpl(n, h2, w2, c, hidden));
    }
    cat.add(name, vec![n, h, w, c_in], None, pieces)
}

/// GLOW with Haar squeeze + factor-out between scales (paper §1).
#[allow(clippy::too_many_arguments)]
fn glow_multiscale(cat: &mut Catalog, name: &str, n: usize, h: usize, w: usize,
                   c_in: usize, scales: usize, k: usize, hidden: usize) -> Result<()> {
    let mut pieces = Vec::new();
    let (mut ch, mut hh, mut ww) = (c_in, h, w);
    for s in 0..scales {
        pieces.push(l_haar(n, hh, ww, ch));
        ch *= 4;
        hh /= 2;
        ww /= 2;
        for _ in 0..k {
            pieces.push(l_actnorm(n, hh, ww, ch));
            pieces.push(l_conv1x1(n, hh, ww, ch));
            pieces.push(l_glowcpl(n, hh, ww, ch, hidden));
        }
        if s != scales - 1 {
            pieces.push(l_split(n, hh, ww, ch));
            ch -= ch / 2;
        }
    }
    cat.add(name, vec![n, h, w, c_in], None, pieces)
}

fn realnvp_dense(cat: &mut Catalog, name: &str, n: usize, d: usize,
                 k: usize, hidden: usize) -> Result<()> {
    let mut pieces = Vec::new();
    for _ in 0..k {
        pieces.push(l_densecpl(n, d, hidden));
        pieces.push(l_permute(vec![n, d]));
    }
    cat.add(name, vec![n, d], None, pieces)
}

fn cond_realnvp_dense(cat: &mut Catalog, name: &str, n: usize, d: usize,
                      dcond: usize, k: usize, hidden: usize) -> Result<()> {
    let mut pieces = Vec::new();
    for _ in 0..k {
        pieces.push(l_condcpl(n, d, dcond, hidden));
        pieces.push(l_permute(vec![n, d]));
    }
    cat.add(name, vec![n, d], Some(vec![n, dcond]), pieces)
}

#[allow(clippy::too_many_arguments)]
fn hint_dense(cat: &mut Catalog, name: &str, n: usize, d: usize, k: usize,
              hidden: usize, depth: usize) -> Result<()> {
    let mut pieces = Vec::new();
    for _ in 0..k {
        pieces.push(l_hint(n, d, hidden, depth));
        pieces.push(l_permute(vec![n, d]));
    }
    cat.add(name, vec![n, d], None, pieces)
}

/// Haar squeeze to 4*c_in channels, then K leapfrog steps on the
/// (prev|curr) paired state.
#[allow(clippy::too_many_arguments)]
fn hyperbolic_net(cat: &mut Catalog, name: &str, n: usize, h: usize, w: usize,
                  c_in: usize, k: usize, hidden: usize) -> Result<()> {
    let mut pieces = vec![l_haar(n, h, w, c_in)];
    let c = 4 * c_in;
    for _ in 0..k {
        pieces.push(l_hyper(n, h / 2, w / 2, c, hidden));
    }
    cat.add(name, vec![n, h, w, c_in], None, pieces)
}

/// NICE-style additive image flow (builtin-only: exercises `addcpl`).
#[allow(clippy::too_many_arguments)]
fn nice_net(cat: &mut Catalog, name: &str, n: usize, h: usize, w: usize,
            c_in: usize, k: usize, hidden: usize) -> Result<()> {
    let mut pieces = vec![l_haar(n, h, w, c_in)];
    let c = 4 * c_in;
    let (h2, w2) = (h / 2, w / 2);
    for _ in 0..k {
        pieces.push(l_addcpl(n, h2, w2, c, hidden));
        pieces.push(l_permute(vec![n, h2, w2, c]));
    }
    cat.add(name, vec![n, h, w, c_in], None, pieces)
}

/// The six end-to-end example networks (the rest of the catalog is
/// figure sweeps and bench/posterior sizings) — the set the static
/// planner's predicted==measured pins and the checkpoint round-trips
/// iterate over.
pub const EXAMPLE_NETS: &[&str] = &[
    "realnvp2d", "cond_realnvp2d", "hint8d", "glow16", "hyper16", "nice16",
];

/// The default catalog: example nets + every figure sweep, mirroring
/// `model.py::default_networks` (plus `nice16`, builtin-only).
///
/// A malformed definition surfaces here as an `Err` (and through
/// `Engine::build`) rather than a process abort — a long-lived server must
/// be able to report a bad catalog and keep serving what it has.
pub fn builtin_manifest() -> Result<Manifest> {
    let mut cat = Catalog::new();
    // e2e examples
    realnvp_dense(&mut cat, "realnvp2d", 256, 2, 8, 64)?;
    cond_realnvp_dense(&mut cat, "cond_realnvp2d", 256, 2, 2, 8, 64)?;
    hint_dense(&mut cat, "hint8d", 256, 8, 4, 64, 2)?;
    // amortized-posterior nets, sized for the posterior::Simulator catalog
    // (builtin-only, like nice16): x rows condition on simulator y rows
    cond_realnvp_dense(&mut cat, "cond_lingauss2d", 128, 2, 2, 6, 32)?;
    cond_realnvp_dense(&mut cat, "cond_denoise16", 128, 16, 16, 6, 64)?;
    cond_realnvp_dense(&mut cat, "cond_deblur16", 128, 16, 16, 6, 64)?;
    cond_realnvp_dense(&mut cat, "cond_inpaint16", 128, 16, 32, 6, 64)?;
    glow_multiscale(&mut cat, "glow16", 16, 16, 16, 3, 2, 4, 32)?;
    hyperbolic_net(&mut cat, "hyper16", 16, 16, 16, 3, 6, 12)?;
    nice_net(&mut cat, "nice16", 16, 16, 16, 3, 4, 32)?;
    // fig1: spatial-size sweep, GLOW, 3 input channels, batch 8
    for hw in [16usize, 32, 64, 128, 256] {
        glow_flat(&mut cat, &format!("glow_fig1_{hw}"), 8, hw, hw, 3, 16, 32)?;
    }
    // fig2: depth sweep at 64x64
    for k in [2usize, 4, 8, 16, 32, 48] {
        glow_flat(&mut cat, &format!("glow_fig2_d{k}"), 8, 64, 64, 3, k, 32)?;
    }
    // throughput / ablation nets
    glow_flat(&mut cat, "glow_bench32", 8, 32, 32, 3, 8, 32)?;
    // large-image catalog nets (vectorized-kernel showcase): a genuinely
    // deep 64x64 multiscale GLOW — 3 squeeze levels, 12 steps each, so
    // stored-mode taping is ~2 orders of magnitude above the invertible
    // walk (gated in the memory_vs_size suite) — and a deep HINT tree
    // (recursive depth 4 over 64 dims: 15 coupling nodes per layer).
    glow_multiscale(&mut cat, "glow64", 4, 64, 64, 3, 3, 12, 64)?;
    hint_dense(&mut cat, "hint64deep", 64, 64, 4, 128, 4)?;

    Ok(Manifest {
        backend: "ref-builtin".to_string(),
        layers: cat.layers,
        heads: cat.heads,
        networks: cat.networks,
        monoliths: BTreeMap::new(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::flow::NetworkDef;

    #[test]
    fn catalog_matches_python_registry_shape() {
        let m = builtin_manifest().unwrap();
        assert!(m.networks.len() >= 17);
        for name in ["realnvp2d", "cond_realnvp2d", "hint8d", "glow16",
                     "hyper16", "nice16", "glow_fig1_16", "glow_fig2_d48",
                     "glow_bench32", "cond_lingauss2d", "cond_denoise16",
                     "cond_deblur16", "cond_inpaint16"] {
            assert!(m.networks.contains_key(name), "missing {name}");
        }
        // spot-check signatures against the python sig convention
        assert!(m.layers.contains_key("densecpl__256x2__hd64"));
        assert!(m.layers.contains_key("condcpl__256x2__hd64__cond256x2"));
        assert!(m.layers.contains_key("condcpl__128x2__hd32__cond128x2"));
        assert!(m.layers.contains_key("condcpl__128x16__hd64__cond128x16"));
        assert!(m.layers.contains_key("condcpl__128x16__hd64__cond128x32"));
        // posterior nets are conditional with the simulator's y width
        assert_eq!(m.networks["cond_lingauss2d"].cond_shape,
                   Some(vec![128, 2]));
        assert_eq!(m.networks["cond_inpaint16"].cond_shape,
                   Some(vec![128, 32]));
        assert!(m.layers.contains_key("hint__256x8__hd64__dep2"));
        assert!(m.layers.contains_key("haar__16x16x16x3"));
        assert!(m.layers.contains_key("hyper__16x8x8x12__hd12"));
        assert!(m.layers.contains_key("glowcpl__8x32x32x12__hd32"));
    }

    #[test]
    fn every_network_resolves() {
        // NetworkDef::resolve re-derives shapes and latent bookkeeping from
        // the layer metas — it failing would mean the catalog is internally
        // inconsistent.
        let m = builtin_manifest().unwrap();
        for name in m.networks.keys() {
            let def = NetworkDef::resolve(&m, name)
                .unwrap_or_else(|e| panic!("{name}: {e:#}"));
            assert!(!def.steps.is_empty(), "{name} has no steps");
            assert!(!def.latent_shapes.is_empty());
        }
    }

    #[test]
    fn bad_network_definitions_error_instead_of_panicking() {
        // a long-lived server must see these as Err from Engine::build,
        // never a process abort
        let mut cat = Catalog::new();
        let err = cat.add("bad_split", vec![4, 4, 4, 2], None,
                          vec![Piece::Split {
                              zc: 2,
                              in_shape: vec![4, 4, 4, 2],
                          }]).unwrap_err();
        assert!(format!("{err:#}").contains("split"), "{err:#}");

        let mut cat = Catalog::new();
        let err = cat.add("bad_chain", vec![8, 2], None,
                          vec![l_densecpl(4, 2, 8)]).unwrap_err();
        assert!(format!("{err:#}").contains("chain"), "{err:#}");

        let mut cat = Catalog::new();
        assert!(cat.add("bad_shape", vec![0, 2], None, vec![]).is_err());
    }

    #[test]
    fn glow16_multiscale_structure() {
        let m = builtin_manifest().unwrap();
        let net = m.network("glow16").unwrap();
        assert_eq!(net.in_shape, vec![16, 16, 16, 3]);
        assert_eq!(net.latent_shapes,
                   vec![vec![16, 8, 8, 6], vec![16, 4, 4, 24]]);
        assert_eq!(net.layers.iter()
                   .filter(|s| s.starts_with("split_zc")).count(), 1);
    }

    #[test]
    fn hint_param_specs_match_python_counts() {
        // d=8, depth=2: nodes r(4,4), rl(2,2)->leaf? d1=4 -> rl has d=4:
        // depth 1, d=4 -> node; its children have d=2 < MIN_D -> leaves.
        let nodes = hint_nodes(8, 2);
        let paths: Vec<&str> = nodes.iter().map(|(p, _, _)| p.as_str()).collect();
        assert_eq!(paths, vec!["r", "rl", "rt"]);
        let specs = hint_specs(8, 64, 2);
        assert_eq!(specs.len(), 3 * 6);
        assert_eq!(specs[0].name, "r_w1");
        assert_eq!(specs[0].shape, vec![4, 64]);
        assert_eq!(specs[6].name, "rl_w1");
        assert_eq!(specs[6].shape, vec![2, 64]);
    }

    #[test]
    fn glow64_is_deep_multiscale() {
        let m = builtin_manifest().unwrap();
        let net = m.network("glow64").unwrap();
        assert_eq!(net.in_shape, vec![4, 64, 64, 3]);
        // 3 squeeze levels -> 2 factor-outs + the final site
        assert_eq!(net.latent_shapes,
                   vec![vec![4, 32, 32, 6], vec![4, 16, 16, 12],
                        vec![4, 8, 8, 48]]);
        assert_eq!(net.layers.iter()
                   .filter(|s| s.starts_with("split_zc")).count(), 2);
        assert_eq!(net.layers.iter()
                   .filter(|s| s.starts_with("glowcpl")).count(), 36);
    }

    #[test]
    fn hint64deep_has_full_depth4_tree() {
        // every node down to depth 4 stays >= HINT_MIN_D wide, so the
        // recursion yields the complete 15-node binary tree
        let nodes = hint_nodes(64, 4);
        assert_eq!(nodes.len(), 15);
        let m = builtin_manifest().unwrap();
        let net = m.network("hint64deep").unwrap();
        assert_eq!(net.in_shape, vec![64, 64]);
        assert!(m.layers.contains_key("hint__64x64__hd128__dep4"));
    }

    #[test]
    fn head_shapes_cover_all_latents() {
        let m = builtin_manifest().unwrap();
        for net in m.networks.values() {
            for z in &net.latent_shapes {
                assert!(m.head_for(z).is_ok(), "{}: missing head {:?}",
                        net.name, z);
            }
        }
    }
}
