//! The measurement suites behind `invertnet bench --suite ...` — the
//! library home of what the `benches/*.rs` binaries used to hand-roll.
//!
//! Each suite takes the [`Engine`] to measure and a [`Scale`]:
//! [`Scale::Quick`] is CI-sized (a couple of minutes on two cores, small
//! sweeps), [`Scale::Full`] is the interactive/bench-binary shape. Every
//! suite returns a [`SuiteReport`] whose deterministic metrics (memory
//! ledger peaks, fixed-seed losses, exact counts) are gated against
//! committed baselines, while wall-clock metrics record the perf
//! trajectory without gating (they are machine-dependent; the env block
//! says which machine).

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::api::Engine;
use crate::bench_figs::measure_peak;
use crate::coordinator::{ActivationSchedule, CheckpointEveryK, ExecMode,
                         InferOpts, SampleOpts};
use crate::data::{synth_images, LinearGaussian};
use crate::posterior::{amortized_train, posterior_samples, summarize,
                       PosteriorTrainConfig, Simulator};
use crate::serve::{BatchConfig, Registry, Request, Response, Server,
                   StatsSnapshot};
use crate::tensor::Tensor;
use crate::train::ParallelTrainer;
use crate::util::bench::bench;
use crate::util::rng::Pcg64;

use super::{Metric, SuiteReport};

/// How big a sweep a suite runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scale {
    /// CI-sized: smallest interesting sweep, few timed iterations.
    Quick,
    /// The full bench-binary shape.
    Full,
}

impl Scale {
    fn pick<T: Copy>(self, quick: T, full: T) -> T {
        match self {
            Scale::Quick => quick,
            Scale::Full => full,
        }
    }
}

fn batch_for(flow: &crate::api::Flow, rng: &mut Pcg64) -> Tensor {
    let s = &flow.def.in_shape;
    if s.len() == 4 {
        synth_images(s[0], s[1], s[2], s[3], rng)
    } else {
        Tensor { shape: s.clone(), data: rng.normal_vec(s.iter().product()) }
    }
}

// ---------------------------------------------------------------------------
// Memory suites (the paper's Figs. 1-2, as gated numbers)
// ---------------------------------------------------------------------------

/// The three canonical schedules every memory suite sweeps, with the
/// short labels the metric names carry.
const MEMORY_SCHEDULES: [(&str, &dyn ActivationSchedule); 3] = [
    ("invertible", &ExecMode::Invertible),
    ("stored", &ExecMode::Stored),
    ("checkpoint4", &CheckpointEveryK(4)),
];

/// Peak training memory vs spatial image size (GLOW, 3 channels, batch 8):
/// one measured `train_step` per (size, schedule) under the byte-exact
/// ledger, plus the static planner's prediction as an equality pin
/// (`predicted_over_measured` must stay exactly 1). All metrics are
/// deterministic and gated.
pub fn memory_vs_size(engine: &Engine, scale: Scale) -> Result<SuiteReport> {
    let sizes: &[usize] = scale.pick(&[16usize][..], &[16, 32, 64][..]);
    let mut r = SuiteReport::new("memory_vs_size");
    for &hw in sizes {
        let net = format!("glow_fig1_{hw}");
        let def = engine.flow(&net)?.def.clone();
        let mut measured = [0i64; MEMORY_SCHEDULES.len()];
        for (j, (label, sched)) in MEMORY_SCHEDULES.iter().enumerate() {
            let m = measure_peak(engine, &net, *sched, None)?;
            measured[j] = m;
            r.metrics.push(Metric::bytes(
                format!("memory_vs_size/hw{hw}/{label}_peak_bytes"), m));
            if m > 0 {
                let predicted = crate::analysis::predict_peak(&def, *sched);
                r.metrics.push(Metric::pinned(
                    format!("memory_vs_size/hw{hw}/\
                             {label}_predicted_over_measured"),
                    predicted as f64 / m as f64));
            }
        }
        let (inv, sto) = (measured[0], measured[1]);
        if inv > 0 {
            // the paper's claim, as a number that must not shrink
            r.metrics.push(Metric::exact(
                format!("memory_vs_size/hw{hw}/stored_over_invertible"),
                sto as f64 / inv as f64, true));
        }
        engine.clear_cache();
    }
    // -- the large-image catalog net ------------------------------------
    // glow64 (64x64x3, 3 squeeze levels, 36 coupling layers) is where the
    // memory claim actually bites: the stored tape holds every multiscale
    // activation while the invertible schedule keeps one. Runs at both
    // scales so CI's quick suite gates the large-net ratio too.
    {
        let net = "glow64";
        let def = engine.flow(net)?.def.clone();
        let mut measured = [0i64; MEMORY_SCHEDULES.len()];
        for (j, (label, sched)) in MEMORY_SCHEDULES.iter().enumerate() {
            let m = measure_peak(engine, net, *sched, None)?;
            measured[j] = m;
            r.metrics.push(Metric::bytes(
                format!("memory_vs_size/{net}/{label}_peak_bytes"), m));
            if m > 0 {
                let predicted = crate::analysis::predict_peak(&def, *sched);
                r.metrics.push(Metric::pinned(
                    format!("memory_vs_size/{net}/\
                             {label}_predicted_over_measured"),
                    predicted as f64 / m as f64));
            }
        }
        let (inv, sto) = (measured[0], measured[1]);
        if inv > 0 {
            r.metrics.push(Metric::exact(
                format!("memory_vs_size/{net}/stored_over_invertible"),
                sto as f64 / inv as f64, true));
        }
        engine.clear_cache();
    }
    Ok(r)
}

/// Peak training memory vs GLOW depth at a fixed 64x64x3 input:
/// invertible must stay flat, stored grows linearly. The flatness ratio
/// (deepest / shallowest invertible peak) is the gated claim metric.
pub fn memory_vs_depth(engine: &Engine, scale: Scale) -> Result<SuiteReport> {
    let depths: &[usize] = scale.pick(&[2usize, 4][..], &[2, 4, 8, 16][..]);
    let mut r = SuiteReport::new("memory_vs_depth");
    let mut inv_first = None;
    let mut inv_last = 0i64;
    let mut sto_last = 0i64;
    for &k in depths {
        let net = format!("glow_fig2_d{k}");
        let def = engine.flow(&net)?.def.clone();
        let mut measured = [0i64; MEMORY_SCHEDULES.len()];
        for (j, (label, sched)) in MEMORY_SCHEDULES.iter().enumerate() {
            let m = measure_peak(engine, &net, *sched, None)?;
            measured[j] = m;
            r.metrics.push(Metric::bytes(
                format!("memory_vs_depth/d{k}/{label}_peak_bytes"), m));
            if m > 0 {
                let predicted = crate::analysis::predict_peak(&def, *sched);
                r.metrics.push(Metric::pinned(
                    format!("memory_vs_depth/d{k}/\
                             {label}_predicted_over_measured"),
                    predicted as f64 / m as f64));
            }
        }
        inv_first.get_or_insert(measured[0]);
        inv_last = measured[0];
        sto_last = measured[1];
        engine.clear_cache();
    }
    let first = inv_first.ok_or_else(|| anyhow!("empty depth sweep"))?;
    if first > 0 {
        r.metrics.push(Metric::exact(
            "memory_vs_depth/invertible_flatness",
            inv_last as f64 / first as f64, false));
    }
    if inv_last > 0 {
        let deepest = depths.last().expect("non-empty sweep");
        r.metrics.push(Metric::exact(
            format!("memory_vs_depth/stored_over_invertible_at_d{deepest}"),
            sto_last as f64 / inv_last as f64, true));
    }
    Ok(r)
}

// ---------------------------------------------------------------------------
// Train throughput (+ the threaded hot paths)
// ---------------------------------------------------------------------------

/// Train-step latency per schedule, the recompute-overhead trade, the
/// data-parallel thread-scaling curve, the threaded inference hot
/// path (relaxed-batch `log_density` / `sample` rows/sec vs thread
/// count), the vectorized-kernel speedup curve at 64x64 scale, and the
/// scratch-pool miss-rate regression check. Wall-clock rates are
/// recorded, never gated; the kernel speedups and the per-step miss
/// bytes gate against the committed baseline (bootstrap-null until a
/// machine class pins them).
pub fn train_throughput(engine: &Engine, scale: Scale)
                        -> Result<SuiteReport> {
    let nets: &[&str] = scale.pick(&["realnvp2d"][..],
                                   &["realnvp2d", "glow_bench32"][..]);
    let (warmup, iters) = scale.pick((1, 3), (2, 8));
    let train_threads: &[usize] =
        scale.pick(&[1usize, 2][..], &[1, 2, 4, 8][..]);
    let infer_threads: &[usize] =
        scale.pick(&[1usize, 2][..], &[1, 2, 4][..]);
    let mut r = SuiteReport::new("train_throughput");
    let mut rng = Pcg64::new(11);

    for net in nets {
        let flow = engine.flow(net)?;
        let params = flow.init_params(3)?;
        let x = batch_for(&flow, &mut rng);

        // -- schedules: invertible vs stored vs hybrid ------------------
        let schedules: [(&str, &dyn ActivationSchedule); 3] = [
            ("invertible", &ExecMode::Invertible),
            ("stored", &ExecMode::Stored),
            ("checkpoint4", &CheckpointEveryK(4)),
        ];
        let mut mean_s = Vec::new();
        for (label, sched) in schedules {
            flow.train_step(&x, None, &params, sched)?; // surface errors
            let s = bench(warmup, iters, || {
                flow.train_step(&x, None, &params, sched).unwrap();
            });
            r.metrics.push(Metric::rate(
                format!("train_throughput/{net}/{label}_steps_per_sec"),
                1.0 / s.mean_s));
            mean_s.push(s.mean_s);
        }
        r.metrics.push(Metric::observed(
            format!("train_throughput/{net}/recompute_overhead_pct"),
            (mean_s[0] / mean_s[1] - 1.0) * 100.0, false));
        // the static cost model's version of the same trade: predicted
        // train-step flops under invertible over stored. Exact integer
        // arithmetic on both sides, so it's an equality pin — any drift
        // means the cost model (or a layer's op count) changed
        let inv_flops = crate::analysis::train_cost(
            &flow.def, engine.manifest(), &ExecMode::Invertible)?.flops;
        let sto_flops = crate::analysis::train_cost(
            &flow.def, engine.manifest(), &ExecMode::Stored)?.flops;
        r.metrics.push(Metric::pinned(
            format!("train_throughput/{net}/recompute_flops_ratio"),
            inv_flops as f64 / sto_flops as f64));

        // -- telemetry overhead gate ------------------------------------
        // the instrumentation contract is "provably inert": per event a
        // gated relaxed-atomic op, no allocation. Bench the same step
        // with the runtime kill switch off and gate the relative cost
        // against the committed baseline (BENCHMARKS.md documents the
        // <2% budget the baseline encodes).
        let s_on = bench(warmup, iters, || {
            flow.train_step(&x, None, &params, &ExecMode::Invertible)
                .unwrap();
        });
        crate::telemetry::set_enabled(false);
        let s_off = bench(warmup, iters, || {
            flow.train_step(&x, None, &params, &ExecMode::Invertible)
                .unwrap();
        });
        crate::telemetry::set_enabled(true);
        r.metrics.push(Metric::exact(
            format!("train_throughput/{net}/telemetry_overhead_pct"),
            (s_on.mean_s / s_off.mean_s - 1.0) * 100.0, false));

        // -- data-parallel thread scaling -------------------------------
        let mut base = 0.0f64;
        for &t in train_threads {
            let trainer = ParallelTrainer::new(t);
            trainer.train_step(&flow, &x, None, &params,
                               &ExecMode::Invertible)?;
            let s = bench(1, iters, || {
                trainer.train_step(&flow, &x, None, &params,
                                   &ExecMode::Invertible).unwrap();
            });
            let sps = 1.0 / s.mean_s;
            if t == *train_threads.first().expect("non-empty") {
                base = sps;
            }
            r.metrics.push(Metric::rate(
                format!("train_throughput/{net}/train_threads{t}_steps_per_sec"),
                sps));
            r.metrics.push(Metric::observed(
                format!("train_throughput/{net}/train_threads{t}_speedup"),
                sps / base, true));
        }

        // -- threaded inference hot path --------------------------------
        // rows chosen so the chunked path engages (n = 4 canonical
        // batches); same latents/inputs at every thread count, so the
        // curve isolates the pool overhead + scaling
        let n = flow.batch() * 4;
        let chunk = flow.infer_chunk();
        // stack 4 canonical batches worth of rows
        let mut xr = batch_for(&flow, &mut rng);
        while xr.shape[0] < n {
            let more = batch_for(&flow, &mut rng);
            xr.data.extend_from_slice(&more.data);
            xr.shape[0] += more.shape[0];
        }
        let mut base_ld = 0.0f64;
        let mut base_sb = 0.0f64;
        for &t in infer_threads {
            // per-call worker override through the unified options structs
            flow.log_density(&xr, &params, InferOpts::relaxed().threads(t))?;
            let s = bench(1, iters, || {
                flow.log_density(&xr, &params,
                                 InferOpts::relaxed().threads(t)).unwrap();
            });
            let rows = n as f64 / s.mean_s;
            let s2 = bench(1, iters, || {
                let mut r2 = Pcg64::new(17);
                flow.sample(&params,
                            SampleOpts::new(n, &mut r2).threads(t)).unwrap();
            });
            let srows = n as f64 / s2.mean_s;
            if t == *infer_threads.first().expect("non-empty") {
                base_ld = rows;
                base_sb = srows;
            }
            r.metrics.push(Metric::rate(
                format!("train_throughput/{net}/log_density_threads{t}_rows_per_sec"),
                rows));
            r.metrics.push(Metric::observed(
                format!("train_throughput/{net}/log_density_threads{t}_speedup"),
                rows / base_ld, true));
            r.metrics.push(Metric::rate(
                format!("train_throughput/{net}/sample_batch_threads{t}_rows_per_sec"),
                srows));
            r.metrics.push(Metric::observed(
                format!("train_throughput/{net}/sample_batch_threads{t}_speedup"),
                srows / base_sb, true));
        }
        // the fixed chunk size the bit-identity contract depends on:
        // drift in EITHER direction is a contract change, so it's a pin
        r.metrics.push(Metric::pinned(
            format!("train_throughput/{net}/infer_chunk_rows"),
            chunk as f64));
        engine.clear_cache();
    }

    // -- vectorized-kernel speedup at 64x64 scale -----------------------
    // The packed 8-wide GEMM and the parallel im2col conv against their
    // scalar triple-loop references, on the exact shapes glow64's first
    // coupling layer lowers to: 4x64x64 pixel rows through a 3x3, 12->64
    // conv (GEMM: 16384 x 108 @ 108 x 64). rows/sec is wall-clock and
    // recorded; the speedup-vs-scalar ratios are the gated tentpole
    // claim. Both paths are cross-checked element-wise first so a wrong
    // fast kernel can never post a winning number.
    {
        use crate::backend::math;
        let kt = scale.pick(2usize, 4);
        let (kw_warm, kw_iters) = scale.pick((1, 2), (2, 6));
        let mut krng = Pcg64::new(23);
        let x = Tensor { shape: vec![4, 64, 64, 12],
                         data: krng.normal_vec(4 * 64 * 64 * 12) };
        let w = Tensor { shape: vec![3, 3, 12, 64],
                         data: krng.normal_vec(3 * 3 * 12 * 64) };
        let rows = 4 * 64 * 64;
        let cols = math::naive::im2col_same(&x, 3, 3);
        let wm = Tensor { shape: vec![9 * 12, 64], data: w.data.clone() };

        let fast_mm = math::par::with_kernel_threads(
            kt, || math::matmul(&cols, &wm));
        let slow_mm = math::naive::matmul(&cols, &wm);
        let fast_cv = math::par::with_kernel_threads(
            kt, || math::conv2d_same(&x, &w));
        let slow_cv = math::naive::conv2d_same(&x, &w);
        for (name, a, b) in [("gemm", &fast_mm, &slow_mm),
                             ("conv", &fast_cv, &slow_cv)] {
            let err = a.data.iter().zip(&b.data)
                .map(|(p, q)| (p - q).abs())
                .fold(0.0f32, f32::max);
            if err > 1e-3 {
                return Err(anyhow!(
                    "{name} kernel disagrees with scalar reference \
                     (max abs err {err:e})"));
            }
        }

        let s_fast = bench(kw_warm, kw_iters, || {
            math::par::with_kernel_threads(kt, || {
                math::scratch::recycle(math::matmul(&cols, &wm));
            });
        });
        let s_slow = bench(kw_warm, kw_iters, || {
            drop(math::naive::matmul(&cols, &wm));
        });
        let gemm_speedup = s_slow.mean_s / s_fast.mean_s;
        r.metrics.push(Metric::rate(
            "train_throughput/kernels64/gemm_rows_per_sec",
            rows as f64 / s_fast.mean_s));
        r.metrics.push(Metric::rate(
            "train_throughput/kernels64/gemm_scalar_rows_per_sec",
            rows as f64 / s_slow.mean_s));
        r.metrics.push(Metric::exact(
            "train_throughput/kernels64/gemm_speedup_vs_scalar",
            gemm_speedup, true));

        let c_fast = bench(kw_warm, kw_iters, || {
            math::par::with_kernel_threads(kt, || {
                math::scratch::recycle(math::conv2d_same(&x, &w));
            });
        });
        let c_slow = bench(kw_warm, kw_iters, || {
            drop(math::naive::conv2d_same(&x, &w));
        });
        r.metrics.push(Metric::rate(
            "train_throughput/kernels64/conv_rows_per_sec",
            rows as f64 / c_fast.mean_s));
        r.metrics.push(Metric::rate(
            "train_throughput/kernels64/conv_scalar_rows_per_sec",
            rows as f64 / c_slow.mean_s));
        r.metrics.push(Metric::exact(
            "train_throughput/kernels64/conv_speedup_vs_scalar",
            c_slow.mean_s / c_fast.mean_s, true));

        let i_fast = bench(kw_warm, kw_iters, || {
            math::scratch::recycle(math::im2col_same(&x, 3, 3));
        });
        r.metrics.push(Metric::rate(
            "train_throughput/kernels64/im2col_rows_per_sec",
            rows as f64 / i_fast.mean_s));
    }

    // -- scratch-pool miss regression -----------------------------------
    // Warm the pool with one step, then count `invertnet_scratch_miss_
    // bytes_total` growth across a fixed workload: a healthy pool serves
    // the steady-state entirely from reuse, so the per-step miss bytes
    // must stay near zero. Gated lower-is-better (satellite of the
    // raised pool cap — a cap regression shows up here as fresh
    // allocations every step).
    {
        let flow = engine.flow("realnvp2d")?;
        let params = flow.init_params(3)?;
        let x = batch_for(&flow, &mut rng);
        flow.train_step(&x, None, &params, &ExecMode::Invertible)?;
        let miss = crate::telemetry::global()
            .counter("invertnet_scratch_miss_bytes_total");
        let steps = scale.pick(4u64, 16);
        let before = miss.get();
        for _ in 0..steps {
            flow.train_step(&x, None, &params, &ExecMode::Invertible)?;
        }
        let delta = miss.get().saturating_sub(before);
        r.metrics.push(Metric::bytes(
            "train_throughput/scratch_miss_bytes_per_step",
            (delta / steps) as i64));
        r.metrics.push(Metric::observed(
            "train_throughput/scratch_pool_budget_bytes",
            crate::backend::math::scratch::pool_budget_bytes() as f64,
            true));
        engine.clear_cache();
    }
    Ok(r)
}

// ---------------------------------------------------------------------------
// Serve latency
// ---------------------------------------------------------------------------

const SERVE_NET: &str = "realnvp2d";

fn boot_server(engine: &Engine, max_batch: usize) -> Result<Server> {
    let registry = Registry::new(engine.clone(), 2);
    registry.register_untrained(SERVE_NET, 3)?;
    Ok(Server::new(registry, BatchConfig {
        max_batch,
        max_delay: Duration::from_micros(300),
        workers: 2,
        queue_cap: 1024,
    }).allow_untrained())
}

/// Fire `clients * reqs` single-item requests, return (requests/sec,
/// stats snapshot). Errored requests are collected and surfaced as an
/// `Err` — never a panic inside a worker thread, so a transient server
/// error (e.g. bounded-queue give-up on a loaded runner) fails the
/// suite cleanly instead of aborting the whole bench process.
fn run_load(server: &Server, op: &str, clients: usize, reqs: usize)
            -> Result<(f64, StatsSnapshot)> {
    use std::sync::Mutex;
    let first_err: Mutex<Option<String>> = Mutex::new(None);
    let t0 = Instant::now();
    std::thread::scope(|scope| {
        for client in 0..clients as u64 {
            let first_err = &first_err;
            scope.spawn(move || {
                let mut rng = Pcg64::new(0xbe7c ^ client);
                for i in 0..reqs as u64 {
                    let req = match op {
                        "sample" => Request::Sample {
                            model: None,
                            n: 1,
                            temperature: 1.0,
                            seed: client * 10_000 + i,
                            cond: None,
                        },
                        _ => Request::Score {
                            model: None,
                            x: Tensor {
                                shape: vec![1, 2],
                                data: rng.normal_vec(2),
                            },
                            cond: None,
                        },
                    };
                    let resp = server.handle(req);
                    if resp.is_error() {
                        first_err.lock().unwrap().get_or_insert_with(
                            || format!("{op} request failed: {resp:?}"));
                        return;
                    }
                }
            });
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    if let Some(msg) = first_err.into_inner().unwrap() {
        return Err(anyhow!("{msg}"));
    }
    let total = (clients * reqs) as f64;
    let Response::Stats(snap) = server.handle(Request::Stats) else {
        return Err(anyhow!("stats request failed"));
    };
    Ok((total / elapsed, snap))
}

/// Serving throughput: coalesced micro-batching (max-batch 8) vs
/// one-request-per-pass (max-batch 1), for `score` and `sample`. The
/// request-count metric is exact and gated; rates/latencies are recorded.
pub fn serve_latency(engine: &Engine, scale: Scale) -> Result<SuiteReport> {
    let (clients, reqs) = scale.pick((4, 25), (8, 150));
    let mut r = SuiteReport::new("serve_latency");
    let mut total_expected = 0u64;
    let mut total_seen = 0u64;
    for op in ["score", "sample"] {
        let base = boot_server(engine, 1)?;
        let (rps_1, snap_1) = run_load(&base, op, clients, reqs)?;
        let coal = boot_server(engine, 8)?;
        let (rps_8, snap_8) = run_load(&coal, op, clients, reqs)?;
        total_expected += 2 * (clients * reqs) as u64;
        total_seen += snap_1.requests + snap_8.requests;

        r.metrics.push(Metric::rate(
            format!("serve_latency/{op}/unbatched_reqs_per_sec"), rps_1));
        r.metrics.push(Metric::rate(
            format!("serve_latency/{op}/coalesced_reqs_per_sec"), rps_8));
        r.metrics.push(Metric::observed(
            format!("serve_latency/{op}/coalesce_speedup"),
            rps_8 / rps_1, true));
        r.metrics.push(Metric::micros(
            format!("serve_latency/{op}/coalesced_p50_us"),
            snap_8.p50_us as f64));
        r.metrics.push(Metric::micros(
            format!("serve_latency/{op}/coalesced_p99_us"),
            snap_8.p99_us as f64));
        r.metrics.push(Metric::micros(
            format!("serve_latency/{op}/coalesced_p999_us"),
            snap_8.p999_us as f64));
        r.metrics.push(Metric::observed(
            format!("serve_latency/{op}/coalesced_mean_batch"),
            snap_8.mean_batch, true));
    }
    // every request must be answered exactly once — an equality pin, so
    // double-counting (ratio > 1) fails just like dropped requests
    r.metrics.push(Metric::pinned(
        "serve_latency/requests_answered_over_sent",
        total_seen as f64 / total_expected as f64));
    Ok(r)
}

// ---------------------------------------------------------------------------
// Posterior end-to-end
// ---------------------------------------------------------------------------

/// End-to-end amortized inference: train `cond_lingauss2d` on the
/// linear-gaussian simulator for a fixed-seed budget, then draw posterior
/// samples for a fixed observation and compare the sample mean against
/// the closed-form posterior. Loss, ledger peak and mean error are
/// deterministic (fixed seeds, single-threaded training) and gated;
/// rates are recorded.
pub fn posterior_e2e(engine: &Engine, scale: Scale) -> Result<SuiteReport> {
    let steps = scale.pick(60, 400);
    let draws = scale.pick(128usize, 256);
    let sim = Simulator::parse("linear-gaussian")?;
    let flow = engine.flow(sim.default_net())?;
    let mut params = flow.init_params(7)?;
    let cfg = PosteriorTrainConfig {
        steps,
        lr: 3e-3,
        seed: 7,
        eval_every: 0,
        eval_batches: 0,
        schedule: Arc::new(ExecMode::Invertible),
        clip: Some(crate::train::GradClip { max_norm: 50.0 }),
        log_every: usize::MAX,
        out_dir: None,
        quiet: true,
        threads: 1,
        microbatch: None,
    };
    let t0 = Instant::now();
    let report = amortized_train(&flow, &mut params, &sim, &cfg)?;
    let train_s = t0.elapsed().as_secs_f64();

    let mut r = SuiteReport::new("posterior");
    r.metrics.push(Metric::exact(
        format!("posterior/lingauss/final_loss_{steps}steps"),
        report.final_loss as f64, false));
    r.metrics.push(Metric::bytes(
        "posterior/lingauss/train_peak_sched_bytes",
        report.peak_sched_bytes));
    r.metrics.push(Metric::rate(
        "posterior/lingauss/train_steps_per_sec",
        steps as f64 / train_s.max(1e-9)));

    // fixed observation, fixed seed -> deterministic sample mean
    let y = [0.7f32, -0.4];
    let t1 = Instant::now();
    let samples = posterior_samples(&flow, &params, &y, draws, 1.0, 99)?;
    let sample_s = t1.elapsed().as_secs_f64();
    let s = summarize(&samples);
    let (mu, _cov) = LinearGaussian::default_problem()
        .posterior([y[0] as f64, y[1] as f64]);
    let err = s.mean.iter().zip(&mu)
        .map(|(a, b)| (*a as f64 - b).abs())
        .fold(0.0f64, f64::max);
    r.metrics.push(Metric::exact(
        format!("posterior/lingauss/mean_abs_err_{steps}steps"),
        err, false));
    r.metrics.push(Metric::rate(
        "posterior/lingauss/sample_rows_per_sec",
        draws as f64 / sample_s.max(1e-9)));
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scale_picks() {
        assert_eq!(Scale::Quick.pick(1, 2), 1);
        assert_eq!(Scale::Full.pick(1, 2), 2);
    }

    #[test]
    fn memory_suite_reports_gated_deterministic_bytes() {
        let engine = Engine::native().unwrap();
        let a = memory_vs_size(&engine, Scale::Quick).unwrap();
        assert!(a.metrics.iter().any(
            |m| m.name == "memory_vs_size/hw16/invertible_peak_bytes"));
        let inv = a.metrics.iter()
            .find(|m| m.name.ends_with("invertible_peak_bytes")).unwrap();
        let sto = a.metrics.iter()
            .find(|m| m.name.ends_with("stored_peak_bytes")).unwrap();
        assert!(inv.check && sto.check);
        assert!(sto.value > inv.value,
                "stored {} should exceed invertible {}",
                sto.value, inv.value);
        // the static planner's equality pins ride along, exactly 1 for
        // every (size, schedule) cell — hw16 and the glow64 block alike
        let pins: Vec<_> = a.metrics.iter()
            .filter(|m| m.name.ends_with("_predicted_over_measured"))
            .collect();
        assert_eq!(pins.len(), 6,
                   "one pin per schedule at hw16 and at glow64");
        for p in pins {
            assert!(p.check && p.pin, "{}", p.name);
            assert_eq!(p.value, 1.0, "{}: predicted != measured", p.name);
        }
        // the large-net rows are present and carry the tentpole claim:
        // at 64x64 multiscale depth the stored tape must cost >= 20x the
        // invertible schedule's peak
        let big = a.metrics.iter()
            .find(|m| m.name == "memory_vs_size/glow64/stored_over_invertible")
            .expect("glow64 ratio metric");
        assert!(big.check);
        assert!(big.value >= 20.0,
                "glow64 stored/invertible ratio {} below the 20x claim",
                big.value);
        // deterministic: a second run reproduces the bytes exactly
        let b = memory_vs_size(&engine, Scale::Quick).unwrap();
        for (ma, mb) in a.metrics.iter().zip(&b.metrics) {
            assert_eq!(ma.name, mb.name);
            assert_eq!(ma.value, mb.value, "{}", ma.name);
        }
    }

    #[test]
    fn depth_suite_pins_the_flatness_claim() {
        let engine = Engine::native().unwrap();
        let r = memory_vs_depth(&engine, Scale::Quick).unwrap();
        let flat = r.metrics.iter()
            .find(|m| m.name == "memory_vs_depth/invertible_flatness")
            .expect("flatness metric");
        assert!(flat.check);
        // invertible peak must stay ~flat in depth (paper claim)
        assert!(flat.value < 1.6, "flatness {}", flat.value);
    }
}
