//! `perf` — the unified performance harness.
//!
//! The paper's headline claim is quantitative (invertible backprop beats
//! the autodiff tape on memory, and the package is *measurably* fast), so
//! regressions in the memory ledger or the training/serving hot paths must
//! be visible, not vibes. This module turns the four ad-hoc `benches/*.rs`
//! binaries into **library suites** with one machine-readable output
//! schema, one CLI verb, and a committed-baseline regression gate:
//!
//! ```text
//! invertnet bench --suite quick --check --baseline baselines/quick.json
//! invertnet bench --suite all --out baselines/        # regenerate
//! ```
//!
//! * [`suites`] — the measurement code: memory-vs-size, memory-vs-depth,
//!   train-throughput, serve-latency, and an end-to-end posterior suite,
//!   each at [`Scale::Quick`] (CI-sized) or [`Scale::Full`].
//! * [`Metric`] — one named number with a unit, a goodness direction, and
//!   a `check` bit: **deterministic** metrics (ledger bytes, exact
//!   counts, fixed-seed losses) gate CI; wall-clock metrics record the
//!   trajectory but never gate, because they are machine-dependent.
//! * [`SuiteReport`] — metrics + suite name, serialized as the
//!   `invertnet-bench/v1` JSON document (`BENCH_<suite>.json`), carrying
//!   the [`crate::util::bench::env_json`] environment block (git rev,
//!   threads, cpus, profile) so historical records are comparable.
//! * [`check_report`] — compare a fresh report against a committed
//!   baseline with a relative tolerance; regressions in the bad direction
//!   beyond `--tol` percent fail the run (either direction for equality
//!   **pins** like the fixed inference chunk). Baseline values of `null`
//!   are *bootstrap* placeholders: they document the expected metric
//!   names before the first trusted machine fills the numbers in, and
//!   never fail the check. A gated metric *absent* from the baseline, or
//!   a baseline recorded for a different suite, DOES fail — the gate
//!   must not silently de-gate itself.

pub mod suites;

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::api::Engine;
use crate::util::bench::env_json;
use crate::util::json::Json;

pub use suites::{memory_vs_depth, memory_vs_size, posterior_e2e,
                 serve_latency, train_throughput, Scale};

/// Schema tag written into (and required of) every bench document.
pub const SCHEMA: &str = "invertnet-bench/v1";

// ---------------------------------------------------------------------------
// Metrics and reports
// ---------------------------------------------------------------------------

/// One measured number. `name` is `suite/case/metric`
/// (e.g. `memory_vs_size/hw16/invertible_peak_bytes`).
#[derive(Debug, Clone)]
pub struct Metric {
    pub name: String,
    pub value: f64,
    pub unit: String,
    /// Which direction is good: `false` for bytes/latency, `true` for
    /// throughput/ratio-of-claim metrics.
    pub higher_is_better: bool,
    /// Gated by `--check`. Only deterministic metrics set this; timing
    /// metrics record the trajectory without gating.
    pub check: bool,
    /// Equality pin: deviation in *either* direction beyond tolerance is
    /// a regression (contract constants like the fixed inference chunk,
    /// or exactly-once counters). `higher_is_better` is ignored.
    pub pin: bool,
}

impl Metric {
    /// Deterministic byte count (ledger peaks): lower is better, gated.
    pub fn bytes(name: impl Into<String>, value: i64) -> Metric {
        Metric {
            name: name.into(),
            value: value as f64,
            unit: "bytes".into(),
            higher_is_better: false,
            check: true,
            pin: false,
        }
    }

    /// Deterministic dimensionless value, gated. `higher_is_better`
    /// states the good direction.
    pub fn exact(name: impl Into<String>, value: f64,
                 higher_is_better: bool) -> Metric {
        Metric {
            name: name.into(),
            value,
            unit: "".into(),
            higher_is_better,
            check: true,
            pin: false,
        }
    }

    /// Deterministic contract constant, gated as an equality pin: any
    /// drift beyond tolerance — in either direction — is a regression.
    pub fn pinned(name: impl Into<String>, value: f64) -> Metric {
        Metric {
            name: name.into(),
            value,
            unit: "".into(),
            higher_is_better: true,
            check: true,
            pin: true,
        }
    }

    /// Wall-clock rate (per second): higher is better, never gated.
    pub fn rate(name: impl Into<String>, value: f64) -> Metric {
        Metric {
            name: name.into(),
            value,
            unit: "per_sec".into(),
            higher_is_better: true,
            check: false,
            pin: false,
        }
    }

    /// Wall-clock duration in microseconds: lower is better, never gated.
    pub fn micros(name: impl Into<String>, value: f64) -> Metric {
        Metric {
            name: name.into(),
            value,
            unit: "us".into(),
            higher_is_better: false,
            check: false,
            pin: false,
        }
    }

    /// Unitless observation (speedups, mean batch sizes): recorded for
    /// the trajectory, never gated.
    pub fn observed(name: impl Into<String>, value: f64,
                    higher_is_better: bool) -> Metric {
        Metric {
            name: name.into(),
            value,
            unit: "".into(),
            higher_is_better,
            check: false,
            pin: false,
        }
    }
}

/// A named bundle of metrics — one `BENCH_<suite>.json` document.
#[derive(Debug, Clone)]
pub struct SuiteReport {
    pub suite: String,
    pub metrics: Vec<Metric>,
}

impl SuiteReport {
    pub fn new(suite: impl Into<String>) -> SuiteReport {
        SuiteReport { suite: suite.into(), metrics: Vec::new() }
    }

    /// Merge another report's metrics into this one (the `quick` and
    /// `memory` CLI suites are unions of library suites).
    pub fn absorb(&mut self, other: SuiteReport) {
        self.metrics.extend(other.metrics);
    }

    /// The full `invertnet-bench/v1` document. `threads` feeds the
    /// environment block; `backend` names the execution backend measured.
    pub fn to_json(&self, backend: &str, threads: usize) -> Json {
        let mut env = match env_json(threads) {
            Json::Obj(m) => m,
            _ => unreachable!("env_json returns an object"),
        };
        env.insert("backend".into(), Json::Str(backend.into()));
        Json::obj(vec![
            ("schema", Json::Str(SCHEMA.into())),
            ("suite", Json::Str(self.suite.clone())),
            ("env", Json::Obj(env)),
            ("metrics", Json::Arr(
                self.metrics.iter().map(|m| Json::obj(vec![
                    ("name", Json::Str(m.name.clone())),
                    ("value", Json::Num(m.value)),
                    ("unit", Json::Str(m.unit.clone())),
                    ("higher_is_better", Json::Bool(m.higher_is_better)),
                    ("check", Json::Bool(m.check)),
                    ("pin", Json::Bool(m.pin)),
                ])).collect())),
        ])
    }

    /// Write the document to `path` and echo a one-line `BENCH {json}`
    /// record on stdout (the convention CI greps for).
    pub fn write(&self, backend: &str, threads: usize, path: &Path)
                 -> Result<()> {
        let doc = self.to_json(backend, threads);
        println!("BENCH {}", doc.to_string());
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {parent:?}"))?;
            }
        }
        std::fs::write(path, doc.to_string_pretty())
            .with_context(|| format!("writing {path:?}"))?;
        println!("# {} suite -> {}", self.suite, path.display());
        Ok(())
    }

    /// Human-readable table of the metrics.
    pub fn print(&self) {
        println!("# suite {} ({} metrics)", self.suite, self.metrics.len());
        for m in &self.metrics {
            println!("{:<56} {:>16.3} {:<8} {}{}",
                     m.name, m.value, m.unit,
                     if m.higher_is_better { "up" } else { "down" },
                     if m.check { "  [gated]" } else { "" });
        }
    }
}

// ---------------------------------------------------------------------------
// Baselines
// ---------------------------------------------------------------------------

/// One baseline entry: `value: None` is a bootstrap placeholder (names
/// the metric, fails nothing).
#[derive(Debug, Clone)]
pub struct BaselineMetric {
    pub value: Option<f64>,
    pub higher_is_better: bool,
    pub check: bool,
    /// Equality pin (optional in the document; defaults to false).
    pub pin: bool,
}

/// A parsed baseline document: metric name -> entry.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    pub suite: String,
    pub metrics: std::collections::BTreeMap<String, BaselineMetric>,
}

impl Baseline {
    pub fn parse(text: &str) -> Result<Baseline> {
        let doc = Json::parse(text)?;
        let schema = doc.req("schema")?.as_str()?;
        if schema != SCHEMA {
            bail!("baseline schema {schema:?} != {SCHEMA:?}");
        }
        let mut b = Baseline {
            suite: doc.req("suite")?.as_str()?.to_string(),
            metrics: Default::default(),
        };
        for m in doc.req("metrics")?.as_arr()? {
            let name = m.req("name")?.as_str()?.to_string();
            let value = match m.req("value")? {
                Json::Null => None,
                v => Some(v.as_f64()?),
            };
            let higher = matches!(m.req("higher_is_better")?,
                                  Json::Bool(true));
            let check = matches!(m.req("check")?, Json::Bool(true));
            let pin = matches!(m.get("pin"), Some(Json::Bool(true)));
            b.metrics.insert(
                name, BaselineMetric { value, higher_is_better: higher,
                                       check, pin });
        }
        Ok(b)
    }

    pub fn load(path: &Path) -> Result<Baseline> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading baseline {path:?}"))?;
        Baseline::parse(&text)
            .with_context(|| format!("parsing baseline {path:?}"))
    }
}

/// Outcome of a baseline comparison.
#[derive(Debug, Default)]
pub struct CheckOutcome {
    /// Gated metrics compared against a recorded value.
    pub compared: usize,
    /// Gated metrics whose baseline value is `null` (bootstrap
    /// placeholders): recorded only, never a failure.
    pub bootstrap: usize,
    /// Gated metrics with NO baseline entry at all. Under `--check` this
    /// is a failure: a renamed metric (or the wrong baseline file) must
    /// not silently de-gate itself — regenerate the baseline instead.
    pub missing: Vec<String>,
    /// `(name, baseline, measured, bad-direction % change)` beyond tol.
    pub regressions: Vec<(String, f64, f64, f64)>,
}

impl CheckOutcome {
    /// Clean iff nothing regressed AND every gated metric had a baseline
    /// entry (null placeholders count as present).
    pub fn ok(&self) -> bool {
        self.regressions.is_empty() && self.missing.is_empty()
    }
}

/// Compare `report` against `baseline` with a relative tolerance of
/// `tol_pct` percent. Errors if the baseline was recorded for a
/// different suite (comparing `serve` numbers against `quick.json` is a
/// user error, not a clean pass). Only metrics gated (`check: true`) in
/// **both** the report and the baseline are compared; the measured value
/// may drift up to `tol_pct` percent in the *bad* direction (per the
/// metric's goodness direction — either direction for equality pins)
/// before it counts as a regression. A gated metric with no baseline
/// entry at all lands in [`CheckOutcome::missing`] and fails
/// [`CheckOutcome::ok`]. Prints one `CHECK` line per gated metric.
pub fn check_report(report: &SuiteReport, baseline: &Baseline,
                    tol_pct: f64) -> Result<CheckOutcome> {
    if baseline.suite != report.suite {
        bail!("baseline is for suite {:?}, report is {:?} — wrong \
               --baseline file?", baseline.suite, report.suite);
    }
    let mut out = CheckOutcome::default();
    for m in report.metrics.iter().filter(|m| m.check) {
        let Some(base) = baseline.metrics.get(&m.name)
            .filter(|b| b.check) else {
            out.missing.push(m.name.clone());
            println!("CHECK {:<56} measured {:>14.3}  MISSING from \
                      baseline (regenerate it)", m.name, m.value);
            continue;
        };
        let Some(base_v) = base.value else {
            out.bootstrap += 1;
            println!("CHECK {:<56} measured {:>14.3}  (baseline null — \
                      bootstrap, recorded only)", m.name, m.value);
            continue;
        };
        // % change in the bad direction; <= 0 means equal or improved.
        // Pins treat ANY deviation as bad.
        let bad_pct = if base_v == 0.0 {
            // relative change is undefined; any bad-direction move on a
            // zero baseline is treated as a full regression
            let moved = if m.pin {
                m.value != 0.0
            } else if m.higher_is_better {
                m.value < 0.0
            } else {
                m.value > 0.0
            };
            if moved { f64::INFINITY } else { 0.0 }
        } else if m.pin {
            (m.value - base_v).abs() / base_v.abs() * 100.0
        } else if m.higher_is_better {
            (base_v - m.value) / base_v.abs() * 100.0
        } else {
            (m.value - base_v) / base_v.abs() * 100.0
        };
        out.compared += 1;
        let verdict = if bad_pct > tol_pct { "REGRESSION" } else { "ok" };
        println!("CHECK {:<56} base {:>14.3}  now {:>14.3}  {:>+8.2}% {}{}",
                 m.name, base_v, m.value,
                 if m.higher_is_better && !m.pin { -bad_pct } else { bad_pct },
                 verdict,
                 if m.pin { " [pin]" } else { "" });
        if bad_pct > tol_pct {
            out.regressions.push((m.name.clone(), base_v, m.value, bad_pct));
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// CLI suite resolution
// ---------------------------------------------------------------------------

/// The CLI-facing suite names `invertnet bench --suite` accepts.
pub const SUITE_NAMES: &[&str] =
    &["all", "quick", "memory", "throughput", "serve", "posterior"];

/// Resolve a CLI suite name into one or more reports. `quick` is a
/// single merged report of every library suite at [`Scale::Quick`];
/// `all` is the four full suites as separate reports; the rest are one
/// full suite each (`memory` merges the size and depth sweeps).
pub fn run_suite(engine: &Engine, name: &str) -> Result<Vec<SuiteReport>> {
    match name {
        "quick" => {
            let mut r = SuiteReport::new("quick");
            r.absorb(memory_vs_size(engine, Scale::Quick)?);
            r.absorb(memory_vs_depth(engine, Scale::Quick)?);
            r.absorb(train_throughput(engine, Scale::Quick)?);
            r.absorb(serve_latency(engine, Scale::Quick)?);
            r.absorb(posterior_e2e(engine, Scale::Quick)?);
            Ok(vec![r])
        }
        "memory" => {
            let mut r = SuiteReport::new("memory");
            r.absorb(memory_vs_size(engine, Scale::Full)?);
            r.absorb(memory_vs_depth(engine, Scale::Full)?);
            Ok(vec![r])
        }
        "throughput" => {
            let mut r = SuiteReport::new("throughput");
            r.absorb(train_throughput(engine, Scale::Full)?);
            Ok(vec![r])
        }
        "serve" => {
            let mut r = SuiteReport::new("serve");
            r.absorb(serve_latency(engine, Scale::Full)?);
            Ok(vec![r])
        }
        "posterior" => {
            let mut r = SuiteReport::new("posterior");
            r.absorb(posterior_e2e(engine, Scale::Full)?);
            Ok(vec![r])
        }
        "all" => {
            let mut out = Vec::new();
            for sub in ["memory", "throughput", "serve", "posterior"] {
                out.extend(run_suite(engine, sub)?);
            }
            Ok(out)
        }
        other => bail!("unknown suite {other:?} (expected one of \
                        {SUITE_NAMES:?})"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report() -> SuiteReport {
        SuiteReport {
            suite: "t".into(),
            metrics: vec![
                Metric::bytes("t/a/peak_bytes", 1000),
                Metric::rate("t/a/steps_per_sec", 42.0),
                Metric::exact("t/a/ratio", 4.0, true),
                Metric::pinned("t/a/chunk", 256.0),
            ],
        }
    }

    #[test]
    fn report_roundtrips_through_json() {
        let r = report();
        let doc = r.to_json("ref", 2);
        assert_eq!(doc.req("schema").unwrap().as_str().unwrap(), SCHEMA);
        assert_eq!(doc.req("suite").unwrap().as_str().unwrap(), "t");
        let env = doc.req("env").unwrap();
        assert_eq!(env.req("backend").unwrap().as_str().unwrap(), "ref");
        assert_eq!(env.req("threads").unwrap().as_usize().unwrap(), 2);
        assert!(env.req("git_rev").is_ok());
        assert!(env.req("profile").is_ok());
        // the serialized report is its own valid baseline
        let b = Baseline::parse(&doc.to_string()).unwrap();
        assert_eq!(b.suite, "t");
        assert_eq!(b.metrics.len(), 4);
        assert_eq!(b.metrics["t/a/peak_bytes"].value, Some(1000.0));
        assert!(b.metrics["t/a/peak_bytes"].check);
        assert!(!b.metrics["t/a/steps_per_sec"].check);
        assert!(b.metrics["t/a/chunk"].pin);
        assert!(!b.metrics["t/a/peak_bytes"].pin);
        // a baseline without "pin" keys (older documents) still parses
        let legacy = Baseline::parse(
            r#"{"schema":"invertnet-bench/v1","suite":"t","metrics":
                [{"name":"x","value":1,"unit":"","higher_is_better":true,
                  "check":true}]}"#).unwrap();
        assert!(!legacy.metrics["x"].pin);
    }

    #[test]
    fn self_comparison_is_clean() {
        let r = report();
        let b = Baseline::parse(&r.to_json("ref", 1).to_string()).unwrap();
        let out = check_report(&r, &b, 2.0).unwrap();
        assert!(out.ok());
        assert_eq!(out.compared, 3); // the three gated metrics
        assert_eq!(out.bootstrap, 0);
        assert!(out.missing.is_empty());
    }

    #[test]
    fn regressions_respect_direction_and_tolerance() {
        let r = report();
        let mut b = Baseline::parse(&r.to_json("ref", 1).to_string())
            .unwrap();
        // bytes grew 10% over baseline -> lower-is-better regression
        b.metrics.get_mut("t/a/peak_bytes").unwrap().value = Some(909.0);
        let out = check_report(&r, &b, 5.0).unwrap();
        assert_eq!(out.regressions.len(), 1);
        assert_eq!(out.regressions[0].0, "t/a/peak_bytes");
        // within tolerance -> clean
        let out = check_report(&r, &b, 15.0).unwrap();
        assert!(out.ok());
        // higher-is-better metric dropping is also a regression
        b.metrics.get_mut("t/a/peak_bytes").unwrap().value = Some(1000.0);
        b.metrics.get_mut("t/a/ratio").unwrap().value = Some(8.0);
        let out = check_report(&r, &b, 5.0).unwrap();
        assert_eq!(out.regressions.len(), 1);
        assert_eq!(out.regressions[0].0, "t/a/ratio");
        // improvements never fail, at any tolerance
        b.metrics.get_mut("t/a/ratio").unwrap().value = Some(1.0);
        assert!(check_report(&r, &b, 0.0).unwrap().ok());
    }

    #[test]
    fn pins_fail_on_drift_in_either_direction() {
        let r = report();
        let mut b = Baseline::parse(&r.to_json("ref", 1).to_string())
            .unwrap();
        // measured 256 vs pinned 128: "higher" would pass a directional
        // gate, but a pin must flag it
        b.metrics.get_mut("t/a/chunk").unwrap().value = Some(128.0);
        let out = check_report(&r, &b, 5.0).unwrap();
        assert_eq!(out.regressions.len(), 1, "{:?}", out.regressions);
        assert_eq!(out.regressions[0].0, "t/a/chunk");
        // and a drop is flagged too
        b.metrics.get_mut("t/a/chunk").unwrap().value = Some(512.0);
        let out = check_report(&r, &b, 5.0).unwrap();
        assert_eq!(out.regressions.len(), 1);
        // exact match is clean at zero tolerance
        b.metrics.get_mut("t/a/chunk").unwrap().value = Some(256.0);
        assert!(check_report(&r, &b, 0.0).unwrap().ok());
    }

    #[test]
    fn null_baselines_bootstrap_without_failing() {
        let r = report();
        let mut b = Baseline::parse(&r.to_json("ref", 1).to_string())
            .unwrap();
        b.metrics.get_mut("t/a/peak_bytes").unwrap().value = None;
        let out = check_report(&r, &b, 0.0).unwrap();
        assert!(out.ok());
        assert_eq!(out.bootstrap, 1);
        assert_eq!(out.compared, 2);
    }

    #[test]
    fn missing_entries_and_wrong_suites_fail_the_gate() {
        let r = report();
        let mut b = Baseline::parse(&r.to_json("ref", 1).to_string())
            .unwrap();
        // a gated metric absent from the baseline must NOT silently pass
        b.metrics.remove("t/a/peak_bytes");
        let out = check_report(&r, &b, 5.0).unwrap();
        assert!(!out.ok());
        assert_eq!(out.missing, vec!["t/a/peak_bytes".to_string()]);
        assert!(out.regressions.is_empty());
        // a baseline recorded for another suite is an error, not a pass
        b.suite = "other".into();
        assert!(check_report(&r, &b, 5.0).is_err());
    }

    #[test]
    fn baseline_rejects_wrong_schema() {
        assert!(Baseline::parse(
            r#"{"schema":"other/v9","suite":"x","metrics":[]}"#).is_err());
        assert!(Baseline::parse("not json").is_err());
    }

    #[test]
    fn unknown_suite_is_an_error() {
        let engine = Engine::native().unwrap();
        assert!(run_suite(&engine, "warp").is_err());
    }
}
