//! # invertnet
//!
//! Memory-frugal normalizing flows: a rust coordinator over AOT-compiled
//! JAX/Pallas compute — a reproduction of *"InvertibleNetworks.jl: A Julia
//! package for scalable normalizing flows"* (Orozco et al., 2023).
//!
//! The paper's contribution is that invertible networks let you **recompute
//! activations from layer inverses during backprop** instead of taping them,
//! making peak training memory O(1) in network depth — something generic
//! autodiff frameworks do not exploit. Here that contribution lives in
//! [`coordinator`]: the invertible executor holds only the current
//! activation while walking hand-written per-layer backward programs, while
//! the stored executor reproduces the PyTorch/normflows baseline by taping
//! every activation. Both run the *same* XLA executables; the only
//! difference is buffer lifetime, which the
//! [`coordinator::memory::MemoryLedger`] measures exactly.
//!
//! Layers of the stack:
//!  * L1 — Pallas kernels (`python/compile/kernels/`), compile-time only.
//!  * L2 — JAX layer entries with hand-written gradients
//!    (`python/compile/layers/`), lowered to HLO text by `make artifacts`.
//!  * L3 — this crate: PJRT runtime, flow graphs, executors, trainer, CLI.

pub mod bench_figs;
pub mod coordinator;
pub mod data;
pub mod flow;
pub mod profile;
pub mod runtime;
pub mod tensor;
pub mod train;
pub mod util;

pub use coordinator::memory::{MemClass, MemoryLedger};
pub use runtime::Runtime;
pub use tensor::Tensor;
