//! # invertnet
//!
//! Memory-frugal normalizing flows — a reproduction of
//! *"InvertibleNetworks.jl: A Julia package for scalable normalizing
//! flows"* (Orozco et al., 2023).
//!
//! The paper's contribution is that invertible networks let you
//! **recompute activations from layer inverses during backprop** instead
//! of taping them, making peak training memory O(1) in network depth —
//! something generic autodiff frameworks do not exploit. Here that
//! contribution lives in [`coordinator`]: an
//! [`coordinator::ActivationSchedule`] decides which layer inputs stay
//! alive; the invertible schedule holds only the current activation while
//! walking hand-written per-layer backward programs, the stored schedule
//! reproduces the PyTorch/normflows tape, and hybrids
//! ([`coordinator::CheckpointEveryK`]) plug in through the same trait. All
//! schedules run the *same* layer programs; the only difference is buffer
//! lifetime, which the [`coordinator::memory::MemoryLedger`] measures
//! exactly.
//!
//! ## Layers of the stack
//!
//! * [`backend`] — the [`backend::Backend`] trait owns program execution.
//!   [`backend::RefBackend`] (default) implements every layer's
//!   forward/inverse/backward natively in Rust, so the crate builds, runs
//!   and tests with **zero external artifacts**. `XlaBackend`
//!   (`--features xla`) executes AOT-compiled HLO from
//!   `python -m compile.aot` over PJRT.
//! * [`runtime`] — the typed layer/network [`runtime::Manifest`], sourced
//!   from the builtin catalog ([`runtime::builtin_manifest`]) or from
//!   `artifacts/manifest.json`.
//! * [`api`] — the [`api::Engine`] facade: `Engine::builder().build()?`
//!   then [`api::Engine::flow`] returns an owned, `Send`
//!   [`api::Flow`] handle exposing `train_step` / `forward` / `sample` /
//!   `inspect`.
//! * [`coordinator`] — schedules, the byte-exact memory ledger, and the
//!   shape-only planner behind the paper's Figs. 1–2.
//! * [`analysis`] — `flowcheck`: the static flow verifier (shape/split/
//!   cond propagation + invertibility audit, structured
//!   [`analysis::Diagnostic`]s) and the exact memory planner
//!   ([`analysis::predict_peak`], pinned `predicted == measured` against
//!   the ledger). Gated in `Engine::build`, the serve registry's
//!   checkpoint loads, and the `invertnet lint` CLI verb.
//! * [`train`], [`data`], [`profile`], [`bench_figs`] — training loop,
//!   the data-parallel [`train::ParallelTrainer`] (`--threads N` on the
//!   CLI), synthetic workloads, per-entry profiler, figure reproductions.
//!   The same `--threads` pool drives the **threaded inference hot
//!   path**: large relaxed-batch [`api::Flow::sample`] /
//!   [`api::Flow::log_density`] / [`api::Flow::invert`] calls chunk
//!   across forked handles, bit-identically to the single-threaded walk.
//! * [`perf`] — the unified performance harness: the bench suites
//!   (memory, throughput, serve latency, posterior end-to-end) as
//!   library code, one `BENCH_<suite>.json` schema with an environment
//!   block, and the committed-baseline regression gate behind
//!   `invertnet bench --suite ... --check` (see BENCHMARKS.md).
//! * [`serve`] — the batched inference-serving subsystem: a checkpoint
//!   [`serve::Registry`] (LRU model cache), a micro-batching scheduler
//!   that coalesces concurrent `sample`/`score`/`posterior` requests into
//!   one batched pass (bit-identical to direct [`api::Flow::sample`]
//!   / [`api::Flow::log_density`] calls), and JSON-lines TCP/stdio fronts
//!   (`invertnet serve`, `invertnet score`).
//! * [`telemetry`] — the observability spine: a lock-sharded metrics
//!   registry (relaxed-atomic counters/gauges/log2-bucket histograms),
//!   RAII [`span!`](crate::span) timers with optional Chrome
//!   `trace_event` export (`--trace FILE`, finalized on every exit
//!   path), a leveled JSON-lines event log with a flight-recorder ring
//!   ([`telemetry::events`], `--log-json`), and a Prometheus
//!   text-exposition encoder behind the serve `metrics` op, a plain
//!   `GET` TCP scrape (`/metrics`, `/healthz`, `/readyz`),
//!   `--metrics-out FILE`, `invertnet metrics`, and the `invertnet top`
//!   live operator view. Serve requests are trace-scoped end to end
//!   (client `trace_id` echo, per-phase timing histograms).
//! * [`posterior`] — amortized Bayesian inference: a simulator catalog of
//!   synthetic inverse problems ([`posterior::Simulator`]), the amortized
//!   training driver ([`posterior::amortized_train`]), posterior
//!   sampling + uncertainty maps, and calibration diagnostics (SBC rank
//!   statistics, credible-interval coverage) validated against the
//!   closed-form [`data::LinearGaussian`] posterior (`invertnet
//!   posterior-train`, `posterior-sample`, `calibrate`).
//!
//! ## Quickstart
//!
//! ```
//! use invertnet::api::Engine;
//! use invertnet::coordinator::ExecMode;
//! use invertnet::data::Density2d;
//! use invertnet::train::ParallelTrainer;
//! use invertnet::util::rng::Pcg64;
//!
//! # fn main() -> anyhow::Result<()> {
//! // Hermetic default: builtin network catalog + pure-Rust RefBackend.
//! let engine = Engine::builder().build()?;
//! let flow = engine.flow("realnvp2d")?;
//! let params = flow.init_params(42)?;
//!
//! let mut rng = Pcg64::new(7);
//! let x = Density2d::TwoMoons.sample(flow.batch(), &mut rng);
//!
//! // One NLL training step under the paper's O(1)-memory schedule ...
//! let inv = flow.train_step(&x, None, &params, &ExecMode::Invertible)?;
//! // ... and under the autodiff-style tape, for the memory comparison.
//! let sto = flow.train_step(&x, None, &params, &ExecMode::Stored)?;
//!
//! assert!(inv.loss.is_finite());
//! assert!(inv.peak_sched_bytes < sto.peak_sched_bytes);
//!
//! // Scale out: shard the batch over 2 worker threads (`--threads 2` on
//! // the CLI). The reduction is deterministic, so the loss and gradients
//! // match the single-threaded step to f32 reassociation error.
//! let par = ParallelTrainer::new(2)
//!     .train_step(&flow, &x, None, &params, &ExecMode::Invertible)?;
//! assert!((par.loss - inv.loss).abs() <= 1e-4 * inv.loss.abs().max(1.0));
//! # Ok(())
//! # }
//! ```

// The crate is unsafe-free except for one audited FFI shim in the
// feature-gated XLA backend (`backend::xla::to_literal`, `#[allow]`ed
// there); without that feature the ban is total.
#![deny(unsafe_code)]
#![cfg_attr(not(feature = "xla"), forbid(unsafe_code))]

pub mod analysis;
pub mod api;
pub mod app;
pub mod backend;
pub mod bench_figs;
pub mod coordinator;
pub mod data;
pub mod flow;
pub mod perf;
pub mod posterior;
pub mod profile;
pub mod runtime;
pub mod serve;
pub mod telemetry;
pub mod tensor;
pub mod train;
pub mod util;

pub use api::{Engine, EngineConfig, Flow};
pub use backend::{Backend, RefBackend, WeightDtype};
pub use coordinator::executor::{BatchMode, InferOpts, SampleOpts};
pub use coordinator::memory::{MemClass, MemoryLedger};
pub use tensor::Tensor;
