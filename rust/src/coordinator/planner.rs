//! Shape-only memory planning entry point for the coordinator.
//!
//! The actual replay now lives in [`crate::analysis::predict_peak`],
//! which simulates the executor's alloc/free order for *any*
//! [`ActivationSchedule`](super::ActivationSchedule) (invertible /
//! stored / checkpoint-every-K) — this module keeps the historical
//! `ExecMode`-typed entry point plus [`glow_flat_shape_def`], the
//! synthetic GLOW program the Fig. 1 bench uses to extend the measured
//! sweep to the paper's full 1024x1024 range. `tests/memory_model.rs`
//! and `tests/analysis.rs` pin the prediction to the real
//! [`MemoryLedger`](super::MemoryLedger) measurements byte-for-byte on
//! executable configs, so extrapolated rows carry the measured rows'
//! semantics.

use crate::flow::{NetworkDef, Step, StepKind};

use super::executor::ExecMode;

/// Predicted peak scheduling bytes (activations+gradients+latents) for
/// one `train_step` of the given mode.
pub fn predict_peak_sched(def: &NetworkDef, mode: ExecMode) -> i64 {
    crate::analysis::predict_peak(def, &mode)
}

/// Build a shape-only GLOW definition matching `model.glow_flat` in
/// python (Haar squeeze then K x [actnorm, conv1x1, coupling]) — used to
/// extrapolate Fig. 1 beyond the compiled artifact sizes.
pub fn glow_flat_shape_def(n: usize, h: usize, w: usize, c_in: usize, k: usize) -> NetworkDef {
    let mut steps = Vec::new();
    let sq = vec![n, h / 2, w / 2, 4 * c_in];
    steps.push(Step {
        kind: StepKind::Layer,
        sig: "haar(model)".into(),
        in_shape: vec![n, h, w, c_in],
        out_shape: sq.clone(),
    });
    for i in 0..k {
        for kind in ["actnorm", "conv1x1", "glowcpl"] {
            steps.push(Step {
                kind: StepKind::Layer,
                sig: format!("{kind}(model)[{i}]"),
                in_shape: sq.clone(),
                out_shape: sq.clone(),
            });
        }
    }
    NetworkDef {
        name: format!("glow_model_{h}x{w}_d{k}"),
        in_shape: vec![n, h, w, c_in],
        cond_shape: None,
        steps,
        latent_shapes: vec![sq],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invertible_peak_constant_in_depth() {
        let p4 = predict_peak_sched(&glow_flat_shape_def(8, 64, 64, 3, 4),
                                    ExecMode::Invertible);
        let p64 = predict_peak_sched(&glow_flat_shape_def(8, 64, 64, 3, 64),
                                     ExecMode::Invertible);
        assert_eq!(p4, p64, "invertible peak must not depend on depth");
    }

    #[test]
    fn stored_peak_linear_in_depth() {
        let d = |k| predict_peak_sched(&glow_flat_shape_def(8, 64, 64, 3, k),
                                       ExecMode::Stored);
        let (p8, p16, p32) = (d(8), d(16), d(32));
        let slope1 = p16 - p8;
        let slope2 = p32 - p16;
        assert!(slope1 > 0);
        // linear: equal increments per depth doubling of the same size
        assert_eq!(slope2, 2 * slope1);
    }

    #[test]
    fn stored_above_invertible() {
        let def = glow_flat_shape_def(8, 128, 128, 3, 16);
        let inv = predict_peak_sched(&def, ExecMode::Invertible);
        let sto = predict_peak_sched(&def, ExecMode::Stored);
        assert!(sto > 3 * inv, "stored {sto} should dwarf invertible {inv}");
    }

    #[test]
    fn peak_scales_quadratically_in_spatial_size() {
        let p = |hw| predict_peak_sched(&glow_flat_shape_def(8, hw, hw, 3, 16),
                                        ExecMode::Stored);
        let (a, b) = (p(64), p(128));
        assert_eq!(b, 4 * a);
    }
}
