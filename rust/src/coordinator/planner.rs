//! Shape-only memory planner: replays the exact alloc/free schedule each
//! executor performs, without executing any compute.
//!
//! Used by the Fig. 1 bench to extend the measured sweep to the paper's
//! full 1024x1024 range (where artifacts would be impractically slow to
//! execute on the CPU test substrate) and to locate OOM crossovers under a
//! budget. `tests/memory_model.rs` pins the planner to the real
//! [`MemoryLedger`] measurements byte-for-byte on executable configs, so
//! the extrapolated rows carry the measured rows' semantics.

use crate::flow::{NetworkDef, Step, StepKind};

use super::executor::ExecMode;

const F32: usize = 4;

fn bytes_of(shape: &[usize]) -> usize {
    shape.iter().product::<usize>() * F32
}

/// Tracks live/peak while replaying the executor schedule.
struct Sim {
    live: i64,
    peak: i64,
}

impl Sim {
    fn new() -> Sim {
        Sim { live: 0, peak: 0 }
    }

    fn alloc(&mut self, shape: &[usize]) {
        self.live += bytes_of(shape) as i64;
        self.peak = self.peak.max(self.live);
    }

    fn free(&mut self, shape: &[usize]) {
        self.live -= bytes_of(shape) as i64;
    }
}

fn z_shape(step: &Step, zc: usize) -> Vec<usize> {
    let mut z = step.in_shape.clone();
    *z.last_mut().unwrap() = zc;
    z
}

/// Predicted peak scheduling bytes (activations+gradients+latents) for one
/// `train_step` of the given mode — mirrors `executor.rs` line by line.
pub fn predict_peak_sched(def: &NetworkDef, mode: ExecMode) -> i64 {
    let mut sim = Sim::new();
    let tape = mode == ExecMode::Stored;

    // ---- forward ----------------------------------------------------------
    // cur = track(x)
    sim.alloc(&def.in_shape);
    // latents pushed in order; in stored mode, taped inputs stay alive
    let mut latent_shapes: Vec<Vec<usize>> = Vec::new();
    for step in &def.steps {
        match step.kind {
            StepKind::Split { zc } => {
                let z = z_shape(step, zc);
                sim.alloc(&z); // latents.push(track(z))
                sim.alloc(&step.out_shape); // next = track(h)
                sim.free(&step.in_shape); // cur dropped
                latent_shapes.push(z);
            }
            StepKind::Layer => {
                sim.alloc(&step.out_shape); // next = track(y)
                if !tape {
                    sim.free(&step.in_shape); // cur dropped (invertible)
                }
                // stored: cur moves into the tape, stays alive
            }
        }
    }
    let final_shape = def.steps.last().map(|s| s.out_shape.clone())
        .unwrap_or_else(|| def.in_shape.clone());
    // z_final = track(cur.into_inner()): free + alloc same bytes (no-op for peak)
    latent_shapes.push(final_shape.clone());

    // ---- backward seeds ----------------------------------------------------
    // dy = track(dz_final)
    sim.alloc(&final_shape);

    // y starts as z_final (already counted); tape entries already counted.
    let mut first_layer_seen = false;
    for step in def.steps.iter().rev() {
        match step.kind {
            StepKind::Split { zc } => {
                let z = z_shape(step, zc);
                // new_dy = track(concat(dz, dy)) ; then old dy freed
                sim.alloc(&step.in_shape);
                sim.free(&step.out_shape);
                // y = track(concat(z, y)) ; old y freed; z (latent) freed
                sim.alloc(&step.in_shape);
                sim.free(&step.out_shape);
                sim.free(&z);
                latent_shapes.pop();
            }
            StepKind::Layer => {
                match mode {
                    ExecMode::Invertible => {
                        // alloc dx; free dy_old; alloc x_rec; free y_old
                        sim.alloc(&step.in_shape);
                        sim.free(&step.out_shape);
                        sim.alloc(&step.in_shape);
                        sim.free(&step.out_shape);
                    }
                    ExecMode::Stored => {
                        // tape entry consumed (freed at end of the arm),
                        // new dy allocated, old dy freed; on the FIRST
                        // layer in reverse order, y (z_final latent ref...)
                        // is set to None — but z_final is a latent that was
                        // popped; it is dropped when `y` is overwritten.
                        sim.free(&step.in_shape); // xin dropped after exec
                        sim.alloc(&step.in_shape); // new_dy = track(dx)
                        sim.free(&step.out_shape); // old dy freed
                        if !first_layer_seen {
                            // y = None drops the z_final Tracked
                            sim.free(&final_shape);
                            first_layer_seen = true;
                        }
                    }
                }
            }
        }
    }
    sim.peak
}

/// Build a shape-only GLOW definition matching `model.glow_flat` in
/// python (Haar squeeze then K x [actnorm, conv1x1, coupling]) — used to
/// extrapolate Fig. 1 beyond the compiled artifact sizes.
pub fn glow_flat_shape_def(n: usize, h: usize, w: usize, c_in: usize, k: usize) -> NetworkDef {
    let mut steps = Vec::new();
    let sq = vec![n, h / 2, w / 2, 4 * c_in];
    steps.push(Step {
        kind: StepKind::Layer,
        sig: "haar(model)".into(),
        in_shape: vec![n, h, w, c_in],
        out_shape: sq.clone(),
    });
    for i in 0..k {
        for kind in ["actnorm", "conv1x1", "glowcpl"] {
            steps.push(Step {
                kind: StepKind::Layer,
                sig: format!("{kind}(model)[{i}]"),
                in_shape: sq.clone(),
                out_shape: sq.clone(),
            });
        }
    }
    NetworkDef {
        name: format!("glow_model_{h}x{w}_d{k}"),
        in_shape: vec![n, h, w, c_in],
        cond_shape: None,
        steps,
        latent_shapes: vec![sq],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn invertible_peak_constant_in_depth() {
        let p4 = predict_peak_sched(&glow_flat_shape_def(8, 64, 64, 3, 4),
                                    ExecMode::Invertible);
        let p64 = predict_peak_sched(&glow_flat_shape_def(8, 64, 64, 3, 64),
                                     ExecMode::Invertible);
        assert_eq!(p4, p64, "invertible peak must not depend on depth");
    }

    #[test]
    fn stored_peak_linear_in_depth() {
        let d = |k| predict_peak_sched(&glow_flat_shape_def(8, 64, 64, 3, k),
                                       ExecMode::Stored);
        let (p8, p16, p32) = (d(8), d(16), d(32));
        let slope1 = p16 - p8;
        let slope2 = p32 - p16;
        assert!(slope1 > 0);
        // linear: equal increments per depth doubling of the same size
        assert_eq!(slope2, 2 * slope1);
    }

    #[test]
    fn stored_above_invertible() {
        let def = glow_flat_shape_def(8, 128, 128, 3, 16);
        let inv = predict_peak_sched(&def, ExecMode::Invertible);
        let sto = predict_peak_sched(&def, ExecMode::Stored);
        assert!(sto > 3 * inv, "stored {sto} should dwarf invertible {inv}");
    }

    #[test]
    fn peak_scales_quadratically_in_spatial_size() {
        let p = |hw| predict_peak_sched(&glow_flat_shape_def(8, hw, hw, 3, 16),
                                        ExecMode::Stored);
        let (a, b) = (p(64), p(128));
        assert_eq!(b, 4 * a);
    }
}
