//! L3 coordinator — the paper's system contribution.
//!
//! * [`executor`]: the schedule-driven training-step walk (methods on
//!   [`crate::api::Flow`]) plus the [`ActivationSchedule`] trait with the
//!   invertible / stored / checkpoint-hybrid schedules.
//! * [`memory`]: the live/peak byte ledger + budgeted (OOM-simulating)
//!   allocation every schedule runs under.
//! * [`planner`]: shape-only replay of the two canonical schedules for
//!   extrapolating the paper's figures beyond executable sizes.

pub mod executor;
pub mod memory;
pub mod planner;

pub use executor::{ActivationSchedule, BatchMode, CheckpointEveryK, ExecMode,
                   InferOpts, SampleOpts, StepResult};
pub use memory::{MemClass, MemoryLedger, Tracked};
