//! L3 coordinator — the paper's system contribution.
//!
//! * [`executor`]: the invertible (recompute-from-inverse) and stored
//!   (autodiff-tape baseline) training-step schedulers.
//! * [`memory`]: the live/peak byte ledger + budgeted (OOM-simulating)
//!   allocation both schedulers run under.

pub mod executor;
pub mod memory;
pub mod planner;

pub use executor::{ExecMode, FlowSession, StepResult};
pub use memory::{MemClass, MemoryLedger, Tracked};
