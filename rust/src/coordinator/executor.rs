//! The training-step schedulers — the system this paper is about.
//!
//! Which activations stay alive is decided by an [`ActivationSchedule`]:
//!
//! * [`ExecMode::Invertible`] (InvertibleNetworks.jl's contribution): the
//!   forward pass keeps **only the current activation**; the backward pass
//!   calls each layer's hand-written `backward` program, which *recomputes*
//!   the layer input from its output via the inverse. Peak scheduling
//!   memory is O(1) in depth.
//! * [`ExecMode::Stored`] (the PyTorch/normflows baseline, built here so
//!   the comparison is like-for-like): the forward pass tapes every layer
//!   input and the backward pass calls `backward_stored`. Peak memory is
//!   O(depth).
//! * Anything in between plugs in through the trait — e.g.
//!   [`CheckpointEveryK`] tapes every k-th layer and recomputes the rest.
//!
//! All schedules execute the *same* backend programs with identical math
//! (integration-tested to produce equal losses and gradients); the only
//! difference is buffer lifetime, which the
//! [`super::memory::MemoryLedger`] records.
//!
//! The algorithms are methods on [`crate::api::Flow`] (the owned handle
//! constructed by `Engine::flow`).

use anyhow::{anyhow, bail, Context, Result};

use crate::api::Flow;
use crate::flow::{ParamStore, StepKind};
use crate::tensor::ops::{add_assign, concat_last_axis, concat_rows,
                         slice_rows, split_last_axis};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

use super::memory::{MemClass, Tracked};

/// Decides, per layer step, whether the forward pass retains (tapes) that
/// step's input for the backward pass. Taped steps run `backward_stored`;
/// untaped steps run `backward`, which recomputes the input from the
/// inverse.
pub trait ActivationSchedule: Send + Sync {
    /// Human-readable name for logs/CSV.
    fn label(&self) -> String;

    /// Should the `layer_idx`-th *layer* (0-based ordinal among the
    /// network's `n_layers` layer steps; coordinator-native splits don't
    /// count) tape its input?
    fn tape(&self, layer_idx: usize, n_layers: usize) -> bool;
}

/// The two canonical schedules from the paper's comparison.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Recompute activations from inverses (the paper's method).
    Invertible,
    /// Tape activations like an autodiff framework (normflows baseline).
    Stored,
}

impl ExecMode {
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Invertible => "invertible",
            ExecMode::Stored => "stored",
        }
    }
}

impl ActivationSchedule for ExecMode {
    fn label(&self) -> String {
        self.name().to_string()
    }

    fn tape(&self, _layer_idx: usize, _n_layers: usize) -> bool {
        matches!(self, ExecMode::Stored)
    }
}

/// Hybrid schedule: tape every k-th layer input, recompute the rest from
/// inverses — the classic checkpointing trade dropped into the invertible
/// walk. `CheckpointEveryK(1)` is `Stored`; `k > depth` tapes only the
/// first layer.
#[derive(Debug, Clone, Copy)]
pub struct CheckpointEveryK(pub usize);

impl ActivationSchedule for CheckpointEveryK {
    fn label(&self) -> String {
        format!("checkpoint_every_{}", self.0.max(1))
    }

    fn tape(&self, layer_idx: usize, _n_layers: usize) -> bool {
        layer_idx % self.0.max(1) == 0
    }
}

/// Leading-dim policy for the unified inference entry points
/// ([`Flow::log_density`], [`Flow::invert`], [`Flow::sample`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BatchMode {
    /// The input batch must equal the network's canonical batch size;
    /// shape bugs fail loudly. The default.
    #[default]
    Strict,
    /// Any leading batch `n >= 1` (per-sample dims still validated).
    /// Batches larger than [`Flow::infer_chunk`] chunk across the
    /// inference worker pool, bit-identically to the one-pass walk.
    Relaxed,
}

/// Options for [`Flow::log_density`] and [`Flow::invert`]: batch policy,
/// conditioning input, and an optional per-call worker-count override.
/// `InferOpts::default()` is strict, unconditioned, engine-default threads.
#[derive(Default)]
pub struct InferOpts<'a> {
    pub batch: BatchMode,
    pub cond: Option<&'a Tensor>,
    /// Replaces the flow's worker count for this call only (clamped >= 1).
    pub threads_override: Option<usize>,
}

impl<'a> InferOpts<'a> {
    /// Strict canonical-batch options (same as `default()`).
    pub fn strict() -> Self {
        InferOpts::default()
    }

    /// Relaxed-batch options (the serving / large-batch path).
    pub fn relaxed() -> Self {
        InferOpts { batch: BatchMode::Relaxed, ..InferOpts::default() }
    }

    /// Attach a conditioning tensor.
    pub fn cond(mut self, c: &'a Tensor) -> Self {
        self.cond = Some(c);
        self
    }

    /// Attach an optional conditioning tensor (for call sites that carry
    /// an `Option` already).
    pub fn cond_opt(mut self, c: Option<&'a Tensor>) -> Self {
        self.cond = c;
        self
    }

    /// Override the inference worker count for this call.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads_override = Some(n.max(1));
        self
    }
}

/// Options for [`Flow::sample`]: sample count, conditioning, latent
/// temperature, the rng to draw from, and an optional worker override.
/// Construct with [`SampleOpts::new`] and chain the setters.
pub struct SampleOpts<'a> {
    /// Number of samples (any `n >= 1`, decoupled from the canonical
    /// batch).
    pub n: usize,
    pub cond: Option<&'a Tensor>,
    /// Latent temperature: z ~ t * N(0, I). `t < 1` samples a sharpened,
    /// higher-likelihood region (the standard reduced-temperature trick);
    /// `t = 1.0` is exact model sampling.
    pub temperature: f32,
    pub rng: &'a mut Pcg64,
    /// Replaces the flow's worker count for this call only (clamped >= 1).
    pub threads_override: Option<usize>,
}

impl<'a> SampleOpts<'a> {
    /// `n` samples at temperature 1.0, unconditioned.
    pub fn new(n: usize, rng: &'a mut Pcg64) -> Self {
        SampleOpts { n, cond: None, temperature: 1.0, rng,
                     threads_override: None }
    }

    /// Attach a conditioning tensor.
    pub fn cond(mut self, c: &'a Tensor) -> Self {
        self.cond = Some(c);
        self
    }

    /// Attach an optional conditioning tensor.
    pub fn cond_opt(mut self, c: Option<&'a Tensor>) -> Self {
        self.cond = c;
        self
    }

    /// Set the latent temperature.
    pub fn temperature(mut self, t: f32) -> Self {
        self.temperature = t;
        self
    }

    /// Override the inference worker count for this call.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads_override = Some(n.max(1));
        self
    }
}

/// Result of one training step.
pub struct StepResult {
    pub loss: f32,
    pub logp_mean: f32,
    pub logdet_mean: f32,
    /// Per-step parameter gradients, aligned with `ParamStore`.
    pub grads: Vec<Vec<Tensor>>,
    /// Gradient w.r.t. the conditioning input (conditional nets only).
    pub dcond: Option<Tensor>,
    /// Peak activation+gradient+latent bytes during this step.
    pub peak_sched_bytes: i64,
    pub peak_total_bytes: i64,
}

impl Flow {
    fn track(&self, t: Tensor, class: MemClass) -> Result<Tracked> {
        Tracked::new(t, class, &self.ledger)
    }

    /// Execute a layer-step entry through the backend. The conditioning
    /// tensor is forwarded only if this step's layer takes one.
    fn exec_step(
        &self,
        step_idx: usize,
        entry: &str,
        acts: &[&Tensor],
        cond: Option<&Tensor>,
        params: &ParamStore,
    ) -> Result<Vec<Tensor>> {
        let sig = &self.def.steps[step_idx].sig;
        let meta = self.manifest.layer(sig)?;
        let c = if meta.cond_shape.is_some() { cond } else { None };
        self.backend
            .execute_layer(meta, entry, acts, c, &params.tensors[step_idx])
            .with_context(|| format!("executing {sig}.{entry}"))
    }

    fn head_t(&self, entry: &str, z: &Tensor) -> Result<Vec<Tensor>> {
        self.backend
            .execute_head(entry, z)
            .with_context(|| format!("head {entry} for {:?}", z.shape))
    }

    /// Validate the conditioning input. `batch` is the leading dim of the
    /// current input batch; with `relax_batch` (the data-parallel shard
    /// path) the cond batch must match it but may differ from the
    /// network's canonical batch size. `pub(crate)` so the parallel
    /// trainer's up-front validation uses the exact same predicate the
    /// per-shard walk applies.
    pub(crate) fn check_cond<'a>(&self, cond: Option<&'a Tensor>, batch: usize,
                                 relax_batch: bool) -> Result<Option<&'a Tensor>> {
        match (cond, &self.def.cond_shape) {
            (Some(c), Some(shape)) => {
                let ok = if relax_batch {
                    c.shape.len() == shape.len()
                        && c.shape[1..] == shape[1..]
                        && c.shape.first() == Some(&batch)
                } else {
                    &c.shape == shape
                };
                if !ok {
                    bail!("cond shape {:?} != network cond {:?} (batch {batch})",
                          c.shape, shape);
                }
                Ok(Some(c))
            }
            (None, None) => Ok(None),
            (Some(_), None) => bail!("network {} takes no cond", self.def.name),
            (None, Some(_)) => bail!("network {} requires cond", self.def.name),
        }
    }

    /// After consuming a taped input at step `i`, is an activation still
    /// needed for an earlier step? True iff the nearest preceding *layer*
    /// step is untaped (splits only reshape the activation on the way).
    fn y_needed_before(&self, i: usize, taped: &[bool]) -> bool {
        for j in (0..i).rev() {
            match self.def.steps[j].kind {
                StepKind::Layer => return !taped[j],
                StepKind::Split { .. } => continue,
            }
        }
        false
    }

    // ------------------------------------------------------------------
    // Forward
    // ------------------------------------------------------------------

    /// Forward pass under a schedule: taped steps additionally retain
    /// their input. Returns (latents in push order, per-sample logdet
    /// totals, tape aligned with steps).
    #[allow(clippy::type_complexity)]
    fn forward_with(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
        schedule: &dyn ActivationSchedule,
        relax_batch: bool,
    ) -> Result<(Vec<Tracked>, Vec<f32>, Vec<Option<Tracked>>)> {
        let shape_ok = if relax_batch {
            // data-parallel shards: any non-empty leading batch, same
            // per-sample dims (every layer program is batch-agnostic)
            x.shape.len() == self.def.in_shape.len()
                && x.shape.first().is_some_and(|&n| n > 0)
                && x.shape[1..] == self.def.in_shape[1..]
        } else {
            x.shape == self.def.in_shape
        };
        if !shape_ok {
            bail!("input shape {:?} != network {:?}", x.shape, self.def.in_shape);
        }
        let n = x.shape[0];
        let cond = self.check_cond(cond, n, relax_batch)?;
        let n_layers = self.def.depth();
        let mut layer_ord = 0usize;
        let mut ld_total = vec![0.0f32; n];
        let mut latents: Vec<Tracked> = Vec::new();
        let mut tape_store: Vec<Option<Tracked>> = Vec::new();
        let mut cur = self.track(x.clone(), MemClass::Activation)?;

        for (i, step) in self.def.steps.iter().enumerate() {
            match step.kind {
                StepKind::Split { zc } => {
                    let (z, h) = split_last_axis(cur.tensor(), zc)?;
                    latents.push(self.track(z, MemClass::Latent)?);
                    let next = self.track(h, MemClass::Activation)?;
                    cur = next; // old `cur` dropped here
                    tape_store.push(None);
                }
                StepKind::Layer => {
                    let outs = self.exec_step(i, "forward",
                                              &[cur.tensor()], cond, params)?;
                    let [y, logdet]: [Tensor; 2] = outs
                        .try_into()
                        .map_err(|_| anyhow!("forward arity"))?;
                    for (acc, v) in ld_total.iter_mut().zip(&logdet.data) {
                        *acc += v;
                    }
                    let next = self.track(y, MemClass::Activation)?;
                    if schedule.tape(layer_ord, n_layers) {
                        tape_store.push(Some(cur));
                    } else {
                        tape_store.push(None);
                        // `cur` dropped: recompute schedules keep nothing
                    }
                    layer_ord += 1;
                    cur = next;
                }
            }
        }
        // final activation is the last latent
        let z_final = self.track(cur.into_inner(), MemClass::Latent)?;
        latents.push(z_final);
        Ok((latents, ld_total, tape_store))
    }

    /// Tape-free forward pass (sampling/eval path): returns the latents in
    /// push order and the per-sample logdet totals.
    pub fn forward(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
    ) -> Result<(Vec<Tracked>, Vec<f32>)> {
        let (latents, ld, _) =
            self.forward_with(x, cond, params, &ExecMode::Invertible, false)?;
        Ok((latents, ld))
    }

    /// Per-sample log density `log p(x) = sum_latents log N(z) + logdet`
    /// under the options' batch policy. [`BatchMode::Strict`] (the
    /// default) demands the network's canonical batch so shape bugs fail
    /// loudly; [`BatchMode::Relaxed`] is the serving / OOD-scoring
    /// workload and accepts any leading size (per-sample dims must still
    /// match). Every layer program is batch-elementwise, so scoring a
    /// concatenated relaxed batch equals concatenating per-item scores
    /// bit-exactly (pinned in `tests/serve.rs`); relaxed batches larger
    /// than [`Flow::infer_chunk`] chunk across the inference worker pool
    /// when the flow carries more than one thread
    /// ([`crate::api::EngineBuilder::threads`]), bit-identically.
    pub fn log_density(
        &self,
        x: &Tensor,
        params: &ParamStore,
        opts: InferOpts,
    ) -> Result<Vec<f32>> {
        let relax = opts.batch == BatchMode::Relaxed;
        match opts.threads_override {
            Some(t) if t.max(1) != self.threads => self
                .clone()
                .with_threads(t)
                .log_density_flex(x, opts.cond, params, relax),
            _ => self.log_density_flex(x, opts.cond, params, relax),
        }
    }

    /// Per-sample log-likelihood at the canonical batch size.
    #[deprecated(note = "use `log_density(x, params, InferOpts::strict()\
.cond_opt(cond))`")]
    pub fn log_likelihood(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
    ) -> Result<Vec<f32>> {
        self.log_density(x, params, InferOpts::strict().cond_opt(cond))
    }

    fn log_density_flex(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
        relax_batch: bool,
    ) -> Result<Vec<f32>> {
        let n = x.shape.first().copied().unwrap_or(0);
        // Threaded hot path: chunk rows across the worker pool. Engaged
        // only when the inputs would validate on the relaxed walk — bad
        // shapes fall through to the serial path so its error messages
        // stay authoritative. Every layer program is batch-elementwise, so
        // chunked scores are bit-identical to the one-pass walk.
        if self.infer_engaged(n, relax_batch)
            && x.shape.len() == self.def.in_shape.len()
            && x.shape[1..] == self.def.in_shape[1..]
            && self.check_cond(cond, n, true).is_ok()
        {
            let parts = self.infer_parallel(n, |f, lo, len| {
                let xs = slice_rows(x, lo, len)?;
                let cs = cond.map(|c| slice_rows(c, lo, len)).transpose()?;
                f.log_density_serial(&xs, cs.as_ref(), params, true)
            })?;
            return Ok(parts.into_iter().flatten().collect());
        }
        self.log_density_serial(x, cond, params, relax_batch)
    }

    /// The single-pass log-density walk (one forward, no chunking).
    fn log_density_serial(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
        relax_batch: bool,
    ) -> Result<Vec<f32>> {
        let (latents, ld, _) =
            self.forward_with(x, cond, params, &ExecMode::Invertible,
                              relax_batch)?;
        let mut out = ld;
        for z in &latents {
            let lp = &self.head_t("gaussian_logp", z.tensor())?[0];
            for (acc, v) in out.iter_mut().zip(&lp.data) {
                *acc += v;
            }
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // Threaded inference hot path
    // ------------------------------------------------------------------

    /// Fixed row-chunk size for the threaded inference paths: the
    /// network's canonical batch. A *fixed* chunk (never derived from the
    /// thread count) is what makes results bit-identical at any thread
    /// count — and since every layer program is batch-elementwise, chunked
    /// results are additionally bit-identical to the unchunked walk
    /// (pinned in `tests/perf.rs` and `tests/serve.rs`).
    pub fn infer_chunk(&self) -> usize {
        self.batch().max(1)
    }

    /// Should an `n`-row relaxed-batch inference call take the chunked
    /// path? Whenever there is more than one chunk of work: with one
    /// worker the chunks run inline (sequentially), which bounds the
    /// activation envelope to one chunk on arbitrarily large batches;
    /// with more workers they fan out across the pool. Either way the
    /// result is bit-identical to the one-pass walk.
    fn infer_engaged(&self, n: usize, relax_batch: bool) -> bool {
        relax_batch && n > self.infer_chunk()
    }

    /// Run `work` over contiguous row-chunks of an `n`-row batch on a
    /// scoped pool of [`Flow::fork`] handles (same sharding/reduction
    /// shape as `train::ParallelTrainer`): worker `w` of `T` owns chunks
    /// `w, w+T, ...` (static round-robin), and results are returned in
    /// chunk order, so the stitched output never depends on thread
    /// completion order. `work(flow, lo, len)` sees row window
    /// `[lo, lo+len)`.
    fn infer_parallel<T, F>(&self, n: usize, work: F) -> Result<Vec<T>>
    where
        T: Send,
        F: Fn(&Flow, usize, usize) -> Result<T> + Sync,
    {
        let chunk = self.infer_chunk();
        let n_chunks = n.div_ceil(chunk);
        let threads = self.threads.min(n_chunks).max(1);
        if threads == 1 {
            // inline sequential chunking: same walk, no thread overhead
            let mut out = Vec::with_capacity(n_chunks);
            for j in 0..n_chunks {
                let lo = j * chunk;
                let hi = ((j + 1) * chunk).min(n);
                out.push(work(self, lo, hi - lo)?);
            }
            return Ok(out);
        }
        let mut slots: Vec<Option<T>> = Vec::new();
        slots.resize_with(n_chunks, || None);
        let work = &work;
        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::with_capacity(threads);
            for w in 0..threads {
                let worker = self.fork();
                handles.push(scope.spawn(
                    move || -> Result<Vec<(usize, T)>> {
                        let mut done = Vec::new();
                        let mut j = w;
                        while j < n_chunks {
                            let lo = j * chunk;
                            let hi = ((j + 1) * chunk).min(n);
                            done.push((j, work(&worker, lo, hi - lo)?));
                            j += threads;
                        }
                        Ok(done)
                    },
                ));
            }
            // join EVERY handle before reporting any failure (see
            // ParallelTrainer: an early return would let thread::scope
            // re-panic over a clean Err)
            let mut first_err: Option<anyhow::Error> = None;
            for (w, handle) in handles.into_iter().enumerate() {
                match handle.join() {
                    Err(payload) => {
                        let msg = payload.downcast_ref::<&str>()
                            .map(|s| s.to_string())
                            .or_else(|| payload.downcast_ref::<String>()
                                .cloned())
                            .unwrap_or_else(
                                || "non-string panic payload".into());
                        first_err.get_or_insert_with(
                            || anyhow!("inference worker {w} panicked: \
                                        {msg}"));
                    }
                    Ok(Err(e)) => {
                        first_err.get_or_insert(e);
                    }
                    Ok(Ok(results)) => {
                        for (j, r) in results {
                            slots[j] = Some(r);
                        }
                    }
                }
            }
            match first_err {
                Some(e) => Err(e),
                None => Ok(()),
            }
        })?;
        slots.into_iter()
            .enumerate()
            .map(|(j, s)| s.ok_or_else(
                || anyhow!("inference chunk {j} missing (scheduler bug)")))
            .collect()
    }

    // ------------------------------------------------------------------
    // Training step
    // ------------------------------------------------------------------

    /// One full NLL training step (forward + loss + backward) under the
    /// given activation schedule, returning parameter gradients and the
    /// memory peaks observed.
    pub fn train_step(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
        schedule: &dyn ActivationSchedule,
    ) -> Result<StepResult> {
        self.train_step_flex(x, cond, params, schedule, false)
    }

    /// [`Flow::train_step`] with an optional relaxed batch check: the
    /// data-parallel trainer ([`crate::train::ParallelTrainer`]) runs this
    /// on minibatch shards whose leading dim differs from the network's
    /// canonical batch size. `Flow::train_step` itself stays strict so
    /// shape bugs in code using the plain API keep failing loudly;
    /// `ParallelTrainer` documents batch-flexibility as its own contract
    /// (gradient accumulation exists to decouple the effective batch from
    /// the canonical one).
    pub(crate) fn train_step_flex(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
        schedule: &dyn ActivationSchedule,
        relax_batch: bool,
    ) -> Result<StepResult> {
        self.ledger.reset_peaks();
        let n = x.shape.first().copied().unwrap_or(0);
        let cond = self.check_cond(cond, n, relax_batch)?;

        let (mut latents, ld_total, mut tape) =
            self.forward_with(x, cond, params, schedule, relax_batch)?;
        let taped: Vec<bool> = tape.iter().map(|t| t.is_some()).collect();

        // ---- loss -----------------------------------------------------
        let mut logp = vec![0.0f32; n];
        for z in &latents {
            let lp = &self.head_t("gaussian_logp", z.tensor())?[0];
            for (acc, v) in logp.iter_mut().zip(&lp.data) {
                *acc += v;
            }
        }
        let logp_mean = logp.iter().sum::<f32>() / n as f32;
        let logdet_mean = ld_total.iter().sum::<f32>() / n as f32;
        let loss = -(logp_mean + logdet_mean);

        // ---- backward seeds --------------------------------------------
        // dL/dlogdet_n = -1/N for every layer's logdet contribution.
        let dld = Tensor::full(&[n], -1.0 / n as f32);

        let z_final = latents.pop().expect("forward always pushes a latent");
        let seeds = self.head_t("nll_seed", z_final.tensor())?;
        let dz_final = seeds.into_iter().next().expect("nll_seed returns dz");
        let mut dy = self.track(dz_final, MemClass::Gradient)?;

        // The recompute walk needs the current activation; taped steps
        // provide inputs directly. The final latent doubles as the
        // activation we walk back from.
        let mut y: Option<Tracked> = Some(z_final);

        let mut grads: Vec<Vec<Tensor>> = vec![Vec::new(); self.def.steps.len()];
        let mut dcond_acc: Option<Tensor> = None;

        for (i, step) in self.def.steps.iter().enumerate().rev() {
            match step.kind {
                StepKind::Split { zc: _ } => {
                    let z = latents.pop().ok_or_else(
                        || anyhow!("latent stack underflow at step {i}"))?;
                    let seeds = self.head_t("nll_seed", z.tensor())?;
                    let dz = seeds.into_iter().next()
                        .ok_or_else(|| anyhow!("nll_seed returned nothing"))?;
                    let new_dy = self.track(
                        concat_last_axis(&dz, dy.tensor())?, MemClass::Gradient)?;
                    dy = new_dy;
                    if let Some(yt) = y.take() {
                        let joined = concat_last_axis(z.tensor(), yt.tensor())?;
                        y = Some(self.track(joined, MemClass::Activation)?);
                    }
                    // z dropped here (its bytes were Latent class)
                }
                StepKind::Layer => {
                    let meta = self.manifest.layer(&step.sig)?;
                    let has_cond = meta.cond_shape.is_some();
                    let n_params = meta.params.len();
                    let recompute = !taped[i];

                    let results = if recompute {
                        let yt = y.as_ref().ok_or_else(
                            || anyhow!("missing activation at step {i}"))?;
                        self.exec_step(
                            i, "backward",
                            &[dy.tensor(), &dld, yt.tensor()], cond, params)?
                    } else {
                        let xin = tape[i].take().ok_or_else(
                            || anyhow!("missing tape entry at step {i}"))?;
                        // the taped input supersedes any activation a later
                        // recompute step left behind — release it now so
                        // live bytes reflect what backward_stored needs
                        y = None;
                        let results = self.exec_step(
                            i, "backward_stored",
                            &[dy.tensor(), &dld, xin.tensor()], cond, params)?;
                        // Keep the taped input alive as the activation iff
                        // an earlier untaped layer will need it; drop it
                        // otherwise (autodiff frees tape entries as
                        // backward consumes them).
                        if self.y_needed_before(i, &taped) {
                            y = Some(xin);
                        }
                        results
                    };

                    let want = 1 + has_cond as usize + n_params
                        + recompute as usize;
                    if results.len() != want {
                        bail!("{}.backward arity {} != {want}",
                              step.sig, results.len());
                    }
                    let mut it = results.into_iter();
                    let dx = it.next().unwrap();
                    if has_cond {
                        let dc = it.next().unwrap();
                        match &mut dcond_acc {
                            Some(acc) => add_assign(acc, &dc)?,
                            None => dcond_acc = Some(dc),
                        }
                    }
                    let mut dtheta = Vec::with_capacity(n_params);
                    for _ in 0..n_params {
                        dtheta.push(it.next().unwrap());
                    }
                    grads[i] = dtheta;

                    let new_dy = self.track(dx, MemClass::Gradient)?;
                    dy = new_dy;
                    if recompute {
                        let x_rec = it.next().unwrap();
                        y = Some(self.track(x_rec, MemClass::Activation)?);
                    }
                }
            }
        }

        Ok(StepResult {
            loss,
            logp_mean,
            logdet_mean,
            grads,
            dcond: dcond_acc,
            peak_sched_bytes: self.ledger.peak_scheduling(),
            peak_total_bytes: self.ledger.peak_total(),
        })
    }

    // ------------------------------------------------------------------
    // Sampling / inversion
    // ------------------------------------------------------------------

    /// Draw samples from the model: z ~ t * N(0, I) at every latent site,
    /// then walk the inverse chain (paper: "efficient sampling"). The
    /// sample count, conditioning, latent temperature and rng all travel
    /// in [`SampleOpts`]; `n` is decoupled from the canonical batch.
    ///
    /// All latents are drawn from the options' rng up front
    /// (sequentially, so the stream is thread-count-independent); the
    /// inverse walk then rides the threaded chunked path when the flow
    /// has more than one worker thread and `n` exceeds
    /// [`Flow::infer_chunk`] — bit-identical to the single-threaded draw
    /// (pinned in `tests/perf.rs`). Temperature 1.0 multiplies every
    /// latent by 1.0, so it is bit-identical to an untempered draw for
    /// matching `n` and rng state.
    pub fn sample(
        &self,
        params: &ParamStore,
        opts: SampleOpts,
    ) -> Result<Tensor> {
        let SampleOpts { n, cond, temperature, rng, threads_override } = opts;
        let zs = self.sample_latents(n, temperature, rng)?;
        let inv = InferOpts {
            batch: BatchMode::Relaxed,
            cond,
            threads_override,
        };
        self.invert(&zs, params, inv)
    }

    /// Draw `n` samples at temperature `t`.
    #[deprecated(note = "use `sample(params, SampleOpts::new(n, rng)\
.temperature(t).cond_opt(cond))`")]
    pub fn sample_batch(
        &self,
        params: &ParamStore,
        n: usize,
        cond: Option<&Tensor>,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Tensor> {
        self.sample(params,
                    SampleOpts::new(n, rng)
                        .temperature(temperature)
                        .cond_opt(cond))
    }

    /// Draw the latent stack for `n` samples at temperature `t`, in the
    /// same site order [`Flow::invert`] consumes. Exposed so the serving
    /// micro-batcher can draw each request's latents from that request's
    /// own seeded rng, concatenate across requests, and run one batched
    /// inverse whose rows are bit-identical to per-request inversions.
    pub fn sample_latents(
        &self,
        n: usize,
        temperature: f32,
        rng: &mut Pcg64,
    ) -> Result<Vec<Tensor>> {
        if n == 0 {
            bail!("sample_latents needs n >= 1");
        }
        if !temperature.is_finite() || temperature < 0.0 {
            bail!("temperature must be finite and >= 0, got {temperature}");
        }
        Ok(self.def.latent_shapes.iter().map(|s| {
            let mut shape = s.clone();
            shape[0] = n;
            let count = shape.iter().product();
            let mut data = rng.normal_vec(count);
            for v in &mut data {
                *v *= temperature;
            }
            Tensor { shape, data }
        }).collect())
    }

    /// Map latents back to input space (inverse of [`Flow::forward`]'s
    /// latents, in the same push order) under the options' batch policy.
    /// [`BatchMode::Strict`] (the default) demands the canonical batch;
    /// under [`BatchMode::Relaxed`] all latents (and the cond, if any)
    /// must share one leading dim `n >= 1`, which may differ from the
    /// canonical batch. Every layer program is batch-agnostic, so row `i`
    /// of the result depends only on row `i` of each latent — which is
    /// also what lets large relaxed batches chunk across the inference
    /// worker pool ([`crate::api::EngineBuilder::threads`]) without
    /// changing a single bit of the result.
    pub fn invert(
        &self,
        latents: &[Tensor],
        params: &ParamStore,
        opts: InferOpts,
    ) -> Result<Tensor> {
        let relax = opts.batch == BatchMode::Relaxed;
        match opts.threads_override {
            Some(t) if t.max(1) != self.threads => self
                .clone()
                .with_threads(t)
                .invert_impl(latents, opts.cond, params, relax),
            _ => self.invert_impl(latents, opts.cond, params, relax),
        }
    }

    /// Relaxed-batch inversion.
    #[deprecated(note = "use `invert(latents, params, InferOpts::relaxed()\
.cond_opt(cond))` (or `InferOpts::strict()` for the old strict mode)")]
    pub fn invert_flex(
        &self,
        latents: &[Tensor],
        cond: Option<&Tensor>,
        params: &ParamStore,
        relax_batch: bool,
    ) -> Result<Tensor> {
        let batch = if relax_batch { BatchMode::Relaxed }
                    else { BatchMode::Strict };
        self.invert(latents, params,
                    InferOpts { batch, cond, threads_override: None })
    }

    /// The validated inversion walk behind [`Flow::invert`].
    fn invert_impl(
        &self,
        latents: &[Tensor],
        cond: Option<&Tensor>,
        params: &ParamStore,
        relax_batch: bool,
    ) -> Result<Tensor> {
        if latents.len() != self.def.latent_shapes.len() {
            bail!("expected {} latents, got {}",
                  self.def.latent_shapes.len(), latents.len());
        }
        let n = latents.first()
            .and_then(|t| t.shape.first().copied())
            .unwrap_or(self.batch());
        for (t, want) in latents.iter().zip(&self.def.latent_shapes) {
            let ok = if relax_batch {
                t.shape.len() == want.len()
                    && t.shape.first() == Some(&n)
                    && n > 0
                    && t.shape[1..] == want[1..]
            } else {
                &t.shape == want
            };
            if !ok {
                bail!("latent shape {:?} != site shape {:?} (batch {n})",
                      t.shape, want);
            }
        }
        let cond = self.check_cond(cond, n, relax_batch)?;
        // Threaded hot path (validated above): chunk the latent rows
        // across the worker pool and stitch results back in chunk order.
        // Row i of the inverse depends only on row i of each latent, so
        // the stitched tensor is bit-identical to the one-pass walk.
        if self.infer_engaged(n, relax_batch) {
            let parts = self.infer_parallel(n, |f, lo, len| {
                let lats: Vec<Tensor> = latents.iter()
                    .map(|t| slice_rows(t, lo, len))
                    .collect::<Result<_>>()?;
                let cs = cond.map(|c| slice_rows(c, lo, len)).transpose()?;
                f.invert_rows(&lats, cs.as_ref(), params)
            })?;
            return concat_rows(&parts.iter().collect::<Vec<_>>());
        }
        self.invert_rows(latents, cond, params)
    }

    /// The single-pass inverse walk; inputs are pre-validated by
    /// [`Flow::invert`] (or are row-slices of validated inputs).
    fn invert_rows(
        &self,
        latents: &[Tensor],
        cond: Option<&Tensor>,
        params: &ParamStore,
    ) -> Result<Tensor> {
        let mut stack: Vec<&Tensor> = latents.iter().collect();
        let mut cur = stack.pop()
            .ok_or_else(|| anyhow!("invert needs at least one latent"))?
            .clone();
        for (i, step) in self.def.steps.iter().enumerate().rev() {
            match step.kind {
                StepKind::Split { zc: _ } => {
                    let z = stack.pop().ok_or_else(
                        || anyhow!("latent underflow inverting step {i}"))?;
                    cur = concat_last_axis(z, &cur)?;
                }
                StepKind::Layer => {
                    let outs = self.exec_step(i, "inverse", &[&cur], cond, params)?;
                    cur = outs.into_iter().next().ok_or_else(
                        || anyhow!("inverse returned nothing"))?;
                }
            }
        }
        Ok(cur)
    }

    /// Forward then invert; returns max |x - x_rec| (invertibility check,
    /// the paper's CI guarantee).
    pub fn roundtrip_error(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
    ) -> Result<f32> {
        let (latents, _) = self.forward(x, cond, params)?;
        let zs: Vec<Tensor> = latents.iter().map(|t| t.tensor().clone()).collect();
        let x_rec = self.invert(&zs, params, InferOpts::strict().cond_opt(cond))?;
        Ok(x.max_abs_diff(&x_rec))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exec_mode_is_a_schedule() {
        assert!(!ExecMode::Invertible.tape(0, 10));
        assert!(ExecMode::Stored.tape(0, 10));
        assert_eq!(ExecMode::Invertible.label(), "invertible");
        assert_eq!(ExecMode::Stored.name(), "stored");
    }

    #[test]
    fn checkpoint_schedule_tapes_every_k() {
        let s = CheckpointEveryK(3);
        let taped: Vec<bool> = (0..7).map(|i| s.tape(i, 7)).collect();
        assert_eq!(taped, vec![true, false, false, true, false, false, true]);
        assert_eq!(s.label(), "checkpoint_every_3");
        // k = 0 is clamped rather than dividing by zero
        assert!(CheckpointEveryK(0).tape(5, 10));
    }

    fn _schedules_are_object_safe(s: &dyn ActivationSchedule) -> String {
        s.label()
    }

    #[test]
    fn schedules_compose_as_trait_objects() {
        let all: Vec<Box<dyn ActivationSchedule>> = vec![
            Box::new(ExecMode::Invertible),
            Box::new(ExecMode::Stored),
            Box::new(CheckpointEveryK(2)),
        ];
        let labels: Vec<String> = all.iter()
            .map(|s| _schedules_are_object_safe(s.as_ref()))
            .collect();
        assert_eq!(labels,
                   vec!["invertible", "stored", "checkpoint_every_2"]);
    }
}
