//! The two training-step schedulers — the system this paper is about.
//!
//! [`ExecMode::Invertible`] (InvertibleNetworks.jl's contribution): the
//! forward pass keeps **only the current activation**; the backward pass
//! calls each layer's hand-written `backward` program, which *recomputes*
//! the layer input from its output via the inverse. Peak scheduling memory
//! is O(1) in depth.
//!
//! [`ExecMode::Stored`] (the PyTorch/normflows baseline, built here so the
//! comparison is like-for-like): the forward pass tapes every layer input
//! and the backward pass calls `backward_stored`. Peak memory is O(depth).
//!
//! Both modes execute the *same* AOT-compiled XLA programs with identical
//! math (integration-tested to produce equal losses and gradients); the
//! only difference is buffer lifetime, which the [`MemoryLedger`] records.

use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::flow::{NetworkDef, ParamStore, StepKind};
use crate::runtime::Runtime;
use crate::tensor::ops::{add_assign, concat_last_axis, split_last_axis};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

use super::memory::{MemClass, MemoryLedger, Tracked};

/// Which activation-lifetime schedule to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// Recompute activations from inverses (the paper's method).
    Invertible,
    /// Tape activations like an autodiff framework (normflows baseline).
    Stored,
}

impl ExecMode {
    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Invertible => "invertible",
            ExecMode::Stored => "stored",
        }
    }
}

/// Result of one training step.
pub struct StepResult {
    pub loss: f32,
    pub logp_mean: f32,
    pub logdet_mean: f32,
    /// Per-step parameter gradients, aligned with `ParamStore`.
    pub grads: Vec<Vec<Tensor>>,
    /// Gradient w.r.t. the conditioning input (conditional nets only).
    pub dcond: Option<Tensor>,
    /// Peak activation+gradient+latent bytes during this step.
    pub peak_sched_bytes: i64,
    pub peak_total_bytes: i64,
}

/// A network bound to a runtime + ledger, ready to train/sample/evaluate.
pub struct FlowSession<'rt> {
    pub rt: &'rt Runtime,
    pub def: NetworkDef,
    pub ledger: Arc<MemoryLedger>,
}

impl<'rt> FlowSession<'rt> {
    pub fn new(rt: &'rt Runtime, net: &str, ledger: Arc<MemoryLedger>) -> Result<Self> {
        let def = NetworkDef::resolve(&rt.manifest, net)?;
        Ok(FlowSession { rt, def, ledger })
    }

    pub fn batch(&self) -> usize {
        self.def.in_shape[0]
    }

    fn track(&self, t: Tensor, class: MemClass) -> Result<Tracked> {
        Tracked::new(t, class, &self.ledger)
    }

    /// Execute a layer-step entry: operands are (activations..., cond?,
    /// params...) per the aot.py convention.
    fn exec_step(
        &self,
        step_idx: usize,
        entry: &str,
        acts: &[&Tensor],
        cond_lit: Option<&xla::Literal>,
        params: &ParamStore,
    ) -> Result<Vec<Tensor>> {
        let sig = &self.def.steps[step_idx].sig;
        let compiled = self.rt.layer_entry(sig, entry)?;
        let act_lits: Vec<xla::Literal> = acts
            .iter()
            .map(|t| t.to_literal())
            .collect::<Result<_>>()?;
        params.with_literals(step_idx, |plits| {
            let mut args: Vec<&xla::Literal> = act_lits.iter().collect();
            if let Some(c) = cond_lit {
                args.push(c);
            }
            args.extend(plits.iter());
            compiled
                .execute_t(&args)
                .with_context(|| format!("executing {sig}.{entry}"))
        })
    }

    fn head_t(&self, entry: &str, z: &Tensor) -> Result<Vec<Tensor>> {
        let compiled = self.rt.head_entry(&z.shape, entry)?;
        let lit = z.to_literal()?;
        compiled.execute_t(&[&lit])
    }

    fn cond_literal(&self, cond: Option<&Tensor>) -> Result<Option<xla::Literal>> {
        match (cond, &self.def.cond_shape) {
            (Some(c), Some(shape)) => {
                if &c.shape != shape {
                    bail!("cond shape {:?} != network cond {:?}", c.shape, shape);
                }
                Ok(Some(c.to_literal()?))
            }
            (None, None) => Ok(None),
            (Some(_), None) => bail!("network {} takes no cond", self.def.name),
            (None, Some(_)) => bail!("network {} requires cond", self.def.name),
        }
    }

    /// Whether a given step's artifact takes the conditioning operand.
    fn step_takes_cond(&self, step_idx: usize) -> bool {
        let step = &self.def.steps[step_idx];
        if step.kind != StepKind::Layer {
            return false;
        }
        self.rt
            .manifest
            .layer(&step.sig)
            .map(|m| m.cond_shape.is_some())
            .unwrap_or(false)
    }

    // ------------------------------------------------------------------
    // Forward
    // ------------------------------------------------------------------

    /// Forward pass. `tape=true` additionally returns every layer input
    /// (the Stored/autodiff schedule); `tape=false` holds only the current
    /// activation (the Invertible schedule).
    ///
    /// Returns (latents in push order, per-sample logdet totals, tape).
    #[allow(clippy::type_complexity)]
    pub fn forward(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
        tape: bool,
    ) -> Result<(Vec<Tracked>, Vec<f32>, Vec<Option<Tracked>>)> {
        if x.shape != self.def.in_shape {
            bail!("input shape {:?} != network {:?}", x.shape, self.def.in_shape);
        }
        let n = self.batch();
        let cond_lit = self.cond_literal(cond)?;
        let mut ld_total = vec![0.0f32; n];
        let mut latents: Vec<Tracked> = Vec::new();
        let mut tape_store: Vec<Option<Tracked>> = Vec::new();
        let mut cur = self.track(x.clone(), MemClass::Activation)?;

        for (i, step) in self.def.steps.iter().enumerate() {
            match step.kind {
                StepKind::Split { zc } => {
                    let (z, h) = split_last_axis(cur.tensor(), zc)?;
                    latents.push(self.track(z, MemClass::Latent)?);
                    let next = self.track(h, MemClass::Activation)?;
                    cur = next; // old `cur` dropped here
                    tape_store.push(None);
                }
                StepKind::Layer => {
                    let cl = if self.step_takes_cond(i) {
                        cond_lit.as_ref()
                    } else {
                        None
                    };
                    let outs = self.exec_step(i, "forward",
                                              &[cur.tensor()], cl, params)?;
                    let [y, logdet]: [Tensor; 2] = outs
                        .try_into()
                        .map_err(|_| anyhow!("forward arity"))?;
                    for (acc, v) in ld_total.iter_mut().zip(&logdet.data) {
                        *acc += v;
                    }
                    let next = self.track(y, MemClass::Activation)?;
                    if tape {
                        tape_store.push(Some(cur));
                    } else {
                        tape_store.push(None);
                        // `cur` dropped: invertible mode keeps nothing
                    }
                    cur = next;
                }
            }
        }
        // final activation is the last latent
        let z_final = self.track(cur.into_inner(), MemClass::Latent)?;
        latents.push(z_final);
        Ok((latents, ld_total, tape_store))
    }

    /// Per-sample log-likelihood of the inputs under the flow:
    /// log p(x) = sum_latents log N(z) + total logdet.
    pub fn log_likelihood(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
    ) -> Result<Vec<f32>> {
        let (latents, ld, _) = self.forward(x, cond, params, false)?;
        let mut out = ld;
        for z in &latents {
            let lp = &self.head_t("gaussian_logp", z.tensor())?[0];
            for (acc, v) in out.iter_mut().zip(&lp.data) {
                *acc += v;
            }
        }
        Ok(out)
    }

    // ------------------------------------------------------------------
    // Training step
    // ------------------------------------------------------------------

    /// One full NLL training step (forward + loss + backward), returning
    /// parameter gradients and the memory peaks observed.
    pub fn train_step(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
        mode: ExecMode,
    ) -> Result<StepResult> {
        self.ledger.reset_peaks();
        let n = self.batch();
        let cond_lit = self.cond_literal(cond)?;

        let (mut latents, ld_total, mut tape) =
            self.forward(x, cond, params, mode == ExecMode::Stored)?;

        // ---- loss -----------------------------------------------------
        let mut logp = vec![0.0f32; n];
        for z in &latents {
            let lp = &self.head_t("gaussian_logp", z.tensor())?[0];
            for (acc, v) in logp.iter_mut().zip(&lp.data) {
                *acc += v;
            }
        }
        let logp_mean = logp.iter().sum::<f32>() / n as f32;
        let logdet_mean = ld_total.iter().sum::<f32>() / n as f32;
        let loss = -(logp_mean + logdet_mean);

        // ---- backward seeds --------------------------------------------
        // dL/dlogdet_n = -1/N for every layer's logdet contribution.
        let dld = Tensor::full(&[n], -1.0 / n as f32);

        let z_final = latents.pop().expect("forward always pushes a latent");
        let seeds = self.head_t("nll_seed", z_final.tensor())?;
        let dz_final = seeds.into_iter().next().expect("nll_seed returns dz");
        let mut dy = self.track(dz_final, MemClass::Gradient)?;

        // In invertible mode the final latent doubles as the activation we
        // walk back from; in stored mode the tape provides inputs.
        let mut y: Option<Tracked> = Some(z_final);

        let mut grads: Vec<Vec<Tensor>> = vec![Vec::new(); self.def.steps.len()];
        let mut dcond_acc: Option<Tensor> = None;

        for (i, step) in self.def.steps.iter().enumerate().rev() {
            match step.kind {
                StepKind::Split { zc: _ } => {
                    let z = latents.pop().ok_or_else(
                        || anyhow!("latent stack underflow at step {i}"))?;
                    let seeds = self.head_t("nll_seed", z.tensor())?;
                    let dz = seeds.into_iter().next().unwrap();
                    let new_dy = self.track(
                        concat_last_axis(&dz, dy.tensor())?, MemClass::Gradient)?;
                    dy = new_dy;
                    if let Some(yt) = y.take() {
                        let joined = concat_last_axis(z.tensor(), yt.tensor())?;
                        y = Some(self.track(joined, MemClass::Activation)?);
                    }
                    // z dropped here (its bytes were Latent class)
                }
                StepKind::Layer => {
                    let meta = self.rt.manifest.layer(&step.sig)?;
                    let has_cond = meta.cond_shape.is_some();
                    let cl = if has_cond { cond_lit.as_ref() } else { None };
                    let n_params = meta.params.len();

                    let results = match mode {
                        ExecMode::Invertible => {
                            let yt = y.as_ref().ok_or_else(
                                || anyhow!("missing activation at step {i}"))?;
                            self.exec_step(
                                i, "backward",
                                &[dy.tensor(), &dld, yt.tensor()], cl, params)?
                        }
                        ExecMode::Stored => {
                            let xin = tape[i].take().ok_or_else(
                                || anyhow!("missing tape entry at step {i}"))?;
                            self.exec_step(
                                i, "backward_stored",
                                &[dy.tensor(), &dld, xin.tensor()], cl, params)?
                            // xin dropped: autodiff frees tape entries as
                            // backward consumes them
                        }
                    };

                    let want = 1 + has_cond as usize + n_params
                        + (mode == ExecMode::Invertible) as usize;
                    if results.len() != want {
                        bail!("{}.backward arity {} != {want}",
                              step.sig, results.len());
                    }
                    let mut it = results.into_iter();
                    let dx = it.next().unwrap();
                    if has_cond {
                        let dc = it.next().unwrap();
                        match &mut dcond_acc {
                            Some(acc) => add_assign(acc, &dc)?,
                            None => dcond_acc = Some(dc),
                        }
                    }
                    let mut dtheta = Vec::with_capacity(n_params);
                    for _ in 0..n_params {
                        dtheta.push(it.next().unwrap());
                    }
                    grads[i] = dtheta;

                    let new_dy = self.track(dx, MemClass::Gradient)?;
                    dy = new_dy;
                    match mode {
                        ExecMode::Invertible => {
                            let x_rec = it.next().unwrap();
                            y = Some(self.track(x_rec, MemClass::Activation)?);
                        }
                        ExecMode::Stored => {
                            y = None;
                        }
                    }
                }
            }
        }

        Ok(StepResult {
            loss,
            logp_mean,
            logdet_mean,
            grads,
            dcond: dcond_acc,
            peak_sched_bytes: self.ledger.peak_scheduling(),
            peak_total_bytes: self.ledger.peak_total(),
        })
    }

    // ------------------------------------------------------------------
    // Sampling / inversion
    // ------------------------------------------------------------------

    /// Draw one batch of samples: z ~ N(0, I) at every latent site, then
    /// walk the inverse chain (paper: "efficient sampling").
    pub fn sample(
        &self,
        params: &ParamStore,
        cond: Option<&Tensor>,
        rng: &mut Pcg64,
    ) -> Result<Tensor> {
        let shapes = &self.def.latent_shapes;
        let zs: Vec<Tensor> = shapes
            .iter()
            .map(|s| Tensor {
                shape: s.clone(),
                data: rng.normal_vec(s.iter().product()),
            })
            .collect();
        self.invert(&zs, cond, params)
    }

    /// Map latents back to input space (inverse of [`forward`]'s latents,
    /// in the same push order).
    pub fn invert(
        &self,
        latents: &[Tensor],
        cond: Option<&Tensor>,
        params: &ParamStore,
    ) -> Result<Tensor> {
        if latents.len() != self.def.latent_shapes.len() {
            bail!("expected {} latents, got {}",
                  self.def.latent_shapes.len(), latents.len());
        }
        let cond_lit = self.cond_literal(cond)?;
        let mut stack: Vec<&Tensor> = latents.iter().collect();
        let mut cur = stack.pop().unwrap().clone();
        for (i, step) in self.def.steps.iter().enumerate().rev() {
            match step.kind {
                StepKind::Split { zc: _ } => {
                    let z = stack.pop().ok_or_else(
                        || anyhow!("latent underflow inverting step {i}"))?;
                    cur = concat_last_axis(z, &cur)?;
                }
                StepKind::Layer => {
                    let cl = if self.step_takes_cond(i) {
                        cond_lit.as_ref()
                    } else {
                        None
                    };
                    let outs = self.exec_step(i, "inverse", &[&cur], cl, params)?;
                    cur = outs.into_iter().next().ok_or_else(
                        || anyhow!("inverse returned nothing"))?;
                }
            }
        }
        Ok(cur)
    }

    /// Forward then invert; returns max |x - x_rec| (invertibility check,
    /// the paper's CI guarantee).
    pub fn roundtrip_error(
        &self,
        x: &Tensor,
        cond: Option<&Tensor>,
        params: &ParamStore,
    ) -> Result<f32> {
        let (latents, _, _) = self.forward(x, cond, params, false)?;
        let zs: Vec<Tensor> = latents.iter().map(|t| t.tensor().clone()).collect();
        let x_rec = self.invert(&zs, cond, params)?;
        Ok(x.max_abs_diff(&x_rec))
    }
}
