//! Exact byte accounting of every live activation/gradient/latent buffer.
//!
//! This is the measurement substrate behind the paper's Figures 1 & 2: the
//! two executors run identical compute; what differs is *which buffers stay
//! alive*, and this ledger observes exactly that. A configurable budget
//! reproduces the 40 GB A100 wall — an allocation pushing the live total
//! past the budget fails with a simulated OOM, which is how the bench finds
//! each executor's out-of-memory crossover (normflows died at 480x480;
//! InvertibleNetworks.jl did not, paper Fig. 1).

use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::tensor::Tensor;

/// What a tracked buffer is, for per-class reporting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MemClass {
    /// Flow activations (the tape in stored mode, the single live pair in
    /// invertible mode).
    Activation = 0,
    /// Activation-shaped gradients flowing backward.
    Gradient = 1,
    /// Factored-out / final latent codes (alive in both modes).
    Latent = 2,
    /// Parameters + parameter gradients + optimizer state (identical in
    /// both modes; reported separately).
    Param = 3,
}

pub const N_CLASSES: usize = 4;

const CLASS_NAMES: [&str; N_CLASSES] = ["activation", "gradient", "latent", "param"];

/// Bytes per element of the single dtype the ledger meters (f32).
pub const BYTES_PER_ELEM: usize = 4;

/// Ledger bytes of an f32 tensor of `shape` — the static planner's unit
/// of account, kept next to the ledger so predicted and measured bytes
/// share one definition.
pub fn bytes_of_shape(shape: &[usize]) -> i64 {
    (shape.iter().product::<usize>() * BYTES_PER_ELEM) as i64
}

/// Thread-safe live/peak byte ledger with an optional budget.
#[derive(Debug, Default)]
pub struct MemoryLedger {
    live: [AtomicI64; N_CLASSES],
    live_total: AtomicI64,
    peak_total: AtomicI64,
    peak_sched: AtomicI64,
    budget: AtomicI64, // <=0: unlimited
    allocs: AtomicU64,
    ooms: AtomicU64,
}

impl MemoryLedger {
    pub fn new() -> Arc<MemoryLedger> {
        Arc::new(MemoryLedger::default())
    }

    pub fn with_budget(bytes: u64) -> Arc<MemoryLedger> {
        let l = MemoryLedger::default();
        l.budget.store(bytes as i64, Ordering::Relaxed);
        Arc::new(l)
    }

    pub fn set_budget(&self, bytes: Option<u64>) {
        self.budget.store(bytes.map_or(0, |b| b as i64), Ordering::Relaxed);
    }

    /// The configured budget, if any (so derived ledgers — e.g.
    /// [`crate::api::Flow::fork`] worker ledgers — can inherit it).
    pub fn budget_bytes(&self) -> Option<u64> {
        match self.budget.load(Ordering::Relaxed) {
            b if b > 0 => Some(b as u64),
            _ => None,
        }
    }

    /// Register an allocation; fails (simulated OOM) if it would exceed the
    /// budget, in which case nothing is recorded.
    pub fn alloc(&self, class: MemClass, bytes: usize) -> Result<()> {
        let b = bytes as i64;
        let budget = self.budget.load(Ordering::Relaxed);
        let new_total = self.live_total.fetch_add(b, Ordering::Relaxed) + b;
        if budget > 0 && new_total > budget {
            self.live_total.fetch_sub(b, Ordering::Relaxed);
            self.ooms.fetch_add(1, Ordering::Relaxed);
            bail!(
                "simulated OOM: allocating {bytes} B of {} puts live total at \
                 {new_total} B > budget {budget} B",
                CLASS_NAMES[class as usize]
            );
        }
        self.live[class as usize].fetch_add(b, Ordering::Relaxed);
        self.allocs.fetch_add(1, Ordering::Relaxed);
        self.peak_total.fetch_max(new_total, Ordering::Relaxed);
        // scheduling memory: everything except params
        if class != MemClass::Param {
            let sched = new_total - self.live[MemClass::Param as usize]
                .load(Ordering::Relaxed);
            self.peak_sched.fetch_max(sched, Ordering::Relaxed);
        }
        Ok(())
    }

    pub fn free(&self, class: MemClass, bytes: usize) {
        let b = bytes as i64;
        self.live[class as usize].fetch_sub(b, Ordering::Relaxed);
        self.live_total.fetch_sub(b, Ordering::Relaxed);
    }

    pub fn live_total(&self) -> i64 {
        self.live_total.load(Ordering::Relaxed)
    }

    pub fn live_of(&self, class: MemClass) -> i64 {
        self.live[class as usize].load(Ordering::Relaxed)
    }

    /// Peak of the total (all classes).
    pub fn peak_total(&self) -> i64 {
        self.peak_total.load(Ordering::Relaxed)
    }

    /// Peak of activation+gradient+latent — the scheduling memory the
    /// paper's figures plot (params are identical across executors).
    pub fn peak_scheduling(&self) -> i64 {
        self.peak_sched.load(Ordering::Relaxed)
    }

    pub fn alloc_count(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }

    pub fn oom_count(&self) -> u64 {
        self.ooms.load(Ordering::Relaxed)
    }

    /// Reset peaks (start of a measured region); live counts are kept.
    pub fn reset_peaks(&self) {
        let live = self.live_total();
        self.peak_total.store(live, Ordering::Relaxed);
        let sched = live - self.live_of(MemClass::Param);
        self.peak_sched.store(sched, Ordering::Relaxed);
    }

    pub fn report(&self) -> String {
        let mut s = String::new();
        for (i, name) in CLASS_NAMES.iter().enumerate() {
            s.push_str(&format!(
                "{name}: {} B live; ",
                self.live[i].load(Ordering::Relaxed)
            ));
        }
        s.push_str(&format!(
            "total {} B live, peak {} B, sched-peak {} B",
            self.live_total(),
            self.peak_total(),
            self.peak_scheduling()
        ));
        s
    }
}

/// A host tensor whose bytes are charged to a [`MemoryLedger`] for its
/// lifetime (RAII: dropping frees).
#[derive(Debug)]
pub struct Tracked {
    t: Option<Tensor>,
    class: MemClass,
    bytes: usize,
    ledger: Arc<MemoryLedger>,
}

impl Tracked {
    pub fn new(t: Tensor, class: MemClass, ledger: &Arc<MemoryLedger>) -> Result<Tracked> {
        let bytes = t.size_bytes();
        ledger.alloc(class, bytes)?;
        Ok(Tracked { t: Some(t), class, bytes, ledger: ledger.clone() })
    }

    pub fn tensor(&self) -> &Tensor {
        self.t.as_ref().expect("tracked tensor already taken")
    }

    pub fn shape(&self) -> &[usize] {
        &self.tensor().shape
    }

    /// Unwrap, releasing the ledger charge.
    pub fn into_inner(mut self) -> Tensor {
        let t = self.t.take().unwrap();
        self.ledger.free(self.class, self.bytes);
        self.bytes = 0;
        t
    }
}

impl Drop for Tracked {
    fn drop(&mut self) {
        if self.t.is_some() {
            self.ledger.free(self.class, self.bytes);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(n: usize) -> Tensor {
        Tensor::zeros(&[n])
    }

    #[test]
    fn alloc_free_balance() {
        let l = MemoryLedger::new();
        {
            let _a = Tracked::new(t(100), MemClass::Activation, &l).unwrap();
            let _b = Tracked::new(t(50), MemClass::Gradient, &l).unwrap();
            assert_eq!(l.live_total(), 600);
            assert_eq!(l.live_of(MemClass::Activation), 400);
        }
        assert_eq!(l.live_total(), 0);
        assert_eq!(l.peak_total(), 600);
    }

    #[test]
    fn into_inner_releases() {
        let l = MemoryLedger::new();
        let a = Tracked::new(t(10), MemClass::Latent, &l).unwrap();
        let inner = a.into_inner();
        assert_eq!(inner.len(), 10);
        assert_eq!(l.live_total(), 0);
    }

    #[test]
    fn budget_ooms_and_rolls_back() {
        let l = MemoryLedger::with_budget(1000);
        let _a = Tracked::new(t(200), MemClass::Activation, &l).unwrap(); // 800 B
        let err = Tracked::new(t(100), MemClass::Activation, &l); // +400 > 1000
        assert!(err.is_err());
        assert!(err.unwrap_err().to_string().contains("simulated OOM"));
        assert_eq!(l.live_total(), 800, "failed alloc must not leak");
        assert_eq!(l.oom_count(), 1);
        // small one still fits
        let _c = Tracked::new(t(25), MemClass::Gradient, &l).unwrap();
        assert_eq!(l.live_total(), 900);
    }

    #[test]
    fn scheduling_peak_excludes_params() {
        let l = MemoryLedger::new();
        let _p = Tracked::new(t(1000), MemClass::Param, &l).unwrap();
        let _a = Tracked::new(t(100), MemClass::Activation, &l).unwrap();
        assert_eq!(l.peak_scheduling(), 400);
        assert_eq!(l.peak_total(), 4400);
    }

    #[test]
    fn budget_is_readable() {
        assert_eq!(MemoryLedger::new().budget_bytes(), None);
        assert_eq!(MemoryLedger::with_budget(4096).budget_bytes(), Some(4096));
        let l = MemoryLedger::new();
        l.set_budget(Some(10));
        assert_eq!(l.budget_bytes(), Some(10));
        l.set_budget(None);
        assert_eq!(l.budget_bytes(), None);
    }

    #[test]
    fn reset_peaks_keeps_live() {
        let l = MemoryLedger::new();
        let a = Tracked::new(t(100), MemClass::Activation, &l).unwrap();
        {
            let _b = Tracked::new(t(100), MemClass::Activation, &l).unwrap();
        }
        assert_eq!(l.peak_total(), 800);
        l.reset_peaks();
        assert_eq!(l.peak_total(), 400);
        drop(a);
        assert_eq!(l.live_total(), 0);
    }
}
