//! Public facade: [`Engine`] owns a backend + manifest; [`Engine::flow`]
//! hands out owned, `Send` [`Flow`] handles that train / sample / invert
//! one network.
//!
//! ```text
//! let engine = Engine::builder().build()?;            // RefBackend, builtin catalog
//! let flow   = engine.flow("realnvp2d")?;             // owned handle
//! let params = flow.init_params(42)?;
//! let step   = flow.train_step(&x, None, &params, &ExecMode::Invertible)?;
//! ```
//!
//! This replaces the old `FlowSession<'rt>`-borrows-`Runtime` pattern: a
//! `Flow` holds `Arc`s to its backend/manifest, so it has no lifetime tie
//! to the engine and can move across threads.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::backend::{Backend, RefBackend, WeightDtype};
use crate::coordinator::memory::MemoryLedger;
use crate::flow::{NetworkDef, ParamStore, StepKind};
use crate::runtime::{builtin_manifest, Manifest};

/// The resolved engine configuration: every knob [`EngineBuilder`] accepts,
/// after defaulting. One inspectable struct ([`Engine::config`]) instead of
/// scattered getters, so tools (bench headers, `serve` boot logs, tests)
/// can report exactly what an engine was built with.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Resolved backend name ("ref", "xla", ...).
    pub backend: String,
    /// Data-parallel worker count for training and the threaded inference
    /// hot path (>= 1).
    pub threads: usize,
    /// Intra-kernel fan-out for the GEMM/conv row-split paths (>= 1);
    /// bit-invisible to results (see `backend::math::par`).
    pub kernel_threads: usize,
    /// Static scheduling-memory budget in bytes, if any.
    pub mem_budget: Option<i64>,
    /// Weight *storage* precision applied at load ([`Engine::load_weights`]);
    /// compute stays f32.
    pub weight_dtype: WeightDtype,
    /// AOT artifact directory the manifest came from (None = builtin).
    pub artifacts: Option<PathBuf>,
}

/// Backend + manifest pair; cheap to clone flows out of.
///
/// `Engine` itself is `Clone` (both halves are `Arc`s): clones share the
/// backend executable cache and the manifest, so tooling that needs an
/// owned engine — e.g. [`crate::serve::Registry::new`] — can take a clone
/// without recompiling anything.
#[derive(Clone)]
pub struct Engine {
    backend: Arc<dyn Backend>,
    manifest: Arc<Manifest>,
    /// The resolved build-time configuration (threads, kernel threads,
    /// memory budget, weight dtype, artifact source). The mem budget is
    /// *static admission control*: the serve [`Registry`] rejects a model
    /// at load when its minimum predicted peak
    /// ([`predict_peak`](crate::analysis::predict_peak) under the
    /// invertible schedule) cannot fit, before any weights are read, and
    /// `--mode auto` uses it as the default schedule-search budget.
    config: EngineConfig,
}

/// Builder for [`Engine`].
///
/// * no options: builtin catalog + [`RefBackend`] (hermetic default);
/// * `.artifacts(dir)`: load `dir/manifest.json`; with `--features xla`
///   and no explicit backend this also selects the XLA backend, otherwise
///   the RefBackend executes the same networks natively;
/// * `.backend(b)`: explicit backend override;
/// * `.threads(n)`: default data-parallel worker count for training;
/// * `.kernel_threads(n)`: intra-kernel GEMM/conv row-split fan-out;
/// * `.mem_budget(bytes)`: static per-model scheduling-memory budget;
/// * `.weight_dtype(d)`: bf16/f16 weight-storage precision at load.
///
/// This builder is the single configuration front: the resolved knobs come
/// back as one [`EngineConfig`] via [`Engine::config`].
#[derive(Default)]
pub struct EngineBuilder {
    artifacts: Option<PathBuf>,
    backend: Option<Arc<dyn Backend>>,
    threads: Option<usize>,
    kernel_threads: Option<usize>,
    mem_budget: Option<i64>,
    weight_dtype: Option<WeightDtype>,
}

impl EngineBuilder {
    /// Use an AOT artifact directory as the manifest source.
    pub fn artifacts(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts = Some(dir.into());
        self
    }

    /// Execute on an explicit backend.
    pub fn backend(mut self, backend: Arc<dyn Backend>) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Default worker-thread count (clamped to at least 1) for both
    /// data-parallel training and the threaded inference hot path: flows
    /// handed out by [`Engine::flow`] chunk large relaxed-batch `sample` /
    /// `log_density` / `invert` calls across this many workers.
    /// Consumers read it back via [`Engine::default_threads`]; per-run
    /// training overrides go through `TrainConfig::threads`.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n.max(1));
        self
    }

    /// Static scheduling-memory budget in bytes, enforced *before*
    /// allocation: the serve [`Registry`](crate::serve::Registry)
    /// rejects models whose minimum predicted peak exceeds it, and
    /// `--mode auto` searches schedules under it by default.
    pub fn mem_budget(mut self, bytes: i64) -> Self {
        self.mem_budget = Some(bytes);
        self
    }

    /// Intra-kernel thread fan-out (clamped to at least 1) for the
    /// GEMM/conv row-split paths inside a single layer call. Orthogonal to
    /// [`threads`](Self::threads): that one splits *batches* across
    /// forked flows; this one splits *output rows* inside one kernel, and
    /// is bitwise invisible to results. Default 1.
    pub fn kernel_threads(mut self, n: usize) -> Self {
        self.kernel_threads = Some(n.max(1));
        self
    }

    /// Weight *storage* precision: non-f32 dtypes round every weight
    /// tensor through bf16/f16 at load time ([`Engine::load_weights`]);
    /// compute stays f32. Default [`WeightDtype::F32`] (no-op).
    pub fn weight_dtype(mut self, dtype: WeightDtype) -> Self {
        self.weight_dtype = Some(dtype);
        self
    }

    pub fn build(self) -> Result<Engine> {
        let manifest: Arc<Manifest> = match &self.artifacts {
            Some(dir) => Arc::new(Manifest::load(dir)
                .with_context(|| format!("loading artifacts from {dir:?}"))?),
            None => Arc::new(builtin_manifest()
                .context("building the builtin network catalog")?),
        };
        // static flow verifier gate: no engine over a manifest with a
        // malformed network — every violation up front, not at first use
        let mut bad: Vec<String> = Vec::new();
        for (name, diags) in crate::analysis::verify_manifest(&manifest) {
            bad.extend(diags.iter()
                .filter(|d| d.is_error())
                .map(|d| format!("{name}: {d}")));
        }
        if !bad.is_empty() {
            bail!("manifest failed the static flow verifier ({} error(s), \
                   run `invertnet lint` for the full report):\n  {}",
                  bad.len(), bad.join("\n  "));
        }
        let kernel_threads = self.kernel_threads.unwrap_or(1);
        let backend: Arc<dyn Backend> = match self.backend {
            Some(b) => b,
            None => default_backend(self.artifacts.as_deref(), &manifest,
                                    kernel_threads)?,
        };
        let config = EngineConfig {
            backend: backend.name().to_string(),
            threads: self.threads.unwrap_or(1),
            kernel_threads,
            mem_budget: self.mem_budget,
            weight_dtype: self.weight_dtype.unwrap_or_default(),
            artifacts: self.artifacts,
        };
        Ok(Engine { backend, manifest, config })
    }
}

#[cfg(feature = "xla")]
fn default_backend(artifacts: Option<&Path>, manifest: &Arc<Manifest>,
                   kernel_threads: usize) -> Result<Arc<dyn Backend>> {
    match artifacts {
        Some(dir) => Ok(Arc::new(
            crate::backend::XlaBackend::with_manifest(dir, manifest.clone())?)),
        None => Ok(Arc::new(RefBackend::with_kernel_threads(kernel_threads))),
    }
}

#[cfg(not(feature = "xla"))]
fn default_backend(_artifacts: Option<&Path>, _manifest: &Arc<Manifest>,
                   kernel_threads: usize) -> Result<Arc<dyn Backend>> {
    Ok(Arc::new(RefBackend::with_kernel_threads(kernel_threads)))
}

impl Engine {
    pub fn builder() -> EngineBuilder {
        EngineBuilder::default()
    }

    /// Shorthand for the hermetic default: builtin catalog + RefBackend.
    pub fn native() -> Result<Engine> {
        Engine::builder().build()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// The resolved build-time configuration.
    pub fn config(&self) -> &EngineConfig {
        &self.config
    }

    /// Default data-parallel worker count configured at build time.
    /// Shorthand for `config().threads`.
    pub fn default_threads(&self) -> usize {
        self.config.threads
    }

    /// Static scheduling-memory budget configured at build time, if any
    /// (see [`EngineBuilder::mem_budget`]). Shorthand for
    /// `config().mem_budget`.
    pub fn mem_budget(&self) -> Option<i64> {
        self.config.mem_budget
    }

    /// Apply the configured weight-storage dtype to a parameter store, one
    /// tensor at a time through [`Backend::load_weight`]. Call once after
    /// loading inference weights; a no-op under [`WeightDtype::F32`].
    pub fn load_weights(&self, params: &mut ParamStore) {
        let dtype = self.config.weight_dtype;
        if dtype == WeightDtype::F32 {
            return;
        }
        for step in &mut params.tensors {
            for t in step {
                self.backend.load_weight(t, dtype);
            }
        }
    }

    /// The underlying execution backend (for tooling like the profiler).
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// Drop backend executable caches (bench hygiene between configs).
    pub fn clear_cache(&self) {
        self.backend.clear_cache()
    }

    /// An owned flow handle over `net` with a fresh memory ledger.
    pub fn flow(&self, net: &str) -> Result<Flow> {
        self.flow_with_ledger(net, MemoryLedger::new())
    }

    /// An owned flow handle charging its buffers to `ledger` (shared
    /// ledgers let callers impose budgets / read peaks).
    pub fn flow_with_ledger(&self, net: &str, ledger: Arc<MemoryLedger>)
                            -> Result<Flow> {
        let def = NetworkDef::resolve(&self.manifest, net)?;
        Ok(Flow {
            backend: self.backend.clone(),
            manifest: self.manifest.clone(),
            def,
            ledger,
            threads: self.config.threads,
        })
    }
}

/// An owned, `Send` handle on one network: train / forward / sample /
/// invert / inspect. The scheduling algorithms live in
/// `coordinator::executor` (an `impl Flow` block there).
pub struct Flow {
    pub(crate) backend: Arc<dyn Backend>,
    pub(crate) manifest: Arc<Manifest>,
    pub def: NetworkDef,
    pub(crate) ledger: Arc<MemoryLedger>,
    /// Worker count for the threaded inference hot path (chunked
    /// relaxed-batch `sample` / `log_density` / `invert`); inherited from
    /// [`EngineBuilder::threads`], overridable via [`Flow::with_threads`].
    pub(crate) threads: usize,
}

impl Clone for Flow {
    /// Cloned handles share the backend, manifest AND memory ledger —
    /// their buffer lifetimes are charged to one account. Use
    /// [`Flow::fork`] for an independently-metered handle.
    fn clone(&self) -> Flow {
        Flow {
            backend: self.backend.clone(),
            manifest: self.manifest.clone(),
            def: self.def.clone(),
            ledger: self.ledger.clone(),
            threads: self.threads,
        }
    }
}

impl Flow {
    /// An independent handle on the same network whose buffers charge a
    /// fresh [`MemoryLedger`]. The data-parallel trainer forks the source
    /// flow once per worker so each worker's activation peak is observable
    /// on its own (concurrent peaks add up across workers).
    ///
    /// A memory budget on the source ledger carries over, applied *per
    /// fork*: each forked walk is individually held to the budget (the
    /// single-threaded simulated-OOM contract), while the concurrent sum
    /// across workers is reported, not capped.
    pub fn fork(&self) -> Flow {
        Flow {
            backend: self.backend.clone(),
            manifest: self.manifest.clone(),
            def: self.def.clone(),
            ledger: match self.ledger.budget_bytes() {
                Some(b) => MemoryLedger::with_budget(b),
                None => MemoryLedger::new(),
            },
            threads: self.threads,
        }
    }

    /// Leading (batch) dimension of the network input.
    pub fn batch(&self) -> usize {
        self.def.in_shape[0]
    }

    /// Worker count the inference hot path fans out over (>= 1).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Override the inference worker count on this handle (clamped to at
    /// least 1). The engine default comes from [`EngineBuilder::threads`].
    pub fn with_threads(mut self, n: usize) -> Flow {
        self.threads = n.max(1);
        self
    }

    /// Random-initialize a parameter store for this network.
    pub fn init_params(&self, seed: u64) -> Result<ParamStore> {
        ParamStore::init(&self.def, &self.manifest, seed)
    }

    pub fn ledger(&self) -> &Arc<MemoryLedger> {
        &self.ledger
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Human-readable step table (the `invertnet inspect` payload): the
    /// network-level input/cond shapes, then per layer its kind, shapes,
    /// conditioning input (`-` for unconditioned layers) and parameter
    /// count — the numbers that make conditional nets debuggable.
    pub fn inspect(&self) -> Result<String> {
        use std::fmt::Write as _;
        let def = &self.def;
        let mut out = String::new();
        match &def.cond_shape {
            Some(c) => writeln!(
                out, "network {}: input {:?}, cond {:?} (conditional)",
                def.name, def.in_shape, c).ok(),
            None => writeln!(out, "network {}: input {:?}, cond None",
                             def.name, def.in_shape).ok(),
        };
        let mut total_params = 0usize;
        for (i, s) in def.steps.iter().enumerate() {
            let (kind, cond, nparams) = match s.kind {
                StepKind::Split { zc } => {
                    (format!("split(zc={zc})"), "-".to_string(), 0)
                }
                StepKind::Layer => {
                    let m = self.manifest.layer(&s.sig)?;
                    let cond = match &m.cond_shape {
                        Some(c) => format!("{c:?}"),
                        None => "-".to_string(),
                    };
                    (m.kind.clone(), cond, m.param_count())
                }
            };
            total_params += nparams;
            writeln!(
                out,
                "  [{i:>3}] {kind:<12} {:>18} -> {:<18} cond {cond:<14} \
                 {:>9} params   {}",
                format!("{:?}", s.in_shape),
                format!("{:?}", s.out_shape),
                nparams,
                s.sig
            ).ok();
        }
        writeln!(out, "latents: {:?}", def.latent_shapes).ok();
        writeln!(out, "total params: {total_params}").ok();
        writeln!(out, "predicted peak scheduling bytes (static planner):")
            .ok();
        let costs = crate::analysis::schedule_costs(def, &self.manifest)?;
        for ((label, bytes), (_, cost)) in
            crate::analysis::schedule_peaks(def).iter().zip(&costs)
        {
            writeln!(out, "  {label:<20} {bytes:>14}  train {:>16} flops",
                     cost.flops).ok();
        }
        let infer = crate::analysis::inference_cost(def, &self.manifest)?;
        writeln!(out, "predicted inference (log-density) flops: {}",
                 infer.flops).ok();
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::ExecMode;
    use crate::data::Density2d;
    use crate::util::rng::Pcg64;

    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn engine_and_flow_are_send_sync() {
        assert_send_sync::<Engine>();
        assert_send_sync::<Flow>();
    }

    #[test]
    fn builder_defaults_to_ref_backend_and_builtin_catalog() {
        let engine = Engine::builder().build().unwrap();
        assert_eq!(engine.backend_name(), "ref");
        assert_eq!(engine.manifest().backend, "ref-builtin");
        assert!(engine.flow("realnvp2d").is_ok());
        assert!(engine.flow("no_such_net").is_err());
    }

    #[test]
    fn missing_artifact_dir_is_a_clear_error() {
        let err = Engine::builder()
            .artifacts("/definitely/not/here")
            .build()
            .unwrap_err();
        assert!(format!("{err:#}").contains("artifacts"), "{err:#}");
    }

    #[test]
    fn flow_handle_works_across_threads() {
        let engine = Engine::native().unwrap();
        let flow = engine.flow("realnvp2d").unwrap();
        drop(engine); // the handle is self-contained
        let handle = std::thread::spawn(move || {
            let params = flow.init_params(7).unwrap();
            let mut rng = Pcg64::new(5);
            let x = Density2d::TwoMoons.sample(flow.batch(), &mut rng);
            flow.train_step(&x, None, &params, &ExecMode::Invertible)
                .unwrap()
                .loss
        });
        let loss = handle.join().unwrap();
        assert!(loss.is_finite());
    }

    #[test]
    fn inspect_renders_the_step_table() {
        let engine = Engine::native().unwrap();
        let flow = engine.flow("glow16").unwrap();
        let table = flow.inspect().unwrap();
        assert!(table.contains("glow16"));
        assert!(table.contains("split(zc=6)"));
        assert!(table.contains("total params:"));
        // static-planner peaks per schedule ride along
        assert!(table.contains("predicted peak scheduling bytes"), "{table}");
        for label in ["invertible", "stored", "checkpoint_every_4"] {
            assert!(table.contains(label), "{label} missing:\n{table}");
        }
    }

    #[test]
    fn threads_flow_from_builder_to_handles() {
        let engine = Engine::builder().threads(4).build().unwrap();
        assert_eq!(engine.default_threads(), 4);
        let flow = engine.flow("realnvp2d").unwrap();
        assert_eq!(flow.threads(), 4);
        // clone and fork both inherit; with_threads overrides and clamps
        assert_eq!(flow.clone().threads(), 4);
        assert_eq!(flow.fork().threads(), 4);
        assert_eq!(flow.clone().with_threads(0).threads(), 1);
        // engine clones share the catalog and the thread default
        let e2 = engine.clone();
        assert_eq!(e2.default_threads(), 4);
        assert!(e2.flow("realnvp2d").is_ok());
    }

    #[test]
    fn resolved_config_is_inspectable() {
        let engine = Engine::builder()
            .threads(3)
            .kernel_threads(2)
            .mem_budget(1 << 20)
            .weight_dtype(WeightDtype::Bf16)
            .build()
            .unwrap();
        let cfg = engine.config();
        assert_eq!(cfg.backend, "ref");
        assert_eq!(cfg.threads, 3);
        assert_eq!(cfg.kernel_threads, 2);
        assert_eq!(cfg.mem_budget, Some(1 << 20));
        assert_eq!(cfg.weight_dtype, WeightDtype::Bf16);
        assert!(cfg.artifacts.is_none());
        // the shorthand getters agree with the config
        assert_eq!(engine.default_threads(), 3);
        assert_eq!(engine.mem_budget(), Some(1 << 20));
        // defaults: everything off / single-threaded
        let plain = Engine::native().unwrap().config().clone();
        assert_eq!(plain.threads, 1);
        assert_eq!(plain.kernel_threads, 1);
        assert_eq!(plain.weight_dtype, WeightDtype::F32);
        assert_eq!(plain.mem_budget, None);
    }

    #[test]
    fn load_weights_applies_storage_dtype() {
        let engine = Engine::builder()
            .weight_dtype(WeightDtype::F16)
            .build()
            .unwrap();
        let flow = engine.flow("realnvp2d").unwrap();
        let mut params = flow.init_params(11).unwrap();
        let before = params.clone();
        engine.load_weights(&mut params);
        let mut changed = false;
        for (sa, sb) in params.tensors.iter().zip(&before.tensors) {
            for (ta, tb) in sa.iter().zip(sb) {
                for (&a, &b) in ta.data.iter().zip(&tb.data) {
                    if a != b {
                        changed = true;
                    }
                    // error contract: rel 2^-11 over the normal range,
                    // abs 2^-25 in the subnormal tail
                    assert!((a - b).abs()
                                <= b.abs() * 0.00048828125 + 3.1e-8,
                            "f16 storage error contract violated: \
                             {b} -> {a}");
                }
            }
        }
        assert!(changed, "f16 rounding should perturb random weights");
        // quantization is idempotent: loading twice changes nothing
        let once = params.clone();
        engine.load_weights(&mut params);
        for (sa, sb) in params.tensors.iter().zip(&once.tensors) {
            for (ta, tb) in sa.iter().zip(sb) {
                assert_eq!(ta.data, tb.data);
            }
        }
    }

    #[test]
    fn inspect_shows_per_layer_conditioning() {
        let engine = Engine::native().unwrap();
        let table = engine.flow("cond_lingauss2d").unwrap().inspect().unwrap();
        assert!(table.contains("(conditional)"), "{table}");
        assert!(table.contains("cond [128, 2]"), "{table}");
        assert!(table.contains("condcpl"), "{table}");
        let table = engine.flow("realnvp2d").unwrap().inspect().unwrap();
        assert!(table.contains("cond None"), "{table}");
        assert!(table.contains("cond -"), "{table}");
    }
}
