//! The paper's evaluation figures as reproducible table printers, shared
//! by `invertnet bench figN` and the `benches/` binaries.
//!
//! * Fig. 1 — peak training memory vs spatial image size (GLOW, 3 input
//!   channels, batch 8): invertible (InvertibleNetworks.jl) vs stored
//!   (PyTorch/normflows). Paper result: normflows OOMs at 480x480 on a
//!   40 GB A100; InvertibleNetworks.jl trains beyond 1024x1024.
//! * Fig. 2 — peak training memory vs depth (64x64): invertible is flat,
//!   stored grows linearly.
//!
//! Rows marked `measured` ran a real training step under the byte-exact
//! [`MemoryLedger`]; rows marked `model` come from the planner, which
//! `tests/memory_model.rs` pins byte-for-byte to measured rows.

use anyhow::Result;

use crate::api::Engine;
use crate::coordinator::planner::{glow_flat_shape_def, predict_peak_sched};
use crate::coordinator::{ActivationSchedule, ExecMode};
use crate::data::synth_images;
use crate::util::bench::fmt_bytes;
use crate::util::rng::Pcg64;
use crate::MemoryLedger;

const GB: f64 = 1024.0 * 1024.0 * 1024.0;

/// Measure one real training step's peak scheduling bytes under any
/// activation schedule; Err(oom) if the budget is exceeded.
pub fn measure_peak(engine: &Engine, net: &str,
                    schedule: &dyn ActivationSchedule,
                    budget: Option<u64>) -> Result<i64> {
    let ledger = match budget {
        Some(b) => MemoryLedger::with_budget(b),
        None => MemoryLedger::new(),
    };
    let flow = engine.flow_with_ledger(net, ledger)?;
    let params = flow.init_params(42)?;
    let s = &flow.def.in_shape;
    let mut rng = Pcg64::new(99);
    let x = synth_images(s[0], s[1], s[2], s[3], &mut rng);
    let result = flow.train_step(&x, None, &params, schedule)?;
    Ok(result.peak_sched_bytes)
}

fn fmt_cell(r: &Result<i64>) -> String {
    match r {
        Ok(b) => fmt_bytes(*b as u64),
        Err(e) if e.to_string().contains("OOM") => "OOM".to_string(),
        Err(e) => format!("error: {e:#}"),
    }
}

/// Fig. 1: memory vs spatial size, GLOW K=16 steps, 3 channels, batch 8.
pub fn fig1(engine: &Engine, budget_gb: f64) -> Result<()> {
    let budget = (budget_gb * GB) as u64;
    println!("# Fig. 1 — peak training memory vs image size");
    println!("# GLOW (Haar squeeze + 16 x [actnorm, conv1x1, affine coupling]), \
              3 channels, batch 8");
    println!("# budget {budget_gb} GB (paper: 40 GB A100; normflows OOM at 480x480)");
    println!("{:>6} {:>10} {:>14} {:>14} {:>8}",
             "size", "kind", "invertible", "stored(AD)", "ratio");
    // the RefBackend executes these on host CPU: keep the measured sweep
    // to sizes that finish interactively, model the rest
    let measured: &[usize] = if engine.backend_name() == "ref" {
        &[16, 32, 64]
    } else {
        &[16, 32, 64, 128, 256]
    };
    for &hw in measured {
        let net = format!("glow_fig1_{hw}");
        let inv = measure_peak(engine, &net, &ExecMode::Invertible, Some(budget));
        let sto = measure_peak(engine, &net, &ExecMode::Stored, Some(budget));
        let ratio = match (&inv, &sto) {
            (Ok(a), Ok(b)) if *a > 0 => format!("{:.1}x", *b as f64 / *a as f64),
            _ => "-".into(),
        };
        println!("{hw:>6} {:>10} {:>14} {:>14} {ratio:>8}",
                 "measured", fmt_cell(&inv), fmt_cell(&sto));
        engine.clear_cache(); // keep compiled executables out of later configs
    }
    // planner extension to the paper's full range (skipping measured sizes)
    for hw in [128usize, 256, 384, 480, 512, 768, 1024, 1536, 2048, 3072, 4096]
        .into_iter().filter(|hw| !measured.contains(hw))
    {
        let def = glow_flat_shape_def(8, hw, hw, 3, 16);
        let inv = predict_peak_sched(&def, ExecMode::Invertible);
        let sto = predict_peak_sched(&def, ExecMode::Stored);
        let show = |b: i64| if b as u64 > budget {
            format!("OOM({})", fmt_bytes(b as u64))
        } else {
            fmt_bytes(b as u64)
        };
        println!("{hw:>6} {:>10} {:>14} {:>14} {:>8}",
                 "model", show(inv), show(sto),
                 format!("{:.1}x", sto as f64 / inv as f64));
    }
    println!("# paper shape check: stored grows O(N^2) and crosses the budget \
              (paper: at 480^2 with normflows' op-level tape, which stores \
              ~38x more bytes/layer than this coordinator-level baseline — \
              see EXPERIMENTS.md); invertible stays far below budget everywhere");
    Ok(())
}

/// Fig. 2: memory vs network depth at 64x64.
pub fn fig2(engine: &Engine, budget_gb: f64) -> Result<()> {
    let budget = (budget_gb * GB) as u64;
    println!("# Fig. 2 — peak training memory vs depth (GLOW steps K), 64x64x3, batch 8");
    println!("{:>6} {:>10} {:>14} {:>14} {:>8}",
             "depth", "kind", "invertible", "stored(AD)", "ratio");
    let measured: &[usize] = if engine.backend_name() == "ref" {
        &[2, 4, 8, 16]
    } else {
        &[2, 4, 8, 16, 32, 48]
    };
    for &k in measured {
        let net = format!("glow_fig2_d{k}");
        let inv = measure_peak(engine, &net, &ExecMode::Invertible, Some(budget));
        let sto = measure_peak(engine, &net, &ExecMode::Stored, Some(budget));
        let ratio = match (&inv, &sto) {
            (Ok(a), Ok(b)) if *a > 0 => format!("{:.1}x", *b as f64 / *a as f64),
            _ => "-".into(),
        };
        println!("{k:>6} {:>10} {:>14} {:>14} {ratio:>8}",
                 "measured", fmt_cell(&inv), fmt_cell(&sto));
        engine.clear_cache();
    }
    // model extension to very deep nets (skipping measured depths)
    for k in [32usize, 48, 96, 192].into_iter()
        .filter(|k| !measured.contains(k))
    {
        let def = glow_flat_shape_def(8, 64, 64, 3, k);
        let inv = predict_peak_sched(&def, ExecMode::Invertible);
        let sto = predict_peak_sched(&def, ExecMode::Stored);
        println!("{k:>6} {:>10} {:>14} {:>14} {:>8}",
                 "model", fmt_bytes(inv as u64), fmt_bytes(sto as u64),
                 format!("{:.1}x", sto as f64 / inv as f64));
    }
    println!("# paper shape check: invertible flat in depth; stored linear in depth");
    Ok(())
}
