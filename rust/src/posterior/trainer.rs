//! The amortized-objective training driver: stream simulator minibatches
//! through the existing (data-parallel) train path instead of a fixed
//! dataset.
//!
//! Amortized variational inference trains the conditional flow on fresh
//! (x, y) draws every step — the "dataset" is the simulator itself, so
//! there is no epoch structure and no risk of memorizing a finite training
//! set. A held-out eval split (drawn once, from a separate stream) feeds
//! the train loop's `eval_nll` model-selection signal.

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::api::Flow;
use crate::coordinator::{ActivationSchedule, ExecMode};
use crate::flow::ParamStore;
use crate::train::{train, Adam, GradClip, TrainConfig, TrainReport};
use crate::util::rng::Pcg64;

use super::simulator::Simulator;

/// Stream tag xor-ed into the seed for the training data stream.
const TRAIN_STREAM: u64 = 0x5e1f_7ea1;
/// Stream tag for the held-out eval split (disjoint from training data).
const EVAL_STREAM: u64 = 0xe7a1_0b5e;

/// Knobs for [`amortized_train`] (CLI: `invertnet posterior-train`).
pub struct PosteriorTrainConfig {
    pub steps: usize,
    pub lr: f32,
    /// Seeds parameter init and both data streams.
    pub seed: u64,
    /// Eval-split scoring cadence (steps); 0 disables the eval split.
    pub eval_every: usize,
    /// Eval-split size in canonical batches; 0 also disables the eval
    /// split (matching `train --eval-batches 0`).
    pub eval_batches: usize,
    pub schedule: Arc<dyn ActivationSchedule>,
    pub clip: Option<GradClip>,
    pub log_every: usize,
    pub out_dir: Option<PathBuf>,
    pub quiet: bool,
    pub threads: usize,
    pub microbatch: Option<usize>,
}

impl Default for PosteriorTrainConfig {
    fn default() -> Self {
        PosteriorTrainConfig {
            steps: 500,
            lr: 3e-3,
            seed: 42,
            eval_every: 50,
            eval_batches: 1,
            schedule: Arc::new(ExecMode::Invertible),
            clip: Some(GradClip { max_norm: 50.0 }),
            log_every: 50,
            out_dir: None,
            quiet: false,
            threads: 1,
            microbatch: None,
        }
    }
}

/// The flow must be a conditional dense network whose input/cond widths
/// match the simulator's (x, y) pair widths.
pub fn check_sim_matches_flow(sim: &Simulator, flow: &Flow) -> Result<()> {
    let def = &flow.def;
    if def.in_shape.len() != 2 || def.in_shape[1] != sim.x_dim() {
        bail!("network {} input {:?} does not match simulator {} x rows \
               (n, {})", def.name, def.in_shape, sim.name(), sim.x_dim());
    }
    match &def.cond_shape {
        None => bail!("network {} takes no cond — amortized training needs \
                       a conditional network (e.g. {})",
                      def.name, sim.default_net()),
        Some(c) if c.len() != 2 || c[1] != sim.y_dim() => {
            bail!("network {} cond {:?} does not match simulator {} y rows \
                   (n, {})", def.name, c, sim.name(), sim.y_dim())
        }
        Some(_) => Ok(()),
    }
}

/// Train `flow` as an amortized posterior sampler for `sim`: every step
/// draws a fresh (x, y) minibatch from the simulator and feeds it through
/// [`crate::train::train`] (which routes through the data-parallel trainer
/// when `threads > 1`).
pub fn amortized_train(
    flow: &Flow,
    params: &mut ParamStore,
    sim: &Simulator,
    cfg: &PosteriorTrainConfig,
) -> Result<TrainReport> {
    check_sim_matches_flow(sim, flow)?;
    let batch = flow.batch();
    let mut opt = Adam::new(cfg.lr);

    let eval_set = if cfg.eval_every > 0 && cfg.eval_batches > 0 {
        let n = batch * cfg.eval_batches;
        let mut erng = Pcg64::new(cfg.seed ^ EVAL_STREAM);
        let (x, y) = sim.sample_pairs(n, &mut erng)
            .context("drawing the eval split")?;
        Some((x, Some(y)))
    } else {
        None
    };

    let tcfg = TrainConfig {
        steps: cfg.steps,
        schedule: cfg.schedule.clone(),
        clip: cfg.clip,
        log_every: cfg.log_every,
        out_dir: cfg.out_dir.clone(),
        quiet: cfg.quiet,
        threads: cfg.threads,
        microbatch: cfg.microbatch,
        eval_set,
        eval_every: cfg.eval_every,
        slow_step_ms: None,
    };

    let mut rng = Pcg64::new(cfg.seed ^ TRAIN_STREAM);
    train(flow, params, &mut opt, &tcfg, |_| {
        let (x, y) = sim.sample_pairs(batch, &mut rng)?;
        Ok((x, Some(y)))
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::Engine;

    #[test]
    fn sim_flow_compatibility_is_validated() {
        let engine = Engine::native().unwrap();
        let lg = Simulator::parse("linear-gaussian").unwrap();
        let den = Simulator::parse("denoise").unwrap();
        let inp = Simulator::parse("inpaint").unwrap();

        let cond2d = engine.flow("cond_lingauss2d").unwrap();
        assert!(check_sim_matches_flow(&lg, &cond2d).is_ok());
        // wrong x width
        assert!(check_sim_matches_flow(&den, &cond2d).is_err());
        // unconditional net
        let plain = engine.flow("realnvp2d").unwrap();
        assert!(check_sim_matches_flow(&lg, &plain).is_err());
        // wrong cond width (denoise net has dcond 16, inpaint needs 32)
        let dnet = engine.flow("cond_denoise16").unwrap();
        assert!(check_sim_matches_flow(&den, &dnet).is_ok());
        assert!(check_sim_matches_flow(&inp, &dnet).is_err());
        let inet = engine.flow("cond_inpaint16").unwrap();
        assert!(check_sim_matches_flow(&inp, &inet).is_ok());
    }

    #[test]
    fn a_few_amortized_steps_run_and_report_eval_nll() {
        let engine = Engine::native().unwrap();
        let flow = engine.flow("cond_lingauss2d").unwrap();
        let mut params = flow.init_params(7).unwrap();
        let sim = Simulator::parse("linear-gaussian").unwrap();
        let cfg = PosteriorTrainConfig {
            steps: 3,
            eval_every: 2,
            quiet: true,
            log_every: usize::MAX,
            ..PosteriorTrainConfig::default()
        };
        let report = amortized_train(&flow, &mut params, &sim, &cfg).unwrap();
        assert_eq!(report.losses.len(), 3);
        assert!(report.final_loss.is_finite());
        let nll = report.eval_nll.expect("eval split was configured");
        assert!(nll.is_finite());
    }

    #[test]
    fn eval_split_can_be_disabled() {
        let engine = Engine::native().unwrap();
        let flow = engine.flow("cond_lingauss2d").unwrap();
        let mut params = flow.init_params(8).unwrap();
        let sim = Simulator::parse("linear-gaussian").unwrap();
        let cfg = PosteriorTrainConfig {
            steps: 2,
            eval_every: 0,
            quiet: true,
            log_every: usize::MAX,
            ..PosteriorTrainConfig::default()
        };
        let report = amortized_train(&flow, &mut params, &sim, &cfg).unwrap();
        assert!(report.eval_nll.is_none());
        // --eval-batches 0 disables it too (same contract as plain train)
        let cfg = PosteriorTrainConfig {
            steps: 2,
            eval_batches: 0,
            quiet: true,
            log_every: usize::MAX,
            ..PosteriorTrainConfig::default()
        };
        let report = amortized_train(&flow, &mut params, &sim, &cfg).unwrap();
        assert!(report.eval_nll.is_none());
    }
}
