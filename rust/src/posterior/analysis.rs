//! Posterior sampling, uncertainty maps and calibration diagnostics.
//!
//! Everything here consumes a *trained conditional flow* as an amortized
//! posterior sampler: tile one observation y across a conditioning batch,
//! transport latent draws through the inverse, and summarize the resulting
//! sample cloud. The two calibration diagnostics are the standard ones for
//! simulation-based inference:
//!
//! * **SBC rank statistics** (Talts et al. 2018): draw (x*, y) from the
//!   simulator, rank x* among L posterior draws given y; a calibrated
//!   sampler produces uniform ranks, checked with a chi-square test;
//! * **credible-interval coverage**: the central `level` interval of the
//!   posterior draws should contain x* a `level` fraction of the time.
//!
//! On [`crate::data::LinearGaussian`] the whole pipeline is validated
//! against the closed-form posterior (`tests/posterior.rs`).
//!
//! The serve-side `posterior` op follows the exact same path —
//! [`tile_observation`], latents from `Pcg64::new(seed)`, a batched
//! inverse, [`summarize`] — so its replies are bit-identical to
//! [`posterior_samples`] + [`summarize`] called in-process.

use anyhow::{bail, Result};

use crate::api::Flow;
use crate::coordinator::SampleOpts;
use crate::flow::ParamStore;
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

use super::simulator::Simulator;

/// Tile one observation row into an (n, len(y)) conditioning tensor.
pub fn tile_observation(y: &[f32], n: usize) -> Result<Tensor> {
    if y.is_empty() {
        bail!("observation y is empty");
    }
    if n == 0 {
        bail!("need n >= 1 posterior samples");
    }
    let mut data = Vec::with_capacity(n * y.len());
    for _ in 0..n {
        data.extend_from_slice(y);
    }
    Tensor::new(vec![n, y.len()], data)
}

/// Draw `n` posterior samples x ~ p(x | y) from an amortized conditional
/// flow. Latents come from `Pcg64::new(seed)`, which is the generator the
/// serve-side `posterior` op uses — both paths return bit-identical
/// samples for the same (y, n, temperature, seed).
pub fn posterior_samples(
    flow: &Flow,
    params: &ParamStore,
    y: &[f32],
    n: usize,
    temperature: f32,
    seed: u64,
) -> Result<Tensor> {
    let cond = tile_observation(y, n)?;
    flow.sample(params, SampleOpts::new(n, &mut Pcg64::new(seed))
                            .temperature(temperature)
                            .cond(&cond))
}

/// Pointwise posterior summary over a sample cloud: per-dimension mean
/// and (unbiased) standard-deviation maps — the paper's "uncertainty
/// image" for imaging problems.
#[derive(Debug, Clone, PartialEq)]
pub struct PosteriorSummary {
    /// Samples the summary was computed from.
    pub n: usize,
    /// Per-dimension posterior mean (the point estimate).
    pub mean: Vec<f32>,
    /// Per-dimension posterior std (the uncertainty map); zeros for n = 1.
    pub std: Vec<f32>,
}

/// Per-column f64 means of an (n, d...) tensor, accumulated row-major in
/// a fixed order — deterministic (equal input bits give equal output
/// bits), which the serve-side bit-identity contract relies on.
fn column_means(samples: &Tensor) -> Vec<f64> {
    let n = samples.batch();
    let d = samples.inner_len();
    let mut mean = vec![0.0f64; d];
    for row in samples.data.chunks(d) {
        for (m, &v) in mean.iter_mut().zip(row) {
            *m += v as f64;
        }
    }
    for m in &mut mean {
        *m /= n.max(1) as f64;
    }
    mean
}

/// Column-wise mean/std of an (n, d...) sample tensor (see
/// [`column_means`] for the determinism contract).
pub fn summarize(samples: &Tensor) -> PosteriorSummary {
    let n = samples.batch();
    let d = samples.inner_len();
    let mean = column_means(samples);
    let mut var = vec![0.0f64; d];
    if n > 1 {
        for row in samples.data.chunks(d) {
            for ((s, &v), m) in var.iter_mut().zip(row).zip(&mean) {
                let dv = v as f64 - m;
                *s += dv * dv;
            }
        }
        for s in &mut var {
            *s = (*s / (n - 1) as f64).sqrt();
        }
    }
    PosteriorSummary {
        n,
        mean: mean.iter().map(|&m| m as f32).collect(),
        std: var.iter().map(|&s| s as f32).collect(),
    }
}

/// Per-dimension quantiles at `probs` (linear interpolation between order
/// statistics, numpy's default scheme). Returns one row per prob, each of
/// `samples.inner_len()` values.
pub fn quantiles(samples: &Tensor, probs: &[f64]) -> Result<Vec<Vec<f32>>> {
    let n = samples.batch();
    let d = samples.inner_len();
    if n == 0 {
        bail!("quantiles need at least one sample");
    }
    for &p in probs {
        if !(0.0..=1.0).contains(&p) {
            bail!("quantile prob {p} outside [0, 1]");
        }
    }
    // a diverged flow can emit NaN/inf samples; that is a data condition,
    // not a programming error — report it instead of panicking mid-sort
    if let Some(bad) = samples.data.iter().find(|v| !v.is_finite()) {
        bail!("samples contain a non-finite value ({bad}); the model \
               likely diverged");
    }
    let mut out = vec![vec![0.0f32; d]; probs.len()];
    let mut col = vec![0.0f32; n];
    for j in 0..d {
        for (i, c) in col.iter_mut().enumerate() {
            *c = samples.data[i * d + j];
        }
        col.sort_unstable_by(f32::total_cmp);
        for (pi, &p) in probs.iter().enumerate() {
            let pos = p * (n - 1) as f64;
            let lo = pos.floor() as usize;
            let hi = pos.ceil() as usize;
            let frac = pos - lo as f64;
            out[pi][j] = (col[lo] as f64 * (1.0 - frac)
                          + col[hi] as f64 * frac) as f32;
        }
    }
    Ok(out)
}

/// Central credible interval at `level` (e.g. 0.9 -> the [5%, 95%]
/// quantile band), per dimension: returns (lo, hi) maps.
pub fn central_interval(samples: &Tensor, level: f64)
                        -> Result<(Vec<f32>, Vec<f32>)> {
    if !(0.0..1.0).contains(&level) || level <= 0.0 {
        bail!("credible level must be in (0, 1), got {level}");
    }
    let a = (1.0 - level) / 2.0;
    let qs = quantiles(samples, &[a, 1.0 - a])?;
    let mut it = qs.into_iter();
    Ok((it.next().unwrap(), it.next().unwrap()))
}

/// Sample mean vector and covariance matrix (f64; unbiased), for
/// validating against [`crate::data::LinearGaussian::posterior`].
pub fn sample_mean_cov(samples: &Tensor) -> (Vec<f64>, Vec<Vec<f64>>) {
    let n = samples.batch();
    let d = samples.inner_len();
    let mu = column_means(samples);
    let mut cov = vec![vec![0.0f64; d]; d];
    if n > 1 {
        for row in samples.data.chunks(d) {
            for i in 0..d {
                let di = row[i] as f64 - mu[i];
                for j in 0..d {
                    cov[i][j] += di * (row[j] as f64 - mu[j]);
                }
            }
        }
        for r in &mut cov {
            for v in r.iter_mut() {
                *v /= (n - 1) as f64;
            }
        }
    }
    (mu, cov)
}

/// Calibration diagnostics for an amortized posterior sampler.
#[derive(Debug, Clone)]
pub struct Calibration {
    pub datasets: usize,
    /// Posterior draws per dataset; ranks take values 0..=draws.
    pub draws: usize,
    /// Histogram bins for the chi-square uniformity test.
    pub bins: usize,
    /// Credible level the coverage was measured at.
    pub level: f64,
    /// `ranks[dim][dataset]`: rank of the true x among the draws.
    pub ranks: Vec<Vec<usize>>,
    /// Chi-square uniformity statistic per dimension (df = bins - 1).
    pub chi2: Vec<f64>,
    /// Fraction of datasets whose truth fell inside the central `level`
    /// interval, per dimension.
    pub coverage: Vec<f64>,
}

impl Calibration {
    /// Degrees of freedom of the per-dimension chi-square statistics.
    pub fn df(&self) -> usize {
        self.bins.saturating_sub(1)
    }

    pub fn worst_chi2(&self) -> f64 {
        self.chi2.iter().cloned().fold(0.0, f64::max)
    }

    /// Largest |coverage - level| across dimensions.
    pub fn worst_coverage_gap(&self) -> f64 {
        self.coverage.iter()
            .map(|c| (c - self.level).abs())
            .fold(0.0, f64::max)
    }
}

/// Run SBC + coverage against `sim`. `post(y, draws, rng)` must return a
/// `(draws, x_dim)` tensor of posterior samples for observation row `y` —
/// pass a closure over a trained flow, or over the analytic oracle to
/// validate the diagnostics themselves.
pub fn calibrate(
    sim: &Simulator,
    datasets: usize,
    draws: usize,
    level: f64,
    bins: usize,
    rng: &mut Pcg64,
    mut post: impl FnMut(&[f32], usize, &mut Pcg64) -> Result<Tensor>,
) -> Result<Calibration> {
    if datasets == 0 || draws == 0 {
        bail!("calibrate needs datasets >= 1 and draws >= 1");
    }
    if bins < 2 || bins > draws + 1 {
        bail!("bins must be in 2..=draws+1 (got bins {bins}, draws {draws})");
    }
    let d = sim.x_dim();
    let mut ranks = vec![Vec::with_capacity(datasets); d];
    let mut inside = vec![0usize; d];
    for _ in 0..datasets {
        let (truth, y) = sim.sample_pairs(1, rng)?;
        let samples = post(&y.data, draws, rng)?;
        if samples.batch() != draws || samples.inner_len() != d {
            bail!("posterior sampler returned shape {:?}, want ({draws}, {d})",
                  samples.shape);
        }
        let (lo, hi) = central_interval(&samples, level)?;
        for dim in 0..d {
            let t = truth.data[dim];
            let r = (0..draws)
                .filter(|&i| samples.data[i * d + dim] < t)
                .count();
            ranks[dim].push(r);
            if lo[dim] <= t && t <= hi[dim] {
                inside[dim] += 1;
            }
        }
    }
    let chi2 = ranks.iter()
        .map(|r| chi_square_uniform(r, draws, bins))
        .collect();
    let coverage = inside.iter()
        .map(|&c| c as f64 / datasets as f64)
        .collect();
    Ok(Calibration { datasets, draws, bins, level, ranks, chi2, coverage })
}

/// Chi-square statistic for uniformity of SBC ranks (values 0..=draws)
/// over `bins` bins. Under a calibrated sampler this is approximately
/// chi-square with `bins - 1` degrees of freedom.
///
/// When `bins` does not divide `draws + 1` the rank-value bins have
/// unequal widths, so each bin's expected count is proportional to the
/// number of rank values it covers — a flat `n / bins` expectation would
/// inflate the statistic for a perfectly calibrated sampler.
pub fn chi_square_uniform(ranks: &[usize], draws: usize, bins: usize) -> f64 {
    let values = draws + 1;
    let mut counts = vec![0usize; bins];
    for &r in ranks {
        counts[(r * bins / values).min(bins - 1)] += 1;
    }
    let mut width = vec![0usize; bins];
    for v in 0..values {
        width[(v * bins / values).min(bins - 1)] += 1;
    }
    let n = ranks.len() as f64;
    counts.iter().zip(&width)
        .map(|(&c, &w)| {
            if w == 0 {
                // only reachable for bins > draws + 1; such a bin can
                // hold no ranks either, so it contributes nothing
                0.0
            } else {
                let e = n * w as f64 / values as f64;
                let d = c as f64 - e;
                d * d / e
            }
        })
        .sum()
}

/// Upper-tail chi-square critical value via the Wilson–Hilferty cube
/// approximation (good to ~1% for df >= 3, plenty for pass/fail
/// calibration gates).
pub fn chi2_crit(df: usize, alpha: f64) -> f64 {
    let df = df.max(1) as f64;
    // clamp extreme significance levels: 1.0 - 1e-20 rounds to exactly
    // 1.0 in f64, which would trip inv_norm_cdf's open-interval domain
    let alpha = alpha.clamp(1e-12, 1.0 - 1e-12);
    let z = inv_norm_cdf(1.0 - alpha);
    let t = 1.0 - 2.0 / (9.0 * df) + z * (2.0 / (9.0 * df)).sqrt();
    df * t * t * t
}

/// Inverse standard-normal CDF (Acklam's rational approximation, max
/// relative error ~1.15e-9).
pub fn inv_norm_cdf(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "inv_norm_cdf needs p in (0, 1), got {p}");
    const A: [f64; 6] = [-3.969683028665376e+01, 2.209460984245205e+02,
                         -2.759285104469687e+02, 1.383577518672690e+02,
                         -3.066479806614716e+01, 2.506628277459239e+00];
    const B: [f64; 5] = [-5.447609879822406e+01, 1.615858368580409e+02,
                         -1.556989798598866e+02, 6.680131188771972e+01,
                         -1.328068155288572e+01];
    const C: [f64; 6] = [-7.784894002430293e-03, -3.223964580411365e-01,
                         -2.400758277161838e+00, -2.549732539343734e+00,
                         4.374664141464968e+00, 2.938163982698783e+00];
    const D: [f64; 4] = [7.784695709041462e-03, 3.224671290700398e-01,
                         2.445134137142996e+00, 3.754408661907416e+00];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5])
            * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r
               + 1.0)
    } else {
        -inv_norm_cdf(1.0 - p)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud(rows: &[[f32; 2]]) -> Tensor {
        Tensor::new(vec![rows.len(), 2],
                    rows.iter().flatten().copied().collect()).unwrap()
    }

    #[test]
    fn tile_repeats_the_observation() {
        let t = tile_observation(&[1.0, -2.0], 3).unwrap();
        assert_eq!(t.shape, vec![3, 2]);
        assert_eq!(t.data, vec![1.0, -2.0, 1.0, -2.0, 1.0, -2.0]);
        assert!(tile_observation(&[], 3).is_err());
        assert!(tile_observation(&[1.0], 0).is_err());
    }

    #[test]
    fn summarize_mean_and_std() {
        let s = summarize(&cloud(&[[0.0, 1.0], [2.0, 1.0], [4.0, 1.0]]));
        assert_eq!(s.n, 3);
        assert_eq!(s.mean, vec![2.0, 1.0]);
        assert!((s.std[0] - 2.0).abs() < 1e-6); // unbiased: var 4
        assert_eq!(s.std[1], 0.0);
        // n = 1: std map is all zeros, not NaN
        let s = summarize(&cloud(&[[5.0, -1.0]]));
        assert_eq!(s.std, vec![0.0, 0.0]);
    }

    #[test]
    fn quantiles_interpolate_linearly() {
        let t = Tensor::new(vec![5, 1], vec![4.0, 0.0, 2.0, 1.0, 3.0]).unwrap();
        let q = quantiles(&t, &[0.0, 0.5, 1.0, 0.25]).unwrap();
        assert_eq!(q[0], vec![0.0]);
        assert_eq!(q[1], vec![2.0]);
        assert_eq!(q[2], vec![4.0]);
        assert_eq!(q[3], vec![1.0]);
        assert!(quantiles(&t, &[1.5]).is_err());
        let (lo, hi) = central_interval(&t, 0.5).unwrap();
        assert_eq!((lo[0], hi[0]), (1.0, 3.0));
        assert!(central_interval(&t, 1.0).is_err());
    }

    #[test]
    fn non_finite_samples_error_instead_of_panicking() {
        // a diverged flow's NaN must surface as Err, not a sort panic
        let t = Tensor::new(vec![3, 1], vec![1.0, f32::NAN, 2.0]).unwrap();
        let err = quantiles(&t, &[0.5]).unwrap_err();
        assert!(format!("{err:#}").contains("non-finite"), "{err:#}");
        assert!(central_interval(&t, 0.9).is_err());
    }

    #[test]
    fn mean_cov_matches_hand_computation() {
        let (mu, cov) = sample_mean_cov(&cloud(
            &[[1.0, 0.0], [3.0, 4.0], [2.0, 2.0]]));
        assert!((mu[0] - 2.0).abs() < 1e-12);
        assert!((mu[1] - 2.0).abs() < 1e-12);
        assert!((cov[0][0] - 1.0).abs() < 1e-9);
        assert!((cov[1][1] - 4.0).abs() < 1e-9);
        assert!((cov[0][1] - 2.0).abs() < 1e-9);
        assert_eq!(cov[0][1], cov[1][0]);
    }

    #[test]
    fn chi_square_flags_nonuniform_ranks() {
        // perfectly uniform ranks over 0..=63 -> statistic 0
        let uniform: Vec<usize> = (0..64).collect();
        assert!(chi_square_uniform(&uniform, 63, 8) < 1e-9);
        // all mass in one bin -> huge statistic
        let spike = vec![0usize; 64];
        assert!(chi_square_uniform(&spike, 63, 8) > 100.0);
    }

    #[test]
    fn chi_square_handles_unequal_bin_widths() {
        // draws = 8 -> 9 rank values over 8 bins: bin 0 covers {0, 1}.
        // one of each rank value is a perfectly proportional draw, so
        // the statistic must be exactly central (0), not inflated
        let proportional: Vec<usize> = (0..=8).collect();
        assert!(chi_square_uniform(&proportional, 8, 8) < 1e-9,
                "{}", chi_square_uniform(&proportional, 8, 8));
        // and a spike still registers
        assert!(chi_square_uniform(&[4usize; 9], 8, 8) > 20.0);
    }

    #[test]
    fn chi2_crit_matches_tables() {
        // textbook values: chi2(df=7): 14.07 @ 0.05, 24.32 @ 0.001
        assert!((chi2_crit(7, 0.05) - 14.07).abs() < 0.2);
        assert!((chi2_crit(7, 0.001) - 24.32).abs() < 0.5);
        assert!((chi2_crit(9, 0.05) - 16.92).abs() < 0.2);
        // extreme alphas clamp instead of panicking in inv_norm_cdf
        let tiny = chi2_crit(7, 1e-300);
        assert!(tiny.is_finite() && tiny > chi2_crit(7, 1e-4));
        assert!(chi2_crit(7, 1.0 - 1e-300).is_finite());
    }

    #[test]
    fn inv_norm_cdf_matches_tables() {
        assert!(inv_norm_cdf(0.5).abs() < 1e-9);
        assert!((inv_norm_cdf(0.975) - 1.959964).abs() < 1e-5);
        assert!((inv_norm_cdf(0.999) - 3.090232).abs() < 1e-5);
        assert!((inv_norm_cdf(0.025) + 1.959964).abs() < 1e-5);
        // tail branch
        assert!((inv_norm_cdf(1e-4) + 3.719016).abs() < 1e-4);
    }

    #[test]
    fn calibrate_validates_its_inputs() {
        let sim = Simulator::parse("linear-gaussian").unwrap();
        let mut rng = Pcg64::new(1);
        let bad = calibrate(&sim, 0, 8, 0.9, 4, &mut rng,
                            |_, _, _| unreachable!());
        assert!(bad.is_err());
        let bad = calibrate(&sim, 4, 8, 0.9, 100, &mut rng,
                            |_, _, _| unreachable!());
        assert!(bad.is_err());
        // a sampler returning the wrong shape is rejected
        let bad = calibrate(&sim, 2, 8, 0.9, 4, &mut rng,
                            |_, _, _| Ok(Tensor::zeros(&[8, 5])));
        assert!(bad.is_err());
    }
}
