//! Synthetic inverse problems for amortized posterior training.
//!
//! Each simulator is a joint distribution over (x, y): draw a latent
//! "ground truth" x from its prior, then a synthetic observation y from
//! the forward model. Training a conditional flow on a stream of such
//! pairs amortizes Bayesian inference — after training, inverting the
//! flow at a fixed y transports N(0, I) to p(x | y) (Papamakarios et al.
//! 2019; the paper's seismic/medical imaging applications all follow this
//! pattern).
//!
//! The catalog covers the paper's imaging motifs at toy scale, over the
//! textured-blob fields of [`crate::data::synth_images`] flattened to
//! feature rows:
//!
//! * `denoise`  — additive white noise: y = x + sigma * eps;
//! * `deblur`   — gaussian-blur deconvolution: y = G x + sigma * eps;
//! * `inpaint`  — random-mask inpainting: y = [x .* m ; m];
//! * `linear-gaussian` — the [`crate::data::LinearGaussian`] problem,
//!   whose **closed-form Gaussian posterior** makes it the end-to-end
//!   correctness oracle for the whole subsystem (see
//!   [`crate::posterior::analysis`]).

use anyhow::{bail, Result};

use crate::data::{synth_images, LinearGaussian};
use crate::tensor::Tensor;
use crate::util::rng::Pcg64;

/// Side length of the image-based simulators' square fields.
pub const IMG_SIDE: usize = 4;
/// Feature width of the image-based simulators (IMG_SIDE^2, one channel).
pub const IMG_DIM: usize = IMG_SIDE * IMG_SIDE;

/// Observation-noise scale for the denoise/deblur simulators.
const NOISE_SIGMA: f64 = 0.2;
/// Per-pixel keep probability for the inpainting mask.
const KEEP_PROB: f64 = 0.7;

/// A catalog entry: a named (x, y) pair generator.
pub enum Simulator {
    /// y = A x + eps with the analytic posterior oracle.
    LinearGaussian(LinearGaussian),
    /// y = x + sigma * eps over flattened textured-blob fields.
    Denoise,
    /// y = blur(x) + sigma * eps (3x3 binomial kernel, renormalized at
    /// the edges).
    Deblur,
    /// y = [x .* m ; m] for a Bernoulli keep-mask m (the mask is part of
    /// the observation, as in masked-acquisition imaging).
    Inpaint,
}

impl Simulator {
    pub fn parse(name: &str) -> Result<Simulator> {
        Ok(match name {
            "linear-gaussian" | "lg" => {
                Simulator::LinearGaussian(LinearGaussian::default_problem())
            }
            "denoise" => Simulator::Denoise,
            "deblur" => Simulator::Deblur,
            "inpaint" => Simulator::Inpaint,
            other => bail!("unknown simulator {other:?} \
                            (linear-gaussian|denoise|deblur|inpaint)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Simulator::LinearGaussian(_) => "linear-gaussian",
            Simulator::Denoise => "denoise",
            Simulator::Deblur => "deblur",
            Simulator::Inpaint => "inpaint",
        }
    }

    /// Feature width of the latent x rows.
    pub fn x_dim(&self) -> usize {
        match self {
            Simulator::LinearGaussian(_) => 2,
            _ => IMG_DIM,
        }
    }

    /// Feature width of the observation y rows.
    pub fn y_dim(&self) -> usize {
        match self {
            Simulator::LinearGaussian(_) => 2,
            Simulator::Denoise | Simulator::Deblur => IMG_DIM,
            // observed pixels and the mask itself
            Simulator::Inpaint => 2 * IMG_DIM,
        }
    }

    /// The builtin conditional network sized for this simulator.
    pub fn default_net(&self) -> &'static str {
        match self {
            Simulator::LinearGaussian(_) => "cond_lingauss2d",
            Simulator::Denoise => "cond_denoise16",
            Simulator::Deblur => "cond_deblur16",
            Simulator::Inpaint => "cond_inpaint16",
        }
    }

    /// The analytic oracle, when this simulator has one.
    pub fn oracle(&self) -> Option<&LinearGaussian> {
        match self {
            Simulator::LinearGaussian(p) => Some(p),
            _ => None,
        }
    }

    /// Draw `n` (x, y) pairs: x with shape (n, x_dim), y with (n, y_dim).
    pub fn sample_pairs(&self, n: usize, rng: &mut Pcg64)
                        -> Result<(Tensor, Tensor)> {
        if n == 0 {
            bail!("sample_pairs needs n >= 1");
        }
        match self {
            Simulator::LinearGaussian(p) => Ok(p.sample(n, rng)),
            Simulator::Denoise => {
                let x = flat_fields(n, rng);
                let y = Tensor {
                    shape: x.shape.clone(),
                    data: x.data.iter()
                        .map(|&v| v + (rng.normal() * NOISE_SIGMA) as f32)
                        .collect(),
                };
                Ok((x, y))
            }
            Simulator::Deblur => {
                let x = flat_fields(n, rng);
                let mut y = blur_rows(&x);
                for v in &mut y.data {
                    *v += (rng.normal() * NOISE_SIGMA) as f32;
                }
                Ok((x, y))
            }
            Simulator::Inpaint => {
                let x = flat_fields(n, rng);
                let mut y = Vec::with_capacity(n * 2 * IMG_DIM);
                for row in x.data.chunks(IMG_DIM) {
                    let mask: Vec<f32> = (0..IMG_DIM)
                        .map(|_| if rng.uniform() < KEEP_PROB { 1.0 } else { 0.0 })
                        .collect();
                    y.extend(row.iter().zip(&mask).map(|(v, m)| v * m));
                    y.extend_from_slice(&mask);
                }
                Ok((x, Tensor::new(vec![n, 2 * IMG_DIM], y)?))
            }
        }
    }
}

/// Textured-blob fields flattened to (n, IMG_DIM) feature rows — NHWC is
/// row-major, so reshaping is free.
fn flat_fields(n: usize, rng: &mut Pcg64) -> Tensor {
    let mut t = synth_images(n, IMG_SIDE, IMG_SIDE, 1, rng);
    t.shape = vec![n, IMG_DIM];
    t
}

/// 3x3 binomial blur ((1,2,1) x (1,2,1) / 16) over each IMG_SIDE^2 row,
/// with the kernel renormalized by its in-bounds weight at the edges so
/// the blur never darkens the border.
fn blur_rows(x: &Tensor) -> Tensor {
    let s = IMG_SIDE as i64;
    let mut out = vec![0.0f32; x.data.len()];
    for (r, row) in x.data.chunks(IMG_DIM).enumerate() {
        for i in 0..s {
            for j in 0..s {
                let mut acc = 0.0f64;
                let mut wsum = 0.0f64;
                for di in -1..=1i64 {
                    for dj in -1..=1i64 {
                        let (ii, jj) = (i + di, j + dj);
                        if ii < 0 || ii >= s || jj < 0 || jj >= s {
                            continue;
                        }
                        let w = ((2 - di.abs()) * (2 - dj.abs())) as f64;
                        acc += w * row[(ii * s + jj) as usize] as f64;
                        wsum += w;
                    }
                }
                out[r * IMG_DIM + (i * s + j) as usize] = (acc / wsum) as f32;
            }
        }
    }
    Tensor { shape: x.shape.clone(), data: out }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_dims() {
        for (name, dx, dy) in [("linear-gaussian", 2, 2),
                               ("denoise", IMG_DIM, IMG_DIM),
                               ("deblur", IMG_DIM, IMG_DIM),
                               ("inpaint", IMG_DIM, 2 * IMG_DIM)] {
            let s = Simulator::parse(name).unwrap();
            assert_eq!(s.name(), name);
            assert_eq!(s.x_dim(), dx);
            assert_eq!(s.y_dim(), dy);
        }
        assert!(Simulator::parse("warp").is_err());
    }

    #[test]
    fn pairs_have_declared_shapes() {
        for name in ["linear-gaussian", "denoise", "deblur", "inpaint"] {
            let s = Simulator::parse(name).unwrap();
            let mut rng = Pcg64::new(3);
            let (x, y) = s.sample_pairs(5, &mut rng).unwrap();
            assert_eq!(x.shape, vec![5, s.x_dim()], "{name}");
            assert_eq!(y.shape, vec![5, s.y_dim()], "{name}");
            assert!(x.data.iter().chain(&y.data).all(|v| v.is_finite()));
            assert!(s.sample_pairs(0, &mut rng).is_err());
        }
    }

    #[test]
    fn fixed_seed_is_bit_exact() {
        for name in ["linear-gaussian", "denoise", "deblur", "inpaint"] {
            let s = Simulator::parse(name).unwrap();
            let (xa, ya) = s.sample_pairs(4, &mut Pcg64::new(11)).unwrap();
            let (xb, yb) = s.sample_pairs(4, &mut Pcg64::new(11)).unwrap();
            assert_eq!(xa, xb, "{name} x drifted");
            assert_eq!(ya, yb, "{name} y drifted");
        }
    }

    #[test]
    fn denoise_observation_stays_near_truth() {
        let s = Simulator::parse("denoise").unwrap();
        let mut rng = Pcg64::new(9);
        let (x, y) = s.sample_pairs(64, &mut rng).unwrap();
        let mut sq = 0.0f64;
        for (a, b) in x.data.iter().zip(&y.data) {
            sq += ((a - b) as f64).powi(2);
        }
        let rms = (sq / x.data.len() as f64).sqrt();
        assert!((rms - NOISE_SIGMA).abs() < 0.05, "residual rms {rms}");
    }

    #[test]
    fn blur_preserves_constant_fields() {
        // edge renormalization means a constant field blurs to itself
        let x = Tensor::full(&[2, IMG_DIM], 0.37);
        let y = blur_rows(&x);
        for v in &y.data {
            assert!((v - 0.37).abs() < 1e-6, "{v}");
        }
    }

    #[test]
    fn inpaint_mask_is_consistent_with_observation() {
        let s = Simulator::parse("inpaint").unwrap();
        let mut rng = Pcg64::new(21);
        let (x, y) = s.sample_pairs(16, &mut rng).unwrap();
        let mut kept = 0usize;
        for (xr, yr) in x.data.chunks(IMG_DIM).zip(y.data.chunks(2 * IMG_DIM)) {
            let (obs, mask) = yr.split_at(IMG_DIM);
            for k in 0..IMG_DIM {
                assert!(mask[k] == 0.0 || mask[k] == 1.0);
                if mask[k] == 1.0 {
                    assert_eq!(obs[k], xr[k]);
                    kept += 1;
                } else {
                    assert_eq!(obs[k], 0.0);
                }
            }
        }
        let frac = kept as f64 / (16 * IMG_DIM) as f64;
        assert!((frac - KEEP_PROB).abs() < 0.15, "keep fraction {frac}");
    }
}
